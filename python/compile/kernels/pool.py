"""Pallas pooling kernels (max / global-average) used by the three CNNs.

SqueezeNet interleaves 3x3/s2 max-pools between Fire modules and ends with a
global average pool; MobileNetV2 / ShuffleNetV2 end with a global average
pool before the classifier. Same shifted-slice decomposition as conv2d, with
max / add as the reduction.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .conv2d import _out_dim, _pad_hw


def _maxpool_kernel(x_ref, o_ref, *, k: int, stride: int):
    _, ho, wo, c = o_ref.shape
    x = x_ref[0]
    acc = jnp.full((ho, wo, c), -jnp.inf, jnp.float32)
    for i in range(k):
        for j in range(k):
            xs = lax.slice(
                x,
                (i, j, 0),
                (i + (ho - 1) * stride + 1, j + (wo - 1) * stride + 1, c),
                (stride, stride, 1),
            )
            acc = jnp.maximum(acc, xs)
    o_ref[0] = acc


@functools.partial(jax.jit, static_argnames=("k", "stride", "padding"))
def maxpool(x: jnp.ndarray, *, k: int = 3, stride: int = 2, padding: int = 0) -> jnp.ndarray:
    """Max pooling. x: (N, H, W, C) f32. Pads with -inf semantics via 0-pad
    only when padding == 0 is not requested (SqueezeNet uses VALID pools)."""
    n, h, w_in, c = x.shape
    ho, wo = _out_dim(h, k, stride, padding), _out_dim(w_in, k, stride, padding)
    assert padding == 0, "paper's nets use VALID max-pools"

    return pl.pallas_call(
        functools.partial(_maxpool_kernel, k=k, stride=stride),
        grid=(n,),
        in_specs=[pl.BlockSpec((1, h, w_in, c), lambda b: (b, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, ho, wo, c), lambda b: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, ho, wo, c), jnp.float32),
        interpret=True,
    )(x)


def _gap_kernel(x_ref, o_ref):
    _, h, w, c = x_ref.shape
    o_ref[0] = jnp.sum(x_ref[0], axis=(0, 1)) * (1.0 / (h * w))


@jax.jit
def global_avgpool(x: jnp.ndarray) -> jnp.ndarray:
    """Global average pool. x: (N, H, W, C) -> (N, C)."""
    n, h, w_in, c = x.shape
    return pl.pallas_call(
        _gap_kernel,
        grid=(n,),
        in_specs=[pl.BlockSpec((1, h, w_in, c), lambda b: (b, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, c), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c), jnp.float32),
        interpret=True,
    )(x)
