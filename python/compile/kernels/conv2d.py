"""Pallas standard convolution kernel (NHWC), the paper's compute hot spot.

Hardware adaptation (DESIGN.md §4): the paper's CUDA kernels tile IFMs into
shared memory per threadblock; on TPU the analogue is an HBM->VMEM BlockSpec
schedule with the *weights pinned in VMEM across grid steps* (constant index
map) — the Pallas equivalent of DHM's "weights next to the MACs". The MAC
work is decomposed as

    conv(x, w) = sum_{i<kh, j<kw}  shift(x, i, j) @ w[i, j]

so every term is a dense (Ho*Wo, Ci) x (Ci, Co) matmul that maps onto the
MXU systolic array, instead of the scalar sliding-window form a direct CUDA
port would produce.

The grid iterates over the batch: one grid step streams one padded IFM
HBM->VMEM while the full weight tensor stays VMEM-resident (its index map
is constant, so Pallas fetches it once). Embedded-CNN layers are small
enough that IFM + weights fit VMEM (checked analytically in DESIGN.md
§Perf); overlapping row-tiling for larger-than-VMEM IFMs is a documented
extension, not expressible with Blocked index maps.

Kernels are lowered with ``interpret=True`` (CPU PJRT cannot run Mosaic
custom-calls); real-TPU VMEM/MXU figures are estimated in DESIGN.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from . import quant


def _out_dim(size: int, k: int, stride: int, pad: int) -> int:
    return (size + 2 * pad - k) // stride + 1


def _pad_hw(x: jnp.ndarray, pad: int) -> jnp.ndarray:
    if pad == 0:
        return x
    return jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))


def _conv_accum(x, w, ho: int, wo: int, stride: int, acc_dtype):
    """sum_{i,j} shifted-slice(x) @ w[i,j] for one IFM.

    x: (H_in, W_in, Ci) already padded; w: (kh, kw, Ci, Co).
    Returns (ho, wo, Co) in acc_dtype. Each term is an MXU-shaped matmul.
    """
    kh, kw, ci, co = w.shape
    acc = jnp.zeros((ho * wo, co), acc_dtype)
    for i in range(kh):
        for j in range(kw):
            xs = lax.slice(
                x,
                (i, j, 0),
                (i + (ho - 1) * stride + 1, j + (wo - 1) * stride + 1, ci),
                (stride, stride, 1),
            )  # (ho, wo, Ci)
            acc = acc + jnp.dot(
                xs.reshape(ho * wo, ci).astype(acc_dtype),
                w[i, j].astype(acc_dtype),
                preferred_element_type=acc_dtype,
            )
    return acc.reshape(ho, wo, co)


def _conv2d_kernel(x_ref, w_ref, o_ref, *, stride: int):
    """One grid step = one batch element; weights VMEM-resident."""
    _, ho, wo, _ = o_ref.shape
    o_ref[0] = _conv_accum(x_ref[0], w_ref[...], ho, wo, stride, jnp.float32)


# VMEM budget per pallas_call (bytes). Half of the ~16 MiB TensorCore VMEM,
# leaving headroom for double buffering — a call whose blocks exceed this is
# split into output-row BANDS at the wrapper level (each band is its own
# grid step sized to fit; the §Perf fix that made the 224x224 Fig-1 convs
# VMEM-feasible).
VMEM_BUDGET = 8 * 1024 * 1024


def _band_rows(h_in: int, w_in: int, ci: int, ho: int, wo: int, co: int,
               kh: int, kw: int, stride: int) -> int:
    """Output rows per band such that one band's blocks fit VMEM_BUDGET."""
    weight_bytes = kh * kw * ci * co * 4
    acc_bytes_per_row = wo * co * 4 * 2  # accumulator + output block
    in_bytes_per_row = w_in * ci * 4 * stride
    fixed = weight_bytes + (kh * w_in * ci * 4)  # halo rows
    budget = VMEM_BUDGET - fixed
    if budget <= 0:
        return 1
    rows = budget // (acc_bytes_per_row + in_bytes_per_row)
    return max(1, min(ho, int(rows)))


def _conv2d_call(xp, w, ho, wo, stride):
    """One pallas_call over a (possibly banded) padded input."""
    n, hp, wp, ci = xp.shape
    kh, kw, _, co = w.shape
    return pl.pallas_call(
        functools.partial(_conv2d_kernel, stride=stride),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, hp, wp, ci), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((kh, kw, ci, co), lambda b: (0, 0, 0, 0)),  # resident
        ],
        out_specs=pl.BlockSpec((1, ho, wo, co), lambda b: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, ho, wo, co), jnp.float32),
        interpret=True,
    )(xp, w)


@functools.partial(jax.jit, static_argnames=("stride", "padding"))
def conv2d(x: jnp.ndarray, w: jnp.ndarray, *, stride: int = 1, padding: int | None = None) -> jnp.ndarray:
    """Standard convolution. x: (N, H, W, Ci) f32, w: (kh, kw, Ci, Co) f32.

    ``padding=None`` means SAME-for-odd-kernels (pad = k//2); an int is an
    explicit symmetric spatial pad. Output: (N, Ho, Wo, Co) f32.

    Large IFMs are split into output-row bands so each pallas_call's VMEM
    working set stays under [`VMEM_BUDGET`] (bands overlap by the kh-stride
    halo; values are identical to the unbanded kernel).
    """
    n, h, w_in, ci = x.shape
    kh, kw, wci, co = w.shape
    assert wci == ci, f"channel mismatch: weight Ci={wci}, input Ci={ci}"
    pad = kh // 2 if padding is None else padding
    ho, wo = _out_dim(h, kh, stride, pad), _out_dim(w_in, kw, stride, pad)
    xp = _pad_hw(x, pad)

    hb = _band_rows(xp.shape[1], xp.shape[2], ci, ho, wo, co, kh, kw, stride)
    if hb >= ho:
        return _conv2d_call(xp, w, ho, wo, stride)

    bands = []
    r0 = 0
    while r0 < ho:
        rows = min(hb, ho - r0)
        in_lo = r0 * stride
        in_hi = (r0 + rows - 1) * stride + kh
        band = lax.slice(xp, (0, in_lo, 0, 0), (n, in_hi, xp.shape[2], ci))
        bands.append(_conv2d_call(band, w, rows, wo, stride))
        r0 += rows
    return jnp.concatenate(bands, axis=1)


def _conv2d_q_kernel(xq_ref, wq_ref, sx_ref, sw_ref, o_ref, *, stride: int):
    """int8 DHM datapath: int8 operands, int32 MAC accumulation, f32 rescale."""
    _, ho, wo, _ = o_ref.shape
    acc = _conv_accum(xq_ref[0], wq_ref[...], ho, wo, stride, jnp.int32)
    o_ref[0] = acc.astype(jnp.float32) * sx_ref[0] * sw_ref[0]


@functools.partial(jax.jit, static_argnames=("stride", "padding"))
def conv2d_q8(x: jnp.ndarray, w: jnp.ndarray, *, stride: int = 1, padding: int | None = None) -> jnp.ndarray:
    """8-bit fixed-point convolution — the arithmetic the FPGA DHM fabric runs.

    Quantizes activations and weights symmetrically (paper §I cites 8-bit as
    accuracy-safe [2]), performs the MAC array in int32 exactly as the DHM
    datapath does, and rescales to f32.
    """
    n, h, w_in, ci = x.shape
    kh, kw, _, co = w.shape
    pad = kh // 2 if padding is None else padding
    ho, wo = _out_dim(h, kh, stride, pad), _out_dim(w_in, kw, stride, pad)

    sx = quant.scale_for(x)
    sw = quant.scale_for(w)
    xq = quant.quantize(_pad_hw(x, pad), sx)
    wq = quant.quantize(w, sw)

    return pl.pallas_call(
        functools.partial(_conv2d_q_kernel, stride=stride),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, xq.shape[1], xq.shape[2], ci), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((kh, kw, ci, co), lambda b: (0, 0, 0, 0)),
            pl.BlockSpec((1,), lambda b: (0,)),
            pl.BlockSpec((1,), lambda b: (0,)),
        ],
        out_specs=pl.BlockSpec((1, ho, wo, co), lambda b: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, ho, wo, co), jnp.float32),
        interpret=True,
    )(xq, wq, sx.reshape(1), sw.reshape(1))
