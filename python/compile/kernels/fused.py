"""Pallas fused-layer kernel (paper §IV, Fig 2c — Fused-Layer partitioning).

The Fused-Layer strategy [Alwani et al. '16] keeps a chain of adjacent
layers resident on the FPGA: intermediate feature maps live in on-chip
memory and only the final OFM crosses PCIe. The Pallas analogue is a single
kernel whose intermediates are VMEM values that never round-trip to HBM —
one ``pallas_call`` for the whole chain instead of one per layer.

``fused_pw_dw_pw`` fuses the ShuffleNetV2 branch (1x1 -> dw3x3 -> 1x1) and
``fused_pw_pw`` the generic two-deep 1x1 chain; both exist in quantized
form because the fused chain runs on the DHM fabric in 8-bit fixed point.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import quant
from .conv2d import _out_dim
from .dwconv import _dw_accum


def _relu(v):
    return jnp.maximum(v, 0.0)


def _fused_pw_dw_pw_kernel(x_ref, w1_ref, wd_ref, w2_ref, o_ref, *, stride: int):
    """1x1(+relu) -> dw3x3 -> 1x1(+relu), intermediates VMEM-only."""
    _, h, w, ci = x_ref.shape
    _, ho, wo, co = o_ref.shape
    cm = w1_ref.shape[-1]

    # stage 1: point-wise expand + relu
    t = _relu(jnp.dot(x_ref[0].reshape(h * w, ci), w1_ref[...],
                      preferred_element_type=jnp.float32)).reshape(h, w, cm)
    # stage 2: depth-wise 3x3 (SAME pad) — pad in VMEM, never to HBM
    tp = jnp.pad(t, ((1, 1), (1, 1), (0, 0)))
    t = _dw_accum(tp, wd_ref[...], ho, wo, stride, jnp.float32)
    # stage 3: point-wise project + relu
    y = _relu(jnp.dot(t.reshape(ho * wo, cm), w2_ref[...],
                      preferred_element_type=jnp.float32))
    o_ref[0] = y.reshape(ho, wo, co)


@functools.partial(jax.jit, static_argnames=("stride",))
def fused_pw_dw_pw(x: jnp.ndarray, w1: jnp.ndarray, wd: jnp.ndarray, w2: jnp.ndarray, *, stride: int = 1) -> jnp.ndarray:
    """Fused 1x1 -> dw3x3(SAME) -> 1x1 chain.

    x: (N, H, W, Ci); w1: (Ci, Cm); wd: (3, 3, Cm); w2: (Cm, Co).
    """
    n, h, w_in, ci = x.shape
    _, cm = w1.shape
    _, co = w2.shape
    assert wd.shape == (3, 3, cm), f"dw weights {wd.shape} != (3,3,{cm})"
    ho, wo = _out_dim(h, 3, stride, 1), _out_dim(w_in, 3, stride, 1)

    return pl.pallas_call(
        functools.partial(_fused_pw_dw_pw_kernel, stride=stride),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, h, w_in, ci), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((ci, cm), lambda b: (0, 0)),
            pl.BlockSpec((3, 3, cm), lambda b: (0, 0, 0)),
            pl.BlockSpec((cm, co), lambda b: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, ho, wo, co), lambda b: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, ho, wo, co), jnp.float32),
        interpret=True,
    )(x, w1, wd, w2)


def _fused_pw_pw_kernel(x_ref, w1_ref, w2_ref, o_ref):
    _, h, w, ci = x_ref.shape
    co = o_ref.shape[-1]
    t = _relu(jnp.dot(x_ref[0].reshape(h * w, ci), w1_ref[...],
                      preferred_element_type=jnp.float32))
    y = _relu(jnp.dot(t, w2_ref[...], preferred_element_type=jnp.float32))
    o_ref[0] = y.reshape(h, w, co)


@jax.jit
def fused_pw_pw(x: jnp.ndarray, w1: jnp.ndarray, w2: jnp.ndarray) -> jnp.ndarray:
    """Fused 1x1(+relu) -> 1x1(+relu). x: (N,H,W,Ci); w1: (Ci,Cm); w2: (Cm,Co)."""
    n, h, w_in, ci = x.shape
    _, cm = w1.shape
    _, co = w2.shape

    return pl.pallas_call(
        _fused_pw_pw_kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, h, w_in, ci), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((ci, cm), lambda b: (0, 0)),
            pl.BlockSpec((cm, co), lambda b: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, w_in, co), lambda b: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h, w_in, co), jnp.float32),
        interpret=True,
    )(x, w1, w2)


def _fused_pw_pw_q_kernel(xq_ref, w1q_ref, w2q_ref, s_ref, o_ref):
    """Quantized fused chain: int8 MACs per stage, int8 re-quantized handoff.

    s_ref holds (sx, sw1, st, sw2): the inter-stage scale st is derived at
    trace time from a float dry-run, mirroring DHM calibration.
    """
    _, h, w, ci = xq_ref.shape
    co = o_ref.shape[-1]
    sx, sw1, st, sw2 = s_ref[0], s_ref[1], s_ref[2], s_ref[3]

    acc1 = jnp.dot(xq_ref[0].reshape(h * w, ci).astype(jnp.int32),
                   w1q_ref[...].astype(jnp.int32), preferred_element_type=jnp.int32)
    t = _relu(acc1.astype(jnp.float32) * sx * sw1)
    tq = jnp.clip(jnp.round(t / st), quant.QMIN, quant.QMAX).astype(jnp.int32)

    acc2 = jnp.dot(tq, w2q_ref[...].astype(jnp.int32), preferred_element_type=jnp.int32)
    y = _relu(acc2.astype(jnp.float32) * st * sw2)
    o_ref[0] = y.reshape(h, w, co)


@jax.jit
def fused_pw_pw_q8(x: jnp.ndarray, w1: jnp.ndarray, w2: jnp.ndarray) -> jnp.ndarray:
    """8-bit fixed-point fused 1x1 -> 1x1 chain (full DHM pipeline arithmetic)."""
    n, h, w_in, ci = x.shape
    _, cm = w1.shape
    _, co = w2.shape

    sx, sw1, sw2 = quant.scale_for(x), quant.scale_for(w1), quant.scale_for(w2)
    # calibrate the inter-stage scale from the float intermediate
    t_f = jnp.maximum(jnp.einsum("nhwc,cm->nhwm", x, w1), 0.0)
    st = quant.scale_for(t_f)
    scales = jnp.stack([sx, sw1, st, sw2])

    return pl.pallas_call(
        _fused_pw_pw_q_kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, h, w_in, ci), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((ci, cm), lambda b: (0, 0)),
            pl.BlockSpec((cm, co), lambda b: (0, 0)),
            pl.BlockSpec((4,), lambda b: (0,)),
        ],
        out_specs=pl.BlockSpec((1, h, w_in, co), lambda b: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h, w_in, co), jnp.float32),
        interpret=True,
    )(quant.quantize(x, sx), quant.quantize(w1, sw1), quant.quantize(w2, sw2), scales)
