"""Pallas depth-wise convolution kernel (paper §IV, DWConv building block).

MobileNetV2 / ShuffleNetV2's k x k depth-wise stage: each input channel is
convolved with its own k x k filter (channel multiplier 1). Decomposed as

    dwconv(x, w) = sum_{i<kh, j<kw}  shift(x, i, j) * w[i, j]      (per channel)

— VPU element-wise work rather than MXU matmuls; the paper's partitioning
keeps this stage on the GPU precisely because it is memory-bound, while the
1x1 point-wise stage (pwconv.py) goes to the FPGA.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from . import quant
from .conv2d import _out_dim, _pad_hw


def _dw_accum(x, w, ho: int, wo: int, stride: int, acc_dtype):
    """x: (H_in, W_in, C) padded; w: (kh, kw, C). Returns (ho, wo, C)."""
    kh, kw, c = w.shape
    acc = jnp.zeros((ho, wo, c), acc_dtype)
    for i in range(kh):
        for j in range(kw):
            xs = lax.slice(
                x,
                (i, j, 0),
                (i + (ho - 1) * stride + 1, j + (wo - 1) * stride + 1, c),
                (stride, stride, 1),
            )
            acc = acc + xs.astype(acc_dtype) * w[i, j].astype(acc_dtype)
    return acc


def _dwconv_kernel(x_ref, w_ref, o_ref, *, stride: int):
    _, ho, wo, _ = o_ref.shape
    o_ref[0] = _dw_accum(x_ref[0], w_ref[...], ho, wo, stride, jnp.float32)


@functools.partial(jax.jit, static_argnames=("stride", "padding"))
def dwconv(x: jnp.ndarray, w: jnp.ndarray, *, stride: int = 1, padding: int | None = None) -> jnp.ndarray:
    """Depth-wise convolution. x: (N, H, W, C) f32, w: (kh, kw, C) f32."""
    n, h, w_in, c = x.shape
    kh, kw, wc = w.shape
    assert wc == c, f"channel mismatch: weight C={wc}, input C={c}"
    pad = kh // 2 if padding is None else padding
    ho, wo = _out_dim(h, kh, stride, pad), _out_dim(w_in, kw, stride, pad)
    xp = _pad_hw(x, pad)

    return pl.pallas_call(
        functools.partial(_dwconv_kernel, stride=stride),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, xp.shape[1], xp.shape[2], c), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((kh, kw, c), lambda b: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, ho, wo, c), lambda b: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, ho, wo, c), jnp.float32),
        interpret=True,
    )(xp, w)


def _dwconv_q_kernel(xq_ref, wq_ref, sx_ref, sw_ref, o_ref, *, stride: int):
    _, ho, wo, _ = o_ref.shape
    acc = _dw_accum(xq_ref[0], wq_ref[...], ho, wo, stride, jnp.int32)
    o_ref[0] = acc.astype(jnp.float32) * sx_ref[0] * sw_ref[0]


@functools.partial(jax.jit, static_argnames=("stride", "padding"))
def dwconv_q8(x: jnp.ndarray, w: jnp.ndarray, *, stride: int = 1, padding: int | None = None) -> jnp.ndarray:
    """8-bit fixed-point depth-wise convolution (DHM datapath arithmetic)."""
    n, h, w_in, c = x.shape
    kh, kw, _ = w.shape
    pad = kh // 2 if padding is None else padding
    ho, wo = _out_dim(h, kh, stride, pad), _out_dim(w_in, kw, stride, pad)

    sx = quant.scale_for(x)
    sw = quant.scale_for(w)
    xq = quant.quantize(_pad_hw(x, pad), sx)
    wq = quant.quantize(w, sw)

    return pl.pallas_call(
        functools.partial(_dwconv_q_kernel, stride=stride),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, xq.shape[1], xq.shape[2], c), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((kh, kw, c), lambda b: (0, 0, 0)),
            pl.BlockSpec((1,), lambda b: (0,)),
            pl.BlockSpec((1,), lambda b: (0,)),
        ],
        out_specs=pl.BlockSpec((1, ho, wo, c), lambda b: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, ho, wo, c), jnp.float32),
        interpret=True,
    )(xq, wq, sx.reshape(1), sw.reshape(1))
