"""Pallas point-wise (1x1) convolution kernel — the paper's FPGA-side stage.

The DWConv partitioning (paper §IV, Fig 2a) delegates every 1x1 convolution
to the FPGA: a 1x1 conv is a pure channel-mixing matmul

    y[n, h, w, :] = x[n, h, w, :] @ w[Ci, Co]

with zero spatial reuse, i.e. exactly the shape DHM maps best (one MAC
column per output channel, weights in registers, activations streamed).
On TPU this is a (H*W, Ci) x (Ci, Co) MXU matmul with the weight matrix
VMEM-resident across the batch grid. The fused variant applies ReLU /
ReLU6 inside the kernel — the Pallas analogue of DHM wiring the activation
function into the pipeline for free.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import quant

_ACTS = {
    "none": lambda v: v,
    "relu": lambda v: jnp.maximum(v, 0.0),
    "relu6": lambda v: jnp.clip(v, 0.0, 6.0),
}


def _pwconv_kernel(x_ref, w_ref, o_ref, *, act: str):
    _, h, w, co = o_ref.shape
    ci = x_ref.shape[-1]
    y = jnp.dot(
        x_ref[0].reshape(h * w, ci),
        w_ref[...],
        preferred_element_type=jnp.float32,
    )
    o_ref[0] = _ACTS[act](y).reshape(h, w, co)


@functools.partial(jax.jit, static_argnames=("act",))
def pwconv(x: jnp.ndarray, w: jnp.ndarray, *, act: str = "none") -> jnp.ndarray:
    """1x1 convolution. x: (N, H, W, Ci) f32, w: (Ci, Co) f32."""
    n, h, w_in, ci = x.shape
    wci, co = w.shape
    assert wci == ci, f"channel mismatch: weight Ci={wci}, input Ci={ci}"
    assert act in _ACTS, f"unknown activation {act!r}"

    return pl.pallas_call(
        functools.partial(_pwconv_kernel, act=act),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, h, w_in, ci), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((ci, co), lambda b: (0, 0)),  # weights resident
        ],
        out_specs=pl.BlockSpec((1, h, w_in, co), lambda b: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h, w_in, co), jnp.float32),
        interpret=True,
    )(x, w)


def _pwconv_q_kernel(xq_ref, wq_ref, sx_ref, sw_ref, o_ref, *, act: str):
    _, h, w, co = o_ref.shape
    ci = xq_ref.shape[-1]
    acc = jnp.dot(
        xq_ref[0].reshape(h * w, ci).astype(jnp.int32),
        wq_ref[...].astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )
    y = acc.astype(jnp.float32) * sx_ref[0] * sw_ref[0]
    o_ref[0] = _ACTS[act](y).reshape(h, w, co)


@functools.partial(jax.jit, static_argnames=("act",))
def pwconv_q8(x: jnp.ndarray, w: jnp.ndarray, *, act: str = "none") -> jnp.ndarray:
    """8-bit fixed-point 1x1 convolution (the DHM-mapped stage's arithmetic)."""
    n, h, w_in, ci = x.shape
    _, co = w.shape
    sx = quant.scale_for(x)
    sw = quant.scale_for(w)
    xq = quant.quantize(x, sx)
    wq = quant.quantize(w, sw)

    return pl.pallas_call(
        functools.partial(_pwconv_q_kernel, act=act),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, h, w_in, ci), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((ci, co), lambda b: (0, 0)),
            pl.BlockSpec((1,), lambda b: (0,)),
            pl.BlockSpec((1,), lambda b: (0,)),
        ],
        out_specs=pl.BlockSpec((1, h, w_in, co), lambda b: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h, w_in, co), jnp.float32),
        interpret=True,
    )(xq, wq, sx.reshape(1), sw.reshape(1))
