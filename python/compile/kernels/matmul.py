"""Tiled Pallas matmul — the classifier (Dense) layer's kernel.

Unlike the conv kernels (grid over batch, full-image blocks), this kernel
demonstrates genuine multi-dimensional BlockSpec tiling: the grid ranges
over (M-tiles, N-tiles), each step loads an (TM, K) activation panel and a
(K, TN) weight panel into VMEM and issues one MXU matmul. This is the
canonical TPU blocking for the 1280x1000 / 1024x1000 classifier matmuls
at the end of MobileNetV2 / ShuffleNetV2, where the weight matrix is the
whole layer (no spatial reuse to exploit).

The K axis is kept whole per step (K <= 1280 fits VMEM comfortably at
these sizes); blocking K with an accumulator loop is the documented
extension for larger-than-VMEM reductions (DESIGN.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, w_ref, o_ref):
    o_ref[...] = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)


def _tile(dim: int, want: int) -> int:
    """Largest divisor of `dim` that is <= want (keeps blocks even)."""
    for cand in range(min(want, dim), 0, -1):
        if dim % cand == 0:
            return cand
    return 1


@functools.partial(jax.jit, static_argnames=("tm", "tn"))
def matmul(x: jnp.ndarray, w: jnp.ndarray, *, tm: int = 128, tn: int = 128) -> jnp.ndarray:
    """Tiled matmul. x: (M, K) f32, w: (K, N) f32 -> (M, N) f32."""
    m, k = x.shape
    wk, n = w.shape
    assert wk == k, f"inner dims {wk} != {k}"
    tm = _tile(m, tm)
    tn = _tile(n, tn)

    return pl.pallas_call(
        _matmul_kernel,
        grid=(m // tm, n // tn),
        in_specs=[
            pl.BlockSpec((tm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, tn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w)


def dense(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Classifier head: (N, C) x (C, classes) via the tiled kernel."""
    return matmul(x, w)
