"""Pallas grouped convolution kernel (paper §IV, Fig 2b — GConv partitioning).

Grouped convolution splits the Ci input channels into G independent groups;
group g convolves channels [g*Ci/G, (g+1)*Ci/G) with its own filter bank
producing Co/G output channels. The paper exploits exactly this independence
to place some groups on the FPGA and the rest on the GPU and run them *in
parallel*, concatenating OFMs afterwards.

Here the group axis becomes a Pallas *grid dimension*: grid = (N, G), each
step loads one group's channel slab and one group's filter bank into VMEM.
``gconv_split`` is the two-device functional decomposition the Rust
coordinator uses to prove partition-equals-monolith numerics.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .conv2d import _conv_accum, _out_dim, _pad_hw, conv2d


def _gconv_kernel(x_ref, w_ref, o_ref, *, stride: int):
    """One grid step = (batch element, group)."""
    _, ho, wo, _ = o_ref.shape
    o_ref[0] = _conv_accum(x_ref[0], w_ref[0], ho, wo, stride, jnp.float32)


@functools.partial(jax.jit, static_argnames=("groups", "stride", "padding"))
def gconv(x: jnp.ndarray, w: jnp.ndarray, *, groups: int, stride: int = 1, padding: int | None = None) -> jnp.ndarray:
    """Grouped convolution.

    x: (N, H, W, Ci) f32; w: (G, kh, kw, Ci/G, Co/G) f32, one filter bank
    per group. Output: (N, Ho, Wo, Co) with group OFMs concatenated along
    channels in group order.
    """
    n, h, w_in, ci = x.shape
    g, kh, kw, cig, cog = w.shape
    assert g == groups, f"weight groups {g} != groups {groups}"
    assert cig * g == ci, f"group channels {cig}*{g} != Ci {ci}"
    pad = kh // 2 if padding is None else padding
    ho, wo = _out_dim(h, kh, stride, pad), _out_dim(w_in, kw, stride, pad)
    xp = _pad_hw(x, pad)

    return pl.pallas_call(
        functools.partial(_gconv_kernel, stride=stride),
        grid=(n, g),
        in_specs=[
            # channel slab for group gi: block index gi over a Ci/G-sized axis
            pl.BlockSpec((1, xp.shape[1], xp.shape[2], cig), lambda b, gi: (b, 0, 0, gi)),
            pl.BlockSpec((1, kh, kw, cig, cog), lambda b, gi: (gi, 0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, ho, wo, cog), lambda b, gi: (b, 0, 0, gi)),
        out_shape=jax.ShapeDtypeStruct((n, ho, wo, g * cog), jnp.float32),
        interpret=True,
    )(xp, w)


def gconv_split(x: jnp.ndarray, w: jnp.ndarray, *, split: int, stride: int = 1, padding: int | None = None):
    """Fig 2b channel partitioning of a *standard* conv into two device halves.

    The FPGA takes the first ``split`` input channels, the GPU the remaining
    Ci - split; both compute partial sums over the full filter depth and the
    results are *added* (a standard conv sums over all Ci):

        conv(x, w) = conv(x[..., :split], w[:, :, :split, :])
                   + conv(x[..., split:], w[:, :, split:, :])

    Returns (fpga_part, gpu_part); callers verify fpga_part + gpu_part ==
    conv2d(x, w). This is the decomposition the Rust scheduler times as two
    parallel device tasks with a max() latency join.
    """
    ci = x.shape[-1]
    assert 0 < split < ci, f"split {split} out of range (0, {ci})"
    fpga = conv2d(x[..., :split], w[:, :, :split, :], stride=stride, padding=padding)
    gpu = conv2d(x[..., split:], w[:, :, split:, :], stride=stride, padding=padding)
    return fpga, gpu
