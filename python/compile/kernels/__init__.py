"""L1 Pallas kernels for the FPGA-GPU heterogeneity reproduction.

All kernels lower with ``interpret=True`` (CPU-PJRT-executable HLO); see
conv2d.py's module docstring for the TPU hardware-adaptation rationale.
"""

from .conv2d import conv2d, conv2d_q8
from .dwconv import dwconv, dwconv_q8
from .fused import fused_pw_dw_pw, fused_pw_pw, fused_pw_pw_q8
from .gconv import gconv, gconv_split
from .im2col import conv2d_im2col
from .matmul import dense, matmul
from .pool import global_avgpool, maxpool
from .pwconv import pwconv, pwconv_q8

__all__ = [
    "conv2d", "conv2d_q8",
    "dwconv", "dwconv_q8",
    "pwconv", "pwconv_q8",
    "gconv", "gconv_split",
    "conv2d_im2col",
    "matmul", "dense",
    "maxpool", "global_avgpool",
    "fused_pw_dw_pw", "fused_pw_pw", "fused_pw_pw_q8",
]
