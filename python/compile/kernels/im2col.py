"""Pallas im2col convolution — an INDEPENDENT second implementation.

This is the GPU-style formulation the paper's CUDA kernels use (§III-B):
materialize the patch matrix ("im2col"), then one big GEMM

    patches: (Ho*Wo, kh*kw*Ci)    weights: (kh*kw*Ci, Co)

Unlike conv2d.py's shifted-slice decomposition (k*k small matmuls), this
kernel builds the patch matrix inside VMEM with gather-free static slices
and issues a single MXU matmul per image. Having two structurally
different Pallas convolutions that must agree with each other AND with
the lax oracle triples the correctness cross-check surface, and the pair
is the CPU stand-in for the paper's "GPU formulation vs DHM formulation"
contrast.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .conv2d import _out_dim, _pad_hw


def _im2col_kernel(x_ref, w_ref, o_ref, *, k: int, stride: int):
    """One grid step = one batch element; patch matrix lives in VMEM."""
    _, ho, wo, co = o_ref.shape
    x = x_ref[0]                      # (Hp, Wp, Ci)
    ci = x.shape[-1]
    # build the (ho*wo, k*k*ci) patch matrix from static shifted slices
    cols = []
    for i in range(k):
        for j in range(k):
            xs = lax.slice(
                x,
                (i, j, 0),
                (i + (ho - 1) * stride + 1, j + (wo - 1) * stride + 1, ci),
                (stride, stride, 1),
            )  # (ho, wo, ci)
            cols.append(xs.reshape(ho * wo, ci))
    patches = jnp.concatenate(cols, axis=1)          # (ho*wo, k*k*ci)
    y = jnp.dot(patches, w_ref[...], preferred_element_type=jnp.float32)
    o_ref[0] = y.reshape(ho, wo, co)


@functools.partial(jax.jit, static_argnames=("stride", "padding"))
def conv2d_im2col(x: jnp.ndarray, w: jnp.ndarray, *, stride: int = 1, padding: int | None = None) -> jnp.ndarray:
    """im2col convolution. x: (N, H, W, Ci) f32, w: (kh, kw, Ci, Co) f32.

    Identical semantics to ``conv2d`` (SAME-for-odd-kernels by default);
    the weight tensor is flattened to the GEMM layout at trace time.
    """
    n, h, w_in, ci = x.shape
    kh, kw, wci, co = w.shape
    assert kh == kw, "square kernels only"
    assert wci == ci, f"channel mismatch: weight Ci={wci}, input Ci={ci}"
    pad = kh // 2 if padding is None else padding
    ho, wo = _out_dim(h, kh, stride, pad), _out_dim(w_in, kw, stride, pad)
    xp = _pad_hw(x, pad)
    # (kh, kw, Ci, Co) -> (kh*kw*Ci, Co), matching the patch column order
    wf = w.reshape(kh * kw * ci, co)

    return pl.pallas_call(
        functools.partial(_im2col_kernel, k=kh, stride=stride),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, xp.shape[1], xp.shape[2], ci), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((kh * kw * ci, co), lambda b: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, ho, wo, co), lambda b: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, ho, wo, co), jnp.float32),
        interpret=True,
    )(xp, wf)
