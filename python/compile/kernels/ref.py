"""Pure-jnp correctness oracles for every L1 Pallas kernel.

These use ``lax.conv_general_dilated`` / ``lax.reduce_window`` — completely
independent code paths from the Pallas shifted-slice decomposition — so a
pytest ``assert_allclose(kernel, ref)`` is a genuine two-implementation
cross-check, the CORE correctness signal of the build (system prompt (c)).
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from . import quant


def _dn():
    return ("NHWC", "HWIO", "NHWC")


def conv2d_ref(x: jnp.ndarray, w: jnp.ndarray, *, stride: int = 1, padding: int | None = None) -> jnp.ndarray:
    """Oracle for kernels.conv2d. x: (N,H,W,Ci), w: (kh,kw,Ci,Co)."""
    pad = w.shape[0] // 2 if padding is None else padding
    return lax.conv_general_dilated(
        x, w, (stride, stride), [(pad, pad), (pad, pad)], dimension_numbers=_dn()
    )


def conv2d_q8_ref(x: jnp.ndarray, w: jnp.ndarray, *, stride: int = 1, padding: int | None = None) -> jnp.ndarray:
    """Oracle for kernels.conv2d_q8: same symmetric int8 quant, f32 conv of
    the *dequantized* operands (int32 MAC of int8 values is exact in f32)."""
    sx, sw = quant.scale_for(x), quant.scale_for(w)
    xd = quant.dequantize(quant.quantize(x, sx), sx)
    wd = quant.dequantize(quant.quantize(w, sw), sw)
    return conv2d_ref(xd, wd, stride=stride, padding=padding)


def dwconv_ref(x: jnp.ndarray, w: jnp.ndarray, *, stride: int = 1, padding: int | None = None) -> jnp.ndarray:
    """Oracle for kernels.dwconv. w: (kh,kw,C) -> HWIO with feature groups."""
    c = x.shape[-1]
    pad = w.shape[0] // 2 if padding is None else padding
    w4 = w[:, :, None, :]  # (kh,kw,1,C)
    return lax.conv_general_dilated(
        x, w4, (stride, stride), [(pad, pad), (pad, pad)],
        dimension_numbers=_dn(), feature_group_count=c,
    )


def dwconv_q8_ref(x: jnp.ndarray, w: jnp.ndarray, *, stride: int = 1, padding: int | None = None) -> jnp.ndarray:
    sx, sw = quant.scale_for(x), quant.scale_for(w)
    xd = quant.dequantize(quant.quantize(x, sx), sx)
    wd = quant.dequantize(quant.quantize(w, sw), sw)
    return dwconv_ref(xd, wd, stride=stride, padding=padding)


def pwconv_ref(x: jnp.ndarray, w: jnp.ndarray, *, act: str = "none") -> jnp.ndarray:
    """Oracle for kernels.pwconv. w: (Ci, Co)."""
    y = jnp.einsum("nhwc,cd->nhwd", x, w)
    if act == "relu":
        y = jnp.maximum(y, 0.0)
    elif act == "relu6":
        y = jnp.clip(y, 0.0, 6.0)
    return y


def pwconv_q8_ref(x: jnp.ndarray, w: jnp.ndarray, *, act: str = "none") -> jnp.ndarray:
    sx, sw = quant.scale_for(x), quant.scale_for(w)
    xd = quant.dequantize(quant.quantize(x, sx), sx)
    wd = quant.dequantize(quant.quantize(w, sw), sw)
    return pwconv_ref(xd, wd, act=act)


def gconv_ref(x: jnp.ndarray, w: jnp.ndarray, *, groups: int, stride: int = 1, padding: int | None = None) -> jnp.ndarray:
    """Oracle for kernels.gconv. w: (G, kh, kw, Ci/G, Co/G)."""
    g = w.shape[0]
    cig = x.shape[-1] // g
    outs = [
        conv2d_ref(x[..., gi * cig:(gi + 1) * cig], w[gi], stride=stride, padding=padding)
        for gi in range(g)
    ]
    return jnp.concatenate(outs, axis=-1)


def maxpool_ref(x: jnp.ndarray, *, k: int = 3, stride: int = 2) -> jnp.ndarray:
    return lax.reduce_window(
        x, -jnp.inf, lax.max, (1, k, k, 1), (1, stride, stride, 1), "VALID"
    )


def global_avgpool_ref(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean(x, axis=(1, 2))


def fused_pw_dw_pw_ref(x, w1, wd, w2, *, stride: int = 1) -> jnp.ndarray:
    t = jnp.maximum(jnp.einsum("nhwc,cm->nhwm", x, w1), 0.0)
    t = dwconv_ref(t, wd, stride=stride, padding=1)
    return jnp.maximum(jnp.einsum("nhwc,cm->nhwm", t, w2), 0.0)


def fused_pw_pw_ref(x, w1, w2) -> jnp.ndarray:
    t = jnp.maximum(jnp.einsum("nhwc,cm->nhwm", x, w1), 0.0)
    return jnp.maximum(jnp.einsum("nhwm,md->nhwd", t, w2), 0.0)


def matmul_ref(x, w) -> jnp.ndarray:
    return x @ w
