"""8-bit fixed-point quantization helpers (paper §I: DHM uses 8-bit fixed point).

The DHM datapath computes with 8-bit fixed-point operands accumulated in
wide registers. We model exactly that arithmetic pipeline so the L1 Pallas
kernels and the Rust-side `quant` module agree bit-for-bit:

    q = clamp(round(x / scale), -128, 127)         (symmetric, per-tensor)
    acc = sum(q_x * q_w)  in int32                 (the DHM MAC array)
    y = acc * (scale_x * scale_w)                  (requantize to f32)

`scale_for` picks the symmetric power-of-two-free scale max|x|/127, which
is what a DHM synthesis flow would derive from calibration data.
"""

from __future__ import annotations

import jax.numpy as jnp

QMIN = -128
QMAX = 127


def scale_for(x: jnp.ndarray) -> jnp.ndarray:
    """Symmetric per-tensor scale so that max|x| maps to 127."""
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8)
    return amax / QMAX


def quantize(x: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """f32 -> int8 with round-to-nearest-even and saturation."""
    q = jnp.round(x / scale)
    return jnp.clip(q, QMIN, QMAX).astype(jnp.int8)


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """int8 -> f32."""
    return q.astype(jnp.float32) * scale


def fake_quant(x: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """Quantize-dequantize round trip (straight-through in fwd-only use)."""
    return dequantize(quantize(x, scale), scale)
