"""L1 performance analysis: VMEM footprint + MXU utilization estimates.

Pallas kernels run here under ``interpret=True`` (CPU), so wall-clock is
meaningless as a TPU proxy (DESIGN.md §7). What CAN be assessed offline is
the *structure* the BlockSpecs pin down:

- **VMEM footprint** per grid step: every in/out block plus weight
  residents must fit the ~16 MiB of VMEM per TensorCore, or the kernel
  simply will not compile for a real TPU.
- **MXU utilization estimate**: each ``jnp.dot`` inside a kernel maps to
  128x128 systolic passes; a (M, K) x (K, N) contraction utilizes roughly
  ``min(M,128)/128 * min(K,128)/128 * min(N,128)/128`` of the array per
  pass — the classic "pad-to-128" law. We report the MAC-weighted average
  over each kernel's dots.

Usage: ``python -m compile.analysis`` prints the per-kernel table pytest
also asserts over (tests/test_analysis.py).
"""

from __future__ import annotations

VMEM_BYTES = 16 * 1024 * 1024
MXU = 128


def _prod(xs):
    out = 1
    for x in xs:
        out *= x
    return out


def mxu_utilization(m: int, k: int, n: int) -> float:
    """Utilization of one (m,k) x (k,n) dot on the 128x128 MXU."""
    return (min(m, MXU) / MXU) * (min(k, MXU) / MXU) * (min(n, MXU) / MXU)


class KernelProfile:
    """Static profile of one Pallas kernel at one geometry."""

    def __init__(self, name: str, blocks: dict[str, tuple[int, ...]],
                 dots: list[tuple[int, int, int]], elem_bytes: int = 4):
        self.name = name
        self.blocks = blocks      # label -> block shape (per grid step)
        self.dots = dots          # (M, K, N) per jnp.dot issued per step
        self.elem_bytes = elem_bytes

    @property
    def vmem_bytes(self) -> int:
        return sum(_prod(s) for s in self.blocks.values()) * self.elem_bytes

    @property
    def vmem_frac(self) -> float:
        return self.vmem_bytes / VMEM_BYTES

    @property
    def macs(self) -> int:
        return sum(m * k * n for m, k, n in self.dots)

    @property
    def mxu_estimate(self) -> float:
        """MAC-weighted MXU utilization across the kernel's dots (0 when
        the kernel is VPU-elementwise, e.g. depth-wise conv)."""
        if not self.dots:
            return 0.0
        total = self.macs
        return sum(mxu_utilization(m, k, n) * (m * k * n) for m, k, n in self.dots) / total


def profile_conv2d(h: int, w: int, ci: int, co: int, k: int, stride: int = 1) -> KernelProfile:
    """conv2d.py: grid over batch; k*k shifted-slice dots of (Hb*Wo, Ci)x(Ci, Co).

    Mirrors the kernel's output-row BANDING (conv2d.VMEM_BUDGET): blocks
    reflect one band, the unit that actually occupies VMEM per call.
    """
    from .kernels.conv2d import _band_rows
    pad = k // 2
    ho = (h + 2 * pad - k) // stride + 1
    wo = (w + 2 * pad - k) // stride + 1
    hb = _band_rows(h + 2 * pad, w + 2 * pad, ci, ho, wo, co, k, k, stride)
    h_in_band = (hb - 1) * stride + k
    blocks = {
        "x(band)": (1, h_in_band, w + 2 * pad, ci),
        "w(resident)": (k, k, ci, co),
        "o(band)": (1, hb, wo, co),
        "acc": (hb * wo, co),
    }
    dots = [(hb * wo, ci, co)] * (k * k)
    label = f"conv2d {k}x{k} {h}x{w}x{ci}->{co}"
    if hb < ho:
        label += f" [{(ho + hb - 1) // hb} bands]"
    return KernelProfile(label, blocks, dots)


def profile_pwconv(h: int, w: int, ci: int, co: int) -> KernelProfile:
    blocks = {"x": (1, h, w, ci), "w(resident)": (ci, co), "o": (1, h, w, co)}
    return KernelProfile(f"pwconv {h}x{w}x{ci}->{co}", blocks, [(h * w, ci, co)])


def profile_dwconv(h: int, w: int, c: int, k: int = 3) -> KernelProfile:
    pad = k // 2
    blocks = {"x": (1, h + 2 * pad, w + 2 * pad, c), "w": (k, k, c), "o": (1, h, w, c)}
    return KernelProfile(f"dwconv {k}x{k} {h}x{w}x{c}", blocks, [])  # VPU work


def profile_matmul(m: int, kdim: int, n: int, tm: int = 128, tn: int = 128) -> KernelProfile:
    tm = min(tm, m)
    tn = min(tn, n)
    blocks = {"x": (tm, kdim), "w": (kdim, tn), "o": (tm, tn)}
    return KernelProfile(f"matmul {m}x{kdim}x{n} (tile {tm}x{tn})", blocks, [(tm, kdim, tn)])


def profile_fused_pw_dw_pw(h: int, w: int, ci: int, cm: int, co: int) -> KernelProfile:
    blocks = {
        "x": (1, h, w, ci),
        "w1(resident)": (ci, cm),
        "wd(resident)": (3, 3, cm),
        "w2(resident)": (cm, co),
        "t(scratch)": (h + 2, w + 2, cm),
        "o": (1, h, w, co),
    }
    dots = [(h * w, ci, cm), (h * w, cm, co)]
    return KernelProfile(f"fused pw-dw-pw {h}x{w} {ci}->{cm}->{co}", blocks, dots)


def paper_profiles() -> list[KernelProfile]:
    """The geometries the three CNNs actually run (representative set)."""
    return [
        profile_conv2d(224, 224, 3, 64, 3),           # Fig 1 sweep point
        profile_conv2d(224, 224, 3, 64, 5),           # Fig 1 cliff design
        profile_conv2d(54, 54, 16, 64, 3),            # fire2 expand3x3
        profile_conv2d(12, 12, 64, 256, 3),           # fire9 expand3x3
        profile_pwconv(54, 54, 96, 16),               # fire2 squeeze
        profile_pwconv(28, 28, 96, 16),               # MNv2 projection
        profile_pwconv(7, 7, 160, 1280),              # MNv2 last conv
        profile_dwconv(28, 28, 96),                   # MNv2 dw stage
        profile_fused_pw_dw_pw(28, 28, 24, 24, 24),   # SNv2 right branch
        profile_matmul(1, 1280, 1000),                # MNv2 classifier
        profile_matmul(8, 1024, 1000),                # SNv2 classifier, batch 8
    ]


def report() -> str:
    rows = [f"{'kernel':<40} {'VMEM':>10} {'%VMEM':>7} {'MXU est':>8}"]
    rows.append("-" * 70)
    for p in paper_profiles():
        rows.append(
            f"{p.name:<40} {p.vmem_bytes / 1024:>8.0f}KB {p.vmem_frac * 100:>6.1f}% "
            f"{p.mxu_estimate * 100:>7.1f}%"
        )
    return "\n".join(rows)


if __name__ == "__main__":
    print(report())
