"""AOT compiler: lower every L2 module/model to HLO *text* + manifest.json.

This is the only place Python touches the artifact boundary. Each artifact
is a jitted L2 function lowered to stablehlo, converted to an XlaComputation
and dumped as HLO text — NOT ``.serialize()``: jax >= 0.5 emits protos with
64-bit instruction ids that the xla crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

``artifacts/manifest.json`` records, per artifact: the HLO file, ordered
input names/shapes/dtypes, output arity and shapes, and tags. The Rust
runtime (rust/src/runtime) is entirely manifest-driven — it never hardcodes
a shape.

Artifact families:
  * op-level     — single kernels (quickstart + runtime integration tests)
  * module-level — Fire / Bottleneck / Shuffle units, monolithic AND
                   partitioned halves (GPU part, FPGA part in both the
                   8-bit DHM datapath and a float twin for exact
                   split==monolith equivalence checks)
  * net-level    — the three full CNNs at 224x224 (end-to-end serving demo)

Usage: python -m compile.aot [--out-dir ../artifacts] [--skip-nets]
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import kernels as K
from . import model as M


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True: rust
    unwraps with to_tuple1/to_tuple)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


class Registry:
    def __init__(self):
        self.entries = []  # (name, fn, [(arg_name, shape)], n_outputs, tags)

    def add(self, name, fn, args, n_outputs=1, tags=()):
        self.entries.append((name, fn, args, n_outputs, list(tags)))


def build_registry(include_nets: bool = True) -> Registry:
    r = Registry()

    # ---- op-level ---------------------------------------------------------
    r.add("conv3x3", lambda x, w: (K.conv2d(x, w),),
          [("x", (1, 56, 56, 16)), ("w", (3, 3, 16, 32))], tags=["op"])
    r.add("conv3x3_q8", lambda x, w: (K.conv2d_q8(x, w),),
          [("x", (1, 56, 56, 16)), ("w", (3, 3, 16, 32))], tags=["op", "q8"])
    r.add("pwconv_relu", lambda x, w: (K.pwconv(x, w, act="relu"),),
          [("x", (1, 56, 56, 64)), ("w", (64, 128))], tags=["op"])
    r.add("dwconv3x3_s2", lambda x, w: (K.dwconv(x, w, stride=2),),
          [("x", (1, 56, 56, 32)), ("w", (3, 3, 32))], tags=["op"])
    r.add("gconv_g2", lambda x, w: (K.gconv(x, w, groups=2),),
          [("x", (1, 28, 28, 32)), ("w", (2, 3, 3, 16, 24))], tags=["op"])
    r.add("fused_pw_pw", lambda x, w1, w2: (K.fused_pw_pw(x, w1, w2),),
          [("x", (1, 28, 28, 32)), ("w1", (32, 64)), ("w2", (64, 32))],
          tags=["op", "fused"])

    # ---- Fire module (SqueezeNet fire2 geometry: 56x56x96 -> 16 -> 64+64) -
    fire_args = [("x", (1, 56, 56, 96)), ("squeeze_w", (96, 16)),
                 ("expand1_w", (16, 64)), ("expand3_w", (3, 3, 16, 64))]
    r.add("fire_full", lambda x, ws, we1, we3: (M.fire_fwd(x, ws, we1, we3),),
          fire_args, tags=["module", "squeezenet"])
    r.add("fire_gpu", lambda x, ws, we1: M.fire_gpu_fwd(x, ws, we1),
          fire_args[:3], n_outputs=2, tags=["module", "squeezenet", "gpu-part"])
    r.add("fire_fpga", lambda s, we3: (M.fire_fpga_fwd(s, we3),),
          [("s", (1, 56, 56, 16)), ("expand3_w", (3, 3, 16, 64))],
          tags=["module", "squeezenet", "fpga-part", "q8"])
    r.add("fire_fpga_f32", lambda s, we3: (M.fire_fpga_fwd_f32(s, we3),),
          [("s", (1, 56, 56, 16)), ("expand3_w", (3, 3, 16, 64))],
          tags=["module", "squeezenet", "fpga-part"])

    # ---- Bottleneck (MNv2 geometry: 28x28x16, t=6, co=16, s=1, residual) --
    bn_fwd = functools.partial(M.bottleneck_fwd, stride=1, expand=6)
    bn_gpu = functools.partial(M.bottleneck_gpu_fwd, stride=1, expand=6)
    bn_args = [("x", (1, 28, 28, 16)), ("expand_w", (16, 96)),
               ("dw_w", (3, 3, 96)), ("project_w", (96, 16))]
    r.add("bottleneck_full", lambda x, we, wd, wp: (bn_fwd(x, we, wd, wp),),
          bn_args, tags=["module", "mobilenetv2"])
    r.add("bottleneck_gpu", lambda x, we, wd: (bn_gpu(x, we, wd),),
          bn_args[:3], tags=["module", "mobilenetv2", "gpu-part"])
    r.add("bottleneck_fpga", lambda t, wp: (M.bottleneck_fpga_fwd(t, wp),),
          [("t", (1, 28, 28, 96)), ("project_w", (96, 16))],
          tags=["module", "mobilenetv2", "fpga-part", "q8"])
    r.add("bottleneck_fpga_f32", lambda t, wp: (M.bottleneck_fpga_fwd_f32(t, wp),),
          [("t", (1, 28, 28, 96)), ("project_w", (96, 16))],
          tags=["module", "mobilenetv2", "fpga-part"])

    # ---- ShuffleNetV2 units (stage-2 geometry: 28x28x48) ------------------
    sb_args = [("x", (1, 28, 28, 48)), ("b1_w", (24, 24)),
               ("bd_w", (3, 3, 24)), ("b2_w", (24, 24))]
    r.add("shuffle_basic_full",
          lambda x, w1, wd, w2: (M.shuffle_basic_fwd(x, w1, wd, w2),),
          sb_args, tags=["module", "shufflenetv2"])
    r.add("shuffle_basic_fpga",
          lambda right, w1, wd, w2: (M.shuffle_basic_fpga_fwd(right, w1, wd, w2),),
          [("right", (1, 28, 28, 24))] + sb_args[1:],
          tags=["module", "shufflenetv2", "fpga-part", "fused"])
    sr_args = [("x", (1, 28, 28, 24)), ("ld_w", (3, 3, 24)), ("l1_w", (24, 24)),
               ("r1_w", (24, 24)), ("rd_w", (3, 3, 24)), ("r2_w", (24, 24))]
    r.add("shuffle_reduce_full",
          lambda x, a, b, c, d, e: (M.shuffle_reduce_fwd(x, a, b, c, d, e),),
          sr_args, tags=["module", "shufflenetv2"])
    r.add("shuffle_reduce_gpu",
          lambda x, c, d, e: (M.shuffle_reduce_gpu_fwd(x, c, d, e),),
          [sr_args[0]] + sr_args[3:], tags=["module", "shufflenetv2", "gpu-part"])
    r.add("shuffle_reduce_fpga",
          lambda x, a, b: (M.shuffle_reduce_fpga_fwd(x, a, b),),
          sr_args[:3], tags=["module", "shufflenetv2", "fpga-part", "q8"])
    r.add("shuffle_reduce_fpga_f32",
          lambda x, a, b: (M.shuffle_reduce_fpga_fwd_f32(x, a, b),),
          sr_args[:3], tags=["module", "shufflenetv2", "fpga-part"])

    # ---- SqueezeNet module chain at 224 geometry ---------------------------
    # Per-module artifacts so the Rust coordinator can execute the ACTUAL
    # heterogeneous pipeline (GPU part -> int8 PCIe boundary -> FPGA part ->
    # concat) module by module and verify it against the monolithic net.
    def _relu_stem(x, w):
        return (jnp.maximum(K.conv2d(x, w, stride=2, padding=0), 0.0),)

    r.add("sq_stem", _relu_stem,
          [("x", (1, 224, 224, 3)), ("conv1_w", (7, 7, 3, 96))], tags=["chain"])

    # geometry walk mirrors model.squeezenet_fwd at 224
    h = (224 - 7) // 2 + 1          # 109 after stem
    h = (h - 3) // 2 + 1            # 54 after pool1
    r.add("sq_pool1", lambda x: (K.maxpool(x, k=3, stride=2),),
          [("x", (1, 109, 109, 96))], tags=["chain"])
    ci = 96
    for i, (fci, s, e1, e3) in enumerate(M.SQUEEZENET_FIRES):
        assert fci == ci, f"fire{i + 2}: {fci} != {ci}"
        name = f"sq_fire{i + 2}"
        fire_args = [("x", (1, h, h, ci)), ("squeeze_w", (ci, s)),
                     ("expand1_w", (s, e1)), ("expand3_w", (3, 3, s, e3))]
        r.add(f"{name}_full", lambda x, ws, we1, we3: (M.fire_fwd(x, ws, we1, we3),),
              fire_args, tags=["chain", "fire"])
        r.add(f"{name}_gpu", lambda x, ws, we1: M.fire_gpu_fwd(x, ws, we1),
              fire_args[:3], n_outputs=2, tags=["chain", "fire", "gpu-part"])
        r.add(f"{name}_fpga", lambda sq, we3: (M.fire_fpga_fwd(sq, we3),),
              [("s", (1, h, h, s)), ("expand3_w", (3, 3, s, e3))],
              tags=["chain", "fire", "fpga-part", "q8"])
        r.add(f"{name}_fpga_f32", lambda sq, we3: (M.fire_fpga_fwd_f32(sq, we3),),
              [("s", (1, h, h, s)), ("expand3_w", (3, 3, s, e3))],
              tags=["chain", "fire", "fpga-part"])
        ci = e1 + e3
        if i == 2 or i == 6:  # pools after fire4 and fire8
            r.add(f"sq_pool{i + 2}", lambda x: (K.maxpool(x, k=3, stride=2),),
                  [("x", (1, h, h, ci))], tags=["chain"])
            h = (h - 3) // 2 + 1
    r.add("sq_conv10", lambda x, w: (K.pwconv(x, w, act="relu"),),
          [("x", (1, h, h, 512)), ("conv10_w", (512, 1000))], tags=["chain"])
    r.add("sq_gap", lambda x: (K.global_avgpool(x),),
          [("x", (1, h, h, 1000))], tags=["chain"])

    # ---- full nets at 224x224 (end-to-end serving demo) -------------------
    if include_nets:
        for mname, (spec_fn, fwd) in M.MODELS.items():
            spec = spec_fn()
            args = [("x", (1, 224, 224, 3))] + [(n, s) for n, s in spec]
            r.add(f"{mname}_224", lambda x, *p, _f=fwd: (_f(x, *p),),
                  args, tags=["net", mname])

    return r


def emit(registry: Registry, out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {}
    for name, fn, args, n_outputs, tags in registry.entries:
        specs = [_spec(shape) for _, shape in args]
        lowered = jax.jit(fn).lower(*specs)
        # record output shapes from the jax-level abstract eval
        out_aval = jax.eval_shape(fn, *specs)
        outs = [{"shape": list(o.shape), "dtype": "f32"} for o in out_aval]
        assert len(outs) == n_outputs, f"{name}: arity {len(outs)} != {n_outputs}"
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        manifest[name] = {
            "file": fname,
            "inputs": [{"name": n, "shape": list(s), "dtype": "f32"} for n, s in args],
            "outputs": outs,
            "tags": tags,
        }
        print(f"  {name}: {len(text) / 1024:.0f} KiB, "
              f"{len(args)} inputs, {n_outputs} outputs")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--skip-nets", action="store_true",
                    help="module/op artifacts only (fast CI path)")
    args = ap.parse_args()
    reg = build_registry(include_nets=not args.skip_nets)
    print(f"lowering {len(reg.entries)} artifacts -> {args.out_dir}")
    manifest = emit(reg, args.out_dir)
    print(f"wrote manifest with {len(manifest)} artifacts")


if __name__ == "__main__":
    main()
