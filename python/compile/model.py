"""L2 — JAX model definitions for the paper's three mobile CNNs.

Every convolution routes through the L1 Pallas kernels (compile.kernels);
jnp is used only for glue (concat, channel shuffle, residual add, the final
classifier matmul). BatchNorm is omitted: the paper measures inference
latency/energy of pre-trained nets where BN folds into the preceding conv,
and no reported metric depends on trained weights (DESIGN.md §2).

Three families, hyper-parameters from the original papers at the widths the
paper evaluates (MobileNetV2 0.5x, ShuffleNetV2 0.5x, SqueezeNet v1.0):

- ``fire_*``        SqueezeNet Fire module + GConv-style GPU/FPGA split
                    (paper Fig 2b / Fig 4a): squeeze on GPU, then expand1x1
                    (GPU) and expand3x3 (FPGA) in parallel, concat.
- ``bottleneck_*``  MobileNetV2 inverted bottleneck + DWConv split (Fig 2a /
                    Fig 4b): pw-expand + dw3x3 on GPU, pw-linear on FPGA,
                    sequential.
- ``shuffle_*``     ShuffleNetV2 unit + split (Fig 4c): reduction units run
                    branches in parallel (left on FPGA), basic units run the
                    branch's fused 1x1->dw->1x1 chain on the FPGA.

Each module/model ``X`` has ``X_spec(...) -> list[(name, shape)]`` so that
AOT artifacts take weights as positional inputs, and ``X_fwd(x, *params)``.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from . import kernels as K

# ---------------------------------------------------------------------------
# parameter plumbing


def init_params(spec, seed: int = 0):
    """He-normal synthetic weights for a spec (list of (name, shape))."""
    rng = np.random.default_rng(seed)
    params = []
    for _, shape in spec:
        fan_in = int(np.prod(shape[:-1])) if len(shape) > 1 else shape[0]
        params.append(jnp.asarray(
            rng.normal(0.0, np.sqrt(2.0 / max(fan_in, 1)), shape).astype(np.float32)))
    return params


def channel_shuffle(x: jnp.ndarray, groups: int = 2) -> jnp.ndarray:
    """ShuffleNet channel shuffle: (.., G*Cg) -> interleave groups."""
    n, h, w, c = x.shape
    return (x.reshape(n, h, w, groups, c // groups)
             .transpose(0, 1, 2, 4, 3)
             .reshape(n, h, w, c))


def relu(x):
    return jnp.maximum(x, 0.0)


def relu6(x):
    return jnp.clip(x, 0.0, 6.0)


# ---------------------------------------------------------------------------
# SqueezeNet Fire module (paper Fig 4a workload)


def fire_spec(ci: int, s: int, e1: int, e3: int):
    """Fire(ci -> s -> e1+e3): squeeze 1x1, expand 1x1, expand 3x3."""
    return [
        ("squeeze_w", (ci, s)),
        ("expand1_w", (s, e1)),
        ("expand3_w", (3, 3, s, e3)),
    ]


def fire_fwd(x, ws, we1, we3):
    """Monolithic Fire: the GPU-only baseline graph."""
    s = K.pwconv(x, ws, act="relu")
    a = K.pwconv(s, we1, act="relu")
    b = relu(K.conv2d(s, we3))
    return jnp.concatenate([a, b], axis=-1)


def fire_gpu_fwd(x, ws, we1):
    """GPU half of the Fire split: squeeze + expand1x1 (returns both:
    the squeeze OFM is what crosses PCIe to the FPGA)."""
    s = K.pwconv(x, ws, act="relu")
    a = K.pwconv(s, we1, act="relu")
    return s, a


def fire_fpga_fwd(s, we3):
    """FPGA half: expand3x3 over the squeeze OFM, 8-bit DHM datapath."""
    return relu(K.conv2d_q8(s, we3))


def fire_fpga_fwd_f32(s, we3):
    """Float twin of the FPGA half — used to prove split==monolith exactly."""
    return relu(K.conv2d(s, we3))


# ---------------------------------------------------------------------------
# MobileNetV2 inverted bottleneck (paper Fig 4b workload)


def bottleneck_spec(ci: int, co: int, expand: int):
    cm = ci * expand
    p = []
    if expand != 1:
        p.append(("expand_w", (ci, cm)))
    p.append(("dw_w", (3, 3, cm)))
    p.append(("project_w", (cm, co)))
    return p


def bottleneck_fwd(x, *params, stride: int = 1, expand: int = 6):
    """Monolithic inverted bottleneck: pw-expand -> dw3x3 -> pw-linear."""
    ci = x.shape[-1]
    i = 0
    t = x
    if expand != 1:
        t = K.pwconv(t, params[i], act="relu6"); i += 1
    t = relu6(K.dwconv(t, params[i], stride=stride)); i += 1
    y = K.pwconv(t, params[i]); i += 1
    if stride == 1 and y.shape[-1] == ci:
        y = y + x
    return y


def bottleneck_gpu_fwd(x, *params, stride: int = 1, expand: int = 6):
    """GPU half of the DWConv split: pw-expand + dw3x3 (the k x k stage)."""
    i = 0
    t = x
    if expand != 1:
        t = K.pwconv(t, params[i], act="relu6"); i += 1
    return relu6(K.dwconv(t, params[i], stride=stride))


def bottleneck_fpga_fwd(t, wp):
    """FPGA half: the 1x1 projection, 8-bit DHM datapath (Fig 2a)."""
    return K.pwconv_q8(t, wp)


def bottleneck_fpga_fwd_f32(t, wp):
    return K.pwconv(t, wp)


# ---------------------------------------------------------------------------
# ShuffleNetV2 units (paper Fig 4c workload)


def shuffle_basic_spec(c: int):
    """Basic (stride-1) unit on c channels; right branch works on c/2."""
    ch = c // 2
    return [
        ("b1_w", (ch, ch)),
        ("bd_w", (3, 3, ch)),
        ("b2_w", (ch, ch)),
    ]


def shuffle_basic_fwd(x, w1, wd, w2):
    """Channel split -> right branch 1x1 -> dw3x3 -> 1x1 -> concat -> shuffle."""
    ch = x.shape[-1] // 2
    left, right = x[..., :ch], x[..., ch:]
    r = K.pwconv(right, w1, act="relu")
    r = K.dwconv(r, wd)
    r = K.pwconv(r, w2, act="relu")
    return channel_shuffle(jnp.concatenate([left, r], axis=-1))


def shuffle_basic_fpga_fwd(right, w1, wd, w2):
    """FPGA side of the basic unit: the whole right branch as ONE fused
    Pallas kernel (Fig 2c fused-layer — intermediates never leave chip)."""
    return K.fused_pw_dw_pw(right, w1, wd, w2)


def shuffle_reduce_spec(ci: int, co: int):
    """Spatial-reduction (stride-2) unit ci -> co; each branch outputs co/2."""
    ch = co // 2
    return [
        ("ld_w", (3, 3, ci)),      # left: dw3x3/s2
        ("l1_w", (ci, ch)),        # left: 1x1
        ("r1_w", (ci, ch)),        # right: 1x1
        ("rd_w", (3, 3, ch)),      # right: dw3x3/s2
        ("r2_w", (ch, ch)),        # right: 1x1
    ]


def shuffle_reduce_fwd(x, wld, wl1, wr1, wrd, wr2):
    """Both branches see the full input; stride-2; concat doubles channels."""
    l = K.dwconv(x, wld, stride=2)
    l = K.pwconv(l, wl1, act="relu")
    r = K.pwconv(x, wr1, act="relu")
    r = K.dwconv(r, wrd, stride=2)
    r = K.pwconv(r, wr2, act="relu")
    return channel_shuffle(jnp.concatenate([l, r], axis=-1))


def shuffle_reduce_fpga_fwd(x, wld, wl1):
    """FPGA side of the reduction unit: the left branch (dw3x3/s2 + 1x1),
    running in parallel with the GPU's right branch (Fig 4c gain)."""
    l = K.dwconv_q8(x, wld, stride=2)
    return K.pwconv_q8(l, wl1, act="relu")


def shuffle_reduce_fpga_fwd_f32(x, wld, wl1):
    l = K.dwconv(x, wld, stride=2)
    return K.pwconv(l, wl1, act="relu")


def shuffle_reduce_gpu_fwd(x, wr1, wrd, wr2):
    r = K.pwconv(x, wr1, act="relu")
    r = K.dwconv(r, wrd, stride=2)
    return K.pwconv(r, wr2, act="relu")


# ---------------------------------------------------------------------------
# Full networks


SQUEEZENET_FIRES = [
    # (ci, squeeze, expand1, expand3) — SqueezeNet v1.0, table 1 of [5]
    (96, 16, 64, 64),     # fire2
    (128, 16, 64, 64),    # fire3
    (128, 32, 128, 128),  # fire4
    (256, 32, 128, 128),  # fire5
    (256, 48, 192, 192),  # fire6
    (384, 48, 192, 192),  # fire7
    (384, 64, 256, 256),  # fire8
    (512, 64, 256, 256),  # fire9
]


def squeezenet_spec(num_classes: int = 1000):
    spec = [("conv1_w", (7, 7, 3, 96))]
    for i, (ci, s, e1, e3) in enumerate(SQUEEZENET_FIRES):
        for name, shape in fire_spec(ci, s, e1, e3):
            spec.append((f"fire{i + 2}_{name}", shape))
    spec.append(("conv10_w", (512, num_classes)))
    return spec


def squeezenet_fwd(x, *params):
    """SqueezeNet v1.0 (stem 7x7/s2-96, pools after fire4 and fire8).
    x: (N, H, W, 3) -> (N, classes)."""
    i = 0
    t = relu(K.conv2d(x, params[i], stride=2, padding=0)); i += 1
    t = K.maxpool(t, k=3, stride=2)
    for fi in range(len(SQUEEZENET_FIRES)):
        t = fire_fwd(t, params[i], params[i + 1], params[i + 2]); i += 3
        if fi in (2, 6):  # pool after fire4 and fire8 (v1.0 layout)
            t = K.maxpool(t, k=3, stride=2)
    t = K.pwconv(t, params[i], act="relu"); i += 1
    return K.global_avgpool(t)


MOBILENETV2_05_SETTING = [
    # (expand t, c_out, repeats n, stride s) — MNv2 paper table 2 at 0.5x
    (1, 8, 1, 1),
    (6, 16, 2, 2),
    (6, 16, 3, 2),
    (6, 32, 4, 2),
    (6, 48, 3, 1),
    (6, 80, 3, 2),
    (6, 160, 1, 1),
]
MOBILENETV2_05_STEM = 16
MOBILENETV2_05_LAST = 1280


def mobilenetv2_05_spec(num_classes: int = 1000):
    spec = [("stem_w", (3, 3, 3, MOBILENETV2_05_STEM))]
    ci = MOBILENETV2_05_STEM
    for bi, (t, c, n, s) in enumerate(MOBILENETV2_05_SETTING):
        for ri in range(n):
            for name, shape in bottleneck_spec(ci, c, t):
                spec.append((f"bn{bi}_{ri}_{name}", shape))
            ci = c
    spec.append(("last_w", (ci, MOBILENETV2_05_LAST)))
    spec.append(("fc_w", (MOBILENETV2_05_LAST, num_classes)))
    return spec


def mobilenetv2_05_fwd(x, *params):
    """MobileNetV2 x0.5. x: (N, H, W, 3) -> (N, classes)."""
    i = 0
    t = relu6(K.conv2d(x, params[i], stride=2)); i += 1
    for (tf, c, n, s) in MOBILENETV2_05_SETTING:
        for ri in range(n):
            stride = s if ri == 0 else 1
            np_ = 2 if tf == 1 else 3
            t = bottleneck_fwd(t, *params[i:i + np_], stride=stride, expand=tf)
            i += np_
    t = K.pwconv(t, params[i], act="relu6"); i += 1
    t = K.global_avgpool(t)
    return K.dense(t, params[i])


SHUFFLENETV2_05_STAGES = [
    # (c_out, repeats) — SNv2 paper table 5, 0.5x: stages 2/3/4
    (48, 4),
    (96, 8),
    (192, 4),
]
SHUFFLENETV2_05_STEM = 24
SHUFFLENETV2_05_LAST = 1024


def shufflenetv2_05_spec(num_classes: int = 1000):
    spec = [("stem_w", (3, 3, 3, SHUFFLENETV2_05_STEM))]
    ci = SHUFFLENETV2_05_STEM
    for si, (c, n) in enumerate(SHUFFLENETV2_05_STAGES):
        for name, shape in shuffle_reduce_spec(ci, c):
            spec.append((f"s{si}_red_{name}", shape))
        for ri in range(n - 1):
            for name, shape in shuffle_basic_spec(c):
                spec.append((f"s{si}_b{ri}_{name}", shape))
        ci = c
    spec.append(("last_w", (ci, SHUFFLENETV2_05_LAST)))
    spec.append(("fc_w", (SHUFFLENETV2_05_LAST, num_classes)))
    return spec


def shufflenetv2_05_fwd(x, *params):
    """ShuffleNetV2 x0.5. x: (N, H, W, 3) -> (N, classes)."""
    i = 0
    t = relu(K.conv2d(x, params[i], stride=2)); i += 1
    t = K.maxpool(t, k=3, stride=2)
    for (c, n) in SHUFFLENETV2_05_STAGES:
        t = shuffle_reduce_fwd(t, *params[i:i + 5]); i += 5
        for _ in range(n - 1):
            t = shuffle_basic_fwd(t, *params[i:i + 3]); i += 3
    t = K.pwconv(t, params[i], act="relu"); i += 1
    t = K.global_avgpool(t)
    return K.dense(t, params[i])


MODELS = {
    "squeezenet": (squeezenet_spec, squeezenet_fwd),
    "mobilenetv2_05": (mobilenetv2_05_spec, mobilenetv2_05_fwd),
    "shufflenetv2_05": (shufflenetv2_05_spec, shufflenetv2_05_fwd),
}
