"""AOT artifact integrity: manifest agrees with registry, HLO text is sane.

The registry is re-built in-process (cheap; no lowering) and cross-checked
against whatever `make artifacts` produced on disk. Runs only when the
artifacts directory exists.
"""

import json
import os

import pytest

from compile import aot

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)


@pytest.fixture(scope="module")
def manifest():
    with open(os.path.join(ART, "manifest.json")) as f:
        return json.load(f)


def test_manifest_covers_registry(manifest):
    reg = aot.build_registry(include_nets=True)
    missing = [n for (n, *_rest) in [(e[0],) for e in reg.entries] if n not in manifest]
    assert not missing, f"artifacts missing from manifest: {missing}"


def test_manifest_files_exist(manifest):
    for name, entry in manifest.items():
        path = os.path.join(ART, entry["file"])
        assert os.path.exists(path), f"{name}: {entry['file']} missing"
        assert os.path.getsize(path) > 100, f"{name}: suspiciously small HLO"


def test_hlo_text_parses_as_hlo(manifest):
    """Every artifact must be HLO text (ENTRY + parameters), not a proto."""
    for name, entry in manifest.items():
        with open(os.path.join(ART, entry["file"])) as f:
            text = f.read()
        assert "HloModule" in text, f"{name}: not HLO text"
        assert "ENTRY" in text, f"{name}: no entry computation"
        for i in range(len(entry["inputs"])):
            assert f"parameter({i})" in text, f"{name}: missing parameter({i})"


def test_manifest_shapes_are_positive(manifest):
    for name, entry in manifest.items():
        for io in entry["inputs"] + entry["outputs"]:
            assert all(d > 0 for d in io["shape"]), f"{name}: bad shape {io}"
        assert len(entry["outputs"]) >= 1


def test_partition_pairs_share_weight_shapes(manifest):
    """fire_full's expand3_w must equal fire_fpga's — the Rust equivalence
    harness feeds the same literal to both sides."""
    def shape_of(art, arg):
        ins = {i["name"]: i["shape"] for i in manifest[art]["inputs"]}
        return ins[arg]

    assert shape_of("fire_full", "expand3_w") == shape_of("fire_fpga", "expand3_w")
    assert shape_of("fire_full", "squeeze_w") == shape_of("fire_gpu", "squeeze_w")
    assert shape_of("bottleneck_full", "project_w") == shape_of("bottleneck_fpga", "project_w")
    assert shape_of("shuffle_reduce_full", "ld_w") == shape_of("shuffle_reduce_fpga", "ld_w")


def test_net_artifacts_take_224_input(manifest):
    for name in ("squeezenet_224", "mobilenetv2_05_224", "shufflenetv2_05_224"):
        x = manifest[name]["inputs"][0]
        assert x["shape"] == [1, 224, 224, 3], f"{name}: {x}"
        assert manifest[name]["outputs"][0]["shape"] == [1, 1000]
