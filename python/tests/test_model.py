"""L2 correctness: module partition==monolith invariants and model shapes.

These are the *functional* proofs behind the paper's Fig 2 partitionings:
splitting a module across devices must not change its output.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from numpy.testing import assert_allclose

from compile import model as M
from compile import kernels as K

RNG = np.random.default_rng(7)


def randf(*shape):
    return jnp.asarray(RNG.normal(size=shape).astype(np.float32))


# ---------------------------------------------------------------------------
# Fire (SqueezeNet) — GConv-style parallel split (Fig 2b / Fig 4a)


def fire_weights(ci=96, s=16, e1=64, e3=64):
    return randf(ci, s), randf(s, e1), randf(3, 3, s, e3)


def test_fire_split_equals_monolith():
    x = randf(1, 14, 14, 96)
    ws, we1, we3 = fire_weights()
    full = M.fire_fwd(x, ws, we1, we3)
    s, a = M.fire_gpu_fwd(x, ws, we1)
    b = M.fire_fpga_fwd_f32(s, we3)
    assert_allclose(jnp.concatenate([a, b], axis=-1), full, rtol=1e-4, atol=1e-4)


def test_fire_fpga_q8_tracks_float():
    x = randf(1, 14, 14, 96)
    ws, we1, we3 = fire_weights()
    s, _ = M.fire_gpu_fwd(x, ws, we1)
    bq = np.asarray(M.fire_fpga_fwd(s, we3))
    bf = np.asarray(M.fire_fpga_fwd_f32(s, we3))
    rel = np.abs(bq - bf).max() / (np.abs(bf).max() + 1e-9)
    assert rel < 0.05, f"DHM 8-bit path deviates {rel:.3f}"


def test_fire_output_channels():
    x = randf(1, 8, 8, 96)
    y = M.fire_fwd(x, *fire_weights())
    assert y.shape == (1, 8, 8, 128)


# ---------------------------------------------------------------------------
# Bottleneck (MobileNetV2) — DWConv sequential split (Fig 2a / Fig 4b)


def bn_weights(ci=16, t=6, co=16):
    return randf(ci, ci * t), randf(3, 3, ci * t), randf(ci * t, co)


@pytest.mark.parametrize("stride", [1, 2])
def test_bottleneck_split_equals_monolith(stride):
    x = randf(1, 14, 14, 16)
    we, wd, wp = bn_weights()
    full = M.bottleneck_fwd(x, we, wd, wp, stride=stride, expand=6)
    t = M.bottleneck_gpu_fwd(x, we, wd, stride=stride, expand=6)
    y = M.bottleneck_fpga_fwd_f32(t, wp)
    if stride == 1:  # residual applies on the re-joined GPU side
        y = y + x
    assert_allclose(y, full, rtol=1e-4, atol=1e-4)


def test_bottleneck_expand1_has_no_expand_conv():
    x = randf(1, 10, 10, 8)
    wd, wp = randf(3, 3, 8), randf(8, 8)
    y = M.bottleneck_fwd(x, wd, wp, stride=1, expand=1)
    assert y.shape == (1, 10, 10, 8)


def test_bottleneck_residual_only_when_shapes_match():
    x = randf(1, 10, 10, 16)
    we, wd, wp = bn_weights(co=24)
    y = M.bottleneck_fwd(x, we, wd, wp, stride=1, expand=6)
    assert y.shape[-1] == 24  # no residual; shape comes from projection


# ---------------------------------------------------------------------------
# ShuffleNetV2 units (Fig 4c)


def test_shuffle_basic_split_equals_monolith():
    c = 48
    x = randf(1, 14, 14, c)
    w1, wd, w2 = randf(c // 2, c // 2), randf(3, 3, c // 2), randf(c // 2, c // 2)
    full = M.shuffle_basic_fwd(x, w1, wd, w2)
    left, right = x[..., :c // 2], x[..., c // 2:]
    r = M.shuffle_basic_fpga_fwd(right, w1, wd, w2)  # fused FPGA branch
    got = M.channel_shuffle(jnp.concatenate([left, r], axis=-1))
    assert_allclose(got, full, rtol=1e-4, atol=1e-4)


def test_shuffle_reduce_split_equals_monolith():
    ci, co = 24, 48
    x = randf(1, 14, 14, ci)
    wld, wl1 = randf(3, 3, ci), randf(ci, co // 2)
    wr1, wrd, wr2 = randf(ci, co // 2), randf(3, 3, co // 2), randf(co // 2, co // 2)
    full = M.shuffle_reduce_fwd(x, wld, wl1, wr1, wrd, wr2)
    l = M.shuffle_reduce_fpga_fwd_f32(x, wld, wl1)
    r = M.shuffle_reduce_gpu_fwd(x, wr1, wrd, wr2)
    got = M.channel_shuffle(jnp.concatenate([l, r], axis=-1))
    assert_allclose(got, full, rtol=1e-4, atol=1e-4)


def test_shuffle_reduce_halves_spatial_doubles_channels():
    x = randf(1, 16, 16, 24)
    wld, wl1 = randf(3, 3, 24), randf(24, 24)
    wr1, wrd, wr2 = randf(24, 24), randf(3, 3, 24), randf(24, 24)
    y = M.shuffle_reduce_fwd(x, wld, wl1, wr1, wrd, wr2)
    assert y.shape == (1, 8, 8, 48)


def test_channel_shuffle_is_permutation():
    x = randf(1, 4, 4, 8)
    y = M.channel_shuffle(x, groups=2)
    assert sorted(np.asarray(x).ravel()) == sorted(np.asarray(y).ravel())
    # shuffle interleaves the two halves: out[2k] = in[k]
    assert_allclose(y[..., 0], x[..., 0])
    assert_allclose(y[..., 1], x[..., 4])


def test_channel_shuffle_involution_for_g2():
    """For G=2 and C=4k... shuffle twice with transposed grouping restores."""
    x = randf(1, 3, 3, 12)
    y = M.channel_shuffle(M.channel_shuffle(x, 2), 6)
    assert_allclose(y, x)


# ---------------------------------------------------------------------------
# full nets: shapes, spec/param agreement, determinism


@pytest.mark.parametrize("name", list(M.MODELS))
def test_model_spec_matches_fwd(name):
    spec_fn, fwd = M.MODELS[name]
    spec = spec_fn()
    params = M.init_params(spec, seed=3)
    x = randf(1, 64, 64, 3)
    y = fwd(x, *params)
    assert y.shape == (1, 1000)
    assert bool(jnp.all(jnp.isfinite(y)))


@pytest.mark.parametrize("name", list(M.MODELS))
def test_model_deterministic(name):
    spec_fn, fwd = M.MODELS[name]
    params = M.init_params(spec_fn(), seed=5)
    x = randf(2, 64, 64, 3)
    assert_allclose(fwd(x, *params), fwd(x, *params), rtol=0, atol=0)


def test_squeezenet_param_count():
    """SqueezeNet v1.0 has ~1.24M weights (sanity vs the published table)."""
    spec = M.squeezenet_spec()
    n = sum(int(np.prod(s)) for _, s in spec)
    assert 1.1e6 < n < 1.4e6, f"param count {n}"


def test_mobilenetv2_05_param_count():
    """MNv2 x0.5 conv stack (no BN/bias) lands near the published ~2M total."""
    spec = M.mobilenetv2_05_spec()
    n = sum(int(np.prod(s)) for _, s in spec)
    assert 1.2e6 < n < 2.5e6, f"param count {n}"


def test_shufflenetv2_05_param_count():
    spec = M.shufflenetv2_05_spec()
    n = sum(int(np.prod(s)) for _, s in spec)
    assert 0.8e6 < n < 1.8e6, f"param count {n}"


def test_batch_consistency():
    """Batched forward == stacked single forwards (grid-over-batch kernels)."""
    spec_fn, fwd = M.MODELS["squeezenet"]
    params = M.init_params(spec_fn(), seed=9)
    xs = randf(2, 64, 64, 3)
    yb = fwd(xs, *params)
    y0 = fwd(xs[:1], *params)
    y1 = fwd(xs[1:], *params)
    assert_allclose(yb, jnp.concatenate([y0, y1]), rtol=1e-4, atol=1e-4)
