"""Structural perf assertions over the L1 kernels (DESIGN.md §7 L1 targets).

interpret=True gives no TPU wall-clock, so the enforceable targets are
structural: every kernel geometry the nets use must fit VMEM, and the
MXU-facing kernels must keep a sane utilization estimate.
"""

from compile import analysis as A


def test_all_paper_geometries_fit_vmem():
    for p in A.paper_profiles():
        assert p.vmem_frac < 1.0, f"{p.name}: {p.vmem_frac:.2f} of VMEM"


def test_most_geometries_fit_comfortably():
    fracs = [p.vmem_frac for p in A.paper_profiles()]
    assert sum(f < 0.5 for f in fracs) >= len(fracs) - 1, fracs


def test_mxu_estimate_bounds():
    for p in A.paper_profiles():
        assert 0.0 <= p.mxu_estimate <= 1.0, p.name


def test_pwconv_mxu_beats_small_conv():
    # channel-rich pwconv (K=96) should use the MXU better than the
    # 3-channel Fig-1 stem conv (K=3)
    pw = A.profile_pwconv(28, 28, 96, 16)
    stem = A.profile_conv2d(224, 224, 3, 64, 3)
    assert pw.mxu_estimate > stem.mxu_estimate


def test_classifier_tiles_saturate_k():
    p = A.profile_matmul(8, 1024, 1000)
    # K=1024 >> 128: the contraction dim fully feeds the systolic array
    assert p.mxu_estimate > 0.05
    assert p.vmem_frac < 0.7


def test_dwconv_is_vpu_work():
    assert A.profile_dwconv(28, 28, 96).mxu_estimate == 0.0


def test_mxu_utilization_formula():
    assert A.mxu_utilization(128, 128, 128) == 1.0
    assert abs(A.mxu_utilization(64, 128, 128) - 0.5) < 1e-12
    assert A.mxu_utilization(1, 1, 1) < 1e-4


def test_fused_kernel_scratch_counted():
    p = A.profile_fused_pw_dw_pw(28, 28, 24, 24, 24)
    assert "t(scratch)" in p.blocks
    assert p.vmem_bytes > A.profile_pwconv(28, 28, 24, 24).vmem_bytes
