"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Parametric sweeps cover the shape/stride/kernel-size space the three CNNs
actually use; hypothesis sweeps random shapes/dtypes beyond that (system
prompt (c): hypothesis on the kernel's shapes, assert_allclose vs ref).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import kernels as K
from compile.kernels import ref as R
from compile.kernels import quant as Q

RNG = np.random.default_rng(1234)


def randf(*shape):
    return jnp.asarray(RNG.normal(size=shape).astype(np.float32))


# ---------------------------------------------------------------------------
# conv2d


@pytest.mark.parametrize("n", [1, 2])
@pytest.mark.parametrize("k", [1, 3, 5, 7])
@pytest.mark.parametrize("stride", [1, 2])
def test_conv2d_matches_ref(n, k, stride):
    x = randf(n, 14, 14, 6)
    w = randf(k, k, 6, 9)
    assert_allclose(K.conv2d(x, w, stride=stride),
                    R.conv2d_ref(x, w, stride=stride), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("pad", [0, 1, 2])
def test_conv2d_explicit_padding(pad):
    x = randf(1, 12, 12, 4)
    w = randf(3, 3, 4, 8)
    assert_allclose(K.conv2d(x, w, padding=pad),
                    R.conv2d_ref(x, w, padding=pad), rtol=1e-4, atol=1e-4)


def test_conv2d_rect_input():
    x = randf(1, 10, 16, 3)
    w = randf(3, 3, 3, 5)
    assert_allclose(K.conv2d(x, w), R.conv2d_ref(x, w), rtol=1e-4, atol=1e-4)


def test_conv2d_channel_mismatch_raises():
    with pytest.raises(AssertionError):
        K.conv2d(randf(1, 8, 8, 4), randf(3, 3, 5, 8))


@settings(max_examples=25, deadline=None)
@given(
    h=st.integers(6, 20), w=st.integers(6, 20),
    ci=st.integers(1, 8), co=st.integers(1, 12),
    k=st.sampled_from([1, 3, 5]), stride=st.sampled_from([1, 2]),
)
def test_conv2d_hypothesis(h, w, ci, co, k, stride):
    x = randf(1, h, w, ci)
    wt = randf(k, k, ci, co)
    assert_allclose(K.conv2d(x, wt, stride=stride),
                    R.conv2d_ref(x, wt, stride=stride), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("k,stride", [(1, 1), (3, 1), (3, 2), (5, 1)])
def test_conv2d_q8_matches_ref(k, stride):
    x = randf(1, 12, 12, 5)
    w = randf(k, k, 5, 7)
    assert_allclose(K.conv2d_q8(x, w, stride=stride),
                    R.conv2d_q8_ref(x, w, stride=stride), rtol=1e-4, atol=1e-4)


def test_conv2d_q8_close_to_float():
    """8-bit fixed point should track the float conv within quant noise
    (paper §I: 8-bit chosen to avoid hurting accuracy)."""
    x = randf(1, 16, 16, 8)
    w = randf(3, 3, 8, 16)
    yq = np.asarray(K.conv2d_q8(x, w))
    yf = np.asarray(R.conv2d_ref(x, w))
    rel = np.abs(yq - yf).max() / (np.abs(yf).max() + 1e-9)
    assert rel < 0.05, f"q8 deviates {rel:.3f} from float"


# ---------------------------------------------------------------------------
# dwconv


@pytest.mark.parametrize("k", [3, 5])
@pytest.mark.parametrize("stride", [1, 2])
def test_dwconv_matches_ref(k, stride):
    x = randf(2, 14, 14, 6)
    w = randf(k, k, 6)
    assert_allclose(K.dwconv(x, w, stride=stride),
                    R.dwconv_ref(x, w, stride=stride), rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(h=st.integers(6, 18), c=st.integers(1, 12), stride=st.sampled_from([1, 2]))
def test_dwconv_hypothesis(h, c, stride):
    x = randf(1, h, h, c)
    w = randf(3, 3, c)
    assert_allclose(K.dwconv(x, w, stride=stride),
                    R.dwconv_ref(x, w, stride=stride), rtol=1e-4, atol=1e-4)


def test_dwconv_q8_matches_ref():
    x = randf(1, 12, 12, 8)
    w = randf(3, 3, 8)
    assert_allclose(K.dwconv_q8(x, w), R.dwconv_q8_ref(x, w), rtol=1e-4, atol=1e-4)


def test_dwconv_is_diagonal_of_full_conv():
    """dwconv == conv2d with a channel-diagonal kernel (cross-impl invariant)."""
    c = 4
    x = randf(1, 10, 10, c)
    wd = randf(3, 3, c)
    wfull = np.zeros((3, 3, c, c), np.float32)
    for ci in range(c):
        wfull[:, :, ci, ci] = np.asarray(wd)[:, :, ci]
    assert_allclose(K.dwconv(x, wd), K.conv2d(x, jnp.asarray(wfull)),
                    rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# pwconv


@pytest.mark.parametrize("act", ["none", "relu", "relu6"])
def test_pwconv_matches_ref(act):
    x = randf(2, 9, 9, 12)
    w = randf(12, 20)
    assert_allclose(K.pwconv(x, w, act=act), R.pwconv_ref(x, w, act=act),
                    rtol=1e-4, atol=1e-4)


def test_pwconv_equals_conv2d_1x1():
    x = randf(1, 8, 8, 6)
    w = randf(6, 10)
    assert_allclose(K.pwconv(x, w), K.conv2d(x, w[None, None]),
                    rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("act", ["none", "relu"])
def test_pwconv_q8_matches_ref(act):
    x = randf(1, 10, 10, 8)
    w = randf(8, 16)
    assert_allclose(K.pwconv_q8(x, w, act=act), R.pwconv_q8_ref(x, w, act=act),
                    rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(h=st.integers(2, 16), ci=st.integers(1, 16), co=st.integers(1, 24))
def test_pwconv_hypothesis(h, ci, co):
    x = randf(1, h, h, ci)
    w = randf(ci, co)
    assert_allclose(K.pwconv(x, w), R.pwconv_ref(x, w), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# gconv


@pytest.mark.parametrize("groups", [1, 2, 4])
def test_gconv_matches_ref(groups):
    ci, cog = 8, 6
    x = randf(1, 10, 10, ci)
    w = randf(groups, 3, 3, ci // groups, cog)
    assert_allclose(K.gconv(x, w, groups=groups),
                    R.gconv_ref(x, w, groups=groups), rtol=1e-4, atol=1e-4)


def test_gconv_g1_equals_conv2d():
    x = randf(1, 8, 8, 6)
    w = randf(3, 3, 6, 9)
    assert_allclose(K.gconv(x, w[None], groups=1), K.conv2d(x, w),
                    rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("split", [1, 3, 5])
def test_gconv_split_sums_to_monolith(split):
    """Fig 2b invariant: FPGA part + GPU part == full conv."""
    x = randf(1, 9, 9, 6)
    w = randf(3, 3, 6, 10)
    f, g = K.gconv_split(x, w, split=split)
    assert_allclose(f + g, R.conv2d_ref(x, w), rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(g=st.sampled_from([1, 2, 3]), cig=st.integers(1, 5), stride=st.sampled_from([1, 2]))
def test_gconv_hypothesis(g, cig, stride):
    x = randf(1, 12, 12, g * cig)
    w = randf(g, 3, 3, cig, 4)
    assert_allclose(K.gconv(x, w, groups=g, stride=stride),
                    R.gconv_ref(x, w, groups=g, stride=stride),
                    rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# pooling


@pytest.mark.parametrize("k,stride", [(2, 2), (3, 2), (3, 1)])
def test_maxpool_matches_ref(k, stride):
    x = randf(2, 13, 13, 5)
    assert_allclose(K.maxpool(x, k=k, stride=stride),
                    R.maxpool_ref(x, k=k, stride=stride), rtol=1e-6)


def test_global_avgpool_matches_ref():
    x = randf(3, 7, 7, 16)
    assert_allclose(K.global_avgpool(x), R.global_avgpool_ref(x),
                    rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# fused chains


@pytest.mark.parametrize("stride", [1, 2])
def test_fused_pw_dw_pw_matches_ref(stride):
    x = randf(1, 12, 12, 6)
    w1, wd, w2 = randf(6, 10), randf(3, 3, 10), randf(10, 8)
    assert_allclose(K.fused_pw_dw_pw(x, w1, wd, w2, stride=stride),
                    R.fused_pw_dw_pw_ref(x, w1, wd, w2, stride=stride),
                    rtol=1e-4, atol=1e-4)


def test_fused_pw_pw_matches_ref():
    x = randf(1, 10, 10, 6)
    w1, w2 = randf(6, 12), randf(12, 8)
    assert_allclose(K.fused_pw_pw(x, w1, w2), R.fused_pw_pw_ref(x, w1, w2),
                    rtol=1e-4, atol=1e-4)


def test_fused_equals_unfused_chain():
    """Fused-layer invariant (Fig 2c): fusion changes locality, not values."""
    x = randf(1, 9, 9, 5)
    w1, wd, w2 = randf(5, 8), randf(3, 3, 8), randf(8, 6)
    t = K.pwconv(x, w1, act="relu")
    t = K.dwconv(t, wd)
    want = K.pwconv(t, w2, act="relu")
    assert_allclose(K.fused_pw_dw_pw(x, w1, wd, w2), want, rtol=1e-4, atol=1e-4)


def test_fused_pw_pw_q8_tracks_float():
    x = randf(1, 10, 10, 6)
    w1, w2 = randf(6, 12), randf(12, 8)
    yq = np.asarray(K.fused_pw_pw_q8(x, w1, w2))
    yf = np.asarray(R.fused_pw_pw_ref(x, w1, w2))
    rel = np.abs(yq - yf).max() / (np.abs(yf).max() + 1e-9)
    assert rel < 0.08, f"fused q8 deviates {rel:.3f}"


# ---------------------------------------------------------------------------
# quantization properties


def test_quant_roundtrip_error_bound():
    x = randf(64, 64)
    s = Q.scale_for(x)
    err = np.abs(np.asarray(Q.fake_quant(x, s) - x)).max()
    assert err <= float(s) / 2 + 1e-7


def test_quant_saturates():
    s = jnp.float32(0.1)
    assert int(Q.quantize(jnp.float32(1e9), s)) == Q.QMAX
    assert int(Q.quantize(jnp.float32(-1e9), s)) == Q.QMIN


@settings(max_examples=30, deadline=None)
@given(st.floats(-50, 50, allow_nan=False))
def test_quant_monotone(v):
    """Quantization preserves order vs 0 (sign)."""
    s = jnp.float32(0.5)
    q = float(Q.quantize(jnp.float32(v), s))
    if v > 0.25:
        assert q >= 0
    if v < -0.25:
        assert q <= 0


def test_scale_for_zero_input_safe():
    assert float(Q.scale_for(jnp.zeros((4, 4)))) > 0


# ---------------------------------------------------------------------------
# tiled matmul (classifier head)


@pytest.mark.parametrize("m,k,n", [(1, 64, 100), (4, 1280, 1000), (8, 1024, 1000), (3, 7, 11)])
def test_matmul_matches_ref(m, k, n):
    x = randf(m, k)
    w = randf(k, n)
    assert_allclose(K.matmul(x, w), R.matmul_ref(x, w), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("tm,tn", [(1, 1), (2, 64), (128, 128), (7, 1000)])
def test_matmul_tiling_invariant(tm, tn):
    """Any tile choice must produce identical results."""
    x = randf(4, 96)
    w = randf(96, 50)
    assert_allclose(K.matmul(x, w, tm=tm, tn=tn), R.matmul_ref(x, w),
                    rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(m=st.integers(1, 8), k=st.integers(1, 64), n=st.integers(1, 64))
def test_matmul_hypothesis(m, k, n):
    x = randf(m, k)
    w = randf(k, n)
    assert_allclose(K.matmul(x, w), R.matmul_ref(x, w), rtol=1e-4, atol=1e-4)


def test_dense_is_matmul():
    x = randf(2, 32)
    w = randf(32, 10)
    assert_allclose(K.dense(x, w), K.matmul(x, w), rtol=0, atol=0)


# ---------------------------------------------------------------------------
# im2col conv — the independent second implementation


@pytest.mark.parametrize("k", [1, 3, 5])
@pytest.mark.parametrize("stride", [1, 2])
def test_im2col_matches_ref(k, stride):
    x = randf(1, 13, 13, 5)
    w = randf(k, k, 5, 7)
    assert_allclose(K.conv2d_im2col(x, w, stride=stride),
                    R.conv2d_ref(x, w, stride=stride), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("stride", [1, 2])
def test_im2col_agrees_with_shifted_slice_impl(stride):
    """Two structurally different Pallas convolutions must agree — the
    GPU-style (im2col+GEMM) vs DHM-style (shifted-slice MACs) contrast."""
    x = randf(2, 11, 11, 4)
    w = randf(3, 3, 4, 6)
    assert_allclose(K.conv2d_im2col(x, w, stride=stride),
                    K.conv2d(x, w, stride=stride), rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(h=st.integers(6, 16), ci=st.integers(1, 6), co=st.integers(1, 8),
       k=st.sampled_from([1, 3]), stride=st.sampled_from([1, 2]))
def test_im2col_hypothesis(h, ci, co, k, stride):
    x = randf(1, h, h, ci)
    w = randf(k, k, ci, co)
    assert_allclose(K.conv2d_im2col(x, w, stride=stride),
                    R.conv2d_ref(x, w, stride=stride), rtol=1e-4, atol=1e-4)


def test_im2col_explicit_padding():
    x = randf(1, 10, 10, 3)
    w = randf(3, 3, 3, 4)
    assert_allclose(K.conv2d_im2col(x, w, padding=0),
                    R.conv2d_ref(x, w, padding=0), rtol=1e-4, atol=1e-4)
