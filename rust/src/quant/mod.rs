//! int8 fixed-point quantization mirroring `python/compile/kernels/quant.py`.
//!
//! The coordinator quantizes feature maps before they cross the PCIe link
//! (DHM consumes 8-bit fixed point — paper §I), so the link model sees
//! 1-byte elements and the numerics match what the FPGA-side artifacts
//! compute. `quantize`/`dequantize` are bit-exact twins of the Python side
//! (round-half-to-even, symmetric per-tensor scale).

pub const QMIN: i32 = -128;
pub const QMAX: i32 = 127;

/// Symmetric per-tensor scale so max|x| maps to 127 (matches quant.py).
pub fn scale_for(xs: &[f32]) -> f32 {
    let amax = xs.iter().fold(0.0f32, |m, &v| m.max(v.abs())).max(1e-8);
    amax / QMAX as f32
}

/// Round-half-to-even, the IEEE default `jnp.round` uses.
fn round_ties_even(v: f32) -> f32 {
    let r = v.round(); // half-away-from-zero
    if (v - v.trunc()).abs() == 0.5 {
        // tie: pick the even neighbour
        let down = v.floor();
        let up = v.ceil();
        if (down as i64) % 2 == 0 { down } else { up }
    } else {
        r
    }
}

/// f32 slice -> int8 with saturation.
pub fn quantize(xs: &[f32], scale: f32) -> Vec<i8> {
    xs.iter()
        .map(|&v| round_ties_even(v / scale).clamp(QMIN as f32, QMAX as f32) as i8)
        .collect()
}

/// int8 slice -> f32.
pub fn dequantize(qs: &[i8], scale: f32) -> Vec<f32> {
    qs.iter().map(|&q| q as f32 * scale).collect()
}

/// Quantize-dequantize round trip (what the FPGA boundary does to features).
pub fn fake_quant(xs: &[f32], scale: f32) -> Vec<f32> {
    dequantize(&quantize(xs, scale), scale)
}

/// Max absolute round-trip error is bounded by scale/2 (+ saturation).
pub fn roundtrip_error_bound(scale: f32) -> f32 {
    scale / 2.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_maps_max_to_127() {
        let xs = [0.5f32, -2.54, 1.0];
        let s = scale_for(&xs);
        assert!((s - 2.54 / 127.0).abs() < 1e-7);
        let q = quantize(&xs, s);
        assert_eq!(q[1], -127);
    }

    #[test]
    fn roundtrip_error_within_half_scale() {
        let xs: Vec<f32> = (0..1000).map(|i| (i as f32 * 0.37).sin() * 3.0).collect();
        let s = scale_for(&xs);
        let rt = fake_quant(&xs, s);
        for (a, b) in xs.iter().zip(&rt) {
            assert!((a - b).abs() <= roundtrip_error_bound(s) + 1e-6);
        }
    }

    #[test]
    fn saturation() {
        let q = quantize(&[1e9, -1e9], 0.1);
        assert_eq!(q, vec![127, -128]);
    }

    #[test]
    fn ties_round_to_even() {
        // 0.5/1.0 = 0.5 -> 0 (even); 1.5 -> 2; 2.5 -> 2
        let q = quantize(&[0.5, 1.5, 2.5], 1.0);
        assert_eq!(q, vec![0, 2, 2]);
        let q = quantize(&[-0.5, -1.5, -2.5], 1.0);
        assert_eq!(q, vec![0, -2, -2]);
    }

    #[test]
    fn zero_input_safe() {
        let s = scale_for(&[0.0, 0.0]);
        assert!(s > 0.0);
        assert_eq!(quantize(&[0.0], s), vec![0]);
    }

    #[test]
    fn dequantize_inverts_exactly_on_grid() {
        let s = 0.03f32;
        let qs: Vec<i8> = (-128..=127).collect();
        let xs = dequantize(&qs, s);
        assert_eq!(quantize(&xs, s), qs);
    }
}
