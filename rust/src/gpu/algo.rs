//! Per-convolution algorithm selection — a cuDNN-style refinement of the
//! base roofline model.
//!
//! PyTorch on the TX2 dispatches each convolution to the fastest cuDNN
//! algorithm; the base [`super::GpuModel`] folds that into one efficiency
//! factor per op class. This module models the choice explicitly:
//!
//! - `Im2colGemm`   — materializes the patch matrix (extra DRAM traffic,
//!                    best GEMM shape),
//! - `ImplicitGemm` — no materialization, slightly lower compute eff,
//! - `Winograd`     — 3x3 stride-1 only: 2.25x fewer MACs, lower eff and
//!                    extra transform traffic,
//! - `Direct`       — depth-wise / tiny shapes.
//!
//! [`AlgoGpuModel::cost`] picks the argmin like cuDNN's heuristic would.
//! The `algo-ablation` comparison (bench hotpath / tests) quantifies how
//! much the refinement moves the paper's Fig 1/Fig 4 results; the shipped
//! experiments keep the calibrated base model (DESIGN.md §2).

use super::{GpuDevice, GpuModel, JETSON_TX2};
use crate::graph::{Layer, OpKind};
use crate::metrics::Cost;

/// Convolution algorithms the selector considers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConvAlgo {
    Im2colGemm,
    ImplicitGemm,
    Winograd,
    Direct,
}

/// Refined GPU model with algorithm selection.
#[derive(Debug, Clone, Copy)]
pub struct AlgoGpuModel {
    pub dev: GpuDevice,
}

impl Default for AlgoGpuModel {
    fn default() -> Self {
        Self { dev: JETSON_TX2 }
    }
}

/// (effective flops fraction, extra DRAM traffic factor on the IFM).
fn algo_params(a: ConvAlgo) -> (f64, f64) {
    match a {
        ConvAlgo::Im2colGemm => (0.50, 2.0),   // patch matrix write+read
        ConvAlgo::ImplicitGemm => (0.40, 1.0),
        ConvAlgo::Winograd => (0.30, 1.6),     // tile transforms
        ConvAlgo::Direct => (0.15, 1.0),
    }
}

impl AlgoGpuModel {
    /// Algorithms applicable to a layer.
    pub fn applicable(&self, l: &Layer) -> Vec<ConvAlgo> {
        match l.op {
            OpKind::Conv { k, stride, .. } => {
                let mut v = vec![ConvAlgo::Im2colGemm, ConvAlgo::ImplicitGemm, ConvAlgo::Direct];
                if k == 3 && stride == 1 {
                    v.push(ConvAlgo::Winograd);
                }
                v
            }
            OpKind::PwConv { .. } | OpKind::GConv { .. } | OpKind::Dense { .. } => {
                vec![ConvAlgo::Im2colGemm, ConvAlgo::ImplicitGemm]
            }
            OpKind::DwConv { .. } => vec![ConvAlgo::Direct],
            _ => vec![ConvAlgo::Direct],
        }
    }

    /// Execution time of one layer under one algorithm (no launch cost).
    pub fn exec_time_with(&self, l: &Layer, a: ConvAlgo) -> f64 {
        let (eff, traffic) = algo_params(a);
        let flops = match a {
            ConvAlgo::Winograd => 2.0 * l.macs() as f64 / 2.25,
            _ => 2.0 * l.macs() as f64,
        };
        let t_compute = if flops > 0.0 { flops / (self.dev.peak_flops * eff) } else { 0.0 };
        let bytes = (l.input.elems() as f64 * traffic
            + l.output.elems() as f64
            + l.weight_count() as f64)
            * 4.0;
        let t_mem = bytes / self.dev.mem_bw;
        t_compute.max(t_mem)
    }

    /// cuDNN-heuristic pick: the fastest applicable algorithm.
    pub fn select(&self, l: &Layer) -> ConvAlgo {
        self.applicable(l)
            .into_iter()
            .min_by(|&a, &b| {
                self.exec_time_with(l, a)
                    .partial_cmp(&self.exec_time_with(l, b))
                    .unwrap()
            })
            .unwrap_or(ConvAlgo::Direct)
    }

    /// Full dispatch cost under the selected algorithm.
    pub fn cost(&self, l: &Layer) -> (ConvAlgo, Cost) {
        let a = self.select(l);
        let exec = self.exec_time_with(l, a);
        let lat = self.dev.launch_overhead + exec;
        // reuse the base model's power curve at the refined utilization
        let base = GpuModel::default();
        let util = ((exec / lat) * 0.8).max(0.3);
        let p = base.dev.p_idle + (base.dev.p_max - base.dev.p_idle) * util;
        (a, Cost::new(lat, p * lat))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Activation, Layer, OpKind, TensorShape};

    fn conv(h: usize, ci: usize, k: usize, n: usize, stride: usize) -> Layer {
        Layer::new(
            OpKind::Conv { k, stride, pad: k / 2, cout: n, act: Activation::Relu },
            TensorShape::new(h, h, ci),
        )
    }

    #[test]
    fn winograd_only_for_3x3_s1() {
        let m = AlgoGpuModel::default();
        assert!(m.applicable(&conv(56, 64, 3, 64, 1)).contains(&ConvAlgo::Winograd));
        assert!(!m.applicable(&conv(56, 64, 3, 64, 2)).contains(&ConvAlgo::Winograd));
        assert!(!m.applicable(&conv(56, 64, 5, 64, 1)).contains(&ConvAlgo::Winograd));
    }

    #[test]
    fn winograd_wins_big_3x3() {
        // compute-bound 3x3: 2.25x MAC reduction dominates
        let m = AlgoGpuModel::default();
        assert_eq!(m.select(&conv(56, 128, 3, 128, 1)), ConvAlgo::Winograd);
    }

    #[test]
    fn dwconv_forced_direct() {
        let m = AlgoGpuModel::default();
        let dw = Layer::new(
            OpKind::DwConv { k: 3, stride: 1, act: Activation::Relu6 },
            TensorShape::new(28, 28, 96),
        );
        assert_eq!(m.select(&dw), ConvAlgo::Direct);
    }

    #[test]
    fn memory_bound_shapes_avoid_im2col() {
        // tiny compute, big IFM: im2col's 2x traffic must lose
        let m = AlgoGpuModel::default();
        let l = conv(224, 3, 1, 2, 1);
        assert_ne!(m.select(&l), ConvAlgo::Im2colGemm);
    }

    #[test]
    fn selection_never_slower_than_any_applicable() {
        let m = AlgoGpuModel::default();
        for l in [conv(56, 64, 3, 64, 1), conv(112, 16, 5, 32, 2), conv(14, 256, 1, 512, 1)] {
            let chosen = m.select(&l);
            let t = m.exec_time_with(&l, chosen);
            for a in m.applicable(&l) {
                assert!(t <= m.exec_time_with(&l, a) + 1e-15);
            }
        }
    }

    #[test]
    fn refined_cost_same_order_as_base_model() {
        // the refinement should stay within ~3x of the calibrated base
        // model for typical layers (sanity against wild divergence)
        let base = GpuModel::default();
        let algo = AlgoGpuModel::default();
        for l in [conv(56, 64, 3, 64, 1), conv(28, 96, 1, 24, 1), conv(112, 16, 3, 32, 2)] {
            let b = base.cost(&l).seconds;
            let (_, a) = algo.cost(&l);
            let ratio = a.seconds / b;
            assert!((0.3..3.0).contains(&ratio), "ratio {ratio}");
        }
    }
}
