//! Jetson TX2 GPU performance/energy model.
//!
//! The paper's GPU-side numbers are measured from PyTorch-generated CUDA
//! kernels on a Jetson TX2 with its on-module INA3221 power monitor.
//! We replace the silicon with a calibrated roofline model (DESIGN.md §2):
//!
//!   latency = launch_overhead + max(flops / (peak * eff_op),
//!                                   bytes / (bw * eff_mem))
//!
//! with per-op-class efficiency factors (depth-wise convs are notoriously
//! inefficient on SIMT hardware; 1x1 convs hit the GEMM fast path), and
//!
//!   power = p_idle + (p_max - p_idle) * utilization
//!
//! so energy concentrates in the big compute-bound convs exactly as the
//! TX2 power rails show. This reproduces Fig 1's GPU curves: flat,
//! launch-bound latency for small layers, rising once compute dominates.

pub mod algo;

use crate::graph::{Layer, OpKind};
use crate::metrics::Cost;

/// Model parameters for an embedded GPU.
#[derive(Debug, Clone, Copy)]
pub struct GpuDevice {
    pub name: &'static str,
    /// Peak FP32 FMA throughput (FLOP/s): 256 cores * 2 * 1.3 GHz.
    pub peak_flops: f64,
    /// Effective DRAM bandwidth (B/s) after LPDDR4 efficiency.
    pub mem_bw: f64,
    /// Per-kernel launch + framework overhead (s). PyTorch on TX2 is
    /// launch-bound for small layers (paper Fig 1a's flat region).
    pub launch_overhead: f64,
    /// GPU-rail idle power (W) — drawn whenever the module waits.
    pub p_idle: f64,
    /// GPU-rail power at full utilization (W).
    pub p_max: f64,
}

/// The board the paper uses (Jetson TX2, Pascal 256-core @ 1.3 GHz).
pub const JETSON_TX2: GpuDevice = GpuDevice {
    name: "Jetson TX2",
    peak_flops: 665.6e9,
    mem_bw: 35.8e9, // 59.7 GB/s theoretical x 0.6 achievable
    launch_overhead: 150.0e-6,
    p_idle: 0.5,
    p_max: 7.5,
};

/// Per-op-class compute efficiency (fraction of peak the CUDA kernel
/// sustains). Calibrated against published TX2 convnet benchmarks.
#[derive(Debug, Clone, Copy)]
pub struct GpuEfficiency {
    pub conv: f64,
    pub pwconv: f64,
    pub dwconv: f64,
    pub gconv: f64,
    pub dense: f64,
}

pub const TX2_EFFICIENCY: GpuEfficiency = GpuEfficiency {
    conv: 0.35,   // implicit-GEMM conv
    pwconv: 0.45, // maps straight onto GEMM
    dwconv: 0.10, // low arithmetic intensity, poor SIMT mapping
    gconv: 0.25,  // grouped conv: worse GEMM shapes than dense conv
    dense: 0.50,
};

/// Roofline + launch-overhead GPU cost model.
#[derive(Debug, Clone, Copy)]
pub struct GpuModel {
    pub dev: GpuDevice,
    pub eff: GpuEfficiency,
    /// Bytes per feature/weight element (4 = f32; the GPU path runs float).
    pub elem_bytes: usize,
}

impl Default for GpuModel {
    fn default() -> Self {
        Self { dev: JETSON_TX2, eff: TX2_EFFICIENCY, elem_bytes: 4 }
    }
}

impl GpuModel {
    pub fn new(dev: GpuDevice, eff: GpuEfficiency) -> Self {
        Self { dev, eff, elem_bytes: 4 }
    }

    fn class_eff(&self, l: &Layer) -> f64 {
        match l.op {
            OpKind::Conv { .. } => self.eff.conv,
            OpKind::PwConv { .. } => self.eff.pwconv,
            OpKind::DwConv { .. } => self.eff.dwconv,
            OpKind::GConv { .. } => self.eff.gconv,
            OpKind::Dense { .. } => self.eff.dense,
            // pooling & data movement: bandwidth-bound, eff handled by mem term
            _ => 1.0,
        }
    }

    /// DRAM traffic for one kernel: read input + weights, write output.
    pub fn bytes(&self, l: &Layer) -> u64 {
        ((l.input.elems() + l.output.elems()) as u64 + l.weight_count())
            * self.elem_bytes as u64
    }

    /// Kernel execution time EXCLUDING launch overhead (s).
    pub fn exec_time(&self, l: &Layer) -> f64 {
        let flops = 2.0 * l.macs() as f64;
        let t_compute = if flops > 0.0 {
            flops / (self.dev.peak_flops * self.class_eff(l))
        } else {
            0.0
        };
        let t_mem = self.bytes(l) as f64 / self.dev.mem_bw;
        t_compute.max(t_mem)
    }

    /// Full latency of one kernel dispatch (s).
    pub fn latency(&self, l: &Layer) -> f64 {
        self.dev.launch_overhead + self.exec_time(l)
    }

    /// Average power over the dispatch: idle floor + utilization-scaled
    /// dynamic power (utilization = exec fraction x roofline occupancy).
    pub fn power(&self, l: &Layer) -> f64 {
        let exec = self.exec_time(l);
        let lat = self.latency(l);
        let occupancy = if exec > 0.0 {
            let flops = 2.0 * l.macs() as f64;
            let t_compute = flops / (self.dev.peak_flops * self.class_eff(l));
            (t_compute / exec).clamp(0.3, 1.0) // mem-bound kernels still toggle
        } else {
            0.0
        };
        // Dispatch floor: during launch overhead the SMs idle but the CPU
        // driver + memory controller stay busy (INA3221 shows ~3 W on the
        // TX2 rails even for launch-bound kernels).
        let util = ((exec / lat) * occupancy).max(0.3);
        self.dev.p_idle + (self.dev.p_max - self.dev.p_idle) * util
    }

    /// Cost of one kernel dispatch.
    pub fn cost(&self, l: &Layer) -> Cost {
        let lat = self.latency(l);
        Cost::new(lat, self.power(l) * lat)
    }

    /// Cost of a data-movement op the framework still launches as a kernel
    /// (concat / shuffle / split): pure bandwidth + launch overhead.
    pub fn data_movement_cost(&self, bytes: u64) -> Cost {
        let lat = self.dev.launch_overhead + bytes as f64 / self.dev.mem_bw;
        Cost::new(lat, self.dev.p_idle * lat + 0.3 * (self.dev.p_max - self.dev.p_idle) * lat)
    }

    /// Energy burned idling for `seconds` (while the FPGA/link works).
    pub fn idle_cost(&self, seconds: f64) -> Cost {
        Cost::new(seconds, self.dev.p_idle * seconds)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Activation, Layer, OpKind, TensorShape};

    fn conv(h: usize, ci: usize, k: usize, n: usize) -> Layer {
        Layer::new(
            OpKind::Conv { k, stride: 1, pad: k / 2, cout: n, act: Activation::Relu },
            TensorShape::new(h, h, ci),
        )
    }

    #[test]
    fn small_convs_are_launch_bound() {
        // Fig 1a flat region: tiny layers cost ~ the launch overhead
        let m = GpuModel::default();
        let l = m.latency(&conv(28, 3, 3, 2));
        assert!(l < 1.5 * m.dev.launch_overhead, "latency {l}");
    }

    #[test]
    fn latency_monotone_in_filters() {
        let m = GpuModel::default();
        let mut prev = 0.0;
        for n in [2, 4, 8, 16, 32, 64] {
            let l = m.latency(&conv(224, 3, 3, n));
            assert!(l >= prev);
            prev = l;
        }
    }

    #[test]
    fn big_conv_is_compute_bound() {
        let m = GpuModel::default();
        let l = conv(224, 64, 3, 64);
        let flops = 2.0 * l.macs() as f64;
        let t_compute = flops / (m.dev.peak_flops * m.eff.conv);
        assert!((m.exec_time(&l) - t_compute).abs() / t_compute < 1e-9);
    }

    #[test]
    fn dwconv_slower_per_mac_than_conv() {
        let m = GpuModel::default();
        let dw = Layer::new(
            OpKind::DwConv { k: 3, stride: 1, act: Activation::Relu6 },
            TensorShape::new(56, 56, 96),
        );
        let cv = conv(56, 96, 3, 96);
        let dw_per_mac = m.exec_time(&dw) / dw.macs() as f64;
        let cv_per_mac = m.exec_time(&cv) / cv.macs() as f64;
        assert!(dw_per_mac > 2.0 * cv_per_mac, "dw should be far less efficient");
    }

    #[test]
    fn power_between_idle_and_max() {
        let m = GpuModel::default();
        for l in [conv(8, 3, 1, 2), conv(224, 64, 5, 64)] {
            let p = m.power(&l);
            assert!(p >= m.dev.p_idle && p <= m.dev.p_max, "power {p}");
        }
        // a big compute-bound conv should push well past idle
        assert!(m.power(&conv(224, 64, 5, 64)) > 5.0);
    }

    #[test]
    fn fig1_gpu_envelope() {
        // paper Fig 1: GPU conv on 224x224x3, 2..64 filters -> ms / mJ scale
        let m = GpuModel::default();
        let c = m.cost(&conv(224, 3, 3, 64));
        assert!(c.ms() > 0.1 && c.ms() < 5.0, "latency {} ms", c.ms());
        assert!(c.mj() > 0.2 && c.mj() < 30.0, "energy {} mJ", c.mj());
    }

    #[test]
    fn idle_energy_accrues() {
        let m = GpuModel::default();
        let c = m.idle_cost(1e-3);
        assert!((c.joules - m.dev.p_idle * 1e-3).abs() < 1e-12);
    }

    #[test]
    fn pooling_is_memory_bound() {
        let m = GpuModel::default();
        let pool = Layer::new(OpKind::MaxPool { k: 3, stride: 2 }, TensorShape::new(109, 109, 96));
        let t_mem = m.bytes(&pool) as f64 / m.dev.mem_bw;
        assert!((m.exec_time(&pool) - t_mem).abs() / t_mem < 1e-9);
    }
}
