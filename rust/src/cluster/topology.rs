//! The replica registry: which [`Node`]s exist, plus the cluster-wide
//! **rolling hot-swap** that upgrades a model across replicas with zero
//! failed client requests.
//!
//! Indices are stable: killing a node leaves a tombstone, so replica
//! `i` in a [`Router`](crate::cluster::Router) started from
//! [`Topology::addrs`] keeps meaning the same node for the topology's
//! lifetime.

use super::node::Node;
use crate::coordinator::{serving_err, Engine, ModelSpec};
use crate::runtime::RuntimeError;
use std::net::SocketAddr;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// A registry of in-process cluster nodes.
#[derive(Default)]
pub struct Topology {
    nodes: Mutex<Vec<Option<Node>>>,
}

impl Topology {
    /// An empty topology.
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a node; returns its stable replica index.
    pub fn add(&self, node: Node) -> usize {
        let mut nodes = self.nodes.lock().unwrap();
        nodes.push(Some(node));
        nodes.len() - 1
    }

    /// Remove a node from the registry and hand it back (alive — the
    /// caller decides whether to [`Node::kill`] it). The slot stays as
    /// a tombstone so sibling indices are undisturbed.
    pub fn remove(&self, idx: usize) -> Option<Node> {
        self.nodes.lock().unwrap().get_mut(idx).and_then(Option::take)
    }

    /// Kill node `idx` in place ([`Node::kill`]), leaving its tombstone.
    /// `false` when the slot is already empty.
    pub fn kill(&self, idx: usize) -> bool {
        match self.remove(idx) {
            Some(mut node) => {
                node.kill();
                true
            }
            None => false,
        }
    }

    /// Listener addresses of every live node, in replica-index order —
    /// what [`Router::start`](crate::cluster::Router::start) takes.
    /// Call before killing nodes so indices line up with the router's.
    pub fn addrs(&self) -> Vec<SocketAddr> {
        self.nodes.lock().unwrap().iter().flatten().map(Node::addr).collect()
    }

    /// The engine of node `idx`, for metrics scraping (engines are
    /// cloneable front doors; the node keeps serving).
    pub fn engine(&self, idx: usize) -> Option<Engine> {
        self.nodes.lock().unwrap().get(idx).and_then(|slot| {
            slot.as_ref().map(|n| n.engine().clone())
        })
    }

    /// Live nodes registered.
    pub fn len(&self) -> usize {
        self.nodes.lock().unwrap().iter().flatten().count()
    }

    /// True when no live node remains.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// March a model upgrade across the cluster, one replica at a time:
    ///
    /// 1. retire `model` on the replica — requests already queued there
    ///    drain with `model_retiring`, later arrivals get
    ///    `unknown_model`; both are retryable, so a router in front
    ///    fails them over to the siblings still serving the model and
    ///    **no client request fails**;
    /// 2. gate on per-node drain: retire joins the model's threads
    ///    synchronously, and the gate re-checks that no in-flight work
    ///    remains before the replacement registers;
    /// 3. register `make_spec()` — the fresh revision, which must keep
    ///    the serving name — and move to the next replica.
    ///
    /// Replicas without the model are skipped. Returns how many were
    /// swapped. On error the march stops (replicas already swapped stay
    /// swapped; the failing one may be left without the model).
    pub fn rolling_swap(
        &self,
        model: &str,
        make_spec: &dyn Fn() -> ModelSpec,
    ) -> Result<usize, RuntimeError> {
        // snapshot the engines first: the per-replica drain below must
        // not hold the registry lock against addrs()/kill() callers
        let engines: Vec<Engine> = {
            let nodes = self.nodes.lock().unwrap();
            nodes.iter().flatten().map(|n| n.engine().clone()).collect()
        };
        let mut swapped = 0;
        for engine in engines {
            if !engine.models().iter().any(|m| m == model) {
                continue;
            }
            engine.retire(model)?;
            // drain gate: retire drained and joined the pool; verify no
            // straggler before the fresh pool takes the name
            let deadline = Instant::now() + Duration::from_secs(5);
            while engine.in_flight(model).unwrap_or(0) > 0 {
                if Instant::now() >= deadline {
                    return Err(serving_err(format!(
                        "rolling swap: {model:?} did not drain within 5s"
                    )));
                }
                std::thread::sleep(Duration::from_millis(1));
            }
            let spec = make_spec();
            if spec.name != model {
                return Err(serving_err(format!(
                    "rolling swap must keep the serving name: spec is {:?}, swapping {model:?}",
                    spec.name
                )));
            }
            engine.register(spec)?;
            swapped += 1;
        }
        Ok(swapped)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fire_spec(seed: u64) -> ModelSpec {
        ModelSpec::new("fire", "fire_full", "squeezenet").workers(1).seed(seed)
    }

    #[test]
    fn indices_stay_stable_across_kill() {
        let topo = Topology::new();
        let a = topo.add(Node::start(vec![fire_spec(0)]).expect("node a"));
        let b = topo.add(Node::start(vec![fire_spec(0)]).expect("node b"));
        assert_eq!((a, b), (0, 1));
        assert_eq!(topo.len(), 2);
        let addr_b = topo.addrs()[1];
        assert!(topo.kill(a));
        assert!(!topo.kill(a), "tombstoned slot kills only once");
        assert_eq!(topo.len(), 1);
        assert!(topo.engine(a).is_none());
        assert_eq!(topo.addrs(), vec![addr_b], "b keeps its address");
    }

    #[test]
    fn rolling_swap_replaces_the_model_on_every_replica() {
        let topo = Topology::new();
        for _ in 0..2 {
            topo.add(Node::start(vec![fire_spec(0)]).expect("node"));
        }
        let swapped = topo.rolling_swap("fire", &|| fire_spec(1)).expect("swap");
        assert_eq!(swapped, 2);
        for idx in 0..2 {
            let engine = topo.engine(idx).expect("alive");
            assert_eq!(engine.models(), vec!["fire".to_string()]);
        }
    }

    #[test]
    fn rolling_swap_rejects_a_renaming_spec() {
        let topo = Topology::new();
        topo.add(Node::start(vec![fire_spec(0)]).expect("node"));
        let err = topo
            .rolling_swap("fire", &|| ModelSpec::new("ember", "fire_full", "squeezenet"))
            .expect_err("rename must fail");
        assert_eq!(err.code(), "serving");
    }
}
