//! An in-process cluster node: one [`Engine`] behind a v2 [`Server`]
//! loop on its own ephemeral listener.
//!
//! A [`Node`] is the unit the [`crate::cluster::Topology`] registers and
//! the [`crate::cluster::Router`] fans out to. Tests and binaries stand
//! up N of them in one process (each owns its engine threads and its
//! accept loop), address them by [`Node::addr`], and tear one down
//! mid-traffic with [`Node::kill`] to exercise failover.

use crate::coordinator::server::{Server, ServerConfig};
use crate::coordinator::{Engine, EngineBuilder, EngineHandle, ModelSpec};
use crate::runtime::RuntimeError;
use std::net::SocketAddr;
use std::time::Duration;

/// One simulated cluster node: an engine plus the v2 listener serving
/// it. Dropping a node kills it ([`Node::kill`]).
pub struct Node {
    addr: SocketAddr,
    engine: Engine,
    handle: Option<EngineHandle>,
    server: Option<Server>,
}

impl Node {
    /// Start a node serving `specs` on an ephemeral `127.0.0.1` port
    /// with the default batching window (see [`EngineBuilder::new`]).
    pub fn start(specs: Vec<ModelSpec>) -> Result<Node, RuntimeError> {
        Self::start_with(specs, 8, Duration::from_millis(2))
    }

    /// [`Node::start`] with explicit batching knobs.
    pub fn start_with(
        specs: Vec<ModelSpec>,
        max_batch: usize,
        max_wait: Duration,
    ) -> Result<Node, RuntimeError> {
        let mut builder = EngineBuilder::new().max_batch(max_batch).max_wait(max_wait);
        for spec in specs {
            builder = builder.model(spec);
        }
        let handle = builder.build()?;
        let engine = handle.engine.clone();
        let server = Server::start_with("127.0.0.1:0", engine.clone(), ServerConfig::default())
            .map_err(|e| crate::coordinator::serving_err(format!("node listener: {e}")))?;
        Ok(Node { addr: server.addr, engine, handle: Some(handle), server: Some(server) })
    }

    /// The node's listener address (ephemeral port already resolved).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The node's engine — for metrics scraping and live
    /// register/retire (the rolling-swap lever).
    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// True until [`Node::kill`] runs.
    pub fn is_alive(&self) -> bool {
        self.handle.is_some()
    }

    /// Tear the node down the way a dying replica actually dies, with
    /// in-flight work answered rather than dropped:
    ///
    /// 1. every model is retired — requests already queued drain with
    ///    `model_retiring`, later submits get `unknown_model` (both
    ///    retryable on a sibling, so a router upstream of this node
    ///    fails them over with zero client-visible errors);
    /// 2. the engine shuts down and its threads join;
    /// 3. the listener stops accepting.
    ///
    /// Open connections see clean error frames first and EOF after —
    /// never a half-written response. Idempotent.
    pub fn kill(&mut self) {
        for model in self.engine.models() {
            let _ = self.engine.retire(&model);
        }
        if let Some(handle) = self.handle.take() {
            handle.shutdown();
        }
        if let Some(server) = self.server.take() {
            server.stop();
        }
    }
}

impl Drop for Node {
    fn drop(&mut self) {
        self.kill();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::{AsyncClient, Reply};
    use crate::runtime::Tensor;

    fn fire_spec() -> ModelSpec {
        ModelSpec::new("fire", "fire_full", "squeezenet").workers(1).seed(0)
    }

    #[test]
    fn node_serves_v2_on_ephemeral_port() {
        let mut node = Node::start(vec![fire_spec()]).expect("node starts");
        assert!(node.is_alive());
        let mut client = AsyncClient::connect(&node.addr()).expect("connect");
        let shape = client.models()[0].1.clone();
        let id = client.submit(None, &Tensor::randn(&shape, 7)).expect("submit");
        match client.recv().expect("recv") {
            Reply::Response(r) => assert_eq!(r.id, id),
            Reply::Error { code, message, .. } => panic!("{code}: {message}"),
        }
        node.kill();
        assert!(!node.is_alive());
    }

    #[test]
    fn kill_is_idempotent_and_answers_later_submits_with_errors() {
        let mut node = Node::start(vec![fire_spec()]).expect("node starts");
        let mut client = AsyncClient::connect(&node.addr()).expect("connect");
        let shape = client.models()[0].1.clone();
        node.kill();
        node.kill();
        // the connection predates the kill: a submit may still write, and
        // the answer is a structured retryable error or a clean EOF —
        // never a hang or a bogus response
        let input = Tensor::randn(&shape, 1);
        if client.submit(None, &input).is_ok() {
            match client.recv() {
                Ok(Reply::Error { .. }) | Err(_) => {}
                Ok(Reply::Response(r)) => panic!("killed node served id {}", r.id),
            }
        }
    }
}
