//! The digest-affinity router: a wire-transparent v2 (and v1-fallback)
//! endpoint that fans client requests out over pooled upstream
//! connections to N replicas.
//!
//! Split step-core-first like the rest of the serving stack: every
//! routing *decision* — which replica serves a request, what happens
//! when one fails, when a retry is spent — lives in the pure
//! [`RouterCore`] state machine, which the [`crate::check`] explorer
//! drives bare through failover interleavings
//! (`check/scenarios.rs::router_failover_exactly_once`). The shell
//! threads ([`Router`]) only move bytes and execute the core's effects.
//!
//! Routing policy (DESIGN.md §12):
//!
//! - **Digest affinity** (on by default): a request's input
//!   [`Tensor::digest`](crate::runtime::Tensor::digest) picks its
//!   replica by rendezvous (highest-random-weight) hashing, so the same
//!   input always lands on the same replica and that replica's
//!   content-digest result cache keeps hitting. When a replica goes
//!   down, only *its* keys move — the others keep their caches warm.
//! - **Load-aware fallback**: with affinity off (or no digest), the
//!   least-loaded healthy replica wins; ties rotate by request tag so
//!   equal-load replicas share traffic.
//! - **Bounded failover**: errors that a sibling can answer
//!   (`model_retiring`, `unknown_model`, `serving`, a lost connection)
//!   re-forward to the next candidate, at most [`RouterConfig::max_retries`]
//!   times, never to a replica already tried. Anything else — and
//!   anything past the retry budget — passes through to the client
//!   unchanged, wire code and all (the router adds no codes of its own;
//!   PROTOCOL.md §6 is untouched).
//! - **Exactly-once delivery**: the pending request's context (the
//!   client's reply channel) moves out of the core exactly once, inside
//!   [`RouterEffect::Deliver`] or [`RouterEffect::Fail`] — a late
//!   response from the original replica racing the retry can therefore
//!   never produce a second reply, by construction.

use crate::config::json::{self, Json};
use crate::coordinator::protocol::{self, AsyncClient, Reply};
use crate::coordinator::server::{self, ClientResponse};
use crate::coordinator::step;
use crate::coordinator::{NodeHealth, Priority};
use crate::obs::TraceId;
use crate::runtime::Tensor;
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// the pure core

/// How a replica's error frame classifies for routing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailClass {
    /// A sibling replica can answer this request (`model_retiring`,
    /// `unknown_model`, `serving`, a lost connection): fail over.
    Retryable,
    /// The request itself is at fault (`bad_request`, `shed`,
    /// `deadline`, …): pass the error through unchanged.
    Fatal,
}

/// The core's view of one replica.
#[derive(Debug, Clone)]
pub struct ReplicaView {
    /// False while the replica's connection is down; unhealthy replicas
    /// are never selected.
    pub healthy: bool,
    /// Outstanding-work estimate: bumped per forward, decremented per
    /// answer, overwritten by [`RouterEvent::Health`] observations.
    pub load: u64,
}

/// A pending request inside the core. `ctx` is the shell's per-request
/// context (the client reply channel); it leaves the core exactly once.
struct Pending<T> {
    replica: usize,
    digest: Option<u64>,
    tried: Vec<usize>,
    ctx: T,
}

/// One input to the routing state machine.
#[derive(Debug)]
pub enum RouterEvent<T> {
    /// A client request arrived: pick a replica and forward.
    Accept {
        /// Router-global request tag (unique per accepted request).
        tag: u64,
        /// Content digest of the input tensor, when affinity applies.
        digest: Option<u64>,
        /// Shell context delivered back exactly once.
        ctx: T,
    },
    /// A replica answered `tag` successfully. Accepted from *any*
    /// replica — after a failover, results are bit-identical, so the
    /// first answer wins and the loser is discarded silently.
    Reply {
        /// The answered request's tag.
        tag: u64,
    },
    /// A replica answered `tag` with an error frame (or the forward
    /// could not be written). Ignored when `tag` is no longer assigned
    /// to `replica` — a stale error from a replica the request already
    /// failed over *from* must not kill the retry in flight elsewhere.
    Fail {
        /// The failed request's tag.
        tag: u64,
        /// The replica reporting the failure.
        replica: usize,
        /// Whether a sibling can still answer.
        class: FailClass,
    },
    /// A replica's connection died: mark it unhealthy and fail over
    /// everything assigned to it.
    ReplicaDown {
        /// The lost replica.
        replica: usize,
    },
    /// A replica's connection (re-)established: mark it healthy.
    ReplicaUp {
        /// The recovered replica.
        replica: usize,
    },
    /// A health probe observed the replica's real queue: overwrite the
    /// local load estimate.
    Health {
        /// The probed replica.
        replica: usize,
        /// Observed outstanding work (in-flight + queued).
        load: u64,
    },
}

/// One instruction from the routing state machine to the shell.
#[derive(Debug)]
pub enum RouterEffect<T> {
    /// Write the request onto `replica`'s upstream connection (the shell
    /// reads the payload via [`RouterCore::ctx`]).
    Forward {
        /// The request to forward.
        tag: u64,
        /// The selected replica.
        replica: usize,
    },
    /// Deliver the successful response to the client. Carries the
    /// request context *by move* — the core no longer knows the tag.
    Deliver {
        /// The answered request's tag.
        tag: u64,
        /// The request context, moved out exactly once.
        ctx: T,
    },
    /// Deliver an error to the client (retries spent, no candidate, or
    /// a fatal-class failure). Carries the context by move, same as
    /// [`RouterEffect::Deliver`] — one of the two happens, never both.
    Fail {
        /// The failed request's tag.
        tag: u64,
        /// The request context, moved out exactly once.
        ctx: T,
    },
}

/// splitmix64 finalizer: the cheap statistical mixer behind the
/// rendezvous hash (and the same family the runtime's deterministic
/// tensor generator uses).
fn splitmix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// Rendezvous score of `(digest, replica)`: each replica gets an
/// independent pseudo-random weight per key; the highest healthy one
/// wins, so removing a replica moves only the keys it owned.
fn rendezvous(digest: u64, replica: usize) -> u64 {
    splitmix(digest ^ splitmix(replica as u64))
}

/// The pure routing state machine. Generic over the shell's per-request
/// context `T` (the real router stores the client reply channel; the
/// checker stores a bare tag).
///
/// Drive it with [`RouterCore::step`]; execute the returned effects in
/// order. Tags must be unique per accepted request.
pub struct RouterCore<T> {
    replicas: Vec<ReplicaView>,
    pending: BTreeMap<u64, Pending<T>>,
    affinity: bool,
    max_retries: usize,
}

impl<T> RouterCore<T> {
    /// Core over `n` replicas, all initially healthy and unloaded.
    /// `max_retries` bounds re-forwards per request (attempts are
    /// `1 + max_retries` at most).
    pub fn new(n: usize, affinity: bool, max_retries: usize) -> Self {
        Self {
            replicas: (0..n).map(|_| ReplicaView { healthy: true, load: 0 }).collect(),
            pending: BTreeMap::new(),
            affinity,
            max_retries,
        }
    }

    /// The context of a pending request (what a
    /// [`RouterEffect::Forward`] tells the shell to serialize).
    pub fn ctx(&self, tag: u64) -> Option<&T> {
        self.pending.get(&tag).map(|p| &p.ctx)
    }

    /// Which replica `tag` is currently assigned to, if still pending —
    /// the shell's guard against submitting stale queue copies after a
    /// failover moved the request elsewhere.
    pub fn assigned(&self, tag: u64) -> Option<usize> {
        self.pending.get(&tag).map(|p| p.replica)
    }

    /// Requests currently pending (forwarded, not yet answered).
    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// The core's view of replica `i`.
    pub fn replica(&self, i: usize) -> Option<&ReplicaView> {
        self.replicas.get(i)
    }

    /// Pick a healthy, not-yet-tried replica: rendezvous on the digest
    /// when affinity applies, least-loaded (ties rotated by `tag`)
    /// otherwise.
    fn select(&self, digest: Option<u64>, tried: &[usize], tag: u64) -> Option<usize> {
        if self.affinity {
            if let Some(d) = digest {
                let mut best: Option<(u64, usize)> = None;
                for (i, r) in self.replicas.iter().enumerate() {
                    if !r.healthy || tried.contains(&i) {
                        continue;
                    }
                    let score = rendezvous(d, i);
                    if best.is_none_or(|(s, _)| score > s) {
                        best = Some((score, i));
                    }
                }
                return best.map(|(_, i)| i);
            }
        }
        let mut min = u64::MAX;
        let mut ties: Vec<usize> = Vec::new();
        for (i, r) in self.replicas.iter().enumerate() {
            if !r.healthy || tried.contains(&i) {
                continue;
            }
            if r.load < min {
                min = r.load;
                ties.clear();
            }
            if r.load == min {
                ties.push(i);
            }
        }
        if ties.is_empty() {
            None
        } else {
            Some(ties[(tag as usize) % ties.len()])
        }
    }

    /// Assign `p` to a fresh replica (recording the attempt) or give it
    /// up; either way exactly one effect comes back.
    fn forward_or_fail(&mut self, tag: u64, mut p: Pending<T>) -> RouterEffect<T> {
        if p.tried.len() > self.max_retries {
            return RouterEffect::Fail { tag, ctx: p.ctx };
        }
        match self.select(p.digest, &p.tried, tag) {
            Some(r) => {
                p.replica = r;
                self.replicas[r].load += 1;
                self.pending.insert(tag, p);
                RouterEffect::Forward { tag, replica: r }
            }
            None => RouterEffect::Fail { tag, ctx: p.ctx },
        }
    }

    /// Advance the state machine by one event; returns the effects the
    /// shell must execute, in order.
    pub fn step(&mut self, event: RouterEvent<T>) -> Vec<RouterEffect<T>> {
        match event {
            RouterEvent::Accept { tag, digest, ctx } => {
                debug_assert!(!self.pending.contains_key(&tag), "tag {tag} reused");
                let p = Pending { replica: usize::MAX, digest, tried: Vec::new(), ctx };
                vec![self.forward_or_fail(tag, p)]
            }
            RouterEvent::Reply { tag } => match self.pending.remove(&tag) {
                // first answer wins, whoever sent it; the loser of a
                // failover race falls into the None arm and is dropped
                Some(p) => {
                    if let Some(r) = self.replicas.get_mut(p.replica) {
                        r.load = r.load.saturating_sub(1);
                    }
                    vec![RouterEffect::Deliver { tag, ctx: p.ctx }]
                }
                None => Vec::new(),
            },
            RouterEvent::Fail { tag, replica, class } => {
                // stale guard: an error from a replica this request
                // already left must not touch the retry in flight
                match self.pending.get(&tag) {
                    Some(p) if p.replica == replica => {}
                    _ => return Vec::new(),
                }
                let mut p = self.pending.remove(&tag).expect("guarded above");
                if let Some(r) = self.replicas.get_mut(replica) {
                    r.load = r.load.saturating_sub(1);
                }
                match class {
                    FailClass::Fatal => vec![RouterEffect::Fail { tag, ctx: p.ctx }],
                    FailClass::Retryable => {
                        p.tried.push(replica);
                        vec![self.forward_or_fail(tag, p)]
                    }
                }
            }
            RouterEvent::ReplicaDown { replica } => {
                let Some(r) = self.replicas.get_mut(replica) else { return Vec::new() };
                r.healthy = false;
                r.load = 0;
                let orphans: Vec<u64> = self
                    .pending
                    .iter()
                    .filter(|(_, p)| p.replica == replica)
                    .map(|(&tag, _)| tag)
                    .collect();
                let mut effects = Vec::with_capacity(orphans.len());
                for tag in orphans {
                    let mut p = self.pending.remove(&tag).expect("listed above");
                    p.tried.push(replica);
                    effects.push(self.forward_or_fail(tag, p));
                }
                effects
            }
            RouterEvent::ReplicaUp { replica } => {
                if let Some(r) = self.replicas.get_mut(replica) {
                    r.healthy = true;
                    r.load = 0;
                }
                Vec::new()
            }
            RouterEvent::Health { replica, load } => {
                if let Some(r) = self.replicas.get_mut(replica) {
                    if r.healthy {
                        r.load = load;
                    }
                }
                Vec::new()
            }
        }
    }
}

// ---------------------------------------------------------------------------
// the shell

/// Error codes a sibling replica can answer: the retire/re-register
/// window of a rolling swap (`model_retiring`, then `unknown_model`
/// until the fresh pool is up) and engine teardown (`serving`).
const RETRYABLE_CODES: &[&str] = &["model_retiring", "unknown_model", "serving"];

/// Router policy and wire knobs.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Route by input-digest rendezvous hashing (default true). Off,
    /// every request takes the load-aware path.
    pub affinity: bool,
    /// Re-forwards allowed per request past the first attempt
    /// (default 2).
    pub max_retries: usize,
    /// Streaming chunk size for downstream v2 responses, in f32
    /// elements (default [`protocol::DEFAULT_CHUNK_ELEMS`]).
    pub chunk_elems: usize,
    /// How often each idle upstream worker probes its replica's HEALTH
    /// (default 50 ms).
    pub health_interval: Duration,
}

impl Default for RouterConfig {
    fn default() -> Self {
        Self {
            affinity: true,
            max_retries: 2,
            chunk_elems: protocol::DEFAULT_CHUNK_ELEMS,
            health_interval: Duration::from_millis(50),
        }
    }
}

/// What the router owes one downstream client: a re-encoded response or
/// a pass-through error frame.
enum RouterOut {
    /// Serialize a successful upstream response under the client's id.
    Ok {
        /// The downstream request id to answer.
        client_id: u64,
        /// The upstream response (payload + timings).
        resp: ClientResponse,
    },
    /// Serialize an error frame under the client's id.
    Err {
        /// The downstream request id to answer.
        client_id: u64,
        /// Wire code, passed through unchanged when upstream-origin.
        code: String,
        /// Human-readable diagnostic.
        message: String,
    },
}

/// Per-request context the core holds: everything needed to forward the
/// request upstream and answer the client downstream.
struct RouterJob {
    client_id: u64,
    model: Option<String>,
    input: Arc<Tensor>,
    priority: Priority,
    deadline: Option<Duration>,
    /// Router-tier flight-recorder identity, minted once at Accept
    /// (`TraceId(tag)`). The core keeps it in the pending ctx, so a
    /// failover re-forward carries the same id — one trace per client
    /// request however many replicas it visits.
    trace: TraceId,
    sink: mpsc::Sender<RouterOut>,
}

/// One forward handed to an upstream worker (a snapshot of the job's
/// wire-relevant fields; the core keeps the authoritative copy).
struct UpstreamJob {
    tag: u64,
    model: Option<String>,
    input: Arc<Tensor>,
    priority: Priority,
    deadline: Option<Duration>,
}

/// State shared by every connection thread and upstream worker.
struct RouterShared {
    core: Mutex<RouterCore<RouterJob>>,
    uplinks: Vec<mpsc::Sender<UpstreamJob>>,
    health_cache: Mutex<Vec<Option<NodeHealth>>>,
    next_tag: AtomicU64,
    table: Arc<Vec<(String, Vec<usize>)>>,
    chunk_elems: usize,
}

/// Step the shared core with `event` and execute the effects: forwards
/// go to the owning worker's queue, delivers/fails go to the client's
/// writer. `reply` carries the upstream response a
/// [`RouterEvent::Reply`] delivers; `fail` is the `(code, message)` a
/// [`RouterEffect::Fail`] serializes — the upstream error verbatim when
/// there is one, a router-synthesized `serving` otherwise.
fn drive(
    shared: &RouterShared,
    event: RouterEvent<RouterJob>,
    mut reply: Option<ClientResponse>,
    fail: (&str, &str),
) {
    let mut core = shared.core.lock().unwrap();
    for effect in core.step(event) {
        match effect {
            RouterEffect::Forward { tag, replica } => {
                if let Some(job) = core.ctx(tag) {
                    let up = UpstreamJob {
                        tag,
                        model: job.model.clone(),
                        input: job.input.clone(),
                        priority: job.priority,
                        deadline: job.deadline,
                    };
                    // a worker that exited (router stopping) drops the
                    // forward; its jobs fail over via ReplicaDown
                    let _ = shared.uplinks[replica].send(up);
                }
            }
            RouterEffect::Deliver { ctx, .. } => {
                if let Some(resp) = reply.take() {
                    let _ = ctx.sink.send(RouterOut::Ok { client_id: ctx.client_id, resp });
                }
            }
            RouterEffect::Fail { ctx, .. } => {
                // name the router-tier trace so a failed request can be
                // correlated against replica flight recorders
                let _ = ctx.sink.send(RouterOut::Err {
                    client_id: ctx.client_id,
                    code: fail.0.to_string(),
                    message: format!("{} [trace {}]", fail.1, ctx.trace),
                });
            }
        }
    }
}

/// A running router. Downstream it is a conforming v2 (and v1) server
/// endpoint; upstream it is a conforming v2 client of every replica —
/// wire transparency is the contract (PROTOCOL.md §7).
pub struct Router {
    /// The bound downstream address (port 0 resolved).
    pub addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    shared: Arc<RouterShared>,
}

impl Router {
    /// Bind `addr` (port 0 for ephemeral) and route to `replicas`. The
    /// downstream model table is snapshotted from the first reachable
    /// replica — every replica of a homogeneous cluster serves the same
    /// registry, which this tier assumes.
    pub fn start(
        addr: &str,
        replicas: &[SocketAddr],
        cfg: RouterConfig,
    ) -> std::io::Result<Router> {
        if replicas.is_empty() {
            return Err(std::io::Error::other("router needs at least one replica"));
        }
        let mut table = None;
        for a in replicas {
            if let Ok(c) = AsyncClient::connect(a) {
                table = Some(c.models().to_vec());
                break;
            }
        }
        let table = Arc::new(
            table.ok_or_else(|| {
                std::io::Error::other("no replica reachable for the model-table snapshot")
            })?,
        );
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let mut uplinks = Vec::with_capacity(replicas.len());
        let mut job_rxs = Vec::with_capacity(replicas.len());
        for _ in replicas {
            let (tx, rx) = mpsc::channel::<UpstreamJob>();
            uplinks.push(tx);
            job_rxs.push(rx);
        }
        let shared = Arc::new(RouterShared {
            core: Mutex::new(RouterCore::new(replicas.len(), cfg.affinity, cfg.max_retries)),
            uplinks,
            health_cache: Mutex::new(vec![None; replicas.len()]),
            next_tag: AtomicU64::new(1),
            table,
            chunk_elems: cfg.chunk_elems.max(1),
        });
        let workers = replicas
            .iter()
            .zip(job_rxs)
            .enumerate()
            .map(|(i, (&addr, jobs))| {
                let shared = shared.clone();
                let stop = stop.clone();
                let every = cfg.health_interval;
                std::thread::Builder::new()
                    .name(format!("hetero-dnn-uplink-{i}"))
                    .spawn(move || uplink_loop(&shared, i, addr, &jobs, &stop, every))
                    .expect("spawn uplink worker")
            })
            .collect();
        let accept_thread = {
            let shared = shared.clone();
            let stop = stop.clone();
            std::thread::Builder::new()
                .name("hetero-dnn-router-accept".into())
                .spawn(move || {
                    while !stop.load(Ordering::Relaxed) {
                        match listener.accept() {
                            Ok((stream, _)) => {
                                let shared = shared.clone();
                                let _ = std::thread::Builder::new()
                                    .name("hetero-dnn-router-conn".into())
                                    .spawn(move || {
                                        let _ = serve_downstream(stream, &shared);
                                    });
                            }
                            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                                std::thread::sleep(Duration::from_millis(5));
                            }
                            Err(_) => break,
                        }
                    }
                })
                .expect("spawn router accept thread")
        };
        Ok(Router { addr: local, stop, accept_thread: Some(accept_thread), workers, shared })
    }

    /// Requests accepted and not yet answered.
    pub fn pending(&self) -> usize {
        self.shared.core.lock().unwrap().pending_len()
    }

    /// Signal shutdown and join the accept loop and upstream workers
    /// (open downstream connections finish and close on next read).
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.accept_thread.take() {
            let _ = j.join();
        }
        for j in self.workers.drain(..) {
            let _ = j.join();
        }
    }
}

// ---------------------------------------------------------------------------
// upstream: one worker per replica

/// Submit one job on the replica's traffic connection, unless the core
/// reassigned it meanwhile (a stale queue copy after failover). `true`
/// means the connection died writing.
fn submit_one(
    shared: &RouterShared,
    replica: usize,
    client: &mut AsyncClient,
    wire_to_tag: &mut HashMap<u64, u64>,
    job: UpstreamJob,
) -> bool {
    if shared.core.lock().unwrap().assigned(job.tag) != Some(replica) {
        return false;
    }
    match client.submit_with(job.model.as_deref(), &job.input, job.priority, job.deadline) {
        Ok(wire_id) => {
            wire_to_tag.insert(wire_id, job.tag);
            false
        }
        Err(_) => {
            drive(
                shared,
                RouterEvent::Fail { tag: job.tag, replica, class: FailClass::Retryable },
                None,
                ("serving", "replica write failed"),
            );
            true
        }
    }
}

/// One replica's upstream worker: drains its forward queue onto a
/// pipelined [`AsyncClient`], polls for completions with
/// [`AsyncClient::recv_deadline`] (a clean timeout means *slow*, any
/// other error means *dead* — the distinction failover runs on), probes
/// HEALTH on a dedicated idle connection, and reconnects after a death.
fn uplink_loop(
    shared: &RouterShared,
    replica: usize,
    addr: SocketAddr,
    jobs: &mpsc::Receiver<UpstreamJob>,
    stop: &AtomicBool,
    health_every: Duration,
) {
    /// Completion-poll slice; also the idle wait on the forward queue.
    const POLL: Duration = Duration::from_millis(10);
    /// Backoff between reconnect attempts to a dead replica.
    const RECONNECT: Duration = Duration::from_millis(20);
    let mut traffic: Option<AsyncClient> = None;
    let mut probe: Option<AsyncClient> = None;
    let mut wire_to_tag: HashMap<u64, u64> = HashMap::new();
    let mut last_probe: Option<Instant> = None;
    let mut carry: Option<UpstreamJob> = None;
    loop {
        if stop.load(Ordering::Relaxed) {
            return;
        }
        if traffic.is_none() {
            match AsyncClient::connect(&addr) {
                Ok(c) => {
                    traffic = Some(c);
                    wire_to_tag.clear();
                    drive(shared, RouterEvent::ReplicaUp { replica }, None, ("", ""));
                }
                Err(_) => {
                    // still down: anything queued for us fails over now
                    // (stale copies of reassigned jobs bounce off the
                    // Fail event's stale guard)
                    loop {
                        let job = match carry.take() {
                            Some(j) => j,
                            None => match jobs.try_recv() {
                                Ok(j) => j,
                                Err(_) => break,
                            },
                        };
                        drive(
                            shared,
                            RouterEvent::Fail {
                                tag: job.tag,
                                replica,
                                class: FailClass::Retryable,
                            },
                            None,
                            ("serving", "replica unavailable"),
                        );
                    }
                    std::thread::sleep(RECONNECT);
                    continue;
                }
            }
        }
        let client = traffic.as_mut().expect("connected above");
        let mut dead = false;
        // 1. forward everything queued
        loop {
            let job = match carry.take() {
                Some(j) => j,
                None => match jobs.try_recv() {
                    Ok(j) => j,
                    Err(_) => break,
                },
            };
            if submit_one(shared, replica, client, &mut wire_to_tag, job) {
                dead = true;
                break;
            }
        }
        // 2. collect completions, or idle-probe and wait for work
        if !dead && client.in_flight() > 0 {
            match client.recv_deadline(POLL) {
                Ok(Reply::Response(r)) => {
                    if let Some(tag) = wire_to_tag.remove(&r.id) {
                        drive(shared, RouterEvent::Reply { tag }, Some(r), ("", ""));
                    }
                }
                Ok(Reply::Error { id, code, message, fatal }) => {
                    if let Some(tag) = wire_to_tag.remove(&id) {
                        let class = if RETRYABLE_CODES.contains(&code.as_str()) {
                            FailClass::Retryable
                        } else {
                            FailClass::Fatal
                        };
                        drive(
                            shared,
                            RouterEvent::Fail { tag, replica, class },
                            None,
                            (&code, &message),
                        );
                    }
                    if fatal {
                        dead = true;
                    }
                }
                Err(ref e) if protocol::is_timeout(e) => {} // slow, not dead
                Err(_) => dead = true,
            }
        } else if !dead {
            let due = match last_probe {
                Some(t) => t.elapsed() >= health_every,
                None => true,
            };
            if due {
                if probe.is_none() {
                    probe = AsyncClient::connect(&addr).ok();
                }
                let mut probe_died = false;
                if let Some(p) = probe.as_mut() {
                    match p.health() {
                        Ok(h) => {
                            shared.health_cache.lock().unwrap()[replica] = Some(h);
                            drive(
                                shared,
                                RouterEvent::Health {
                                    replica,
                                    load: h.in_flight + h.queue_depth,
                                },
                                None,
                                ("", ""),
                            );
                        }
                        // the probe connection died; the traffic
                        // connection decides liveness, not this one
                        Err(_) => probe_died = true,
                    }
                }
                if probe_died {
                    probe = None;
                }
                last_probe = Some(Instant::now());
            }
            match jobs.recv_timeout(POLL) {
                Ok(job) => carry = Some(job),
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => return,
            }
        }
        if dead {
            traffic = None;
            probe = None;
            wire_to_tag.clear();
            shared.health_cache.lock().unwrap()[replica] = None;
            drive(
                shared,
                RouterEvent::ReplicaDown { replica },
                None,
                ("serving", "replica connection lost"),
            );
        }
    }
}

// ---------------------------------------------------------------------------
// downstream: the client-facing endpoint

/// Everything cached per-replica summed into the one snapshot a
/// downstream HEALTH probe sees: the cluster as a single node.
fn aggregate_health(cache: &[Option<NodeHealth>]) -> NodeHealth {
    let (mut in_flight, mut queued, mut rate_sum, mut n) = (0u64, 0u64, 0.0f32, 0u32);
    for h in cache.iter().flatten() {
        in_flight += h.in_flight;
        queued += h.queue_depth;
        rate_sum += h.cache_hit_rate;
        n += 1;
    }
    NodeHealth {
        in_flight,
        queue_depth: queued,
        cache_hit_rate: if n == 0 { 0.0 } else { rate_sum / n as f32 },
    }
}

/// Sniff the protocol version like the node server does and dispatch.
fn serve_downstream(mut stream: TcpStream, shared: &Arc<RouterShared>) -> std::io::Result<()> {
    stream.set_nodelay(true)?;
    let mut first = [0u8; 4];
    if !protocol::read_exact_or_eof(&mut stream, &mut first)? {
        return Ok(());
    }
    if first == protocol::MAGIC {
        serve_downstream_v2(stream, shared)
    } else {
        serve_downstream_v1(&mut stream, shared, u32::from_le_bytes(first))
    }
}

/// The v2 downstream session: HELLO handshake against the snapshot
/// table, then the same reader/writer split as the node server — except
/// completions come from the routing core instead of a local engine.
fn serve_downstream_v2(mut stream: TcpStream, shared: &Arc<RouterShared>) -> std::io::Result<()> {
    let mut rest = [0u8; 4];
    if !protocol::read_exact_or_eof(&mut stream, &mut rest)? {
        return Ok(());
    }
    let (version, kind, rank) = (rest[0], rest[1], rest[3]);
    let mut body = [0u8; 16];
    if !protocol::read_exact_or_eof(&mut stream, &mut body)? {
        return Ok(());
    }
    if version != protocol::VERSION || kind != protocol::KIND_HELLO || rank != 0 {
        stream.write_all(&protocol::encode_error(
            0,
            "bad_frame",
            "expected HELLO as the first v2 frame",
            true,
        ))?;
        return Ok(());
    }
    let (min, max) = (body[0], body[1]);
    if min > protocol::VERSION || max < protocol::VERSION {
        stream.write_all(&protocol::encode_error(
            0,
            "unsupported_version",
            &format!("no common version in client range [{min}, {max}]"),
            true,
        ))?;
        return Ok(());
    }
    let table = shared.table.clone();
    stream.write_all(&protocol::encode_hello_ack(protocol::VERSION, &table))?;
    stream.flush()?;

    let (sink, out) = mpsc::channel::<RouterOut>();
    let fatal: Arc<Mutex<Option<server::FatalFrame>>> = Arc::new(Mutex::new(None));
    let window = server::Window::new();
    let health: Arc<Mutex<VecDeque<(u64, NodeHealth)>>> = Arc::new(Mutex::new(VecDeque::new()));
    let writer = {
        let stream = stream.try_clone()?;
        let table = table.clone();
        let fatal = fatal.clone();
        let window = window.clone();
        let health = health.clone();
        let chunk_elems = shared.chunk_elems;
        std::thread::Builder::new()
            .name("hetero-dnn-router-writer".into())
            .spawn(move || router_v2_writer(stream, &out, &table, &fatal, chunk_elems, &window, &health))
            .expect("spawn router connection writer")
    };
    let result = router_v2_reader(&mut stream, shared, &sink, &fatal, &window, &health);
    drop(sink);
    let _ = writer.join();
    result
}

/// Parse downstream v2 frames and feed the routing core — the router's
/// analogue of the node server's reader thread. Same framing rules,
/// same fatal-frame discipline, same per-request window accounting.
fn router_v2_reader(
    stream: &mut TcpStream,
    shared: &Arc<RouterShared>,
    sink: &mpsc::Sender<RouterOut>,
    fatal: &Mutex<Option<server::FatalFrame>>,
    window: &server::Window,
    health: &Mutex<VecDeque<(u64, NodeHealth)>>,
) -> std::io::Result<()> {
    let reject = |id: u64, code: &str, message: String| {
        let _ = sink.send(RouterOut::Err { client_id: id, code: code.to_string(), message });
    };
    loop {
        let mut pre = [0u8; 8];
        if !protocol::read_exact_or_eof(stream, &mut pre)? {
            return Ok(());
        }
        let p = match protocol::parse_prelude(&pre) {
            Ok(p) => p,
            Err(e) => {
                server::set_fatal(fatal, 0, "bad_frame", e);
                return Ok(());
            }
        };
        if p.kind == protocol::KIND_HEALTH {
            if p.rank != 0 {
                server::set_fatal(fatal, 0, "bad_frame", format!("HEALTH frame with rank {}", p.rank));
                return Ok(());
            }
            let mut body = [0u8; 16];
            if !protocol::read_exact_or_eof(stream, &mut body)? {
                return Ok(());
            }
            let id = u64::from_le_bytes(body[..8].try_into().expect("8 bytes"));
            if !window.acquire() {
                return Ok(());
            }
            let agg = aggregate_health(&shared.health_cache.lock().unwrap());
            health.lock().unwrap().push_back((id, agg));
            continue;
        }
        if p.kind != protocol::KIND_REQUEST {
            server::set_fatal(fatal, 0, "bad_frame", format!("unexpected frame kind {:#04x}", p.kind));
            return Ok(());
        }
        let mut body = [0u8; 16];
        if !protocol::read_exact_or_eof(stream, &mut body)? {
            return Ok(());
        }
        let id = u64::from_le_bytes(body[..8].try_into().expect("8 bytes"));
        if p.rank == 0 || p.rank > protocol::MAX_RANK {
            server::set_fatal(fatal, id, "bad_frame", format!("bad rank {}", p.rank));
            return Ok(());
        }
        let mut frame = Vec::with_capacity(24 + p.rank as usize * 4);
        frame.extend_from_slice(&pre);
        frame.extend_from_slice(&body);
        let dims_at = frame.len();
        frame.resize(dims_at + p.rank as usize * 4, 0);
        if !protocol::read_exact_or_eof(stream, &mut frame[dims_at..])? {
            return Ok(());
        }
        let header = match protocol::decode_request_header(&frame) {
            Ok((h, _)) => h,
            Err(e) => {
                server::set_fatal(fatal, id, "bad_frame", e);
                return Ok(());
            }
        };
        let elems = header
            .dims
            .iter()
            .try_fold(1usize, |a, &d| a.checked_mul(d))
            .unwrap_or(usize::MAX);
        if elems == 0 || elems > protocol::MAX_ELEMS {
            server::set_fatal(fatal, header.id, "bad_frame", "bad tensor size".into());
            return Ok(());
        }
        let mut payload = vec![0u8; elems * 4];
        if !protocol::read_exact_or_eof(stream, &mut payload)? {
            return Ok(());
        }
        let data = protocol::f32_from_bytes(&payload);
        if !window.acquire() {
            return Ok(());
        }
        let model = if header.model == protocol::DEFAULT_MODEL {
            None // the replicas' default model — the table is shared
        } else {
            match shared.table.get(header.model as usize) {
                Some((name, _)) => Some(name.clone()),
                None => {
                    reject(
                        header.id,
                        "unknown_model",
                        format!("model #{} not in the connection's table", header.model),
                    );
                    continue;
                }
            }
        };
        let priority = match protocol::priority_from_wire(header.priority) {
            Some(p) => p,
            None => {
                reject(
                    header.id,
                    "bad_request",
                    format!("priority {} undefined (0 normal | 1 high | 2 low)", header.priority),
                );
                continue;
            }
        };
        let deadline = (header.deadline_us > 0)
            .then(|| Duration::from_micros(header.deadline_us as u64));
        let input = Tensor::new(header.dims, data);
        let digest = input.digest();
        let tag = shared.next_tag.fetch_add(1, Ordering::Relaxed);
        drive(
            shared,
            RouterEvent::Accept {
                tag,
                digest: Some(digest),
                ctx: RouterJob {
                    client_id: header.id,
                    model,
                    input: Arc::new(input),
                    priority,
                    deadline,
                    trace: TraceId(tag),
                    sink: sink.clone(),
                },
            },
            None,
            ("serving", "no healthy replica available"),
        );
    }
}

/// Serialize routed results onto the downstream socket — the router's
/// analogue of the node server's writer thread, reusing the same
/// [`step::WriterCore`] effect discipline and health-ack flushing.
fn router_v2_writer(
    mut stream: TcpStream,
    out: &mpsc::Receiver<RouterOut>,
    table: &[(String, Vec<usize>)],
    fatal: &Mutex<Option<server::FatalFrame>>,
    chunk_elems: usize,
    window: &server::Window,
    health: &Mutex<VecDeque<(u64, NodeHealth)>>,
) {
    let mut core = step::WriterCore;
    loop {
        if server::flush_health_acks(&mut core, health, &mut stream, window, fatal) {
            return;
        }
        let item = match out.recv_timeout(Duration::from_millis(5)) {
            Ok(item) => item,
            Err(mpsc::RecvTimeoutError::Timeout) => continue,
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        };
        let written = match item {
            RouterOut::Ok { client_id, resp } if resp.output.data.len() > protocol::MAX_ELEMS => {
                stream
                    .write_all(&protocol::encode_error(
                        client_id,
                        "serving",
                        &format!(
                            "output of {} elements exceeds the wire bound {}",
                            resp.output.data.len(),
                            protocol::MAX_ELEMS
                        ),
                        false,
                    ))
                    .and_then(|()| stream.flush())
            }
            RouterOut::Ok { client_id, resp } => {
                write_routed_response(&mut stream, client_id, &resp, table, chunk_elems)
            }
            RouterOut::Err { client_id, code, message } => stream
                .write_all(&protocol::encode_error(client_id, &code, &message, false))
                .and_then(|()| stream.flush()),
        };
        let event =
            if written.is_ok() { step::WriterEvent::WroteOk } else { step::WriterEvent::WroteErr };
        if server::drive_writer_effects(&mut core, event, window, fatal, &mut stream) {
            return;
        }
    }
    if server::flush_health_acks(&mut core, health, &mut stream, window, fatal) {
        return;
    }
    server::drive_writer_effects(&mut core, step::WriterEvent::Drained, window, fatal, &mut stream);
}

/// Re-encode an upstream [`ClientResponse`] as a downstream RESPONSE
/// head plus CHUNK frames under the client's id. Timings, sim costs and
/// the cached flag pass through unchanged (wire transparency).
fn write_routed_response(
    stream: &mut TcpStream,
    id: u64,
    resp: &ClientResponse,
    table: &[(String, Vec<usize>)],
    chunk_elems: usize,
) -> std::io::Result<()> {
    let model = table
        .iter()
        .position(|(n, _)| *n == resp.model)
        .map(|i| i as u16)
        .unwrap_or(protocol::DEFAULT_MODEL);
    let total = resp.output.data.len();
    let first = total.min(chunk_elems);
    let payload = protocol::f32_bytes(&resp.output.data);
    let head = protocol::ResponseHeader {
        id,
        model,
        batch_size: resp.batch_size.min(u16::MAX as usize) as u16,
        exec_us: resp.exec_us.min(u32::MAX as u64) as u32,
        queued_us: resp.queued_us.min(u32::MAX as u64) as u32,
        chunk_elems: first as u32,
        sim_ms: resp.sim_ms,
        sim_mj: resp.sim_mj,
        cached: resp.cached,
        last: first == total,
        dims: resp.output.shape.clone(),
    };
    stream.write_all(&protocol::encode_response_head(&head))?;
    stream.write_all(&payload[..first * 4])?;
    let (mut at, mut seq) = (first, 1u32);
    while at < total {
        let n = (total - at).min(chunk_elems);
        let last = at + n == total;
        stream.write_all(&protocol::encode_chunk_header(id, seq, n as u32, last))?;
        stream.write_all(&payload[at * 4..(at + n) * 4])?;
        at += n;
        seq += 1;
    }
    stream.flush()
}

/// Maximum accepted v1 header size (same bound as the node server).
const MAX_HEADER: u32 = 1 << 16;

/// The v1 downstream fallback: lockstep JSON frames routed one at a
/// time through the same core — a v1 client sees the cluster exactly as
/// it would see a single node.
fn serve_downstream_v1(
    stream: &mut TcpStream,
    shared: &Arc<RouterShared>,
    first_len: u32,
) -> std::io::Result<()> {
    let mut hlen = first_len;
    loop {
        if !route_v1_frame(stream, shared, hlen)? {
            return Ok(());
        }
        let mut len4 = [0u8; 4];
        if !protocol::read_exact_or_eof(stream, &mut len4)? {
            return Ok(());
        }
        hlen = u32::from_le_bytes(len4);
    }
}

/// Route one v1 frame; `Ok(false)` closes the connection (same framing
/// rules as the node server's v1 path).
fn route_v1_frame(
    stream: &mut TcpStream,
    shared: &Arc<RouterShared>,
    hlen: u32,
) -> std::io::Result<bool> {
    if hlen == 0 || hlen > MAX_HEADER {
        server::error_frame(stream, 0, "bad_frame", "bad header length")?;
        return Ok(false);
    }
    let mut hbuf = vec![0u8; hlen as usize];
    if !protocol::read_exact_or_eof(stream, &mut hbuf)? {
        return Ok(false);
    }
    let header = match std::str::from_utf8(&hbuf).ok().and_then(|s| json::parse(s).ok()) {
        Some(h) => h,
        None => {
            server::error_frame(stream, 0, "bad_frame", "header not valid JSON")?;
            return Ok(false);
        }
    };
    let id = header.get("id").and_then(Json::as_usize).unwrap_or(0) as u64;
    let Some(shape) = header
        .get("shape")
        .and_then(Json::as_arr)
        .map(|a| a.iter().filter_map(Json::as_usize).collect::<Vec<_>>())
    else {
        server::error_frame(stream, id, "bad_frame", "missing shape")?;
        return Ok(false);
    };
    let elems = shape
        .iter()
        .try_fold(1usize, |a, &d| a.checked_mul(d))
        .unwrap_or(usize::MAX);
    if elems == 0 || elems > protocol::MAX_ELEMS {
        server::error_frame(stream, id, "bad_frame", "bad tensor size")?;
        return Ok(false);
    }
    let mut payload = vec![0u8; elems * 4];
    if !protocol::read_exact_or_eof(stream, &mut payload)? {
        return Ok(false);
    }
    let data = protocol::f32_from_bytes(&payload);
    let model = match header.get("model") {
        None => None,
        Some(m) => match m.as_str() {
            Some(m) if shared.table.iter().any(|(n, _)| n == m) => Some(m.to_string()),
            Some(m) => {
                server::error_frame(
                    stream,
                    id,
                    "unknown_model",
                    &format!("model {m:?} not in the cluster's table"),
                )?;
                return Ok(true);
            }
            None => {
                server::error_frame(stream, id, "bad_request", "model must be a string")?;
                return Ok(true);
            }
        },
    };
    let priority = match header.get("priority").map(|p| p.as_str()) {
        None => Priority::Normal,
        Some(Some("high")) => Priority::High,
        Some(Some("normal")) => Priority::Normal,
        Some(Some("low")) => Priority::Low,
        Some(_) => {
            server::error_frame(
                stream,
                id,
                "bad_request",
                "priority must be \"high\", \"normal\" or \"low\"",
            )?;
            return Ok(true);
        }
    };
    let deadline = match header.get("deadline_us") {
        None => None,
        Some(d) => match d.as_usize() {
            Some(us) => Some(Duration::from_micros(us as u64)),
            None => {
                server::error_frame(
                    stream,
                    id,
                    "bad_request",
                    "deadline_us must be a non-negative integer",
                )?;
                return Ok(true);
            }
        },
    };
    let input = Tensor::new(shape, data);
    let digest = input.digest();
    let (tx, rx) = mpsc::channel::<RouterOut>();
    let tag = shared.next_tag.fetch_add(1, Ordering::Relaxed);
    drive(
        shared,
        RouterEvent::Accept {
            tag,
            digest: Some(digest),
            ctx: RouterJob {
                client_id: id,
                model,
                input: Arc::new(input),
                priority,
                deadline,
                trace: TraceId(tag),
                sink: tx,
            },
        },
        None,
        ("serving", "no healthy replica available"),
    );
    // lockstep: block until the core answers (it always does — retries
    // are bounded and every failure path carries a Fail effect)
    match rx.recv() {
        Ok(RouterOut::Ok { resp, .. }) if resp.output.data.len() > protocol::MAX_ELEMS => {
            server::error_frame(
                stream,
                id,
                "serving",
                &format!(
                    "output of {} elements exceeds the wire bound {}",
                    resp.output.data.len(),
                    protocol::MAX_ELEMS
                ),
            )?;
        }
        Ok(RouterOut::Ok { resp, .. }) => {
            let out_shape: Vec<String> = resp.output.shape.iter().map(|d| d.to_string()).collect();
            let header = format!(
                "{{\"id\":{id},\"model\":{:?},\"shape\":[{}],\"exec_us\":{},\"queued_us\":{},\"batch_size\":{},\"cached\":{},\"sim_ms\":{:.4},\"sim_mj\":{:.4}}}",
                resp.model,
                out_shape.join(","),
                resp.exec_us,
                resp.queued_us,
                resp.batch_size,
                resp.cached,
                resp.sim_ms,
                resp.sim_mj
            );
            server::write_frame(stream, &header, &resp.output.data)?;
        }
        Ok(RouterOut::Err { code, message, .. }) => {
            server::error_frame(stream, id, &code, &message)?;
        }
        Err(_) => {
            server::error_frame(stream, id, "serving", "router shutting down")?;
        }
    }
    Ok(true)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn accept(core: &mut RouterCore<u64>, tag: u64, digest: Option<u64>) -> Vec<RouterEffect<u64>> {
        core.step(RouterEvent::Accept { tag, digest, ctx: tag })
    }

    #[test]
    fn trace_identity_survives_failover() {
        // the ctx (here a bare TraceId, in the shell a RouterJob carrying
        // one) must ride the pending entry through Fail -> Forward: one
        // trace per client request, however many replicas it visits
        let mut core: RouterCore<TraceId> = RouterCore::new(3, false, 2);
        let effects = core.step(RouterEvent::Accept { tag: 9, digest: None, ctx: TraceId(9) });
        let first = match &effects[..] {
            [RouterEffect::Forward { tag: 9, replica }] => *replica,
            other => panic!("expected one Forward, got {other:?}"),
        };
        assert_eq!(core.ctx(9), Some(&TraceId(9)));

        let effects =
            core.step(RouterEvent::Fail { tag: 9, replica: first, class: FailClass::Retryable });
        match &effects[..] {
            [RouterEffect::Forward { tag: 9, replica }] => assert_ne!(*replica, first),
            other => panic!("expected a failover Forward, got {other:?}"),
        }
        assert_eq!(core.ctx(9), Some(&TraceId(9)), "same trace after failover");
    }

    fn forwarded_to(effects: &[RouterEffect<u64>]) -> usize {
        match effects {
            [RouterEffect::Forward { replica, .. }] => *replica,
            other => panic!("expected one Forward, got {other:?}"),
        }
    }

    #[test]
    fn affinity_is_stable_per_digest() {
        let mut core = RouterCore::new(3, true, 2);
        let first = forwarded_to(&accept(&mut core, 1, Some(0xfeed)));
        for tag in 2..20 {
            let effects = accept(&mut core, tag, Some(0xfeed));
            assert_eq!(forwarded_to(&effects), first, "digest must pin its replica");
        }
    }

    #[test]
    fn affinity_moves_only_the_downed_replicas_keys() {
        let owner = |core: &mut RouterCore<u64>, tag: u64, d: u64| {
            let r = forwarded_to(&accept(core, tag, Some(d)));
            // answer immediately so pending state never skews selection
            core.step(RouterEvent::Reply { tag });
            r
        };
        let mut core = RouterCore::new(3, true, 2);
        let before: Vec<usize> = (0..40).map(|d| owner(&mut core, 1000 + d, d)).collect();
        let downed = before[0];
        core.step(RouterEvent::ReplicaDown { replica: downed });
        for (d, &was) in before.iter().enumerate() {
            let now = owner(&mut core, 2000 + d as u64, d as u64);
            if was == downed {
                assert_ne!(now, downed, "keys of the downed replica must move");
            } else {
                assert_eq!(now, was, "keys of healthy replicas must stay put");
            }
        }
    }

    #[test]
    fn digestless_ties_rotate_across_replicas() {
        let mut core = RouterCore::new(3, false, 2);
        let mut seen = [false; 3];
        for tag in 0..3 {
            let r = forwarded_to(&accept(&mut core, tag, Some(0xfeed)));
            seen[r] = true;
            core.step(RouterEvent::Reply { tag });
        }
        assert_eq!(seen, [true; 3], "equal-load replicas must share traffic");
    }

    #[test]
    fn health_observations_steer_digestless_traffic() {
        let mut core = RouterCore::new(2, false, 2);
        core.step(RouterEvent::Health { replica: 0, load: 5 });
        core.step(RouterEvent::Health { replica: 1, load: 0 });
        for tag in 0..4 {
            assert_eq!(forwarded_to(&accept(&mut core, tag, None)), 1);
            core.step(RouterEvent::Reply { tag });
            core.step(RouterEvent::Health { replica: 1, load: 0 });
        }
    }

    #[test]
    fn retryable_failure_moves_to_an_untried_sibling() {
        let mut core = RouterCore::new(2, true, 2);
        let first = forwarded_to(&accept(&mut core, 7, Some(3)));
        let effects =
            core.step(RouterEvent::Fail { tag: 7, replica: first, class: FailClass::Retryable });
        let second = forwarded_to(&effects);
        assert_ne!(second, first, "a failed replica must not be retried");
        let spent =
            core.step(RouterEvent::Fail { tag: 7, replica: second, class: FailClass::Retryable });
        match &spent[..] {
            [RouterEffect::Fail { tag: 7, ctx: 7 }] => {}
            other => panic!("no candidate left: expected Fail to client, got {other:?}"),
        }
        assert_eq!(core.pending_len(), 0);
    }

    #[test]
    fn retry_budget_bounds_attempts() {
        // 5 replicas but only 1 retry: the second failure gives up even
        // though untried siblings remain
        let mut core = RouterCore::new(5, true, 1);
        let a = forwarded_to(&accept(&mut core, 1, Some(9)));
        let b = forwarded_to(&core.step(RouterEvent::Fail {
            tag: 1,
            replica: a,
            class: FailClass::Retryable,
        }));
        let spent = core.step(RouterEvent::Fail { tag: 1, replica: b, class: FailClass::Retryable });
        assert!(
            matches!(&spent[..], [RouterEffect::Fail { tag: 1, .. }]),
            "retry budget spent: expected Fail, got {spent:?}"
        );
    }

    #[test]
    fn fatal_failure_passes_through_without_retry() {
        let mut core = RouterCore::new(3, true, 2);
        let r = forwarded_to(&accept(&mut core, 4, Some(1)));
        let effects = core.step(RouterEvent::Fail { tag: 4, replica: r, class: FailClass::Fatal });
        assert!(matches!(&effects[..], [RouterEffect::Fail { tag: 4, .. }]));
        assert_eq!(core.pending_len(), 0);
    }

    #[test]
    fn stale_fail_from_the_original_replica_is_ignored() {
        let mut core = RouterCore::new(2, true, 2);
        let a = forwarded_to(&accept(&mut core, 9, Some(2)));
        let b = forwarded_to(&core.step(RouterEvent::ReplicaDown { replica: a }));
        assert_ne!(a, b);
        // the original replica's late model_retiring arrives AFTER the
        // failover: it must not kill the retry in flight on b
        let stale = core.step(RouterEvent::Fail { tag: 9, replica: a, class: FailClass::Retryable });
        assert!(stale.is_empty(), "stale Fail must be ignored, got {stale:?}");
        let delivered = core.step(RouterEvent::Reply { tag: 9 });
        assert!(matches!(&delivered[..], [RouterEffect::Deliver { tag: 9, ctx: 9 }]));
    }

    #[test]
    fn late_reply_after_failover_delivers_exactly_once() {
        let mut core = RouterCore::new(2, true, 2);
        let a = forwarded_to(&accept(&mut core, 5, Some(8)));
        core.step(RouterEvent::ReplicaDown { replica: a });
        // the original replica's response was already in flight: first
        // answer wins …
        let first = core.step(RouterEvent::Reply { tag: 5 });
        assert!(matches!(&first[..], [RouterEffect::Deliver { tag: 5, ctx: 5 }]));
        // … and the failover target's answer finds nothing to deliver
        let second = core.step(RouterEvent::Reply { tag: 5 });
        assert!(second.is_empty(), "second reply must be discarded, got {second:?}");
    }

    #[test]
    fn down_with_no_sibling_fails_pending_to_the_client() {
        let mut core = RouterCore::new(1, true, 2);
        accept(&mut core, 3, Some(1));
        let effects = core.step(RouterEvent::ReplicaDown { replica: 0 });
        assert!(matches!(&effects[..], [RouterEffect::Fail { tag: 3, ctx: 3 }]));
        assert_eq!(core.pending_len(), 0);
    }

    #[test]
    fn load_accounting_balances_forwards_and_answers() {
        let mut core = RouterCore::new(2, false, 2);
        for tag in 0..6 {
            accept(&mut core, tag, None);
        }
        let total: u64 = (0..2).map(|i| core.replica(i).unwrap().load).sum();
        assert_eq!(total, 6);
        for tag in 0..6 {
            core.step(RouterEvent::Reply { tag });
        }
        let total: u64 = (0..2).map(|i| core.replica(i).unwrap().load).sum();
        assert_eq!(total, 0);
    }

    #[test]
    fn out_of_range_replica_events_are_ignored() {
        let mut core: RouterCore<u64> = RouterCore::new(2, true, 2);
        assert!(core.step(RouterEvent::ReplicaDown { replica: 9 }).is_empty());
        assert!(core.step(RouterEvent::ReplicaUp { replica: 9 }).is_empty());
        assert!(core.step(RouterEvent::Health { replica: 9, load: 1 }).is_empty());
    }
}
