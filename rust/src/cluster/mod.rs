//! The cluster tier: shard the [`Engine`](crate::coordinator::Engine)
//! across N simulated nodes behind a digest-affinity router.
//!
//! The paper's platform is one embedded FPGA+GPU board; an embedded
//! *fleet* (a rack of boards, a vehicle with several SoMs) serves the
//! same models from N such boards. This module reproduces that tier
//! in-process, on the real wire protocol:
//!
//! - [`node`] — an in-process "node": one `Engine` behind a v2
//!   [`Server`](crate::coordinator::server::Server) loop on its own
//!   ephemeral listener, so tests and binaries can stand up N nodes in
//!   one process and kill them mid-traffic.
//! - [`router`] — a wire-transparent v2 (and v1-fallback) endpoint that
//!   fans client requests out over pooled
//!   [`AsyncClient`](crate::coordinator::protocol::AsyncClient)
//!   upstream connections. Replica choice is **digest-affine**: the
//!   same input tensor rendezvous-hashes to the same replica, so that
//!   replica's content-digest result cache keeps hitting; digest-less
//!   policy traffic falls back to health/load-aware selection. Failures
//!   that are retryable on a sibling (`model_retiring`, a lost
//!   connection) fail over with bounded retries and **never deliver a
//!   reply twice** — the request context moves out of the routing core
//!   exactly once, by construction.
//! - [`topology`] — the replica registry: node add/remove plus a
//!   cluster-wide **rolling hot-swap** that marches a model
//!   retire/re-register across replicas, gated on per-node drain, so a
//!   fleet upgrades a model with zero failed client requests.
//!
//! The router's forwarding loop is split step-core-first like the rest
//! of the serving stack (DESIGN.md §11): [`router::RouterCore`] is a
//! pure state machine the [`crate::check`] explorer drives through
//! failover interleavings (`check/scenarios.rs`:
//! `router_failover_exactly_once`), and the shell threads only execute
//! its effects. DESIGN.md §12 covers the affinity hash and the failover
//! ordering rules.

#![warn(missing_docs)]

pub mod node;
pub mod router;
pub mod topology;

pub use node::Node;
pub use router::{
    FailClass, ReplicaView, Router, RouterConfig, RouterCore, RouterEffect, RouterEvent,
};
pub use topology::Topology;
