//! Named traffic scenarios as **data**, and the seeded open-loop
//! schedule builder that turns one into a concrete arrival list.
//!
//! The contract (DESIGN.md §13): a [`Schedule`] is a pure function of
//! `(scenario, seed, model count, duration)`. Arrival times, model
//! choices, input digests, priorities and deadlines are all drawn from a
//! single splitmix64 stream keyed on the seed — **never** from completion
//! times, wall clocks, or any other replay-side state. That is what makes
//! the generator open-loop: a slow server cannot retroactively thin the
//! offered load, so the replay measures the system against the traffic it
//! was offered, not the traffic it managed to absorb (no coordinated
//! omission).

use crate::coordinator::Priority;
use std::time::Duration;

/// The seven named scenarios, in registration order.
pub const SCENARIO_NAMES: [&str; 7] = [
    "diurnal_ramp",
    "flash_crowd",
    "zipf_models",
    "cache_hostile",
    "deadline_burst",
    "slow_loris",
    "multi_tenant",
];

/// How the offered rate moves across the run (`frac` is elapsed
/// fraction of the schedule duration, in `[0, 1)`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RateShape {
    /// Constant `base` rate.
    Flat,
    /// One smooth day: `base` at the edges, `peak` mid-run (raised
    /// cosine — the diurnal ramp).
    Diurnal,
    /// `base` rate with a step to `peak` on `[from, until)` — the flash
    /// crowd window.
    Flash {
        /// Window start, as a fraction of the duration.
        from: f64,
        /// Window end, as a fraction of the duration.
        until: f64,
    },
}

/// How arrivals choose among the registered models.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ModelSkew {
    /// Arrival `i` goes to model `i % models` — even pressure.
    RoundRobin,
    /// Heavy-tail draw: model `k` is picked with weight
    /// `1 / (k + 1)^exponent` — the zipf-over-models scenario.
    Zipf {
        /// The tail exponent (≈1.0 is the classic zipf).
        exponent: f64,
    },
}

/// How arrivals choose their input tensor (by digest, so the result
/// cache sees them).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum InputMix {
    /// Inputs drawn from a pool of `distinct` digests — cacheable.
    Shared {
        /// Pool size the input seeds are drawn from.
        distinct: u32,
    },
    /// Every arrival carries a never-repeated digest — cache-hostile.
    Unique,
}

/// How arrivals carry deadlines (deadline-bearing arrivals are also
/// promoted to [`Priority::High`] — latency-sensitive work).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DeadlineMix {
    /// No arrival carries a deadline.
    None,
    /// Periodic bursts: within each `period`-arrival window, the last
    /// `len` arrivals carry `deadline_us` deadlines.
    Bursts {
        /// Arrivals per burst cycle.
        period: u32,
        /// Deadline-bearing arrivals at the end of each cycle.
        len: u32,
        /// The deadline each burst arrival carries, in microseconds.
        deadline_us: u32,
    },
}

/// One named traffic scenario, fully described as data. Adding a
/// scenario means adding a row to [`ScenarioSpec::named`] — the builder,
/// driver and report never special-case a name.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScenarioSpec {
    /// The scenario's registered name (see [`SCENARIO_NAMES`]).
    pub name: &'static str,
    /// Offered rate at the trough, requests/second.
    pub base_rate: f64,
    /// Offered rate at the apex, requests/second.
    pub peak_rate: f64,
    /// How the rate moves between the two across the run.
    pub shape: RateShape,
    /// How arrivals spread over the registered models.
    pub skew: ModelSkew,
    /// How arrivals choose input digests.
    pub inputs: InputMix,
    /// How arrivals carry deadlines.
    pub deadlines: DeadlineMix,
    /// v2 connections that deliberately stall mid-frame for the whole
    /// replay (the slow-loris clients; meaningful only against a wire
    /// endpoint — the in-proc driver has no connections to wedge).
    pub stalled_conns: u32,
}

impl ScenarioSpec {
    /// Look a scenario up by name.
    pub fn named(name: &str) -> Option<ScenarioSpec> {
        let flat = |name| ScenarioSpec {
            name,
            base_rate: 800.0,
            peak_rate: 800.0,
            shape: RateShape::Flat,
            skew: ModelSkew::RoundRobin,
            inputs: InputMix::Shared { distinct: 32 },
            deadlines: DeadlineMix::None,
            stalled_conns: 0,
        };
        match name {
            "diurnal_ramp" => Some(ScenarioSpec {
                base_rate: 300.0,
                peak_rate: 1200.0,
                shape: RateShape::Diurnal,
                ..flat("diurnal_ramp")
            }),
            "flash_crowd" => Some(ScenarioSpec {
                base_rate: 400.0,
                peak_rate: 4000.0,
                shape: RateShape::Flash { from: 0.4, until: 0.7 },
                ..flat("flash_crowd")
            }),
            "zipf_models" => {
                Some(ScenarioSpec { skew: ModelSkew::Zipf { exponent: 1.1 }, ..flat("zipf_models") })
            }
            "cache_hostile" => {
                Some(ScenarioSpec { inputs: InputMix::Unique, ..flat("cache_hostile") })
            }
            "deadline_burst" => Some(ScenarioSpec {
                deadlines: DeadlineMix::Bursts { period: 64, len: 16, deadline_us: 1_500 },
                ..flat("deadline_burst")
            }),
            "slow_loris" => Some(ScenarioSpec {
                base_rate: 400.0,
                peak_rate: 400.0,
                inputs: InputMix::Shared { distinct: 16 },
                stalled_conns: 2,
                ..flat("slow_loris")
            }),
            // steady moderate load spread evenly over the registered
            // models — the co-location workload the arbiter tests replay
            // against a shared-device engine (DESIGN.md §14)
            "multi_tenant" => Some(ScenarioSpec {
                base_rate: 600.0,
                peak_rate: 600.0,
                inputs: InputMix::Shared { distinct: 24 },
                ..flat("multi_tenant")
            }),
            _ => None,
        }
    }

    /// Every named scenario, in [`SCENARIO_NAMES`] order.
    pub fn all() -> Vec<ScenarioSpec> {
        SCENARIO_NAMES.iter().map(|n| ScenarioSpec::named(n).expect("registered name")).collect()
    }

    /// Offered rate (requests/second) at elapsed fraction `frac ∈ [0, 1)`.
    pub fn rate_at(&self, frac: f64) -> f64 {
        match self.shape {
            RateShape::Flat => self.base_rate,
            RateShape::Diurnal => {
                // raised cosine: base at frac 0 and 1, peak at frac 0.5
                let lift = 0.5 - 0.5 * (std::f64::consts::TAU * frac).cos();
                self.base_rate + (self.peak_rate - self.base_rate) * lift
            }
            RateShape::Flash { from, until } => {
                if frac >= from && frac < until {
                    self.peak_rate
                } else {
                    self.base_rate
                }
            }
        }
    }
}

/// One scheduled request: when it is offered, which model it names,
/// which input digest it carries, and its priority/deadline class.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Arrival {
    /// Offset from replay start at which this request is offered.
    pub at: Duration,
    /// Index into the replay's model list (taken modulo its length).
    pub model: usize,
    /// Seed for the deterministic input tensor (equal seeds ⇒ equal
    /// digests, so [`InputMix::Shared`] exercises the result cache).
    pub input_seed: u64,
    /// Batch ordering class the request carries.
    pub priority: Priority,
    /// Deadline the request carries, when the scenario assigns one.
    pub deadline: Option<Duration>,
}

/// A fully materialized arrival schedule: the open-loop replay input.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Schedule {
    /// The scenario this schedule was built from.
    pub scenario: &'static str,
    /// The seed it was built with.
    pub seed: u64,
    /// The span the arrivals cover.
    pub duration: Duration,
    /// Model count the arrivals were drawn over.
    pub models: usize,
    /// Slow-loris connections the replay should wedge (wire mode only).
    pub stalled_conns: u32,
    /// The arrivals, strictly ordered by [`Arrival::at`].
    pub arrivals: Vec<Arrival>,
}

impl Schedule {
    /// Order-sensitive digest over every arrival field — two schedules
    /// are byte-identical iff their fingerprints match. This is what the
    /// CLI prints and the determinism tests compare.
    pub fn fingerprint(&self) -> u64 {
        let mut h = splitmix64(self.seed ^ self.arrivals.len() as u64);
        for a in &self.arrivals {
            h = splitmix64(h ^ a.at.as_nanos() as u64);
            h = splitmix64(h ^ a.model as u64);
            h = splitmix64(h ^ a.input_seed);
            h = splitmix64(h ^ a.priority as u64);
            h = splitmix64(h ^ a.deadline.map_or(u64::MAX, |d| d.as_micros() as u64));
        }
        h
    }
}

/// The schedule builder's PRNG: one splitmix64 round (same mixer the
/// cluster router's rendezvous hash uses).
pub fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// A draw in `[0, 1)` from one splitmix64 output.
fn unit(x: u64) -> f64 {
    (x >> 11) as f64 / (1u64 << 53) as f64
}

/// Build the deterministic arrival schedule for one scenario.
///
/// Inter-arrival gaps are jittered uniformly over `[0.5, 1.5)` of the
/// shape's instantaneous mean gap, so over any window the arrival count
/// stays within analytic bounds of the configured rate (the property
/// tests assert `rate·span / 1.5 ≤ count ≤ rate·span / 0.5` exactly).
/// The draw stream consumes exactly three splitmix64 outputs per
/// arrival, so for rate shapes that do not stretch with the duration
/// ([`RateShape::Flat`]) a schedule built for a shorter duration is a
/// strict prefix of one built for a longer duration — the structural
/// form of the open-loop guarantee (nothing outside `(spec, seed)`
/// feeds the stream).
pub fn build_schedule(
    spec: &ScenarioSpec,
    models: usize,
    seed: u64,
    duration: Duration,
) -> Schedule {
    assert!(models > 0, "schedule needs at least one model");
    let total = duration.as_secs_f64();
    let mut stream = splitmix64(seed ^ 0x7261_6666_6963); // "raffic"
    let mut draw = move || {
        stream = splitmix64(stream);
        stream
    };
    let mut arrivals = Vec::new();
    let mut t = 0.0f64;
    let mut i: u64 = 0;
    loop {
        let rate = spec.rate_at((t / total).min(1.0)).max(1e-9);
        let gap = (0.5 + unit(draw())) / rate;
        let model_u = unit(draw());
        let input_u = draw();
        t += gap;
        if t >= total {
            break;
        }
        let model = match spec.skew {
            ModelSkew::RoundRobin => (i as usize) % models,
            ModelSkew::Zipf { exponent } => zipf_pick(model_u, models, exponent),
        };
        let input_seed = match spec.inputs {
            InputMix::Shared { distinct } => input_u % u64::from(distinct.max(1)),
            // splitmix64 is a bijection, so distinct arrival indices
            // yield distinct seeds — every digest unseen, cache-hostile
            InputMix::Unique => splitmix64(seed ^ (i << 8) ^ 0x756e_6971_7565),
        };
        let deadline = match spec.deadlines {
            DeadlineMix::None => None,
            DeadlineMix::Bursts { period, len, deadline_us } => {
                let phase = (i % u64::from(period.max(1))) as u32;
                (phase >= period.saturating_sub(len))
                    .then(|| Duration::from_micros(u64::from(deadline_us)))
            }
        };
        let priority = if deadline.is_some() { Priority::High } else { Priority::Normal };
        arrivals.push(Arrival {
            at: Duration::from_secs_f64(t),
            model,
            input_seed,
            priority,
            deadline,
        });
        i += 1;
    }
    Schedule {
        scenario: spec.name,
        seed,
        duration,
        models,
        stalled_conns: spec.stalled_conns,
        arrivals,
    }
}

/// Map a uniform draw to a model index under zipf weights
/// `w(k) = 1/(k+1)^s` (models are few, so the linear scan is fine).
fn zipf_pick(u: f64, models: usize, exponent: f64) -> usize {
    let weights: Vec<f64> = (0..models).map(|k| 1.0 / ((k + 1) as f64).powf(exponent)).collect();
    let total: f64 = weights.iter().sum();
    let mut acc = 0.0;
    for (k, w) in weights.iter().enumerate() {
        acc += w / total;
        if u < acc {
            return k;
        }
    }
    models - 1
}
