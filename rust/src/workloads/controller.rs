//! The SLO-driven adaptive placement controller.
//!
//! Split per DESIGN.md §11 into a pure step core and a thin shell:
//!
//! - [`ControllerCore`] — `step(ControllerEvent) -> Vec<ControllerEffect>`.
//!   No clocks, no channels, no engine handle: every input arrives inside
//!   the event (including `now`), every output is a value. That is what
//!   the model checker explores and the unit tests pin down.
//! - [`Controller`] — the shell. It snapshots each model's baseline
//!   [`ModelSpec`] at construction, feeds the core observation ticks, and
//!   applies the returned effects through the engine's existing hot-swap
//!   seam (`Engine::retire` + `Engine::register` with a re-specced model).
//!
//! The core climbs a per-model escalation ladder on sustained SLO
//! breach — flip the model's placement to the designated fast plan, then
//! shed low-priority work and cap the in-flight budget — and descends it
//! on sustained recovery. Both flips are gated by a **hysteresis window**:
//! a model that just flipped cannot flip back until the window has fully
//! elapsed, whatever the observations say, so the controller cannot flap.

use crate::coordinator::{Engine, ModelSpec, Placement, Priority};
use crate::partition::Strategy;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// One model's health as seen at a controller tick. The driver (or any
/// other shell) assembles these from the latency histograms it trusts —
/// wall-clock quantiles in wall replays, deterministic simulated-cost
/// quantiles in virtual replays.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelObservation {
    /// The model this observation describes.
    pub model: String,
    /// p99 latency over the observation window, microseconds.
    pub p99_us: u64,
    /// Requests currently in flight for the model.
    pub in_flight: u64,
    /// Where the model executes right now.
    pub placement: Placement,
}

/// Everything the controller core reacts to.
#[derive(Debug, Clone, PartialEq)]
pub enum ControllerEvent {
    /// A periodic observation tick. `now` is whatever clock the shell
    /// trusts (virtual replay time in deterministic runs) — the core
    /// never reads a clock itself.
    Tick {
        /// Tick timestamp, used only for hysteresis arithmetic.
        now: Instant,
        /// Per-model health at this tick.
        observations: Vec<ModelObservation>,
    },
}

/// Which end of the placement flip an effect targets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlipTo {
    /// Re-spec the model onto the configured fast placement
    /// (hetero pipeline under [`ControllerConfig::fast_strategy`]).
    Fast,
    /// Restore the model's baseline spec (whatever it was registered
    /// with before the controller first intervened).
    Baseline,
}

/// Everything the controller core can ask its shell to do.
#[derive(Debug, Clone, PartialEq)]
pub enum ControllerEffect {
    /// Hot-swap the model's placement (retire + register re-spec).
    Flip {
        /// The model to re-spec.
        model: String,
        /// Which direction to flip.
        to: FlipTo,
    },
    /// Stop admitting work below `floor` for this model (the driver's
    /// front-door shed valve; [`Priority::Low`] means admit everything).
    ShedFloor {
        /// The model the floor applies to.
        model: String,
        /// Minimum priority still admitted.
        floor: Priority,
    },
    /// Cap (or, with 0, uncap) the model's in-flight budget on the next
    /// re-spec.
    SetBudget {
        /// The model whose budget changes.
        model: String,
        /// New in-flight cap; 0 removes the cap.
        budget: u64,
    },
}

/// Tuning for [`ControllerCore`].
#[derive(Debug, Clone, PartialEq)]
pub struct ControllerConfig {
    /// The SLO the controller defends: p99 latency, microseconds.
    pub slo_p99_us: u64,
    /// Consecutive over-SLO ticks before the core escalates.
    pub breach_ticks: u32,
    /// Consecutive recovered ticks before the core de-escalates.
    pub clear_ticks: u32,
    /// Recovery must reach `slo_p99_us * clear_frac` — the dead band
    /// between the escalate and de-escalate thresholds.
    pub clear_frac: f64,
    /// Minimum spacing between opposite placement flips of one model.
    pub hysteresis: Duration,
    /// The plan a breaching model is flipped onto.
    pub fast_strategy: Strategy,
    /// In-flight cap imposed at the shedding rung of the ladder.
    pub shed_budget: u64,
}

impl Default for ControllerConfig {
    fn default() -> Self {
        Self {
            slo_p99_us: 50_000,
            breach_ticks: 2,
            clear_ticks: 4,
            clear_frac: 0.8,
            hysteresis: Duration::from_millis(50),
            fast_strategy: Strategy::Paper,
            shed_budget: 64,
        }
    }
}

/// Per-model ladder state inside the core.
#[derive(Debug, Clone, Default)]
struct Rung {
    /// 0 = baseline, 1 = flipped fast, 2 = flipped fast + shedding.
    level: u8,
    /// Consecutive ticks over the SLO.
    over: u32,
    /// Consecutive ticks under the recovery threshold.
    under: u32,
    /// When this model last changed placement (either direction).
    last_flip: Option<Instant>,
}

/// The pure decision core. Feed it ticks, apply what it returns.
#[derive(Debug, Clone)]
pub struct ControllerCore {
    cfg: ControllerConfig,
    models: BTreeMap<String, Rung>,
}

impl ControllerCore {
    /// A core with no per-model history yet.
    pub fn new(cfg: ControllerConfig) -> Self {
        Self { cfg, models: BTreeMap::new() }
    }

    /// The configuration the core was built with.
    pub fn config(&self) -> &ControllerConfig {
        &self.cfg
    }

    /// A model's current escalation rung (0 = baseline, 1 = flipped,
    /// 2 = flipped + shedding). Unobserved models sit at 0.
    pub fn level(&self, model: &str) -> u8 {
        self.models.get(model).map_or(0, |r| r.level)
    }

    /// Whether a placement flip of `model` is allowed at `now` — false
    /// until one full hysteresis window has passed since its last flip.
    fn flip_allowed(&self, model: &str, now: Instant) -> bool {
        match self.models.get(model).and_then(|r| r.last_flip) {
            Some(at) => now.saturating_duration_since(at) >= self.cfg.hysteresis,
            None => true,
        }
    }

    /// Advance the core by one event. Pure: equal state + equal event ⇒
    /// equal effects, every time.
    pub fn step(&mut self, event: ControllerEvent) -> Vec<ControllerEffect> {
        let ControllerEvent::Tick { now, observations } = event;
        let mut effects = Vec::new();
        for obs in observations {
            let rung = self.models.entry(obs.model.clone()).or_default();
            let breached = obs.p99_us > self.cfg.slo_p99_us;
            let recovered = (obs.p99_us as f64) <= self.cfg.slo_p99_us as f64 * self.cfg.clear_frac;
            if breached {
                rung.over += 1;
                rung.under = 0;
            } else if recovered {
                rung.under += 1;
                rung.over = 0;
            } else {
                // dead band: decay both streaks, change nothing
                rung.over = 0;
                rung.under = 0;
            }
            let level = rung.level;
            let sustained_breach = rung.over >= self.cfg.breach_ticks;
            let sustained_recovery = rung.under >= self.cfg.clear_ticks;
            // borrow ends here; re-borrow mutably only where a rung changes
            match level {
                0 if sustained_breach => {
                    if self.flip_allowed(&obs.model, now) {
                        let rung = self.models.get_mut(&obs.model).expect("rung just inserted");
                        rung.level = 1;
                        rung.over = 0;
                        rung.last_flip = Some(now);
                        effects.push(ControllerEffect::Flip {
                            model: obs.model.clone(),
                            to: FlipTo::Fast,
                        });
                    }
                }
                1 if sustained_breach => {
                    // the flip was not enough: shed below Normal and cap
                    // the budget so queues stop compounding
                    let rung = self.models.get_mut(&obs.model).expect("rung exists");
                    rung.level = 2;
                    rung.over = 0;
                    effects.push(ControllerEffect::SetBudget {
                        model: obs.model.clone(),
                        budget: self.cfg.shed_budget,
                    });
                    effects.push(ControllerEffect::ShedFloor {
                        model: obs.model.clone(),
                        floor: Priority::Normal,
                    });
                }
                2 if sustained_recovery => {
                    // stop shedding first; placement stays fast until the
                    // recovery survives another full clear window
                    let rung = self.models.get_mut(&obs.model).expect("rung exists");
                    rung.level = 1;
                    rung.under = 0;
                    effects.push(ControllerEffect::SetBudget { model: obs.model.clone(), budget: 0 });
                    effects.push(ControllerEffect::ShedFloor {
                        model: obs.model.clone(),
                        floor: Priority::Low,
                    });
                }
                1 if sustained_recovery => {
                    if self.flip_allowed(&obs.model, now) {
                        let rung = self.models.get_mut(&obs.model).expect("rung exists");
                        rung.level = 0;
                        rung.under = 0;
                        rung.last_flip = Some(now);
                        effects.push(ControllerEffect::Flip {
                            model: obs.model.clone(),
                            to: FlipTo::Baseline,
                        });
                    }
                }
                _ => {}
            }
        }
        effects
    }
}

/// The thin shell: owns an [`Engine`] clone and the baseline specs, and
/// turns core effects into engine calls. Placement flips and budget
/// changes go through the existing `retire` + `register` hot-swap;
/// [`ControllerEffect::ShedFloor`] is recorded here for the replay
/// driver's front door to enforce (the engine has no priority valve —
/// shedding before submit is the client-side half of the contract).
pub struct Controller {
    engine: Engine,
    core: ControllerCore,
    baseline: BTreeMap<String, ModelSpec>,
    floors: BTreeMap<String, Priority>,
    budgets: BTreeMap<String, u64>,
    flips: u64,
    actions: Vec<String>,
}

impl Controller {
    /// Snapshot every registered model's spec as its baseline and wrap a
    /// fresh core around `cfg`.
    pub fn new(engine: Engine, cfg: ControllerConfig) -> Self {
        let mut baseline = BTreeMap::new();
        for name in engine.models() {
            if let Some(spec) = engine.spec(&name) {
                baseline.insert(name, spec);
            }
        }
        Self {
            engine,
            core: ControllerCore::new(cfg),
            baseline,
            floors: BTreeMap::new(),
            budgets: BTreeMap::new(),
            flips: 0,
            actions: Vec::new(),
        }
    }

    /// The core's view of a model's ladder rung (see
    /// [`ControllerCore::level`]).
    pub fn level(&self, model: &str) -> u8 {
        self.core.level(model)
    }

    /// Placement flips applied so far (both directions).
    pub fn flips(&self) -> u64 {
        self.flips
    }

    /// Human-readable log of every effect applied, in order.
    pub fn actions(&self) -> &[String] {
        &self.actions
    }

    /// The front-door admission floor for a model, when the controller
    /// is shedding it ([`Priority::Low`] / absent means admit all).
    pub fn shed_floor(&self, model: &str) -> Priority {
        self.floors.get(model).copied().unwrap_or(Priority::Low)
    }

    /// Feed the core one tick and apply whatever it returns. Returns how
    /// many effects were applied.
    pub fn tick(&mut self, now: Instant, observations: Vec<ModelObservation>) -> usize {
        let effects = self.core.step(ControllerEvent::Tick { now, observations });
        let n = effects.len();
        for effect in effects {
            self.apply(effect);
        }
        n
    }

    /// Build the re-spec for a flip direction from the model's baseline.
    fn respec(&self, model: &str, to: FlipTo) -> Option<ModelSpec> {
        let mut spec = self.baseline.get(model)?.clone();
        if to == FlipTo::Fast {
            spec.placement = Placement::Hetero;
            spec.strategy = self.core.config().fast_strategy;
        }
        if let Some(&budget) = self.budgets.get(model) {
            spec.budget = (budget > 0).then_some(budget);
        }
        Some(spec)
    }

    fn apply(&mut self, effect: ControllerEffect) {
        match effect {
            ControllerEffect::Flip { model, to } => {
                let Some(spec) = self.respec(&model, to) else { return };
                // an operator may have retired the model out from under
                // us — a failed actuation is logged, never fatal
                match self.engine.retire(&model).and_then(|()| self.engine.register(spec)) {
                    Ok(()) => {
                        self.flips += 1;
                        self.actions.push(format!("flip {model} -> {to:?}"));
                    }
                    Err(e) => self.actions.push(format!("flip {model} -> {to:?} failed: {e}")),
                }
            }
            ControllerEffect::SetBudget { model, budget } => {
                self.budgets.insert(model.clone(), budget);
                let flipped = self.core.level(&model) >= 1;
                let to = if flipped { FlipTo::Fast } else { FlipTo::Baseline };
                let Some(spec) = self.respec(&model, to) else { return };
                match self.engine.retire(&model).and_then(|()| self.engine.register(spec)) {
                    Ok(()) => self.actions.push(format!("budget {model} -> {budget}")),
                    Err(e) => self.actions.push(format!("budget {model} -> {budget} failed: {e}")),
                }
            }
            ControllerEffect::ShedFloor { model, floor } => {
                self.actions.push(format!("shed-floor {model} -> {floor:?}"));
                if floor == Priority::Low {
                    self.floors.remove(&model);
                } else {
                    self.floors.insert(model, floor);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ControllerConfig {
        ControllerConfig {
            slo_p99_us: 1_000,
            breach_ticks: 2,
            clear_ticks: 2,
            clear_frac: 0.8,
            hysteresis: Duration::from_millis(10),
            ..ControllerConfig::default()
        }
    }

    fn obs(p99_us: u64) -> Vec<ModelObservation> {
        vec![ModelObservation {
            model: "m".into(),
            p99_us,
            in_flight: 0,
            placement: Placement::Pool,
        }]
    }

    fn flips_of(effects: &[ControllerEffect]) -> Vec<FlipTo> {
        effects
            .iter()
            .filter_map(|e| match e {
                ControllerEffect::Flip { to, .. } => Some(*to),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn escalates_only_on_sustained_breach() {
        let mut core = ControllerCore::new(cfg());
        let t0 = Instant::now();
        assert!(core.step(ControllerEvent::Tick { now: t0, observations: obs(5_000) }).is_empty());
        let fx = core
            .step(ControllerEvent::Tick { now: t0 + Duration::from_millis(1), observations: obs(5_000) });
        assert_eq!(flips_of(&fx), vec![FlipTo::Fast]);
        assert_eq!(core.level("m"), 1);
    }

    #[test]
    fn one_over_tick_is_not_a_breach() {
        let mut core = ControllerCore::new(cfg());
        let t0 = Instant::now();
        assert!(core.step(ControllerEvent::Tick { now: t0, observations: obs(5_000) }).is_empty());
        // recovery resets the streak
        let _ = core
            .step(ControllerEvent::Tick { now: t0 + Duration::from_millis(1), observations: obs(100) });
        assert!(core
            .step(ControllerEvent::Tick {
                now: t0 + Duration::from_millis(2),
                observations: obs(5_000)
            })
            .is_empty());
        assert_eq!(core.level("m"), 0);
    }

    #[test]
    fn hysteresis_blocks_the_opposite_flip() {
        let mut core = ControllerCore::new(cfg());
        let t0 = Instant::now();
        let ms = Duration::from_millis;
        for k in 0..2 {
            let _ = core.step(ControllerEvent::Tick { now: t0 + ms(k), observations: obs(5_000) });
        }
        assert_eq!(core.level("m"), 1);
        // instant recovery — but the window has not elapsed, so no flip
        for k in 2..6 {
            let fx = core.step(ControllerEvent::Tick { now: t0 + ms(k), observations: obs(100) });
            assert!(flips_of(&fx).is_empty(), "flap inside the hysteresis window");
        }
        assert_eq!(core.level("m"), 1);
        // once the window elapses, the same observations flip it back
        let fx = core.step(ControllerEvent::Tick { now: t0 + ms(20), observations: obs(100) });
        assert_eq!(flips_of(&fx), vec![FlipTo::Baseline]);
        assert_eq!(core.level("m"), 0);
    }

    #[test]
    fn shedding_rung_engages_and_releases() {
        let mut core = ControllerCore::new(cfg());
        let t0 = Instant::now();
        let ms = Duration::from_millis;
        for k in 0..4 {
            let _ = core.step(ControllerEvent::Tick { now: t0 + ms(k), observations: obs(5_000) });
        }
        assert_eq!(core.level("m"), 2);
        let fx = core.step(ControllerEvent::Tick { now: t0 + ms(4), observations: obs(5_000) });
        assert!(fx.is_empty(), "level 2 is the ladder top");
        // sustained recovery releases the shed valve before flipping back
        let _ = core.step(ControllerEvent::Tick { now: t0 + ms(30), observations: obs(100) });
        let fx = core.step(ControllerEvent::Tick { now: t0 + ms(31), observations: obs(100) });
        assert!(fx.contains(&ControllerEffect::ShedFloor {
            model: "m".into(),
            floor: Priority::Low
        }));
        assert_eq!(core.level("m"), 1);
    }
}
