//! The traffic lab: open-loop workload generation, replay, and the
//! SLO-driven adaptive placement controller (DESIGN.md §13).
//!
//! Three pieces, layered front to back:
//!
//! - [`scenario`] — seven named traffic scenarios as **data**
//!   ([`ScenarioSpec`]) and the seeded builder that turns one into a
//!   deterministic [`Schedule`] of arrivals. The schedule is a pure
//!   function of `(scenario, seed)` — never of completion times — which
//!   is what makes replays open-loop (no coordinated omission).
//! - [`driver`] — replays a schedule against an in-process
//!   [`Engine`](crate::coordinator::Engine) or any wire-protocol-v2
//!   endpoint, and folds the outcome into an [`SloReport`] (SLO
//!   attainment, latency quantiles, shed/rejected counts,
//!   joules/inference).
//! - [`controller`] — a pure [`ControllerCore`] step core plus the thin
//!   [`Controller`] shell that watches latency histograms and
//!   device metrics on a tick and re-places models live through the
//!   engine's hot-swap seam, with hysteresis so it cannot flap.
//!
//! The `traffic-lab` CLI subcommand and `tests/integration_traffic.rs`
//! are the two front doors; `check::scenarios::controller_actions_linearized`
//! model-checks the controller's flip against racing operator swaps.

#![warn(missing_docs)]

pub mod controller;
pub mod driver;
pub mod scenario;

pub use controller::{
    Controller, ControllerConfig, ControllerCore, ControllerEffect, ControllerEvent, FlipTo,
    ModelObservation,
};
pub use driver::{
    replay_endpoint, replay_engine, stall_connections, Pacing, ReplayConfig, SloReport,
};
pub use scenario::{
    build_schedule, Arrival, DeadlineMix, InputMix, ModelSkew, RateShape, Schedule, ScenarioSpec,
    SCENARIO_NAMES,
};
