//! The replay driver: offer a [`Schedule`] to a serving target and fold
//! what happens into an [`SloReport`].
//!
//! Two pacing modes (DESIGN.md §13):
//!
//! - [`Pacing::Virtual`] — the deterministic mode. Arrivals are replayed
//!   in schedule order through [`Engine::infer`], latency is the
//!   request's **simulated platform cost** (microseconds, from
//!   [`InferenceResponse::simulated`]; a cache hit costs 0), deadlines
//!   are judged against that virtual latency by the driver, and the
//!   controller's clock is the schedule's own arrival offsets. Every
//!   quantity in the report is a pure function of
//!   `(schedule, engine config, replay config)` — same seed, same
//!   `SloReport`, bit for bit.
//! - [`Pacing::Wall`] — the open-loop load test. Arrivals are submitted
//!   at their scheduled wall-clock times (optionally time-scaled)
//!   through the pipelined [`Engine::submit`] seam, deadlines ride the
//!   requests into the engine, and latency is measured **from the
//!   scheduled arrival time** — a submit delayed by backpressure still
//!   charges the server for the wait, so a slow server cannot thin the
//!   offered load (no coordinated omission).
//!
//! [`replay_endpoint`] replays wall-paced through an [`AsyncClient`], so
//! anything that speaks wire protocol v2 — a plain [`Server`], the
//! cluster router — can sit on the other side.
//! [`stall_connections`] wedges slow-loris connections against such an
//! endpoint: each sends a valid HELLO and then the first bytes of a
//! request frame, and stalls mid-frame holding the socket open.
//!
//! [`Server`]: crate::coordinator::server::Server

use super::controller::{Controller, ControllerConfig, ModelObservation};
use super::scenario::{splitmix64, Schedule};
use crate::coordinator::protocol::{self, AsyncClient, Reply};
use crate::coordinator::{Completion, Engine, InferenceRequest, InferenceResponse};
use crate::metrics::histogram::LogHistogram;
use crate::obs::NodeStats;
use crate::runtime::{RuntimeError, Tensor};
use std::collections::BTreeMap;
use std::fmt;
use std::io::Write;
use std::net::{SocketAddr, TcpStream};
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// How the driver paces a schedule against its target.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Pacing {
    /// Deterministic sequential replay on virtual time (see module doc).
    Virtual,
    /// Real open-loop pacing at `speedup`× schedule time (1.0 = real
    /// time; 10.0 compresses a 2 s schedule into 200 ms of wall clock).
    Wall {
        /// Time-compression factor applied to arrival offsets.
        speedup: f64,
    },
}

/// Replay knobs shared by every scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplayConfig {
    /// The p99 target a reply must beat to count toward attainment, µs.
    pub slo_p99_us: u64,
    /// How the schedule is paced (see [`Pacing`]).
    pub pacing: Pacing,
    /// Run the adaptive controller with this tuning (`None` = off).
    pub controller: Option<ControllerConfig>,
    /// Arrivals between controller observation ticks.
    pub tick_every: u32,
}

impl Default for ReplayConfig {
    fn default() -> Self {
        Self {
            slo_p99_us: 50_000,
            pacing: Pacing::Virtual,
            controller: None,
            tick_every: 25,
        }
    }
}

/// What one scenario replay did to the target, folded per DESIGN.md §13.
///
/// The accounting identity the integration suite pins:
/// `submitted == served + shed + rejected + errors` — nothing offered is
/// ever lost or double-counted.
#[derive(Debug, Clone, PartialEq)]
pub struct SloReport {
    /// Scenario the replayed schedule was built from.
    pub scenario: String,
    /// Seed the schedule was built with.
    pub seed: u64,
    /// The p99 target replies were judged against, µs.
    pub slo_p99_us: u64,
    /// Requests the schedule offered.
    pub submitted: u64,
    /// Requests answered successfully (cache hits included).
    pub served: u64,
    /// Requests shed for deadline/admission reasons (engine shed,
    /// deadline exceeded, drained by a retire — or, virtually, a reply
    /// whose simulated latency overran its deadline).
    pub shed: u64,
    /// Requests rejected before execution (budget caps, controller
    /// shed-floor at the driver's front door).
    pub rejected: u64,
    /// Requests that failed for any other reason.
    pub errors: u64,
    /// Served requests whose latency beat [`SloReport::slo_p99_us`].
    pub within_slo: u64,
    /// Median latency over answered requests, µs ([`LogHistogram`]).
    pub p50_us: u64,
    /// p99 latency over answered requests, µs ([`LogHistogram`]).
    pub p99_us: u64,
    /// Energy per hetero-served inference, joules — summed over each
    /// model's [`Engine::device_metrics`] lanes at report time; 0.0 when
    /// nothing ran on a hetero placement.
    pub joules_per_inference: f64,
    /// Controller effects applied during the replay.
    pub controller_actions: u64,
    /// Placement flips among those effects.
    pub controller_flips: u64,
    /// Flight-recorder stage-latency breakdown snapshotted from the
    /// engine at report time — all zeros when the engine runs with
    /// tracing off or the target sits across the wire. **Excluded from
    /// [`SloReport::fingerprint`]**: stage latencies are wall-clock
    /// measurements and must not break replay-determinism assertions.
    pub stages: NodeStats,
}

impl SloReport {
    /// Fraction of **offered** requests answered within the SLO — shed,
    /// rejected and failed work all count against attainment.
    pub fn attainment(&self) -> f64 {
        self.within_slo as f64 / self.submitted.max(1) as f64
    }

    /// Order-insensitive digest over every field, for the determinism
    /// assertions (`--seed N` twice ⇒ equal fingerprints).
    pub fn fingerprint(&self) -> u64 {
        let mut h = splitmix64(self.seed ^ self.submitted);
        for v in [
            self.slo_p99_us,
            self.served,
            self.shed,
            self.rejected,
            self.errors,
            self.within_slo,
            self.p50_us,
            self.p99_us,
            self.joules_per_inference.to_bits(),
            self.controller_actions,
            self.controller_flips,
        ] {
            h = splitmix64(h ^ v);
        }
        h
    }
}

impl fmt::Display for SloReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{:<14} seed={} offered={} served={} shed={} rejected={} errors={} \
             attainment={:.4} p50={}us p99={}us (slo {}us) J/inf={:.4} ctl={}/{}",
            self.scenario,
            self.seed,
            self.submitted,
            self.served,
            self.shed,
            self.rejected,
            self.errors,
            self.attainment(),
            self.p50_us,
            self.p99_us,
            self.slo_p99_us,
            self.joules_per_inference,
            self.controller_flips,
            self.controller_actions,
        )?;
        if !self.stages.is_empty() {
            write!(f, "\n{}", self.stages.table().trim_end())?;
        }
        Ok(())
    }
}

/// Internal tally shared by both pacing modes.
struct Tally {
    served: u64,
    shed: u64,
    rejected: u64,
    errors: u64,
    within: u64,
    hist: LogHistogram,
    /// Per-model latency histogram since the last controller tick.
    window: BTreeMap<String, LogHistogram>,
}

impl Tally {
    fn new() -> Self {
        Self {
            served: 0,
            shed: 0,
            rejected: 0,
            errors: 0,
            within: 0,
            hist: LogHistogram::new(),
            window: BTreeMap::new(),
        }
    }

    fn record_latency(&mut self, model: &str, us: u64) {
        self.hist.record(us);
        self.window.entry(model.to_string()).or_insert_with(LogHistogram::new).record(us);
    }

    fn classify_err(&mut self, e: &RuntimeError) {
        match e {
            RuntimeError::Shed { .. }
            | RuntimeError::DeadlineExceeded { .. }
            | RuntimeError::ModelRetiring { .. } => self.shed += 1,
            RuntimeError::BudgetExhausted { .. } => self.rejected += 1,
            _ => self.errors += 1,
        }
    }

    fn observations(&self, engine: &Engine, models: &[String]) -> Vec<ModelObservation> {
        models
            .iter()
            .map(|m| ModelObservation {
                model: m.clone(),
                p99_us: self.window.get(m).map_or(0, |h| h.quantile(0.99)),
                in_flight: engine.in_flight(m).unwrap_or(0),
                placement: engine.placement(m).unwrap_or_default(),
            })
            .collect()
    }

    fn into_report(self, schedule: &Schedule, cfg: &ReplayConfig, engine: &Engine) -> SloReport {
        let (mut joules, mut images) = (0.0f64, 0u64);
        for m in engine.models() {
            if let Some(dm) = engine.device_metrics(&m) {
                joules += dm.gpu.joules() + dm.fpga.joules() + dm.link.joules();
                images += dm.images();
            }
        }
        SloReport {
            scenario: schedule.scenario.to_string(),
            seed: schedule.seed,
            slo_p99_us: cfg.slo_p99_us,
            submitted: schedule.arrivals.len() as u64,
            served: self.served,
            shed: self.shed,
            rejected: self.rejected,
            errors: self.errors,
            within_slo: self.within,
            p50_us: self.hist.quantile(0.5),
            p99_us: self.hist.quantile(0.99),
            joules_per_inference: if images == 0 { 0.0 } else { joules / images as f64 },
            controller_actions: 0,
            controller_flips: 0,
            stages: engine.node_stats(),
        }
    }
}

/// Replay a schedule against an in-process [`Engine`] under `cfg` and
/// fold the outcome into an [`SloReport`]. The engine's model list is
/// snapshotted at entry; arrival model indices map into that snapshot
/// (modulo), so controller hot-swaps mid-replay never re-aim traffic.
pub fn replay_engine(engine: &Engine, schedule: &Schedule, cfg: &ReplayConfig) -> SloReport {
    let models = engine.models();
    assert!(!models.is_empty(), "replay target serves no models");
    match cfg.pacing {
        Pacing::Virtual => replay_virtual(engine, schedule, cfg, &models),
        Pacing::Wall { speedup } => replay_wall(engine, schedule, cfg, &models, speedup),
    }
}

fn controller_tick(
    controller: &mut Option<Controller>,
    tally: &mut Tally,
    engine: &Engine,
    models: &[String],
    now: Instant,
    actions: &mut u64,
) {
    if let Some(ctl) = controller.as_mut() {
        let obs = tally.observations(engine, models);
        *actions += ctl.tick(now, obs) as u64;
        tally.window.clear();
    }
}

fn replay_virtual(
    engine: &Engine,
    schedule: &Schedule,
    cfg: &ReplayConfig,
    models: &[String],
) -> SloReport {
    // the virtual epoch: only offsets from it ever matter, so the
    // controller's hysteresis arithmetic is replay-deterministic
    let t0 = Instant::now();
    let mut controller = cfg.controller.clone().map(|c| Controller::new(engine.clone(), c));
    let mut tally = Tally::new();
    let mut actions = 0u64;
    let tick_every = cfg.tick_every.max(1) as usize;
    for (idx, a) in schedule.arrivals.iter().enumerate() {
        if idx > 0 && idx % tick_every == 0 {
            controller_tick(&mut controller, &mut tally, engine, models, t0 + a.at, &mut actions);
        }
        let model = &models[a.model % models.len()];
        if let Some(ctl) = &controller {
            if a.priority < ctl.shed_floor(model) {
                tally.rejected += 1;
                continue;
            }
        }
        let Some(shape) = engine.input_shape(model) else {
            tally.errors += 1;
            continue;
        };
        // the deadline is judged against virtual latency below, not
        // handed to the engine — wall-clock queue timers would leak
        // machine speed into the report
        let req = InferenceRequest::new(model.clone(), Tensor::randn(&shape, a.input_seed))
            .with_priority(a.priority);
        match engine.infer(req) {
            Ok(resp) => {
                let virt_us = virtual_us(&resp);
                tally.record_latency(model, virt_us);
                match a.deadline {
                    Some(d) if u128::from(virt_us) > d.as_micros() => tally.shed += 1,
                    _ => {
                        tally.served += 1;
                        if virt_us <= cfg.slo_p99_us {
                            tally.within += 1;
                        }
                    }
                }
            }
            Err(e) => tally.classify_err(&e),
        }
    }
    let flips = controller.as_ref().map_or(0, |c| c.flips());
    let mut report = tally.into_report(schedule, cfg, engine);
    report.controller_actions = actions;
    report.controller_flips = flips;
    report
}

/// A reply's virtual latency: its simulated platform cost in µs (a
/// cache hit reuses a computed result — zero platform cost).
fn virtual_us(resp: &InferenceResponse) -> u64 {
    if resp.cached {
        0
    } else {
        (resp.simulated.seconds * 1e6).round() as u64
    }
}

fn replay_wall(
    engine: &Engine,
    schedule: &Schedule,
    cfg: &ReplayConfig,
    models: &[String],
    speedup: f64,
) -> SloReport {
    let speedup = speedup.max(1e-9);
    let mut controller = cfg.controller.clone().map(|c| Controller::new(engine.clone(), c));
    let mut tally = Tally::new();
    let mut actions = 0u64;
    let tick_every = cfg.tick_every.max(1) as usize;
    let (sink, completions) = mpsc::channel::<Completion>();
    // tag → (model index, scheduled offer time): latency is measured
    // from the *scheduled* time, so late submits still charge the server
    let mut pending: BTreeMap<u64, (usize, Instant)> = BTreeMap::new();
    let mut outstanding = 0u64;
    let start = Instant::now();
    let slo = cfg.slo_p99_us;
    for (idx, a) in schedule.arrivals.iter().enumerate() {
        let due = start + a.at.div_f64(speedup);
        loop {
            while let Ok(c) = completions.try_recv() {
                outstanding -= 1;
                settle_completion(&mut tally, &mut pending, models, slo, c);
            }
            let now = Instant::now();
            if now >= due {
                break;
            }
            std::thread::sleep((due - now).min(Duration::from_micros(200)));
        }
        if idx > 0 && idx % tick_every == 0 {
            controller_tick(
                &mut controller,
                &mut tally,
                engine,
                models,
                Instant::now(),
                &mut actions,
            );
        }
        let mi = a.model % models.len();
        let model = &models[mi];
        if let Some(ctl) = &controller {
            if a.priority < ctl.shed_floor(model) {
                tally.rejected += 1;
                continue;
            }
        }
        let Some(shape) = engine.input_shape(model) else {
            tally.errors += 1;
            continue;
        };
        let mut req = InferenceRequest::new(model.clone(), Tensor::randn(&shape, a.input_seed))
            .with_priority(a.priority);
        if let Some(d) = a.deadline {
            req = req.with_deadline(d);
        }
        let tag = idx as u64;
        pending.insert(tag, (mi, due));
        match engine.submit(req, tag, &sink) {
            Ok(()) => outstanding += 1,
            Err(e) => {
                pending.remove(&tag);
                tally.classify_err(&e);
            }
        }
    }
    // open loop is over; wait (bounded) for the tail of the pipeline
    let deadline = Instant::now() + Duration::from_secs(10);
    while outstanding > 0 && Instant::now() < deadline {
        match completions.recv_timeout(Duration::from_millis(100)) {
            Ok(c) => {
                outstanding -= 1;
                settle_completion(&mut tally, &mut pending, models, slo, c);
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        }
    }
    tally.errors += outstanding; // replies that never came back
    let flips = controller.as_ref().map_or(0, |c| c.flips());
    let mut report = tally.into_report(schedule, cfg, engine);
    report.controller_actions = actions;
    report.controller_flips = flips;
    report
}

/// Fold one pipelined engine completion into the tally, charging
/// latency from the request's scheduled arrival time.
fn settle_completion(
    tally: &mut Tally,
    pending: &mut BTreeMap<u64, (usize, Instant)>,
    models: &[String],
    slo: u64,
    c: Completion,
) {
    let Some((mi, scheduled)) = pending.remove(&c.tag) else { return };
    match c.result {
        Ok(_) => {
            let us = Instant::now().saturating_duration_since(scheduled).as_micros() as u64;
            tally.record_latency(&models[mi], us);
            tally.served += 1;
            if us <= slo {
                tally.within += 1;
            }
        }
        Err(e) => tally.classify_err(&e),
    }
}

/// Fold one wire reply into the tally, mapping wire error codes onto
/// the same shed/rejected/error classes the in-proc replay uses.
fn settle_reply(
    tally: &mut Tally,
    pending: &mut BTreeMap<u64, (usize, Instant)>,
    models: &[String],
    slo: u64,
    reply: Reply,
) {
    let (id, outcome) = match reply {
        Reply::Response(r) => (r.id, Ok(())),
        Reply::Error { id, code, .. } => (id, Err(code)),
    };
    let Some((mi, scheduled)) = pending.remove(&id) else { return };
    match outcome {
        Ok(()) => {
            let us = Instant::now().saturating_duration_since(scheduled).as_micros() as u64;
            tally.record_latency(&models[mi], us);
            tally.served += 1;
            if us <= slo {
                tally.within += 1;
            }
        }
        Err(code) => match code.as_str() {
            "shed" | "deadline_exceeded" | "model_retiring" => tally.shed += 1,
            "budget_exhausted" => tally.rejected += 1,
            _ => tally.errors += 1,
        },
    }
}

/// Replay a schedule wall-paced through wire protocol v2 against
/// whatever serves at `addr` — a single node or the cluster router.
/// Latency is measured from each arrival's scheduled time (open loop);
/// the adaptive controller does not run here (it needs an in-process
/// [`Engine`] to actuate).
pub fn replay_endpoint(
    addr: &SocketAddr,
    schedule: &Schedule,
    cfg: &ReplayConfig,
) -> std::io::Result<SloReport> {
    let speedup = match cfg.pacing {
        Pacing::Wall { speedup } => speedup.max(1e-9),
        Pacing::Virtual => 1.0,
    };
    let mut client = AsyncClient::connect(addr)?;
    let models: Vec<String> = client.models().iter().map(|(n, _)| n.clone()).collect();
    let shapes: Vec<Vec<usize>> = client.models().iter().map(|(_, s)| s.clone()).collect();
    assert!(!models.is_empty(), "endpoint serves no models");
    let mut tally = Tally::new();
    // id → (model index, scheduled offer time); AsyncClient ids are
    // assigned by submit, returned to us for matching
    let mut pending: BTreeMap<u64, (usize, Instant)> = BTreeMap::new();
    let start = Instant::now();
    let slo = cfg.slo_p99_us;
    for a in &schedule.arrivals {
        let due = start + a.at.div_f64(speedup);
        loop {
            let now = Instant::now();
            if now >= due {
                break;
            }
            // drain the socket while waiting so the server's write side
            // never backs up into our submit path
            if !pending.is_empty() && due - now > Duration::from_millis(2) {
                if let Ok(reply) = client.recv_deadline(Duration::from_millis(1)) {
                    settle_reply(&mut tally, &mut pending, &models, slo, reply);
                }
            } else {
                std::thread::sleep((due - now).min(Duration::from_micros(200)));
            }
        }
        let mi = a.model % models.len();
        // stay under the server's per-connection pipelining window
        while client.in_flight() >= 128 {
            match client.recv_deadline(Duration::from_millis(50)) {
                Ok(reply) => settle_reply(&mut tally, &mut pending, &models, slo, reply),
                Err(e) if protocol::is_timeout(&e) => {}
                Err(e) => return Err(e),
            }
        }
        let input = Tensor::randn(&shapes[mi], a.input_seed);
        let id = client.submit_with(Some(&models[mi]), &input, a.priority, a.deadline)?;
        pending.insert(id, (mi, due));
    }
    let deadline = Instant::now() + Duration::from_secs(10);
    while !pending.is_empty() && Instant::now() < deadline {
        match client.recv_deadline(Duration::from_millis(100)) {
            Ok(reply) => settle_reply(&mut tally, &mut pending, &models, slo, reply),
            Err(e) if protocol::is_timeout(&e) => {}
            Err(e) => return Err(e),
        }
    }
    tally.errors += pending.len() as u64;
    // fold with a detached engine view: no device metrics over the wire
    let report = SloReport {
        scenario: schedule.scenario.to_string(),
        seed: schedule.seed,
        slo_p99_us: cfg.slo_p99_us,
        submitted: schedule.arrivals.len() as u64,
        served: tally.served,
        shed: tally.shed,
        rejected: tally.rejected,
        errors: tally.errors,
        within_slo: tally.within,
        p50_us: tally.hist.quantile(0.5),
        p99_us: tally.hist.quantile(0.99),
        joules_per_inference: 0.0,
        controller_actions: 0,
        controller_flips: 0,
        stages: NodeStats::default(),
    };
    Ok(report)
}

/// Open `n` slow-loris connections against a v2 endpoint: each performs
/// a valid HELLO, then writes only the first 8 bytes of a request frame
/// and stalls, holding the socket (and exactly one server reader thread)
/// hostage. Returns the live sockets — drop them to release the server.
/// Well-behaved sibling connections must keep serving throughout; the
/// integration suite asserts exactly that.
pub fn stall_connections(addr: &SocketAddr, n: u32) -> std::io::Result<Vec<TcpStream>> {
    let mut held = Vec::with_capacity(n as usize);
    for i in 0..n {
        let mut s = TcpStream::connect(addr)?;
        s.write_all(&protocol::encode_hello())?;
        let frame = protocol::encode_request_header(&protocol::RequestHeader {
            id: u64::from(i) + 1,
            model: 0,
            priority: 0,
            deadline_us: 0,
            dims: vec![1, 56, 56, 96],
        });
        // mid-frame stall: prelude only, the header's remaining 16 bytes
        // (and the whole payload) never arrive
        s.write_all(&frame[..8])?;
        s.flush()?;
        held.push(s);
    }
    Ok(held)
}
