//! The serving face: a multi-model, batch-first [`Engine`] (L3 hot path).
//!
//! Architecture (vLLM-router style, adapted to this paper's single-node
//! FPGA+GPU board; implemented on std threads — see DESIGN.md §Offline):
//!
//! - A cloneable front door ([`Engine::infer`]) accepts typed
//!   [`InferenceRequest`]s (model, input, priority, optional deadline)
//!   from any client thread, validates model + input shape immediately,
//!   consults the model's optional **content-digest result cache**, and
//!   applies the optional **shared admission controller** plus the
//!   model's optional **per-model budget**.
//! - Every registered model ([`ModelSpec`]) owns one **batcher thread** +
//!   one **executor worker pool**. The batcher drains its queue with a
//!   deadline-based dynamic batcher, sheds requests that out-waited their
//!   own deadline, orders the formed batch by priority (stable — FIFO
//!   within a class), and dispatches it to the least-loaded worker.
//! - Each worker owns its own [`crate::runtime::Runtime`] plus a private
//!   copy of the synthetic model weights and executes the formed batch as
//!   **one N-sized backend call** (`Executable::run_literals_batch`) —
//!   per-request overheads (literal conversion, dispatch, metrics locks)
//!   are paid once per batch, which is the paper's amortization argument
//!   applied to serving. Identical seeds + the deterministic backend make
//!   results independent of which worker served a request.
//! - A model can opt out of the flat pool onto the **online
//!   heterogeneous pipeline** (`ModelSpec::placement(strategy)`): its
//!   partition plan runs as FPGA → PCIe link → GPU device lanes with
//!   bounded inter-stage queues, bit-identical to pool execution, with
//!   per-device occupancy counters ([`Engine::device_metrics`]) — the
//!   paper's hybrid-beats-GPU-only claim, reproduced at the serving
//!   layer (see [`crate::hetero`] and DESIGN.md §10).
//! - The model registry is **live**: [`Engine::register`] spins up a new
//!   model's batcher + pool on a running engine, [`Engine::retire`]
//!   drains one model without disturbing its siblings (DESIGN.md §6).
//! - Two front-door entry points: blocking [`Engine::infer`], and the
//!   **completion-order seam** [`Engine::submit`] — submit without
//!   waiting, receive tagged [`Completion`]s through an `mpsc` sink in
//!   whatever order requests finish. The wire protocol's pipelined v2
//!   connections ([`server`], [`protocol`]; spec in PROTOCOL.md) are
//!   built on it.
//! - Every response carries both the *measured* wall-clock numbers
//!   (queue, amortized execute) and the *simulated* heterogeneous-platform
//!   cost of the request under the model's partition strategy.
//!
//! Shutdown is deterministic per pool (close → drain → join): the handle
//! posts a Stop marker to every batcher, each batcher dispatches the batch
//! it already accepted, answers everything still queued with a clean
//! [`RuntimeError::Serving`], closes its worker channels, and the handle
//! joins batchers then workers — no in-flight response is ever dropped
//! silently.
//!
//! The deprecated single-model `Coordinator` shim was removed after its
//! one-release grace period; `EngineBuilder` + one [`ModelSpec`] is the
//! one-model configuration.

#![warn(missing_docs)]

pub mod admission;
pub mod cache;
pub mod engine;
pub mod protocol;
pub mod server;
pub mod step;

pub use engine::{Completion, Engine, EngineBuilder, EngineHandle, ModelSpec, Placement};

use crate::metrics::Cost;
use crate::runtime::{RuntimeError, Tensor};
use std::time::Duration;

/// Request priority: within one formed batch, higher priorities execute
/// first. Declaration order defines `Ord` (`Low < Normal < High`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum Priority {
    /// Background work: trails every formed batch.
    Low,
    /// The default class; FIFO among its peers.
    #[default]
    Normal,
    /// Latency-sensitive work: leads every formed batch.
    High,
}

/// A typed inference request against a registered model.
///
/// ```
/// use hetero_dnn::coordinator::{InferenceRequest, Priority};
/// use hetero_dnn::runtime::Tensor;
/// use std::time::Duration;
///
/// let req = InferenceRequest::new("squeezenet", Tensor::zeros(&[1, 224, 224, 3]))
///     .with_priority(Priority::High)
///     .with_deadline(Duration::from_millis(50));
/// assert_eq!(req.model, "squeezenet");
/// assert_eq!(req.priority, Priority::High);
/// ```
#[derive(Debug, Clone)]
pub struct InferenceRequest {
    /// Registered model name (see [`EngineBuilder::model`]).
    pub model: String,
    /// Input tensor; must match the model's manifest input shape.
    pub input: Tensor,
    /// Batch ordering class (default [`Priority::Normal`]).
    pub priority: Priority,
    /// Queue-time budget: a request still undispatched this long after
    /// submission is shed with [`RuntimeError::DeadlineExceeded`] instead
    /// of executing past its useful-by point.
    pub deadline: Option<Duration>,
    /// Caller-assigned flight-recorder identity ([`crate::obs::TraceId`]).
    /// Normally `None`: a tracing engine allocates one at admission. A
    /// router that already traced the request upstream sets it so both
    /// tiers record under one id. Ignored when the engine's recorder is
    /// off.
    pub trace: Option<crate::obs::TraceId>,
}

impl InferenceRequest {
    /// Request against `model` with default priority and no deadline.
    pub fn new(model: impl Into<String>, input: Tensor) -> Self {
        Self {
            model: model.into(),
            input,
            priority: Priority::Normal,
            deadline: None,
            trace: None,
        }
    }

    /// Set the batch ordering class.
    pub fn with_priority(mut self, priority: Priority) -> Self {
        self.priority = priority;
        self
    }

    /// Set the queue-time budget.
    pub fn with_deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// Pre-assign the flight-recorder trace id (see
    /// [`field@InferenceRequest::trace`]).
    pub fn with_trace(mut self, trace: crate::obs::TraceId) -> Self {
        self.trace = Some(trace);
        self
    }
}

/// A served inference result.
#[derive(Debug)]
pub struct InferenceResponse {
    /// Engine-global request id (one id space across every model).
    pub id: u64,
    /// Registered model that served this request.
    pub model: String,
    /// Class logits (1, 1000) — or the served artifact's output tensor.
    pub output: Tensor,
    /// Wall-clock time spent queued before the batch executed (zero for
    /// cache hits, which never queue).
    pub queued: Duration,
    /// Amortized wall-clock execution time: the batch's single backend
    /// call divided by the batch size (zero for cache hits).
    pub exec: Duration,
    /// Size of the batch this request was drained with (1 for cache hits).
    pub batch_size: usize,
    /// Position within the formed batch after priority ordering.
    pub batch_index: usize,
    /// Index of the pool worker that executed the batch (0 for cache
    /// hits, which no worker touched).
    pub worker: usize,
    /// True when the result-cache answered at the front door — no
    /// admission slot, no budget slot, no batcher, no backend call. The
    /// output is bit-identical to what execution would have produced.
    pub cached: bool,
    /// Simulated (latency, energy) on the paper's heterogeneous platform;
    /// [`Cost::ZERO`] for cache hits, which execute nothing.
    pub simulated: Cost,
}

/// Aggregate serving metrics (per model, shared across its pool workers).
#[derive(Debug, Default)]
pub struct MetricsInner {
    /// Successfully answered requests that *executed* (cache hits and
    /// errors are counted separately, so throughput/latency figures never
    /// include short-circuited or failed requests).
    pub served: u64,
    /// Requests that reached a worker but failed execution.
    pub errors: u64,
    /// Requests shed by the batcher because their deadline passed while
    /// they were still queued.
    pub shed: u64,
    /// Requests rejected by this model's admission budget
    /// ([`ModelSpec::budget()`]) because its in-flight cap was reached.
    pub budget_rejected: u64,
    /// Result-cache hits: requests answered at the front door without
    /// executing ([`ModelSpec::cache()`]).
    pub cache_hits: u64,
    /// Result-cache misses: cache-enabled requests that passed admission
    /// and budget and were enqueued for execution (outputs are inserted
    /// on success; deadline shedding can still drain one first). Shed or
    /// budget-rejected lookups count as neither hit nor miss, so the hit
    /// rate reflects the workload's repeat rate, not overload.
    pub cache_misses: u64,
    /// Cache entries displaced by LRU eviction to stay within capacity.
    pub cache_evictions: u64,
    /// Formed batches dispatched to workers.
    pub batches: u64,
    /// Total wall-clock backend execution time, microseconds.
    pub exec_us_total: u64,
    /// Total wall-clock queue time across executed requests, microseconds.
    pub queue_us_total: u64,
    /// Wall-clock latency distribution (us). Log-bucketed histogram:
    /// bounded memory over long serving runs, O(1) record (the pre-perf
    /// Vec-and-sort version re-sorted every scrape and grew forever).
    pub latencies: crate::metrics::histogram::LogHistogram,
}

impl MetricsInner {
    /// Latency percentile in microseconds; 0 on an empty histogram.
    pub fn percentile(&self, p: f64) -> u64 {
        self.latencies.quantile(p)
    }

    /// Mean formed-batch size (all executed requests, successful or not,
    /// over formed batches); 0.0 before the first batch.
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            (self.served + self.errors) as f64 / self.batches as f64
        }
    }

    /// Result-cache hit rate: hits over (hits + enqueued misses); 0.0
    /// before the first counted lookup (or with caching disabled).
    pub fn cache_hit_rate(&self) -> f64 {
        let lookups = self.cache_hits + self.cache_misses;
        if lookups == 0 {
            0.0
        } else {
            self.cache_hits as f64 / lookups as f64
        }
    }
}

/// A node-level load snapshot, aggregated across every registered model
/// — what a cluster router reads (through the wire protocol's HEALTH
/// frame, PROTOCOL.md §5.8) to pick a replica for digest-less traffic.
/// Produced by [`Engine::node_health`]; serialized by
/// [`protocol::encode_health_ack`].
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct NodeHealth {
    /// Requests admitted at the front door and not yet answered, summed
    /// over all models ([`Engine::in_flight`]).
    pub in_flight: u64,
    /// Of those, requests still queued ahead of their batcher (not yet
    /// pulled into a formed batch) — the waiting line a newly routed
    /// request would join.
    pub queue_depth: u64,
    /// Result-cache hit rate pooled across models (hits over hits +
    /// misses); 0.0 before the first counted lookup.
    pub cache_hit_rate: f32,
}

pub(crate) fn serving_err(msg: impl Into<String>) -> RuntimeError {
    RuntimeError::Serving(msg.into())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_percentiles() {
        let mut m = MetricsInner::default();
        for v in [10u64, 20, 30, 40, 50, 60, 70, 80, 90, 100] {
            m.latencies.record(v);
        }
        assert_eq!(m.percentile(0.0), 10);
        assert_eq!(m.percentile(1.0), 100);
        // log-bucketed: p50 within one sub-bucket of the exact 60
        let p50 = m.percentile(0.5);
        assert!((55..=65).contains(&p50), "p50 {p50}");
    }

    #[test]
    fn metrics_empty_safe() {
        let m = MetricsInner::default();
        assert_eq!(m.percentile(0.99), 0);
        assert_eq!(m.mean_batch(), 0.0);
        assert_eq!(m.cache_hit_rate(), 0.0);
    }

    #[test]
    fn mean_batch() {
        let m = MetricsInner { served: 10, batches: 4, ..Default::default() };
        assert!((m.mean_batch() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn cache_hit_rate() {
        let m = MetricsInner { cache_hits: 3, cache_misses: 1, ..Default::default() };
        assert!((m.cache_hit_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn priority_orders_low_to_high() {
        assert!(Priority::Low < Priority::Normal);
        assert!(Priority::Normal < Priority::High);
        assert_eq!(Priority::default(), Priority::Normal);
    }

    #[test]
    fn request_builder_sets_fields() {
        let r = InferenceRequest::new("squeezenet", Tensor::zeros(&[1, 2]))
            .with_priority(Priority::High)
            .with_deadline(Duration::from_millis(5));
        assert_eq!(r.model, "squeezenet");
        assert_eq!(r.priority, Priority::High);
        assert_eq!(r.deadline, Some(Duration::from_millis(5)));
    }
}
