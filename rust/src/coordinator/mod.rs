//! The serving coordinator: request router + dynamic batcher (L3 hot path).
//!
//! Architecture (vLLM-router style, adapted to this paper's single-node
//! FPGA+GPU board; implemented on std threads — see DESIGN.md §Offline):
//!
//! - A cloneable front door ([`Coordinator::infer`]) accepts classification
//!   requests from any client thread.
//! - A dedicated **executor thread** owns the PJRT [`Runtime`] (PJRT
//!   handles are `!Send`) plus the model weights, drains the request queue
//!   with a deadline-based dynamic batcher, executes the AOT artifact for
//!   each request, and answers through per-request channels.
//! - Every response carries both the *measured* wall-clock numbers (queue,
//!   execute) and the *simulated* heterogeneous-platform cost of the
//!   request under the configured partition strategy, so the serving demo
//!   reports the paper's metrics alongside real execution.
//!
//! Python never runs here: the executor consumes `artifacts/*.hlo.txt`.

pub mod admission;
pub mod server;

use crate::metrics::Cost;
use crate::partition::{Planner, Strategy};
use crate::runtime::{Runtime, RuntimeError, Tensor};
use crate::sched;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Net-level artifact to serve (e.g. "squeezenet_224").
    pub artifact: String,
    /// Model graph name for the simulated platform cost (must match).
    pub model: String,
    /// Partition strategy simulated per request.
    pub strategy: Strategy,
    /// Max requests drained into one batch.
    pub max_batch: usize,
    /// Max time the batcher waits to fill a batch.
    pub max_wait: Duration,
    /// Seed for the synthetic weights.
    pub seed: u64,
    /// Optional admission control (None = accept everything).
    pub admission: Option<admission::AdmissionConfig>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            artifact: "squeezenet_224".into(),
            model: "squeezenet".into(),
            strategy: Strategy::Auto,
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            seed: 0,
            admission: None,
        }
    }
}

/// A served inference result.
#[derive(Debug)]
pub struct InferenceResponse {
    pub id: u64,
    /// Class logits (1, 1000).
    pub output: Tensor,
    /// Wall-clock time spent queued before execution.
    pub queued: Duration,
    /// Wall-clock PJRT execution time.
    pub exec: Duration,
    /// Size of the batch this request was drained with.
    pub batch_size: usize,
    /// Simulated (latency, energy) on the paper's heterogeneous platform.
    pub simulated: Cost,
}

struct Request {
    id: u64,
    input: Tensor,
    enqueued: Instant,
    resp: mpsc::Sender<Result<InferenceResponse, RuntimeError>>,
}

/// Executor mailbox message.
enum Msg {
    Req(Request),
    /// Explicit shutdown: the executor drains nothing further and exits.
    /// (Relying on sender-drop alone deadlocks when a long-lived clone —
    /// e.g. a blocked TCP connection thread — still holds a sender.)
    Stop,
}

/// Aggregate serving metrics.
#[derive(Debug, Default)]
pub struct MetricsInner {
    pub served: u64,
    pub batches: u64,
    pub exec_us_total: u64,
    pub queue_us_total: u64,
    /// Wall-clock latency distribution (us). Log-bucketed histogram:
    /// bounded memory over long serving runs, O(1) record (the pre-perf
    /// Vec-and-sort version re-sorted every scrape and grew forever).
    pub latencies: crate::metrics::histogram::LogHistogram,
}

impl MetricsInner {
    pub fn percentile(&self, p: f64) -> u64 {
        self.latencies.quantile(p)
    }

    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 { 0.0 } else { self.served as f64 / self.batches as f64 }
    }
}

fn io_err(msg: impl Into<String>) -> RuntimeError {
    RuntimeError::Config(crate::config::ConfigError::Io(std::io::Error::other(msg.into())))
}

/// The front door. Cheap to clone; every clone feeds the same executor.
#[derive(Clone)]
pub struct Coordinator {
    tx: mpsc::Sender<Msg>,
    next_id: Arc<AtomicU64>,
    pub metrics: Arc<Mutex<MetricsInner>>,
    pub admission: Option<Arc<admission::AdmissionController>>,
    input_shape: Vec<usize>,
}

/// Handle that joins the executor thread on shutdown.
pub struct CoordinatorHandle {
    pub coordinator: Coordinator,
    join: Option<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Start the executor thread and return the front door.
    ///
    /// Fails fast (before any request) if the artifact or manifest is
    /// missing, via a startup handshake with the executor thread.
    pub fn start(cfg: CoordinatorConfig) -> Result<CoordinatorHandle, RuntimeError> {
        let cfg_admission = cfg.admission;
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<Vec<usize>, String>>();
        let metrics = Arc::new(Mutex::new(MetricsInner::default()));
        let metrics_thread = metrics.clone();

        let join = std::thread::Builder::new()
            .name("pjrt-executor".into())
            .spawn(move || executor_loop(cfg, rx, ready_tx, metrics_thread))
            .expect("spawn executor");

        let input_shape = match ready_rx.recv() {
            Ok(Ok(shape)) => shape,
            Ok(Err(msg)) => {
                let _ = join.join();
                return Err(io_err(msg));
            }
            Err(_) => {
                let _ = join.join();
                return Err(io_err("executor thread died during startup"));
            }
        };

        let admission = cfg_admission.map(|a| Arc::new(admission::AdmissionController::new(a)));
        let coordinator = Coordinator {
            tx,
            next_id: Arc::new(AtomicU64::new(0)),
            metrics,
            admission,
            input_shape,
        };
        Ok(CoordinatorHandle { coordinator, join: Some(join) })
    }

    /// Expected input shape (from the manifest).
    pub fn input_shape(&self) -> &[usize] {
        &self.input_shape
    }

    /// Submit one inference request and block until its response.
    ///
    /// With admission control configured, requests that would miss the
    /// deadline are shed immediately with an error naming the projected
    /// wait (the client's retry signal).
    pub fn infer(&self, input: Tensor) -> Result<InferenceResponse, RuntimeError> {
        if let Some(ctl) = &self.admission {
            match ctl.admit() {
                admission::Admission::Accept => {}
                admission::Admission::Reject { projected_wait } => {
                    return Err(io_err(format!(
                        "shed: projected wait {projected_wait:?} exceeds deadline"
                    )));
                }
            }
        }
        let t_admit = Instant::now();
        let (resp_tx, resp_rx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = Request { id, input, enqueued: Instant::now(), resp: resp_tx };
        let result = (|| {
            self.tx.send(Msg::Req(req)).map_err(|_| io_err("executor thread gone"))?;
            resp_rx.recv().map_err(|_| io_err("executor dropped request"))?
        })();
        if let Some(ctl) = &self.admission {
            ctl.complete(t_admit.elapsed());
        }
        result
    }
}

impl CoordinatorHandle {
    /// Graceful shutdown: tell the executor to stop and join it. In-flight
    /// requests already drained into a batch complete first; queued
    /// requests behind the Stop marker get a disconnect error on their
    /// response channel. Clones of the Coordinator held elsewhere (e.g. by
    /// TCP connection threads) cannot prevent shutdown.
    pub fn shutdown(mut self) {
        if let Some(j) = self.join.take() {
            let _ = self.coordinator.tx.send(Msg::Stop);
            let _ = j.join();
        }
    }
}

fn executor_loop(
    cfg: CoordinatorConfig,
    rx: mpsc::Receiver<Msg>,
    ready: mpsc::Sender<Result<Vec<usize>, String>>,
    metrics: Arc<Mutex<MetricsInner>>,
) {
    // --- startup: runtime, artifact, weights, simulated per-request cost
    let rt = match Runtime::new() {
        Ok(rt) => rt,
        Err(e) => {
            let _ = ready.send(Err(format!("runtime: {e}")));
            return;
        }
    };
    let exe = match rt.load(&cfg.artifact) {
        Ok(e) => e,
        Err(e) => {
            let _ = ready.send(Err(format!("load {}: {e}", cfg.artifact)));
            return;
        }
    };
    // inputs[0] is the image; the rest are weights we synthesize once
    let all_inputs = match rt.synth_inputs(&cfg.artifact, cfg.seed) {
        Ok(v) => v,
        Err(e) => {
            let _ = ready.send(Err(format!("synth inputs: {e}")));
            return;
        }
    };
    let weights: Vec<Tensor> = all_inputs[1..].to_vec();
    // convert the invariant weights to device literals ONCE (§Perf: the
    // per-request weight memcpy dominated serving overhead before this)
    let weight_lits = match exe.prepare(&weights, 1) {
        Ok(v) => v,
        Err(e) => {
            let _ = ready.send(Err(format!("prepare weights: {e}")));
            return;
        }
    };
    let input_shape = exe.entry.inputs[0].shape.clone();

    // simulated platform cost of one request under the configured strategy
    let graph = match cfg.model.as_str() {
        "squeezenet" => crate::graph::squeezenet(224),
        "mobilenetv2_05" => crate::graph::mobilenetv2_05(224),
        "shufflenetv2_05" => crate::graph::shufflenetv2_05(224),
        other => {
            let _ = ready.send(Err(format!("unknown model {other}")));
            return;
        }
    };
    let planner = Planner::default();
    let plan = planner.plan_model(&graph, cfg.strategy);
    let simulated = sched::evaluate_model(&plan).total;

    let _ = ready.send(Ok(input_shape));

    // --- serve: deadline-based dynamic batching
    'serve: while let Ok(msg) = rx.recv() {
        let first = match msg {
            Msg::Req(r) => r,
            Msg::Stop => break 'serve,
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + cfg.max_wait;
        while batch.len() < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(Msg::Req(r)) => batch.push(r),
                Ok(Msg::Stop) => {
                    // serve what we already accepted, then exit
                    serve_batch(&exe, &weight_lits, simulated, &metrics, batch);
                    break 'serve;
                }
                Err(_) => break,
            }
        }
        serve_batch(&exe, &weight_lits, simulated, &metrics, batch);
    }
}

/// Execute one drained batch and answer every request in it.
fn serve_batch(
    exe: &std::rc::Rc<crate::runtime::Executable>,
    weight_lits: &[xla::Literal],
    simulated: Cost,
    metrics: &Arc<Mutex<MetricsInner>>,
    batch: Vec<Request>,
) {
    let bs = batch.len();
    // count the batch before responding so clients observing metrics
    // after their response never see a stale batch count
    metrics.lock().unwrap().batches += 1;
    for req in batch {
        let queued = req.enqueued.elapsed();
        let t0 = Instant::now();
        // only the request's own tensor is converted per call; weights are
        // pre-converted literals shared across requests
        let result = exe
            .prepare(std::slice::from_ref(&req.input), 0)
            .and_then(|input_lit| {
                let mut refs: Vec<&xla::Literal> = Vec::with_capacity(1 + weight_lits.len());
                refs.push(&input_lit[0]);
                refs.extend(weight_lits.iter());
                exe.run_literals(&refs)
            })
            .map(|mut outs| InferenceResponse {
                id: req.id,
                output: outs.remove(0),
                queued,
                exec: t0.elapsed(),
                batch_size: bs,
                simulated,
            });
        {
            let mut m = metrics.lock().unwrap();
            m.served += 1;
            m.exec_us_total += t0.elapsed().as_micros() as u64;
            m.queue_us_total += queued.as_micros() as u64;
            m.latencies.record((queued + t0.elapsed()).as_micros() as u64);
        }
        let _ = req.resp.send(result);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_percentiles() {
        let mut m = MetricsInner::default();
        for v in [10u64, 20, 30, 40, 50, 60, 70, 80, 90, 100] {
            m.latencies.record(v);
        }
        assert_eq!(m.percentile(0.0), 10);
        assert_eq!(m.percentile(1.0), 100);
        // log-bucketed: p50 within one sub-bucket of the exact 60
        let p50 = m.percentile(0.5);
        assert!((55..=65).contains(&p50), "p50 {p50}");
    }

    #[test]
    fn metrics_empty_safe() {
        let m = MetricsInner::default();
        assert_eq!(m.percentile(0.99), 0);
        assert_eq!(m.mean_batch(), 0.0);
    }

    #[test]
    fn mean_batch() {
        let m = MetricsInner { served: 10, batches: 4, ..Default::default() };
        assert!((m.mean_batch() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn default_config_sane() {
        let c = CoordinatorConfig::default();
        assert!(c.max_batch >= 1);
        assert!(!c.artifact.is_empty());
    }
}
