//! The serving face: a multi-model, batch-first [`Engine`] (L3 hot path).
//!
//! Architecture (vLLM-router style, adapted to this paper's single-node
//! FPGA+GPU board; implemented on std threads — see DESIGN.md §Offline):
//!
//! - A cloneable front door ([`Engine::infer`]) accepts typed
//!   [`InferenceRequest`]s (model, input, priority, optional deadline)
//!   from any client thread, validates model + input shape immediately,
//!   and applies the optional **shared admission controller**.
//! - Every registered model ([`ModelSpec`]) owns one **batcher thread** +
//!   one **executor worker pool**. The batcher drains its queue with a
//!   deadline-based dynamic batcher, sheds requests that out-waited their
//!   own deadline, orders the formed batch by priority (stable — FIFO
//!   within a class), and dispatches it to the least-loaded worker.
//! - Each worker owns its own [`crate::runtime::Runtime`] plus a private
//!   copy of the synthetic model weights and executes the formed batch as
//!   **one N-sized backend call** (`Executable::run_literals_batch`) —
//!   per-request overheads (literal conversion, dispatch, metrics locks)
//!   are paid once per batch, which is the paper's amortization argument
//!   applied to serving. Identical seeds + the deterministic backend make
//!   results independent of which worker served a request.
//! - Every response carries both the *measured* wall-clock numbers
//!   (queue, amortized execute) and the *simulated* heterogeneous-platform
//!   cost of the request under the model's partition strategy.
//!
//! Shutdown is deterministic per pool (close → drain → join): the handle
//! posts a Stop marker to every batcher, each batcher dispatches the batch
//! it already accepted, answers everything still queued with a clean
//! [`RuntimeError::Serving`], closes its worker channels, and the handle
//! joins batchers then workers — no in-flight response is ever dropped
//! silently.
//!
//! [`Coordinator`] remains as a deprecated one-model shim over the engine
//! for one release.

pub mod admission;
pub mod engine;
pub mod server;

pub use engine::{Engine, EngineBuilder, EngineHandle, ModelSpec};

use crate::metrics::Cost;
use crate::runtime::{RuntimeError, Tensor};
use std::sync::atomic::AtomicU64;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Request priority: within one formed batch, higher priorities execute
/// first. Declaration order defines `Ord` (`Low < Normal < High`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum Priority {
    Low,
    #[default]
    Normal,
    High,
}

/// A typed inference request against a registered model.
#[derive(Debug, Clone)]
pub struct InferenceRequest {
    /// Registered model name (see [`EngineBuilder::model`]).
    pub model: String,
    /// Input tensor; must match the model's manifest input shape.
    pub input: Tensor,
    /// Batch ordering class (default [`Priority::Normal`]).
    pub priority: Priority,
    /// Queue-time budget: a request still undispatched this long after
    /// submission is shed with [`RuntimeError::DeadlineExceeded`] instead
    /// of executing past its useful-by point.
    pub deadline: Option<Duration>,
}

impl InferenceRequest {
    pub fn new(model: impl Into<String>, input: Tensor) -> Self {
        Self { model: model.into(), input, priority: Priority::Normal, deadline: None }
    }

    pub fn with_priority(mut self, priority: Priority) -> Self {
        self.priority = priority;
        self
    }

    pub fn with_deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }
}

/// A served inference result.
#[derive(Debug)]
pub struct InferenceResponse {
    pub id: u64,
    /// Registered model that served this request.
    pub model: String,
    /// Class logits (1, 1000) — or the served artifact's output tensor.
    pub output: Tensor,
    /// Wall-clock time spent queued before the batch executed.
    pub queued: Duration,
    /// Amortized wall-clock execution time: the batch's single backend
    /// call divided by the batch size.
    pub exec: Duration,
    /// Size of the batch this request was drained with.
    pub batch_size: usize,
    /// Position within the formed batch after priority ordering.
    pub batch_index: usize,
    /// Index of the pool worker that executed the batch.
    pub worker: usize,
    /// Simulated (latency, energy) on the paper's heterogeneous platform.
    pub simulated: Cost,
}

/// Aggregate serving metrics (per model, shared across its pool workers).
#[derive(Debug, Default)]
pub struct MetricsInner {
    /// Successfully answered requests (errors are counted separately, so
    /// throughput/latency figures never include failed executions).
    pub served: u64,
    /// Requests that reached a worker but failed execution.
    pub errors: u64,
    /// Requests shed by the batcher because their deadline passed while
    /// they were still queued.
    pub shed: u64,
    pub batches: u64,
    pub exec_us_total: u64,
    pub queue_us_total: u64,
    /// Wall-clock latency distribution (us). Log-bucketed histogram:
    /// bounded memory over long serving runs, O(1) record (the pre-perf
    /// Vec-and-sort version re-sorted every scrape and grew forever).
    pub latencies: crate::metrics::histogram::LogHistogram,
}

impl MetricsInner {
    /// Latency percentile in microseconds; 0 on an empty histogram.
    pub fn percentile(&self, p: f64) -> u64 {
        self.latencies.quantile(p)
    }

    /// Mean formed-batch size (all executed requests, successful or not,
    /// over formed batches); 0.0 before the first batch.
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            (self.served + self.errors) as f64 / self.batches as f64
        }
    }
}

pub(crate) fn serving_err(msg: impl Into<String>) -> RuntimeError {
    RuntimeError::Serving(msg.into())
}

// ---------------------------------------------------------------------------
// deprecated single-model shim

/// Configuration of the deprecated single-model [`Coordinator`] shim.
#[deprecated(
    since = "0.2.0",
    note = "use EngineBuilder + ModelSpec; the Coordinator serves exactly one model"
)]
#[allow(deprecated)]
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Net-level artifact to serve (e.g. "squeezenet_224").
    pub artifact: String,
    /// Model graph name for the simulated platform cost (must match).
    pub model: String,
    /// Partition strategy simulated per request.
    pub strategy: crate::partition::Strategy,
    /// Max requests drained into one batch (must be >= 1).
    pub max_batch: usize,
    /// Max time the batcher waits to fill a batch (zero = dispatch
    /// immediately, batches of 1).
    pub max_wait: Duration,
    /// Seed for the synthetic weights (shared by every worker so results
    /// are worker-independent).
    pub seed: u64,
    /// Optional admission control (None = accept everything).
    pub admission: Option<admission::AdmissionConfig>,
    /// Executor pool size (must be >= 1). Each worker owns a Runtime.
    pub workers: usize,
}

#[allow(deprecated)]
impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            artifact: "squeezenet_224".into(),
            model: "squeezenet".into(),
            strategy: crate::partition::Strategy::Auto,
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            seed: 0,
            admission: None,
            workers: 1,
        }
    }
}

/// Deprecated one-model front door: a thin shim over [`Engine`] kept for
/// one release. `infer` forwards to the engine with [`Priority::Normal`]
/// and no deadline; the public `metrics` / `accepted` / `admission`
/// fields alias the underlying engine state.
#[deprecated(since = "0.2.0", note = "use Engine (EngineBuilder::build); this shim forwards to it")]
#[allow(deprecated)]
#[derive(Clone)]
pub struct Coordinator {
    engine: Engine,
    model: String,
    pub metrics: Arc<Mutex<MetricsInner>>,
    /// Requests the batcher has pulled off the queue (accepted into a
    /// batch). Every accepted request is guaranteed a response, even
    /// across shutdown.
    pub accepted: Arc<AtomicU64>,
    pub admission: Option<Arc<admission::AdmissionController>>,
    input_shape: Vec<usize>,
    workers: usize,
}

/// Handle that joins the shimmed engine on shutdown.
#[deprecated(since = "0.2.0", note = "use EngineHandle")]
#[allow(deprecated)]
pub struct CoordinatorHandle {
    pub coordinator: Coordinator,
    engine: EngineHandle,
}

#[allow(deprecated)]
impl Coordinator {
    /// Start a one-model engine and wrap it in the legacy front door.
    pub fn start(cfg: CoordinatorConfig) -> Result<CoordinatorHandle, RuntimeError> {
        let name = cfg.model.clone();
        let mut builder = EngineBuilder::new().max_batch(cfg.max_batch).max_wait(cfg.max_wait);
        if let Some(a) = cfg.admission {
            builder = builder.admission(a);
        }
        let handle = builder
            .model(
                ModelSpec::new(name.clone(), cfg.artifact, cfg.model)
                    .strategy(cfg.strategy)
                    .workers(cfg.workers)
                    .seed(cfg.seed),
            )
            .build()?;
        let engine = handle.engine.clone();
        let (metrics, accepted, input_shape, workers) = {
            let state = engine.inner.models.get(&name).expect("model was just registered");
            (
                state.metrics.clone(),
                state.accepted.clone(),
                state.input_shape.clone(),
                state.workers,
            )
        };
        let coordinator = Coordinator {
            admission: engine.inner.admission.clone(),
            engine,
            model: name,
            metrics,
            accepted,
            input_shape,
            workers,
        };
        Ok(CoordinatorHandle { coordinator, engine: handle })
    }

    /// Expected input shape (from the manifest).
    pub fn input_shape(&self) -> &[usize] {
        &self.input_shape
    }

    /// Executor pool size.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Submit one inference request and block until its response.
    pub fn infer(&self, input: Tensor) -> Result<InferenceResponse, RuntimeError> {
        self.engine.infer(InferenceRequest::new(self.model.clone(), input))
    }
}

#[allow(deprecated)]
impl CoordinatorHandle {
    /// Graceful shutdown (close → drain → join, see [`EngineHandle`]).
    pub fn shutdown(self) {
        self.engine.shutdown()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_percentiles() {
        let mut m = MetricsInner::default();
        for v in [10u64, 20, 30, 40, 50, 60, 70, 80, 90, 100] {
            m.latencies.record(v);
        }
        assert_eq!(m.percentile(0.0), 10);
        assert_eq!(m.percentile(1.0), 100);
        // log-bucketed: p50 within one sub-bucket of the exact 60
        let p50 = m.percentile(0.5);
        assert!((55..=65).contains(&p50), "p50 {p50}");
    }

    #[test]
    fn metrics_empty_safe() {
        let m = MetricsInner::default();
        assert_eq!(m.percentile(0.99), 0);
        assert_eq!(m.mean_batch(), 0.0);
    }

    #[test]
    fn mean_batch() {
        let m = MetricsInner { served: 10, batches: 4, ..Default::default() };
        assert!((m.mean_batch() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn priority_orders_low_to_high() {
        assert!(Priority::Low < Priority::Normal);
        assert!(Priority::Normal < Priority::High);
        assert_eq!(Priority::default(), Priority::Normal);
    }

    #[test]
    fn request_builder_sets_fields() {
        let r = InferenceRequest::new("squeezenet", Tensor::zeros(&[1, 2]))
            .with_priority(Priority::High)
            .with_deadline(Duration::from_millis(5));
        assert_eq!(r.model, "squeezenet");
        assert_eq!(r.priority, Priority::High);
        assert_eq!(r.deadline, Some(Duration::from_millis(5)));
    }

    #[test]
    #[allow(deprecated)]
    fn default_shim_config_sane() {
        let c = CoordinatorConfig::default();
        assert!(c.max_batch >= 1);
        assert!(c.workers >= 1);
        assert!(!c.artifact.is_empty());
    }

    #[test]
    #[allow(deprecated)]
    fn shim_zero_max_batch_rejected() {
        let cfg = CoordinatorConfig { max_batch: 0, ..Default::default() };
        let err = Coordinator::start(cfg).expect_err("zero max_batch must fail");
        assert!(err.to_string().contains("max_batch"), "{err}");
    }

    #[test]
    #[allow(deprecated)]
    fn shim_zero_workers_rejected() {
        let cfg = CoordinatorConfig { workers: 0, ..Default::default() };
        let err = Coordinator::start(cfg).expect_err("zero workers must fail");
        assert!(err.to_string().contains("workers"), "{err}");
    }

    #[test]
    #[allow(deprecated)]
    fn shim_unknown_model_rejected_before_spawn() {
        let cfg = CoordinatorConfig { model: "no_such_model".into(), ..Default::default() };
        assert!(Coordinator::start(cfg).is_err());
    }
}
