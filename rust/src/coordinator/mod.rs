//! The serving coordinator: request router + dynamic batcher over an
//! N-worker executor pool (L3 hot path).
//!
//! Architecture (vLLM-router style, adapted to this paper's single-node
//! FPGA+GPU board; implemented on std threads — see DESIGN.md §Offline):
//!
//! - A cloneable front door ([`Coordinator::infer`]) accepts classification
//!   requests from any client thread.
//! - A dedicated **batcher thread** drains the request queue with a
//!   deadline-based dynamic batcher and dispatches each formed batch to the
//!   **least-loaded worker** of an executor pool.
//! - Each of the N **worker threads** owns its own [`Runtime`] instance
//!   (runtimes are single-threaded by construction) plus a private copy of
//!   the synthetic model weights, executes the artifact per request, and
//!   answers through per-request channels. Identical seeds + the
//!   deterministic backend make results independent of which worker served
//!   a request.
//! - Every response carries both the *measured* wall-clock numbers (queue,
//!   execute) and the *simulated* heterogeneous-platform cost of the
//!   request under the configured partition strategy, so the serving demo
//!   reports the paper's metrics alongside real execution.
//!
//! Shutdown is deterministic: the front door posts a Stop marker, the
//! batcher dispatches the batch it already accepted, answers every request
//! still queued behind the marker with a clean [`RuntimeError::Serving`],
//! closes the worker channels, and the handle joins batcher then workers —
//! no in-flight response is ever dropped silently.

pub mod admission;
pub mod server;

use crate::metrics::Cost;
use crate::partition::{Planner, Strategy};
use crate::runtime::{Executable, Literal, Runtime, RuntimeError, Tensor};
use crate::sched;
use std::rc::Rc;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Net-level artifact to serve (e.g. "squeezenet_224").
    pub artifact: String,
    /// Model graph name for the simulated platform cost (must match).
    pub model: String,
    /// Partition strategy simulated per request.
    pub strategy: Strategy,
    /// Max requests drained into one batch (must be >= 1).
    pub max_batch: usize,
    /// Max time the batcher waits to fill a batch (zero = dispatch
    /// immediately, batches of 1).
    pub max_wait: Duration,
    /// Seed for the synthetic weights (shared by every worker so results
    /// are worker-independent).
    pub seed: u64,
    /// Optional admission control (None = accept everything).
    pub admission: Option<admission::AdmissionConfig>,
    /// Executor pool size (must be >= 1). Each worker owns a Runtime.
    pub workers: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            artifact: "squeezenet_224".into(),
            model: "squeezenet".into(),
            strategy: Strategy::Auto,
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            seed: 0,
            admission: None,
            workers: 1,
        }
    }
}

/// A served inference result.
#[derive(Debug)]
pub struct InferenceResponse {
    pub id: u64,
    /// Class logits (1, 1000) — or the served artifact's output tensor.
    pub output: Tensor,
    /// Wall-clock time spent queued before execution.
    pub queued: Duration,
    /// Wall-clock execution time.
    pub exec: Duration,
    /// Size of the batch this request was drained with.
    pub batch_size: usize,
    /// Index of the pool worker that executed the request.
    pub worker: usize,
    /// Simulated (latency, energy) on the paper's heterogeneous platform.
    pub simulated: Cost,
}

struct Request {
    id: u64,
    input: Tensor,
    enqueued: Instant,
    resp: mpsc::Sender<Result<InferenceResponse, RuntimeError>>,
}

/// Batcher mailbox message.
enum Msg {
    Req(Request),
    /// Explicit shutdown: the batcher drains nothing further and exits.
    /// (Relying on sender-drop alone deadlocks when a long-lived clone —
    /// e.g. a blocked TCP connection thread — still holds a sender.)
    Stop,
}

type Batch = Vec<Request>;

/// Aggregate serving metrics (shared across all pool workers).
#[derive(Debug, Default)]
pub struct MetricsInner {
    /// Successfully answered requests (errors are counted separately, so
    /// throughput/latency figures never include failed executions).
    pub served: u64,
    /// Requests that reached a worker but failed execution.
    pub errors: u64,
    pub batches: u64,
    pub exec_us_total: u64,
    pub queue_us_total: u64,
    /// Wall-clock latency distribution (us). Log-bucketed histogram:
    /// bounded memory over long serving runs, O(1) record (the pre-perf
    /// Vec-and-sort version re-sorted every scrape and grew forever).
    pub latencies: crate::metrics::histogram::LogHistogram,
}

impl MetricsInner {
    /// Latency percentile in microseconds; 0 on an empty histogram.
    pub fn percentile(&self, p: f64) -> u64 {
        self.latencies.quantile(p)
    }

    /// Mean formed-batch size (all executed requests, successful or not,
    /// over formed batches); 0.0 before the first batch.
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            (self.served + self.errors) as f64 / self.batches as f64
        }
    }
}

fn serving_err(msg: impl Into<String>) -> RuntimeError {
    RuntimeError::Serving(msg.into())
}

/// The front door. Cheap to clone; every clone feeds the same batcher.
#[derive(Clone)]
pub struct Coordinator {
    tx: mpsc::Sender<Msg>,
    next_id: Arc<AtomicU64>,
    pub metrics: Arc<Mutex<MetricsInner>>,
    /// Requests the batcher has pulled off the queue (accepted into a
    /// batch). Every accepted request is guaranteed a response, even
    /// across shutdown. Lock-free: the batcher bumps it on its hot path.
    pub accepted: Arc<AtomicU64>,
    pub admission: Option<Arc<admission::AdmissionController>>,
    input_shape: Vec<usize>,
    workers: usize,
}

/// Handle that joins the batcher and the worker pool on shutdown.
pub struct CoordinatorHandle {
    pub coordinator: Coordinator,
    batcher: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Start the batcher + worker pool and return the front door.
    ///
    /// Fails fast (before any request) on an invalid config, an unknown
    /// model, or a missing artifact, via a startup handshake with every
    /// worker. When the AOT artifacts are not built, workers fall back to
    /// the simulated platform runtime with a one-time log notice.
    pub fn start(cfg: CoordinatorConfig) -> Result<CoordinatorHandle, RuntimeError> {
        if cfg.workers == 0 {
            return Err(serving_err("workers must be >= 1"));
        }
        if cfg.max_batch == 0 {
            return Err(serving_err("max_batch must be >= 1 (a zero-sized batch can never drain)"));
        }

        // validate the model and pre-compute the simulated per-request
        // platform cost once — it is identical for every worker
        let graph = match cfg.model.as_str() {
            "squeezenet" => crate::graph::squeezenet(224),
            "mobilenetv2_05" => crate::graph::mobilenetv2_05(224),
            "shufflenetv2_05" => crate::graph::shufflenetv2_05(224),
            other => return Err(serving_err(format!("unknown model {other}"))),
        };
        let planner = Planner::default();
        let plan = planner.plan_model(&graph, cfg.strategy);
        let simulated = sched::evaluate_model(&plan).total;

        let metrics = Arc::new(Mutex::new(MetricsInner::default()));
        let loads: Arc<Vec<AtomicUsize>> =
            Arc::new((0..cfg.workers).map(|_| AtomicUsize::new(0)).collect());

        // --- spawn the worker pool
        let (ready_tx, ready_rx) = mpsc::channel::<Result<Vec<usize>, String>>();
        let mut worker_txs: Vec<mpsc::Sender<Batch>> = Vec::with_capacity(cfg.workers);
        let mut workers = Vec::with_capacity(cfg.workers);
        for wid in 0..cfg.workers {
            let (btx, brx) = mpsc::channel::<Batch>();
            worker_txs.push(btx);
            let ready = ready_tx.clone();
            let metrics = metrics.clone();
            let loads = loads.clone();
            let artifact = cfg.artifact.clone();
            let seed = cfg.seed;
            let join = std::thread::Builder::new()
                .name(format!("executor-{wid}"))
                .spawn(move || {
                    worker_loop(wid, &artifact, seed, simulated, brx, ready, metrics, loads)
                })
                .map_err(|e| serving_err(format!("spawn worker {wid}: {e}")))?;
            workers.push(join);
        }
        drop(ready_tx);

        // --- startup handshake: every worker must come up with the same shape
        let mut input_shape: Option<Vec<usize>> = None;
        let mut startup_error: Option<RuntimeError> = None;
        for _ in 0..cfg.workers {
            match ready_rx.recv() {
                Ok(Ok(shape)) => {
                    if input_shape.is_none() {
                        input_shape = Some(shape);
                    } else if input_shape.as_deref() != Some(&shape[..]) {
                        startup_error = Some(serving_err(format!(
                            "worker input shapes diverge: {input_shape:?} vs {shape:?}"
                        )));
                        break;
                    }
                }
                Ok(Err(msg)) => {
                    startup_error = Some(serving_err(msg));
                    break;
                }
                Err(_) => {
                    startup_error = Some(serving_err("executor worker died during startup"));
                    break;
                }
            }
        }
        if let Some(e) = startup_error {
            drop(worker_txs); // closes every worker's batch channel
            for j in workers {
                let _ = j.join();
            }
            return Err(e);
        }
        let input_shape = input_shape.expect("workers >= 1 checked above");

        // --- spawn the batcher
        let (tx, rx) = mpsc::channel::<Msg>();
        let (max_batch, max_wait) = (cfg.max_batch, cfg.max_wait);
        let loads_b = loads.clone();
        let accepted = Arc::new(AtomicU64::new(0));
        let accepted_b = accepted.clone();
        let batcher = std::thread::Builder::new()
            .name("batcher".into())
            .spawn(move || batcher_loop(rx, worker_txs, loads_b, accepted_b, max_batch, max_wait))
            .map_err(|e| serving_err(format!("spawn batcher: {e}")))?;

        let admission = cfg.admission.map(|a| Arc::new(admission::AdmissionController::new(a)));
        let coordinator = Coordinator {
            tx,
            next_id: Arc::new(AtomicU64::new(0)),
            metrics,
            accepted,
            admission,
            input_shape,
            workers: cfg.workers,
        };
        Ok(CoordinatorHandle { coordinator, batcher: Some(batcher), workers })
    }

    /// Expected input shape (from the manifest).
    pub fn input_shape(&self) -> &[usize] {
        &self.input_shape
    }

    /// Executor pool size.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Submit one inference request and block until its response.
    ///
    /// With admission control configured, requests that would miss the
    /// deadline are shed immediately with an error naming the projected
    /// wait (the client's retry signal). A request arriving after shutdown
    /// gets a clean [`RuntimeError::Serving`] instead of hanging.
    pub fn infer(&self, input: Tensor) -> Result<InferenceResponse, RuntimeError> {
        if let Some(ctl) = &self.admission {
            match ctl.admit() {
                admission::Admission::Accept => {}
                admission::Admission::Reject { projected_wait } => {
                    return Err(serving_err(format!(
                        "shed: projected wait {projected_wait:?} exceeds deadline"
                    )));
                }
            }
        }
        let t_admit = Instant::now();
        let (resp_tx, resp_rx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = Request { id, input, enqueued: Instant::now(), resp: resp_tx };
        let result = (|| {
            self.tx
                .send(Msg::Req(req))
                .map_err(|_| serving_err("coordinator is shut down"))?;
            resp_rx
                .recv()
                .map_err(|_| serving_err("request dropped during coordinator shutdown"))?
        })();
        if let Some(ctl) = &self.admission {
            ctl.complete(t_admit.elapsed());
        }
        result
    }
}

impl CoordinatorHandle {
    /// Graceful shutdown: stop the batcher, then join every worker.
    ///
    /// Ordering guarantees (the close -> drain -> join contract):
    /// 1. the Stop marker is posted; the batcher dispatches the batch it
    ///    already accepted,
    /// 2. requests still queued behind the marker are answered with a clean
    ///    shutdown error (never silently dropped),
    /// 3. the worker channels close; each worker finishes every batch that
    ///    was dispatched to it before exiting,
    /// 4. batcher and workers are joined, in that order.
    ///
    /// Clones of the Coordinator held elsewhere (e.g. by TCP connection
    /// threads) cannot prevent shutdown; their later `infer` calls fail
    /// with a clean error.
    pub fn shutdown(mut self) {
        if let Some(b) = self.batcher.take() {
            let _ = self.coordinator.tx.send(Msg::Stop);
            let _ = b.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

// ---------------------------------------------------------------------------
// batcher

fn batcher_loop(
    rx: mpsc::Receiver<Msg>,
    worker_txs: Vec<mpsc::Sender<Batch>>,
    loads: Arc<Vec<AtomicUsize>>,
    accepted: Arc<AtomicU64>,
    max_batch: usize,
    max_wait: Duration,
) {
    let dispatch = |batch: Batch| {
        if batch.is_empty() {
            return;
        }
        // least-loaded worker; ties break toward the lowest index
        let wid = loads
            .iter()
            .enumerate()
            .min_by_key(|(_, l)| l.load(Ordering::Relaxed))
            .map(|(i, _)| i)
            .expect("pool has >= 1 worker");
        loads[wid].fetch_add(batch.len(), Ordering::Relaxed);
        if let Err(mpsc::SendError(batch)) = worker_txs[wid].send(batch) {
            // worker died: evict it from selection (a plain undo would
            // reset its load to the minimum and keep routing every batch
            // to the corpse) and fail this batch cleanly
            loads[wid].store(usize::MAX, Ordering::Relaxed);
            for req in batch {
                let _ = req.resp.send(Err(serving_err("executor worker gone")));
            }
        }
    };

    'serve: while let Ok(msg) = rx.recv() {
        let first = match msg {
            Msg::Req(r) => r,
            Msg::Stop => break 'serve,
        };
        accepted.fetch_add(1, Ordering::Relaxed);
        let mut batch = vec![first];
        let mut stopping = false;
        let deadline = Instant::now() + max_wait;
        while batch.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(Msg::Req(r)) => {
                    accepted.fetch_add(1, Ordering::Relaxed);
                    batch.push(r);
                }
                Ok(Msg::Stop) => {
                    // dispatch what we already accepted, then exit
                    stopping = true;
                    break;
                }
                Err(_) => break,
            }
        }
        dispatch(batch);
        if stopping {
            break 'serve;
        }
    }

    // drain: everything still queued behind the Stop marker gets a definite,
    // clean answer instead of a dangling response channel
    while let Ok(msg) = rx.try_recv() {
        if let Msg::Req(req) = msg {
            let _ = req.resp.send(Err(serving_err("coordinator shutting down")));
        }
    }
    // worker_txs drop here: the pool channels close, workers drain whatever
    // was dispatched to them and exit
}

// ---------------------------------------------------------------------------
// workers

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    wid: usize,
    artifact: &str,
    seed: u64,
    simulated: Cost,
    brx: mpsc::Receiver<Batch>,
    ready: mpsc::Sender<Result<Vec<usize>, String>>,
    metrics: Arc<Mutex<MetricsInner>>,
    loads: Arc<Vec<AtomicUsize>>,
) {
    // --- startup: runtime, artifact, weights (identical across workers)
    let rt = Runtime::new_or_simulated();
    let exe = match rt.load(artifact) {
        Ok(e) => e,
        Err(e) => {
            let _ = ready.send(Err(format!("load {artifact}: {e}")));
            return;
        }
    };
    if exe.entry.inputs.is_empty() {
        let _ = ready.send(Err(format!("artifact {artifact} has no inputs")));
        return;
    }
    if exe.entry.outputs.is_empty() {
        // guard here, not at serve time: a zero-output entry would panic
        // outs.remove(0) and silently kill the worker mid-batch
        let _ = ready.send(Err(format!("artifact {artifact} has no outputs")));
        return;
    }
    // inputs[0] is the image; the rest are weights we synthesize once
    let all_inputs = match rt.synth_inputs(artifact, seed) {
        Ok(v) => v,
        Err(e) => {
            let _ = ready.send(Err(format!("synth inputs: {e}")));
            return;
        }
    };
    let weights: Vec<Tensor> = all_inputs[1..].to_vec();
    // convert the invariant weights to literals ONCE (§Perf: the
    // per-request weight conversion dominated serving overhead before this)
    let weight_lits = match exe.prepare(&weights, 1) {
        Ok(v) => v,
        Err(e) => {
            let _ = ready.send(Err(format!("prepare weights: {e}")));
            return;
        }
    };
    let input_shape = exe.entry.inputs[0].shape.clone();
    let _ = ready.send(Ok(input_shape));

    // --- serve dispatched batches until the batcher closes the channel
    while let Ok(batch) = brx.recv() {
        serve_batch(wid, &exe, &weight_lits, simulated, &metrics, &loads[wid], batch);
    }
}

/// Execute one dispatched batch and answer every request in it.
fn serve_batch(
    wid: usize,
    exe: &Rc<Executable>,
    weight_lits: &[Literal],
    simulated: Cost,
    metrics: &Arc<Mutex<MetricsInner>>,
    load: &AtomicUsize,
    batch: Batch,
) {
    let bs = batch.len();
    // count the batch before responding so clients observing metrics
    // after their response never see a stale batch count
    metrics.lock().unwrap().batches += 1;
    for req in batch {
        let queued = req.enqueued.elapsed();
        let t0 = Instant::now();
        // only the request's own tensor is converted per call; weights are
        // pre-converted literals shared across requests
        let result = exe
            .prepare(std::slice::from_ref(&req.input), 0)
            .and_then(|input_lit| {
                let mut refs: Vec<&Literal> = Vec::with_capacity(1 + weight_lits.len());
                refs.push(&input_lit[0]);
                refs.extend(weight_lits.iter());
                exe.run_literals(&refs)
            })
            .map(|mut outs| InferenceResponse {
                id: req.id,
                output: outs.remove(0),
                queued,
                exec: t0.elapsed(),
                batch_size: bs,
                worker: wid,
                simulated,
            });
        {
            let mut m = metrics.lock().unwrap();
            if result.is_ok() {
                m.served += 1;
                m.exec_us_total += t0.elapsed().as_micros() as u64;
                m.queue_us_total += queued.as_micros() as u64;
                m.latencies.record((queued + t0.elapsed()).as_micros() as u64);
            } else {
                m.errors += 1;
            }
        }
        load.fetch_sub(1, Ordering::Relaxed);
        let _ = req.resp.send(result);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_percentiles() {
        let mut m = MetricsInner::default();
        for v in [10u64, 20, 30, 40, 50, 60, 70, 80, 90, 100] {
            m.latencies.record(v);
        }
        assert_eq!(m.percentile(0.0), 10);
        assert_eq!(m.percentile(1.0), 100);
        // log-bucketed: p50 within one sub-bucket of the exact 60
        let p50 = m.percentile(0.5);
        assert!((55..=65).contains(&p50), "p50 {p50}");
    }

    #[test]
    fn metrics_empty_safe() {
        let m = MetricsInner::default();
        assert_eq!(m.percentile(0.99), 0);
        assert_eq!(m.mean_batch(), 0.0);
    }

    #[test]
    fn mean_batch() {
        let m = MetricsInner { served: 10, batches: 4, ..Default::default() };
        assert!((m.mean_batch() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn default_config_sane() {
        let c = CoordinatorConfig::default();
        assert!(c.max_batch >= 1);
        assert!(c.workers >= 1);
        assert!(!c.artifact.is_empty());
    }

    #[test]
    fn zero_max_batch_rejected() {
        let cfg = CoordinatorConfig { max_batch: 0, ..Default::default() };
        let err = Coordinator::start(cfg).expect_err("zero max_batch must fail");
        assert!(err.to_string().contains("max_batch"), "{err}");
    }

    #[test]
    fn zero_workers_rejected() {
        let cfg = CoordinatorConfig { workers: 0, ..Default::default() };
        let err = Coordinator::start(cfg).expect_err("zero workers must fail");
        assert!(err.to_string().contains("workers"), "{err}");
    }

    #[test]
    fn unknown_model_rejected_before_spawn() {
        let cfg = CoordinatorConfig { model: "no_such_model".into(), ..Default::default() };
        assert!(Coordinator::start(cfg).is_err());
    }
}
