//! Pure step-function cores for the engine's concurrency loops.
//!
//! Every concurrency loop in the serving stack — the per-model batcher
//! and worker loops ([`super::engine`]), and the v2 connection's window /
//! writer completion path ([`super::server`]) — is split into a **core**
//! and a **shell**:
//!
//! - the *core* (this module) holds the loop's state and advances it one
//!   event at a time: `fn step(&mut self, event) -> Vec<Effect>`. Cores
//!   never touch the wall clock, never block, and never perform I/O —
//!   time arrives stamped into events (`now: Instant`), and everything
//!   the loop *would do* comes back as data ([`BatcherEffect`],
//!   [`WriterEffect`], …).
//! - the *shell* (the production loop) pumps real `std::sync` primitives
//!   — `mpsc` channels, `Condvar`s, `Instant::now()` — translates what it
//!   observes into events, and executes the returned effects.
//!
//! Because a core is a deterministic function of its event sequence, the
//! same code the production threads drive can be driven by the
//! [`crate::check`] schedule explorer: a DFS over event interleavings
//! with invariant asserters, where a failing schedule replays exactly.
//! The determinism contract and the seam's design are documented in
//! DESIGN.md §11.

use super::{serving_err, Priority};
use crate::runtime::RuntimeError;
use std::sync::Mutex;
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// batcher core

/// Why a pool is being stopped — decides the error queued-behind-Stop
/// requests drain with (see [`super::engine`]'s close → drain → join
/// contract).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StopCause {
    /// Whole-engine shutdown: drained requests get a serving error.
    Shutdown,
    /// Single-model retire: drained requests get
    /// [`RuntimeError::ModelRetiring`].
    Retire,
}

/// What the batcher core needs to know about a queued item. Implemented
/// by the engine's real request type and by the checker's test requests,
/// so the *same* [`BatcherCore`] runs in production and under the
/// schedule explorer.
pub trait BatchItem {
    /// Batch ordering class; a formed batch is stably sorted High-first.
    fn priority(&self) -> Priority;
    /// Queue-time budget: an item still undispatched this long after
    /// [`BatchItem::enqueued`] is shed instead of dispatched.
    fn deadline(&self) -> Option<Duration>;
    /// When the item entered the queue (stamped by the producer).
    fn enqueued(&self) -> Instant;
}

/// What the batcher shell should block on next (from [`BatcherCore::wait`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatcherWait {
    /// No batch is filling: block indefinitely for the next message.
    Message,
    /// A batch is filling: block for the next message *at most* until
    /// this deadline, then report [`BatcherEvent::WindowElapsed`].
    Window(Instant),
}

/// One observation the batcher shell feeds the core.
#[derive(Debug)]
pub enum BatcherEvent<R> {
    /// A request arrived on the mailbox.
    Arrived(R),
    /// A Stop marker arrived: flush, then exit with this cause.
    Stop(StopCause),
    /// The filling batch's window deadline passed with no message.
    WindowElapsed,
    /// Every mailbox sender is gone (treated as engine shutdown).
    MailboxClosed,
}

/// One instruction the batcher core hands back to its shell, in order.
#[derive(Debug)]
pub enum BatcherEffect<R> {
    /// An item was accepted into the filling batch (bump the model's
    /// `accepted` counter *before* any same-event flush effects).
    Accepted,
    /// These items out-waited their own deadline while queued, observed
    /// at `at`: count them shed, then answer each with
    /// [`RuntimeError::DeadlineExceeded`].
    Shed {
        /// The expired items, in arrival order.
        expired: Vec<R>,
        /// The single `now` sample the expiry decision was made at.
        at: Instant,
    },
    /// A formed (non-empty, priority-ordered) batch: dispatch it.
    Dispatch(Vec<R>),
    /// Exit the serve loop and drain the mailbox per the cause. Always
    /// the last effect of the event that produced it.
    Exit(StopCause),
}

/// The dynamic batcher's pure core: deadline-windowed batch filling,
/// per-item expiry shedding, stable priority ordering — exactly the
/// semantics of the original `batcher_loop`, minus the clock and the
/// channel. See the module docs for the core/shell split.
#[derive(Debug)]
pub struct BatcherCore<R> {
    max_batch: usize,
    max_wait: Duration,
    /// The filling batch and its window deadline, while one is open.
    filling: Option<(Vec<R>, Instant)>,
}

impl<R: BatchItem> BatcherCore<R> {
    /// Core with the pool's batching knobs (`max_batch >= 1`).
    pub fn new(max_batch: usize, max_wait: Duration) -> Self {
        Self { max_batch, max_wait, filling: None }
    }

    /// What the shell should block on next.
    pub fn wait(&self) -> BatcherWait {
        match &self.filling {
            Some((_, window)) => BatcherWait::Window(*window),
            None => BatcherWait::Message,
        }
    }

    /// Advance the batcher by one event observed at `now`. Effects must
    /// be executed in order; [`BatcherEffect::Exit`] is always last.
    pub fn step(&mut self, now: Instant, event: BatcherEvent<R>) -> Vec<BatcherEffect<R>> {
        let mut out = Vec::new();
        match event {
            BatcherEvent::Arrived(item) => {
                out.push(BatcherEffect::Accepted);
                match &mut self.filling {
                    Some((batch, _)) => batch.push(item),
                    None => self.filling = Some((vec![item], now + self.max_wait)),
                }
                if self.filling.as_ref().is_some_and(|(b, _)| b.len() >= self.max_batch) {
                    self.flush(now, &mut out);
                }
            }
            BatcherEvent::WindowElapsed => self.flush(now, &mut out),
            BatcherEvent::Stop(cause) => {
                // dispatch what was already accepted, then exit
                self.flush(now, &mut out);
                out.push(BatcherEffect::Exit(cause));
            }
            BatcherEvent::MailboxClosed => {
                self.flush(now, &mut out);
                out.push(BatcherEffect::Exit(StopCause::Shutdown));
            }
        }
        out
    }

    /// Close the filling batch: shed items past their own deadline, then
    /// emit the survivors stably ordered High-first. No-op when nothing
    /// is filling.
    fn flush(&mut self, now: Instant, out: &mut Vec<BatcherEffect<R>>) {
        let Some((batch, _)) = self.filling.take() else { return };
        let mut live: Vec<R> = Vec::with_capacity(batch.len());
        let mut expired: Vec<R> = Vec::new();
        for item in batch {
            match item.deadline() {
                Some(d) if now.saturating_duration_since(item.enqueued()) > d => {
                    expired.push(item)
                }
                _ => live.push(item),
            }
        }
        if !expired.is_empty() {
            out.push(BatcherEffect::Shed { expired, at: now });
        }
        // stable: FIFO holds within a priority class
        live.sort_by_key(|r| std::cmp::Reverse(r.priority()));
        if !live.is_empty() {
            out.push(BatcherEffect::Dispatch(live));
        }
    }
}

/// Time remaining until `window` as seen from `now`, or `None` when the
/// window has already elapsed (or elapses exactly now).
///
/// This is the audited replacement for the old `window - now` in the
/// batcher shell: the original subtraction was guarded by a `now >=
/// window` check on the *same* `now` sample, so it could not underflow —
/// but only by that one-sample coincidence. Re-sampling the clock between
/// check and subtraction (the natural refactor) would panic in release
/// builds the instant `now` crossed `window` between the two reads.
/// `checked_duration_since` makes the guard structural instead of
/// coincidental; a zero remainder maps to `None` so the shell never parks
/// on a zero-length timeout.
pub fn time_left(window: Instant, now: Instant) -> Option<Duration> {
    match window.checked_duration_since(now) {
        Some(left) if !left.is_zero() => Some(left),
        _ => None,
    }
}

// ---------------------------------------------------------------------------
// worker core

/// One observation the worker shell feeds [`WorkerCore`].
#[derive(Debug)]
pub enum WorkerEvent<B> {
    /// The batcher dispatched a formed batch to this worker.
    Batch(B),
    /// The batch channel closed (the batcher exited): drain and exit.
    Closed,
}

/// What the worker shell should do next (from [`WorkerCore::step`]).
#[derive(Debug)]
pub enum WorkerStep<B> {
    /// Execute this batch as one backend call and answer every request.
    Execute(B),
    /// Exit the worker loop.
    Exit,
}

/// The executor worker's pure core: serve every dispatched batch until
/// the channel closes. Deliberately thin — the worker's interleaving
/// surface is *which* batches arrive in what order, which is exactly what
/// the checker schedules; the execution itself is a leaf.
#[derive(Debug, Default)]
pub struct WorkerCore {
    closed: bool,
}

impl WorkerCore {
    /// Advance the worker by one event.
    pub fn step<B>(&mut self, event: WorkerEvent<B>) -> WorkerStep<B> {
        match event {
            WorkerEvent::Batch(b) if !self.closed => WorkerStep::Execute(b),
            WorkerEvent::Batch(_) | WorkerEvent::Closed => {
                self.closed = true;
                WorkerStep::Exit
            }
        }
    }
}

// ---------------------------------------------------------------------------
// v2 connection window + writer cores

/// Outcome of one [`WindowCore::try_acquire`] probe.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WindowAcquire {
    /// A window slot was taken; the frame may be submitted.
    Acquired,
    /// The window is full: wait for a release (or writer death).
    Full,
    /// The writer is gone; the reader must stop accepting frames. Death
    /// dominates a full *and* a non-full window — a reader woken by a
    /// dying writer must observe `Dead`, never a free slot.
    Dead,
}

/// Pure state of a v2 connection's in-flight window: how many requests
/// are admitted-but-unanswered, the cap, and whether the writer died.
/// The server's `Window` wraps this in a `Mutex` + `Condvar` shell;
/// the checker drives it bare.
#[derive(Debug)]
pub struct WindowCore {
    outstanding: usize,
    limit: usize,
    gone: bool,
}

impl WindowCore {
    /// Empty window with room for `limit` in-flight requests.
    pub fn new(limit: usize) -> Self {
        Self { outstanding: 0, limit, gone: false }
    }

    /// Try to take one in-flight slot. Never blocks; the shell decides
    /// what [`WindowAcquire::Full`] means (park on the condvar).
    pub fn try_acquire(&mut self) -> WindowAcquire {
        if self.gone {
            return WindowAcquire::Dead;
        }
        if self.outstanding >= self.limit {
            return WindowAcquire::Full;
        }
        self.outstanding += 1;
        WindowAcquire::Acquired
    }

    /// Return one in-flight slot (saturating: a release without a
    /// matching acquire is a bug upstream, not a panic here).
    pub fn release(&mut self) {
        self.outstanding = self.outstanding.saturating_sub(1);
    }

    /// Mark the writer dead: every current and future acquire observes
    /// [`WindowAcquire::Dead`].
    pub fn writer_gone(&mut self) {
        self.gone = true;
    }

    /// Requests currently admitted and unanswered.
    pub fn outstanding(&self) -> usize {
        self.outstanding
    }

    /// Whether the writer has been marked dead.
    pub fn is_gone(&self) -> bool {
        self.gone
    }
}

/// One observation the v2 writer shell feeds [`WriterCore`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WriterEvent {
    /// A completion was serialized and written to the socket successfully.
    WroteOk,
    /// The socket write failed: the peer is gone.
    WroteErr,
    /// The completion channel drained (every submitter hung up).
    Drained,
}

/// One instruction the writer core hands back to its shell, in order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WriterEffect {
    /// Release one window slot. Ordered **before** [`WriterEffect::WriterGone`]
    /// on the write-error path: a reader parked on a full window must be
    /// woken into the `Dead` state, not left counting a stale slot.
    Release,
    /// Mark the window's writer dead (wakes parked readers).
    WriterGone,
    /// Emit the connection's pending fatal frame, if one was recorded —
    /// the connection's last bytes.
    EmitFatal,
    /// Exit the writer loop.
    Exit,
}

/// The v2 writer's pure core: window bookkeeping around each written
/// completion, and the death/drain orderings the wire contract depends
/// on (release-before-gone on error; gone-before-fatal on drain).
#[derive(Debug, Default, Clone, Copy)]
pub struct WriterCore;

impl WriterCore {
    /// Advance the writer by one event. Effects must be executed in
    /// order; [`WriterEffect::Exit`] is always last.
    pub fn step(&mut self, event: WriterEvent) -> Vec<WriterEffect> {
        match event {
            WriterEvent::WroteOk => vec![WriterEffect::Release],
            WriterEvent::WroteErr => {
                vec![WriterEffect::Release, WriterEffect::WriterGone, WriterEffect::Exit]
            }
            WriterEvent::Drained => {
                vec![WriterEffect::WriterGone, WriterEffect::EmitFatal, WriterEffect::Exit]
            }
        }
    }
}

// ---------------------------------------------------------------------------
// dispatch-boundary panic containment

/// Run one dispatch-boundary closure, converting a panic into a clean
/// [`RuntimeError::Serving`] instead of unwinding the worker/lane thread.
///
/// Without this, a panicking executor strands its whole batch: no reply
/// is ever sent (clients hang until shutdown's drop-delivery), the
/// worker thread dies, and the batcher keeps routing to the corpse. With
/// it, the panic becomes a per-request `serving_err` through the normal
/// batch-failure path, the thread survives, and `Engine::shutdown` joins
/// cleanly — the regression test drives this with
/// [`inject_dispatch_panic`].
pub fn catch_dispatch_panic<T>(
    f: impl FnOnce() -> Result<T, RuntimeError>,
) -> Result<T, RuntimeError> {
    // AssertUnwindSafe: the closure only touches executor-call state that
    // is discarded wholesale on the error path, so a broken invariant
    // inside it cannot be observed afterwards.
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(f)) {
        Ok(r) => r,
        Err(payload) => {
            let msg = if let Some(s) = payload.downcast_ref::<&'static str>() {
                (*s).to_string()
            } else if let Some(s) = payload.downcast_ref::<String>() {
                s.clone()
            } else {
                "non-string panic payload".to_string()
            };
            Err(serving_err(format!("executor panicked: {msg}")))
        }
    }
}

/// The armed fault-injection key, if any (see [`inject_dispatch_panic`]).
static PANIC_KEY: Mutex<Option<String>> = Mutex::new(None);

/// Arm a one-shot panic at the next dispatch boundary whose key matches:
/// the pool worker path fires on its **model name**, the hetero lane
/// path on its **artifact name**. Test-only seam (the simulated backend
/// is a pure digest fold and has no organic data-dependent panic), keyed
/// so concurrent tests in one process cannot consume each other's
/// injection — use a uniquely named model per test.
pub fn inject_dispatch_panic(key: &str) {
    *PANIC_KEY.lock().unwrap() = Some(key.to_string());
}

/// Fire (and disarm) the injected panic if `key` matches the armed one.
/// The key slot is cleared and the lock released *before* panicking, so
/// the injection never poisons its own mutex.
pub(crate) fn fire_injected_panic(key: &str) {
    let fire = {
        let mut g = PANIC_KEY.lock().unwrap();
        if g.as_deref() == Some(key) {
            *g = None;
            true
        } else {
            false
        }
    };
    if fire {
        panic!("injected dispatch panic for {key}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimal batch item for driving the core directly.
    #[derive(Debug)]
    struct Item {
        tag: u64,
        priority: Priority,
        deadline: Option<Duration>,
        enqueued: Instant,
    }

    impl Item {
        fn new(tag: u64, enqueued: Instant) -> Self {
            Self { tag, priority: Priority::Normal, deadline: None, enqueued }
        }
    }

    impl BatchItem for Item {
        fn priority(&self) -> Priority {
            self.priority
        }
        fn deadline(&self) -> Option<Duration> {
            self.deadline
        }
        fn enqueued(&self) -> Instant {
            self.enqueued
        }
    }

    const MS: Duration = Duration::from_millis(1);

    #[test]
    fn time_left_boundary() {
        let w = Instant::now();
        assert_eq!(time_left(w, w), None, "exactly-elapsed window yields no timeout");
        assert_eq!(time_left(w, w + MS), None, "crossed window yields no timeout");
        assert_eq!(time_left(w + 5 * MS, w), Some(5 * MS));
    }

    #[test]
    fn batcher_flushes_at_max_batch() {
        let t0 = Instant::now();
        let mut core: BatcherCore<Item> = BatcherCore::new(2, Duration::from_secs(1));
        assert_eq!(core.wait(), BatcherWait::Message);
        let fx = core.step(t0, BatcherEvent::Arrived(Item::new(1, t0)));
        assert!(matches!(fx[..], [BatcherEffect::Accepted]), "{fx:?}");
        assert_eq!(core.wait(), BatcherWait::Window(t0 + Duration::from_secs(1)));
        let fx = core.step(t0 + MS, BatcherEvent::Arrived(Item::new(2, t0)));
        match &fx[..] {
            [BatcherEffect::Accepted, BatcherEffect::Dispatch(b)] => {
                assert_eq!(b.iter().map(|i| i.tag).collect::<Vec<_>>(), vec![1, 2]);
            }
            other => panic!("expected accept+dispatch, got {other:?}"),
        }
        assert_eq!(core.wait(), BatcherWait::Message, "flush closes the window");
    }

    #[test]
    fn batcher_flushes_on_window_elapsed() {
        let t0 = Instant::now();
        let mut core: BatcherCore<Item> = BatcherCore::new(8, 2 * MS);
        core.step(t0, BatcherEvent::Arrived(Item::new(7, t0)));
        let fx = core.step(t0 + 2 * MS, BatcherEvent::WindowElapsed);
        match &fx[..] {
            [BatcherEffect::Dispatch(b)] => assert_eq!(b[0].tag, 7),
            other => panic!("expected dispatch, got {other:?}"),
        }
    }

    #[test]
    fn batcher_sheds_expired_and_keeps_live() {
        let t0 = Instant::now();
        let mut core: BatcherCore<Item> = BatcherCore::new(8, 2 * MS);
        let expired =
            Item { tag: 1, priority: Priority::Normal, deadline: Some(MS), enqueued: t0 };
        let live = Item::new(2, t0);
        core.step(t0, BatcherEvent::Arrived(expired));
        core.step(t0, BatcherEvent::Arrived(live));
        let at = t0 + 3 * MS;
        let fx = core.step(at, BatcherEvent::WindowElapsed);
        match &fx[..] {
            [BatcherEffect::Shed { expired, at: seen }, BatcherEffect::Dispatch(b)] => {
                assert_eq!(expired[0].tag, 1);
                assert_eq!(*seen, at);
                assert_eq!(b[0].tag, 2);
            }
            other => panic!("expected shed+dispatch, got {other:?}"),
        }
    }

    #[test]
    fn batcher_orders_by_priority_stably() {
        let t0 = Instant::now();
        let mut core: BatcherCore<Item> = BatcherCore::new(8, MS);
        for (tag, pri) in
            [(1, Priority::Low), (2, Priority::High), (3, Priority::Normal), (4, Priority::High)]
        {
            let item = Item { tag, priority: pri, deadline: None, enqueued: t0 };
            core.step(t0, BatcherEvent::Arrived(item));
        }
        let fx = core.step(t0 + MS, BatcherEvent::WindowElapsed);
        match &fx[..] {
            [BatcherEffect::Dispatch(b)] => {
                let tags: Vec<u64> = b.iter().map(|i| i.tag).collect();
                assert_eq!(tags, vec![2, 4, 3, 1], "High first, FIFO within a class");
            }
            other => panic!("expected dispatch, got {other:?}"),
        }
    }

    #[test]
    fn batcher_stop_mid_fill_dispatches_then_exits() {
        let t0 = Instant::now();
        let mut core: BatcherCore<Item> = BatcherCore::new(8, Duration::from_secs(1));
        core.step(t0, BatcherEvent::Arrived(Item::new(1, t0)));
        let fx = core.step(t0 + MS, BatcherEvent::Stop(StopCause::Retire));
        match &fx[..] {
            [BatcherEffect::Dispatch(b), BatcherEffect::Exit(StopCause::Retire)] => {
                assert_eq!(b[0].tag, 1, "accepted batch is dispatched before exit");
            }
            other => panic!("expected dispatch+exit, got {other:?}"),
        }
    }

    #[test]
    fn batcher_idle_stop_and_mailbox_close_exit_clean() {
        let t0 = Instant::now();
        let mut core: BatcherCore<Item> = BatcherCore::new(8, MS);
        let fx = core.step(t0, BatcherEvent::Stop(StopCause::Shutdown));
        assert!(matches!(fx[..], [BatcherEffect::Exit(StopCause::Shutdown)]), "{fx:?}");
        let fx = core.step(t0, BatcherEvent::MailboxClosed);
        assert!(matches!(fx[..], [BatcherEffect::Exit(StopCause::Shutdown)]), "{fx:?}");
    }

    #[test]
    fn worker_core_executes_until_closed() {
        let mut core = WorkerCore::default();
        assert!(matches!(core.step(WorkerEvent::Batch(1u32)), WorkerStep::Execute(1)));
        assert!(matches!(core.step::<u32>(WorkerEvent::Closed), WorkerStep::Exit));
        assert!(matches!(core.step(WorkerEvent::Batch(2u32)), WorkerStep::Exit));
    }

    #[test]
    fn window_core_dead_dominates() {
        let mut w = WindowCore::new(2);
        assert_eq!(w.try_acquire(), WindowAcquire::Acquired);
        assert_eq!(w.try_acquire(), WindowAcquire::Acquired);
        assert_eq!(w.try_acquire(), WindowAcquire::Full);
        w.release();
        assert_eq!(w.outstanding(), 1);
        w.writer_gone();
        assert_eq!(w.try_acquire(), WindowAcquire::Dead, "dead even though not full");
        assert!(w.is_gone());
        w.release();
        w.release();
        w.release();
        assert_eq!(w.outstanding(), 0, "release saturates at zero");
    }

    #[test]
    fn writer_core_orderings() {
        let mut w = WriterCore;
        assert_eq!(w.step(WriterEvent::WroteOk), vec![WriterEffect::Release]);
        assert_eq!(
            w.step(WriterEvent::WroteErr),
            vec![WriterEffect::Release, WriterEffect::WriterGone, WriterEffect::Exit],
            "release precedes gone so parked readers wake into Dead, not a stale slot"
        );
        assert_eq!(
            w.step(WriterEvent::Drained),
            vec![WriterEffect::WriterGone, WriterEffect::EmitFatal, WriterEffect::Exit],
            "the fatal frame is the connection's last bytes"
        );
    }

    #[test]
    fn catch_dispatch_panic_converts_payloads() {
        assert_eq!(catch_dispatch_panic(|| Ok(7u32)).unwrap(), 7);
        let e = catch_dispatch_panic::<u32>(|| panic!("boom")).unwrap_err();
        assert!(e.to_string().contains("executor panicked: boom"), "{e}");
        let e = catch_dispatch_panic::<u32>(|| panic!("{}", String::from("heap boom")))
            .unwrap_err();
        assert!(e.to_string().contains("heap boom"), "{e}");
        let e =
            catch_dispatch_panic::<u32>(|| Err(serving_err("plain error"))).unwrap_err();
        assert!(e.to_string().contains("plain error"), "passthrough: {e}");
    }

    #[test]
    fn injected_panic_is_keyed_and_one_shot() {
        inject_dispatch_panic("step-test-model");
        fire_injected_panic("some-other-model"); // must not fire
        let e = catch_dispatch_panic::<u32>(|| {
            fire_injected_panic("step-test-model");
            Ok(1)
        })
        .unwrap_err();
        assert!(e.to_string().contains("injected dispatch panic"), "{e}");
        // disarmed after firing
        assert_eq!(
            catch_dispatch_panic(|| {
                fire_injected_panic("step-test-model");
                Ok(2u32)
            })
            .unwrap(),
            2
        );
    }
}
