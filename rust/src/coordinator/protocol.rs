//! Wire protocol v2: binary frame codecs and the pipelined [`AsyncClient`].
//!
//! **The normative specification lives in `PROTOCOL.md` at the repository
//! root** — byte-level frame diagrams, the HELLO negotiation state
//! machine, streaming chunk semantics, the wire-code table, and a worked
//! hex dump that the conformance suite checks these codecs against. This
//! module is the implementation; when the two disagree, PROTOCOL.md wins
//! and the code is wrong.
//!
//! v2 replaces the v1 per-request JSON header with a fixed-layout
//! little-endian binary header and lifts the v1 one-request-at-a-time
//! lockstep: a connection carries **pipelined** requests (many in flight,
//! responses in completion order, matched by `id`) and **streaming**
//! responses (chunked output frames, `seq`/`last`). Version negotiation
//! is a one-time HELLO exchange; servers sniff the magic bytes, so v1
//! JSON clients keep working unchanged ([`super::server`] handles both).
//!
//! Every frame shares an 8-byte prelude:
//!
//! ```text
//!   magic "HDP2" (4) | version u8 | kind u8 | flags u8 | rank u8
//! ```
//!
//! followed by a kind-specific fixed body and variable tail (see the
//! `encode_*` functions, or PROTOCOL.md §4 for the authoritative layout).

use super::server::ClientResponse;
use super::{NodeHealth, Priority};
use crate::obs::{NodeStats, StageStats, STAGES};
use crate::runtime::Tensor;
use std::io::{self, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

/// Frame magic: the first four bytes of every v2 frame. A v1 frame starts
/// with a `u32` JSON-header length bounded far below this value, so the
/// first four bytes of a connection identify the protocol unambiguously.
pub const MAGIC: [u8; 4] = *b"HDP2";
/// Highest wire version this implementation speaks.
pub const VERSION: u8 = 2;
/// `model` field sentinel: route to the server's default (first
/// registered) model.
pub const DEFAULT_MODEL: u16 = 0xFFFF;
/// Default streaming chunk size for response payloads, in f32 elements
/// (64 KiB of payload per frame).
pub const DEFAULT_CHUNK_ELEMS: usize = 16 * 1024;
/// Maximum tensor rank a v2 frame may carry.
pub const MAX_RANK: u8 = 8;
/// Maximum tensor elements either side accepts in one payload (64 MiB of
/// f32) — enforced by the server on requests and by [`AsyncClient`] on
/// response frames, so a corrupt size field can never drive a huge
/// allocation.
pub const MAX_ELEMS: usize = 16 << 20;
/// Maximum HELLO_ACK model-table entries a client accepts.
pub const MAX_TABLE_MODELS: usize = 4096;
/// Maximum model-name bytes in a HELLO_ACK table entry.
pub const MAX_NAME_LEN: usize = 4096;

/// Frame kind: HELLO — client's opening frame (version negotiation).
pub const KIND_HELLO: u8 = 0x01;
/// Frame kind: HELLO_ACK — server's reply (negotiated version + model table).
pub const KIND_HELLO_ACK: u8 = 0x02;
/// Frame kind: REQUEST — one inference request (client to server).
pub const KIND_REQUEST: u8 = 0x03;
/// Frame kind: RESPONSE — head frame of a response (carries metadata,
/// dims, and the first payload chunk).
pub const KIND_RESPONSE: u8 = 0x04;
/// Frame kind: CHUNK — response payload continuation.
pub const KIND_CHUNK: u8 = 0x05;
/// Frame kind: ERROR — structured error, matched by `id`.
pub const KIND_ERROR: u8 = 0x06;
/// Frame kind: HEALTH — client asks for the server's load snapshot
/// (cluster routers poll this for load-aware replica selection).
pub const KIND_HEALTH: u8 = 0x07;
/// Frame kind: HEALTH_ACK — server's [`crate::coordinator::NodeHealth`]
/// snapshot, matched to a HEALTH probe by `id`.
pub const KIND_HEALTH_ACK: u8 = 0x08;
/// Frame kind: STATS — client asks for the server's flight-recorder
/// stage-latency breakdown (all-zero when tracing is off).
pub const KIND_STATS: u8 = 0x09;
/// Frame kind: STATS_ACK — server's [`NodeStats`] breakdown, matched to
/// a STATS probe by `id`.
pub const KIND_STATS_ACK: u8 = 0x0A;

/// RESPONSE flag: the result came from the server's result cache.
pub const FLAG_CACHED: u8 = 0x01;
/// RESPONSE/CHUNK flag: this is the final frame of the response.
pub const FLAG_LAST: u8 = 0x02;
/// ERROR flag: the fault is unrecoverable and the server is closing the
/// connection after this frame.
pub const FLAG_FATAL: u8 = 0x04;

/// Wire codes emitted by the protocol layer itself, on top of
/// [`crate::runtime::RuntimeError::code`] (see PROTOCOL.md §6 for the
/// complete table): `bad_frame` (unparseable/oversized frame — fatal) and
/// `unsupported_version` (negotiation found no common version — fatal).
pub const PROTOCOL_CODES: &[&str] = &["bad_frame", "unsupported_version"];

/// True for the error kinds a timed-out [`AsyncClient::recv_deadline`]
/// read surfaces (`WouldBlock` on Unix, `TimedOut` on Windows). A timeout
/// that returns true here left the connection usable — the frame stream
/// was not entered — so the caller may simply try again later; any other
/// error means the connection is done.
pub fn is_timeout(e: &io::Error) -> bool {
    matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut)
}

// ---------------------------------------------------------------------------
// little-endian building blocks

fn put_u16(buf: &mut Vec<u8>, v: u16) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn get_u16(buf: &[u8], at: usize) -> u16 {
    u16::from_le_bytes([buf[at], buf[at + 1]])
}

fn get_u32(buf: &[u8], at: usize) -> u32 {
    u32::from_le_bytes([buf[at], buf[at + 1], buf[at + 2], buf[at + 3]])
}

fn get_u64(buf: &[u8], at: usize) -> u64 {
    let mut b = [0u8; 8];
    b.copy_from_slice(&buf[at..at + 8]);
    u64::from_le_bytes(b)
}

fn put_prelude(buf: &mut Vec<u8>, kind: u8, flags: u8, rank: u8) {
    buf.extend_from_slice(&MAGIC);
    buf.push(VERSION);
    buf.push(kind);
    buf.push(flags);
    buf.push(rank);
}

/// Serialize an f32 slice to its little-endian wire bytes.
pub fn f32_bytes(data: &[f32]) -> Vec<u8> {
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for v in data {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    bytes
}

/// Parse little-endian wire bytes back to f32s — the inverse of
/// [`f32_bytes`], and the single definition of payload decoding for both
/// protocol versions and both clients. Trailing bytes short of a full
/// element are ignored (callers size their reads to whole elements).
pub fn f32_from_bytes(bytes: &[u8]) -> Vec<f32> {
    bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

/// Read exactly `buf.len()` bytes; `Ok(false)` on a clean EOF **before the
/// first byte**, `Err(UnexpectedEof)` on a truncation mid-buffer (the
/// stream died inside a frame — the data read so far is unusable).
pub(crate) fn read_exact_or_eof(stream: &mut TcpStream, buf: &mut [u8]) -> io::Result<bool> {
    let mut read = 0;
    while read < buf.len() {
        match stream.read(&mut buf[read..]) {
            Ok(0) if read == 0 => return Ok(false),
            Ok(0) => {
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    format!("stream closed mid-frame ({read}/{} bytes)", buf.len()),
                ))
            }
            Ok(n) => read += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

// ---------------------------------------------------------------------------
// prelude

/// A parsed 8-byte frame prelude (magic already validated).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Prelude {
    /// Wire version the frame was encoded under.
    pub version: u8,
    /// Frame kind (`KIND_*`).
    pub kind: u8,
    /// Frame flags (`FLAG_*`).
    pub flags: u8,
    /// Tensor rank for frames that carry dims; 0 otherwise.
    pub rank: u8,
}

/// Parse and validate an 8-byte prelude.
pub fn parse_prelude(bytes: &[u8; 8]) -> Result<Prelude, String> {
    if bytes[..4] != MAGIC {
        return Err(format!("bad magic {:02x?}", &bytes[..4]));
    }
    if bytes[4] != VERSION {
        return Err(format!("unsupported frame version {}", bytes[4]));
    }
    Ok(Prelude { version: bytes[4], kind: bytes[5], flags: bytes[6], rank: bytes[7] })
}

// ---------------------------------------------------------------------------
// HELLO / HELLO_ACK

/// Encode the client's opening HELLO frame, advertising the version range
/// this implementation speaks (`[min, max]`, both [`VERSION`]).
pub fn encode_hello() -> Vec<u8> {
    let mut buf = Vec::with_capacity(24);
    put_prelude(&mut buf, KIND_HELLO, 0, 0);
    buf.push(VERSION); // min supported
    buf.push(VERSION); // max supported
    buf.extend_from_slice(&[0u8; 14]);
    buf
}

/// Encode the server's HELLO_ACK: the negotiated version plus the model
/// table snapshot (index order is the wire `model` index space). Callers
/// must pre-filter entries to [`MAX_NAME_LEN`] / [`MAX_RANK`] /
/// [`MAX_TABLE_MODELS`] — clients reject tables past those bounds, and
/// a name longer than `u16::MAX` would silently desync the frame.
pub fn encode_hello_ack(version: u8, models: &[(String, Vec<usize>)]) -> Vec<u8> {
    debug_assert!(models.len() <= MAX_TABLE_MODELS);
    debug_assert!(models
        .iter()
        .all(|(n, s)| n.len() <= MAX_NAME_LEN && s.len() <= MAX_RANK as usize));
    let mut buf = Vec::with_capacity(24 + models.len() * 32);
    put_prelude(&mut buf, KIND_HELLO_ACK, 0, 0);
    buf.push(version);
    buf.push(0);
    put_u16(&mut buf, models.len() as u16);
    buf.extend_from_slice(&[0u8; 12]);
    for (name, shape) in models {
        put_u16(&mut buf, name.len() as u16);
        buf.extend_from_slice(name.as_bytes());
        buf.push(shape.len() as u8);
        for &d in shape {
            put_u32(&mut buf, d as u32);
        }
    }
    buf
}

// ---------------------------------------------------------------------------
// REQUEST

/// Decoded fields of a v2 request header (everything before the payload).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RequestHeader {
    /// Client-chosen request id, echoed on the matching response frames.
    pub id: u64,
    /// Model index into the HELLO_ACK table ([`DEFAULT_MODEL`] = server
    /// default).
    pub model: u16,
    /// Wire priority: 0 = normal, 1 = high, 2 = low.
    pub priority: u8,
    /// Queue-time deadline in microseconds; 0 = none.
    pub deadline_us: u32,
    /// Input tensor dims, outermost first.
    pub dims: Vec<usize>,
}

/// Encode a request frame header (prelude + fixed body + dims); the f32
/// payload follows on the wire, `prod(dims)` elements.
pub fn encode_request_header(h: &RequestHeader) -> Vec<u8> {
    let mut buf = Vec::with_capacity(24 + h.dims.len() * 4);
    put_prelude(&mut buf, KIND_REQUEST, 0, h.dims.len() as u8);
    put_u64(&mut buf, h.id);
    put_u16(&mut buf, h.model);
    buf.push(h.priority);
    buf.push(0);
    put_u32(&mut buf, h.deadline_us);
    for &d in &h.dims {
        put_u32(&mut buf, d as u32);
    }
    buf
}

/// Encode a complete request frame (header + payload bytes).
pub fn encode_request(h: &RequestHeader, payload: &[f32]) -> Vec<u8> {
    let mut buf = encode_request_header(h);
    buf.extend_from_slice(&f32_bytes(payload));
    buf
}

/// Decode a request frame header from a byte buffer; returns the header
/// and the byte offset where the payload starts. The inverse of
/// [`encode_request_header`] (used by the server, the conformance suite
/// and the `hotpath` v1-vs-v2 header bench).
pub fn decode_request_header(buf: &[u8]) -> Result<(RequestHeader, usize), String> {
    if buf.len() < 24 {
        return Err(format!("request frame too short ({} bytes)", buf.len()));
    }
    let mut prelude = [0u8; 8];
    prelude.copy_from_slice(&buf[..8]);
    let p = parse_prelude(&prelude)?;
    if p.kind != KIND_REQUEST {
        return Err(format!("expected REQUEST frame, got kind {:#04x}", p.kind));
    }
    if p.rank == 0 || p.rank > MAX_RANK {
        return Err(format!("bad rank {}", p.rank));
    }
    let need = 24 + p.rank as usize * 4;
    if buf.len() < need {
        return Err(format!("request frame too short for rank {} ({} bytes)", p.rank, buf.len()));
    }
    let dims = (0..p.rank as usize).map(|i| get_u32(buf, 24 + i * 4) as usize).collect();
    Ok((
        RequestHeader {
            id: get_u64(buf, 8),
            model: get_u16(buf, 16),
            priority: buf[18],
            deadline_us: get_u32(buf, 20),
            dims,
        },
        need,
    ))
}

/// Map an engine [`Priority`] to its wire value.
pub fn priority_to_wire(p: Priority) -> u8 {
    match p {
        Priority::Normal => 0,
        Priority::High => 1,
        Priority::Low => 2,
    }
}

/// Map a wire priority value back; `None` for values the protocol does
/// not define (the server answers those with a `bad_request` error frame).
pub fn priority_from_wire(v: u8) -> Option<Priority> {
    match v {
        0 => Some(Priority::Normal),
        1 => Some(Priority::High),
        2 => Some(Priority::Low),
        _ => None,
    }
}

// ---------------------------------------------------------------------------
// RESPONSE / CHUNK / ERROR

/// Decoded fields of a v2 response head frame (everything before the
/// first payload chunk).
#[derive(Debug, Clone, PartialEq)]
pub struct ResponseHeader {
    /// Echoed request id.
    pub id: u64,
    /// Model index into the HELLO_ACK table ([`DEFAULT_MODEL`] when the
    /// served model is not in the connection's snapshot).
    pub model: u16,
    /// Size of the formed batch this request rode in.
    pub batch_size: u16,
    /// Amortized execution time, microseconds.
    pub exec_us: u32,
    /// Queue time, microseconds.
    pub queued_us: u32,
    /// Payload elements carried by THIS frame.
    pub chunk_elems: u32,
    /// Simulated platform latency, milliseconds.
    pub sim_ms: f32,
    /// Simulated platform energy, millijoules.
    pub sim_mj: f32,
    /// Result-cache hit ([`FLAG_CACHED`]).
    pub cached: bool,
    /// This frame completes the response ([`FLAG_LAST`]).
    pub last: bool,
    /// Full output tensor dims (all chunks together).
    pub dims: Vec<usize>,
}

/// Encode a response head frame (prelude + fixed body + dims); the first
/// payload chunk follows on the wire, `chunk_elems` elements. `seq` is
/// always 0 for a head frame.
pub fn encode_response_head(h: &ResponseHeader) -> Vec<u8> {
    let mut buf = Vec::with_capacity(44 + h.dims.len() * 4);
    let mut flags = 0u8;
    if h.cached {
        flags |= FLAG_CACHED;
    }
    if h.last {
        flags |= FLAG_LAST;
    }
    put_prelude(&mut buf, KIND_RESPONSE, flags, h.dims.len() as u8);
    put_u64(&mut buf, h.id);
    put_u16(&mut buf, h.model);
    put_u16(&mut buf, h.batch_size);
    put_u32(&mut buf, h.exec_us);
    put_u32(&mut buf, h.queued_us);
    put_u32(&mut buf, 0); // seq: a head frame is always chunk 0
    put_u32(&mut buf, h.chunk_elems);
    buf.extend_from_slice(&h.sim_ms.to_le_bytes());
    buf.extend_from_slice(&h.sim_mj.to_le_bytes());
    for &d in &h.dims {
        put_u32(&mut buf, d as u32);
    }
    buf
}

/// Decode a response head frame's fixed body + dims (everything after the
/// prelude); `body` must hold at least `36 + 4 * rank` bytes.
pub fn decode_response_body(p: &Prelude, body: &[u8]) -> Result<ResponseHeader, String> {
    let need = 36 + p.rank as usize * 4;
    if body.len() < need {
        return Err(format!("response body too short ({} < {need})", body.len()));
    }
    let seq = get_u32(body, 20);
    if seq != 0 {
        return Err(format!("response head must be chunk 0, got seq {seq}"));
    }
    let dims = (0..p.rank as usize).map(|i| get_u32(body, 36 + i * 4) as usize).collect();
    Ok(ResponseHeader {
        id: get_u64(body, 0),
        model: get_u16(body, 8),
        batch_size: get_u16(body, 10),
        exec_us: get_u32(body, 12),
        queued_us: get_u32(body, 16),
        chunk_elems: get_u32(body, 24),
        sim_ms: f32::from_le_bytes([body[28], body[29], body[30], body[31]]),
        sim_mj: f32::from_le_bytes([body[32], body[33], body[34], body[35]]),
        cached: p.flags & FLAG_CACHED != 0,
        last: p.flags & FLAG_LAST != 0,
        dims,
    })
}

/// Encode a payload-continuation CHUNK frame header; `chunk_elems`
/// f32 elements follow on the wire.
pub fn encode_chunk_header(id: u64, seq: u32, chunk_elems: u32, last: bool) -> Vec<u8> {
    let mut buf = Vec::with_capacity(24);
    put_prelude(&mut buf, KIND_CHUNK, if last { FLAG_LAST } else { 0 }, 0);
    put_u64(&mut buf, id);
    put_u32(&mut buf, seq);
    put_u32(&mut buf, chunk_elems);
    buf
}

/// Truncate to at most `max` bytes, on a char boundary.
fn clamp_utf8(s: &str, max: usize) -> &str {
    if s.len() <= max {
        return s;
    }
    let mut end = max;
    while !s.is_char_boundary(end) {
        end -= 1;
    }
    &s[..end]
}

/// Encode a structured ERROR frame (code + human-readable message, both
/// UTF-8). `fatal` marks unrecoverable framing faults: the server closes
/// the connection right after this frame. Strings longer than the u16
/// length fields can carry are truncated (on char boundaries) — the
/// alternative would silently desync the frame stream.
pub fn encode_error(id: u64, code: &str, message: &str, fatal: bool) -> Vec<u8> {
    let code = clamp_utf8(code, u16::MAX as usize);
    let message = clamp_utf8(message, u16::MAX as usize);
    let mut buf = Vec::with_capacity(24 + code.len() + message.len());
    put_prelude(&mut buf, KIND_ERROR, if fatal { FLAG_FATAL } else { 0 }, 0);
    put_u64(&mut buf, id);
    put_u16(&mut buf, code.len() as u16);
    put_u16(&mut buf, message.len() as u16);
    put_u32(&mut buf, 0);
    buf.extend_from_slice(code.as_bytes());
    buf.extend_from_slice(message.as_bytes());
    buf
}

// ---------------------------------------------------------------------------
// HEALTH / HEALTH_ACK

/// Encode a HEALTH probe (client to server): prelude + 16-byte body
/// carrying the probe `id` (echoed on the ack) and 8 reserved bytes.
pub fn encode_health(id: u64) -> Vec<u8> {
    let mut buf = Vec::with_capacity(24);
    put_prelude(&mut buf, KIND_HEALTH, 0, 0);
    put_u64(&mut buf, id);
    buf.extend_from_slice(&[0u8; 8]);
    buf
}

/// Encode a HEALTH_ACK frame (server to client): prelude + 32-byte body —
/// echoed probe `id`, then the [`NodeHealth`] snapshot (`in_flight` u64,
/// `queue_depth` u64, `cache_hit_rate` f32) and 4 reserved bytes.
pub fn encode_health_ack(id: u64, h: &NodeHealth) -> Vec<u8> {
    let mut buf = Vec::with_capacity(40);
    put_prelude(&mut buf, KIND_HEALTH_ACK, 0, 0);
    put_u64(&mut buf, id);
    put_u64(&mut buf, h.in_flight);
    put_u64(&mut buf, h.queue_depth);
    buf.extend_from_slice(&h.cache_hit_rate.to_le_bytes());
    buf.extend_from_slice(&[0u8; 4]);
    buf
}

/// Decode a HEALTH_ACK body (the 32 bytes after the prelude) back to the
/// echoed probe id and the [`NodeHealth`] snapshot.
pub fn decode_health_ack(body: &[u8]) -> Result<(u64, NodeHealth), String> {
    if body.len() < 32 {
        return Err(format!("health ack body too short ({} < 32)", body.len()));
    }
    Ok((
        get_u64(body, 0),
        NodeHealth {
            in_flight: get_u64(body, 8),
            queue_depth: get_u64(body, 16),
            cache_hit_rate: f32::from_le_bytes([body[24], body[25], body[26], body[27]]),
        },
    ))
}

// ---------------------------------------------------------------------------
// STATS / STATS_ACK

/// Encode a STATS probe (client to server): prelude + 16-byte body
/// carrying the probe `id` (echoed on the ack) and 8 reserved bytes —
/// the same shape as a HEALTH probe.
pub fn encode_stats(id: u64) -> Vec<u8> {
    let mut buf = Vec::with_capacity(24);
    put_prelude(&mut buf, KIND_STATS, 0, 0);
    put_u64(&mut buf, id);
    buf.extend_from_slice(&[0u8; 8]);
    buf
}

/// Encode a STATS_ACK frame (server to client): prelude + 200-byte body —
/// echoed probe `id`, then one block per [`crate::obs::STAGE_NAMES`]
/// entry, in order: `count` u64, `mean_us` u64, `p50_us` u64, `p99_us`
/// u64 (6 stages × 32 bytes).
pub fn encode_stats_ack(id: u64, s: &NodeStats) -> Vec<u8> {
    let mut buf = Vec::with_capacity(16 + STAGES * 32);
    put_prelude(&mut buf, KIND_STATS_ACK, 0, 0);
    put_u64(&mut buf, id);
    for st in &s.stages {
        put_u64(&mut buf, st.count);
        put_u64(&mut buf, st.mean_us);
        put_u64(&mut buf, st.p50_us);
        put_u64(&mut buf, st.p99_us);
    }
    buf
}

/// Decode a STATS_ACK body (the 200 bytes after the prelude) back to the
/// echoed probe id and the [`NodeStats`] breakdown.
pub fn decode_stats_ack(body: &[u8]) -> Result<(u64, NodeStats), String> {
    let need = 8 + STAGES * 32;
    if body.len() < need {
        return Err(format!("stats ack body too short ({} < {need})", body.len()));
    }
    let mut stats = NodeStats::default();
    for (i, st) in stats.stages.iter_mut().enumerate() {
        let at = 8 + i * 32;
        *st = StageStats {
            count: get_u64(body, at),
            mean_us: get_u64(body, at + 8),
            p50_us: get_u64(body, at + 16),
            p99_us: get_u64(body, at + 24),
        };
    }
    Ok((get_u64(body, 0), stats))
}

// ---------------------------------------------------------------------------
// pipelined client

/// Metadata of one response, available before its payload chunks.
#[derive(Debug, Clone, PartialEq)]
pub struct ResponseHead {
    /// Echoed request id (matches a [`AsyncClient::submit`] return value).
    pub id: u64,
    /// Served model name, resolved against the connection's model table
    /// (empty when the server reports a model outside the snapshot).
    pub model: String,
    /// Full output tensor shape.
    pub shape: Vec<usize>,
    /// Server-side amortized execution time, microseconds.
    pub exec_us: u64,
    /// Server-side queue time, microseconds.
    pub queued_us: u64,
    /// Size of the formed batch this request rode in.
    pub batch_size: usize,
    /// True when the server answered from its result cache.
    pub cached: bool,
    /// Simulated platform latency, milliseconds.
    pub sim_ms: f32,
    /// Simulated platform energy, millijoules.
    pub sim_mj: f32,
}

/// One completed exchange, as returned by [`AsyncClient::recv`].
#[derive(Debug)]
pub enum Reply {
    /// A successful response (all chunks assembled).
    Response(ClientResponse),
    /// A structured error frame, matched to a submitted request by `id`.
    Error {
        /// The request id the error answers (0 for connection-level
        /// faults that predate any request).
        id: u64,
        /// Stable wire code (PROTOCOL.md §6).
        code: String,
        /// Human-readable diagnostic.
        message: String,
        /// True when the server closed the connection after this frame;
        /// every later call on this client fails.
        fatal: bool,
    },
}

/// An in-progress streamed response: consume payload chunks as the
/// server produces them ([`ResponseStream::next_chunk`]) or assemble the
/// whole tensor ([`ResponseStream::collect`]). The stream borrows the
/// client; **abandoning it mid-payload poisons the connection** (the
/// remaining chunk bytes are unread), and later calls fail cleanly.
pub struct ResponseStream<'c> {
    client: &'c mut AsyncClient,
    head: ResponseHead,
    /// Unread payload of the current frame + its LAST flag.
    pending: Option<(u32, bool)>,
    next_seq: u32,
    received: usize,
    done: bool,
}

/// What [`AsyncClient::recv_streaming`] yields: a streamable response or
/// an error frame (errors have no payload, so nothing streams).
pub enum StreamReply<'c> {
    /// A response whose payload can be consumed chunk by chunk.
    Stream(ResponseStream<'c>),
    /// A structured error frame (same fields as [`Reply::Error`]).
    Error {
        /// The request id the error answers.
        id: u64,
        /// Stable wire code (PROTOCOL.md §6).
        code: String,
        /// Human-readable diagnostic.
        message: String,
        /// True when the server closed the connection after this frame.
        fatal: bool,
    },
}

impl ResponseStream<'_> {
    /// Response metadata (id, model, full shape, timings).
    pub fn head(&self) -> &ResponseHead {
        &self.head
    }

    /// Read the next payload chunk; `Ok(None)` once the response is
    /// complete. Chunks arrive in `seq` order and concatenate to the full
    /// row-major tensor.
    pub fn next_chunk(&mut self) -> io::Result<Option<Vec<f32>>> {
        if self.done {
            return Ok(None);
        }
        let (elems, last) = match self.pending.take() {
            Some(p) => p,
            None => {
                let mut pre = [0u8; 8];
                if !read_exact_or_eof(&mut self.client.stream, &mut pre)? {
                    self.client.poisoned = true;
                    return Err(io::Error::new(
                        io::ErrorKind::UnexpectedEof,
                        "server closed mid-stream",
                    ));
                }
                let p = parse_prelude(&pre).map_err(io::Error::other)?;
                if p.kind != KIND_CHUNK {
                    self.client.poisoned = true;
                    return Err(io::Error::other(format!(
                        "expected CHUNK frame, got kind {:#04x}",
                        p.kind
                    )));
                }
                let mut body = [0u8; 16];
                read_all(&mut self.client.stream, &mut body)?;
                let id = get_u64(&body, 0);
                let seq = get_u32(&body, 8);
                if id != self.head.id || seq != self.next_seq {
                    self.client.poisoned = true;
                    return Err(io::Error::other(format!(
                        "chunk out of order: id {id} seq {seq}, expected id {} seq {}",
                        self.head.id, self.next_seq
                    )));
                }
                (get_u32(&body, 12), p.flags & FLAG_LAST != 0)
            }
        };
        // an empty non-final frame makes no progress: accepting it would
        // let a buggy server spin collect() forever
        if elems == 0 && !last {
            self.client.poisoned = true;
            return Err(io::Error::other("empty non-final chunk frame"));
        }
        // a chunk may never carry the stream past the head frame's total
        // (also bounds the allocation below against a corrupt size field)
        let total: usize = self.head.shape.iter().product();
        if self.received + elems as usize > total {
            self.client.poisoned = true;
            return Err(io::Error::other(format!(
                "chunk overruns the response: {} + {elems} > {total} elements",
                self.received
            )));
        }
        self.next_seq += 1;
        let data = self.client.read_f32s(elems as usize)?;
        self.received += data.len();
        if last {
            self.done = true;
            self.client.mid_stream = false;
            if self.received != total {
                self.client.poisoned = true;
                return Err(io::Error::other(format!(
                    "stream ended after {} of {total} elements",
                    self.received
                )));
            }
        }
        Ok(Some(data))
    }

    /// Drain every remaining chunk and assemble the full response.
    pub fn collect(mut self) -> io::Result<ClientResponse> {
        let total: usize = self.head.shape.iter().product();
        let mut data = Vec::with_capacity(total);
        while let Some(chunk) = self.next_chunk()? {
            data.extend_from_slice(&chunk);
        }
        // clone rather than move: ResponseStream implements Drop (the
        // abandonment guard below), which forbids moving fields out
        let head = self.head.clone();
        Ok(ClientResponse {
            id: head.id,
            model: head.model,
            output: Tensor::new(head.shape, data),
            exec_us: head.exec_us,
            queued_us: head.queued_us,
            batch_size: head.batch_size,
            cached: head.cached,
            sim_ms: head.sim_ms,
            sim_mj: head.sim_mj,
        })
    }
}

/// The documented abandonment contract: dropping a stream before its
/// LAST chunk leaves unread payload bytes on the socket, so framing is
/// lost — the client is poisoned (every later call fails with the
/// poisoned error, not a misleading "finish the stream" one).
impl Drop for ResponseStream<'_> {
    fn drop(&mut self) {
        self.client.mid_stream = false;
        if !self.done {
            self.client.poisoned = true;
        }
    }
}

/// Pipelined wire-protocol-v2 client: many requests in flight on one
/// connection, responses in **completion order**, matched by id.
///
/// [`AsyncClient::connect`] performs the HELLO exchange and snapshots the
/// server's model table; [`AsyncClient::submit`] writes a request without
/// waiting; [`AsyncClient::recv`] blocks for the **next completed**
/// response, whichever request it answers. The v1 lockstep client
/// ([`super::server::Client`]) remains for servers predating v2.
pub struct AsyncClient {
    stream: TcpStream,
    next_id: u64,
    version: u8,
    models: Vec<(String, Vec<usize>)>,
    in_flight: usize,
    /// A ResponseStream was dropped mid-payload: unread chunk bytes sit
    /// on the socket and framing is lost.
    poisoned: bool,
    /// A recv_streaming is outstanding (stream not yet fully consumed).
    mid_stream: bool,
}

fn read_all(stream: &mut TcpStream, buf: &mut [u8]) -> io::Result<()> {
    if !read_exact_or_eof(stream, buf)? {
        return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "server closed"));
    }
    Ok(())
}

impl AsyncClient {
    /// Connect and negotiate: send HELLO, await HELLO_ACK (or a fatal
    /// ERROR frame from servers configured v1-only, surfaced as
    /// `io::Error`). On success the client holds the negotiated version
    /// and the server's model table snapshot.
    pub fn connect(addr: &std::net::SocketAddr) -> io::Result<AsyncClient> {
        let mut stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        stream.write_all(&encode_hello())?;
        let mut pre = [0u8; 8];
        read_all(&mut stream, &mut pre)?;
        let p = parse_prelude(&pre).map_err(io::Error::other)?;
        if p.kind == KIND_ERROR {
            let (id, code, message) = read_error_body(&mut stream)?;
            return Err(io::Error::other(format!(
                "negotiation failed (id {id}): {code}: {message}"
            )));
        }
        if p.kind != KIND_HELLO_ACK {
            return Err(io::Error::other(format!("expected HELLO_ACK, got kind {:#04x}", p.kind)));
        }
        let mut body = [0u8; 16];
        read_all(&mut stream, &mut body)?;
        let version = body[0];
        let count = get_u16(&body, 2) as usize;
        // bound server-declared table sizes before allocating on them —
        // the handshake must honor the same "no size field drives a huge
        // allocation" rule as payload frames
        if count > MAX_TABLE_MODELS {
            return Err(io::Error::other(format!("model table of {count} exceeds the bound")));
        }
        let mut models = Vec::with_capacity(count);
        for _ in 0..count {
            let mut len2 = [0u8; 2];
            read_all(&mut stream, &mut len2)?;
            let name_len = u16::from_le_bytes(len2) as usize;
            if name_len > MAX_NAME_LEN {
                return Err(io::Error::other(format!("model name of {name_len} bytes")));
            }
            let mut name = vec![0u8; name_len];
            read_all(&mut stream, &mut name)?;
            let name = String::from_utf8(name).map_err(io::Error::other)?;
            let mut rank = [0u8; 1];
            read_all(&mut stream, &mut rank)?;
            if rank[0] > MAX_RANK {
                return Err(io::Error::other(format!("model shape rank {}", rank[0])));
            }
            let mut dims = Vec::with_capacity(rank[0] as usize);
            for _ in 0..rank[0] {
                let mut d = [0u8; 4];
                read_all(&mut stream, &mut d)?;
                dims.push(u32::from_le_bytes(d) as usize);
            }
            models.push((name, dims));
        }
        Ok(AsyncClient {
            stream,
            // id 0 is what ERROR frames carry for connection-level faults
            // predating any request (PROTOCOL.md §5.7); starting at 1
            // keeps those unambiguous from a real request's failure
            next_id: 1,
            version,
            models,
            in_flight: 0,
            poisoned: false,
            mid_stream: false,
        })
    }

    /// The negotiated wire version (2 for this implementation).
    pub fn version(&self) -> u8 {
        self.version
    }

    /// The server's model table snapshot from HELLO_ACK: `(name, input
    /// shape)` in wire-index order. Models registered after the handshake
    /// are not visible on this connection — reconnect to refresh.
    pub fn models(&self) -> &[(String, Vec<usize>)] {
        &self.models
    }

    /// Requests submitted and not yet answered by a `recv`.
    pub fn in_flight(&self) -> usize {
        self.in_flight
    }

    fn check_usable(&self) -> io::Result<()> {
        if self.poisoned {
            return Err(io::Error::other(
                "connection poisoned: a streamed response was abandoned mid-payload",
            ));
        }
        if self.mid_stream {
            return Err(io::Error::other(
                "a streamed response is still being consumed; finish it first",
            ));
        }
        Ok(())
    }

    fn model_index(&self, model: Option<&str>) -> io::Result<u16> {
        match model {
            None => Ok(DEFAULT_MODEL),
            Some(m) => self
                .models
                .iter()
                .position(|(n, _)| n == m)
                .map(|i| i as u16)
                .ok_or_else(|| {
                    io::Error::other(format!(
                        "model {m:?} not in the connection's table (reconnect to refresh): {:?}",
                        self.models.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>()
                    ))
                }),
        }
    }

    /// Submit one request **without waiting** and return its id; the
    /// response arrives through [`AsyncClient::recv`] in completion
    /// order. `None` routes to the server's default model. Many requests
    /// may be in flight on the one connection — that is the point.
    ///
    /// ```no_run
    /// use hetero_dnn::coordinator::protocol::{AsyncClient, Reply};
    /// use hetero_dnn::runtime::Tensor;
    ///
    /// let addr = "127.0.0.1:7878".parse().unwrap();
    /// let mut client = AsyncClient::connect(&addr)?;
    /// let shape = client.models()[0].1.clone();
    /// // pipeline 8 requests before reading a single response …
    /// let ids: Vec<u64> = (0..8)
    ///     .map(|seed| client.submit(None, &Tensor::randn(&shape, seed)))
    ///     .collect::<std::io::Result<_>>()?;
    /// // … then drain them in completion order, matched by id
    /// for _ in &ids {
    ///     match client.recv()? {
    ///         Reply::Response(r) => assert!(ids.contains(&r.id)),
    ///         Reply::Error { id, code, .. } => eprintln!("{id} failed: {code}"),
    ///     }
    /// }
    /// # Ok::<(), std::io::Error>(())
    /// ```
    pub fn submit(&mut self, model: Option<&str>, input: &Tensor) -> io::Result<u64> {
        self.submit_with(model, input, Priority::Normal, None)
    }

    /// [`AsyncClient::submit`] with an explicit priority and queue-time
    /// deadline (micros, capped at `u32::MAX`; `None` = no deadline).
    pub fn submit_with(
        &mut self,
        model: Option<&str>,
        input: &Tensor,
        priority: Priority,
        deadline: Option<Duration>,
    ) -> io::Result<u64> {
        self.check_usable()?;
        // reject unencodable tensors HERE, per request: silently truncating
        // rank to u8 or dims to u32 would desync the frame and fatally
        // kill every other in-flight request on the connection
        if input.shape.len() > MAX_RANK as usize {
            return Err(io::Error::other(format!(
                "tensor rank {} exceeds the protocol maximum {MAX_RANK}",
                input.shape.len()
            )));
        }
        if input.shape.iter().any(|&d| d > u32::MAX as usize) {
            return Err(io::Error::other("tensor dimension exceeds the u32 wire format"));
        }
        if input.data.is_empty() || input.data.len() > MAX_ELEMS {
            return Err(io::Error::other(format!(
                "tensor of {} elements is outside the protocol bounds [1, {MAX_ELEMS}]",
                input.data.len()
            )));
        }
        let id = self.next_id;
        self.next_id += 1;
        let header = RequestHeader {
            id,
            model: self.model_index(model)?,
            priority: priority_to_wire(priority),
            // 0 means "no deadline" on the wire, so an explicit
            // sub-microsecond deadline is clamped UP to 1 µs rather than
            // silently becoming unbounded
            deadline_us: deadline
                .map(|d| u32::try_from(d.as_micros()).unwrap_or(u32::MAX).max(1))
                .unwrap_or(0),
            dims: input.shape.clone(),
        };
        self.stream.write_all(&encode_request_header(&header))?;
        self.stream.write_all(&f32_bytes(&input.data))?;
        self.stream.flush()?;
        self.in_flight += 1;
        Ok(id)
    }

    /// Block for the next **completed** response or error frame — not
    /// necessarily answering the oldest submit; match on the returned id.
    /// Payload chunks are assembled into the full tensor; use
    /// [`AsyncClient::recv_streaming`] to consume them incrementally.
    ///
    /// ```no_run
    /// use hetero_dnn::coordinator::protocol::{AsyncClient, Reply};
    /// use hetero_dnn::runtime::Tensor;
    ///
    /// let addr = "127.0.0.1:7878".parse().unwrap();
    /// let mut client = AsyncClient::connect(&addr)?;
    /// let shape = client.models()[0].1.clone();
    /// let id = client.submit(None, &Tensor::randn(&shape, 0))?;
    /// match client.recv()? {
    ///     Reply::Response(r) => assert_eq!(r.id, id),
    ///     Reply::Error { code, message, .. } => panic!("{code}: {message}"),
    /// }
    /// # Ok::<(), std::io::Error>(())
    /// ```
    pub fn recv(&mut self) -> io::Result<Reply> {
        match self.recv_streaming()? {
            StreamReply::Stream(s) => Ok(Reply::Response(s.collect()?)),
            StreamReply::Error { id, code, message, fatal } => {
                Ok(Reply::Error { id, code, message, fatal })
            }
        }
    }

    /// Like [`AsyncClient::recv`], but yields the response as a
    /// [`ResponseStream`] so large tensors can be consumed chunk by chunk
    /// as the server produces them, instead of buffering the whole
    /// payload first.
    pub fn recv_streaming(&mut self) -> io::Result<StreamReply<'_>> {
        self.check_usable()?;
        let mut pre = [0u8; 8];
        read_all(&mut self.stream, &mut pre)?;
        let p = match parse_prelude(&pre) {
            Ok(p) => p,
            Err(e) => {
                // the 8 consumed bytes were not a frame: framing is lost
                self.poisoned = true;
                return Err(io::Error::other(e));
            }
        };
        self.stream_after_prelude(p)
    }

    /// Like [`AsyncClient::recv`], but gives up after `timeout` if no
    /// frame **starts** arriving — the seam a cluster router needs to
    /// tell a *slow* replica from a *dead* one. Three outcomes:
    ///
    /// - a frame arrives in time → the assembled [`Reply`], exactly as
    ///   [`AsyncClient::recv`] would return it;
    /// - the deadline passes with **zero** frame bytes read → an error
    ///   for which [`is_timeout`] returns true; the connection is still
    ///   usable (nothing was consumed) and `in_flight` is unchanged —
    ///   the replica is slow, call again later;
    /// - the stream dies or hangs **mid-frame** → any other error; the
    ///   connection is poisoned (framing is lost) and must be dropped —
    ///   the replica is dead.
    ///
    /// Once a frame's first byte lands the rest is read blocking: a
    /// frame that started is expected to finish promptly, and tearing
    /// the connection down mid-frame would forfeit it anyway.
    pub fn recv_deadline(&mut self, timeout: Duration) -> io::Result<Reply> {
        self.check_usable()?;
        let p = self.read_prelude_deadline(timeout)?;
        match self.stream_after_prelude(p)? {
            StreamReply::Stream(s) => Ok(Reply::Response(s.collect()?)),
            StreamReply::Error { id, code, message, fatal } => {
                Ok(Reply::Error { id, code, message, fatal })
            }
        }
    }

    /// Read the 8-byte prelude under a read timeout, then restore the
    /// socket to blocking mode. A timeout before the first byte is clean
    /// ([`is_timeout`], not poisoned); a timeout or EOF after it poisons
    /// the connection (partial frame — framing is lost).
    fn read_prelude_deadline(&mut self, timeout: Duration) -> io::Result<Prelude> {
        // a zero timeout means "disable the timeout" to the OS — clamp up
        let timeout = timeout.max(Duration::from_millis(1));
        self.stream.set_read_timeout(Some(timeout))?;
        let mut pre = [0u8; 8];
        let mut read = 0;
        let outcome = loop {
            match self.stream.read(&mut pre[read..]) {
                Ok(0) => {
                    break Err(io::Error::new(
                        io::ErrorKind::UnexpectedEof,
                        format!("server closed ({read}/8 prelude bytes)"),
                    ));
                }
                Ok(n) => {
                    read += n;
                    if read == 8 {
                        break Ok(());
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => break Err(e),
            }
        };
        // restore blocking mode FIRST — frame bodies and later recv()
        // calls must not inherit the probe timeout
        self.stream.set_read_timeout(None)?;
        if let Err(e) = outcome {
            if read > 0 || !is_timeout(&e) {
                // bytes were consumed (or the stream errored outright):
                // the next read would land mid-frame
                self.poisoned = true;
            }
            return Err(e);
        }
        match parse_prelude(&pre) {
            Ok(p) => Ok(p),
            Err(e) => {
                self.poisoned = true;
                Err(io::Error::other(e))
            }
        }
    }

    /// Lockstep health probe: send HEALTH, await the matching
    /// HEALTH_ACK. Requires an idle connection (`in_flight == 0`) — with
    /// responses pending, the ack would interleave with completion-order
    /// response frames and this simple exchange could not match it.
    /// Routers keep a dedicated probe connection per replica instead.
    pub fn health(&mut self) -> io::Result<NodeHealth> {
        self.check_usable()?;
        if self.in_flight != 0 {
            return Err(io::Error::other(format!(
                "health is a lockstep exchange; {} request(s) in flight",
                self.in_flight
            )));
        }
        let id = self.next_id;
        self.next_id += 1;
        self.stream.write_all(&encode_health(id))?;
        self.stream.flush()?;
        let mut pre = [0u8; 8];
        read_all(&mut self.stream, &mut pre)?;
        let p = match parse_prelude(&pre) {
            Ok(p) => p,
            Err(e) => {
                self.poisoned = true;
                return Err(io::Error::other(e));
            }
        };
        match p.kind {
            KIND_ERROR => {
                let (eid, code, message) = read_error_body(&mut self.stream)?;
                if p.flags & FLAG_FATAL != 0 {
                    self.poisoned = true;
                }
                Err(io::Error::other(format!("health probe failed (id {eid}): {code}: {message}")))
            }
            KIND_HEALTH_ACK => {
                let mut body = [0u8; 32];
                read_all(&mut self.stream, &mut body)?;
                let (ack_id, h) = decode_health_ack(&body).map_err(io::Error::other)?;
                if ack_id != id {
                    self.poisoned = true;
                    return Err(io::Error::other(format!(
                        "health ack id {ack_id} does not match probe id {id}"
                    )));
                }
                Ok(h)
            }
            other => {
                self.poisoned = true;
                Err(io::Error::other(format!("expected HEALTH_ACK, got kind {other:#04x}")))
            }
        }
    }

    /// Lockstep stats probe: send STATS, await the matching STATS_ACK
    /// carrying the node's flight-recorder stage breakdown (all zeros
    /// when the server runs with tracing off). Same idle-connection
    /// contract as [`AsyncClient::health`].
    pub fn stats(&mut self) -> io::Result<NodeStats> {
        self.check_usable()?;
        if self.in_flight != 0 {
            return Err(io::Error::other(format!(
                "stats is a lockstep exchange; {} request(s) in flight",
                self.in_flight
            )));
        }
        let id = self.next_id;
        self.next_id += 1;
        self.stream.write_all(&encode_stats(id))?;
        self.stream.flush()?;
        let mut pre = [0u8; 8];
        read_all(&mut self.stream, &mut pre)?;
        let p = match parse_prelude(&pre) {
            Ok(p) => p,
            Err(e) => {
                self.poisoned = true;
                return Err(io::Error::other(e));
            }
        };
        match p.kind {
            KIND_ERROR => {
                let (eid, code, message) = read_error_body(&mut self.stream)?;
                if p.flags & FLAG_FATAL != 0 {
                    self.poisoned = true;
                }
                Err(io::Error::other(format!("stats probe failed (id {eid}): {code}: {message}")))
            }
            KIND_STATS_ACK => {
                let mut body = [0u8; 8 + STAGES * 32];
                read_all(&mut self.stream, &mut body)?;
                let (ack_id, s) = decode_stats_ack(&body).map_err(io::Error::other)?;
                if ack_id != id {
                    self.poisoned = true;
                    return Err(io::Error::other(format!(
                        "stats ack id {ack_id} does not match probe id {id}"
                    )));
                }
                Ok(s)
            }
            other => {
                self.poisoned = true;
                Err(io::Error::other(format!("expected STATS_ACK, got kind {other:#04x}")))
            }
        }
    }

    /// Dispatch one frame whose prelude has been read and validated: the
    /// shared tail of [`AsyncClient::recv_streaming`] and
    /// [`AsyncClient::recv_deadline`].
    fn stream_after_prelude(&mut self, p: Prelude) -> io::Result<StreamReply<'_>> {
        match p.kind {
            KIND_ERROR => {
                let (id, code, message) = read_error_body(&mut self.stream)?;
                let fatal = p.flags & FLAG_FATAL != 0;
                if fatal {
                    self.poisoned = true;
                } else {
                    self.in_flight = self.in_flight.saturating_sub(1);
                }
                Ok(StreamReply::Error { id, code, message, fatal })
            }
            KIND_RESPONSE => {
                let mut body = vec![0u8; 36 + p.rank as usize * 4];
                read_all(&mut self.stream, &mut body)?;
                let h = match decode_response_body(&p, &body) {
                    Ok(h) => h,
                    Err(e) => {
                        self.poisoned = true;
                        return Err(io::Error::other(e));
                    }
                };
                // bound server-declared sizes BEFORE any allocation keyed
                // on them — the mirror of the server's request-side check
                let total = h
                    .dims
                    .iter()
                    .try_fold(1usize, |a, &d| a.checked_mul(d))
                    .unwrap_or(usize::MAX);
                if total > MAX_ELEMS || h.chunk_elems as usize > total {
                    self.poisoned = true;
                    return Err(io::Error::other(format!(
                        "response size out of bounds: {:?} dims, chunk {}",
                        h.dims, h.chunk_elems
                    )));
                }
                let model = self
                    .models
                    .get(h.model as usize)
                    .map(|(n, _)| n.clone())
                    .unwrap_or_default();
                let head = ResponseHead {
                    id: h.id,
                    model,
                    shape: h.dims.clone(),
                    exec_us: h.exec_us as u64,
                    queued_us: h.queued_us as u64,
                    batch_size: h.batch_size as usize,
                    cached: h.cached,
                    sim_ms: h.sim_ms,
                    sim_mj: h.sim_mj,
                };
                self.in_flight = self.in_flight.saturating_sub(1);
                self.mid_stream = true;
                // the head frame IS chunk 0: next_seq advances to 1 once
                // its pending payload is consumed, matching the server's
                // numbering of the first CHUNK continuation
                Ok(StreamReply::Stream(ResponseStream {
                    client: self,
                    head,
                    pending: Some((h.chunk_elems, h.last)),
                    next_seq: 0,
                    received: 0,
                    done: false,
                }))
            }
            other => {
                // the frame's body length is unknown for an undefined
                // kind, so the stream cannot be resynchronized
                self.poisoned = true;
                Err(io::Error::other(format!("unexpected frame kind {other:#04x}")))
            }
        }
    }

    /// Read `elems` payload f32s; callers bound `elems` by [`MAX_ELEMS`]
    /// before this allocates.
    fn read_f32s(&mut self, elems: usize) -> io::Result<Vec<f32>> {
        let mut bytes = vec![0u8; elems * 4];
        match read_all(&mut self.stream, &mut bytes) {
            Ok(()) => {}
            Err(e) => {
                self.poisoned = true;
                return Err(e);
            }
        }
        Ok(f32_from_bytes(&bytes))
    }
}

fn read_error_body(stream: &mut TcpStream) -> io::Result<(u64, String, String)> {
    let mut body = [0u8; 16];
    read_all(stream, &mut body)?;
    let id = get_u64(&body, 0);
    let mut code = vec![0u8; get_u16(&body, 8) as usize];
    read_all(stream, &mut code)?;
    let mut msg = vec![0u8; get_u16(&body, 10) as usize];
    read_all(stream, &mut msg)?;
    Ok((
        id,
        String::from_utf8_lossy(&code).into_owned(),
        String::from_utf8_lossy(&msg).into_owned(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_header_roundtrip() {
        let h = RequestHeader {
            id: 42,
            model: 1,
            priority: priority_to_wire(Priority::High),
            deadline_us: 2_000,
            dims: vec![1, 224, 224, 3],
        };
        let buf = encode_request_header(&h);
        assert_eq!(buf.len(), 24 + 4 * 4);
        let (back, payload_at) = decode_request_header(&buf).expect("decode");
        assert_eq!(back, h);
        assert_eq!(payload_at, buf.len());
    }

    #[test]
    fn request_frame_appends_payload() {
        let h = RequestHeader { id: 1, model: 0, priority: 0, deadline_us: 0, dims: vec![1, 2] };
        let buf = encode_request(&h, &[0.5, -1.5]);
        let (_, payload_at) = decode_request_header(&buf).expect("decode");
        assert_eq!(&buf[payload_at..], &f32_bytes(&[0.5, -1.5])[..]);
    }

    #[test]
    fn response_head_roundtrip() {
        let h = ResponseHeader {
            id: 7,
            model: 0,
            batch_size: 4,
            exec_us: 250,
            queued_us: 90,
            chunk_elems: 3,
            sim_ms: 1.25,
            sim_mj: 2.5,
            cached: true,
            last: true,
            dims: vec![1, 3],
        };
        let buf = encode_response_head(&h);
        let mut pre = [0u8; 8];
        pre.copy_from_slice(&buf[..8]);
        let p = parse_prelude(&pre).expect("prelude");
        assert_eq!(p.kind, KIND_RESPONSE);
        let back = decode_response_body(&p, &buf[8..]).expect("decode");
        assert_eq!(back, h);
    }

    #[test]
    fn prelude_rejects_bad_magic_and_version() {
        let mut buf = encode_hello();
        buf[0] = b'X';
        let mut pre = [0u8; 8];
        pre.copy_from_slice(&buf[..8]);
        assert!(parse_prelude(&pre).is_err());
        let mut buf = encode_hello();
        buf[4] = 9;
        pre.copy_from_slice(&buf[..8]);
        assert!(parse_prelude(&pre).is_err());
    }

    #[test]
    fn decode_request_rejects_bad_rank() {
        let h = RequestHeader { id: 1, model: 0, priority: 0, deadline_us: 0, dims: vec![1] };
        let mut buf = encode_request_header(&h);
        buf[7] = 0;
        assert!(decode_request_header(&buf).is_err());
        buf[7] = MAX_RANK + 1;
        assert!(decode_request_header(&buf).is_err());
    }

    #[test]
    fn priority_wire_mapping_roundtrips() {
        for p in [Priority::Low, Priority::Normal, Priority::High] {
            assert_eq!(priority_from_wire(priority_to_wire(p)), Some(p));
        }
        assert_eq!(priority_from_wire(3), None);
    }

    #[test]
    fn error_frame_layout() {
        let buf = encode_error(9, "shed", "try later", false);
        assert_eq!(&buf[..4], &MAGIC);
        assert_eq!(buf[5], KIND_ERROR);
        assert_eq!(buf[6], 0);
        assert_eq!(get_u64(&buf, 8), 9);
        assert_eq!(get_u16(&buf, 16), 4);
        assert_eq!(get_u16(&buf, 18), 9);
        assert_eq!(&buf[24..28], b"shed");
        let fatal = encode_error(0, "bad_frame", "x", true);
        assert_eq!(fatal[6], FLAG_FATAL);
    }

    #[test]
    fn health_frames_roundtrip() {
        let probe = encode_health(11);
        assert_eq!(probe.len(), 24);
        assert_eq!(probe[5], KIND_HEALTH);
        assert_eq!(probe[7], 0, "health frames carry no dims");
        assert_eq!(get_u64(&probe, 8), 11);

        let h = NodeHealth { in_flight: 3, queue_depth: 2, cache_hit_rate: 0.75 };
        let ack = encode_health_ack(11, &h);
        assert_eq!(ack.len(), 40);
        assert_eq!(ack[5], KIND_HEALTH_ACK);
        let (id, back) = decode_health_ack(&ack[8..]).expect("decode");
        assert_eq!(id, 11);
        assert_eq!(back, h);
        assert!(decode_health_ack(&ack[8..32]).is_err(), "short body must be rejected");
    }

    #[test]
    fn stats_frames_roundtrip() {
        let probe = encode_stats(17);
        assert_eq!(probe.len(), 24);
        assert_eq!(probe[5], KIND_STATS);
        assert_eq!(probe[7], 0, "stats frames carry no dims");
        assert_eq!(get_u64(&probe, 8), 17);

        let mut s = NodeStats::default();
        for (i, st) in s.stages.iter_mut().enumerate() {
            let base = (i as u64 + 1) * 100;
            *st = StageStats {
                count: base,
                mean_us: base + 1,
                p50_us: base + 2,
                p99_us: base + 3,
            };
        }
        let ack = encode_stats_ack(17, &s);
        assert_eq!(ack.len(), 16 + STAGES * 32, "prelude + id + 6 stage blocks");
        assert_eq!(ack[5], KIND_STATS_ACK);
        let (id, back) = decode_stats_ack(&ack[8..]).expect("decode");
        assert_eq!(id, 17);
        assert_eq!(back, s);
        assert!(decode_stats_ack(&ack[8..80]).is_err(), "short body must be rejected");
    }

    #[test]
    fn hello_ack_encodes_model_table() {
        let models = vec![
            ("fire".to_string(), vec![1, 56, 56, 96]),
            ("bn".to_string(), vec![1, 28, 28, 16]),
        ];
        let buf = encode_hello_ack(VERSION, &models);
        assert_eq!(buf[5], KIND_HELLO_ACK);
        assert_eq!(buf[8], VERSION);
        assert_eq!(get_u16(&buf, 10), 2);
        // first table entry starts right after the 16-byte body
        assert_eq!(get_u16(&buf, 24), 4);
        assert_eq!(&buf[26..30], b"fire");
        assert_eq!(buf[30], 4, "rank of the first input shape");
    }
}
