//! TCP serving front end over the [`Engine`], speaking both wire
//! protocol versions (the normative spec is `PROTOCOL.md` at the repo
//! root; see also `rust/DESIGN.md` §9 for the connection architecture).
//!
//! The server sniffs the first four bytes of every connection:
//!
//! - **v2 (binary, pipelined, streaming)** — they equal the
//!   [`protocol::MAGIC`] bytes: the connection starts with a one-time
//!   HELLO/HELLO_ACK exchange, then splits into a **reader thread**
//!   (parses request frames, feeds [`Engine::submit`]) and a **writer
//!   thread** (serializes completions as they finish). A client may have
//!   many requests in flight; responses return in **completion order**,
//!   matched by `id`, and large outputs stream as chunked frames. Speak
//!   it with [`protocol::AsyncClient`]. A connection may also probe the
//!   node's load with a HEALTH frame, answered with the engine's
//!   aggregated [`NodeHealth`] snapshot (PROTOCOL.md §5.8) — what the
//!   cluster router's load-aware selection reads ([`crate::cluster`]) —
//!   and its flight-recorder stage breakdown with a STATS frame,
//!   answered with a [`NodeStats`] ack (PROTOCOL.md §5.10).
//! - **v1 (JSON, lockstep)** — anything else is a v1 length prefix:
//!   `u32 header_len | header JSON | f32 payload` per request, one
//!   request at a time, answered in order. Request header: `{"id",
//!   "shape"}` plus optional `"model"`, `"priority"`, `"deadline_us"`;
//!   response header `{"id", "model", "shape", "exec_us", "queued_us",
//!   "batch_size", "cached", "sim_ms", "sim_mj"}`, or a structured error
//!   frame `{"id", "code", "error"}` with no payload. Speak it with
//!   [`Client`]. v1 stays accepted for one release past v2 (PROTOCOL.md
//!   §2 is the deprecation schedule).
//!
//! Either way, recoverable request errors (unknown model, shape
//! mismatch, shed, budget exhaustion, model retiring, deadline) answer
//! with a structured error frame and keep the connection open; only
//! unrecoverable framing faults (bad length prefix or magic, unparseable
//! header, oversized tensor) close it, because the byte stream can no
//! longer be trusted. The complete wire-code table lives in PROTOCOL.md
//! §6.
//!
//! One OS thread per connection (embedded-scale fan-in) plus one writer
//! thread per v2 connection; every connection shares the per-model
//! batchers through the [`Engine`] front door, so batching happens
//! across connections exactly like a vLLM-style router.

use super::engine::Completion;
use super::protocol::{self, read_exact_or_eof};
use super::step;
use super::{Engine, InferenceRequest, NodeHealth, Priority};
use crate::config::json::{self, Json};
use crate::obs::NodeStats;
use crate::runtime::{RuntimeError, Tensor};
use std::collections::VecDeque;
use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Maximum accepted v1 header size (sanity bound).
const MAX_HEADER: u32 = 1 << 16;
/// Maximum accepted tensor elements (64 MiB of f32) — shared with the
/// client-side bound so both directions enforce the same ceiling.
const MAX_ELEMS: usize = protocol::MAX_ELEMS;

/// Per-server wire-protocol knobs.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Streaming chunk size for v2 response payloads, in f32 elements
    /// (default [`protocol::DEFAULT_CHUNK_ELEMS`]). Outputs larger than
    /// this flow as multiple frames.
    pub chunk_elems: usize,
    /// Accept v2 binary negotiation (default true). When false the
    /// server is v1-JSON-only and answers HELLO with a fatal
    /// `unsupported_version` error frame.
    pub v2: bool,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self { chunk_elems: protocol::DEFAULT_CHUNK_ELEMS, v2: true }
    }
}

/// Running server handle.
pub struct Server {
    /// The bound address (resolves port 0 to the ephemeral port chosen).
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    /// Connections accepted since startup.
    pub connections: Arc<AtomicU64>,
}

impl Server {
    /// Bind `addr` (use port 0 for an ephemeral port) and serve the
    /// engine's registered models until [`Server::stop`] is called, with
    /// the default [`ServerConfig`] (v2 accepted, v1 fallback).
    pub fn start(addr: &str, engine: Engine) -> std::io::Result<Server> {
        Self::start_with(addr, engine, ServerConfig::default())
    }

    /// [`Server::start`] with explicit wire-protocol knobs.
    pub fn start_with(addr: &str, engine: Engine, cfg: ServerConfig) -> std::io::Result<Server> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let connections = Arc::new(AtomicU64::new(0));
        let stop_t = stop.clone();
        let conns_t = connections.clone();
        let accept_thread = std::thread::Builder::new()
            .name("hetero-dnn-accept".into())
            .spawn(move || {
                while !stop_t.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            conns_t.fetch_add(1, Ordering::Relaxed);
                            let engine = engine.clone();
                            let cfg = cfg.clone();
                            let _ = std::thread::Builder::new()
                                .name("hetero-dnn-conn".into())
                                .spawn(move || {
                                    let _ = serve_connection(stream, engine, cfg);
                                });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            })
            .expect("spawn accept thread");
        Ok(Server { addr: local, stop, accept_thread: Some(accept_thread), connections })
    }

    /// Signal shutdown and join the accept loop (open connections finish
    /// their in-flight requests and close on next read).
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.accept_thread.take() {
            let _ = j.join();
        }
    }
}

pub(crate) fn write_frame(
    stream: &mut TcpStream,
    header: &str,
    payload: &[f32],
) -> std::io::Result<()> {
    stream.write_all(&(header.len() as u32).to_le_bytes())?;
    stream.write_all(header.as_bytes())?;
    stream.write_all(&protocol::f32_bytes(payload))?;
    stream.flush()
}

/// Structured v1 error frame: `{"id", "code", "error"}`, no payload.
pub(crate) fn error_frame(
    stream: &mut TcpStream,
    id: u64,
    code: &str,
    msg: &str,
) -> std::io::Result<()> {
    let header = format!("{{\"id\":{id},\"code\":{code:?},\"error\":{msg:?}}}");
    write_frame(stream, &header, &[])
}

/// Sniff the protocol version from the connection's first four bytes and
/// dispatch: [`protocol::MAGIC`] opens a v2 session, anything else is a
/// v1 length prefix (v1 bounds it below the magic's integer value, so
/// the two can never be confused — PROTOCOL.md §3).
fn serve_connection(
    mut stream: TcpStream,
    engine: Engine,
    cfg: ServerConfig,
) -> std::io::Result<()> {
    stream.set_nodelay(true)?;
    let mut first = [0u8; 4];
    if !read_exact_or_eof(&mut stream, &mut first)? {
        return Ok(()); // connected and left
    }
    if first == protocol::MAGIC {
        serve_v2(stream, engine, &cfg)
    } else {
        serve_v1(stream, engine, u32::from_le_bytes(first))
    }
}

// ---------------------------------------------------------------------------
// v1: JSON headers, one request at a time

fn serve_v1(mut stream: TcpStream, engine: Engine, first_len: u32) -> std::io::Result<()> {
    let mut hlen = first_len;
    loop {
        if !serve_v1_frame(&mut stream, &engine, hlen)? {
            return Ok(());
        }
        let mut len4 = [0u8; 4];
        if !read_exact_or_eof(&mut stream, &mut len4)? {
            return Ok(()); // client closed between requests
        }
        hlen = u32::from_le_bytes(len4);
    }
}

/// Serve one v1 frame whose length prefix is already read; `Ok(false)`
/// closes the connection (clean client EOF or unrecoverable framing).
fn serve_v1_frame(stream: &mut TcpStream, engine: &Engine, hlen: u32) -> std::io::Result<bool> {
    if hlen == 0 || hlen > MAX_HEADER {
        // framing is unrecoverable: answer, then close
        error_frame(stream, 0, "bad_frame", "bad header length")?;
        return Ok(false);
    }
    let mut hbuf = vec![0u8; hlen as usize];
    if !read_exact_or_eof(stream, &mut hbuf)? {
        return Ok(false);
    }
    let header = match std::str::from_utf8(&hbuf).ok().and_then(|s| json::parse(s).ok()) {
        Some(h) => h,
        None => {
            error_frame(stream, 0, "bad_frame", "header not valid JSON")?;
            return Ok(false);
        }
    };
    let id = header.get("id").and_then(Json::as_usize).unwrap_or(0) as u64;
    let Some(shape) = header
        .get("shape")
        .and_then(Json::as_arr)
        .map(|a| a.iter().filter_map(Json::as_usize).collect::<Vec<_>>())
    else {
        // without a shape the payload length is unknown — close
        error_frame(stream, id, "bad_frame", "missing shape")?;
        return Ok(false);
    };
    // checked product: an overflowing shape must land in the bad_frame
    // branch, not wrap into a small "valid" payload length
    let elems = shape
        .iter()
        .try_fold(1usize, |a, &d| a.checked_mul(d))
        .unwrap_or(usize::MAX);
    if elems == 0 || elems > MAX_ELEMS {
        error_frame(stream, id, "bad_frame", "bad tensor size")?;
        return Ok(false);
    }
    let mut payload = vec![0u8; elems * 4];
    if !read_exact_or_eof(stream, &mut payload)? {
        return Ok(false);
    }
    // payload fully consumed: every error past this point answers with
    // a structured frame and KEEPS the connection open
    let data = protocol::f32_from_bytes(&payload);
    let model = match header.get("model") {
        None => match engine.default_model() {
            Some(m) => m,
            None => {
                // every model was retired; the registry may refill, so
                // the connection stays open
                error_frame(stream, id, "unknown_model", "no models registered")?;
                return Ok(true);
            }
        },
        Some(m) => match m.as_str() {
            Some(m) => m.to_string(),
            None => {
                error_frame(stream, id, "bad_request", "model must be a string")?;
                return Ok(true);
            }
        },
    };
    let mut req = InferenceRequest::new(model, Tensor::new(shape, data));
    if let Some(p) = header.get("priority") {
        match p.as_str() {
            Some("high") => req = req.with_priority(Priority::High),
            Some("normal") => {}
            Some("low") => req = req.with_priority(Priority::Low),
            _ => {
                // malformed fields get a structured answer, not a
                // silent default the client would mistake for applied
                error_frame(
                    stream,
                    id,
                    "bad_request",
                    "priority must be \"high\", \"normal\" or \"low\"",
                )?;
                return Ok(true);
            }
        }
    }
    if let Some(d) = header.get("deadline_us") {
        match d.as_usize() {
            Some(us) => req = req.with_deadline(Duration::from_micros(us as u64)),
            None => {
                error_frame(
                    stream,
                    id,
                    "bad_request",
                    "deadline_us must be a non-negative integer",
                )?;
                return Ok(true);
            }
        }
    }
    match engine.infer(req) {
        // v1 clients bound response payloads at MAX_ELEMS too
        Ok(resp) if resp.output.data.len() > MAX_ELEMS => {
            error_frame(
                stream,
                id,
                "serving",
                &format!(
                    "output of {} elements exceeds the wire bound {MAX_ELEMS}",
                    resp.output.data.len()
                ),
            )?;
        }
        Ok(resp) => {
            let out_shape: Vec<String> = resp.output.shape.iter().map(|d| d.to_string()).collect();
            let header = format!(
                "{{\"id\":{id},\"model\":{:?},\"shape\":[{}],\"exec_us\":{},\"queued_us\":{},\"batch_size\":{},\"cached\":{},\"sim_ms\":{:.4},\"sim_mj\":{:.4}}}",
                resp.model,
                out_shape.join(","),
                resp.exec.as_micros(),
                resp.queued.as_micros(),
                resp.batch_size,
                resp.cached,
                resp.simulated.ms(),
                resp.simulated.mj()
            );
            write_frame(stream, &header, &resp.output.data)?;
        }
        Err(e) => error_frame(stream, id, e.code(), &e.to_string())?,
    }
    Ok(true)
}

// ---------------------------------------------------------------------------
// v2: binary frames, pipelined requests, streamed responses

/// The one fatal frame a v2 connection emits before closing; recorded by
/// the reader, written by the writer **after** every in-flight
/// completion has drained, so outstanding responses are never lost to a
/// later framing fault.
pub(crate) struct FatalFrame {
    pub(crate) id: u64,
    pub(crate) code: &'static str,
    pub(crate) msg: String,
}

/// Completions one connection may have queued-or-unwritten at once. Past
/// this the reader stops consuming the socket, so TCP backpressure
/// reaches the client.
const MAX_CONN_WINDOW: usize = 256;

/// Per-connection pipelining window — the backpressure v1's lockstep had
/// implicitly: the reader acquires one unit per request frame *before*
/// feeding the engine, the writer releases one per completion
/// serialized. A client that submits but never reads therefore bounds
/// its own connection at [`MAX_CONN_WINDOW`] buffered responses instead
/// of growing server memory without limit.
///
/// The Mutex + Condvar shell around the pure [`step::WindowCore`]: all
/// window *policy* (death dominates a free slot, saturating release)
/// lives in the core, which the [`crate::check`] explorer drives bare.
pub(crate) struct Window {
    state: Mutex<step::WindowCore>,
    cv: Condvar,
}

impl Window {
    pub(crate) fn new() -> Arc<Window> {
        Arc::new(Window {
            state: Mutex::new(step::WindowCore::new(MAX_CONN_WINDOW)),
            cv: Condvar::new(),
        })
    }

    /// Block until a unit is free; `false` once the writer is gone (the
    /// connection is dead and the reader must stop).
    pub(crate) fn acquire(&self) -> bool {
        let mut s = self.state.lock().unwrap();
        loop {
            match s.try_acquire() {
                step::WindowAcquire::Acquired => return true,
                step::WindowAcquire::Dead => return false,
                step::WindowAcquire::Full => s = self.cv.wait(s).unwrap(),
            }
        }
    }

    pub(crate) fn release(&self) {
        self.state.lock().unwrap().release();
        self.cv.notify_all();
    }

    /// Writer exit: unblocks any reader waiting on a window unit.
    pub(crate) fn writer_gone(&self) {
        self.state.lock().unwrap().writer_gone();
        self.cv.notify_all();
    }
}

fn serve_v2(mut stream: TcpStream, engine: Engine, cfg: &ServerConfig) -> std::io::Result<()> {
    // the sniff consumed the magic; finish the HELLO prelude + body
    let mut rest = [0u8; 4];
    if !read_exact_or_eof(&mut stream, &mut rest)? {
        return Ok(());
    }
    let (version, kind, rank) = (rest[0], rest[1], rest[3]);
    let mut body = [0u8; 16];
    if !read_exact_or_eof(&mut stream, &mut body)? {
        return Ok(());
    }
    if !cfg.v2 {
        stream.write_all(&protocol::encode_error(
            0,
            "unsupported_version",
            "this server speaks wire protocol v1 (JSON) only",
            true,
        ))?;
        return Ok(());
    }
    if version != protocol::VERSION || kind != protocol::KIND_HELLO || rank != 0 {
        stream.write_all(&protocol::encode_error(
            0,
            "bad_frame",
            "expected HELLO as the first v2 frame",
            true,
        ))?;
        return Ok(());
    }
    let (min, max) = (body[0], body[1]);
    if min > protocol::VERSION || max < protocol::VERSION {
        stream.write_all(&protocol::encode_error(
            0,
            "unsupported_version",
            &format!("no common version in client range [{min}, {max}]"),
            true,
        ))?;
        return Ok(());
    }

    // the connection's model-index space: a snapshot at handshake time
    // (models hot-swapped in later need a reconnect to be addressable).
    // Entries outside the table bounds clients enforce (name length,
    // rank, count) are skipped rather than desyncing the handshake —
    // such a model is simply not addressable over v2.
    let models: Arc<Vec<(String, Vec<usize>)>> = Arc::new(
        engine
            .models()
            .into_iter()
            .map(|m| {
                let shape = engine.input_shape(&m).unwrap_or_default();
                (m, shape)
            })
            .filter(|(name, shape)| {
                name.len() <= protocol::MAX_NAME_LEN && shape.len() <= protocol::MAX_RANK as usize
            })
            .take(protocol::MAX_TABLE_MODELS)
            .collect(),
    );
    stream.write_all(&protocol::encode_hello_ack(protocol::VERSION, &models))?;
    stream.flush()?;

    // reader/writer split: after the ACK, every socket write happens on
    // the writer thread, fed completions in completion order
    let (sink, completions) = std::sync::mpsc::channel::<Completion>();
    let fatal: Arc<Mutex<Option<FatalFrame>>> = Arc::new(Mutex::new(None));
    let window = Window::new();
    // health probes queue here (reader side) and are answered by the
    // writer — probes share the connection window with completions, so
    // a probe flood is backpressured like any other traffic
    let health: Arc<Mutex<VecDeque<(u64, NodeHealth)>>> = Arc::new(Mutex::new(VecDeque::new()));
    // STATS probes (flight-recorder stage breakdown) queue the same way
    let stats: Arc<Mutex<VecDeque<(u64, NodeStats)>>> = Arc::new(Mutex::new(VecDeque::new()));
    let writer = {
        let stream = stream.try_clone()?;
        let models = models.clone();
        let fatal = fatal.clone();
        let window = window.clone();
        let health = health.clone();
        let stats = stats.clone();
        let chunk_elems = cfg.chunk_elems.max(1);
        std::thread::Builder::new()
            .name("hetero-dnn-conn-writer".into())
            .spawn(move || {
                v2_writer(stream, completions, models, fatal, chunk_elems, window, health, stats)
            })
            .expect("spawn connection writer")
    };
    let result = v2_reader(&mut stream, &engine, &models, &sink, &fatal, &window, &health, &stats);
    // dropping the reader's sink lets the writer drain every in-flight
    // completion (whose responders hold the remaining senders) and exit
    drop(sink);
    let _ = writer.join();
    result
}

pub(crate) fn set_fatal(fatal: &Mutex<Option<FatalFrame>>, id: u64, code: &'static str, msg: String) {
    *fatal.lock().unwrap() = Some(FatalFrame { id, code, msg });
}

/// Parse request frames and feed [`Engine::submit`] without ever waiting
/// for a response — the pipelining half of the connection. Recoverable
/// per-request errors flow through `sink` like any completion;
/// unrecoverable framing faults record a [`FatalFrame`] and stop the
/// reader.
#[allow(clippy::too_many_arguments)]
fn v2_reader(
    stream: &mut TcpStream,
    engine: &Engine,
    models: &[(String, Vec<usize>)],
    sink: &std::sync::mpsc::Sender<Completion>,
    fatal: &Mutex<Option<FatalFrame>>,
    window: &Window,
    health: &Mutex<VecDeque<(u64, NodeHealth)>>,
    stats: &Mutex<VecDeque<(u64, NodeStats)>>,
) -> std::io::Result<()> {
    let reject = |id: u64, e: RuntimeError| {
        let _ = sink.send(Completion { tag: id, result: Err(e), trace: None });
    };
    loop {
        let mut pre = [0u8; 8];
        if !read_exact_or_eof(stream, &mut pre)? {
            return Ok(()); // client is done submitting
        }
        let p = match protocol::parse_prelude(&pre) {
            Ok(p) => p,
            Err(e) => {
                set_fatal(fatal, 0, "bad_frame", e);
                return Ok(());
            }
        };
        if p.kind == protocol::KIND_HEALTH {
            if p.rank != 0 {
                set_fatal(fatal, 0, "bad_frame", format!("HEALTH frame with rank {}", p.rank));
                return Ok(());
            }
            let mut body = [0u8; 16];
            if !read_exact_or_eof(stream, &mut body)? {
                return Ok(());
            }
            let id = u64::from_le_bytes(body[..8].try_into().expect("8 bytes"));
            // a probe occupies a window unit like any request: the ack
            // the writer owes is a buffered response too
            if !window.acquire() {
                return Ok(());
            }
            health.lock().unwrap().push_back((id, engine.node_health()));
            continue;
        }
        if p.kind == protocol::KIND_STATS {
            if p.rank != 0 {
                set_fatal(fatal, 0, "bad_frame", format!("STATS frame with rank {}", p.rank));
                return Ok(());
            }
            let mut body = [0u8; 16];
            if !read_exact_or_eof(stream, &mut body)? {
                return Ok(());
            }
            let id = u64::from_le_bytes(body[..8].try_into().expect("8 bytes"));
            if !window.acquire() {
                return Ok(());
            }
            // tracing off -> an all-zero breakdown, not an error: probes
            // must be safe to send blind
            stats.lock().unwrap().push_back((id, engine.node_stats()));
            continue;
        }
        if p.kind != protocol::KIND_REQUEST {
            set_fatal(fatal, 0, "bad_frame", format!("unexpected frame kind {:#04x}", p.kind));
            return Ok(());
        }
        let mut body = [0u8; 16];
        if !read_exact_or_eof(stream, &mut body)? {
            return Ok(());
        }
        // the id is pre-read only so rank faults can name the request;
        // the layout itself is parsed exactly once, by the shared codec
        let id = u64::from_le_bytes(body[..8].try_into().expect("8 bytes"));
        if p.rank == 0 || p.rank > protocol::MAX_RANK {
            set_fatal(fatal, id, "bad_frame", format!("bad rank {}", p.rank));
            return Ok(());
        }
        let mut frame = Vec::with_capacity(24 + p.rank as usize * 4);
        frame.extend_from_slice(&pre);
        frame.extend_from_slice(&body);
        let dims_at = frame.len();
        frame.resize(dims_at + p.rank as usize * 4, 0);
        if !read_exact_or_eof(stream, &mut frame[dims_at..])? {
            return Ok(());
        }
        let header = match protocol::decode_request_header(&frame) {
            Ok((h, _)) => h,
            Err(e) => {
                set_fatal(fatal, id, "bad_frame", e);
                return Ok(());
            }
        };
        let elems = header
            .dims
            .iter()
            .try_fold(1usize, |a, &d| a.checked_mul(d))
            .unwrap_or(usize::MAX);
        if elems == 0 || elems > MAX_ELEMS {
            // the advertised payload cannot be skipped safely — close
            set_fatal(fatal, header.id, "bad_frame", "bad tensor size".into());
            return Ok(());
        }
        let mut payload = vec![0u8; elems * 4];
        if !read_exact_or_eof(stream, &mut payload)? {
            return Ok(());
        }
        let data = protocol::f32_from_bytes(&payload);

        // frame fully consumed. Backpressure: every path below queues
        // exactly ONE completion, paid for here — past the window the
        // reader stops consuming the socket until the writer catches up
        if !window.acquire() {
            return Ok(()); // writer died; the connection is tearing down
        }

        // everything below answers with an error completion (matched by
        // id) and keeps the connection open
        let model = if header.model == protocol::DEFAULT_MODEL {
            match engine.default_model() {
                Some(m) => m,
                None => {
                    reject(
                        header.id,
                        RuntimeError::UnknownModel { name: "<default>".into(), registered: vec![] },
                    );
                    continue;
                }
            }
        } else {
            match models.get(header.model as usize) {
                Some((name, _)) => name.clone(),
                None => {
                    reject(
                        header.id,
                        RuntimeError::UnknownModel {
                            name: format!("#{}", header.model),
                            registered: engine.models(),
                        },
                    );
                    continue;
                }
            }
        };
        let mut req = InferenceRequest::new(model, Tensor::new(header.dims, data));
        match protocol::priority_from_wire(header.priority) {
            Some(p) => req = req.with_priority(p),
            None => {
                reject(
                    header.id,
                    RuntimeError::BadRequest(format!(
                        "priority {} undefined (0 normal | 1 high | 2 low)",
                        header.priority
                    )),
                );
                continue;
            }
        }
        if header.deadline_us > 0 {
            req = req.with_deadline(Duration::from_micros(header.deadline_us as u64));
        }
        // non-blocking: the front door runs inline, the response arrives
        // through `sink` in completion order
        if let Err(e) = engine.submit(req, header.id, sink) {
            reject(header.id, e);
        }
    }
}

/// Serialize completions onto the socket as they finish — the streaming
/// half of the connection. Exits when every completion sender (the
/// reader's plus one per in-flight request) is gone, then emits the
/// recorded fatal frame, if any, as the connection's last bytes. Queued
/// health acks are flushed ahead of each completion wait, so a probe is
/// answered promptly even on an otherwise idle connection (the 5 ms poll
/// matches the accept loop's cadence).
#[allow(clippy::too_many_arguments)]
fn v2_writer(
    mut stream: TcpStream,
    completions: std::sync::mpsc::Receiver<Completion>,
    models: Arc<Vec<(String, Vec<usize>)>>,
    fatal: Arc<Mutex<Option<FatalFrame>>>,
    chunk_elems: usize,
    window: Arc<Window>,
    health: Arc<Mutex<VecDeque<(u64, NodeHealth)>>>,
    stats: Arc<Mutex<VecDeque<(u64, NodeStats)>>>,
) {
    let mut core = step::WriterCore;
    loop {
        if flush_health_acks(&mut core, &health, &mut stream, &window, &fatal) {
            return; // write error mid-ack; the client is gone
        }
        if flush_stats_acks(&mut core, &stats, &mut stream, &window, &fatal) {
            return;
        }
        let done = match completions.recv_timeout(Duration::from_millis(5)) {
            Ok(done) => done,
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => continue,
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
        };
        let written = match done.result {
            // clients reject payloads past MAX_ELEMS, so an oversized
            // output must become a per-request error frame here rather
            // than a stream the client will treat as a protocol fault
            Ok(resp) if resp.output.data.len() > MAX_ELEMS => stream
                .write_all(&protocol::encode_error(
                    done.tag,
                    "serving",
                    &format!(
                        "output of {} elements exceeds the wire bound {MAX_ELEMS}",
                        resp.output.data.len()
                    ),
                    false,
                ))
                .and_then(|()| stream.flush()),
            Ok(resp) => write_v2_response(&mut stream, done.tag, &resp, &models, chunk_elems),
            Err(e) => stream
                .write_all(&protocol::encode_error(done.tag, e.code(), &e.to_string(), false))
                .and_then(|()| stream.flush()),
        };
        let event =
            if written.is_ok() { step::WriterEvent::WroteOk } else { step::WriterEvent::WroteErr };
        if drive_writer_effects(&mut core, event, &window, &fatal, &mut stream) {
            return; // client gone; nothing left worth draining
        }
    }
    // acks enqueued after the last flush but before the channel closed
    if flush_health_acks(&mut core, &health, &mut stream, &window, &fatal) {
        return;
    }
    if flush_stats_acks(&mut core, &stats, &mut stream, &window, &fatal) {
        return;
    }
    drive_writer_effects(&mut core, step::WriterEvent::Drained, &window, &fatal, &mut stream);
}

/// Write every queued STATS ack; `true` means a write failed and the
/// writer must exit. Mirrors [`flush_health_acks`] — a stats probe is a
/// windowed response like any other.
pub(crate) fn flush_stats_acks(
    core: &mut step::WriterCore,
    stats: &Mutex<VecDeque<(u64, NodeStats)>>,
    stream: &mut TcpStream,
    window: &Window,
    fatal: &Mutex<Option<FatalFrame>>,
) -> bool {
    loop {
        let next = stats.lock().unwrap().pop_front();
        let Some((id, s)) = next else { return false };
        let written = stream
            .write_all(&protocol::encode_stats_ack(id, &s))
            .and_then(|()| stream.flush());
        let event =
            if written.is_ok() { step::WriterEvent::WroteOk } else { step::WriterEvent::WroteErr };
        if drive_writer_effects(core, event, window, fatal, stream) {
            return true;
        }
    }
}

/// Write every queued health ack; `true` means a write failed and the
/// writer must exit (the effects of the failing step already ran).
pub(crate) fn flush_health_acks(
    core: &mut step::WriterCore,
    health: &Mutex<VecDeque<(u64, NodeHealth)>>,
    stream: &mut TcpStream,
    window: &Window,
    fatal: &Mutex<Option<FatalFrame>>,
) -> bool {
    loop {
        let next = health.lock().unwrap().pop_front();
        let Some((id, h)) = next else { return false };
        let written = stream
            .write_all(&protocol::encode_health_ack(id, &h))
            .and_then(|()| stream.flush());
        let event =
            if written.is_ok() { step::WriterEvent::WroteOk } else { step::WriterEvent::WroteErr };
        if drive_writer_effects(core, event, window, fatal, stream) {
            return true;
        }
    }
}

/// Execute one [`step::WriterCore`] step's effects against the real
/// window/fatal-frame/socket; `true` means the writer must exit. The
/// effect *order* is the wire contract (release before gone on error;
/// gone before the fatal frame on drain) — pinned by the core's unit
/// tests and the checker, executed here.
pub(crate) fn drive_writer_effects(
    core: &mut step::WriterCore,
    event: step::WriterEvent,
    window: &Window,
    fatal: &Mutex<Option<FatalFrame>>,
    stream: &mut TcpStream,
) -> bool {
    let mut exit = false;
    for effect in core.step(event) {
        match effect {
            step::WriterEffect::Release => window.release(),
            step::WriterEffect::WriterGone => window.writer_gone(),
            step::WriterEffect::EmitFatal => {
                if let Some(f) = fatal.lock().unwrap().take() {
                    let _ = stream.write_all(&protocol::encode_error(f.id, f.code, &f.msg, true));
                    let _ = stream.flush();
                }
            }
            step::WriterEffect::Exit => exit = true,
        }
    }
    exit
}

/// Write one response as a head frame plus as many CHUNK continuations
/// as the payload needs at `chunk_elems` elements per frame.
fn write_v2_response(
    stream: &mut TcpStream,
    id: u64,
    resp: &super::InferenceResponse,
    models: &[(String, Vec<usize>)],
    chunk_elems: usize,
) -> std::io::Result<()> {
    let model = models
        .iter()
        .position(|(n, _)| *n == resp.model)
        .map(|i| i as u16)
        .unwrap_or(protocol::DEFAULT_MODEL);
    let total = resp.output.data.len();
    let first = total.min(chunk_elems);
    // one payload conversion per RESPONSE; chunk frames slice it, so the
    // hot write path pays a single allocation however many chunks flow
    let payload = protocol::f32_bytes(&resp.output.data);
    let head = protocol::ResponseHeader {
        id,
        model,
        batch_size: resp.batch_size.min(u16::MAX as usize) as u16,
        exec_us: resp.exec.as_micros().min(u32::MAX as u128) as u32,
        queued_us: resp.queued.as_micros().min(u32::MAX as u128) as u32,
        chunk_elems: first as u32,
        sim_ms: resp.simulated.ms() as f32,
        sim_mj: resp.simulated.mj() as f32,
        cached: resp.cached,
        last: first == total,
        dims: resp.output.shape.clone(),
    };
    stream.write_all(&protocol::encode_response_head(&head))?;
    stream.write_all(&payload[..first * 4])?;
    let (mut at, mut seq) = (first, 1u32);
    while at < total {
        let n = (total - at).min(chunk_elems);
        let last = at + n == total;
        stream.write_all(&protocol::encode_chunk_header(id, seq, n as u32, last))?;
        stream.write_all(&payload[at * 4..(at + n) * 4])?;
        at += n;
        seq += 1;
    }
    stream.flush()
}

// ---------------------------------------------------------------------------
// v1 client

/// Client-side response (shared by the v1 [`Client`] and the v2
/// [`protocol::AsyncClient`]).
#[derive(Debug)]
pub struct ClientResponse {
    /// Request id echoed by the server.
    pub id: u64,
    /// Model name the server reports having served (empty for servers
    /// predating the multi-model protocol).
    pub model: String,
    /// The served output tensor.
    pub output: Tensor,
    /// Server-side amortized execution time, microseconds.
    pub exec_us: u64,
    /// Server-side queue time, microseconds.
    pub queued_us: u64,
    /// Size of the formed batch this request rode in.
    pub batch_size: usize,
    /// True when the server answered from its result cache (false for
    /// servers predating the cache protocol field).
    pub cached: bool,
    /// Simulated platform latency, milliseconds (0.0 for cache hits and
    /// for servers predating the field).
    pub sim_ms: f32,
    /// Simulated platform energy, millijoules (0.0 likewise).
    pub sim_mj: f32,
}

/// Blocking v1 (JSON) client: one request at a time, answered in order.
/// For many requests in flight on one connection, use the pipelined
/// [`protocol::AsyncClient`] instead (PROTOCOL.md compares the two).
pub struct Client {
    stream: TcpStream,
    next_id: u64,
}

impl Client {
    /// Connect to a serving endpoint.
    pub fn connect(addr: &std::net::SocketAddr) -> std::io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(Client { stream, next_id: 0 })
    }

    /// Send one tensor against the server's default model.
    pub fn infer(&mut self, input: &Tensor) -> std::io::Result<ClientResponse> {
        self.infer_model(None, input)
    }

    /// Send one tensor against a named model (None = server default) and
    /// await the response. Server-side request errors come back as
    /// `io::Error` with a `code: message` payload and leave the
    /// connection usable for further requests; a server that closes
    /// mid-response surfaces as `UnexpectedEof`, never as a silently
    /// zero-filled tensor.
    pub fn infer_model(
        &mut self,
        model: Option<&str>,
        input: &Tensor,
    ) -> std::io::Result<ClientResponse> {
        let id = self.next_id;
        self.next_id += 1;
        let dims: Vec<String> = input.shape.iter().map(|d| d.to_string()).collect();
        let header = match model {
            Some(m) => format!("{{\"id\":{id},\"model\":{m:?},\"shape\":[{}]}}", dims.join(",")),
            None => format!("{{\"id\":{id},\"shape\":[{}]}}", dims.join(",")),
        };
        write_frame(&mut self.stream, &header, &input.data)?;

        let mut len4 = [0u8; 4];
        if !read_exact_or_eof(&mut self.stream, &mut len4)? {
            return Err(std::io::Error::other("server closed"));
        }
        let mut hbuf = vec![0u8; u32::from_le_bytes(len4) as usize];
        if !read_exact_or_eof(&mut self.stream, &mut hbuf)? {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "server closed before the response header",
            ));
        }
        let header = json::parse(std::str::from_utf8(&hbuf).map_err(std::io::Error::other)?)
            .map_err(std::io::Error::other)?;
        if let Some(err) = header.get("error").and_then(Json::as_str) {
            let code = header.get("code").and_then(Json::as_str).unwrap_or("error");
            return Err(std::io::Error::other(format!("{code}: {err}")));
        }
        let shape: Vec<usize> = header
            .get("shape")
            .and_then(Json::as_arr)
            .map(|a| a.iter().filter_map(Json::as_usize).collect())
            .ok_or_else(|| std::io::Error::other("missing shape"))?;
        // bound the server-declared size before allocating on it
        let elems = shape
            .iter()
            .try_fold(1usize, |a, &d| a.checked_mul(d))
            .unwrap_or(usize::MAX);
        if elems > MAX_ELEMS {
            return Err(std::io::Error::other(format!("response shape {shape:?} out of bounds")));
        }
        let mut payload = vec![0u8; elems * 4];
        if !read_exact_or_eof(&mut self.stream, &mut payload)? {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "server closed before the response payload",
            ));
        }
        let data = protocol::f32_from_bytes(&payload);
        Ok(ClientResponse {
            id: header.get("id").and_then(Json::as_usize).unwrap_or(0) as u64,
            model: header
                .get("model")
                .and_then(Json::as_str)
                .unwrap_or_default()
                .to_string(),
            output: Tensor::new(shape, data),
            exec_us: header.get("exec_us").and_then(Json::as_usize).unwrap_or(0) as u64,
            queued_us: header.get("queued_us").and_then(Json::as_usize).unwrap_or(0) as u64,
            batch_size: header.get("batch_size").and_then(Json::as_usize).unwrap_or(1),
            cached: matches!(header.get("cached"), Some(Json::Bool(true))),
            sim_ms: header.get("sim_ms").and_then(Json::as_f64).unwrap_or(0.0) as f32,
            sim_mj: header.get("sim_mj").and_then(Json::as_f64).unwrap_or(0.0) as f32,
        })
    }
}
