//! TCP serving front end: a minimal wire protocol over the [`Engine`].
//!
//! Frame format (little-endian), both directions:
//!
//! ```text
//!   u32 header_len | header JSON | f32 payload ...
//! ```
//!
//! Request header: `{"id": <u64>, "shape": [dims...]}` plus optional
//! `"model"` (defaults to the engine's first registered model),
//! `"priority"` (`"high" | "normal" | "low"`) and `"deadline_us"`,
//! followed by `prod(shape)` f32s. Response header: `{"id", "model",
//! "shape", "exec_us", "queued_us", "batch_size", "cached", "sim_ms",
//! "sim_mj"}` followed by the output tensor, or a **structured error
//! frame** `{"id", "code", "error"}` with no payload. Recoverable request
//! errors (unknown model, shape mismatch, shed, budget exhaustion, model
//! retiring, deadline) answer with an error frame and keep the connection
//! open; only unrecoverable framing errors (bad length prefix,
//! unparseable header) close it, because the byte stream can no longer be
//! trusted. The complete wire-code table lives in DESIGN.md §6.
//!
//! One OS thread per connection (embedded-scale fan-in); every connection
//! shares the per-model batchers through the [`Engine`] front door, so
//! batching happens across connections exactly like a vLLM-style router.

use super::{Engine, InferenceRequest, Priority};
use crate::config::json::{self, Json};
use crate::runtime::Tensor;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Maximum accepted header size (sanity bound).
const MAX_HEADER: u32 = 1 << 16;
/// Maximum accepted tensor elements (64 MiB of f32).
const MAX_ELEMS: usize = 16 << 20;

/// Running server handle.
pub struct Server {
    /// The bound address (resolves port 0 to the ephemeral port chosen).
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    /// Connections accepted since startup.
    pub connections: Arc<AtomicU64>,
}

impl Server {
    /// Bind `addr` (use port 0 for an ephemeral port) and serve the
    /// engine's registered models until [`Server::stop`] is called.
    pub fn start(addr: &str, engine: Engine) -> std::io::Result<Server> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let connections = Arc::new(AtomicU64::new(0));
        let stop_t = stop.clone();
        let conns_t = connections.clone();
        let accept_thread = std::thread::Builder::new()
            .name("hetero-dnn-accept".into())
            .spawn(move || {
                while !stop_t.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            conns_t.fetch_add(1, Ordering::Relaxed);
                            let engine = engine.clone();
                            let _ = std::thread::Builder::new()
                                .name("hetero-dnn-conn".into())
                                .spawn(move || {
                                    let _ = serve_connection(stream, engine);
                                });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            })
            .expect("spawn accept thread");
        Ok(Server { addr: local, stop, accept_thread: Some(accept_thread), connections })
    }

    /// Signal shutdown and join the accept loop (open connections finish
    /// their in-flight request and close on next read).
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.accept_thread.take() {
            let _ = j.join();
        }
    }
}

fn read_exact_or_eof(stream: &mut TcpStream, buf: &mut [u8]) -> std::io::Result<bool> {
    let mut read = 0;
    while read < buf.len() {
        match stream.read(&mut buf[read..]) {
            Ok(0) => return Ok(false), // clean EOF
            Ok(n) => read += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

fn write_frame(stream: &mut TcpStream, header: &str, payload: &[f32]) -> std::io::Result<()> {
    stream.write_all(&(header.len() as u32).to_le_bytes())?;
    stream.write_all(header.as_bytes())?;
    let mut bytes = Vec::with_capacity(payload.len() * 4);
    for v in payload {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    stream.write_all(&bytes)?;
    stream.flush()
}

/// Structured error frame: `{"id", "code", "error"}`, no payload.
fn error_frame(stream: &mut TcpStream, id: u64, code: &str, msg: &str) -> std::io::Result<()> {
    let header = format!("{{\"id\":{id},\"code\":{code:?},\"error\":{msg:?}}}");
    write_frame(stream, &header, &[])
}

fn serve_connection(mut stream: TcpStream, engine: Engine) -> std::io::Result<()> {
    stream.set_nodelay(true)?;
    loop {
        let mut len4 = [0u8; 4];
        if !read_exact_or_eof(&mut stream, &mut len4)? {
            return Ok(()); // client closed
        }
        let hlen = u32::from_le_bytes(len4);
        if hlen == 0 || hlen > MAX_HEADER {
            // framing is unrecoverable: answer, then close
            return error_frame(&mut stream, 0, "bad_frame", "bad header length");
        }
        let mut hbuf = vec![0u8; hlen as usize];
        if !read_exact_or_eof(&mut stream, &mut hbuf)? {
            return Ok(());
        }
        let header = match std::str::from_utf8(&hbuf).ok().and_then(|s| json::parse(s).ok()) {
            Some(h) => h,
            None => return error_frame(&mut stream, 0, "bad_frame", "header not valid JSON"),
        };
        let id = header.get("id").and_then(Json::as_usize).unwrap_or(0) as u64;
        let Some(shape) = header
            .get("shape")
            .and_then(Json::as_arr)
            .map(|a| a.iter().filter_map(Json::as_usize).collect::<Vec<_>>())
        else {
            // without a shape the payload length is unknown — close
            return error_frame(&mut stream, id, "bad_frame", "missing shape");
        };
        let elems: usize = shape.iter().product();
        if elems == 0 || elems > MAX_ELEMS {
            return error_frame(&mut stream, id, "bad_frame", "bad tensor size");
        }
        let mut payload = vec![0u8; elems * 4];
        if !read_exact_or_eof(&mut stream, &mut payload)? {
            return Ok(());
        }
        // payload fully consumed: every error past this point answers with
        // a structured frame and KEEPS the connection open
        let data: Vec<f32> = payload
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let model = match header.get("model") {
            None => match engine.default_model() {
                Some(m) => m,
                None => {
                    // every model was retired; the registry may refill, so
                    // the connection stays open
                    error_frame(&mut stream, id, "unknown_model", "no models registered")?;
                    continue;
                }
            },
            Some(m) => match m.as_str() {
                Some(m) => m.to_string(),
                None => {
                    error_frame(&mut stream, id, "bad_request", "model must be a string")?;
                    continue;
                }
            },
        };
        let mut req = InferenceRequest::new(model, Tensor::new(shape, data));
        if let Some(p) = header.get("priority") {
            match p.as_str() {
                Some("high") => req = req.with_priority(Priority::High),
                Some("normal") => {}
                Some("low") => req = req.with_priority(Priority::Low),
                _ => {
                    // malformed fields get a structured answer, not a
                    // silent default the client would mistake for applied
                    error_frame(
                        &mut stream,
                        id,
                        "bad_request",
                        "priority must be \"high\", \"normal\" or \"low\"",
                    )?;
                    continue;
                }
            }
        }
        if let Some(d) = header.get("deadline_us") {
            match d.as_usize() {
                Some(us) => req = req.with_deadline(Duration::from_micros(us as u64)),
                None => {
                    error_frame(
                        &mut stream,
                        id,
                        "bad_request",
                        "deadline_us must be a non-negative integer",
                    )?;
                    continue;
                }
            }
        }
        match engine.infer(req) {
            Ok(resp) => {
                let out_shape: Vec<String> =
                    resp.output.shape.iter().map(|d| d.to_string()).collect();
                let header = format!(
                    "{{\"id\":{id},\"model\":{:?},\"shape\":[{}],\"exec_us\":{},\"queued_us\":{},\"batch_size\":{},\"cached\":{},\"sim_ms\":{:.4},\"sim_mj\":{:.4}}}",
                    resp.model,
                    out_shape.join(","),
                    resp.exec.as_micros(),
                    resp.queued.as_micros(),
                    resp.batch_size,
                    resp.cached,
                    resp.simulated.ms(),
                    resp.simulated.mj()
                );
                write_frame(&mut stream, &header, &resp.output.data)?;
            }
            Err(e) => error_frame(&mut stream, id, e.code(), &e.to_string())?,
        }
    }
}

/// Client-side response.
#[derive(Debug)]
pub struct ClientResponse {
    /// Request id echoed by the server.
    pub id: u64,
    /// Model name the server reports having served (empty for servers
    /// predating the multi-model protocol).
    pub model: String,
    /// The served output tensor.
    pub output: Tensor,
    /// Server-side amortized execution time, microseconds.
    pub exec_us: u64,
    /// Size of the formed batch this request rode in.
    pub batch_size: usize,
    /// True when the server answered from its result cache (false for
    /// servers predating the cache protocol field).
    pub cached: bool,
}

/// Blocking client for the wire protocol (used by tests and the demo CLI).
pub struct Client {
    stream: TcpStream,
    next_id: u64,
}

impl Client {
    /// Connect to a serving endpoint.
    pub fn connect(addr: &std::net::SocketAddr) -> std::io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(Client { stream, next_id: 0 })
    }

    /// Send one tensor against the server's default model.
    pub fn infer(&mut self, input: &Tensor) -> std::io::Result<ClientResponse> {
        self.infer_model(None, input)
    }

    /// Send one tensor against a named model (None = server default) and
    /// await the response. Server-side request errors come back as
    /// `io::Error` with a `code: message` payload and leave the
    /// connection usable for further requests.
    pub fn infer_model(
        &mut self,
        model: Option<&str>,
        input: &Tensor,
    ) -> std::io::Result<ClientResponse> {
        let id = self.next_id;
        self.next_id += 1;
        let dims: Vec<String> = input.shape.iter().map(|d| d.to_string()).collect();
        let header = match model {
            Some(m) => format!("{{\"id\":{id},\"model\":{m:?},\"shape\":[{}]}}", dims.join(",")),
            None => format!("{{\"id\":{id},\"shape\":[{}]}}", dims.join(",")),
        };
        write_frame(&mut self.stream, &header, &input.data)?;

        let mut len4 = [0u8; 4];
        if !read_exact_or_eof(&mut self.stream, &mut len4)? {
            return Err(std::io::Error::other("server closed"));
        }
        let mut hbuf = vec![0u8; u32::from_le_bytes(len4) as usize];
        read_exact_or_eof(&mut self.stream, &mut hbuf)?;
        let header = json::parse(std::str::from_utf8(&hbuf).map_err(std::io::Error::other)?)
            .map_err(std::io::Error::other)?;
        if let Some(err) = header.get("error").and_then(Json::as_str) {
            let code = header.get("code").and_then(Json::as_str).unwrap_or("error");
            return Err(std::io::Error::other(format!("{code}: {err}")));
        }
        let shape: Vec<usize> = header
            .get("shape")
            .and_then(Json::as_arr)
            .map(|a| a.iter().filter_map(Json::as_usize).collect())
            .ok_or_else(|| std::io::Error::other("missing shape"))?;
        let elems: usize = shape.iter().product();
        let mut payload = vec![0u8; elems * 4];
        read_exact_or_eof(&mut self.stream, &mut payload)?;
        let data: Vec<f32> = payload
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(ClientResponse {
            id: header.get("id").and_then(Json::as_usize).unwrap_or(0) as u64,
            model: header
                .get("model")
                .and_then(Json::as_str)
                .unwrap_or_default()
                .to_string(),
            output: Tensor::new(shape, data),
            exec_us: header.get("exec_us").and_then(Json::as_usize).unwrap_or(0) as u64,
            batch_size: header.get("batch_size").and_then(Json::as_usize).unwrap_or(1),
            cached: matches!(header.get("cached"), Some(Json::Bool(true))),
        })
    }
}
