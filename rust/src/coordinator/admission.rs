//! Admission control: bounded queueing with load shedding.
//!
//! An embedded serving node has a hard latency budget; when the request
//! queue grows past the point where a new arrival could still meet it,
//! accepting the request only wastes work. [`AdmissionController`] tracks
//! in-flight depth and a smoothed service-time estimate and sheds load
//! once the projected queueing delay exceeds the deadline — classic
//! controlled-delay admission, shared across every model of the engine.
//! Per-model fairness is layered on top by [`crate::coordinator::ModelSpec::budget()`]:
//! the engine takes a shared slot first, then checks the model's own
//! in-flight cap, and returns the shared slot via
//! [`AdmissionController::cancel`] when the budget rejects (DESIGN.md §6).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Admission decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// Admitted: the caller owns one in-flight slot and MUST release it
    /// via [`AdmissionController::complete`] (or
    /// [`AdmissionController::cancel`] if the request never executes).
    Accept,
    /// Shed.
    Reject {
        /// Projected queueing delay at rejection time (for the client's
        /// retry policy).
        projected_wait: Duration,
    },
}

/// Configuration for the controller.
#[derive(Debug, Clone, Copy)]
pub struct AdmissionConfig {
    /// Deadline a request must still be able to meet when admitted.
    pub deadline: Duration,
    /// Hard cap on in-flight requests regardless of service estimate.
    pub max_in_flight: u64,
    /// EWMA weight for service-time updates (0..1, higher = more reactive).
    pub alpha: f64,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        Self { deadline: Duration::from_secs(5), max_in_flight: 64, alpha: 0.2 }
    }
}

/// Lock-free admission controller (shared by all front-door clones).
pub struct AdmissionController {
    cfg: AdmissionConfig,
    in_flight: AtomicU64,
    /// Smoothed service time in nanoseconds.
    service_ns: AtomicU64,
    /// Requests admitted since startup (net of [`AdmissionController::cancel`]).
    pub admitted: AtomicU64,
    /// Requests shed since startup.
    pub rejected: AtomicU64,
}

impl AdmissionController {
    /// Fresh controller with zeroed counters and no service estimate.
    pub fn new(cfg: AdmissionConfig) -> Self {
        Self {
            cfg,
            in_flight: AtomicU64::new(0),
            service_ns: AtomicU64::new(0),
            admitted: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
        }
    }

    /// Current smoothed service-time estimate.
    pub fn service_estimate(&self) -> Duration {
        Duration::from_nanos(self.service_ns.load(Ordering::Relaxed))
    }

    /// Requests currently admitted and not yet completed.
    pub fn in_flight(&self) -> u64 {
        self.in_flight.load(Ordering::Relaxed)
    }

    /// Projected wait if admitted now: queue depth x service estimate.
    pub fn projected_wait(&self) -> Duration {
        let depth = self.in_flight.load(Ordering::Relaxed);
        let svc = self.service_ns.load(Ordering::Relaxed);
        Duration::from_nanos(depth.saturating_mul(svc))
    }

    /// Try to admit one request. On `Accept` the caller MUST later call
    /// [`AdmissionController::complete`] exactly once.
    pub fn admit(&self) -> Admission {
        let projected = self.projected_wait();
        let depth = self.in_flight.load(Ordering::Relaxed);
        if depth >= self.cfg.max_in_flight || projected > self.cfg.deadline {
            self.rejected.fetch_add(1, Ordering::Relaxed);
            return Admission::Reject { projected_wait: projected };
        }
        self.in_flight.fetch_add(1, Ordering::Relaxed);
        self.admitted.fetch_add(1, Ordering::Relaxed);
        Admission::Accept
    }

    /// Roll back an [`AdmissionController::admit`] acceptance whose
    /// request was rejected downstream (e.g. by a per-model budget)
    /// without ever executing: the in-flight slot is returned and the
    /// admitted counter is undone, while the service-time estimate stays
    /// untouched — a request that never ran carries no service signal.
    pub fn cancel(&self) {
        self.in_flight.fetch_sub(1, Ordering::Relaxed);
        self.admitted.fetch_sub(1, Ordering::Relaxed);
    }

    /// Record a completion with its measured service time.
    pub fn complete(&self, service: Duration) {
        self.in_flight.fetch_sub(1, Ordering::Relaxed);
        let sample = service.as_nanos() as u64;
        // EWMA via CAS loop
        let mut cur = self.service_ns.load(Ordering::Relaxed);
        loop {
            let next = if cur == 0 {
                sample
            } else {
                ((1.0 - self.cfg.alpha) * cur as f64 + self.cfg.alpha * sample as f64) as u64
            };
            match self.service_ns.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(now) => cur = now,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctl(deadline_ms: u64, max: u64) -> AdmissionController {
        AdmissionController::new(AdmissionConfig {
            deadline: Duration::from_millis(deadline_ms),
            max_in_flight: max,
            alpha: 0.5,
        })
    }

    #[test]
    fn admits_when_idle() {
        let c = ctl(100, 4);
        assert_eq!(c.admit(), Admission::Accept);
        assert_eq!(c.in_flight(), 1);
    }

    #[test]
    fn hard_cap_enforced() {
        let c = ctl(10_000, 2);
        assert_eq!(c.admit(), Admission::Accept);
        assert_eq!(c.admit(), Admission::Accept);
        assert!(matches!(c.admit(), Admission::Reject { .. }));
        c.complete(Duration::from_millis(1));
        assert_eq!(c.admit(), Admission::Accept);
    }

    #[test]
    fn sheds_when_projected_wait_exceeds_deadline() {
        let c = ctl(50, 1000);
        // teach it a 30 ms service time
        assert_eq!(c.admit(), Admission::Accept);
        c.complete(Duration::from_millis(30));
        // two in flight -> projected 60 ms > 50 ms deadline for the third
        assert_eq!(c.admit(), Admission::Accept);
        assert_eq!(c.admit(), Admission::Accept);
        match c.admit() {
            Admission::Reject { projected_wait } => {
                assert!(projected_wait >= Duration::from_millis(50));
            }
            other => panic!("expected rejection, got {other:?}"),
        }
    }

    #[test]
    fn ewma_converges() {
        let c = ctl(1000, 10);
        for _ in 0..20 {
            assert_eq!(c.admit(), Admission::Accept);
            c.complete(Duration::from_millis(10));
        }
        let est = c.service_estimate();
        assert!(
            (est.as_millis() as i64 - 10).abs() <= 1,
            "estimate {est:?} should converge to 10ms"
        );
    }

    #[test]
    fn cancel_returns_the_slot() {
        let c = ctl(10_000, 1);
        assert_eq!(c.admit(), Admission::Accept);
        assert!(matches!(c.admit(), Admission::Reject { .. }), "cap of 1 is full");
        c.cancel();
        assert_eq!(c.in_flight(), 0);
        assert_eq!(c.admitted.load(Ordering::Relaxed), 0, "cancel undoes admitted");
        assert_eq!(c.admit(), Admission::Accept, "cancelled slot is reusable");
    }

    #[test]
    fn counters_track() {
        let c = ctl(10_000, 1);
        let _ = c.admit();
        let _ = c.admit();
        assert_eq!(c.admitted.load(Ordering::Relaxed), 1);
        assert_eq!(c.rejected.load(Ordering::Relaxed), 1);
    }
}
