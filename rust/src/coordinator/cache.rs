//! Content-digest result cache: a bounded LRU over (input digest →
//! output tensor).
//!
//! The deterministic runtime makes every artifact a pure function of its
//! input digests (DESIGN.md §Backends), so two requests with the same
//! [`crate::runtime::Tensor::digest`] are guaranteed the same output —
//! serving the stored tensor is **bit-identical** to re-executing. The
//! engine's front door consults the cache after shape validation and
//! before admission control, so a hit costs one hash pass and one map
//! lookup: no admission slot, no budget slot, no batcher round trip, no
//! backend call.
//!
//! Invalidation: entries are only ever displaced by LRU eviction. The
//! stored outputs can never go stale while a model is registered — the
//! (artifact, seed, weights) triple is fixed for the lifetime of its
//! pool — and the cache is owned by the model's [`super::ModelSpec`]
//! registration, so retiring a model drops its cache with it. A model
//! re-registered with different weights (another `seed`) starts from an
//! empty cache.

use crate::runtime::Tensor;
use std::collections::{BTreeMap, HashMap};

/// One cached output with its recency stamp.
struct Slot {
    output: Tensor,
    /// Monotone recency tick; also the key into [`ResultCache::by_age`].
    tick: u64,
}

/// A bounded LRU result cache, keyed on input content digest.
///
/// Recency is tracked with a monotone tick per access: `by_age` maps
/// tick → digest, so the least-recently-used entry is the map's first
/// key and every operation is O(log n). Hit/miss/eviction *counters*
/// live in [`super::MetricsInner`], next to the other serving metrics —
/// this type only reports eviction facts to its caller.
pub struct ResultCache {
    capacity: usize,
    map: HashMap<u64, Slot>,
    by_age: BTreeMap<u64, u64>,
    tick: u64,
}

impl ResultCache {
    /// New cache bounded to `capacity` entries.
    ///
    /// # Panics
    /// Panics when `capacity` is zero — a zero-capacity cache can never
    /// hold an entry; callers model "caching disabled" by not
    /// constructing one (see [`super::ModelSpec::cache()`]).
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "a result cache needs capacity >= 1");
        Self { capacity, map: HashMap::new(), by_age: BTreeMap::new(), tick: 0 }
    }

    /// Entries currently cached.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// True when nothing is cached yet.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// The configured capacity bound.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Look up a digest; a hit clones the stored output and promotes the
    /// entry to most-recently-used.
    pub fn get(&mut self, digest: u64) -> Option<Tensor> {
        self.tick += 1;
        let tick = self.tick;
        let slot = self.map.get_mut(&digest)?;
        self.by_age.remove(&slot.tick);
        slot.tick = tick;
        self.by_age.insert(tick, digest);
        Some(slot.output.clone())
    }

    /// Insert (or refresh) a digest's output; returns `true` when an
    /// older entry was evicted to stay within capacity.
    pub fn insert(&mut self, digest: u64, output: Tensor) -> bool {
        self.tick += 1;
        let tick = self.tick;
        if let Some(slot) = self.map.get_mut(&digest) {
            // refresh: identical digest means identical output on the
            // deterministic backend, but promote recency all the same
            self.by_age.remove(&slot.tick);
            slot.tick = tick;
            slot.output = output;
            self.by_age.insert(tick, digest);
            return false;
        }
        let mut evicted = false;
        if self.map.len() >= self.capacity {
            let oldest = self.by_age.iter().next().map(|(&t, &d)| (t, d));
            if let Some((t, victim)) = oldest {
                self.by_age.remove(&t);
                self.map.remove(&victim);
                evicted = true;
            }
        }
        self.map.insert(digest, Slot { output, tick });
        self.by_age.insert(tick, digest);
        evicted
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(v: f32) -> Tensor {
        Tensor::new(vec![1], vec![v])
    }

    #[test]
    fn hit_returns_stored_output() {
        let mut c = ResultCache::new(2);
        assert!(c.is_empty());
        assert!(c.get(1).is_none());
        assert!(!c.insert(1, t(1.0)));
        assert_eq!(c.len(), 1);
        assert_eq!(c.get(1).expect("hit").data, vec![1.0]);
    }

    #[test]
    fn evicts_least_recently_used() {
        let mut c = ResultCache::new(2);
        c.insert(1, t(1.0));
        c.insert(2, t(2.0));
        assert!(c.insert(3, t(3.0)), "third insert must evict");
        assert_eq!(c.len(), 2);
        assert!(c.get(1).is_none(), "oldest entry must be the victim");
        assert!(c.get(2).is_some());
        assert!(c.get(3).is_some());
    }

    #[test]
    fn get_promotes_recency() {
        let mut c = ResultCache::new(2);
        c.insert(1, t(1.0));
        c.insert(2, t(2.0));
        assert!(c.get(1).is_some(), "promote 1 over 2");
        assert!(c.insert(3, t(3.0)));
        assert!(c.get(1).is_some(), "promoted entry must survive");
        assert!(c.get(2).is_none(), "demoted entry must be the victim");
    }

    #[test]
    fn refresh_does_not_evict() {
        let mut c = ResultCache::new(2);
        c.insert(1, t(1.0));
        c.insert(2, t(2.0));
        assert!(!c.insert(1, t(1.5)), "refreshing a resident digest must not evict");
        assert_eq!(c.len(), 2);
        assert_eq!(c.get(1).expect("hit").data, vec![1.5]);
        assert!(c.get(2).is_some());
    }

    #[test]
    fn capacity_one_keeps_newest() {
        let mut c = ResultCache::new(1);
        assert_eq!(c.capacity(), 1);
        c.insert(1, t(1.0));
        assert!(c.insert(2, t(2.0)));
        assert!(c.get(1).is_none());
        assert_eq!(c.get(2).expect("hit").data, vec![2.0]);
    }

    #[test]
    #[should_panic]
    fn zero_capacity_panics() {
        ResultCache::new(0);
    }
}
