//! Multi-model, batch-first serving engine with live model hot-swap.
//!
//! [`EngineBuilder`] registers one or more [`ModelSpec`]s from the
//! manifest and builds an [`Engine`]: per model, one batcher thread plus
//! an executor worker pool; across models, one shared admission
//! controller and one global request-id space. The batcher orders each
//! formed batch by [`Priority`] and sheds requests whose deadline passed
//! while queued; workers execute a formed batch as **one N-sized backend
//! call** ([`Executable::run_literals_batch`]) — the batch seam that
//! amortizes per-inference overhead, which is the paper's core serving
//! argument.
//!
//! Three serving scenarios layer on top (DESIGN.md §6):
//!
//! - **Result cache** ([`ModelSpec::cache()`]): a per-model bounded LRU
//!   keyed on the input's content digest; a hit short-circuits admission,
//!   budgets and the batcher, and is bit-identical to re-execution.
//! - **Per-model admission budgets** ([`ModelSpec::budget()`]): a cap on a
//!   single model's in-flight requests layered on the shared controller,
//!   so one hot model cannot starve its siblings
//!   ([`RuntimeError::BudgetExhausted`], wire code `budget_exhausted`).
//! - **Hot-swap** ([`Engine::register`] / [`Engine::retire`]): models
//!   join and leave a *live* engine; retiring drains that model's pool
//!   without disturbing in-flight requests on other models.
//!
//! ```no_run
//! use hetero_dnn::coordinator::{EngineBuilder, InferenceRequest, ModelSpec};
//! use hetero_dnn::runtime::Tensor;
//!
//! let handle = EngineBuilder::new()
//!     .model(ModelSpec::net("squeezenet").workers(2).cache(256))
//!     .build()?;
//! let engine = handle.engine.clone();
//! let x = Tensor::randn(&engine.input_shape("squeezenet").unwrap(), 0);
//! let resp = engine.infer(InferenceRequest::new("squeezenet", x))?;
//! assert_eq!(resp.output.shape, vec![1, 1000]);
//!
//! // hot-swap on the live engine: spin up a second model, then drain it
//! engine.register(ModelSpec::net("shufflenetv2_05").workers(2))?;
//! engine.retire("shufflenetv2_05")?;
//! handle.shutdown();
//! # Ok::<(), hetero_dnn::runtime::RuntimeError>(())
//! ```

use super::admission::{self, Admission, AdmissionController};
use super::cache::ResultCache;
use super::step::{self, BatchItem, BatcherEffect, BatcherEvent, BatcherWait, StopCause};
use super::{serving_err, InferenceRequest, InferenceResponse, MetricsInner, NodeHealth, Priority};
use crate::hetero::{self, HeteroExecutable};
use crate::metrics::device::{HeteroMetrics, NodeDeviceMetrics};
use crate::metrics::Cost;
use crate::obs::{EventKind, NodeStats, Recorder, TraceId, TraceSnapshot};
use crate::partition::{Planner, Strategy};
use crate::runtime::arbiter::DeviceSet;
use crate::runtime::{Executable, Literal, Runtime, RuntimeError, Tensor};
use crate::sched;
use std::collections::BTreeMap;
use std::rc::Rc;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

/// Where a registered model's requests execute (see
/// [`method@ModelSpec::placement`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Placement {
    /// The flat executor worker pool: each formed batch is one N-sized
    /// backend call on the least-loaded worker (the default).
    #[default]
    Pool,
    /// The online heterogeneous pipeline ([`crate::hetero`]): the model's
    /// partition plan runs as FPGA → link → GPU device lanes with bounded
    /// inter-stage queues, paying the simulated platform's service times
    /// while staying bit-identical to pool execution.
    Hetero,
}

/// One model registration: serving name, manifest artifact, the graph +
/// strategy used for the simulated per-request platform cost, and the
/// model's serving-scenario knobs (pool size, result cache, admission
/// budget).
///
/// ```
/// use hetero_dnn::coordinator::ModelSpec;
///
/// let spec = ModelSpec::net("squeezenet").workers(2).cache(128).budget(32);
/// assert_eq!(spec.artifact, "squeezenet_224");
/// assert_eq!(spec.cache, 128);
/// assert_eq!(spec.budget, Some(32));
/// ```
#[derive(Debug, Clone)]
pub struct ModelSpec {
    /// Serving name clients address ([`InferenceRequest::model`]).
    pub name: String,
    /// Manifest artifact executed per request (e.g. "squeezenet_224").
    pub artifact: String,
    /// Model graph costed on the simulated platform (one of the three
    /// paper nets: squeezenet | mobilenetv2_05 | shufflenetv2_05).
    pub graph: String,
    /// Partition strategy simulated per request.
    pub strategy: Strategy,
    /// Executor pool size for this model (must be >= 1). Ignored under
    /// [`Placement::Hetero`], where parallelism is the plan's lane count.
    pub workers: usize,
    /// Seed for the synthetic weights (shared by every worker of the pool
    /// so results are worker-independent).
    pub seed: u64,
    /// Result-cache capacity in entries; 0 disables caching for this
    /// model (see [`ModelSpec::cache()`]).
    pub cache: usize,
    /// Per-model admission budget: max in-flight requests for this model,
    /// layered on the shared controller; `None` = no per-model cap (see
    /// [`ModelSpec::budget()`]).
    pub budget: Option<u64>,
    /// Where this model's requests execute: the flat worker pool (the
    /// default) or the online heterogeneous pipeline (see
    /// [`method@ModelSpec::placement`]).
    pub placement: Placement,
}

impl ModelSpec {
    /// Spec with explicit serving name, artifact and cost graph; every
    /// scenario knob at its default (1 worker, seed 0, no cache, no
    /// budget, auto strategy).
    pub fn new(
        name: impl Into<String>,
        artifact: impl Into<String>,
        graph: impl Into<String>,
    ) -> Self {
        Self {
            name: name.into(),
            artifact: artifact.into(),
            graph: graph.into(),
            strategy: Strategy::Auto,
            workers: 1,
            seed: 0,
            cache: 0,
            budget: None,
            placement: Placement::Pool,
        }
    }

    /// Spec for one of the three paper nets under its graph name
    /// (`"squeezenet"` → artifact `squeezenet_224`, graph `squeezenet`).
    pub fn net(graph: &str) -> Self {
        Self::new(graph, format!("{graph}_224"), graph)
    }

    /// Set the partition strategy simulated per request.
    pub fn strategy(mut self, strategy: Strategy) -> Self {
        self.strategy = strategy;
        self
    }

    /// Set the executor pool size (must be >= 1).
    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = workers;
        self
    }

    /// Set the synthetic-weight seed.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Bound this model's result cache to `capacity` entries (0 =
    /// caching disabled, the default). The cache is a per-model LRU
    /// keyed on [`Tensor::digest`]; a hit answers at the front door —
    /// bit-identical to execution — without consuming an admission or
    /// budget slot (see `coordinator::cache`).
    pub fn cache(mut self, capacity: usize) -> Self {
        self.cache = capacity;
        self
    }

    /// Cap this model's in-flight requests at `budget`, layered on the
    /// shared admission controller. Past the cap, requests are rejected
    /// with [`RuntimeError::BudgetExhausted`] (wire code
    /// `budget_exhausted`) instead of queueing — one hot model can no
    /// longer starve its siblings out of the shared pool. `budget(0)`
    /// means **uncapped** (the default), consistent with
    /// [`ModelSpec::cache()`] and the CLI's `--budget 0`.
    pub fn budget(mut self, budget: u64) -> Self {
        self.budget = (budget > 0).then_some(budget);
        self
    }

    /// Serve this model on the **online heterogeneous pipeline** under
    /// `strategy` instead of the flat worker pool: the strategy's
    /// partition plan becomes FPGA → link → GPU device lanes with bounded
    /// inter-stage queues ([`crate::hetero`]), image *i+1* entering the
    /// FPGA lane while image *i* occupies the GPU lane. Outputs stay
    /// bit-identical to pool execution; per-device occupancy counters
    /// surface through [`Engine::device_metrics`]. `Strategy::GpuOnly`
    /// yields the single-lane GPU-only serving baseline the `hotpath`
    /// hybrid-vs-GPU verdict compares against.
    ///
    /// Under this placement [`field@ModelSpec::workers`] is **ignored**: the
    /// parallelism is the plan's lane count (one per device stage), and
    /// [`Engine::workers`] reports that count.
    pub fn placement(mut self, strategy: Strategy) -> Self {
        self.placement = Placement::Hetero;
        self.strategy = strategy;
        self
    }
}

/// Builder for [`Engine`]: shared batching/admission knobs plus the
/// initial model registry (models can also [`Engine::register`] later).
/// `build` validates everything (unknown graph, missing artifact,
/// zero-sized pools) before any request is accepted, via a startup
/// handshake with every worker of every pool.
///
/// ```no_run
/// use hetero_dnn::coordinator::{admission::AdmissionConfig, EngineBuilder, ModelSpec};
/// use std::time::Duration;
///
/// let handle = EngineBuilder::new()
///     .max_batch(8)
///     .max_wait(Duration::from_millis(2))
///     .admission(AdmissionConfig::default())
///     .model(ModelSpec::net("squeezenet").workers(2).cache(256).budget(32))
///     .model(ModelSpec::net("shufflenetv2_05").workers(2))
///     .build()?;
/// handle.shutdown();
/// # Ok::<(), hetero_dnn::runtime::RuntimeError>(())
/// ```
#[derive(Debug, Clone)]
pub struct EngineBuilder {
    models: Vec<ModelSpec>,
    max_batch: usize,
    max_wait: Duration,
    admission: Option<admission::AdmissionConfig>,
    share_devices: bool,
    /// Flight-recorder ring capacity (events per thread); `None` = off.
    tracing: Option<usize>,
}

impl Default for EngineBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl EngineBuilder {
    /// Builder with an empty registry and the default batching window
    /// (`max_batch` 8, `max_wait` 2 ms, no admission control).
    pub fn new() -> Self {
        Self {
            models: Vec::new(),
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            admission: None,
            share_devices: false,
            tracing: None,
        }
    }

    /// Register a model (order defines the default model: the first one).
    pub fn model(mut self, spec: ModelSpec) -> Self {
        self.models.push(spec);
        self
    }

    /// Max requests drained into one batch (must be >= 1).
    pub fn max_batch(mut self, max_batch: usize) -> Self {
        self.max_batch = max_batch;
        self
    }

    /// Max time a batcher waits to fill a batch (zero = dispatch
    /// immediately, batches of 1).
    pub fn max_wait(mut self, max_wait: Duration) -> Self {
        self.max_wait = max_wait;
        self
    }

    /// Shared admission control across every model (None = accept all).
    pub fn admission(mut self, cfg: admission::AdmissionConfig) -> Self {
        self.admission = Some(cfg);
        self
    }

    /// Co-locate every hetero model on one node-scoped
    /// [`DeviceSet`]: the engine owns a single simulated GPU, FPGA and
    /// link, and each hetero pipeline registers as a tenant whose lanes
    /// *acquire* the shared devices per hold (DESIGN.md §14). Without
    /// this flag every pipeline keeps private devices — the
    /// contention-free behaviour existing tests pin. Applies to models
    /// registered later through [`Engine::register`] too.
    pub fn shared_devices(mut self) -> Self {
        self.share_devices = true;
        self
    }

    /// Turn the flight recorder on ([`crate::obs`]): every request gets a
    /// [`TraceId`] at admission and emits span events (admitted,
    /// cache hit/miss, enqueued, batched, dispatched, device
    /// acquire/hold/release, link DMA, reply written) into fixed-capacity
    /// per-thread rings that **never block the hot path**. Drain with
    /// [`Engine::trace_snapshot`] / summarize with [`Engine::node_stats`].
    /// Off by default; recording never feeds the digest fold, so outputs
    /// stay bit-identical either way.
    pub fn tracing(mut self) -> Self {
        self.tracing = Some(crate::obs::recorder::DEFAULT_RING_CAPACITY);
        self
    }

    /// [`EngineBuilder::tracing`] with an explicit per-thread ring
    /// capacity in events (full rings overwrite their oldest event).
    pub fn tracing_capacity(mut self, capacity: usize) -> Self {
        self.tracing = Some(capacity);
        self
    }

    /// Start every model pool and return the engine handle. On any
    /// startup failure the pools already started are shut down cleanly
    /// before the error is returned.
    pub fn build(self) -> Result<EngineHandle, RuntimeError> {
        if self.models.is_empty() {
            return Err(serving_err("engine needs at least one registered model"));
        }
        if self.max_batch == 0 {
            return Err(serving_err("max_batch must be >= 1 (a zero-sized batch can never drain)"));
        }
        for (i, spec) in self.models.iter().enumerate() {
            if spec.name.is_empty() {
                return Err(serving_err("model name must be non-empty"));
            }
            if self.models[..i].iter().any(|s| s.name == spec.name) {
                return Err(serving_err(format!("duplicate model name {:?}", spec.name)));
            }
        }

        let devices = self.share_devices.then(|| Arc::new(DeviceSet::new()));
        let recorder = self.tracing.map(|cap| Arc::new(Recorder::new(cap)));
        let mut registry = Registry { models: BTreeMap::new(), order: Vec::new() };
        let mut started: Vec<Arc<ModelState>> = Vec::with_capacity(self.models.len());
        let mut failure = None;
        for spec in &self.models {
            match start_pool(
                spec,
                self.max_batch,
                self.max_wait,
                devices.as_ref(),
                recorder.as_ref(),
            ) {
                Ok(state) => {
                    let state = Arc::new(state);
                    registry.order.push(spec.name.clone());
                    registry.models.insert(spec.name.clone(), state.clone());
                    started.push(state);
                }
                Err(e) => {
                    failure = Some(e);
                    break;
                }
            }
        }
        if let Some(e) = failure {
            stop_states(&started, StopCause::Shutdown);
            return Err(e);
        }

        let admission = self.admission.map(|a| Arc::new(AdmissionController::new(a)));
        let engine = Engine {
            inner: Arc::new(EngineInner {
                registry: RwLock::new(registry),
                admission,
                next_id: AtomicU64::new(0),
                next_trace: AtomicU64::new(0),
                max_batch: self.max_batch,
                max_wait: self.max_wait,
                devices,
                recorder,
                closed: AtomicBool::new(false),
            }),
        };
        Ok(EngineHandle { engine })
    }
}

/// One completed submission, delivered through the sink passed to
/// [`Engine::submit`]: the caller-chosen tag (e.g. a wire request id)
/// plus the response or the request's terminal error. Completions arrive
/// in **completion order** — whichever request finishes first is
/// delivered first — which is exactly what a pipelined connection's
/// writer thread wants to serialize onto the socket.
#[derive(Debug)]
pub struct Completion {
    /// The tag the caller handed to [`Engine::submit`].
    pub tag: u64,
    /// The served response, or why the request terminally failed.
    pub result: Result<InferenceResponse, RuntimeError>,
    /// The request's flight-recorder identity, when the engine traced it
    /// (`None` with tracing off, and on error completions synthesized
    /// outside the engine).
    pub trace: Option<TraceId>,
}

/// The front-door slot a queued request holds: the model's in-flight
/// count plus (when configured) the shared admission slot. Released
/// exactly once, on drop — so every response path (worker success,
/// batcher shed/drain, dead-worker dispatch failure, queue-closed send
/// error) returns the slot without per-site bookkeeping, and a dropped
/// request can never leak capacity.
struct Slot {
    state: Arc<ModelState>,
    admission: Option<Arc<AdmissionController>>,
    t_admit: Instant,
}

impl Drop for Slot {
    fn drop(&mut self) {
        self.state.in_flight.fetch_sub(1, Ordering::SeqCst);
        if let Some(ctl) = &self.admission {
            ctl.complete(self.t_admit.elapsed());
        }
    }
}

/// Where a request's response goes: back to a blocking [`Engine::infer`]
/// caller, or tagged into a [`Engine::submit`] completion sink.
enum Responder {
    /// Blocking infer: the caller is recv'ing on the paired receiver.
    Sync(mpsc::Sender<Result<InferenceResponse, RuntimeError>>),
    /// Pipelined submit: deliver into the caller's completion sink.
    Tagged { tag: u64, sink: mpsc::Sender<Completion> },
}

/// A request's response channel bundled with its front-door [`Slot`].
/// `send` releases the slot **before** delivering, so a caller that wakes
/// on the response already observes the freed capacity. Dropping an
/// unsent Reply delivers a clean shutdown error instead of nothing —
/// without it, a request that lands in a pool queue in the instant
/// between the batcher's final drain and the receiver drop would vanish
/// silently, and a pipelined wire client would wait on its id forever.
struct Reply {
    slot: Option<Slot>,
    resp: Option<Responder>,
    /// The engine's recorder + this request's trace: whichever thread
    /// delivers the response emits the chain-closing `reply_written`
    /// span event (`None`/no-op with tracing off).
    recorder: Option<Arc<Recorder>>,
    trace: Option<TraceId>,
}

impl Reply {
    fn new(
        slot: Slot,
        resp: Responder,
        recorder: Option<Arc<Recorder>>,
        trace: Option<TraceId>,
    ) -> Self {
        Reply { slot: Some(slot), resp: Some(resp), recorder, trace }
    }

    fn send(mut self, result: Result<InferenceResponse, RuntimeError>) {
        drop(self.slot.take());
        if let Some(resp) = self.resp.take() {
            // emit-then-deliver: the channel send publishes the event to
            // any caller that snapshots the recorder as soon as it wakes
            if let Some(rec) = self.recorder.take() {
                rec.emit(self.trace, EventKind::ReplyWritten);
            }
            resp.deliver(result, self.trace);
        }
        // the Drop below sees every field taken and does nothing
    }

    /// Release the slot and discard the responder **without delivering**:
    /// for failures reported to the caller synchronously, where a drop
    /// delivery would hand the sink a duplicate error for the same tag.
    /// (No `reply_written` either — the caller saw an error, not a reply.)
    fn disarm(&mut self) {
        drop(self.slot.take());
        let _ = self.resp.take();
        let _ = self.recorder.take();
    }
}

impl Drop for Reply {
    fn drop(&mut self) {
        drop(self.slot.take());
        if let Some(resp) = self.resp.take() {
            // emit-then-deliver, as in `send`
            if let Some(rec) = self.recorder.take() {
                rec.emit(self.trace, EventKind::ReplyWritten);
            }
            resp.deliver(
                Err(serving_err("request dropped during engine shutdown or model retire")),
                self.trace,
            );
        }
    }
}

impl Responder {
    fn deliver(self, result: Result<InferenceResponse, RuntimeError>, trace: Option<TraceId>) {
        match self {
            Responder::Sync(tx) => {
                let _ = tx.send(result);
            }
            Responder::Tagged { tag, sink } => {
                let _ = sink.send(Completion { tag, result, trace });
            }
        }
    }
}

/// Per-model serving state behind the front door. Owns the pool's
/// threads, so a model can be retired (drained + joined) independently
/// of every other model and of the engine handle.
struct ModelState {
    tx: mpsc::Sender<Msg>,
    metrics: Arc<Mutex<MetricsInner>>,
    /// Requests this model's batcher has pulled off its queue (accepted
    /// into a batch). Every accepted deadline-free request is guaranteed
    /// a successful response, even across shutdown.
    accepted: Arc<AtomicU64>,
    /// Requests currently inside `infer` for this model (admitted at the
    /// front door, response not yet delivered) — the quantity the
    /// per-model budget caps.
    in_flight: AtomicU64,
    /// Per-model admission budget (see [`ModelSpec::budget()`]).
    budget: Option<u64>,
    /// Per-model result cache (see [`ModelSpec::cache()`]).
    cache: Option<Arc<Mutex<ResultCache>>>,
    input_shape: Vec<usize>,
    input_arg: String,
    artifact: String,
    workers: usize,
    /// How this model executes (pool vs hetero pipeline).
    placement: Placement,
    /// Per-device lane counters; `Some` only for hetero placements.
    device_metrics: Option<Arc<HeteroMetrics>>,
    /// The spec this state was started from — what [`Engine::spec`]
    /// returns, so an adaptive controller can re-register a modified
    /// copy through the hot-swap seam.
    spec: ModelSpec,
    /// The pool's threads; taken exactly once, by retire or shutdown.
    pool: Mutex<Option<PoolThreads>>,
}

/// The live model registry: name → state, plus registration order
/// (`order[0]` is the default model).
struct Registry {
    models: BTreeMap<String, Arc<ModelState>>,
    order: Vec<String>,
}

struct EngineInner {
    registry: RwLock<Registry>,
    admission: Option<Arc<AdmissionController>>,
    next_id: AtomicU64,
    /// Trace-id space, separate from `next_id` so turning tracing on or
    /// off never shifts the request ids clients observe.
    next_trace: AtomicU64,
    /// Batching knobs shared by every pool, including hot-swapped ones.
    max_batch: usize,
    max_wait: Duration,
    /// The node's shared devices ([`EngineBuilder::shared_devices`]);
    /// `None` = every hetero pipeline owns private lanes.
    devices: Option<Arc<DeviceSet>>,
    /// The flight recorder ([`EngineBuilder::tracing`]); `None` = off.
    recorder: Option<Arc<Recorder>>,
    /// Set by [`EngineHandle::shutdown`]; a closed engine answers every
    /// `infer`/`register` with a clean serving error.
    closed: AtomicBool,
}

/// The multi-model front door. Cheap to clone; every clone feeds the same
/// per-model batchers, shares the admission controller, and observes the
/// same live registry (models registered or retired through any clone).
#[derive(Clone)]
pub struct Engine {
    inner: Arc<EngineInner>,
}

impl Engine {
    /// Snapshot one model's state under the registry read lock.
    fn state(&self, model: &str) -> Option<Arc<ModelState>> {
        self.inner.registry.read().unwrap().models.get(model).cloned()
    }

    /// Registered model names, in registration order.
    pub fn models(&self) -> Vec<String> {
        self.inner.registry.read().unwrap().order.clone()
    }

    /// The first registered model — what the wire protocol serves when a
    /// request header names no model. `None` once every model has been
    /// retired.
    pub fn default_model(&self) -> Option<String> {
        self.inner.registry.read().unwrap().order.first().cloned()
    }

    /// Expected input shape of a registered model (from the manifest).
    pub fn input_shape(&self, model: &str) -> Option<Vec<usize>> {
        self.state(model).map(|s| s.input_shape.clone())
    }

    /// Executor pool size of a registered model.
    pub fn workers(&self, model: &str) -> Option<usize> {
        self.state(model).map(|s| s.workers)
    }

    /// Serving metrics of a registered model.
    pub fn metrics(&self, model: &str) -> Option<Arc<Mutex<MetricsInner>>> {
        self.state(model).map(|s| s.metrics.clone())
    }

    /// Requests a model's batcher has accepted into batches so far.
    pub fn accepted(&self, model: &str) -> Option<u64> {
        self.state(model).map(|s| s.accepted.load(Ordering::SeqCst))
    }

    /// Requests currently in flight for a model (admitted, not yet
    /// answered) — the quantity [`ModelSpec::budget()`] caps.
    pub fn in_flight(&self, model: &str) -> Option<u64> {
        self.state(model).map(|s| s.in_flight.load(Ordering::SeqCst))
    }

    /// Where a registered model's requests execute.
    pub fn placement(&self, model: &str) -> Option<Placement> {
        self.state(model).map(|s| s.placement)
    }

    /// The [`ModelSpec`] a registered model was started from — the
    /// observation half of the adaptive-controller seam. A controller
    /// clones this, edits the placement/budget/cache knobs, and applies
    /// the change through [`Engine::retire`] + [`Engine::register`].
    pub fn spec(&self, model: &str) -> Option<ModelSpec> {
        self.state(model).map(|s| s.spec.clone())
    }

    /// Node-level load snapshot, aggregated across every registered
    /// model: total in-flight requests, how many of those are still
    /// queued ahead of a batcher, and the pooled result-cache hit rate.
    /// This is what a cluster router reads through the wire protocol's
    /// HEALTH frame for load-aware replica selection (PROTOCOL.md §5.8).
    pub fn node_health(&self) -> NodeHealth {
        let states: Vec<Arc<ModelState>> =
            self.inner.registry.read().unwrap().models.values().cloned().collect();
        let (mut in_flight, mut queued, mut hits, mut misses) = (0u64, 0u64, 0u64, 0u64);
        for s in &states {
            let inf = s.in_flight.load(Ordering::SeqCst);
            let accepted = s.accepted.load(Ordering::SeqCst);
            in_flight += inf;
            let (answered, h, m) = {
                let met = s.metrics.lock().unwrap();
                (met.served + met.errors + met.shed, met.cache_hits, met.cache_misses)
            };
            // of the admitted requests, those the batcher has neither
            // pulled into a batch nor answered yet are still waiting in
            // line (counters are sampled racily, hence the saturation)
            queued += inf.saturating_sub(accepted.saturating_sub(answered.min(accepted)));
            hits += h;
            misses += m;
        }
        let lookups = hits + misses;
        NodeHealth {
            in_flight,
            queue_depth: queued,
            cache_hit_rate: if lookups == 0 { 0.0 } else { hits as f32 / lookups as f32 },
        }
    }

    /// Per-device lane counters of a registered model — `Some` only for
    /// models served on the heterogeneous pipeline
    /// ([`method@ModelSpec::placement`]): simulated busy time, wall occupancy
    /// and energy per GPU/FPGA/link lane, plus link traffic.
    pub fn device_metrics(&self, model: &str) -> Option<Arc<HeteroMetrics>> {
        self.state(model).and_then(|s| s.device_metrics.clone())
    }

    /// Cross-tenant arbitration counters of the node's shared devices —
    /// `Some` only on an engine built with
    /// [`EngineBuilder::shared_devices`]: per-device grants, queueing
    /// wait, hold time and retire-cancelled waits, aggregated across
    /// every co-located hetero model.
    pub fn node_device_metrics(&self) -> Option<Arc<NodeDeviceMetrics>> {
        self.inner.devices.as_ref().map(|d| d.metrics().clone())
    }

    /// The shared admission controller, when configured.
    pub fn admission(&self) -> Option<&Arc<AdmissionController>> {
        self.inner.admission.as_ref()
    }

    /// The engine's flight recorder, when tracing is on
    /// ([`EngineBuilder::tracing`]).
    pub fn recorder(&self) -> Option<&Arc<Recorder>> {
        self.inner.recorder.as_ref()
    }

    /// Drain the flight recorder into a [`TraceSnapshot`]: every span
    /// event recorded so far (rings are copied, not cleared), the
    /// per-stage latency breakdown, and the measured Chrome-trace export
    /// ([`TraceSnapshot::chrome_trace_json`]). `None` when tracing is
    /// off.
    pub fn trace_snapshot(&self) -> Option<TraceSnapshot> {
        self.inner.recorder.as_ref().map(|r| r.snapshot())
    }

    /// Per-stage latency summary (count/mean/p50/p99 per breakdown
    /// stage) — what the v2 `STATS` frame serves next to HEALTH
    /// (PROTOCOL.md §5.10). All-zero when tracing is off or nothing has
    /// been traced yet.
    pub fn node_stats(&self) -> NodeStats {
        self.trace_snapshot().map(|s| s.breakdown.summary()).unwrap_or_default()
    }

    /// Register a model on the **live** engine: its batcher + worker pool
    /// spin up (with the engine's shared batching knobs) and the model
    /// starts serving as soon as this returns. In-flight requests on
    /// other models are never disturbed. Fails on a duplicate name, an
    /// unknown graph/artifact, a zero-sized pool, or a closed engine.
    pub fn register(&self, spec: ModelSpec) -> Result<(), RuntimeError> {
        if self.inner.closed.load(Ordering::SeqCst) {
            return Err(serving_err("engine is shut down"));
        }
        if spec.name.is_empty() {
            return Err(serving_err("model name must be non-empty"));
        }
        // cheap pre-check before paying for a pool spin-up; the write
        // lock below re-checks, so a racing duplicate still loses cleanly
        if self.state(&spec.name).is_some() {
            return Err(serving_err(format!("duplicate model name {:?}", spec.name)));
        }
        let state = Arc::new(start_pool(
            &spec,
            self.inner.max_batch,
            self.inner.max_wait,
            self.inner.devices.as_ref(),
            self.inner.recorder.as_ref(),
        )?);
        {
            let mut reg = self.inner.registry.write().unwrap();
            // re-check closed UNDER the write lock: shutdown sets the flag
            // before snapshotting the registry under the read lock, so a
            // register that passes this check is guaranteed to be visible
            // to that snapshot — without it, a register racing shutdown
            // could insert a pool whose threads are never joined
            if self.inner.closed.load(Ordering::SeqCst) {
                drop(reg);
                stop_states(&[state], StopCause::Shutdown);
                return Err(serving_err("engine is shut down"));
            }
            if reg.models.contains_key(&spec.name) {
                drop(reg);
                stop_states(&[state], StopCause::Shutdown);
                return Err(serving_err(format!("duplicate model name {:?}", spec.name)));
            }
            reg.order.push(spec.name.clone());
            reg.models.insert(spec.name.clone(), state);
        }
        Ok(())
    }

    /// Retire a model from the **live** engine: it leaves the registry
    /// immediately (new requests get [`RuntimeError::UnknownModel`]),
    /// then its pool drains — the batch already accepted is dispatched
    /// and served, requests still queued are answered with
    /// [`RuntimeError::ModelRetiring`] (wire code `model_retiring`) —
    /// and its threads are joined before this returns. Sibling models
    /// serve uninterrupted throughout.
    pub fn retire(&self, model: &str) -> Result<(), RuntimeError> {
        let state = {
            let mut reg = self.inner.registry.write().unwrap();
            match reg.models.remove(model) {
                Some(s) => {
                    reg.order.retain(|n| n != model);
                    s
                }
                None => {
                    return Err(RuntimeError::UnknownModel {
                        name: model.to_string(),
                        registered: reg.order.clone(),
                    })
                }
            }
        };
        // registry lock released: draining this pool must not block the
        // front door of sibling models
        stop_states(&[state], StopCause::Retire);
        Ok(())
    }

    /// Submit one request and block until its response.
    ///
    /// The front-door pipeline, in order:
    ///
    /// 1. model lookup + input-shape validation — unknown models and
    ///    mismatched shapes fail before the request ever reaches a queue;
    /// 2. **result cache** (when the model has one): a content-digest hit
    ///    answers right here, bit-identical to execution, consuming no
    ///    admission or budget slot;
    /// 3. **shared admission** (when configured): requests that would
    ///    miss the global deadline are shed with [`RuntimeError::Shed`],
    ///    naming the projected wait (the client's retry signal);
    /// 4. **per-model budget** (when the spec set one): past the model's
    ///    in-flight cap the request is rejected with
    ///    [`RuntimeError::BudgetExhausted`] and the shared admission slot
    ///    is returned — siblings keep their capacity.
    ///
    /// A request arriving after shutdown (or while its model is
    /// retiring) gets a clean error instead of hanging.
    pub fn infer(&self, req: InferenceRequest) -> Result<InferenceResponse, RuntimeError> {
        let model = req.model.clone();
        let (tx, rx) = mpsc::channel();
        match self.dispatch(req, Responder::Sync(tx))? {
            Some((hit, _trace)) => Ok(hit),
            None => rx.recv().map_err(|_| {
                self.queue_closed_error(&model, "request dropped during engine shutdown")
            })?,
        }
    }

    /// Submit one request **without blocking for its response** — the
    /// completion-order delivery seam a pipelined connection is built on.
    ///
    /// The synchronous front door (model lookup, shape check, result
    /// cache, shared admission, per-model budget — the same pipeline as
    /// [`Engine::infer`]) runs inline: a front-door rejection returns
    /// `Err` immediately and nothing reaches `sink`. An accepted request
    /// is queued and `Ok(())` returned; its [`Completion`] — tagged with
    /// `tag`, which the engine never interprets — is delivered into
    /// `sink` when it completes, **in completion order** across every
    /// request submitted to the same sink. A cache hit completes before
    /// `submit` returns. Deadline sheds, retires and shutdown drains
    /// arrive as `Err` completions through the sink, never silently.
    ///
    /// ```no_run
    /// use hetero_dnn::coordinator::{Completion, EngineBuilder, InferenceRequest, ModelSpec};
    /// use hetero_dnn::runtime::Tensor;
    /// use std::sync::mpsc;
    ///
    /// let handle = EngineBuilder::new()
    ///     .model(ModelSpec::net("squeezenet").workers(2))
    ///     .build()?;
    /// let engine = handle.engine.clone();
    /// let (sink, completions) = mpsc::channel::<Completion>();
    /// // pipeline 8 requests without waiting on any of them …
    /// for tag in 0..8u64 {
    ///     let x = Tensor::randn(&engine.input_shape("squeezenet").unwrap(), tag);
    ///     engine.submit(InferenceRequest::new("squeezenet", x), tag, &sink)?;
    /// }
    /// // … and drain completions as they finish, matched by tag
    /// for _ in 0..8 {
    ///     let done = completions.recv().unwrap();
    ///     assert!(done.tag < 8);
    /// }
    /// handle.shutdown();
    /// # Ok::<(), hetero_dnn::runtime::RuntimeError>(())
    /// ```
    pub fn submit(
        &self,
        req: InferenceRequest,
        tag: u64,
        sink: &mpsc::Sender<Completion>,
    ) -> Result<(), RuntimeError> {
        let responder = Responder::Tagged { tag, sink: sink.clone() };
        if let Some((hit, trace)) = self.dispatch(req, responder)? {
            let _ = sink.send(Completion { tag, result: Ok(hit), trace });
        }
        Ok(())
    }

    /// The shared front door behind [`Engine::infer`] and
    /// [`Engine::submit`]: validate, consult the cache (`Ok(Some)` = hit,
    /// answered here), take admission + budget slots, and enqueue with
    /// the given responder (`Ok(None)` = the response will be delivered
    /// through it).
    fn dispatch(
        &self,
        req: InferenceRequest,
        resp: Responder,
    ) -> Result<Option<(InferenceResponse, Option<TraceId>)>, RuntimeError> {
        let InferenceRequest { model, input, priority, deadline, trace } = req;
        if self.inner.closed.load(Ordering::SeqCst) {
            return Err(serving_err("engine is shut down"));
        }
        let state = self.state(&model).ok_or_else(|| RuntimeError::UnknownModel {
            name: model.clone(),
            registered: self.models(),
        })?;
        if input.shape != state.input_shape {
            return Err(RuntimeError::ShapeMismatch {
                name: state.artifact.clone(),
                index: 0,
                arg: state.input_arg.clone(),
                expected: state.input_shape.clone(),
                got: input.shape,
            });
        }
        // trace ids live in their own counter so enabling the recorder
        // never shifts the response id sequence (bit-identical outputs)
        let recorder = self.inner.recorder.clone();
        let trace = recorder.as_ref().map(|_| {
            trace.unwrap_or_else(|| TraceId(self.inner.next_trace.fetch_add(1, Ordering::Relaxed)))
        });
        if let Some(rec) = &recorder {
            rec.emit(trace, EventKind::Admitted);
        }

        // result cache: one hash pass; a hit never touches admission,
        // budgets or the batcher (the digest is reused by the worker on a
        // miss, so the input is still hashed exactly once end to end)
        let digest = state.cache.as_ref().map(|_| input.digest());
        if let Some(cache) = &state.cache {
            let digest = digest.expect("digest computed when cache is on");
            if let Some(output) = cache.lock().unwrap().get(digest) {
                state.metrics.lock().unwrap().cache_hits += 1;
                if let Some(rec) = &recorder {
                    rec.emit(trace, EventKind::CacheHit);
                    rec.emit(trace, EventKind::ReplyWritten);
                }
                return Ok(Some((
                    InferenceResponse {
                        id: self.inner.next_id.fetch_add(1, Ordering::Relaxed),
                        model,
                        output,
                        queued: Duration::ZERO,
                        exec: Duration::ZERO,
                        batch_size: 1,
                        batch_index: 0,
                        worker: 0,
                        cached: true,
                        // nothing executed: a hit is free on the platform
                        simulated: Cost::ZERO,
                    },
                    trace,
                )));
            }
        }

        // shared admission across models, then the per-model budget
        // layered on top of it
        if let Some(ctl) = &self.inner.admission {
            match ctl.admit() {
                Admission::Accept => {}
                Admission::Reject { projected_wait } => {
                    return Err(RuntimeError::Shed { projected_wait });
                }
            }
        }
        let in_flight = state.in_flight.fetch_add(1, Ordering::SeqCst);
        if let Some(budget) = state.budget {
            if in_flight >= budget {
                state.in_flight.fetch_sub(1, Ordering::SeqCst);
                // return the shared slot: the budget rejection is this
                // model's problem, not the node's
                if let Some(ctl) = &self.inner.admission {
                    ctl.cancel();
                }
                state.metrics.lock().unwrap().budget_rejected += 1;
                return Err(RuntimeError::BudgetExhausted { model, in_flight, budget });
            }
        }
        // count the miss only once the request is actually bound for the
        // queue: a shed or budget-rejected lookup says nothing about the
        // workload's repeat rate, and polluting the hit rate with it would
        // read as "the cache is useless" under overload
        if state.cache.is_some() {
            state.metrics.lock().unwrap().cache_misses += 1;
            if let Some(rec) = &recorder {
                rec.emit(trace, EventKind::CacheMiss);
            }
        }

        // the slot releases in-flight + shared admission on drop, so the
        // send-failure path below (the request is dropped inside the
        // SendError) returns capacity exactly like a served response does
        let slot = Slot {
            state: state.clone(),
            admission: self.inner.admission.clone(),
            t_admit: Instant::now(),
        };
        let id = self.inner.next_id.fetch_add(1, Ordering::Relaxed);
        if let Some(rec) = &recorder {
            rec.emit(trace, EventKind::Enqueued);
        }
        let request = Request {
            id,
            input,
            digest,
            priority,
            deadline,
            trace,
            enqueued: Instant::now(),
            reply: Reply::new(slot, resp, recorder, trace),
        };
        if let Err(mpsc::SendError(msg)) = state.tx.send(Msg::Req(request)) {
            // the caller receives this failure as the return value, so the
            // bounced request must not ALSO deliver through its responder
            if let Msg::Req(mut req) = msg {
                req.reply.disarm();
            }
            return Err(self.queue_closed_error(&model, "engine is shut down"));
        }
        Ok(None)
    }

    /// A model's queue can only close for two reasons: whole-engine
    /// shutdown (the closed flag is set *before* any pool drains) or a
    /// concurrent [`Engine::retire`] of this model. Report the right one
    /// — wire clients key retry/route logic on the stable codes, and a
    /// routine hot-swap must not read as a server fault.
    fn queue_closed_error(&self, model: &str, shutdown_msg: &str) -> RuntimeError {
        if self.inner.closed.load(Ordering::SeqCst) {
            serving_err(shutdown_msg)
        } else {
            RuntimeError::ModelRetiring { model: model.to_string() }
        }
    }
}

/// Threads of one model pool, joined on retire/shutdown.
struct PoolThreads {
    stop_tx: mpsc::Sender<Msg>,
    batcher: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

/// Handle returned by [`EngineBuilder::build`]; owns the engine's
/// lifetime and joins every pool's threads on shutdown.
pub struct EngineHandle {
    /// The front door; clone it freely across client threads.
    pub engine: Engine,
}

impl EngineHandle {
    /// Graceful shutdown, per pool (the close → drain → join contract):
    ///
    /// 1. the engine is marked closed (later `infer`/`register` calls
    ///    fail cleanly) and a Stop marker is posted to every batcher
    ///    (pools wind down in parallel); each batcher dispatches the
    ///    batch it already accepted,
    /// 2. requests still queued behind the marker are answered with a
    ///    clean shutdown error (never silently dropped),
    /// 3. the worker channels close; each worker finishes every batch
    ///    that was dispatched to it before exiting,
    /// 4. batchers and workers are joined, in that order.
    ///
    /// Clones of the Engine held elsewhere (e.g. by TCP connection
    /// threads) cannot prevent shutdown; their later `infer` calls fail
    /// with a clean error. Pools already drained by [`Engine::retire`]
    /// are skipped.
    pub fn shutdown(self) {
        self.engine.inner.closed.store(true, Ordering::SeqCst);
        let states: Vec<Arc<ModelState>> =
            self.engine.inner.registry.read().unwrap().models.values().cloned().collect();
        stop_states(&states, StopCause::Shutdown);
    }
}

/// Stop + join a set of pools: every Stop marker is posted before any
/// join, so the pools wind down in parallel. Taking `ModelState::pool`
/// makes this idempotent — a pool already drained (retired) is skipped.
fn stop_states(states: &[Arc<ModelState>], cause: StopCause) {
    let mut taken: Vec<PoolThreads> =
        states.iter().filter_map(|s| s.pool.lock().unwrap().take()).collect();
    for p in &taken {
        let _ = p.stop_tx.send(Msg::Stop(cause));
    }
    for p in &mut taken {
        if let Some(b) = p.batcher.take() {
            let _ = b.join();
        }
        for w in p.workers.drain(..) {
            let _ = w.join();
        }
    }
}

// ---------------------------------------------------------------------------
// pool startup

/// One queued request, from the front door to a worker.
struct Request {
    id: u64,
    input: Tensor,
    /// Content digest of `input`, pre-computed at the front door when the
    /// model has a result cache (the worker reuses it — the input is
    /// hashed exactly once end to end — and inserts the output under it).
    digest: Option<u64>,
    priority: Priority,
    deadline: Option<Duration>,
    /// Flight-recorder identity; `Some` iff the engine's recorder is on.
    trace: Option<TraceId>,
    enqueued: Instant,
    /// Response channel + front-door slot; consumed by exactly one
    /// [`Reply::send`] on whichever path answers the request.
    reply: Reply,
}

/// The batcher core sees queued requests through this lens — the same
/// trait the checker's synthetic requests implement, so the production
/// [`step::BatcherCore`] is the one explored under schedules.
impl BatchItem for Request {
    fn priority(&self) -> Priority {
        self.priority
    }
    fn deadline(&self) -> Option<Duration> {
        self.deadline
    }
    fn enqueued(&self) -> Instant {
        self.enqueued
    }
}

/// Batcher mailbox message.
enum Msg {
    Req(Request),
    /// Explicit shutdown: the batcher drains nothing further and exits.
    /// (Relying on sender-drop alone deadlocks when a long-lived clone —
    /// e.g. a blocked TCP connection thread — still holds a sender.)
    Stop(StopCause),
}

type Batch = Vec<Request>;

/// Worker startup handshake payload: (input shape, input arg name).
type ReadyMsg = Result<(Vec<usize>, String), String>;

fn model_graph(name: &str) -> Result<crate::graph::ModelGraph, RuntimeError> {
    crate::graph::models::by_name(name, 224).ok_or_else(|| {
        serving_err(format!(
            "unknown model graph {name} (squeezenet | mobilenetv2_05 | shufflenetv2_05)"
        ))
    })
}

/// Everything a worker thread needs besides its channels: identity,
/// artifact coordinates, and the model-shared metrics + cache handles.
struct WorkerSetup {
    wid: usize,
    model: String,
    artifact: String,
    seed: u64,
    simulated: Cost,
    metrics: Arc<Mutex<MetricsInner>>,
    cache: Option<Arc<Mutex<ResultCache>>>,
}

/// Start one model's serving backend: batcher + worker pool, or batcher +
/// heterogeneous device pipeline, per the spec's [`Placement`].
fn start_pool(
    spec: &ModelSpec,
    max_batch: usize,
    max_wait: Duration,
    devices: Option<&Arc<DeviceSet>>,
    recorder: Option<&Arc<Recorder>>,
) -> Result<ModelState, RuntimeError> {
    match spec.placement {
        Placement::Pool => start_worker_pool(spec, max_batch, max_wait, recorder),
        Placement::Hetero => start_hetero_pipeline(spec, max_batch, max_wait, devices, recorder),
    }
}

/// A request's journey through the hetero pipeline: everything the
/// completion callback needs to answer it.
struct PipeCtx {
    id: u64,
    digest: Option<u64>,
    enqueued: Instant,
    reply: Reply,
}

/// Start one model's batcher + heterogeneous device pipeline
/// ([`Placement::Hetero`]): the spec's partition plan becomes device
/// lanes; the batcher keeps its deadline/priority semantics and feeds the
/// formed batch into the pipeline's bounded intake image by image (a full
/// pipeline back-pressures the batcher, not the front door).
fn start_hetero_pipeline(
    spec: &ModelSpec,
    max_batch: usize,
    max_wait: Duration,
    devices: Option<&Arc<DeviceSet>>,
    recorder: Option<&Arc<Recorder>>,
) -> Result<ModelState, RuntimeError> {
    let graph = model_graph(&spec.graph)?;
    let planner = Planner::default();
    let plan = planner.plan_model(&graph, spec.strategy);
    let simulated = sched::evaluate_model(&plan).total;

    let metrics = Arc::new(Mutex::new(MetricsInner::default()));
    let cache = (spec.cache > 0).then(|| Arc::new(Mutex::new(ResultCache::new(spec.cache))));

    // completion side: lane threads answer requests through this callback
    let on_done: hetero::pipeline::OnDone<PipeCtx> = {
        let metrics = metrics.clone();
        let cache = cache.clone();
        let model = spec.name.clone();
        Arc::new(move |ctx: PipeCtx, result| {
            let PipeCtx { id, digest, enqueued, reply } = ctx;
            match result {
                Ok(done) => {
                    let queued = done.entered.saturating_duration_since(enqueued);
                    let exec = done.entered.elapsed();
                    {
                        let mut m = metrics.lock().unwrap();
                        m.served += 1;
                        m.exec_us_total += exec.as_micros() as u64;
                        m.queue_us_total += queued.as_micros() as u64;
                        m.latencies.record((queued + exec).as_micros() as u64);
                    }
                    let mut outs = done.outputs;
                    let output = outs.remove(0);
                    if let (Some(cache), Some(d)) = (&cache, digest) {
                        if cache.lock().unwrap().insert(d, output.clone()) {
                            metrics.lock().unwrap().cache_evictions += 1;
                        }
                    }
                    reply.send(Ok(InferenceResponse {
                        id,
                        model: model.clone(),
                        output,
                        queued,
                        exec,
                        // the pipeline services images one at a time; the
                        // amortization story lives in lane overlap instead
                        batch_size: 1,
                        batch_index: 0,
                        worker: 0,
                        cached: false,
                        simulated,
                    }));
                }
                Err(e) => {
                    metrics.lock().unwrap().errors += 1;
                    reply.send(Err(e));
                }
            }
        })
    };

    // spawn the device lanes, then derive the executable split they serve
    let rt = Runtime::new_or_simulated();
    let n_inputs = rt.load(&spec.artifact)?.entry.inputs.len();
    if n_inputs == 0 {
        return Err(serving_err(format!("artifact {} has no inputs", spec.artifact)));
    }
    drop(rt);
    let hexe = HeteroExecutable::from_plan(&plan, n_inputs);
    let lanes = hexe.stages().len();
    let sp = hetero::pipeline::spawn_obs(
        &spec.artifact,
        spec.seed,
        &hexe,
        hetero::PipelineConfig::default(),
        devices.cloned(),
        recorder.cloned(),
        on_done,
    )?;

    // the batcher: same deadline/priority front end as a worker pool,
    // dispatching into the pipeline intake instead of worker channels
    let (tx, rx) = mpsc::channel::<Msg>();
    let accepted = Arc::new(AtomicU64::new(0));
    let batcher = {
        let accepted = accepted.clone();
        let metrics = metrics.clone();
        let model = spec.name.clone();
        let recorder = recorder.cloned();
        let sink = DispatchSink::Pipeline { intake: sp.intake };
        std::thread::Builder::new()
            .name(format!("{}-batcher", spec.name))
            .spawn(move || {
                batcher_loop(model, rx, sink, accepted, metrics, max_batch, max_wait, recorder)
            })
            .map_err(|e| serving_err(format!("spawn batcher: {e}")))?
    };

    Ok(ModelState {
        tx: tx.clone(),
        metrics,
        accepted,
        in_flight: AtomicU64::new(0),
        budget: spec.budget,
        cache,
        input_shape: sp.input_shape,
        input_arg: sp.input_arg,
        artifact: spec.artifact.clone(),
        workers: lanes,
        placement: Placement::Hetero,
        device_metrics: Some(sp.metrics),
        spec: spec.clone(),
        pool: Mutex::new(Some(PoolThreads {
            stop_tx: tx,
            batcher: Some(batcher),
            workers: sp.threads,
        })),
    })
}

/// Start one model's batcher + worker pool ([`Placement::Pool`]).
fn start_worker_pool(
    spec: &ModelSpec,
    max_batch: usize,
    max_wait: Duration,
    recorder: Option<&Arc<Recorder>>,
) -> Result<ModelState, RuntimeError> {
    if spec.workers == 0 {
        return Err(serving_err(format!("model {:?}: workers must be >= 1", spec.name)));
    }
    // validate the graph and pre-compute the simulated per-request
    // platform cost once — it is identical for every worker of the pool
    let graph = model_graph(&spec.graph)?;
    let planner = Planner::default();
    let plan = planner.plan_model(&graph, spec.strategy);
    let simulated = sched::evaluate_model(&plan).total;

    let metrics = Arc::new(Mutex::new(MetricsInner::default()));
    let cache = (spec.cache > 0).then(|| Arc::new(Mutex::new(ResultCache::new(spec.cache))));
    let loads: Arc<Vec<AtomicUsize>> =
        Arc::new((0..spec.workers).map(|_| AtomicUsize::new(0)).collect());

    // --- spawn the worker pool
    let (ready_tx, ready_rx) = mpsc::channel::<ReadyMsg>();
    let mut worker_txs: Vec<mpsc::Sender<Batch>> = Vec::with_capacity(spec.workers);
    let mut workers = Vec::with_capacity(spec.workers);
    for wid in 0..spec.workers {
        let (btx, brx) = mpsc::channel::<Batch>();
        worker_txs.push(btx);
        let ready = ready_tx.clone();
        let loads = loads.clone();
        let setup = WorkerSetup {
            wid,
            model: spec.name.clone(),
            artifact: spec.artifact.clone(),
            seed: spec.seed,
            simulated,
            metrics: metrics.clone(),
            cache: cache.clone(),
        };
        let join = std::thread::Builder::new()
            .name(format!("{}-exec-{wid}", spec.name))
            .spawn(move || worker_loop(setup, brx, ready, loads))
            .map_err(|e| serving_err(format!("spawn worker {wid}: {e}")))?;
        workers.push(join);
    }
    drop(ready_tx);

    // --- startup handshake: every worker must come up with the same shape
    let mut shape_arg: Option<(Vec<usize>, String)> = None;
    let mut startup_error: Option<RuntimeError> = None;
    for _ in 0..spec.workers {
        match ready_rx.recv() {
            Ok(Ok(sa)) => {
                if shape_arg.is_none() {
                    shape_arg = Some(sa);
                } else if shape_arg.as_ref() != Some(&sa) {
                    startup_error = Some(serving_err(format!(
                        "worker input shapes diverge: {shape_arg:?} vs {sa:?}"
                    )));
                    break;
                }
            }
            Ok(Err(msg)) => {
                startup_error = Some(serving_err(msg));
                break;
            }
            Err(_) => {
                startup_error = Some(serving_err("executor worker died during startup"));
                break;
            }
        }
    }
    if let Some(e) = startup_error {
        drop(worker_txs); // closes every worker's batch channel
        for j in workers {
            let _ = j.join();
        }
        return Err(e);
    }
    let (input_shape, input_arg) = shape_arg.expect("workers >= 1 checked above");

    // --- spawn the batcher
    let (tx, rx) = mpsc::channel::<Msg>();
    let accepted = Arc::new(AtomicU64::new(0));
    let batcher = {
        let accepted = accepted.clone();
        let metrics = metrics.clone();
        let model = spec.name.clone();
        let recorder = recorder.cloned();
        let sink = DispatchSink::Pool { worker_txs, loads: loads.clone() };
        std::thread::Builder::new()
            .name(format!("{}-batcher", spec.name))
            .spawn(move || {
                batcher_loop(model, rx, sink, accepted, metrics, max_batch, max_wait, recorder)
            })
            .map_err(|e| serving_err(format!("spawn batcher: {e}")))?
    };

    Ok(ModelState {
        tx: tx.clone(),
        metrics,
        accepted,
        in_flight: AtomicU64::new(0),
        budget: spec.budget,
        cache,
        input_shape,
        input_arg,
        artifact: spec.artifact.clone(),
        workers: spec.workers,
        placement: Placement::Pool,
        device_metrics: None,
        spec: spec.clone(),
        pool: Mutex::new(Some(PoolThreads { stop_tx: tx, batcher: Some(batcher), workers })),
    })
}

// ---------------------------------------------------------------------------
// batcher

/// Where a batcher sends its formed batches: a worker pool (least-loaded
/// dispatch, one N-sized backend call per batch) or a hetero pipeline
/// intake (images enter the first device lane in batch order; a full
/// pipeline blocks the batcher — backpressure without dropping).
enum DispatchSink {
    /// The flat executor pool of [`Placement::Pool`].
    Pool { worker_txs: Vec<mpsc::Sender<Batch>>, loads: Arc<Vec<AtomicUsize>> },
    /// The bounded intake of a [`Placement::Hetero`] device pipeline.
    Pipeline { intake: hetero::pipeline::Intake<PipeCtx> },
}

impl DispatchSink {
    fn dispatch(&self, batch: Batch, metrics: &Mutex<MetricsInner>, recorder: Option<&Recorder>) {
        if batch.is_empty() {
            return;
        }
        match self {
            DispatchSink::Pool { worker_txs, loads } => {
                // least-loaded worker; ties break toward the lowest index
                let wid = loads
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, l)| l.load(Ordering::Relaxed))
                    .map(|(i, _)| i)
                    .expect("pool has >= 1 worker");
                if let Some(rec) = recorder {
                    for req in &batch {
                        rec.emit(req.trace, EventKind::DispatchedWorker { worker: wid as u32 });
                    }
                }
                loads[wid].fetch_add(batch.len(), Ordering::Relaxed);
                if let Err(mpsc::SendError(batch)) = worker_txs[wid].send(batch) {
                    // worker died: evict it from selection (a plain undo
                    // would reset its load to the minimum and keep routing
                    // every batch to the corpse) and fail this batch cleanly
                    loads[wid].store(usize::MAX, Ordering::Relaxed);
                    for req in batch {
                        req.reply.send(Err(serving_err("executor worker gone")));
                    }
                }
            }
            DispatchSink::Pipeline { intake } => {
                // the pipeline executes per image: the worker-side batch
                // counter moves here so mean_batch stays meaningful
                metrics.lock().unwrap().batches += 1;
                for req in batch {
                    let Request { id, input, digest, trace, enqueued, reply, .. } = req;
                    if let Some(rec) = recorder {
                        rec.emit(trace, EventKind::DispatchedLane);
                    }
                    // host-side literal conversion (the "upload"): hash
                    // once, reusing the front door's digest when present
                    let lit = match digest {
                        Some(d) => Literal::from_tensor_with_digest(input, d),
                        None => Literal::from_tensor(input),
                    };
                    let ctx = PipeCtx { id, digest, enqueued, reply };
                    if let Err(ctx) = intake.send_traced(ctx, lit, trace) {
                        ctx.reply.send(Err(serving_err("hetero pipeline gone")));
                    }
                }
            }
        }
    }
}

/// The batcher's production shell: pump the mailbox per
/// [`step::BatcherCore::wait`], stamp `Instant::now()` into each event,
/// and execute the core's effects on the real metrics/sink/counters.
/// All batching *policy* (window, expiry shedding, priority order, stop
/// semantics) lives in the core, which the [`crate::check`] explorer
/// drives under synthetic schedules.
#[allow(clippy::too_many_arguments)]
fn batcher_loop(
    model: String,
    rx: mpsc::Receiver<Msg>,
    sink: DispatchSink,
    accepted: Arc<AtomicU64>,
    metrics: Arc<Mutex<MetricsInner>>,
    max_batch: usize,
    max_wait: Duration,
    recorder: Option<Arc<Recorder>>,
) {
    let mut core: step::BatcherCore<Request> = step::BatcherCore::new(max_batch, max_wait);
    // requests in the forming batch, tracked in the shell purely for the
    // flight recorder's `batched{size}` span (the core owns the policy)
    let mut forming: u32 = 0;
    let cause = 'serve: loop {
        let event = match core.wait() {
            BatcherWait::Message => match rx.recv() {
                Ok(Msg::Req(r)) => BatcherEvent::Arrived(r),
                Ok(Msg::Stop(c)) => BatcherEvent::Stop(c),
                Err(_) => BatcherEvent::MailboxClosed,
            },
            BatcherWait::Window(window) => match step::time_left(window, Instant::now()) {
                // the checked guard (not `window - now`): see step::time_left
                None => BatcherEvent::WindowElapsed,
                Some(left) => match rx.recv_timeout(left) {
                    Ok(Msg::Req(r)) => BatcherEvent::Arrived(r),
                    Ok(Msg::Stop(c)) => BatcherEvent::Stop(c),
                    Err(mpsc::RecvTimeoutError::Timeout) => BatcherEvent::WindowElapsed,
                    Err(mpsc::RecvTimeoutError::Disconnected) => BatcherEvent::MailboxClosed,
                },
            },
        };
        let arrived_trace = match &event {
            BatcherEvent::Arrived(r) => r.trace,
            _ => None,
        };
        for effect in core.step(Instant::now(), event) {
            match effect {
                BatcherEffect::Accepted => {
                    accepted.fetch_add(1, Ordering::SeqCst);
                    forming += 1;
                    if let Some(rec) = &recorder {
                        rec.emit(arrived_trace, EventKind::Batched { size: forming });
                    }
                }
                BatcherEffect::Shed { expired, at } => {
                    forming = 0;
                    // count BEFORE responding so a client observing metrics
                    // right after its own shed response never sees a stale
                    // counter
                    metrics.lock().unwrap().shed += expired.len() as u64;
                    for req in expired {
                        let waited = at.saturating_duration_since(req.enqueued);
                        let deadline = req.deadline.expect("only deadlined requests expire");
                        req.reply.send(Err(RuntimeError::DeadlineExceeded { waited, deadline }));
                    }
                }
                BatcherEffect::Dispatch(batch) => {
                    forming = 0;
                    sink.dispatch(batch, &metrics, recorder.as_deref());
                }
                BatcherEffect::Exit(c) => break 'serve c,
            }
        }
    };

    // drain: everything still queued behind the Stop marker gets a definite,
    // clean answer instead of a dangling response channel — which answer
    // depends on WHY the pool is stopping (engine shutdown vs model retire)
    while let Ok(msg) = rx.try_recv() {
        if let Msg::Req(req) = msg {
            let err = match cause {
                StopCause::Shutdown => serving_err("engine shutting down"),
                StopCause::Retire => RuntimeError::ModelRetiring { model: model.clone() },
            };
            req.reply.send(Err(err));
        }
    }
    // worker_txs drop here: the pool channels close, workers drain whatever
    // was dispatched to them and exit
}

// ---------------------------------------------------------------------------
// workers

fn worker_loop(
    setup: WorkerSetup,
    brx: mpsc::Receiver<Batch>,
    ready: mpsc::Sender<ReadyMsg>,
    loads: Arc<Vec<AtomicUsize>>,
) {
    // --- startup: runtime, artifact, weights (identical across workers)
    let rt = Runtime::new_or_simulated();
    let exe = match rt.load(&setup.artifact) {
        Ok(e) => e,
        Err(e) => {
            let _ = ready.send(Err(format!("load {}: {e}", setup.artifact)));
            return;
        }
    };
    if exe.entry.inputs.is_empty() {
        let _ = ready.send(Err(format!("artifact {} has no inputs", setup.artifact)));
        return;
    }
    if exe.entry.outputs.is_empty() {
        // guard here, not at serve time: a zero-output entry would panic
        // on output extraction and silently kill the worker mid-batch
        let _ = ready.send(Err(format!("artifact {} has no outputs", setup.artifact)));
        return;
    }
    // inputs[0] is the image; the rest are weights we synthesize once
    let all_inputs = match rt.synth_inputs(&setup.artifact, setup.seed) {
        Ok(v) => v,
        Err(e) => {
            let _ = ready.send(Err(format!("synth inputs: {e}")));
            return;
        }
    };
    let weights: Vec<Tensor> = all_inputs[1..].to_vec();
    // convert the invariant weights to literals ONCE (§Perf: the
    // per-request weight conversion dominated serving overhead before this)
    let weight_lits = match exe.prepare(&weights, 1) {
        Ok(v) => v,
        Err(e) => {
            let _ = ready.send(Err(format!("prepare weights: {e}")));
            return;
        }
    };
    let input_shape = exe.entry.inputs[0].shape.clone();
    let input_arg = exe.entry.inputs[0].name.clone();
    let _ = ready.send(Ok((input_shape, input_arg)));

    // --- serve dispatched batches until the batcher closes the channel
    // (the thin WorkerCore shell: the interesting interleavings are which
    // batches arrive in what order, which the checker schedules directly)
    let mut core = step::WorkerCore::default();
    loop {
        let event = match brx.recv() {
            Ok(batch) => step::WorkerEvent::Batch(batch),
            Err(_) => step::WorkerEvent::Closed,
        };
        match core.step(event) {
            step::WorkerStep::Execute(batch) => {
                serve_batch(&setup, &exe, &weight_lits, &loads[setup.wid], batch)
            }
            step::WorkerStep::Exit => break,
        }
    }
}

/// Execute one dispatched batch as **one backend call** and answer every
/// request in it; successful outputs are inserted into the model's result
/// cache (when it has one) *before* the response is sent, so a client
/// that re-sends the same input immediately after its response hits.
fn serve_batch(
    setup: &WorkerSetup,
    exe: &Rc<Executable>,
    weight_lits: &[Literal],
    load: &AtomicUsize,
    batch: Batch,
) {
    let bs = batch.len();
    // count the batch before responding so clients observing metrics
    // after their response never see a stale batch count
    setup.metrics.lock().unwrap().batches += 1;

    // take each request apart: the input MOVES into its literal (one hash
    // pass, no data copy — `Literal::from_tensor` takes the buffer by
    // move; with a cache the front door already hashed, so the pre-computed
    // digest is reused and the input is hashed exactly once end to end);
    // weights are the pool's shared pre-converted literals
    let mut meta = Vec::with_capacity(bs);
    let mut input_lits = Vec::with_capacity(bs);
    for req in batch {
        let lit = match req.digest {
            Some(d) => Literal::from_tensor_with_digest(req.input, d),
            None => Literal::from_tensor(req.input),
        };
        input_lits.push(lit);
        meta.push((req.id, req.digest, req.enqueued, req.reply));
    }
    let elements: Vec<Vec<&Literal>> = input_lits
        .iter()
        .map(|lit| {
            let mut refs: Vec<&Literal> = Vec::with_capacity(1 + weight_lits.len());
            refs.push(lit);
            refs.extend(weight_lits.iter());
            refs
        })
        .collect();

    // ONE N-sized backend call for the whole formed batch (the batch
    // seam), behind the dispatch-boundary panic guard: a panicking
    // executor becomes a per-request serving error through the normal
    // batch-failure path below instead of stranding the batch and
    // killing the worker thread (replies still fire, load still drops,
    // shutdown still joins). `fire_injected_panic` is the test seam that
    // simulates the panic, keyed on this pool's model name.
    let t0 = Instant::now();
    let result = step::catch_dispatch_panic(|| {
        step::fire_injected_panic(&setup.model);
        exe.run_literals_batch(&elements)
    });
    let exec = t0.elapsed();
    let per_req_exec = exec / bs as u32;

    match result {
        Ok(outputs) => {
            {
                let mut m = setup.metrics.lock().unwrap();
                m.served += bs as u64;
                m.exec_us_total += exec.as_micros() as u64;
                for (_, _, enqueued, _) in &meta {
                    let queued = t0.saturating_duration_since(*enqueued);
                    m.queue_us_total += queued.as_micros() as u64;
                    // client-observed latency: every response waits for the
                    // FULL batch call, so the histogram records queued +
                    // whole-batch exec (the amortized figure lives in
                    // `InferenceResponse::exec` and `exec_us_total`)
                    m.latencies.record((queued + exec).as_micros() as u64);
                }
            }
            for (bi, ((id, digest, enqueued, reply), mut outs)) in
                meta.into_iter().zip(outputs).enumerate()
            {
                let output = outs.remove(0);
                if let (Some(cache), Some(d)) = (&setup.cache, digest) {
                    if cache.lock().unwrap().insert(d, output.clone()) {
                        setup.metrics.lock().unwrap().cache_evictions += 1;
                    }
                }
                reply.send(Ok(InferenceResponse {
                    id,
                    model: setup.model.clone(),
                    output,
                    queued: t0.saturating_duration_since(enqueued),
                    exec: per_req_exec,
                    batch_size: bs,
                    batch_index: bi,
                    worker: setup.wid,
                    cached: false,
                    simulated: setup.simulated,
                }));
            }
        }
        Err(e) => {
            // the whole batch failed to validate/execute — including a
            // contained executor panic (shape errors cannot happen for
            // requests admitted through the front door, which shape-checks)
            setup.metrics.lock().unwrap().errors += bs as u64;
            let msg = format!("batch execution failed: {e}");
            for (_, _, _, reply) in meta {
                reply.send(Err(serving_err(msg.clone())));
            }
        }
    }
    load.fetch_sub(bs, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_rejects_empty_registry() {
        let err = EngineBuilder::new().build().expect_err("no models must fail");
        assert!(err.to_string().contains("model"), "{err}");
    }

    #[test]
    fn builder_rejects_zero_max_batch() {
        let err = EngineBuilder::new()
            .max_batch(0)
            .model(ModelSpec::new("fire", "fire_full", "squeezenet"))
            .build()
            .expect_err("zero max_batch must fail");
        assert!(err.to_string().contains("max_batch"), "{err}");
    }

    #[test]
    fn builder_rejects_zero_workers() {
        let err = EngineBuilder::new()
            .model(ModelSpec::new("fire", "fire_full", "squeezenet").workers(0))
            .build()
            .expect_err("zero workers must fail");
        assert!(err.to_string().contains("workers"), "{err}");
    }

    #[test]
    fn builder_rejects_duplicate_names() {
        let err = EngineBuilder::new()
            .model(ModelSpec::new("fire", "fire_full", "squeezenet"))
            .model(ModelSpec::new("fire", "fire_full", "squeezenet"))
            .build()
            .expect_err("duplicate names must fail");
        assert!(err.to_string().contains("duplicate"), "{err}");
    }

    #[test]
    fn builder_rejects_unknown_graph_before_spawn() {
        let err = EngineBuilder::new()
            .model(ModelSpec::new("x", "fire_full", "no_such_graph"))
            .build()
            .expect_err("unknown graph must fail");
        assert!(err.to_string().contains("graph"), "{err}");
    }

    #[test]
    fn net_spec_derives_artifact() {
        let s = ModelSpec::net("squeezenet");
        assert_eq!(s.name, "squeezenet");
        assert_eq!(s.artifact, "squeezenet_224");
        assert_eq!(s.graph, "squeezenet");
        assert_eq!(s.cache, 0, "caching defaults to off");
        assert_eq!(s.budget, None, "budget defaults to uncapped");
    }

    #[test]
    fn spec_scenario_knobs() {
        let s = ModelSpec::net("squeezenet").cache(64).budget(4);
        assert_eq!(s.cache, 64);
        assert_eq!(s.budget, Some(4));
        let s = ModelSpec::net("squeezenet").budget(0);
        assert_eq!(s.budget, None, "budget(0) means uncapped, like --budget 0");
    }

    #[test]
    fn register_rejects_duplicates_and_unknown_graphs() {
        let handle = EngineBuilder::new()
            .model(ModelSpec::new("fire", "fire_full", "squeezenet"))
            .build()
            .expect("engine");
        let engine = handle.engine.clone();
        let err = engine
            .register(ModelSpec::new("fire", "fire_full", "squeezenet"))
            .expect_err("duplicate register must fail");
        assert!(err.to_string().contains("duplicate"), "{err}");
        let err = engine
            .register(ModelSpec::new("y", "fire_full", "no_such_graph"))
            .expect_err("unknown graph must fail");
        assert!(err.to_string().contains("graph"), "{err}");
        assert_eq!(engine.models(), vec!["fire"]);
        handle.shutdown();
    }

    #[test]
    fn shared_devices_engine_serves_and_exposes_node_metrics() {
        let handle = EngineBuilder::new()
            .shared_devices()
            .max_wait(Duration::ZERO)
            .model(
                ModelSpec::new("fire-a", "fire_full", "squeezenet").placement(Strategy::Paper),
            )
            .model(
                ModelSpec::new("fire-b", "fire_full", "squeezenet").placement(Strategy::Paper),
            )
            .build()
            .expect("engine");
        let engine = handle.engine.clone();
        let shape = engine.input_shape("fire-a").expect("shape");
        for model in ["fire-a", "fire-b"] {
            let resp = engine
                .infer(InferenceRequest::new(model, Tensor::zeros(&shape)))
                .expect("infer");
            assert_eq!(resp.model, model);
        }
        let node = engine.node_device_metrics().expect("shared engine exposes node metrics");
        assert!(node.gpu.grants() > 0, "gpu grants: {}", node.gpu.grants());
        handle.shutdown();

        // without the flag there is no node-scoped arbiter
        let private = EngineBuilder::new()
            .model(ModelSpec::new("fire", "fire_full", "squeezenet"))
            .build()
            .expect("engine");
        assert!(private.engine.node_device_metrics().is_none());
        private.shutdown();
    }

    #[test]
    fn retire_unknown_model_errors() {
        let handle = EngineBuilder::new()
            .model(ModelSpec::new("fire", "fire_full", "squeezenet"))
            .build()
            .expect("engine");
        let err = handle.engine.retire("nope").expect_err("unknown retire must fail");
        assert!(matches!(err, RuntimeError::UnknownModel { .. }), "{err}");
        handle.shutdown();
    }

    #[test]
    fn closed_engine_rejects_register_and_infer() {
        let handle = EngineBuilder::new()
            .model(ModelSpec::new("fire", "fire_full", "squeezenet"))
            .build()
            .expect("engine");
        let engine = handle.engine.clone();
        handle.shutdown();
        let err = engine
            .register(ModelSpec::new("late", "fire_full", "squeezenet"))
            .expect_err("register after shutdown must fail");
        assert!(err.to_string().contains("shut"), "{err}");
        let err = engine
            .infer(InferenceRequest::new("fire", Tensor::zeros(&[1, 56, 56, 96])))
            .expect_err("infer after shutdown must fail");
        assert!(err.to_string().contains("shut"), "{err}");
    }
}
