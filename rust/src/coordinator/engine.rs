//! Multi-model, batch-first serving engine.
//!
//! [`EngineBuilder`] registers one or more [`ModelSpec`]s from the
//! manifest and builds an [`Engine`]: per model, one batcher thread plus
//! an executor worker pool; across models, one shared admission
//! controller and one global request-id space. The batcher orders each
//! formed batch by [`Priority`] and sheds requests whose deadline passed
//! while queued; workers execute a formed batch as **one N-sized backend
//! call** ([`Executable::run_literals_batch`]) — the batch seam that
//! amortizes per-inference overhead, which is the paper's core serving
//! argument.
//!
//! ```no_run
//! use hetero_dnn::coordinator::{EngineBuilder, InferenceRequest, ModelSpec};
//! use hetero_dnn::runtime::Tensor;
//!
//! let handle = EngineBuilder::new()
//!     .model(ModelSpec::net("squeezenet").workers(2))
//!     .model(ModelSpec::net("shufflenetv2_05").workers(2))
//!     .build()?;
//! let engine = handle.engine.clone();
//! let x = Tensor::randn(engine.input_shape("squeezenet").unwrap(), 0);
//! let resp = engine.infer(InferenceRequest::new("squeezenet", x))?;
//! assert_eq!(resp.output.shape, vec![1, 1000]);
//! handle.shutdown();
//! # Ok::<(), hetero_dnn::runtime::RuntimeError>(())
//! ```

use super::admission::{self, Admission, AdmissionController};
use super::{serving_err, InferenceRequest, InferenceResponse, MetricsInner, Priority};
use crate::metrics::Cost;
use crate::partition::{Planner, Strategy};
use crate::runtime::{Executable, Literal, Runtime, RuntimeError, Tensor};
use crate::sched;
use std::collections::BTreeMap;
use std::rc::Rc;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// One model registration: serving name, manifest artifact, and the graph
/// + strategy used for the simulated per-request platform cost.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    /// Serving name clients address ([`InferenceRequest::model`]).
    pub name: String,
    /// Manifest artifact executed per request (e.g. "squeezenet_224").
    pub artifact: String,
    /// Model graph costed on the simulated platform (one of the three
    /// paper nets: squeezenet | mobilenetv2_05 | shufflenetv2_05).
    pub graph: String,
    /// Partition strategy simulated per request.
    pub strategy: Strategy,
    /// Executor pool size for this model (must be >= 1).
    pub workers: usize,
    /// Seed for the synthetic weights (shared by every worker of the pool
    /// so results are worker-independent).
    pub seed: u64,
}

impl ModelSpec {
    pub fn new(
        name: impl Into<String>,
        artifact: impl Into<String>,
        graph: impl Into<String>,
    ) -> Self {
        Self {
            name: name.into(),
            artifact: artifact.into(),
            graph: graph.into(),
            strategy: Strategy::Auto,
            workers: 1,
            seed: 0,
        }
    }

    /// Spec for one of the three paper nets under its graph name
    /// (`"squeezenet"` → artifact `squeezenet_224`, graph `squeezenet`).
    pub fn net(graph: &str) -> Self {
        Self::new(graph, format!("{graph}_224"), graph)
    }

    pub fn strategy(mut self, strategy: Strategy) -> Self {
        self.strategy = strategy;
        self
    }

    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = workers;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

/// Builder for [`Engine`]: shared batching/admission knobs plus the model
/// registry. `build` validates everything (unknown graph, missing
/// artifact, zero-sized pools) before any request is accepted, via a
/// startup handshake with every worker of every pool.
#[derive(Debug, Clone)]
pub struct EngineBuilder {
    models: Vec<ModelSpec>,
    max_batch: usize,
    max_wait: Duration,
    admission: Option<admission::AdmissionConfig>,
}

impl Default for EngineBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl EngineBuilder {
    pub fn new() -> Self {
        Self {
            models: Vec::new(),
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            admission: None,
        }
    }

    /// Register a model (order defines the default model: the first one).
    pub fn model(mut self, spec: ModelSpec) -> Self {
        self.models.push(spec);
        self
    }

    /// Max requests drained into one batch (must be >= 1).
    pub fn max_batch(mut self, max_batch: usize) -> Self {
        self.max_batch = max_batch;
        self
    }

    /// Max time a batcher waits to fill a batch (zero = dispatch
    /// immediately, batches of 1).
    pub fn max_wait(mut self, max_wait: Duration) -> Self {
        self.max_wait = max_wait;
        self
    }

    /// Shared admission control across every model (None = accept all).
    pub fn admission(mut self, cfg: admission::AdmissionConfig) -> Self {
        self.admission = Some(cfg);
        self
    }

    /// Start every model pool and return the engine handle. On any
    /// startup failure the pools already started are shut down cleanly
    /// before the error is returned.
    pub fn build(self) -> Result<EngineHandle, RuntimeError> {
        if self.models.is_empty() {
            return Err(serving_err("engine needs at least one registered model"));
        }
        if self.max_batch == 0 {
            return Err(serving_err("max_batch must be >= 1 (a zero-sized batch can never drain)"));
        }
        for (i, spec) in self.models.iter().enumerate() {
            if spec.name.is_empty() {
                return Err(serving_err("model name must be non-empty"));
            }
            if self.models[..i].iter().any(|s| s.name == spec.name) {
                return Err(serving_err(format!("duplicate model name {:?}", spec.name)));
            }
        }

        let mut models = BTreeMap::new();
        let mut order = Vec::with_capacity(self.models.len());
        let mut pools: Vec<PoolThreads> = Vec::with_capacity(self.models.len());
        let mut failure = None;
        for spec in &self.models {
            match start_pool(spec, self.max_batch, self.max_wait) {
                Ok((state, threads)) => {
                    order.push(spec.name.clone());
                    models.insert(spec.name.clone(), state);
                    pools.push(threads);
                }
                Err(e) => {
                    failure = Some(e);
                    break;
                }
            }
        }
        if let Some(e) = failure {
            shutdown_pools(&mut pools);
            return Err(e);
        }

        let admission = self.admission.map(|a| Arc::new(AdmissionController::new(a)));
        let engine = Engine {
            inner: Arc::new(EngineInner { models, order, admission, next_id: AtomicU64::new(0) }),
        };
        Ok(EngineHandle { engine, pools })
    }
}

/// Per-model serving state behind the front door.
pub(crate) struct ModelState {
    pub(crate) tx: mpsc::Sender<Msg>,
    pub(crate) metrics: Arc<Mutex<MetricsInner>>,
    /// Requests this model's batcher has pulled off its queue (accepted
    /// into a batch). Every accepted deadline-free request is guaranteed
    /// a successful response, even across shutdown.
    pub(crate) accepted: Arc<AtomicU64>,
    pub(crate) input_shape: Vec<usize>,
    pub(crate) input_arg: String,
    pub(crate) artifact: String,
    pub(crate) workers: usize,
}

pub(crate) struct EngineInner {
    pub(crate) models: BTreeMap<String, ModelState>,
    /// Registration order; `order[0]` is the default model.
    pub(crate) order: Vec<String>,
    pub(crate) admission: Option<Arc<AdmissionController>>,
    pub(crate) next_id: AtomicU64,
}

/// The multi-model front door. Cheap to clone; every clone feeds the same
/// per-model batchers and shares the admission controller.
#[derive(Clone)]
pub struct Engine {
    pub(crate) inner: Arc<EngineInner>,
}

impl Engine {
    /// Registered model names, in registration order.
    pub fn models(&self) -> Vec<&str> {
        self.inner.order.iter().map(String::as_str).collect()
    }

    /// The first registered model — what the wire protocol serves when a
    /// request header names no model.
    pub fn default_model(&self) -> &str {
        &self.inner.order[0]
    }

    /// Expected input shape of a registered model (from the manifest).
    pub fn input_shape(&self, model: &str) -> Option<&[usize]> {
        self.inner.models.get(model).map(|s| s.input_shape.as_slice())
    }

    /// Executor pool size of a registered model.
    pub fn workers(&self, model: &str) -> Option<usize> {
        self.inner.models.get(model).map(|s| s.workers)
    }

    /// Serving metrics of a registered model.
    pub fn metrics(&self, model: &str) -> Option<Arc<Mutex<MetricsInner>>> {
        self.inner.models.get(model).map(|s| s.metrics.clone())
    }

    /// Requests a model's batcher has accepted into batches so far.
    pub fn accepted(&self, model: &str) -> Option<u64> {
        self.inner.models.get(model).map(|s| s.accepted.load(Ordering::SeqCst))
    }

    /// The shared admission controller, when configured.
    pub fn admission(&self) -> Option<&Arc<AdmissionController>> {
        self.inner.admission.as_ref()
    }

    /// Submit one request and block until its response.
    ///
    /// Unknown models and input-shape mismatches fail here, before the
    /// request ever reaches a queue. With admission control configured,
    /// requests that would miss the global deadline are shed immediately
    /// with an error naming the projected wait (the client's retry
    /// signal). A request arriving after shutdown gets a clean
    /// [`RuntimeError::Serving`] instead of hanging.
    pub fn infer(&self, req: InferenceRequest) -> Result<InferenceResponse, RuntimeError> {
        let InferenceRequest { model, input, priority, deadline } = req;
        let state = self.inner.models.get(&model).ok_or_else(|| RuntimeError::UnknownModel {
            name: model.clone(),
            registered: self.inner.order.clone(),
        })?;
        if input.shape != state.input_shape {
            return Err(RuntimeError::ShapeMismatch {
                name: state.artifact.clone(),
                index: 0,
                arg: state.input_arg.clone(),
                expected: state.input_shape.clone(),
                got: input.shape,
            });
        }
        if let Some(ctl) = &self.inner.admission {
            match ctl.admit() {
                Admission::Accept => {}
                Admission::Reject { projected_wait } => {
                    return Err(RuntimeError::Shed { projected_wait });
                }
            }
        }
        let t_admit = Instant::now();
        let (resp_tx, resp_rx) = mpsc::channel();
        let id = self.inner.next_id.fetch_add(1, Ordering::Relaxed);
        let request =
            Request { id, input, priority, deadline, enqueued: Instant::now(), resp: resp_tx };
        let result = (|| {
            state
                .tx
                .send(Msg::Req(request))
                .map_err(|_| serving_err("engine is shut down"))?;
            resp_rx
                .recv()
                .map_err(|_| serving_err("request dropped during engine shutdown"))?
        })();
        if let Some(ctl) = &self.inner.admission {
            ctl.complete(t_admit.elapsed());
        }
        result
    }
}

/// Threads of one model pool, joined on shutdown.
struct PoolThreads {
    stop_tx: mpsc::Sender<Msg>,
    batcher: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

/// Handle that owns every pool's threads and joins them on shutdown.
pub struct EngineHandle {
    pub engine: Engine,
    pools: Vec<PoolThreads>,
}

impl EngineHandle {
    /// Graceful shutdown, per pool (the close → drain → join contract):
    ///
    /// 1. a Stop marker is posted to every batcher (pools wind down in
    ///    parallel); each batcher dispatches the batch it already
    ///    accepted,
    /// 2. requests still queued behind the marker are answered with a
    ///    clean shutdown error (never silently dropped),
    /// 3. the worker channels close; each worker finishes every batch
    ///    that was dispatched to it before exiting,
    /// 4. batchers and workers are joined, in that order.
    ///
    /// Clones of the Engine held elsewhere (e.g. by TCP connection
    /// threads) cannot prevent shutdown; their later `infer` calls fail
    /// with a clean error.
    pub fn shutdown(mut self) {
        shutdown_pools(&mut self.pools);
    }
}

fn shutdown_pools(pools: &mut [PoolThreads]) {
    for p in pools.iter() {
        let _ = p.stop_tx.send(Msg::Stop);
    }
    for p in pools.iter_mut() {
        if let Some(b) = p.batcher.take() {
            let _ = b.join();
        }
        for w in p.workers.drain(..) {
            let _ = w.join();
        }
    }
}

// ---------------------------------------------------------------------------
// pool startup

pub(crate) struct Request {
    pub(crate) id: u64,
    pub(crate) input: Tensor,
    pub(crate) priority: Priority,
    pub(crate) deadline: Option<Duration>,
    pub(crate) enqueued: Instant,
    pub(crate) resp: mpsc::Sender<Result<InferenceResponse, RuntimeError>>,
}

/// Batcher mailbox message.
pub(crate) enum Msg {
    Req(Request),
    /// Explicit shutdown: the batcher drains nothing further and exits.
    /// (Relying on sender-drop alone deadlocks when a long-lived clone —
    /// e.g. a blocked TCP connection thread — still holds a sender.)
    Stop,
}

type Batch = Vec<Request>;

/// Worker startup handshake payload: (input shape, input arg name).
type ReadyMsg = Result<(Vec<usize>, String), String>;

fn model_graph(name: &str) -> Result<crate::graph::ModelGraph, RuntimeError> {
    Ok(match name {
        "squeezenet" => crate::graph::squeezenet(224),
        "mobilenetv2_05" => crate::graph::mobilenetv2_05(224),
        "shufflenetv2_05" => crate::graph::shufflenetv2_05(224),
        other => {
            return Err(serving_err(format!(
                "unknown model graph {other} (squeezenet | mobilenetv2_05 | shufflenetv2_05)"
            )))
        }
    })
}

/// Start one model's batcher + worker pool.
fn start_pool(
    spec: &ModelSpec,
    max_batch: usize,
    max_wait: Duration,
) -> Result<(ModelState, PoolThreads), RuntimeError> {
    if spec.workers == 0 {
        return Err(serving_err(format!("model {:?}: workers must be >= 1", spec.name)));
    }
    // validate the graph and pre-compute the simulated per-request
    // platform cost once — it is identical for every worker of the pool
    let graph = model_graph(&spec.graph)?;
    let planner = Planner::default();
    let plan = planner.plan_model(&graph, spec.strategy);
    let simulated = sched::evaluate_model(&plan).total;

    let metrics = Arc::new(Mutex::new(MetricsInner::default()));
    let loads: Arc<Vec<AtomicUsize>> =
        Arc::new((0..spec.workers).map(|_| AtomicUsize::new(0)).collect());

    // --- spawn the worker pool
    let (ready_tx, ready_rx) = mpsc::channel::<ReadyMsg>();
    let mut worker_txs: Vec<mpsc::Sender<Batch>> = Vec::with_capacity(spec.workers);
    let mut workers = Vec::with_capacity(spec.workers);
    for wid in 0..spec.workers {
        let (btx, brx) = mpsc::channel::<Batch>();
        worker_txs.push(btx);
        let ready = ready_tx.clone();
        let metrics = metrics.clone();
        let loads = loads.clone();
        let model = spec.name.clone();
        let artifact = spec.artifact.clone();
        let seed = spec.seed;
        let join = std::thread::Builder::new()
            .name(format!("{}-exec-{wid}", spec.name))
            .spawn(move || {
                worker_loop(wid, &model, &artifact, seed, simulated, brx, ready, metrics, loads)
            })
            .map_err(|e| serving_err(format!("spawn worker {wid}: {e}")))?;
        workers.push(join);
    }
    drop(ready_tx);

    // --- startup handshake: every worker must come up with the same shape
    let mut shape_arg: Option<(Vec<usize>, String)> = None;
    let mut startup_error: Option<RuntimeError> = None;
    for _ in 0..spec.workers {
        match ready_rx.recv() {
            Ok(Ok(sa)) => {
                if shape_arg.is_none() {
                    shape_arg = Some(sa);
                } else if shape_arg.as_ref() != Some(&sa) {
                    startup_error = Some(serving_err(format!(
                        "worker input shapes diverge: {shape_arg:?} vs {sa:?}"
                    )));
                    break;
                }
            }
            Ok(Err(msg)) => {
                startup_error = Some(serving_err(msg));
                break;
            }
            Err(_) => {
                startup_error = Some(serving_err("executor worker died during startup"));
                break;
            }
        }
    }
    if let Some(e) = startup_error {
        drop(worker_txs); // closes every worker's batch channel
        for j in workers {
            let _ = j.join();
        }
        return Err(e);
    }
    let (input_shape, input_arg) = shape_arg.expect("workers >= 1 checked above");

    // --- spawn the batcher
    let (tx, rx) = mpsc::channel::<Msg>();
    let accepted = Arc::new(AtomicU64::new(0));
    let batcher = {
        let loads = loads.clone();
        let accepted = accepted.clone();
        let metrics = metrics.clone();
        std::thread::Builder::new()
            .name(format!("{}-batcher", spec.name))
            .spawn(move || {
                batcher_loop(rx, worker_txs, loads, accepted, metrics, max_batch, max_wait)
            })
            .map_err(|e| serving_err(format!("spawn batcher: {e}")))?
    };

    let state = ModelState {
        tx: tx.clone(),
        metrics,
        accepted,
        input_shape,
        input_arg,
        artifact: spec.artifact.clone(),
        workers: spec.workers,
    };
    Ok((state, PoolThreads { stop_tx: tx, batcher: Some(batcher), workers }))
}

// ---------------------------------------------------------------------------
// batcher

fn batcher_loop(
    rx: mpsc::Receiver<Msg>,
    worker_txs: Vec<mpsc::Sender<Batch>>,
    loads: Arc<Vec<AtomicUsize>>,
    accepted: Arc<AtomicU64>,
    metrics: Arc<Mutex<MetricsInner>>,
    max_batch: usize,
    max_wait: Duration,
) {
    let dispatch = |batch: Batch| {
        if batch.is_empty() {
            return;
        }
        // least-loaded worker; ties break toward the lowest index
        let wid = loads
            .iter()
            .enumerate()
            .min_by_key(|(_, l)| l.load(Ordering::Relaxed))
            .map(|(i, _)| i)
            .expect("pool has >= 1 worker");
        loads[wid].fetch_add(batch.len(), Ordering::Relaxed);
        if let Err(mpsc::SendError(batch)) = worker_txs[wid].send(batch) {
            // worker died: evict it from selection (a plain undo would
            // reset its load to the minimum and keep routing every batch
            // to the corpse) and fail this batch cleanly
            loads[wid].store(usize::MAX, Ordering::Relaxed);
            for req in batch {
                let _ = req.resp.send(Err(serving_err("executor worker gone")));
            }
        }
    };

    'serve: while let Ok(msg) = rx.recv() {
        let first = match msg {
            Msg::Req(r) => r,
            Msg::Stop => break 'serve,
        };
        accepted.fetch_add(1, Ordering::Relaxed);
        let mut batch = vec![first];
        let mut stopping = false;
        let window = Instant::now() + max_wait;
        while batch.len() < max_batch {
            let now = Instant::now();
            if now >= window {
                break;
            }
            match rx.recv_timeout(window - now) {
                Ok(Msg::Req(r)) => {
                    accepted.fetch_add(1, Ordering::Relaxed);
                    batch.push(r);
                }
                Ok(Msg::Stop) => {
                    // dispatch what we already accepted, then exit
                    stopping = true;
                    break;
                }
                Err(_) => break,
            }
        }
        // shed requests that out-waited their own deadline in the queue:
        // answering them past-deadline would only delay the rest of the
        // batch (per-inference amortization should pay for requests that
        // still matter)
        let now = Instant::now();
        let mut live: Batch = Vec::with_capacity(batch.len());
        let mut expired: Vec<Request> = Vec::new();
        for req in batch {
            match req.deadline {
                Some(d) if now.saturating_duration_since(req.enqueued) > d => expired.push(req),
                _ => live.push(req),
            }
        }
        if !expired.is_empty() {
            // count BEFORE responding so a client observing metrics right
            // after its own shed response never sees a stale counter
            metrics.lock().unwrap().shed += expired.len() as u64;
            for req in expired {
                let waited = now.saturating_duration_since(req.enqueued);
                let deadline = req.deadline.expect("only deadlined requests expire");
                let _ = req
                    .resp
                    .send(Err(RuntimeError::DeadlineExceeded { waited, deadline }));
            }
        }
        // priority order within the formed batch: High first; the sort is
        // stable, so FIFO holds within a priority class
        live.sort_by_key(|r| std::cmp::Reverse(r.priority));
        dispatch(live);
        if stopping {
            break 'serve;
        }
    }

    // drain: everything still queued behind the Stop marker gets a definite,
    // clean answer instead of a dangling response channel
    while let Ok(msg) = rx.try_recv() {
        if let Msg::Req(req) = msg {
            let _ = req.resp.send(Err(serving_err("engine shutting down")));
        }
    }
    // worker_txs drop here: the pool channels close, workers drain whatever
    // was dispatched to them and exit
}

// ---------------------------------------------------------------------------
// workers

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    wid: usize,
    model: &str,
    artifact: &str,
    seed: u64,
    simulated: Cost,
    brx: mpsc::Receiver<Batch>,
    ready: mpsc::Sender<ReadyMsg>,
    metrics: Arc<Mutex<MetricsInner>>,
    loads: Arc<Vec<AtomicUsize>>,
) {
    // --- startup: runtime, artifact, weights (identical across workers)
    let rt = Runtime::new_or_simulated();
    let exe = match rt.load(artifact) {
        Ok(e) => e,
        Err(e) => {
            let _ = ready.send(Err(format!("load {artifact}: {e}")));
            return;
        }
    };
    if exe.entry.inputs.is_empty() {
        let _ = ready.send(Err(format!("artifact {artifact} has no inputs")));
        return;
    }
    if exe.entry.outputs.is_empty() {
        // guard here, not at serve time: a zero-output entry would panic
        // on output extraction and silently kill the worker mid-batch
        let _ = ready.send(Err(format!("artifact {artifact} has no outputs")));
        return;
    }
    // inputs[0] is the image; the rest are weights we synthesize once
    let all_inputs = match rt.synth_inputs(artifact, seed) {
        Ok(v) => v,
        Err(e) => {
            let _ = ready.send(Err(format!("synth inputs: {e}")));
            return;
        }
    };
    let weights: Vec<Tensor> = all_inputs[1..].to_vec();
    // convert the invariant weights to literals ONCE (§Perf: the
    // per-request weight conversion dominated serving overhead before this)
    let weight_lits = match exe.prepare(&weights, 1) {
        Ok(v) => v,
        Err(e) => {
            let _ = ready.send(Err(format!("prepare weights: {e}")));
            return;
        }
    };
    let input_shape = exe.entry.inputs[0].shape.clone();
    let input_arg = exe.entry.inputs[0].name.clone();
    let _ = ready.send(Ok((input_shape, input_arg)));

    // --- serve dispatched batches until the batcher closes the channel
    while let Ok(batch) = brx.recv() {
        serve_batch(wid, model, &exe, &weight_lits, simulated, &metrics, &loads[wid], batch);
    }
}

/// Execute one dispatched batch as **one backend call** and answer every
/// request in it.
#[allow(clippy::too_many_arguments)]
fn serve_batch(
    wid: usize,
    model: &str,
    exe: &Rc<Executable>,
    weight_lits: &[Literal],
    simulated: Cost,
    metrics: &Arc<Mutex<MetricsInner>>,
    load: &AtomicUsize,
    batch: Batch,
) {
    let bs = batch.len();
    // count the batch before responding so clients observing metrics
    // after their response never see a stale batch count
    metrics.lock().unwrap().batches += 1;

    // take each request apart: the input MOVES into its literal (one hash
    // pass, no data copy — `Literal::from_tensor` takes the buffer by
    // move); weights are the pool's shared pre-converted literals
    let mut meta = Vec::with_capacity(bs);
    let mut input_lits = Vec::with_capacity(bs);
    for req in batch {
        input_lits.push(Literal::from_tensor(req.input));
        meta.push((req.id, req.enqueued, req.resp));
    }
    let elements: Vec<Vec<&Literal>> = input_lits
        .iter()
        .map(|lit| {
            let mut refs: Vec<&Literal> = Vec::with_capacity(1 + weight_lits.len());
            refs.push(lit);
            refs.extend(weight_lits.iter());
            refs
        })
        .collect();

    // ONE N-sized backend call for the whole formed batch (the batch seam)
    let t0 = Instant::now();
    let result = exe.run_literals_batch(&elements);
    let exec = t0.elapsed();
    let per_req_exec = exec / bs as u32;

    match result {
        Ok(outputs) => {
            {
                let mut m = metrics.lock().unwrap();
                m.served += bs as u64;
                m.exec_us_total += exec.as_micros() as u64;
                for (_, enqueued, _) in &meta {
                    let queued = t0.saturating_duration_since(*enqueued);
                    m.queue_us_total += queued.as_micros() as u64;
                    // client-observed latency: every response waits for the
                    // FULL batch call, so the histogram records queued +
                    // whole-batch exec (the amortized figure lives in
                    // `InferenceResponse::exec` and `exec_us_total`)
                    m.latencies.record((queued + exec).as_micros() as u64);
                }
            }
            for (bi, ((id, enqueued, resp), mut outs)) in
                meta.into_iter().zip(outputs).enumerate()
            {
                let _ = resp.send(Ok(InferenceResponse {
                    id,
                    model: model.to_string(),
                    output: outs.remove(0),
                    queued: t0.saturating_duration_since(enqueued),
                    exec: per_req_exec,
                    batch_size: bs,
                    batch_index: bi,
                    worker: wid,
                    simulated,
                }));
            }
        }
        Err(e) => {
            // the whole batch failed to validate/execute (cannot happen for
            // requests admitted through the front door, which shape-checks;
            // kept for defense in depth)
            metrics.lock().unwrap().errors += bs as u64;
            let msg = format!("batch execution failed: {e}");
            for (_, _, resp) in meta {
                let _ = resp.send(Err(serving_err(msg.clone())));
            }
        }
    }
    load.fetch_sub(bs, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_rejects_empty_registry() {
        let err = EngineBuilder::new().build().expect_err("no models must fail");
        assert!(err.to_string().contains("model"), "{err}");
    }

    #[test]
    fn builder_rejects_zero_max_batch() {
        let err = EngineBuilder::new()
            .max_batch(0)
            .model(ModelSpec::new("fire", "fire_full", "squeezenet"))
            .build()
            .expect_err("zero max_batch must fail");
        assert!(err.to_string().contains("max_batch"), "{err}");
    }

    #[test]
    fn builder_rejects_zero_workers() {
        let err = EngineBuilder::new()
            .model(ModelSpec::new("fire", "fire_full", "squeezenet").workers(0))
            .build()
            .expect_err("zero workers must fail");
        assert!(err.to_string().contains("workers"), "{err}");
    }

    #[test]
    fn builder_rejects_duplicate_names() {
        let err = EngineBuilder::new()
            .model(ModelSpec::new("fire", "fire_full", "squeezenet"))
            .model(ModelSpec::new("fire", "fire_full", "squeezenet"))
            .build()
            .expect_err("duplicate names must fail");
        assert!(err.to_string().contains("duplicate"), "{err}");
    }

    #[test]
    fn builder_rejects_unknown_graph_before_spawn() {
        let err = EngineBuilder::new()
            .model(ModelSpec::new("x", "fire_full", "no_such_graph"))
            .build()
            .expect_err("unknown graph must fail");
        assert!(err.to_string().contains("graph"), "{err}");
    }

    #[test]
    fn net_spec_derives_artifact() {
        let s = ModelSpec::net("squeezenet");
        assert_eq!(s.name, "squeezenet");
        assert_eq!(s.artifact, "squeezenet_224");
        assert_eq!(s.graph, "squeezenet");
    }
}
