//! PCIe gen2 x4 inter-device link model.
//!
//! The paper's prototype board connects the Jetson TX2 and the Cyclone 10
//! GX through a 4-lane PCIe gen2 interface and states the setup is "highly
//! bounded by the PCIe throughput of 2.5 GBytes/s" (§V-B). Feature maps
//! cross the link in the FPGA's 8-bit fixed-point format (1 byte/element);
//! partial sums returning from a GConv split cross as int16.
//!
//! Model: per-transfer DMA setup latency + bytes/bandwidth, plus a
//! per-byte + per-transfer energy term covering both PHYs and the DMA
//! engines (related work [12,13] motivates the setup-cost term: small
//! transfers are latency-dominated).

pub mod contention;

use crate::metrics::Cost;

/// Link parameters.
#[derive(Debug, Clone, Copy)]
pub struct LinkDevice {
    pub name: &'static str,
    /// Sustained throughput (B/s). Paper: 2.5 GB/s on PCIe gen2 x4.
    pub bandwidth: f64,
    /// Per-transfer DMA setup latency (s): descriptor, doorbell, interrupt.
    pub setup_latency: f64,
    /// Energy per transferred byte (J/B): both PHYs + controllers.
    pub energy_per_byte: f64,
    /// Fixed per-transfer energy (J): DMA engine + driver work.
    pub energy_per_transfer: f64,
}

/// The paper's board-to-board interconnect.
pub const PCIE_GEN2_X4: LinkDevice = LinkDevice {
    name: "PCIe gen2 x4",
    bandwidth: 2.5e9,
    setup_latency: 10.0e-6,
    energy_per_byte: 0.3e-9,
    energy_per_transfer: 2.0e-6,
};

/// Element width of a feature map crossing the link.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    /// DHM native 8-bit fixed point (activations to/from the FPGA).
    Int8,
    /// Partial sums from a channel-split conv (must keep headroom).
    Int16,
    /// Full float (GPU native; used when quantization is disabled).
    F32,
}

impl Precision {
    pub fn bytes(self) -> usize {
        match self {
            Precision::Int8 => 1,
            Precision::Int16 => 2,
            Precision::F32 => 4,
        }
    }
}

/// PCIe transfer cost model.
#[derive(Debug, Clone, Copy)]
pub struct LinkModel {
    pub dev: LinkDevice,
}

impl Default for LinkModel {
    fn default() -> Self {
        Self { dev: PCIE_GEN2_X4 }
    }
}

impl LinkModel {
    pub fn new(dev: LinkDevice) -> Self {
        Self { dev }
    }

    /// Cost of one DMA transfer of `elems` elements at `prec`.
    pub fn transfer(&self, elems: usize, prec: Precision) -> Cost {
        let bytes = (elems * prec.bytes()) as f64;
        let lat = self.dev.setup_latency + bytes / self.dev.bandwidth;
        let energy = self.dev.energy_per_transfer + bytes * self.dev.energy_per_byte;
        Cost::new(lat, energy)
    }

    /// Round trip: payload out, `back_elems` back (sequential transfers).
    pub fn round_trip(&self, out_elems: usize, out_prec: Precision, back_elems: usize, back_prec: Precision) -> Cost {
        self.transfer(out_elems, out_prec).then(self.transfer(back_elems, back_prec))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_transfer_is_setup_dominated() {
        let m = LinkModel::default();
        let c = m.transfer(64, Precision::Int8);
        assert!(c.seconds < 1.1 * m.dev.setup_latency);
        assert!(c.seconds >= m.dev.setup_latency);
    }

    #[test]
    fn large_transfer_is_bandwidth_dominated() {
        let m = LinkModel::default();
        let elems = 25_000_000; // 25 MB int8
        let c = m.transfer(elems, Precision::Int8);
        let bw_time = elems as f64 / m.dev.bandwidth;
        assert!((c.seconds - bw_time) / bw_time < 0.01);
    }

    #[test]
    fn precision_scales_bytes() {
        let m = LinkModel::default();
        let a = m.transfer(1_000_000, Precision::Int8);
        let b = m.transfer(1_000_000, Precision::F32);
        let a_bw = a.seconds - m.dev.setup_latency;
        let b_bw = b.seconds - m.dev.setup_latency;
        assert!((b_bw / a_bw - 4.0).abs() < 1e-6);
    }

    #[test]
    fn paper_bandwidth_envelope() {
        // 56x56x16 int8 feature map ~ 50 KB -> ~20 us + setup at 2.5 GB/s
        let m = LinkModel::default();
        let c = m.transfer(56 * 56 * 16, Precision::Int8);
        assert!(c.seconds > 25e-6 && c.seconds < 40e-6, "{}", c.seconds);
    }

    #[test]
    fn round_trip_adds() {
        let m = LinkModel::default();
        let rt = m.round_trip(1000, Precision::Int8, 500, Precision::Int16);
        let manual = m.transfer(1000, Precision::Int8).then(m.transfer(500, Precision::Int16));
        assert!((rt.seconds - manual.seconds).abs() < 1e-15);
        assert!((rt.joules - manual.joules).abs() < 1e-15);
    }
}
