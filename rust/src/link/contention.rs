//! PCIe contention model: a shared-bus DMA scheduler.
//!
//! The single-image evaluator treats each transfer in isolation; under
//! batch pipelining (sched::pipeline) or multi-tenant serving, transfers
//! from different images contend for the one PCIe link. This module
//! models the link as a FIFO-arbitrated shared bus: requests arrive with
//! timestamps, each occupies the bus for `setup + bytes/bw`, and the
//! scheduler reports per-request completion plus aggregate utilization —
//! the quantity the paper's §V-B caveat ("highly bounded by the PCIe
//! throughput") is about.

use super::{LinkDevice, Precision};

/// One DMA request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DmaRequest {
    /// Arrival time (s).
    pub at: f64,
    pub elems: usize,
    pub prec: Precision,
    /// Opaque tag for the caller (image index, module index, ...).
    pub tag: u64,
}

/// Completion record for one request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DmaCompletion {
    pub tag: u64,
    pub start: f64,
    pub end: f64,
    /// Time spent waiting for the bus before service began.
    pub queued: f64,
}

/// Outcome of scheduling a request trace.
#[derive(Debug, Clone, Default)]
pub struct BusSchedule {
    pub completions: Vec<DmaCompletion>,
    /// Total bus-busy seconds.
    pub busy: f64,
    /// Last completion time.
    pub makespan: f64,
}

impl BusSchedule {
    pub fn utilization(&self) -> f64 {
        if self.makespan > 0.0 { self.busy / self.makespan } else { 0.0 }
    }

    pub fn mean_queueing(&self) -> f64 {
        if self.completions.is_empty() {
            return 0.0;
        }
        self.completions.iter().map(|c| c.queued).sum::<f64>() / self.completions.len() as f64
    }
}

/// FIFO shared-bus scheduler.
#[derive(Debug, Clone, Copy)]
pub struct BusModel {
    pub dev: LinkDevice,
}

impl Default for BusModel {
    fn default() -> Self {
        Self { dev: super::PCIE_GEN2_X4 }
    }
}

impl BusModel {
    /// Service time of one crossing of `bytes` on the wire (setup +
    /// wire time). This is the **live pricing seam**: a node-scoped
    /// [`crate::runtime::device::LinkChannel`] prices every shared DMA
    /// hold with it, so queueing delay behind co-located tenants
    /// emerges from real arbitration waits on top of this service time.
    pub fn service_seconds(&self, bytes: u64) -> f64 {
        self.dev.setup_latency + bytes as f64 / self.dev.bandwidth
    }

    /// Service time of one request (setup + wire time).
    pub fn service_time(&self, r: &DmaRequest) -> f64 {
        self.service_seconds((r.elems * r.prec.bytes()) as u64)
    }

    /// Schedule a trace of requests FIFO by arrival time (ties broken by
    /// tag for determinism). Requests need not be pre-sorted.
    pub fn schedule(&self, requests: &[DmaRequest]) -> BusSchedule {
        let mut reqs: Vec<&DmaRequest> = requests.iter().collect();
        reqs.sort_by(|a, b| a.at.partial_cmp(&b.at).unwrap().then(a.tag.cmp(&b.tag)));
        let mut out = BusSchedule::default();
        let mut free_at = 0.0f64;
        for r in reqs {
            let start = free_at.max(r.at);
            let svc = self.service_time(r);
            let end = start + svc;
            out.completions.push(DmaCompletion { tag: r.tag, start, end, queued: start - r.at });
            out.busy += svc;
            free_at = end;
            out.makespan = out.makespan.max(end);
        }
        out
    }

    /// Max sustainable image rate when each image moves `bytes_per_image`
    /// across the link (the crossover quantity for the sensitivity bench).
    pub fn saturation_rate(&self, transfers_per_image: usize, bytes_per_image: usize) -> f64 {
        let per_image =
            transfers_per_image as f64 * self.dev.setup_latency + bytes_per_image as f64 / self.dev.bandwidth;
        1.0 / per_image
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(at: f64, kb: usize, tag: u64) -> DmaRequest {
        DmaRequest { at, elems: kb * 1024, prec: Precision::Int8, tag }
    }

    #[test]
    fn uncontended_requests_start_on_arrival() {
        let bus = BusModel::default();
        let s = bus.schedule(&[req(0.0, 10, 0), req(1.0, 10, 1)]);
        assert_eq!(s.completions[0].queued, 0.0);
        assert_eq!(s.completions[1].queued, 0.0);
        assert!((s.completions[1].start - 1.0).abs() < 1e-12);
    }

    #[test]
    fn simultaneous_requests_queue_fifo() {
        let bus = BusModel::default();
        let s = bus.schedule(&[req(0.0, 100, 0), req(0.0, 100, 1), req(0.0, 100, 2)]);
        assert_eq!(s.completions.len(), 3);
        assert_eq!(s.completions[0].queued, 0.0);
        assert!(s.completions[1].queued > 0.0);
        assert!(s.completions[2].queued > s.completions[1].queued);
        // bus never overlaps itself
        for w in s.completions.windows(2) {
            assert!(w[1].start >= w[0].end - 1e-15);
        }
    }

    #[test]
    fn busy_equals_sum_of_service_times() {
        let bus = BusModel::default();
        let reqs = [req(0.0, 5, 0), req(0.001, 50, 1), req(0.002, 500, 2)];
        let s = bus.schedule(&reqs);
        let want: f64 = reqs.iter().map(|r| bus.service_time(r)).sum();
        assert!((s.busy - want).abs() < 1e-15);
    }

    #[test]
    fn utilization_bounded() {
        let bus = BusModel::default();
        let reqs: Vec<_> = (0..50).map(|i| req(i as f64 * 1e-5, 100, i)).collect();
        let s = bus.schedule(&reqs);
        assert!(s.utilization() > 0.5 && s.utilization() <= 1.0, "{}", s.utilization());
    }

    #[test]
    fn out_of_order_arrivals_sorted() {
        let bus = BusModel::default();
        let s = bus.schedule(&[req(2.0, 1, 7), req(0.0, 1, 3)]);
        assert_eq!(s.completions[0].tag, 3);
        assert_eq!(s.completions[1].tag, 7);
    }

    #[test]
    fn service_seconds_is_the_per_request_formula() {
        let bus = BusModel::default();
        let r = req(0.0, 64, 0);
        let want = bus.dev.setup_latency + (64 * 1024) as f64 / bus.dev.bandwidth;
        assert!((bus.service_seconds(64 * 1024) - want).abs() < 1e-15);
        assert!((bus.service_time(&r) - want).abs() < 1e-15);
    }

    #[test]
    fn saturation_rate_matches_bandwidth() {
        let bus = BusModel::default();
        // one big transfer per image: rate ~ bw / bytes
        let rate = bus.saturation_rate(1, 25_000_000);
        let pure_bw = bus.dev.bandwidth / 25_000_000.0;
        assert!(rate < pure_bw && rate > pure_bw * 0.99);
    }
}
