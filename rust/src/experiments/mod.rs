//! Experiment harness: regenerates every table and figure of the paper.
//!
//! Each function returns a [`Report`] whose rows mirror what the paper
//! plots/prints (DESIGN.md §5 experiment index):
//!
//! - [`fig1`] — Fig 1 (a) latency and (b) energy: FPGA-DHM vs GPU across
//!   convolution sizes on a 224x224x3 input.
//! - [`fig4`] — Fig 4 (a/b/c): per-module average energy/latency for the
//!   GPU-only vs heterogeneous platform, per model, across IFM scales.
//! - [`table1`] — Table I: module-level energy gain & latency speedup
//!   (ours) next to the related-work rows the paper quotes.
//!
//! The bench targets (`cargo bench`) and the CLI both call these.

use crate::graph::{models, Activation, Layer, ModuleKind, OpKind, TensorShape};
use crate::metrics::{Cost, Gain, Report};
use crate::partition::{Planner, Strategy};
use crate::sched;

/// Fig 1 sweep: conv on 224x224x3, kernel sizes x filter counts.
pub const FIG1_KERNELS: [usize; 3] = [1, 3, 5];
pub const FIG1_FILTERS: [usize; 6] = [2, 4, 8, 16, 32, 64];

/// One Fig 1 data point.
#[derive(Debug, Clone)]
pub struct Fig1Point {
    pub k: usize,
    pub n: usize,
    pub gpu: Cost,
    /// None when the DHM mapping overflows the device (the paper's cliff).
    pub fpga: Option<Cost>,
}

/// Raw Fig 1 series (both subfigures derive from it).
pub fn fig1_points(planner: &Planner) -> Vec<Fig1Point> {
    let mut out = Vec::new();
    for &k in &FIG1_KERNELS {
        for &n in &FIG1_FILTERS {
            let l = Layer::new(
                OpKind::Conv { k, stride: 1, pad: k / 2, cout: n, act: Activation::Relu },
                TensorShape::new(224, 224, 3),
            );
            let gpu = planner.gpu.cost(&l);
            let fpga = planner.dhm.cost(&l).ok();
            out.push(Fig1Point { k, n, gpu, fpga });
        }
    }
    out
}

/// Fig 1 as a printable report (latency + energy columns together).
pub fn fig1(planner: &Planner) -> Report {
    let mut r = Report::new(
        "Fig 1 — Conv 224x224x3: FPGA (DHM, Cyclone10GX) vs GPU (TX2)",
        &[
            "kernel", "filters",
            "fpga_lat_ms", "gpu_lat_ms",
            "fpga_mj", "gpu_mj",
            "lat_ratio(gpu/fpga)", "energy_ratio(gpu/fpga)",
        ],
    );
    for p in fig1_points(planner) {
        let (fl, fe, lr, er) = match p.fpga {
            Some(f) => (
                format!("{:.4}", f.ms()),
                format!("{:.4}", f.mj()),
                format!("{:.1}", p.gpu.seconds / f.seconds),
                format!("{:.1}", p.gpu.joules / f.joules),
            ),
            None => ("OVERFLOW".into(), "OVERFLOW".into(), "-".into(), "-".into()),
        };
        r.row(vec![
            format!("{0}x{0}", p.k),
            p.n.to_string(),
            fl,
            format!("{:.4}", p.gpu.ms()),
            fe,
            format!("{:.4}", p.gpu.mj()),
            lr,
            er,
        ]);
    }
    r
}

/// Per-module Fig 4 scatter point.
#[derive(Debug, Clone)]
pub struct Fig4Point {
    pub module: String,
    pub kind: ModuleKind,
    pub gpu: Cost,
    pub hetero: Cost,
    pub strategy: Strategy,
}

/// Fig 4 data for one model at one input resolution.
///
/// The heterogeneous side follows the paper's methodology
/// ([`Planner::plan_model_paper`]): each module is measured with the
/// fabric to itself, exactly like the paper's §V-A per-task measurements.
/// The deployable shared-fabric variant is covered by the resident-set
/// ablation (see benches).
pub fn fig4_points(planner: &Planner, model: &str, res: usize) -> Vec<Fig4Point> {
    let g = match model {
        "squeezenet" => models::squeezenet(res),
        "mobilenetv2_05" => models::mobilenetv2_05(res),
        "shufflenetv2_05" => models::shufflenetv2_05(res),
        other => panic!("unknown model {other}"),
    };
    let het_plan = planner.plan_model_paper(&g);
    let mut out = Vec::new();
    for (m, hp) in g.modules.iter().zip(&het_plan.modules) {
        let base = sched::evaluate_with(&planner.plan_gpu_only(m), sched::IdleParams::paper());
        let het = sched::evaluate_with(hp, sched::IdleParams::paper());
        out.push(Fig4Point {
            module: m.name.clone(),
            kind: m.kind,
            gpu: base.total,
            hetero: het.total,
            strategy: hp.strategy,
        });
    }
    out
}

/// The IFM scales the paper samples ("224x224, 112x112 and so on down to
/// 4x4" — we sweep the resolutions that keep every module's spatial dims
/// >= 1 for the three nets).
pub const FIG4_RESOLUTIONS: [usize; 4] = [224, 160, 112, 96];

/// Fig 4 report for one model: per-module rows + the summary row the
/// paper's text quotes (average energy / latency over partitionable
/// modules, all resolutions).
pub fn fig4(planner: &Planner, model: &str) -> Report {
    let mut r = Report::new(
        &format!("Fig 4 — {model}: GPU-only vs FPGA-GPU heterogeneous"),
        &[
            "res", "module", "strategy",
            "gpu_lat_ms", "het_lat_ms",
            "gpu_mj", "het_mj",
        ],
    );
    let mut tot_gpu = Cost::ZERO;
    let mut tot_het = Cost::ZERO;
    for &res in &FIG4_RESOLUTIONS {
        for p in fig4_points(planner, model, res) {
            // only partitionable modules make the scatter (paper plots layers)
            if matches!(p.kind, ModuleKind::Plain | ModuleKind::Pool) {
                continue;
            }
            tot_gpu = tot_gpu.then(p.gpu);
            tot_het = tot_het.then(p.hetero);
            r.row(vec![
                res.to_string(),
                p.module,
                p.strategy.to_string(),
                format!("{:.4}", p.gpu.ms()),
                format!("{:.4}", p.hetero.ms()),
                format!("{:.4}", p.gpu.mj()),
                format!("{:.4}", p.hetero.mj()),
            ]);
        }
    }
    let gain = Gain::of(tot_gpu, tot_het);
    r.row(vec![
        "ALL".into(),
        "TOTAL".into(),
        "paper".into(),
        format!("{:.3}", tot_gpu.ms()),
        format!("{:.3}", tot_het.ms()),
        format!("{:.3}", tot_gpu.mj()),
        format!("{:.3}", tot_het.mj()),
    ]);
    r.row(vec![
        "ALL".into(),
        "GAIN".into(),
        format!("E {:.0}% / L {:.0}%", gain.energy_reduction_pct(), gain.latency_reduction_pct()),
        format!("{:.2}x", gain.latency_speedup),
        "-".into(),
        format!("{:.2}x", gain.energy_gain),
        "-".into(),
    ]);
    r
}

/// Table I module benchmarks: (display name, model, module prefix).
pub const TABLE1_MODULES: [(&str, &str, &str); 3] = [
    ("SqueezeNet's Fire", "squeezenet", "fire"),
    ("MobileNet's v2 Bottleneck", "mobilenetv2_05", "bn"),
    ("ShuffleNet's v2 Stage", "shufflenetv2_05", "s"),
];

/// Our Table I gains: averaged over the *partitioned* instances of the
/// module family at 224 (the paper evaluates the module where its
/// partitioning applies; instances that fall back to the GPU because the
/// fabric cannot host them are the paper's own §III-A resource-cliff
/// caveat, reported separately by the coverage column of the bench).
pub fn table1_gains(planner: &Planner) -> Vec<(&'static str, Gain)> {
    TABLE1_MODULES
        .iter()
        .map(|&(label, model, prefix)| {
            let pts = fig4_points(planner, model, 224);
            let mut gpu = Cost::ZERO;
            let mut het = Cost::ZERO;
            for p in pts
                .iter()
                .filter(|p| p.module.starts_with(prefix) && p.strategy != Strategy::GpuOnly)
            {
                gpu = gpu.then(p.gpu);
                het = het.then(p.hetero);
            }
            if het.seconds == 0.0 {
                // nothing partitioned: gain 1.0 by definition
                return (label, Gain { energy_gain: 1.0, latency_speedup: 1.0 });
            }
            (label, Gain::of(gpu, het))
        })
        .collect()
}

/// Fraction of a module family's instances that actually received a
/// heterogeneous partition (the resource-cliff coverage the paper's
/// §III-A caveat implies).
pub fn table1_coverage(planner: &Planner) -> Vec<(&'static str, f64)> {
    TABLE1_MODULES
        .iter()
        .map(|&(label, model, prefix)| {
            let pts = fig4_points(planner, model, 224);
            let family: Vec<_> = pts.iter().filter(|p| p.module.starts_with(prefix)).collect();
            let part = family.iter().filter(|p| p.strategy != Strategy::GpuOnly).count();
            (label, part as f64 / family.len().max(1) as f64)
        })
        .collect()
}

/// Related-work rows the paper quotes in Table I (for context, verbatim).
pub const TABLE1_RELATED: [(&str, &str, &str, &str); 4] = [
    ("Qasaimeh et al. [8]", "TX2 + ZCU102", "Harris corners", "3.94x / -"),
    ("Hosseinabady et al. [9]", "TX1 + Zynq US+", "Histogram", "1.45-2.29x / 1.18-1.79x"),
    ("Tu et al. [10]", "TX2 + Artix 7", "CNN (N=32)", "1.94x / 1.19x"),
    ("Paper (this work)", "TX2 + Cyclone10GX", "Fire/Bottleneck/Stage", "1.34-1.55x / 1.01-1.35x"),
];

/// Table I as a report: our measured rows + the quoted context rows.
pub fn table1(planner: &Planner) -> Report {
    let mut r = Report::new(
        "Table I — energy gain & latency speedup, module level",
        &["work", "platform", "workload", "energy_gain", "latency_speedup"],
    );
    for (work, platform, algo, gains) in TABLE1_RELATED {
        let mut it = gains.split(" / ");
        r.row(vec![
            work.into(),
            platform.into(),
            algo.into(),
            it.next().unwrap_or("-").into(),
            it.next().unwrap_or("-").into(),
        ]);
    }
    for (label, gain) in table1_gains(planner) {
        r.row(vec![
            "THIS REPRO".into(),
            "TX2-model + C10GX-model".into(),
            label.into(),
            format!("{:.2}x", gain.energy_gain),
            format!("{:.2}x", gain.latency_speedup),
        ]);
    }
    r
}

/// §V-B headline summary: per-model energy/latency reduction percentages.
pub fn headline_summary(planner: &Planner) -> Report {
    let mut r = Report::new(
        "Headline — full-model hetero vs GPU-only (paper §V-B bands)",
        &["model", "gpu_lat_ms", "het_lat_ms", "gpu_mj", "het_mj", "energy_red_%", "latency_red_%"],
    );
    for g in models::all_models() {
        let base = sched::evaluate_model_with(&planner.plan_model(&g, Strategy::GpuOnly), sched::IdleParams::paper()).total;
        let het_plan = planner.plan_model_paper(&g);
        let het = sched::evaluate_model_with(&het_plan, sched::IdleParams::paper()).total;
        let gain = Gain::of(base, het);
        r.row(vec![
            g.name.clone(),
            format!("{:.3}", base.ms()),
            format!("{:.3}", het.ms()),
            format!("{:.3}", base.mj()),
            format!("{:.3}", het.mj()),
            format!("{:.1}", gain.energy_reduction_pct()),
            format!("{:.1}", gain.latency_reduction_pct()),
        ]);
    }
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    fn planner() -> Planner {
        Planner::default()
    }

    #[test]
    fn fig1_has_full_grid() {
        let pts = fig1_points(&planner());
        assert_eq!(pts.len(), FIG1_KERNELS.len() * FIG1_FILTERS.len());
    }

    #[test]
    fn fig1_fpga_wins_when_it_fits() {
        // the paper's §III-B observation: FPGA beats GPU in BOTH metrics
        for p in fig1_points(&planner()) {
            if let Some(f) = p.fpga {
                assert!(f.seconds < p.gpu.seconds, "latency k{} n{}", p.k, p.n);
                assert!(f.joules < p.gpu.joules, "energy k{} n{}", p.k, p.n);
            }
        }
    }

    #[test]
    fn fig1_energy_orders_of_magnitude() {
        // "outperforms the GPU with orders of magnitude" (energy)
        let pts = fig1_points(&planner());
        let big = pts.iter().filter(|p| p.n >= 16).filter_map(|p| {
            p.fpga.map(|f| p.gpu.joules / f.joules)
        });
        for ratio in big {
            assert!(ratio > 10.0, "energy ratio {ratio}");
        }
    }

    #[test]
    fn fig1_cliff_at_5x5_64() {
        let pts = fig1_points(&planner());
        let p = pts.iter().find(|p| p.k == 5 && p.n == 64).unwrap();
        assert!(p.fpga.is_some(), "5x5x64 must fit (paper's max)");
        // and nothing overflows below the cliff
        for p in &pts {
            assert!(p.fpga.is_some(), "k{} n{} should fit", p.k, p.n);
        }
    }

    #[test]
    fn fig4_reports_nonempty() {
        let p = planner();
        for model in ["squeezenet", "mobilenetv2_05", "shufflenetv2_05"] {
            let r = fig4(&p, model);
            assert!(r.rows.len() > 10, "{model} rows {}", r.rows.len());
        }
    }

    #[test]
    fn fig4_hetero_saves_energy_per_model() {
        let p = planner();
        for model in ["squeezenet", "mobilenetv2_05", "shufflenetv2_05"] {
            let pts = fig4_points(&p, model, 224);
            let gpu: f64 = pts.iter().map(|x| x.gpu.joules).sum();
            let het: f64 = pts.iter().map(|x| x.hetero.joules).sum();
            assert!(het < gpu, "{model}: {het} !< {gpu}");
        }
    }

    #[test]
    fn table1_gains_positive() {
        for (label, gain) in table1_gains(&planner()) {
            assert!(gain.energy_gain > 1.0, "{label}: energy {}", gain.energy_gain);
            assert!(gain.latency_speedup > 0.95, "{label}: latency {}", gain.latency_speedup);
        }
    }

    #[test]
    fn headline_bands_shape() {
        // paper abstract: 12-30% energy reduction across the three nets;
        // we accept the shape (everything positive, within sane bounds)
        let r = headline_summary(&planner());
        assert_eq!(r.rows.len(), 3);
        for row in &r.rows {
            let e: f64 = row[5].parse().unwrap();
            assert!(e > 5.0 && e < 60.0, "energy reduction {e}% out of band");
        }
    }
}
