//! Configuration: AOT artifact manifest + experiment setup.
//!
//! `artifacts/manifest.json` is written by `python -m compile.aot` and is
//! the single source of truth for artifact geometry; the runtime never
//! hardcodes a shape. [`Manifest::load`] finds it relative to the repo root
//! (or via `HETERO_DNN_ARTIFACTS`).

pub mod json;
pub mod sim;

use json::{Json, JsonError};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One input or output tensor description.
#[derive(Debug, Clone)]
pub struct TensorDesc {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorDesc {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT artifact entry.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub file: String,
    pub inputs: Vec<TensorDesc>,
    pub outputs: Vec<TensorDesc>,
    pub tags: Vec<String>,
}

impl ArtifactEntry {
    pub fn has_tag(&self, t: &str) -> bool {
        self.tags.iter().any(|x| x == t)
    }
}

/// The artifact manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, ArtifactEntry>,
    pub dir: PathBuf,
    /// True for the in-tree simulated manifest ([`Manifest::simulated`]),
    /// false when loaded from `artifacts/manifest.json`.
    pub simulated: bool,
}

/// Configuration errors.
#[derive(Debug, thiserror::Error)]
pub enum ConfigError {
    #[error("artifacts directory not found; run `make artifacts` (looked in {0:?})")]
    NotFound(Vec<PathBuf>),
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    #[error("manifest parse: {0}")]
    Parse(#[from] JsonError),
    #[error("manifest schema: {0}")]
    Schema(String),
    #[error("unknown artifact {0:?}")]
    UnknownArtifact(String),
}

fn schema_err(msg: impl Into<String>) -> ConfigError {
    ConfigError::Schema(msg.into())
}

fn parse_tensor_desc(v: &Json, ctx: &str) -> Result<TensorDesc, ConfigError> {
    let shape = v
        .get("shape")
        .and_then(Json::as_arr)
        .ok_or_else(|| schema_err(format!("{ctx}: missing shape")))?
        .iter()
        .map(|d| d.as_usize().ok_or_else(|| schema_err(format!("{ctx}: bad dim"))))
        .collect::<Result<Vec<_>, _>>()?;
    Ok(TensorDesc {
        name: v.get("name").and_then(Json::as_str).unwrap_or_default().to_string(),
        shape,
        dtype: v.get("dtype").and_then(Json::as_str).unwrap_or("f32").to_string(),
    })
}

fn parse_entry(name: &str, v: &Json) -> Result<ArtifactEntry, ConfigError> {
    let file = v
        .get("file")
        .and_then(Json::as_str)
        .ok_or_else(|| schema_err(format!("{name}: missing file")))?
        .to_string();
    let parse_list = |key: &str| -> Result<Vec<TensorDesc>, ConfigError> {
        v.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| schema_err(format!("{name}: missing {key}")))?
            .iter()
            .enumerate()
            .map(|(i, t)| parse_tensor_desc(t, &format!("{name}.{key}[{i}]")))
            .collect()
    };
    let tags = v
        .get("tags")
        .and_then(Json::as_arr)
        .map(|a| a.iter().filter_map(|t| t.as_str().map(str::to_string)).collect())
        .unwrap_or_default();
    Ok(ArtifactEntry { file, inputs: parse_list("inputs")?, outputs: parse_list("outputs")?, tags })
}

/// Parse a manifest JSON document into the artifact map.
pub fn parse_manifest(text: &str) -> Result<BTreeMap<String, ArtifactEntry>, ConfigError> {
    let doc = json::parse(text)?;
    let obj = doc.as_obj().ok_or_else(|| schema_err("manifest root must be an object"))?;
    let mut out = BTreeMap::new();
    for (name, v) in obj {
        out.insert(name.clone(), parse_entry(name, v)?);
    }
    Ok(out)
}

impl Manifest {
    /// Candidate artifact directories, best first.
    pub fn candidate_dirs() -> Vec<PathBuf> {
        let mut v = Vec::new();
        if let Ok(env) = std::env::var("HETERO_DNN_ARTIFACTS") {
            v.push(PathBuf::from(env));
        }
        v.push(PathBuf::from("artifacts"));
        if let Ok(mani) = std::env::var("CARGO_MANIFEST_DIR") {
            v.push(Path::new(&mani).join("artifacts"));
        }
        v.push(PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"));
        v
    }

    /// Load the manifest from the first candidate dir that has one.
    pub fn load() -> Result<Manifest, ConfigError> {
        let cands = Self::candidate_dirs();
        for dir in &cands {
            let p = dir.join("manifest.json");
            if p.exists() {
                return Self::load_from(dir);
            }
        }
        Err(ConfigError::NotFound(cands))
    }

    /// Load from an explicit directory.
    pub fn load_from(dir: &Path) -> Result<Manifest, ConfigError> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        let artifacts = parse_manifest(&text)?;
        Ok(Manifest { artifacts, dir: dir.to_path_buf(), simulated: false })
    }

    /// Absolute path of an artifact's HLO file.
    pub fn hlo_path(&self, name: &str) -> Result<PathBuf, ConfigError> {
        let e = self
            .artifacts
            .get(name)
            .ok_or_else(|| ConfigError::UnknownArtifact(name.to_string()))?;
        Ok(self.dir.join(&e.file))
    }

    pub fn entry(&self, name: &str) -> Result<&ArtifactEntry, ConfigError> {
        self.artifacts
            .get(name)
            .ok_or_else(|| ConfigError::UnknownArtifact(name.to_string()))
    }

    /// Artifact names carrying a tag (sorted).
    pub fn tagged(&self, tag: &str) -> Vec<&str> {
        self.artifacts
            .iter()
            .filter(|(_, e)| e.has_tag(tag))
            .map(|(n, _)| n.as_str())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn example_manifest() -> Manifest {
        let json = r#"{
            "conv3x3": {
                "file": "conv3x3.hlo.txt",
                "inputs": [
                    {"name": "x", "shape": [1, 56, 56, 16], "dtype": "f32"},
                    {"name": "w", "shape": [3, 3, 16, 32], "dtype": "f32"}
                ],
                "outputs": [{"shape": [1, 56, 56, 32], "dtype": "f32"}],
                "tags": ["op"]
            }
        }"#;
        let artifacts = parse_manifest(json).unwrap();
        Manifest { artifacts, dir: PathBuf::from("/tmp/x"), simulated: false }
    }

    #[test]
    fn parse_entry() {
        let m = example_manifest();
        let e = m.entry("conv3x3").unwrap();
        assert_eq!(e.inputs.len(), 2);
        assert_eq!(e.inputs[0].elems(), 56 * 56 * 16);
        assert!(e.has_tag("op"));
        assert!(!e.has_tag("net"));
    }

    #[test]
    fn unknown_artifact_errors() {
        let m = example_manifest();
        assert!(matches!(m.entry("nope"), Err(ConfigError::UnknownArtifact(_))));
    }

    #[test]
    fn hlo_path_joins_dir() {
        let m = example_manifest();
        assert_eq!(m.hlo_path("conv3x3").unwrap(), PathBuf::from("/tmp/x/conv3x3.hlo.txt"));
    }

    #[test]
    fn tagged_filter() {
        let m = example_manifest();
        assert_eq!(m.tagged("op"), vec!["conv3x3"]);
        assert!(m.tagged("net").is_empty());
    }

    #[test]
    fn real_manifest_loads_when_built() {
        // exercised fully by integration tests; here just don't panic
        let _ = Manifest::load();
    }
}
