//! Minimal JSON parser for the artifact manifest (offline substitute for
//! serde_json — DESIGN.md §Offline).
//!
//! Supports the full JSON grammar the manifest uses: objects, arrays,
//! strings (with escapes), numbers, booleans, null. Object key order is
//! preserved via a Vec-backed map so error messages are stable.

use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(v) => Some(v),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj()?.iter().find(|(k, _)| k == key).map(|(_, v)| v)
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, JsonError> {
        Err(JsonError { offset: self.pos, message: msg.into() })
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            self.err(format!("expected {:?}, found {:?}", b as char, self.peek().map(|c| c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => self.err(format!("unexpected {:?}", other.map(|c| c as char))),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            self.err(format!("expected {word}"))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                other => return self.err(format!("expected ',' or '}}', found {:?}", other.map(|c| c as char))),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                other => return self.err(format!("expected ',' or ']', found {:?}", other.map(|c| c as char))),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return self.err("unterminated string"),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or(JsonError {
                                offset: self.pos,
                                message: "truncated \\u escape".into(),
                            })?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or(JsonError {
                                    offset: self.pos,
                                    message: "bad hex digit".into(),
                                })?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    other => {
                        return self.err(format!("bad escape {:?}", other.map(|c| c as char)))
                    }
                },
                Some(c) if c < 0x20 => return self.err("control char in string"),
                Some(c) => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 { 4 } else if c >= 0xE0 { 3 } else { 2 };
                        if start + len > self.bytes.len() {
                            return self.err("truncated utf-8");
                        }
                        match std::str::from_utf8(&self.bytes[start..start + len]) {
                            Ok(s) => {
                                out.push_str(s);
                                self.pos = start + len;
                            }
                            Err(_) => return self.err("invalid utf-8"),
                        }
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| JsonError { offset: start, message: format!("bad number {text:?}: {e}") })
    }
}

/// Parse a complete JSON document (trailing whitespace allowed).
pub fn parse(text: &str) -> Result<Json, JsonError> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return p.err("trailing garbage");
    }
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn escapes() {
        assert_eq!(parse(r#""a\nb\t\"c\"""#).unwrap(), Json::Str("a\nb\t\"c\"".into()));
        assert_eq!(parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn utf8_passthrough() {
        assert_eq!(parse("\"héllo → 日本\"").unwrap(), Json::Str("héllo → 日本".into()));
    }

    #[test]
    fn nested_structures() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": {"d": [true]}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_arr().unwrap()[0], Json::Bool(true));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(vec![]));
        assert_eq!(parse("[ ]").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn whitespace_tolerant() {
        let v = parse(" {\n\t\"k\" :  [ 1 , 2 ] }\r\n").unwrap();
        assert_eq!(v.get("k").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn error_offsets() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn as_usize_guards() {
        assert_eq!(parse("7").unwrap().as_usize(), Some(7));
        assert_eq!(parse("7.5").unwrap().as_usize(), None);
        assert_eq!(parse("-7").unwrap().as_usize(), None);
    }

    #[test]
    fn key_order_preserved() {
        let v = parse(r#"{"z":1,"a":2}"#).unwrap();
        let keys: Vec<_> = v.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["z", "a"]);
    }

    #[test]
    fn manifest_like_document() {
        let doc = r#"{
            "conv3x3": {
                "file": "conv3x3.hlo.txt",
                "inputs": [{"name": "x", "shape": [1, 56, 56, 16], "dtype": "f32"}],
                "outputs": [{"shape": [1, 56, 56, 32], "dtype": "f32"}],
                "tags": ["op"]
            }
        }"#;
        let v = parse(doc).unwrap();
        let entry = v.get("conv3x3").unwrap();
        assert_eq!(entry.get("file").unwrap().as_str(), Some("conv3x3.hlo.txt"));
        let shape: Vec<usize> = entry.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|d| d.as_usize().unwrap())
            .collect();
        assert_eq!(shape, vec![1, 56, 56, 16]);
    }
}
