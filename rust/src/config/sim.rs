//! Built-in simulated artifact manifest.
//!
//! `python -m compile.aot` writes `artifacts/manifest.json`; when the AOT
//! artifacts have not been built (no JAX in the environment, fresh CI
//! checkout), the serving stack still needs a manifest to describe artifact
//! geometry. [`Manifest::simulated`] reproduces the aot.py registry
//! *shapes* in-tree — same artifact names, same ordered input/output
//! descriptors, same tags — so the coordinator, CLI, benches and examples
//! run end-to-end against the deterministic simulated backend
//! (see `runtime` and DESIGN.md §Offline).
//!
//! The geometry here is a contract with `python/compile/aot.py`: the
//! `sim_matches_*` tests below cross-check it against the Rust graph
//! builders, and the Python side's manifest is the source of truth
//! whenever real artifacts exist.

use super::{ArtifactEntry, Manifest, TensorDesc};
use crate::graph::models::SQUEEZENET_FIRES;
use crate::graph::{models, Layer, ModelGraph, OpKind};
use std::collections::BTreeMap;
use std::path::PathBuf;

fn desc(name: &str, shape: &[usize]) -> TensorDesc {
    TensorDesc { name: name.to_string(), shape: shape.to_vec(), dtype: "f32".into() }
}

fn entry(
    name: &str,
    inputs: Vec<TensorDesc>,
    outputs: Vec<TensorDesc>,
    tags: &[&str],
) -> (String, ArtifactEntry) {
    (
        name.to_string(),
        ArtifactEntry {
            file: format!("{name}.hlo.txt"),
            inputs,
            outputs,
            tags: tags.iter().map(|t| t.to_string()).collect(),
        },
    )
}

/// Weight tensor shape a layer's kernel takes (None for weight-less ops).
/// Mirrors the L2 JAX parameter shapes lowered by aot.py.
fn weight_shape(l: &Layer) -> Option<Vec<usize>> {
    let ci = l.input.c;
    match l.op {
        OpKind::Conv { k, cout, .. } => Some(vec![k, k, ci, cout]),
        OpKind::DwConv { k, .. } => Some(vec![k, k, ci]),
        OpKind::PwConv { cout, .. } => Some(vec![ci, cout]),
        OpKind::GConv { k, groups, cout, .. } => {
            Some(vec![groups, k, k, ci / groups, cout / groups])
        }
        OpKind::Dense { cout } => Some(vec![ci, cout]),
        _ => None,
    }
}

/// Whole-net artifact: x plus every weight-bearing layer's parameter, in
/// module order — the order `runtime::chain::ChainExecutor::flat_weights`
/// and the serving coordinator rely on.
fn net_entry(g: &ModelGraph) -> (String, ArtifactEntry) {
    let mut inputs = vec![desc("x", &[1, g.input.h, g.input.w, g.input.c])];
    for m in &g.modules {
        for (li, l) in m.layers.iter().enumerate() {
            if let Some(shape) = weight_shape(l) {
                inputs.push(desc(&format!("{}_{li}_w", m.name), &shape));
            }
        }
    }
    entry(
        &format!("{}_224", g.name),
        inputs,
        vec![desc("logits", &[1, 1000])],
        &["net", &g.name],
    )
}

impl Manifest {
    /// The in-tree simulated manifest (aot.py registry geometry).
    pub fn simulated() -> Manifest {
        let mut a: BTreeMap<String, ArtifactEntry> = BTreeMap::new();
        let mut add = |e: (String, ArtifactEntry)| {
            a.insert(e.0, e.1);
        };

        // ---- op-level -----------------------------------------------------
        add(entry(
            "conv3x3",
            vec![desc("x", &[1, 56, 56, 16]), desc("w", &[3, 3, 16, 32])],
            vec![desc("y", &[1, 56, 56, 32])],
            &["op"],
        ));
        add(entry(
            "conv3x3_q8",
            vec![desc("x", &[1, 56, 56, 16]), desc("w", &[3, 3, 16, 32])],
            vec![desc("y", &[1, 56, 56, 32])],
            &["op", "q8"],
        ));
        add(entry(
            "pwconv_relu",
            vec![desc("x", &[1, 56, 56, 64]), desc("w", &[64, 128])],
            vec![desc("y", &[1, 56, 56, 128])],
            &["op"],
        ));
        add(entry(
            "dwconv3x3_s2",
            vec![desc("x", &[1, 56, 56, 32]), desc("w", &[3, 3, 32])],
            vec![desc("y", &[1, 28, 28, 32])],
            &["op"],
        ));
        add(entry(
            "gconv_g2",
            vec![desc("x", &[1, 28, 28, 32]), desc("w", &[2, 3, 3, 16, 24])],
            vec![desc("y", &[1, 28, 28, 48])],
            &["op"],
        ));
        add(entry(
            "fused_pw_pw",
            vec![desc("x", &[1, 28, 28, 32]), desc("w1", &[32, 64]), desc("w2", &[64, 32])],
            vec![desc("y", &[1, 28, 28, 32])],
            &["op", "fused"],
        ));

        // ---- Fire module (SqueezeNet fire2 geometry) ----------------------
        let fire_args = vec![
            desc("x", &[1, 56, 56, 96]),
            desc("squeeze_w", &[96, 16]),
            desc("expand1_w", &[16, 64]),
            desc("expand3_w", &[3, 3, 16, 64]),
        ];
        add(entry(
            "fire_full",
            fire_args.clone(),
            vec![desc("y", &[1, 56, 56, 128])],
            &["module", "squeezenet"],
        ));
        add(entry(
            "fire_gpu",
            fire_args[..3].to_vec(),
            vec![desc("s", &[1, 56, 56, 16]), desc("a", &[1, 56, 56, 64])],
            &["module", "squeezenet", "gpu-part"],
        ));
        for (name, tags) in [
            ("fire_fpga", &["module", "squeezenet", "fpga-part", "q8"][..]),
            ("fire_fpga_f32", &["module", "squeezenet", "fpga-part"][..]),
        ] {
            add(entry(
                name,
                vec![desc("s", &[1, 56, 56, 16]), desc("expand3_w", &[3, 3, 16, 64])],
                vec![desc("b", &[1, 56, 56, 64])],
                tags,
            ));
        }

        // ---- Bottleneck (MNv2 geometry: 28x28x16, t=6, co=16, s=1) --------
        let bn_args = vec![
            desc("x", &[1, 28, 28, 16]),
            desc("expand_w", &[16, 96]),
            desc("dw_w", &[3, 3, 96]),
            desc("project_w", &[96, 16]),
        ];
        add(entry(
            "bottleneck_full",
            bn_args.clone(),
            vec![desc("y", &[1, 28, 28, 16])],
            &["module", "mobilenetv2"],
        ));
        add(entry(
            "bottleneck_gpu",
            bn_args[..3].to_vec(),
            vec![desc("t", &[1, 28, 28, 96])],
            &["module", "mobilenetv2", "gpu-part"],
        ));
        for (name, tags) in [
            ("bottleneck_fpga", &["module", "mobilenetv2", "fpga-part", "q8"][..]),
            ("bottleneck_fpga_f32", &["module", "mobilenetv2", "fpga-part"][..]),
        ] {
            add(entry(
                name,
                vec![desc("t", &[1, 28, 28, 96]), desc("project_w", &[96, 16])],
                vec![desc("y", &[1, 28, 28, 16])],
                tags,
            ));
        }

        // ---- ShuffleNetV2 units (stage-2 geometry: 28x28x48) --------------
        let sb_ws = [desc("b1_w", &[24, 24]), desc("bd_w", &[3, 3, 24]), desc("b2_w", &[24, 24])];
        let mut sb_full = vec![desc("x", &[1, 28, 28, 48])];
        sb_full.extend(sb_ws.iter().cloned());
        add(entry(
            "shuffle_basic_full",
            sb_full,
            vec![desc("y", &[1, 28, 28, 48])],
            &["module", "shufflenetv2"],
        ));
        let mut sb_fpga = vec![desc("right", &[1, 28, 28, 24])];
        sb_fpga.extend(sb_ws.iter().cloned());
        add(entry(
            "shuffle_basic_fpga",
            sb_fpga,
            vec![desc("r", &[1, 28, 28, 24])],
            &["module", "shufflenetv2", "fpga-part", "fused"],
        ));
        let sr_args = [
            desc("x", &[1, 28, 28, 24]),
            desc("ld_w", &[3, 3, 24]),
            desc("l1_w", &[24, 24]),
            desc("r1_w", &[24, 24]),
            desc("rd_w", &[3, 3, 24]),
            desc("r2_w", &[24, 24]),
        ];
        add(entry(
            "shuffle_reduce_full",
            sr_args.to_vec(),
            vec![desc("y", &[1, 14, 14, 48])],
            &["module", "shufflenetv2"],
        ));
        let mut sr_gpu = vec![sr_args[0].clone()];
        sr_gpu.extend(sr_args[3..].iter().cloned());
        add(entry(
            "shuffle_reduce_gpu",
            sr_gpu,
            vec![desc("r", &[1, 14, 14, 24])],
            &["module", "shufflenetv2", "gpu-part"],
        ));
        for (name, tags) in [
            ("shuffle_reduce_fpga", &["module", "shufflenetv2", "fpga-part", "q8"][..]),
            ("shuffle_reduce_fpga_f32", &["module", "shufflenetv2", "fpga-part"][..]),
        ] {
            add(entry(
                name,
                sr_args[..3].to_vec(),
                vec![desc("l", &[1, 14, 14, 24])],
                tags,
            ));
        }

        // ---- SqueezeNet module chain at 224 (mirrors aot.py geometry walk)
        add(entry(
            "sq_stem",
            vec![desc("x", &[1, 224, 224, 3]), desc("conv1_w", &[7, 7, 3, 96])],
            vec![desc("y", &[1, 109, 109, 96])],
            &["chain"],
        ));
        add(entry(
            "sq_pool1",
            vec![desc("x", &[1, 109, 109, 96])],
            vec![desc("y", &[1, 54, 54, 96])],
            &["chain"],
        ));
        let mut h = 54usize;
        let mut ci = 96usize;
        for (i, &(s, e1, e3)) in SQUEEZENET_FIRES.iter().enumerate() {
            let name = format!("sq_fire{}", i + 2);
            let fire_args = vec![
                desc("x", &[1, h, h, ci]),
                desc("squeeze_w", &[ci, s]),
                desc("expand1_w", &[s, e1]),
                desc("expand3_w", &[3, 3, s, e3]),
            ];
            add(entry(
                &format!("{name}_full"),
                fire_args.clone(),
                vec![desc("y", &[1, h, h, e1 + e3])],
                &["chain", "fire"],
            ));
            add(entry(
                &format!("{name}_gpu"),
                fire_args[..3].to_vec(),
                vec![desc("s", &[1, h, h, s]), desc("a", &[1, h, h, e1])],
                &["chain", "fire", "gpu-part"],
            ));
            for (suffix, tags) in [
                ("_fpga", &["chain", "fire", "fpga-part", "q8"][..]),
                ("_fpga_f32", &["chain", "fire", "fpga-part"][..]),
            ] {
                add(entry(
                    &format!("{name}{suffix}"),
                    vec![desc("s", &[1, h, h, s]), desc("expand3_w", &[3, 3, s, e3])],
                    vec![desc("b", &[1, h, h, e3])],
                    tags,
                ));
            }
            ci = e1 + e3;
            if i == 2 || i == 6 {
                let ho = (h - 3) / 2 + 1;
                add(entry(
                    &format!("sq_pool{}", i + 2),
                    vec![desc("x", &[1, h, h, ci])],
                    vec![desc("y", &[1, ho, ho, ci])],
                    &["chain"],
                ));
                h = ho;
            }
        }
        add(entry(
            "sq_conv10",
            vec![desc("x", &[1, h, h, 512]), desc("conv10_w", &[512, 1000])],
            vec![desc("y", &[1, h, h, 1000])],
            &["chain"],
        ));
        add(entry(
            "sq_gap",
            vec![desc("x", &[1, h, h, 1000])],
            vec![desc("logits", &[1, 1000])],
            &["chain"],
        ));

        // ---- full nets at 224 (serving front door) ------------------------
        for g in models::all_models() {
            add(net_entry(&g));
        }

        Manifest { artifacts: a, dir: PathBuf::from("<simulated>"), simulated: true }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_manifest_has_all_families() {
        let m = Manifest::simulated();
        for tag in ["op", "module", "net", "fpga-part", "gpu-part", "q8", "chain"] {
            assert!(!m.tagged(tag).is_empty(), "no artifacts tagged {tag}");
        }
        assert!(m.simulated);
    }

    #[test]
    fn sim_fire_full_matches_graph_geometry() {
        let m = Manifest::simulated();
        let e = m.entry("fire_full").unwrap();
        assert_eq!(e.inputs[0].shape, vec![1, 56, 56, 96]);
        assert_eq!(e.outputs[0].shape, vec![1, 56, 56, 128]);
        let g = m.entry("fire_gpu").unwrap();
        assert_eq!(g.outputs.len(), 2);
        assert_eq!(g.outputs[0].shape, vec![1, 56, 56, 16]);
    }

    #[test]
    fn sim_chain_geometry_walks_consistently() {
        // each sq_* artifact's input matches its predecessor's output
        let m = Manifest::simulated();
        let mut cur = m.entry("sq_stem").unwrap().outputs[0].shape.clone();
        cur = {
            assert_eq!(m.entry("sq_pool1").unwrap().inputs[0].shape, cur);
            m.entry("sq_pool1").unwrap().outputs[0].shape.clone()
        };
        for i in 0..8 {
            let full = m.entry(&format!("sq_fire{}_full", i + 2)).unwrap();
            assert_eq!(full.inputs[0].shape, cur, "fire{}", i + 2);
            cur = full.outputs[0].shape.clone();
            if i == 2 || i == 6 {
                let pool = m.entry(&format!("sq_pool{}", i + 2)).unwrap();
                assert_eq!(pool.inputs[0].shape, cur);
                cur = pool.outputs[0].shape.clone();
            }
        }
        assert_eq!(m.entry("sq_conv10").unwrap().inputs[0].shape, cur);
    }

    #[test]
    fn sim_nets_cover_all_three_models() {
        let m = Manifest::simulated();
        for name in ["squeezenet_224", "mobilenetv2_05_224", "shufflenetv2_05_224"] {
            let e = m.entry(name).unwrap();
            assert_eq!(e.inputs[0].shape, vec![1, 224, 224, 3], "{name}");
            assert_eq!(e.outputs[0].shape, vec![1, 1000], "{name}");
            assert!(e.inputs.len() > 10, "{name}: missing weights");
        }
        // squeezenet: x + stem + 8 fire triples + conv10 = 27 inputs
        assert_eq!(m.entry("squeezenet_224").unwrap().inputs.len(), 27);
    }

    #[test]
    fn sim_fire_split_geometry_is_concat_consistent() {
        // gpu expand1 channels + fpga expand3 channels == full output channels
        let m = Manifest::simulated();
        for i in 0..8 {
            let full = m.entry(&format!("sq_fire{}_full", i + 2)).unwrap();
            let gpu = m.entry(&format!("sq_fire{}_gpu", i + 2)).unwrap();
            let fpga = m.entry(&format!("sq_fire{}_fpga", i + 2)).unwrap();
            let e1 = gpu.outputs[1].shape[3];
            let e3 = fpga.outputs[0].shape[3];
            assert_eq!(e1 + e3, full.outputs[0].shape[3]);
        }
    }
}
