//! Flight recorder: end-to-end request tracing for the serving stack.
//!
//! The offline scheduler can already *predict* a model's FPGA → link →
//! GPU timeline ([`crate::sched::trace`]); this module records what the
//! serving stack *actually does*. A [`TraceId`] is allocated when a
//! request reaches the engine front door and threaded through the
//! batcher, the dispatch sinks, the hetero lanes and the reply path;
//! every hop appends a span [`Event`] to a fixed-capacity per-thread
//! ring buffer ([`recorder::ThreadRing`]) that **never blocks the hot
//! path** — on contention the event is dropped and counted, and when a
//! ring is full the oldest event is overwritten.
//!
//! Recording is off by default and enabled per engine via
//! `EngineBuilder::tracing()`. A drained [`snapshot::TraceSnapshot`]
//! yields:
//!
//! - the per-stage latency breakdown ([`snapshot::StageBreakdown`]:
//!   admission wait, queue wait, batch-formation wait, device wait vs
//!   hold, writer wait) as [`crate::metrics::histogram::LogHistogram`]s,
//!   summarized
//!   into the wire-serializable [`NodeStats`] served over the v2 `STATS`
//!   frame next to HEALTH;
//! - a Chrome trace-event JSON export of the measured run that shares
//!   the [`crate::sched::trace`] track vocabulary (same device tids,
//!   same `cat` strings, same metadata events), so a measured hetero
//!   run and its `ModelPlan` prediction load side-by-side in one
//!   viewer (DESIGN.md §15).

#![warn(missing_docs)]

pub mod recorder;
pub mod snapshot;

pub use recorder::{LaneObs, Recorder, ThreadRing};
pub use snapshot::{StageBreakdown, TraceSnapshot, TracedEvent};

use crate::partition::Resource;

/// Identity of one traced request, allocated at the engine front door
/// and carried through every span event the request produces.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TraceId(pub u64);

impl std::fmt::Display for TraceId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "t{}", self.0)
    }
}

/// Number of stages in the latency breakdown (and in the wire `STATS`
/// frame, which carries one [`StageStats`] block per stage).
pub const STAGES: usize = 6;

/// Stage names, in breakdown/wire order.
pub const STAGE_NAMES: [&str; STAGES] = [
    "admission_wait",
    "queue_wait",
    "batch_wait",
    "device_wait",
    "device_hold",
    "writer_wait",
];

/// One span event on a request's path through the engine.
///
/// The vocabulary is fixed (see [`EventKind::name`]); every variant is
/// a *point* in time — durations (device holds, stage waits) are
/// derived between points when a snapshot is taken, never measured on
/// the hot path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// The request passed the engine front door (trace allocated).
    Admitted,
    /// The result cache answered the request; no batcher involved.
    CacheHit,
    /// The result cache missed; the request continues to admission.
    CacheMiss,
    /// The request entered its model's batcher queue.
    Enqueued,
    /// The batcher accepted the request into the forming batch that
    /// currently holds `size` requests.
    Batched {
        /// Requests in the forming batch after this one joined.
        size: u32,
    },
    /// A formed batch handed this request to pool worker `worker`.
    DispatchedWorker {
        /// Zero-based worker index within the model's pool.
        worker: u32,
    },
    /// A formed batch handed this request to the hetero pipeline intake.
    DispatchedLane,
    /// A lane asked for the simulated device (starts the device wait).
    DeviceAcquire {
        /// The device being acquired.
        dev: Resource,
    },
    /// The device was granted after `wait_us` of queueing; the hold
    /// starts now.
    DeviceHold {
        /// The device being held.
        dev: Resource,
        /// Microseconds spent queued for the grant.
        wait_us: u64,
    },
    /// The device was released after `held_us` of wall-clock hold —
    /// the **same** microsecond truncation
    /// [`crate::metrics::device::ArbiterCounters::record_hold`] uses,
    /// so event sums reconcile exactly against node counters.
    DeviceRelease {
        /// The device being released.
        dev: Resource,
        /// Microseconds the grant held the device.
        held_us: u64,
    },
    /// One simulated DMA crossing of `bytes` on the link lane.
    LinkDma {
        /// Bytes that crossed the simulated PCIe boundary.
        bytes: u64,
    },
    /// The reply left the engine (the span chain's end).
    ReplyWritten,
}

impl EventKind {
    /// The event's wire/vocabulary name (`dispatched` covers both the
    /// worker and the lane variant — the target is an argument).
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::Admitted => "admitted",
            EventKind::CacheHit => "cache_hit",
            EventKind::CacheMiss => "cache_miss",
            EventKind::Enqueued => "enqueued",
            EventKind::Batched { .. } => "batched",
            EventKind::DispatchedWorker { .. } | EventKind::DispatchedLane => "dispatched",
            EventKind::DeviceAcquire { .. } => "device_acquire",
            EventKind::DeviceHold { .. } => "device_hold",
            EventKind::DeviceRelease { .. } => "device_release",
            EventKind::LinkDma { .. } => "link_dma",
            EventKind::ReplyWritten => "reply_written",
        }
    }
}

/// One recorded event: which request, when (microseconds since the
/// recorder's epoch), and what happened.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Event {
    /// The request this event belongs to.
    pub trace: TraceId,
    /// Microseconds since the owning [`Recorder`]'s epoch.
    pub t_us: u64,
    /// What happened.
    pub kind: EventKind,
}

/// Wire-serializable summary of one breakdown stage (a `STATS` frame
/// block): sample count plus mean/p50/p99 in microseconds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct StageStats {
    /// Samples recorded into the stage's histogram.
    pub count: u64,
    /// Mean latency, microseconds (rounded).
    pub mean_us: u64,
    /// Median latency, microseconds.
    pub p50_us: u64,
    /// 99th-percentile latency, microseconds.
    pub p99_us: u64,
}

/// The per-stage latency summary a node serves over the v2 `STATS`
/// frame: one [`StageStats`] block per [`STAGE_NAMES`] entry, in order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct NodeStats {
    /// Per-stage summaries, in [`STAGE_NAMES`] order.
    pub stages: [StageStats; STAGES],
}

impl NodeStats {
    /// True when no stage recorded any sample (tracing off or no
    /// traffic yet).
    pub fn is_empty(&self) -> bool {
        self.stages.iter().all(|s| s.count == 0)
    }

    /// Render the breakdown as the fixed-width table the serve summary
    /// and the traffic-lab report print.
    pub fn table(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "  {:<16} {:>8} {:>10} {:>10} {:>10}\n",
            "stage", "count", "mean_us", "p50_us", "p99_us"
        ));
        for (name, s) in STAGE_NAMES.iter().zip(self.stages.iter()) {
            out.push_str(&format!(
                "  {:<16} {:>8} {:>10} {:>10} {:>10}\n",
                name, s.count, s.mean_us, s.p50_us, s.p99_us
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_names_match_the_stage_count() {
        assert_eq!(STAGE_NAMES.len(), STAGES);
        let unique: std::collections::BTreeSet<_> = STAGE_NAMES.iter().collect();
        assert_eq!(unique.len(), STAGES, "stage names must be unique");
    }

    #[test]
    fn event_names_cover_the_issue_vocabulary() {
        let kinds = [
            EventKind::Admitted,
            EventKind::CacheHit,
            EventKind::CacheMiss,
            EventKind::Enqueued,
            EventKind::Batched { size: 4 },
            EventKind::DispatchedWorker { worker: 0 },
            EventKind::DispatchedLane,
            EventKind::DeviceAcquire { dev: Resource::Gpu },
            EventKind::DeviceHold { dev: Resource::Fpga, wait_us: 1 },
            EventKind::DeviceRelease { dev: Resource::Link, held_us: 2 },
            EventKind::LinkDma { bytes: 3 },
            EventKind::ReplyWritten,
        ];
        let names: Vec<_> = kinds.iter().map(|k| k.name()).collect();
        for want in [
            "admitted",
            "cache_hit",
            "cache_miss",
            "enqueued",
            "batched",
            "dispatched",
            "device_acquire",
            "device_hold",
            "device_release",
            "link_dma",
            "reply_written",
        ] {
            assert!(names.contains(&want), "missing event name {want}");
        }
    }

    #[test]
    fn empty_stats_know_they_are_empty() {
        let s = NodeStats::default();
        assert!(s.is_empty());
        let table = s.table();
        for name in STAGE_NAMES {
            assert!(table.contains(name), "table missing {name}");
        }
        let mut s = s;
        s.stages[0].count = 1;
        assert!(!s.is_empty());
    }
}
