//! Draining the recorder: stage-latency breakdown + Chrome trace export.
//!
//! A [`TraceSnapshot`] copies every ring's events (emitters keep
//! running; a concurrent emit that hits the copy lock is dropped and
//! counted, never blocked), reconstructs per-request span chains, and
//! derives:
//!
//! - [`StageBreakdown`]: per-stage [`LogHistogram`]s over the waits the
//!   ISSUE vocabulary names — admission, queue, batch formation, device
//!   wait vs hold, writer — plus **exact** per-device hold totals that
//!   reconcile against
//!   [`crate::metrics::device::NodeDeviceMetrics`];
//! - [`TraceSnapshot::chrome_trace_json`]: the measured run in Chrome
//!   trace-event format, on the same device tracks (tid/name/cat) as
//!   the predicted [`crate::sched::trace::model_trace_json`] timeline.

use super::recorder::ThreadRing;
use super::{Event, EventKind, NodeStats, StageStats, TraceId, STAGES};
use crate::metrics::histogram::LogHistogram;
use crate::partition::Resource;
use crate::sched::trace::device_track;
use std::collections::BTreeMap;
use std::sync::Arc;

/// One snapshotted event plus the ring (viewer thread) it came from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TracedEvent {
    /// Trace-viewer thread id of the emitting ring.
    pub tid: u32,
    /// The recorded event.
    pub event: Event,
}

/// The virtual track request spans are exported on (no ring emits
/// there; it exists only in the viewer).
const REQUESTS_TID: u32 = 4;

fn res_idx(r: Resource) -> usize {
    match r {
        Resource::Gpu => 0,
        Resource::Fpga => 1,
        Resource::Link => 2,
    }
}

/// Per-stage latency breakdown assembled from a snapshot's events.
#[derive(Debug, Clone, Default)]
pub struct StageBreakdown {
    /// Front door to batcher queue (`admitted` → `enqueued`).
    pub admission_wait: LogHistogram,
    /// Batcher queue to forming batch (`enqueued` → `batched`).
    pub queue_wait: LogHistogram,
    /// Forming batch to dispatch (`batched` → `dispatched`) — the
    /// max-wait / batch-fill time.
    pub batch_wait: LogHistogram,
    /// Per-request total device-grant queueing (Σ `device_hold.wait_us`).
    pub device_wait: LogHistogram,
    /// Per-request total device occupancy (Σ `device_release.held_us`).
    pub device_hold: LogHistogram,
    /// Last dispatch/device work to the reply leaving the engine.
    pub writer_wait: LogHistogram,
    /// End to end (`admitted` → `reply_written`).
    pub e2e: LogHistogram,
    hold_us: [u64; 3],
    dma_bytes: u64,
}

impl StageBreakdown {
    /// Assemble the breakdown from (time-sorted) snapshot events.
    pub fn from_events(events: &[TracedEvent]) -> Self {
        #[derive(Default)]
        struct Marks {
            admitted: Option<u64>,
            enqueued: Option<u64>,
            batched: Option<u64>,
            dispatched: Option<u64>,
            reply: Option<u64>,
            dev_wait_us: u64,
            dev_held_us: u64,
            saw_wait: bool,
            saw_hold: bool,
            last_device: Option<u64>,
        }
        let mut per: BTreeMap<TraceId, Marks> = BTreeMap::new();
        let mut out = Self::default();
        for te in events {
            let m = per.entry(te.event.trace).or_default();
            let t = te.event.t_us;
            match te.event.kind {
                EventKind::Admitted => m.admitted = m.admitted.or(Some(t)),
                EventKind::Enqueued => m.enqueued = m.enqueued.or(Some(t)),
                EventKind::Batched { .. } => m.batched = m.batched.or(Some(t)),
                EventKind::DispatchedWorker { .. } | EventKind::DispatchedLane => {
                    m.dispatched = m.dispatched.or(Some(t));
                }
                EventKind::DeviceHold { wait_us, .. } => {
                    m.dev_wait_us += wait_us;
                    m.saw_wait = true;
                }
                EventKind::DeviceRelease { dev, held_us } => {
                    m.dev_held_us += held_us;
                    m.saw_hold = true;
                    m.last_device = Some(m.last_device.unwrap_or(0).max(t));
                    out.hold_us[res_idx(dev)] += held_us;
                }
                EventKind::LinkDma { bytes } => out.dma_bytes += bytes,
                EventKind::ReplyWritten => m.reply = m.reply.or(Some(t)),
                EventKind::CacheHit | EventKind::CacheMiss | EventKind::DeviceAcquire { .. } => {}
            }
        }
        for m in per.values() {
            if let (Some(a), Some(e)) = (m.admitted, m.enqueued) {
                out.admission_wait.record(e.saturating_sub(a));
            }
            if let (Some(e), Some(b)) = (m.enqueued, m.batched) {
                out.queue_wait.record(b.saturating_sub(e));
            }
            if let (Some(b), Some(d)) = (m.batched, m.dispatched) {
                out.batch_wait.record(d.saturating_sub(b));
            }
            if m.saw_wait {
                out.device_wait.record(m.dev_wait_us);
            }
            if m.saw_hold {
                out.device_hold.record(m.dev_held_us);
            }
            if let (Some(d), Some(r)) = (m.dispatched, m.reply) {
                let work_end = m.last_device.unwrap_or(d).max(d);
                out.writer_wait.record(r.saturating_sub(work_end));
            }
            if let (Some(a), Some(r)) = (m.admitted, m.reply) {
                out.e2e.record(r.saturating_sub(a));
            }
        }
        out
    }

    /// Exact total microseconds the snapshot's `device_release` events
    /// held `dev` — the same accumulation (and truncation)
    /// [`crate::metrics::device::ArbiterCounters::holds`] reports, so
    /// on a fully traced shared node the two match to the microsecond.
    pub fn hold_us(&self, dev: Resource) -> u64 {
        self.hold_us[res_idx(dev)]
    }

    /// Total bytes the snapshot saw cross the simulated link.
    pub fn dma_bytes(&self) -> u64 {
        self.dma_bytes
    }

    /// The stage histograms in [`super::STAGE_NAMES`] order.
    pub fn stages(&self) -> [&LogHistogram; STAGES] {
        [
            &self.admission_wait,
            &self.queue_wait,
            &self.batch_wait,
            &self.device_wait,
            &self.device_hold,
            &self.writer_wait,
        ]
    }

    /// Summarize into the wire-serializable [`NodeStats`].
    pub fn summary(&self) -> NodeStats {
        let mut stats = NodeStats::default();
        for (slot, h) in stats.stages.iter_mut().zip(self.stages()) {
            *slot = StageStats {
                count: h.count(),
                mean_us: h.mean().round() as u64,
                p50_us: h.quantile(0.5),
                p99_us: h.quantile(0.99),
            };
        }
        stats
    }
}

/// A drained view of the recorder: every ring's events (time-sorted),
/// the track table, loss counters and the derived stage breakdown.
#[derive(Debug, Clone, Default)]
pub struct TraceSnapshot {
    /// All events, sorted by timestamp (ties by tid).
    pub events: Vec<TracedEvent>,
    /// `(tid, thread name)` per viewer track, deduplicated by tid.
    pub tracks: Vec<(u32, String)>,
    /// Events dropped ring-side because a snapshot held the copy lock.
    pub dropped: u64,
    /// Events overwritten ring-side because a ring was full.
    pub overwritten: u64,
    /// The per-stage latency breakdown over `events`.
    pub breakdown: StageBreakdown,
}

impl TraceSnapshot {
    /// Copy `rings` out into a snapshot (called by
    /// [`super::Recorder::snapshot`]).
    pub(super) fn collect(rings: &[Arc<ThreadRing>]) -> Self {
        let mut events = Vec::new();
        let mut tracks: Vec<(u32, String)> = Vec::new();
        let mut dropped = 0;
        let mut overwritten = 0;
        for ring in rings {
            dropped += ring.dropped();
            overwritten += ring.overwritten();
            if !tracks.iter().any(|(tid, _)| *tid == ring.tid()) {
                tracks.push((ring.tid(), ring.name().to_string()));
            }
            for event in ring.copy_events() {
                events.push(TracedEvent { tid: ring.tid(), event });
            }
        }
        events.sort_by_key(|te| (te.event.t_us, te.tid));
        tracks.sort_by_key(|(tid, _)| *tid);
        let breakdown = StageBreakdown::from_events(&events);
        Self { events, tracks, dropped, overwritten, breakdown }
    }

    /// Per-trace span-chain accounting: how many `admitted` and
    /// `reply_written` events each [`TraceId`] produced. A well-formed
    /// run has exactly `(1, 1)` per entry.
    pub fn chains(&self) -> BTreeMap<TraceId, (usize, usize)> {
        let mut chains: BTreeMap<TraceId, (usize, usize)> = BTreeMap::new();
        for te in &self.events {
            match te.event.kind {
                EventKind::Admitted => chains.entry(te.event.trace).or_default().0 += 1,
                EventKind::ReplyWritten => chains.entry(te.event.trace).or_default().1 += 1,
                _ => {}
            }
        }
        chains
    }

    /// Export the measured run in Chrome trace-event JSON, on the same
    /// device tracks (tid / thread name / `cat`) as the predicted
    /// [`crate::sched::trace::model_trace_json`] timeline: device holds
    /// become complete ("X") spans on tids 1–3, request lifetimes
    /// become spans on the virtual "requests" track, and every other
    /// recorded event becomes a thread-scoped instant ("i") on its
    /// ring's track.
    pub fn chrome_trace_json(&self) -> String {
        use crate::sched::trace::escape;
        let mut out = String::from("{\"traceEvents\":[");
        let mut first = true;
        let mut push = |out: &mut String, first: &mut bool, ev: String| {
            if !*first {
                out.push(',');
            }
            *first = false;
            out.push_str(&ev);
        };
        push(
            &mut out,
            &mut first,
            "{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":1,\
             \"args\":{\"name\":\"measured run (flight recorder)\"}}"
                .to_string(),
        );
        // device tracks carry their canonical names even when several
        // lane rings share the tid; the requests track is virtual
        let mut tracks = self.tracks.clone();
        if !tracks.iter().any(|(tid, _)| *tid == REQUESTS_TID) {
            tracks.push((REQUESTS_TID, "requests".to_string()));
            tracks.sort_by_key(|(tid, _)| *tid);
        }
        for (tid, name) in &tracks {
            let name = match [Resource::Gpu, Resource::Fpga, Resource::Link]
                .into_iter()
                .find(|r| device_track(*r).0 == *tid)
            {
                Some(r) => device_track(r).1.to_string(),
                None => name.clone(),
            };
            push(
                &mut out,
                &mut first,
                format!(
                    "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":1,\"tid\":{tid},\
                     \"args\":{{\"name\":\"{}\"}}}}",
                    escape(&name)
                ),
            );
        }
        // per-request lifetime spans on the virtual requests track
        let mut lifetime: BTreeMap<TraceId, (Option<u64>, Option<u64>)> = BTreeMap::new();
        for te in &self.events {
            let slot = lifetime.entry(te.event.trace).or_default();
            match te.event.kind {
                EventKind::Admitted => slot.0 = slot.0.or(Some(te.event.t_us)),
                EventKind::ReplyWritten => slot.1 = slot.1.or(Some(te.event.t_us)),
                _ => {}
            }
        }
        for (trace, (admitted, reply)) in &lifetime {
            if let (Some(a), Some(r)) = (admitted, reply) {
                push(
                    &mut out,
                    &mut first,
                    format!(
                        "{{\"name\":\"request\",\"cat\":\"Request\",\"ph\":\"X\",\"ts\":{a},\
                         \"dur\":{},\"pid\":1,\"tid\":{REQUESTS_TID},\
                         \"args\":{{\"trace\":{}}}}}",
                        r.saturating_sub(*a),
                        trace.0
                    ),
                );
            }
        }
        for te in &self.events {
            let t = te.event.t_us;
            let trace = te.event.trace.0;
            match te.event.kind {
                // a release closes a hold span: [t - held, t] on the
                // device track, cat = the Resource debug string the
                // predicted emitter uses
                EventKind::DeviceRelease { dev, held_us } => push(
                    &mut out,
                    &mut first,
                    format!(
                        "{{\"name\":\"hold\",\"cat\":\"{dev:?}\",\"ph\":\"X\",\"ts\":{},\
                         \"dur\":{held_us},\"pid\":1,\"tid\":{},\
                         \"args\":{{\"trace\":{trace}}}}}",
                        t.saturating_sub(held_us),
                        device_track(dev).0
                    ),
                ),
                EventKind::DeviceAcquire { .. } | EventKind::DeviceHold { .. } => {}
                kind => push(
                    &mut out,
                    &mut first,
                    format!(
                        "{{\"name\":\"{}\",\"cat\":\"Request\",\"ph\":\"i\",\"ts\":{t},\
                         \"pid\":1,\"tid\":{},\"s\":\"t\",\"args\":{{\"trace\":{trace}}}}}",
                        kind.name(),
                        te.tid
                    ),
                ),
            }
        }
        out.push_str("]}");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::json;
    use crate::obs::Recorder;

    fn traced(tid: u32, trace: u64, t_us: u64, kind: EventKind) -> TracedEvent {
        TracedEvent { tid, event: Event { trace: TraceId(trace), t_us, kind } }
    }

    #[test]
    fn breakdown_tiles_a_simple_request() {
        let events = vec![
            traced(10, 1, 100, EventKind::Admitted),
            traced(10, 1, 110, EventKind::Enqueued),
            traced(11, 1, 150, EventKind::Batched { size: 1 }),
            traced(11, 1, 180, EventKind::DispatchedLane),
            traced(1, 1, 200, EventKind::DeviceHold { dev: Resource::Gpu, wait_us: 5 }),
            traced(1, 1, 400, EventKind::DeviceRelease { dev: Resource::Gpu, held_us: 200 }),
            traced(12, 1, 410, EventKind::ReplyWritten),
        ];
        let b = StageBreakdown::from_events(&events);
        assert_eq!(b.admission_wait.quantile(0.5), 10);
        assert_eq!(b.queue_wait.quantile(0.5), 40);
        assert_eq!(b.batch_wait.quantile(0.5), 30);
        assert_eq!(b.device_wait.quantile(0.5), 5);
        assert_eq!(b.device_hold.quantile(0.5), 200);
        assert_eq!(b.writer_wait.quantile(0.5), 10);
        assert_eq!(b.e2e.quantile(0.5), 310);
        assert_eq!(b.hold_us(Resource::Gpu), 200);
        assert_eq!(b.hold_us(Resource::Fpga), 0);
        // the stage means tile the end-to-end span up to scheduling gaps
        let sum: f64 = b.stages().iter().map(|h| h.mean()).sum();
        assert!((sum - 295.0).abs() < 1e-9, "summed means {sum}");
    }

    #[test]
    fn summary_matches_the_histograms() {
        let events = vec![
            traced(10, 1, 0, EventKind::Admitted),
            traced(10, 1, 7, EventKind::Enqueued),
            traced(10, 1, 9, EventKind::ReplyWritten),
        ];
        let b = StageBreakdown::from_events(&events);
        let s = b.summary();
        assert_eq!(s.stages[0].count, 1);
        assert_eq!(s.stages[0].p50_us, 7);
        assert_eq!(s.stages[0].mean_us, 7);
        assert_eq!(s.stages[1].count, 0, "no batcher events -> empty queue stage");
        assert!(!s.is_empty());
    }

    #[test]
    fn chains_count_span_endpoints_per_trace() {
        let rec = Recorder::new(64);
        let ring = rec.register("t");
        ring.emit(TraceId(1), EventKind::Admitted);
        ring.emit(TraceId(1), EventKind::ReplyWritten);
        ring.emit(TraceId(2), EventKind::Admitted);
        let chains = rec.snapshot().chains();
        assert_eq!(chains[&TraceId(1)], (1, 1));
        assert_eq!(chains[&TraceId(2)], (1, 0));
    }

    #[test]
    fn chrome_export_parses_and_lands_holds_on_device_tracks() {
        let rec = Recorder::new(64);
        let caller = rec.register("caller");
        let gpu = rec.lane_obs(Resource::Gpu);
        let link = rec.lane_obs(Resource::Link);
        caller.emit(TraceId(1), EventKind::Admitted);
        caller.emit(TraceId(1), EventKind::Enqueued);
        gpu.acquire(Some(TraceId(1)));
        gpu.release(Some(TraceId(1)), 0, 120);
        link.dma(Some(TraceId(1)), 2048);
        link.release(Some(TraceId(1)), 3, 40);
        caller.emit(TraceId(1), EventKind::ReplyWritten);
        let text = rec.snapshot().chrome_trace_json();
        let doc = json::parse(&text).expect("chrome export must be valid JSON");
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let mut x_tids = std::collections::BTreeSet::new();
        let mut cats = std::collections::BTreeSet::new();
        let mut metas = std::collections::BTreeSet::new();
        for e in events {
            match e.get("ph").and_then(json::Json::as_str) {
                Some("X") => {
                    x_tids.insert(e.get("tid").unwrap().as_usize().unwrap());
                    if let Some(c) = e.get("cat").and_then(json::Json::as_str) {
                        cats.insert(c.to_string());
                    }
                }
                Some("M") => {
                    metas.insert(e.get("name").unwrap().as_str().unwrap().to_string());
                }
                _ => {}
            }
        }
        // device holds on tids 1 (Gpu) and 3 (Link), request span on 4
        assert!(x_tids.contains(&1) && x_tids.contains(&3) && x_tids.contains(&4), "{x_tids:?}");
        assert!(cats.contains("Gpu") && cats.contains("Link"), "{cats:?}");
        assert!(metas.contains("process_name") && metas.contains("thread_name"), "{metas:?}");
    }
}
