//! The recorder: fixed-capacity per-thread event rings behind one
//! engine-scoped handle.
//!
//! Hot-path contract (DESIGN.md §15): [`ThreadRing::emit`] never blocks.
//! Each ring is written by exactly one thread, so its `try_lock` only
//! ever contends with a concurrent snapshot — and then the event is
//! *dropped and counted*, never waited for. A full ring overwrites its
//! oldest event (also counted), so a recorder left on forever costs
//! bounded memory.

use super::{Event, EventKind, TraceId};
use crate::partition::Resource;
use crate::sched::trace::device_track;
use std::cell::RefCell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Default ring capacity (events per thread): generous enough that a
/// test or bench run never overwrites, small enough (~48 B/event) that
/// an always-on recorder stays a few MB per thread.
pub const DEFAULT_RING_CAPACITY: usize = 65_536;

/// First tid handed to non-device threads. Tids 1–3 are the device
/// lanes (shared with the predicted-timeline emitter, see
/// [`device_track`]); tid 4 is the export's virtual "requests" track.
const FIRST_DYNAMIC_TID: u32 = 10;

/// Recorder instances get process-unique ids so the per-thread ring
/// cache in [`Recorder::emit`] never mixes engines.
static NEXT_RECORDER_ID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    /// (recorder id, this thread's ring) — the fast path of
    /// [`Recorder::emit`] for threads the engine does not register
    /// explicitly (callers, workers, the batcher).
    static CURRENT_RING: RefCell<Option<(u64, Arc<ThreadRing>)>> = const { RefCell::new(None) };
}

/// One thread's fixed-capacity event ring.
#[derive(Debug)]
pub struct ThreadRing {
    tid: u32,
    name: String,
    epoch: Instant,
    capacity: usize,
    events: Mutex<VecDeque<Event>>,
    dropped: AtomicU64,
    overwritten: AtomicU64,
}

impl ThreadRing {
    fn new(tid: u32, name: String, epoch: Instant, capacity: usize) -> Self {
        Self {
            tid,
            name,
            epoch,
            capacity,
            events: Mutex::new(VecDeque::with_capacity(capacity.min(1024))),
            dropped: AtomicU64::new(0),
            overwritten: AtomicU64::new(0),
        }
    }

    /// The ring's trace-viewer thread id.
    pub fn tid(&self) -> u32 {
        self.tid
    }

    /// The ring's trace-viewer thread name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Append one event. **Never blocks**: if the ring is locked by a
    /// concurrent snapshot the event is dropped (counted in
    /// [`ThreadRing::dropped`]) and `false` is returned; if the ring is
    /// full the oldest event is overwritten (counted in
    /// [`ThreadRing::overwritten`]).
    pub fn emit(&self, trace: TraceId, kind: EventKind) -> bool {
        let t_us = self.epoch.elapsed().as_micros() as u64;
        match self.events.try_lock() {
            Ok(mut q) => {
                if q.len() >= self.capacity {
                    q.pop_front();
                    self.overwritten.fetch_add(1, Ordering::Relaxed);
                }
                q.push_back(Event { trace, t_us, kind });
                true
            }
            Err(_) => {
                self.dropped.fetch_add(1, Ordering::Relaxed);
                false
            }
        }
    }

    /// Events dropped because the ring was locked by a snapshot.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Events overwritten because the ring was full.
    pub fn overwritten(&self) -> u64 {
        self.overwritten.load(Ordering::Relaxed)
    }

    /// Copy the ring's events out (used by snapshots; blocks only the
    /// snapshot taker, never the emitting thread).
    pub(super) fn copy_events(&self) -> Vec<Event> {
        match self.events.lock() {
            Ok(q) => q.iter().copied().collect(),
            Err(poisoned) => poisoned.into_inner().iter().copied().collect(),
        }
    }
}

/// Per-lane emission handle: a device ring plus the lane's resource,
/// so the hetero lane loop emits acquire/hold/release/dma with one call
/// each (all no-ops when the job carries no trace).
#[derive(Clone)]
pub struct LaneObs {
    ring: Arc<ThreadRing>,
    dev: Resource,
}

impl LaneObs {
    /// The lane asked for its device.
    pub fn acquire(&self, trace: Option<TraceId>) {
        if let Some(t) = trace {
            self.ring.emit(t, EventKind::DeviceAcquire { dev: self.dev });
        }
    }

    /// The device was granted after `wait_us` and held for `held_us`
    /// (emitted together once the hold ends; the snapshot reconstructs
    /// the hold span from `held_us`).
    pub fn release(&self, trace: Option<TraceId>, wait_us: u64, held_us: u64) {
        if let Some(t) = trace {
            self.ring.emit(t, EventKind::DeviceHold { dev: self.dev, wait_us });
            self.ring.emit(t, EventKind::DeviceRelease { dev: self.dev, held_us });
        }
    }

    /// One DMA crossing of `bytes` (link lanes only).
    pub fn dma(&self, trace: Option<TraceId>, bytes: u64) {
        if let Some(t) = trace {
            self.ring.emit(t, EventKind::LinkDma { bytes });
        }
    }
}

/// The engine-scoped flight recorder: owns every thread ring and the
/// shared epoch all timestamps are relative to.
#[derive(Debug)]
pub struct Recorder {
    id: u64,
    epoch: Instant,
    capacity: usize,
    rings: Mutex<Vec<Arc<ThreadRing>>>,
    next_tid: AtomicU32,
}

impl Recorder {
    /// New recorder with `capacity` events per thread ring.
    pub fn new(capacity: usize) -> Self {
        Self {
            id: NEXT_RECORDER_ID.fetch_add(1, Ordering::Relaxed),
            epoch: Instant::now(),
            capacity: capacity.max(1),
            rings: Mutex::new(Vec::new()),
            next_tid: AtomicU32::new(FIRST_DYNAMIC_TID),
        }
    }

    /// New recorder at the default ring capacity.
    pub fn with_default_capacity() -> Self {
        Self::new(DEFAULT_RING_CAPACITY)
    }

    /// The instant all event timestamps are measured from.
    pub fn epoch(&self) -> Instant {
        self.epoch
    }

    fn push_ring(&self, ring: Arc<ThreadRing>) -> Arc<ThreadRing> {
        match self.rings.lock() {
            Ok(mut v) => v.push(ring.clone()),
            Err(poisoned) => poisoned.into_inner().push(ring.clone()),
        }
        ring
    }

    /// Register a ring for the calling (engine-managed) thread under an
    /// explicit `name`; dynamic tids start at 10.
    pub fn register(&self, name: &str) -> Arc<ThreadRing> {
        let tid = self.next_tid.fetch_add(1, Ordering::Relaxed);
        self.push_ring(Arc::new(ThreadRing::new(tid, name.to_string(), self.epoch, self.capacity)))
    }

    /// Register a device-lane ring: the tid and track name come from
    /// the shared [`device_track`] table, so measured device events
    /// land on the same viewer tracks as the predicted timeline.
    pub fn register_device(&self, dev: Resource) -> Arc<ThreadRing> {
        let (tid, name) = device_track(dev);
        self.push_ring(Arc::new(ThreadRing::new(tid, name.to_string(), self.epoch, self.capacity)))
    }

    /// Per-lane emission handle over a freshly registered device ring.
    pub fn lane_obs(&self, dev: Resource) -> LaneObs {
        LaneObs { ring: self.register_device(dev), dev }
    }

    /// Emit one event from the calling thread, registering it on first
    /// use (ring handle cached thread-locally; the thread's name labels
    /// its track). A `None` trace is a no-op — call sites pass the
    /// request's optional trace straight through.
    pub fn emit(&self, trace: Option<TraceId>, kind: EventKind) {
        let Some(trace) = trace else { return };
        CURRENT_RING.with(|cell| {
            let mut cached = cell.borrow_mut();
            match cached.as_ref() {
                Some((id, ring)) if *id == self.id => {
                    ring.emit(trace, kind);
                }
                _ => {
                    let name = std::thread::current()
                        .name()
                        .map(str::to_string)
                        .unwrap_or_else(|| "caller".to_string());
                    let ring = self.register(&name);
                    ring.emit(trace, kind);
                    *cached = Some((self.id, ring));
                }
            }
        });
    }

    /// Snapshot every ring into a [`super::TraceSnapshot`] (events are
    /// copied, not drained — a later snapshot sees the same history
    /// plus whatever arrived in between, up to ring capacity).
    pub fn snapshot(&self) -> super::TraceSnapshot {
        let rings: Vec<Arc<ThreadRing>> = match self.rings.lock() {
            Ok(v) => v.clone(),
            Err(poisoned) => poisoned.into_inner().clone(),
        };
        super::TraceSnapshot::collect(&rings)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn emit_appends_and_full_ring_overwrites_oldest() {
        let rec = Recorder::new(3);
        let ring = rec.register("t");
        for i in 0..5u64 {
            assert!(ring.emit(TraceId(i), EventKind::Admitted));
        }
        assert_eq!(ring.overwritten(), 2);
        assert_eq!(ring.dropped(), 0);
        let events = ring.copy_events();
        let ids: Vec<u64> = events.iter().map(|e| e.trace.0).collect();
        assert_eq!(ids, vec![2, 3, 4], "oldest events overwritten first");
    }

    #[test]
    fn emit_under_a_held_lock_drops_instead_of_blocking() {
        let rec = Recorder::new(8);
        let ring = rec.register("t");
        assert!(ring.emit(TraceId(1), EventKind::Admitted));
        let guard = ring.events.lock().unwrap();
        // the ring is locked (as during a snapshot copy): emit must
        // return immediately with the event dropped, not block
        assert!(!ring.emit(TraceId(2), EventKind::ReplyWritten));
        drop(guard);
        assert_eq!(ring.dropped(), 1);
        assert_eq!(ring.copy_events().len(), 1);
    }

    #[test]
    fn recorder_emit_registers_the_calling_thread_once() {
        let rec = Recorder::new(16);
        rec.emit(Some(TraceId(7)), EventKind::Admitted);
        rec.emit(Some(TraceId(7)), EventKind::ReplyWritten);
        rec.emit(None, EventKind::CacheHit); // no-op
        let snap = rec.snapshot();
        assert_eq!(snap.events.len(), 2);
        let tids: std::collections::BTreeSet<u32> =
            snap.events.iter().map(|e| e.tid).collect();
        assert_eq!(tids.len(), 1, "one thread -> one ring");
    }

    #[test]
    fn device_rings_use_the_shared_track_table() {
        let rec = Recorder::new(16);
        for dev in [Resource::Gpu, Resource::Fpga, Resource::Link] {
            let ring = rec.register_device(dev);
            let (tid, name) = device_track(dev);
            assert_eq!(ring.tid(), tid);
            assert_eq!(ring.name(), name);
        }
        // dynamic tids never collide with the device tracks
        assert!(rec.register("x").tid() >= FIRST_DYNAMIC_TID);
    }

    #[test]
    fn lane_obs_emits_the_device_vocabulary() {
        let rec = Recorder::new(16);
        let obs = rec.lane_obs(Resource::Link);
        obs.acquire(Some(TraceId(1)));
        obs.release(Some(TraceId(1)), 5, 40);
        obs.dma(Some(TraceId(1)), 1024);
        obs.acquire(None); // no-op without a trace
        let snap = rec.snapshot();
        let names: Vec<&str> = snap.events.iter().map(|e| e.event.kind.name()).collect();
        assert_eq!(names, vec!["device_acquire", "device_hold", "device_release", "link_dma"]);
    }
}
