//! Optimal fabric allocation by dynamic programming — the exact
//! counterpart of [`super::Planner::plan_model`]'s greedy knapsack.
//!
//! The shared-fabric allocation problem is a 0/1 knapsack with one
//! flexible item class: each non-Fire module contributes one
//! all-or-nothing candidate (its paper-strategy plan, with ALM weight and
//! energy-saving value), and each Fire module contributes a *menu* of
//! mutually exclusive candidates (one per GConv share g). We solve it
//! exactly with a DP over a quantized ALM axis and compare against the
//! greedy allocator — the `greedy_vs_dp` ablation bench quantifies the
//! optimality gap (and thereby justifies shipping the greedy planner on
//! the request path).

use crate::graph::{ModelGraph, ModuleKind};
use crate::metrics::Cost;
use crate::partition::{ModelPlan, ModulePlan, Planner, Strategy};
use crate::sched::{self, IdleParams};

/// ALM quantum for the DP axis. 256 ALMs per cell keeps the table small
/// (~300 columns for the GX220) with < 0.4% rounding on the budget.
pub const ALM_QUANTUM: u64 = 256;

struct Candidate {
    module_idx: usize,
    plan: ModulePlan,
    cells: usize,
    saving: f64,
}

/// Result of the exact allocation.
pub struct DpAllocation {
    pub plan: ModelPlan,
    /// Total energy saving vs GPU-only under paper idle params.
    pub saving: f64,
    /// ALM cells used / available.
    pub cells_used: usize,
    pub cells_total: usize,
}

/// Exact shared-fabric allocation for a model.
pub fn plan_model_dp(planner: &Planner, g: &ModelGraph) -> DpAllocation {
    let dhm = planner.sdhm();
    let ceiling = (dhm.dev.alms as f64 * dhm.dev.util_ceiling) as u64;
    let cells_total = (ceiling / ALM_QUANTUM) as usize;

    let base_plans: Vec<ModulePlan> = g.modules.iter().map(|m| planner.plan_gpu_only(m)).collect();
    let base_costs: Vec<Cost> = base_plans
        .iter()
        .map(|p| sched::evaluate_cost(p, IdleParams::paper()))
        .collect();

    // build the candidate menus: group[i] = mutually exclusive options for
    // module i (not taking any option = GPU-only)
    let mut menus: Vec<Vec<Candidate>> = Vec::new();
    for (idx, m) in g.modules.iter().enumerate() {
        let mut menu = Vec::new();
        let mut push = |plan: ModulePlan| {
            let c = sched::evaluate_cost(&plan, IdleParams::paper());
            let base = base_costs[idx];
            let saving = base.joules - c.joules;
            if saving > 0.0 && c.seconds <= base.seconds * 1.02 {
                let cells = (plan.fpga_usage().alms.div_ceil(ALM_QUANTUM)) as usize;
                menu.push(Candidate { module_idx: idx, plan, cells, saving });
            }
        };
        if m.kind == ModuleKind::Fire {
            // menu over GConv shares: probe a log-spaced ladder of budgets
            let mut seen = std::collections::BTreeSet::new();
            let mut budget = ceiling;
            while budget >= ALM_QUANTUM {
                if let Ok(plan) = planner.plan_gconv_split_budgeted(m, Some(budget)) {
                    let cells = plan.fpga_usage().alms;
                    if seen.insert(cells) {
                        push(plan);
                    }
                }
                budget /= 2;
            }
        } else {
            let want = Planner::paper_strategy(m.kind);
            if want != Strategy::GpuOnly {
                if let Ok(plan) = planner.plan_module(m, want) {
                    push(plan);
                }
            }
        }
        if !menu.is_empty() {
            menus.push(menu);
        }
    }

    // DP over (menu group, cells): value = max saving
    // choice[g][c] = Some(option index in group g) if taken
    let n_groups = menus.len();
    let mut value = vec![vec![0.0f64; cells_total + 1]; n_groups + 1];
    let mut choice = vec![vec![usize::MAX; cells_total + 1]; n_groups];
    for gi in 0..n_groups {
        for c in 0..=cells_total {
            // skip this group's module
            value[gi + 1][c] = value[gi][c];
            choice[gi][c] = usize::MAX;
            for (oi, cand) in menus[gi].iter().enumerate() {
                if cand.cells <= c {
                    let v = value[gi][c - cand.cells] + cand.saving;
                    if v > value[gi + 1][c] {
                        value[gi + 1][c] = v;
                        choice[gi][c] = oi;
                    }
                }
            }
        }
    }

    // backtrack
    let mut plans = base_plans;
    let mut c = cells_total;
    let mut cells_used = 0;
    for gi in (0..n_groups).rev() {
        let oi = choice[gi][c];
        if oi != usize::MAX {
            let cand = &menus[gi][oi];
            plans[cand.module_idx] = cand.plan.clone();
            c -= cand.cells;
            cells_used += cand.cells;
        }
    }

    DpAllocation {
        plan: ModelPlan {
            model_name: g.name.clone(),
            strategy: Strategy::Auto,
            modules: plans,
        },
        saving: value[n_groups][cells_total],
        cells_used,
        cells_total,
    }
}

/// Energy saving of a plan vs its GPU-only baseline (paper idle params).
pub fn plan_saving(planner: &Planner, g: &ModelGraph, plan: &ModelPlan) -> f64 {
    let base = sched::evaluate_model_with(
        &planner.plan_model(g, Strategy::GpuOnly),
        IdleParams::paper(),
    );
    let ours = sched::evaluate_model_with(plan, IdleParams::paper());
    base.total.joules - ours.total.joules
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;

    fn planner() -> Planner {
        Planner::default()
    }

    #[test]
    fn dp_respects_budget() {
        let p = planner();
        for g in models::all_models() {
            let alloc = plan_model_dp(&p, &g);
            assert!(alloc.cells_used <= alloc.cells_total, "{}", g.name);
            let dhm = p.sdhm();
            let ceiling = (dhm.dev.alms as f64 * dhm.dev.util_ceiling) as u64;
            assert!(
                alloc.plan.fpga_usage().alms <= ceiling + ALM_QUANTUM * 4,
                "{}: {} ALMs",
                g.name,
                alloc.plan.fpga_usage().alms
            );
        }
    }

    #[test]
    fn dp_at_least_as_good_as_greedy() {
        let p = planner();
        for g in models::all_models() {
            let greedy = p.plan_model(&g, Strategy::Auto);
            let dp = plan_model_dp(&p, &g);
            let gs = plan_saving(&p, &g, &greedy);
            let ds = plan_saving(&p, &g, &dp.plan);
            assert!(
                ds >= gs * 0.999,
                "{}: dp {} < greedy {}",
                g.name,
                ds,
                gs
            );
        }
    }

    #[test]
    fn dp_saving_is_nonnegative_and_consistent() {
        let p = planner();
        let g = models::squeezenet(224);
        let alloc = plan_model_dp(&p, &g);
        assert!(alloc.saving >= 0.0);
        let realized = plan_saving(&p, &g, &alloc.plan);
        // DP objective == realized saving (same evaluation both ways)
        assert!(
            (alloc.saving - realized).abs() <= 1e-9 + realized.abs() * 1e-6,
            "{} vs {realized}",
            alloc.saving
        );
    }

    #[test]
    fn dp_on_tiny_budget_degenerates_to_gpu_only() {
        let mut p = planner();
        // shrink the device to near nothing
        p.dhm.dev.alms = 100;
        let g = models::mobilenetv2_05(224);
        let alloc = plan_model_dp(&p, &g);
        assert_eq!(alloc.cells_used, 0);
        assert!(!alloc.plan.uses_fpga());
    }
}
