//! GPU/FPGA partitioning engine — the paper's §IV contribution.
//!
//! A [`Planner`] turns each [`Module`] into a device-annotated [`ModulePlan`]
//! under one of the paper's strategies (Fig 2):
//!
//! - [`Strategy::GpuOnly`] — the homogeneous baseline the paper compares
//!   against (every layer a CUDA kernel, data-movement ops included).
//! - [`Strategy::DwSplit`] — Fig 2a: the k x k (depth-wise) stage stays on
//!   the GPU, the 1x1 convolution is delegated to the FPGA (sequential
//!   GPU -> PCIe -> FPGA -> PCIe handoff). Used for MobileNetV2.
//! - [`Strategy::GConvSplit`] — Fig 2b: the convolution is re-expressed as
//!   a 2-group grouped convolution; the FPGA takes `g` input channels and
//!   the proportional share of filters, the GPU the rest, both run *in
//!   parallel* and OFMs are concatenated. Used for SqueezeNet Fire.
//! - [`Strategy::FusedLayer`] — Fig 2c: a whole chain of small layers is
//!   DHM-resident on the FPGA; intermediates never cross PCIe. Used for
//!   ShuffleNetV2 right branches.
//! - [`Strategy::FpgaOnly`] — everything DHM-mapped when it fits (Fig 1's
//!   blue bars).
//! - [`Strategy::Paper`] — per module kind, the mapping the paper uses
//!   (Fire -> GConvSplit, Bottleneck -> DwSplit, Shuffle -> FusedLayer).
//! - [`Strategy::Auto`] — per module, the best-energy applicable plan whose
//!   latency does not exceed GPU-only (the paper's acceptance criterion).
//!
//! ## The shared fabric (whole-network planning)
//!
//! DHM cannot reconfigure between layers (a Cyclone 10 reconfiguration
//! takes ~100 ms, vs ~10 ms inference), so **every FPGA-resident piece of
//! the network coexists on the device** — the paper states it maps "all
//! the 1x1 convolution on the FPGA for all layers". [`Planner::plan_model`]
//! therefore runs a global allocation: each module nominates its FPGA
//! piece, and a greedy knapsack (energy saving per ALM, subject to the
//! module's latency not regressing) grants fabric until the device is
//! full; Fire modules then split the leftover fabric evenly among
//! themselves via the GConv share knob. Modules that lose allocation fall
//! back to the GPU.

pub mod dp;

use crate::dhm::{DhmModel, ResourceUsage};
use crate::gpu::GpuModel;
use crate::graph::{Layer, Module, ModuleKind, ModelGraph, OpKind, TensorShape};
use crate::link::{LinkModel, Precision};
use crate::metrics::Cost;

/// Partitioning strategy selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    GpuOnly,
    FpgaOnly,
    DwSplit,
    GConvSplit,
    FusedLayer,
    /// The paper's per-module-kind mapping (Fig 2 as published).
    Paper,
    /// Best-energy plan under the latency acceptance criterion.
    Auto,
}

impl Strategy {
    /// Every strategy, in declaration order — the single list CLI parsing,
    /// sweeps and help text draw from (no more hand-rolled enumerations).
    pub const ALL: [Strategy; 7] = [
        Strategy::GpuOnly,
        Strategy::FpgaOnly,
        Strategy::DwSplit,
        Strategy::GConvSplit,
        Strategy::FusedLayer,
        Strategy::Paper,
        Strategy::Auto,
    ];

    /// The concrete single-module strategies (everything except the
    /// composite `Paper`/`Auto` selectors) — what per-module exploration
    /// sweeps iterate.
    pub const MODULE_LEVEL: [Strategy; 5] = [
        Strategy::GpuOnly,
        Strategy::FpgaOnly,
        Strategy::DwSplit,
        Strategy::GConvSplit,
        Strategy::FusedLayer,
    ];

    /// The stable CLI/display name (what `Strategy::from_str` parses).
    pub fn name(&self) -> &'static str {
        match self {
            Strategy::GpuOnly => "gpu-only",
            Strategy::FpgaOnly => "fpga-only",
            Strategy::DwSplit => "dw-split",
            Strategy::GConvSplit => "gconv-split",
            Strategy::FusedLayer => "fused-layer",
            Strategy::Paper => "paper",
            Strategy::Auto => "auto",
        }
    }
}

impl std::fmt::Display for Strategy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for Strategy {
    type Err = String;

    /// Parse a strategy by its display name (the inverse of `Display`).
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Strategy::ALL.iter().copied().find(|k| k.name() == s).ok_or_else(|| {
            let names: Vec<&str> = Strategy::ALL.iter().map(Strategy::name).collect();
            format!("unknown strategy {s:?} (one of: {})", names.join(" | "))
        })
    }
}

/// Which engine a step occupies (for busy/idle accounting).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Resource {
    Gpu,
    Fpga,
    Link,
}

/// One scheduled operation with its pre-computed cost.
#[derive(Debug, Clone)]
pub enum Step {
    /// A CUDA kernel dispatch.
    Gpu { label: String, layer: Layer, cost: Cost },
    /// A framework data-movement kernel (concat / shuffle / split / add).
    GpuData { label: String, cost: Cost },
    /// A DHM-resident (possibly fused) chain streaming one feature map.
    Fpga { label: String, layers: Vec<Layer>, usage: ResourceUsage, cost: Cost },
    /// A PCIe DMA transfer.
    Transfer { label: String, to_fpga: bool, elems: usize, prec: Precision, cost: Cost },
    /// Two branches racing; join = max latency (the paper's hiding).
    Parallel { gpu: Vec<Step>, fpga: Vec<Step> },
}

impl Step {
    /// Primary resource this step occupies (Parallel handled by caller).
    pub fn resource(&self) -> Resource {
        match self {
            Step::Gpu { .. } | Step::GpuData { .. } => Resource::Gpu,
            Step::Fpga { .. } => Resource::Fpga,
            Step::Transfer { .. } => Resource::Link,
            Step::Parallel { .. } => unreachable!("parallel spans resources"),
        }
    }
}

/// Device-annotated plan for one module.
#[derive(Debug, Clone)]
pub struct ModulePlan {
    pub module_name: String,
    pub kind: ModuleKind,
    pub strategy: Strategy,
    pub steps: Vec<Step>,
    /// True if any step touches the FPGA or link.
    pub uses_fpga: bool,
}

impl ModulePlan {
    /// Fabric this plan occupies (sum over its FPGA steps, incl. nested).
    pub fn fpga_usage(&self) -> ResourceUsage {
        fn walk(steps: &[Step], acc: &mut ResourceUsage) {
            for s in steps {
                match s {
                    Step::Fpga { usage, .. } => *acc = acc.add(*usage),
                    Step::Parallel { gpu, fpga } => {
                        walk(gpu, acc);
                        walk(fpga, acc);
                    }
                    _ => {}
                }
            }
        }
        let mut u = ResourceUsage::default();
        walk(&self.steps, &mut u);
        u
    }
}

/// A plan for the whole network.
#[derive(Debug, Clone)]
pub struct ModelPlan {
    pub model_name: String,
    pub strategy: Strategy,
    pub modules: Vec<ModulePlan>,
}

impl ModelPlan {
    pub fn uses_fpga(&self) -> bool {
        self.modules.iter().any(|m| m.uses_fpga)
    }

    /// Total fabric footprint of the resident set.
    pub fn fpga_usage(&self) -> ResourceUsage {
        self.modules
            .iter()
            .fold(ResourceUsage::default(), |acc, m| acc.add(m.fpga_usage()))
    }
}

/// Planning errors.
#[derive(Debug, thiserror::Error)]
pub enum PlanError {
    #[error("strategy {0} not applicable to module kind {1:?}")]
    NotApplicable(Strategy, ModuleKind),
    #[error("module {0} does not fit the FPGA: {1}")]
    DoesNotFit(String, crate::dhm::DhmError),
}

/// The partitioner: owns the three device models.
#[derive(Debug, Clone, Copy, Default)]
pub struct Planner {
    /// Standalone DHM model (full device per design — Fig 1 experiments).
    pub dhm: DhmModel,
    pub gpu: GpuModel,
    pub link: LinkModel,
    /// Co-located hetero tenants sharing this node's link *besides* the
    /// one being planned (0 = private devices, today's default). Under
    /// FIFO arbitration a transfer expects to queue behind half of each
    /// co-tenant's concurrent crossing on average, so every link step's
    /// time is inflated by `1 + extra_tenants/2` — the contention-aware
    /// cost hook that lets plans price expected queueing (DESIGN.md §14).
    pub extra_tenants: usize,
}

impl Planner {
    /// This planner, pricing link transfers as if `extra_tenants` other
    /// co-located models contend for the shared link.
    pub fn contended(mut self, extra_tenants: usize) -> Self {
        self.extra_tenants = extra_tenants;
        self
    }

    /// The multiplier applied to every link transfer's time under the
    /// expected-queueing model (energy is not inflated — waiting does
    /// not move bytes).
    pub fn link_contention_factor(&self) -> f64 {
        1.0 + self.extra_tenants as f64 * 0.5
    }

    /// Shared-fabric DHM model used for all module/network planning.
    pub fn sdhm(&self) -> DhmModel {
        DhmModel::shared(self.dhm.dev)
    }

    // ---------------------------------------------------------------- steps

    fn gpu_step(&self, label: &str, layer: Layer) -> Step {
        Step::Gpu { label: label.into(), layer, cost: self.gpu.cost(&layer) }
    }

    fn gpu_data(&self, label: &str, elems: usize) -> Step {
        let bytes = (elems * 4) as u64; // f32 on the GPU side
        Step::GpuData { label: label.into(), cost: self.gpu.data_movement_cost(bytes) }
    }

    fn fpga_step(&self, label: &str, layers: Vec<Layer>) -> Result<Step, PlanError> {
        let dhm = self.sdhm();
        let mut usage = ResourceUsage::default();
        for l in &layers {
            usage = usage.add(
                dhm.resources(l).map_err(|e| PlanError::DoesNotFit(label.into(), e))?,
            );
        }
        let cost = dhm
            .fused_cost(&layers)
            .map_err(|e| PlanError::DoesNotFit(label.into(), e))?;
        Ok(Step::Fpga { label: label.into(), layers, usage, cost })
    }

    fn xfer(&self, label: &str, to_fpga: bool, elems: usize, prec: Precision) -> Step {
        let mut cost = self.link.transfer(elems, prec);
        // expected queueing behind co-located tenants: time stretches,
        // the bytes (and so the energy) do not
        cost.seconds *= self.link_contention_factor();
        Step::Transfer { label: label.into(), to_fpga, elems, prec, cost }
    }

    // ------------------------------------------------------------ baselines

    /// GPU-only plan: every compute layer is a kernel; the module's implied
    /// data movement (concat / shuffle / residual add) is a kernel too —
    /// exactly what the PyTorch execution the paper measures does.
    pub fn plan_gpu_only(&self, m: &Module) -> ModulePlan {
        let mut steps = Vec::new();
        for (i, l) in m.layers.iter().enumerate() {
            steps.push(self.gpu_step(&format!("{}[{}]", m.name, i), *l));
        }
        match m.kind {
            ModuleKind::Fire => {
                steps.push(self.gpu_data("concat", m.output.elems()));
            }
            ModuleKind::Bottleneck { residual: true } => {
                steps.push(self.gpu_data("residual-add", m.output.elems()));
            }
            ModuleKind::ShuffleBasic | ModuleKind::ShuffleReduce => {
                steps.push(self.gpu_data("concat", m.output.elems()));
                steps.push(self.gpu_data("shuffle", m.output.elems()));
            }
            _ => {}
        }
        ModulePlan {
            module_name: m.name.clone(),
            kind: m.kind,
            strategy: Strategy::GpuOnly,
            steps,
            uses_fpga: false,
        }
    }

    /// FPGA-only plan: the whole module as one fused DHM chain (fails with
    /// the resource cliff for anything big — the paper's §III-A point).
    pub fn plan_fpga_only(&self, m: &Module) -> Result<ModulePlan, PlanError> {
        let compute: Vec<Layer> = m.layers.clone();
        let steps = vec![
            self.xfer("ifm->fpga", true, m.input.elems(), Precision::Int8),
            self.fpga_step(&m.name, compute)?,
            self.xfer("ofm->gpu", false, m.output.elems(), Precision::Int8),
        ];
        Ok(ModulePlan {
            module_name: m.name.clone(),
            kind: m.kind,
            strategy: Strategy::FpgaOnly,
            steps,
            uses_fpga: true,
        })
    }

    // ------------------------------------------------------- Fig 2a: DWConv

    /// DWConv split (MobileNetV2): k x k stage on GPU, 1x1 projection on
    /// FPGA, sequential with a PCIe round trip.
    pub fn plan_dw_split(&self, m: &Module) -> Result<ModulePlan, PlanError> {
        let ModuleKind::Bottleneck { residual } = m.kind else {
            return Err(PlanError::NotApplicable(Strategy::DwSplit, m.kind));
        };
        let n = m.layers.len();
        let (gpu_layers, proj) = m.layers.split_at(n - 1);
        let proj = proj[0];
        let mut steps = Vec::new();
        for (i, l) in gpu_layers.iter().enumerate() {
            steps.push(self.gpu_step(&format!("{}[{}]", m.name, i), *l));
        }
        steps.push(self.xfer("t->fpga", true, proj.input.elems(), Precision::Int8));
        steps.push(self.fpga_step(&format!("{}:project", m.name), vec![proj])?);
        steps.push(self.xfer("y->gpu", false, proj.output.elems(), Precision::Int8));
        if residual {
            steps.push(self.gpu_data("residual-add", m.output.elems()));
        }
        Ok(ModulePlan {
            module_name: m.name.clone(),
            kind: m.kind,
            strategy: Strategy::DwSplit,
            steps,
            uses_fpga: true,
        })
    }

    // ------------------------------------------------------- Fig 2b: GConv

    /// Re-express a dense conv as a 2-group GConv and take the largest
    /// FPGA share whose footprint fits `alm_budget` (None = whole device).
    /// Returns (fpga_layer, gpu_layer, g).
    fn gconv_halves(&self, conv: &Layer, alm_budget: Option<u64>) -> Option<(Layer, Layer, usize)> {
        let OpKind::Conv { k, stride, pad, cout, act } = conv.op else { return None };
        let ci = conv.input.c;
        let dhm = self.sdhm();
        let probe_of = |g: usize| {
            let co_f = (cout * g / ci).max(1);
            Layer::new(
                OpKind::Conv { k, stride, pad, cout: co_f, act },
                TensorShape::new(conv.input.h, conv.input.w, g),
            )
        };
        let fits = |g: usize| {
            let u = match dhm.resources(&probe_of(g)) {
                Ok(u) => u,
                Err(_) => return false,
            };
            if dhm.check_fit(u).is_err() {
                return false;
            }
            match alm_budget {
                Some(b) => u.alms <= b,
                None => true,
            }
        };
        let mut g_best = 0usize;
        for g in 1..ci {
            if fits(g) {
                g_best = g;
            }
        }
        if g_best == 0 {
            return None;
        }
        let g = g_best;
        let fpga = probe_of(g);
        let co_f = fpga.output.c;
        let gpu = Layer::new(
            OpKind::GConv { k, stride, groups: 1, cout: cout - co_f, act },
            TensorShape::new(conv.input.h, conv.input.w, ci - g),
        );
        Some((fpga, gpu, g))
    }

    /// GConv split for a Fire module: squeeze on GPU, then expand1x1 (GPU)
    /// and the FPGA share of expand3x3 run in parallel with the GPU share.
    /// `alm_budget` bounds the FPGA share (shared-fabric allocation).
    pub fn plan_gconv_split_budgeted(
        &self,
        m: &Module,
        alm_budget: Option<u64>,
    ) -> Result<ModulePlan, PlanError> {
        if m.kind != ModuleKind::Fire {
            return Err(PlanError::NotApplicable(Strategy::GConvSplit, m.kind));
        }
        let squeeze = m.layers[0];
        let expand1 = m.layers[1];
        let expand3 = m.layers[2];
        let (e3_fpga, e3_gpu, g) = self.gconv_halves(&expand3, alm_budget).ok_or_else(|| {
            PlanError::DoesNotFit(
                m.name.clone(),
                crate::dhm::DhmError::Unmappable("no feasible GConv share".into()),
            )
        })?;
        let gpu_branch = vec![
            self.gpu_step(&format!("{}:expand1", m.name), expand1),
            self.gpu_step(&format!("{}:expand3[{}ch]", m.name, e3_gpu.input.c), e3_gpu),
        ];
        let fpga_branch = vec![
            self.xfer(&format!("s[..{}]->fpga", g), true, e3_fpga.input.elems(), Precision::Int8),
            self.fpga_step(&format!("{}:expand3[{}ch]", m.name, g), vec![e3_fpga])?,
            self.xfer("ofm->gpu", false, e3_fpga.output.elems(), Precision::Int8),
        ];
        let steps = vec![
            self.gpu_step(&format!("{}:squeeze", m.name), squeeze),
            Step::Parallel { gpu: gpu_branch, fpga: fpga_branch },
            self.gpu_data("concat", m.output.elems()),
        ];
        Ok(ModulePlan {
            module_name: m.name.clone(),
            kind: m.kind,
            strategy: Strategy::GConvSplit,
            steps,
            uses_fpga: true,
        })
    }

    /// GConv split with the whole device as budget (single-module view).
    pub fn plan_gconv_split(&self, m: &Module) -> Result<ModulePlan, PlanError> {
        self.plan_gconv_split_budgeted(m, None)
    }

    // -------------------------------------------------- Fig 2c: Fused-Layer

    /// Fused-layer plans for ShuffleNetV2 units.
    ///
    /// Basic unit: the whole right branch (1x1 -> dw3x3 -> 1x1) is one
    /// DHM-resident chain; the GPU only pays the final concat+shuffle.
    /// Reduction unit: the left branch (dw3x3/s2 -> 1x1) is DHM-resident and
    /// runs in parallel with the GPU's right branch.
    pub fn plan_fused(&self, m: &Module) -> Result<ModulePlan, PlanError> {
        match m.kind {
            ModuleKind::ShuffleBasic => {
                let chain = m.layers.clone(); // [pw1, dw, pw2] on C/2
                let in_elems = m.layers[0].input.elems();
                let out_elems = m.layers[2].output.elems();
                let fpga_branch = vec![
                    self.xfer("right->fpga", true, in_elems, Precision::Int8),
                    self.fpga_step(&format!("{}:right-branch", m.name), chain)?,
                    self.xfer("right->gpu", false, out_elems, Precision::Int8),
                ];
                // left half stays resident on the GPU: no work until concat
                let steps = vec![
                    Step::Parallel { gpu: vec![], fpga: fpga_branch },
                    self.gpu_data("concat", m.output.elems()),
                    self.gpu_data("shuffle", m.output.elems()),
                ];
                Ok(ModulePlan {
                    module_name: m.name.clone(),
                    kind: m.kind,
                    strategy: Strategy::FusedLayer,
                    steps,
                    uses_fpga: true,
                })
            }
            ModuleKind::ShuffleReduce => {
                let left = vec![m.layers[0], m.layers[1]];
                let right = [m.layers[2], m.layers[3], m.layers[4]];
                let fpga_branch = vec![
                    self.xfer("ifm->fpga", true, m.input.elems(), Precision::Int8),
                    self.fpga_step(&format!("{}:left-branch", m.name), left)?,
                    self.xfer("left->gpu", false, m.layers[1].output.elems(), Precision::Int8),
                ];
                let gpu_branch: Vec<Step> = right
                    .iter()
                    .enumerate()
                    .map(|(i, l)| self.gpu_step(&format!("{}:right[{}]", m.name, i), *l))
                    .collect();
                let steps = vec![
                    Step::Parallel { gpu: gpu_branch, fpga: fpga_branch },
                    self.gpu_data("concat", m.output.elems()),
                    self.gpu_data("shuffle", m.output.elems()),
                ];
                Ok(ModulePlan {
                    module_name: m.name.clone(),
                    kind: m.kind,
                    strategy: Strategy::FusedLayer,
                    steps,
                    uses_fpga: true,
                })
            }
            k => Err(PlanError::NotApplicable(Strategy::FusedLayer, k)),
        }
    }

    // ---------------------------------------------------------------- entry

    /// Plan one module under a strategy with the whole device available
    /// (the single-module view used by strategy exploration; whole-network
    /// planning goes through [`Planner::plan_model`]).
    pub fn plan_module(&self, m: &Module, strategy: Strategy) -> Result<ModulePlan, PlanError> {
        match strategy {
            Strategy::GpuOnly => Ok(self.plan_gpu_only(m)),
            Strategy::FpgaOnly => self.plan_fpga_only(m),
            Strategy::DwSplit => self.plan_dw_split(m),
            Strategy::GConvSplit => self.plan_gconv_split(m),
            Strategy::FusedLayer => self.plan_fused(m),
            Strategy::Paper => match Self::paper_strategy(m.kind) {
                Strategy::GpuOnly => Ok(self.plan_gpu_only(m)),
                s => self.plan_module(m, s),
            },
            Strategy::Auto => Ok(self.plan_auto(m)),
        }
    }

    /// Paper-default heterogeneous strategy for a module kind.
    pub fn paper_strategy(kind: ModuleKind) -> Strategy {
        match kind {
            ModuleKind::Fire => Strategy::GConvSplit,
            ModuleKind::Bottleneck { .. } => Strategy::DwSplit,
            ModuleKind::ShuffleBasic | ModuleKind::ShuffleReduce => Strategy::FusedLayer,
            _ => Strategy::GpuOnly,
        }
    }

    fn plan_auto(&self, m: &Module) -> ModulePlan {
        let baseline = self.plan_gpu_only(m);
        let base_cost = crate::sched::evaluate_cost(&baseline, crate::sched::IdleParams::default());
        let mut best = baseline;
        let mut best_energy = base_cost.joules;
        for strat in [Strategy::DwSplit, Strategy::GConvSplit, Strategy::FusedLayer, Strategy::FpgaOnly] {
            if let Ok(plan) = self.plan_module(m, strat) {
                let c = crate::sched::evaluate_cost(&plan, crate::sched::IdleParams::default());
                if c.seconds <= base_cost.seconds * 1.02 && c.joules < best_energy {
                    best_energy = c.joules;
                    best = plan;
                }
            }
        }
        best
    }

    // --------------------------------------------- whole-network allocation

    /// Paper-methodology model plan: every module is planned independently
    /// with the full device available (paper §V-A measures each task's
    /// FPGA cost in isolation and composes — its Fig 4 / Table I numbers
    /// assume per-task fabric availability). Use [`Planner::plan_model`]
    /// for the deployable shared-fabric variant; the difference between the
    /// two is quantified by the resident-set ablation bench.
    pub fn plan_model_paper(&self, g: &ModelGraph) -> ModelPlan {
        let modules = g
            .modules
            .iter()
            .map(|m| {
                let base = self.plan_gpu_only(m);
                match self.plan_module(m, Strategy::Paper) {
                    Ok(plan) if plan.uses_fpga => {
                        // paper acceptance criterion: the partition must not
                        // regress either metric materially
                        let b = crate::sched::evaluate_cost(&base, crate::sched::IdleParams::paper());
                        let h = crate::sched::evaluate_cost(&plan, crate::sched::IdleParams::paper());
                        if h.joules < b.joules && h.seconds <= b.seconds * 1.02 {
                            plan
                        } else {
                            base
                        }
                    }
                    _ => base,
                }
            })
            .collect();
        ModelPlan { model_name: g.name.clone(), strategy: Strategy::Paper, modules }
    }

    /// Plan a whole model under the shared-fabric constraint.
    ///
    /// `GpuOnly` plans everything on the GPU. Every other strategy runs the
    /// global allocation described in the module docs: all-or-nothing FPGA
    /// candidates are granted greedily by energy-saving density, then Fire
    /// modules split the leftover fabric evenly via their GConv share.
    pub fn plan_model(&self, g: &ModelGraph, strategy: Strategy) -> ModelPlan {
        if strategy == Strategy::GpuOnly {
            let modules = g.modules.iter().map(|m| self.plan_gpu_only(m)).collect();
            return ModelPlan { model_name: g.name.clone(), strategy, modules };
        }

        let dhm = self.sdhm();
        let ceiling = (dhm.dev.alms as f64 * dhm.dev.util_ceiling) as u64;
        let mut alms_left = ceiling;
        let mut m20k_left = dhm.dev.m20ks;

        // start from the GPU-only baseline everywhere
        let mut plans: Vec<ModulePlan> = g.modules.iter().map(|m| self.plan_gpu_only(m)).collect();
        let base_costs: Vec<Cost> = plans
            .iter()
            .map(|p| crate::sched::evaluate_cost(p, crate::sched::IdleParams::default()))
            .collect();

        // Phase A: all-or-nothing candidates, greedy by saving density.
        struct Cand {
            idx: usize,
            plan: ModulePlan,
            usage: ResourceUsage,
            saving: f64,
        }
        let mut cands: Vec<Cand> = Vec::new();
        let mut fire_idxs: Vec<usize> = Vec::new();
        for (idx, m) in g.modules.iter().enumerate() {
            let want = match strategy {
                Strategy::Paper | Strategy::Auto => Self::paper_strategy(m.kind),
                s => s,
            };
            if m.kind == ModuleKind::Fire {
                fire_idxs.push(idx);
                continue; // flexible item, phase B
            }
            if want == Strategy::GpuOnly {
                continue;
            }
            let Ok(plan) = self.plan_module(m, want) else { continue };
            let c = crate::sched::evaluate_cost(&plan, crate::sched::IdleParams::default());
            let base = base_costs[idx];
            let saving = base.joules - c.joules;
            if saving <= 0.0 || c.seconds > base.seconds * 1.02 {
                continue;
            }
            let usage = plan.fpga_usage();
            cands.push(Cand { idx, plan, usage, saving });
        }
        cands.sort_by(|a, b| {
            let da = a.saving / (a.usage.alms.max(1) as f64);
            let db = b.saving / (b.usage.alms.max(1) as f64);
            db.partial_cmp(&da).unwrap()
        });
        for c in cands {
            if c.usage.alms <= alms_left && c.usage.m20ks <= m20k_left {
                alms_left -= c.usage.alms;
                m20k_left -= c.usage.m20ks;
                plans[c.idx] = c.plan;
            }
        }

        // Phase B: Fire modules share the leftover fabric evenly.
        if !fire_idxs.is_empty() {
            let per_fire = alms_left / fire_idxs.len() as u64;
            for &idx in &fire_idxs {
                let m = &g.modules[idx];
                let Ok(plan) = self.plan_gconv_split_budgeted(m, Some(per_fire)) else {
                    continue;
                };
                let c = crate::sched::evaluate_cost(&plan, crate::sched::IdleParams::default());
                let base = base_costs[idx];
                if c.joules >= base.joules || c.seconds > base.seconds * 1.02 {
                    continue;
                }
                let usage = plan.fpga_usage();
                if usage.alms <= alms_left && usage.m20ks <= m20k_left {
                    alms_left -= usage.alms;
                    m20k_left -= usage.m20ks;
                    plans[idx] = plan;
                }
            }
        }

        ModelPlan { model_name: g.name.clone(), strategy, modules: plans }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::graph::TensorShape;

    fn planner() -> Planner {
        Planner::default()
    }

    #[test]
    fn gpu_only_fire_has_concat() {
        let m = models::fire("fire2", TensorShape::new(54, 54, 96), 16, 64, 64);
        let p = planner().plan_gpu_only(&m);
        assert_eq!(p.steps.len(), 4); // 3 convs + concat
        assert!(!p.uses_fpga);
        assert_eq!(p.fpga_usage(), ResourceUsage::default());
    }

    #[test]
    fn gconv_split_fire_structure() {
        let m = models::fire("fire2", TensorShape::new(54, 54, 96), 16, 64, 64);
        let p = planner().plan_gconv_split(&m).unwrap();
        assert!(p.uses_fpga);
        assert!(matches!(p.steps[1], Step::Parallel { .. }));
        if let Step::Parallel { ref gpu, ref fpga } = p.steps[1] {
            assert_eq!(gpu.len(), 2); // expand1 + partial expand3
            assert_eq!(fpga.len(), 3); // in-xfer, conv, out-xfer
        }
        assert!(p.fpga_usage().alms > 0);
    }

    #[test]
    fn gconv_split_shares_sum_to_full_layer() {
        let m = models::fire("f", TensorShape::new(54, 54, 96), 16, 64, 64);
        let p = planner();
        let (f, g, gch) = p.gconv_halves(&m.layers[2], None).unwrap();
        assert_eq!(f.input.c + g.input.c, 16);
        assert_eq!(f.input.c, gch);
        let (fc, gc) = match (f.op, g.op) {
            (OpKind::Conv { cout: a, .. }, OpKind::GConv { cout: b, .. }) => (a, b),
            other => panic!("unexpected ops {other:?}"),
        };
        assert_eq!(fc + gc, 64);
    }

    #[test]
    fn gconv_budget_shrinks_share() {
        let m = models::fire("f", TensorShape::new(54, 54, 96), 16, 64, 64);
        let p = planner();
        let (_, _, g_full) = p.gconv_halves(&m.layers[2], None).unwrap();
        let (_, _, g_tight) = p.gconv_halves(&m.layers[2], Some(10_000)).unwrap();
        assert!(g_tight < g_full, "{g_tight} !< {g_full}");
    }

    #[test]
    fn dw_split_bottleneck_structure() {
        let m = models::bottleneck("bn", TensorShape::new(28, 28, 16), 16, 6, 1);
        let p = planner().plan_dw_split(&m).unwrap();
        // expand, dw, xfer, fpga, xfer, residual-add
        assert_eq!(p.steps.len(), 6);
        assert!(matches!(p.steps[3], Step::Fpga { .. }));
        assert!(matches!(p.steps[5], Step::GpuData { .. }));
    }

    #[test]
    fn dw_split_rejects_fire() {
        let m = models::fire("f", TensorShape::new(54, 54, 96), 16, 64, 64);
        assert!(matches!(
            planner().plan_dw_split(&m),
            Err(PlanError::NotApplicable(..))
        ));
    }

    #[test]
    fn fused_basic_unit_gpu_branch_empty() {
        let m = models::shuffle_basic("b", TensorShape::new(28, 28, 48));
        let p = planner().plan_fused(&m).unwrap();
        if let Step::Parallel { ref gpu, ref fpga } = p.steps[0] {
            assert!(gpu.is_empty());
            assert_eq!(fpga.len(), 3);
        } else {
            panic!("expected parallel step");
        }
    }

    #[test]
    fn fused_reduce_unit_has_parallel_branches() {
        let m = models::shuffle_reduce("r", TensorShape::new(55, 55, 24), 48);
        let p = planner().plan_fused(&m).unwrap();
        if let Step::Parallel { ref gpu, ref fpga } = p.steps[0] {
            assert_eq!(gpu.len(), 3);
            assert_eq!(fpga.len(), 3);
        } else {
            panic!("expected parallel step");
        }
    }

    #[test]
    fn fpga_only_rejects_oversized_module() {
        // fire8 at 26x26x384: squeeze alone is 384*64 = 24K MACs -> overflow
        let m = models::fire("fire8", TensorShape::new(26, 26, 384), 64, 256, 256);
        assert!(planner().plan_fpga_only(&m).is_err());
    }

    #[test]
    fn model_plan_respects_fabric_budget() {
        // the global invariant: the resident set fits the device
        let p = planner();
        let dev = p.sdhm().dev;
        let ceiling = (dev.alms as f64 * dev.util_ceiling) as u64;
        for g in models::all_models() {
            for strat in [Strategy::Paper, Strategy::Auto] {
                let plan = p.plan_model(&g, strat);
                let u = plan.fpga_usage();
                assert!(
                    u.alms <= ceiling,
                    "{} {}: resident set {} ALMs > ceiling {}",
                    g.name,
                    strat,
                    u.alms,
                    ceiling
                );
                assert!(u.m20ks <= dev.m20ks);
            }
        }
    }

    #[test]
    fn auto_never_worse_than_gpu_only() {
        let p = planner();
        for g in models::all_models() {
            let base = p.plan_model(&g, Strategy::GpuOnly);
            let auto = p.plan_model(&g, Strategy::Auto);
            let cb = crate::sched::evaluate_model(&base).total;
            let ca = crate::sched::evaluate_model(&auto).total;
            assert!(
                ca.joules <= cb.joules * 1.001,
                "{}: auto {} J vs gpu {} J",
                g.name,
                ca.joules,
                cb.joules
            );
        }
    }

    #[test]
    fn plan_model_covers_every_module() {
        let p = planner();
        for g in models::all_models() {
            let plan = p.plan_model(&g, Strategy::Paper);
            assert_eq!(plan.modules.len(), g.modules.len());
        }
    }

    #[test]
    fn paper_plan_uses_fpga_on_all_three_nets() {
        let p = planner();
        for g in models::all_models() {
            let plan = p.plan_model(&g, Strategy::Paper);
            assert!(plan.uses_fpga(), "{} never touched the FPGA", g.name);
        }
    }

    #[test]
    fn strategy_names_roundtrip() {
        for s in Strategy::ALL {
            let parsed: Strategy = s.to_string().parse().expect("display name parses back");
            assert_eq!(parsed, s);
        }
        assert!("warp-drive".parse::<Strategy>().unwrap_err().contains("gpu-only"));
        // MODULE_LEVEL is exactly ALL minus the composite selectors
        assert!(Strategy::MODULE_LEVEL
            .iter()
            .all(|s| !matches!(s, Strategy::Paper | Strategy::Auto)));
        assert_eq!(Strategy::MODULE_LEVEL.len() + 2, Strategy::ALL.len());
    }

    #[test]
    fn contended_planner_inflates_link_time_and_nothing_else() {
        let base = planner();
        let contended = planner().contended(2);
        assert!((contended.link_contention_factor() - 2.0).abs() < 1e-12);
        assert!((base.link_contention_factor() - 1.0).abs() < 1e-12);
        fn sum_steps(steps: &[Step]) -> (f64, f64, f64) {
            let mut link_s = 0.0;
            let mut other_s = 0.0;
            let mut joules = 0.0;
            for s in steps {
                match s {
                    Step::Transfer { cost, .. } => {
                        link_s += cost.seconds;
                        joules += cost.joules;
                    }
                    Step::Gpu { cost, .. }
                    | Step::GpuData { cost, .. }
                    | Step::Fpga { cost, .. } => {
                        other_s += cost.seconds;
                        joules += cost.joules;
                    }
                    Step::Parallel { gpu, fpga } => {
                        let (l1, o1, j1) = sum_steps(gpu);
                        let (l2, o2, j2) = sum_steps(fpga);
                        link_s += l1 + l2;
                        other_s += o1 + o2;
                        joules += j1 + j2;
                    }
                }
            }
            (link_s, other_s, joules)
        }
        for g in models::all_models() {
            let a = base.plan_model(&g, Strategy::Paper);
            let b = contended.plan_model(&g, Strategy::Paper);
            let steps_a: Vec<Step> = a.modules.iter().flat_map(|m| m.steps.clone()).collect();
            let steps_b: Vec<Step> = b.modules.iter().flat_map(|m| m.steps.clone()).collect();
            let (la, oa, ja) = sum_steps(&steps_a);
            let (lb, ob, jb) = sum_steps(&steps_b);
            assert!(la > 0.0, "{} paper plan must cross the link", g.name);
            assert!((lb - la * 2.0).abs() < 1e-12, "{}: {lb} vs 2*{la}", g.name);
            assert!((ob - oa).abs() < 1e-12, "{}: compute time must not change", g.name);
            assert!((jb - ja).abs() < 1e-12, "{}: energy must not change", g.name);
        }
    }

    #[test]
    fn paper_strategy_mapping() {
        assert_eq!(Planner::paper_strategy(ModuleKind::Fire), Strategy::GConvSplit);
        assert_eq!(
            Planner::paper_strategy(ModuleKind::Bottleneck { residual: true }),
            Strategy::DwSplit
        );
        assert_eq!(Planner::paper_strategy(ModuleKind::ShuffleBasic), Strategy::FusedLayer);
        assert_eq!(Planner::paper_strategy(ModuleKind::Pool), Strategy::GpuOnly);
    }
}
