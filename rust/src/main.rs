//! hetero-dnn CLI: the leader entrypoint.
//!
//! Subcommands map one-to-one onto the paper's experiments plus the serving
//! demo. Arg parsing is hand-rolled (offline build — no clap; DESIGN.md
//! §Offline).
//!
//! ```text
//! hetero-dnn info
//! hetero-dnn run [ARTIFACT] [--seed N]
//! hetero-dnn fig1
//! hetero-dnn fig4 [MODEL|all]
//! hetero-dnn table1
//! hetero-dnn headline
//! hetero-dnn partition [MODEL]
//! hetero-dnn serve [--models M1,M2] [--requests N] [--clients C] [--workers W]
//! hetero-dnn traffic-lab [--scenario NAME|all] [--seed N] [--controller on|off]
//! ```
//!
//! Runtime-facing commands fall back to the simulated platform runtime
//! when the AOT artifacts are not built.

use anyhow::{bail, Context, Result};
use hetero_dnn::coordinator::{EngineBuilder, InferenceRequest, ModelSpec};
use hetero_dnn::experiments;
use hetero_dnn::graph::{models, ModelGraph};
use hetero_dnn::metrics::Gain;
use hetero_dnn::partition::{Planner, Strategy};
use hetero_dnn::runtime::{Runtime, Tensor};
use hetero_dnn::sched;
use std::time::Duration;

const USAGE: &str = "\
hetero-dnn — FPGA-GPU heterogeneous embedded DNN inference (paper reproduction)

USAGE:
  hetero-dnn info                      show platform + artifact inventory
  hetero-dnn run [ARTIFACT] [--seed N] run one AOT artifact via PJRT
  hetero-dnn fig1                      regenerate paper Fig 1 (FPGA vs GPU sweep)
  hetero-dnn fig4 [MODEL|all]          regenerate paper Fig 4 (a/b/c)
  hetero-dnn table1                    regenerate paper Table I
  hetero-dnn headline                  full-model summary (paper abstract bands)
  hetero-dnn partition [MODEL]         per-module strategy exploration
  hetero-dnn trace [MODEL] [--out F]   write a chrome://tracing timeline of the plan
  hetero-dnn floorplan [MODEL]         FPGA resident-set floorplan of the deployable plan
  hetero-dnn pipeline [MODEL] [--batch N]
                                       batch-pipelined throughput analysis
  hetero-dnn serve [--models M1,M2] [--requests N] [--clients C] [--workers W]
                                       end-to-end serving demo (multi-model engine)
  hetero-dnn serve-tcp [--addr HOST:PORT] [--models M1,M2] [--workers W]
                                       TCP serving front end (wire protocol,
                                       see PROTOCOL.md)
  hetero-dnn serve-cluster [--nodes N] [--addr HOST:PORT] [--models M1,M2]
                                       N-node cluster behind the digest-affinity
                                       router (README \"Running a cluster\")
  hetero-dnn traffic-lab [--scenario NAME|all] [--seed N] [--duration-ms N]
                         [--slo-p99-us N] [--controller on|off]
                                       replay named open-loop traffic scenarios
                                       against a fresh engine and print one SLO
                                       report per scenario, with schedule and
                                       report fingerprints (README \"Traffic
                                       lab\"; same seed => same fingerprints)
MODELS: squeezenet | mobilenetv2_05 | shufflenetv2_05
serve, serve-tcp and traffic-lab accept --trace-out F: turn the flight
recorder on (README \"Observing the engine\") and write the measured
Chrome-trace timeline to F — serve and traffic-lab at the end of the
run (also printing the per-stage latency breakdown table), serve-tcp
rewritten every 5 s so the file is current at ctrl-c;
serve/serve-tcp also accept --artifact (single-model override), --max-batch,
--max-wait-ms, --seed, --cache N (per-model result-cache entries, 0 = off),
--budget N (per-model in-flight cap, 0 = uncapped) and --placement
pool|STRATEGY (pool = flat worker pool, the default; a strategy name —
e.g. paper, auto, gpu-only — serves each model on the online heterogeneous
pipeline: FPGA/link/GPU device lanes paying the simulated platform's
service times, see DESIGN.md §10); serve-tcp also accepts --protocol
v1|v2 (v1 = JSON lockstep only; v2 = binary pipelined with v1 fallback,
the default) and --chunk-elems N (v2 streaming chunk size in f32
elements); serve-cluster also accepts --affinity on|off (digest-affinity
routing, on by default) and --retries N (failover budget per request);
traffic-lab shares the serve model flags (--models, --workers, --cache,
--budget, --placement, --max-batch, --max-wait-ms)";

fn parse_model(name: &str) -> Result<ModelGraph> {
    models::by_name(name, 224).with_context(|| format!("unknown model {name}; see --help"))
}

/// Tiny flag parser: positional args + `--key value` pairs.
struct Args {
    positional: Vec<String>,
    flags: Vec<(String, String)>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args> {
        let mut positional = Vec::new();
        let mut flags = Vec::new();
        let mut it = argv.iter();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                let val = it.next().with_context(|| format!("--{key} needs a value"))?;
                flags.push((key.to_string(), val.clone()));
            } else {
                positional.push(a.clone());
            }
        }
        Ok(Args { positional, flags })
    }

    fn flag(&self, key: &str) -> Option<&str> {
        self.flags.iter().rev().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    fn flag_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.flag(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow::anyhow!("--{key} {v}: {e}")),
        }
    }
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "-h" {
        println!("{USAGE}");
        return Ok(());
    }
    let cmd = argv[0].as_str();
    let args = Args::parse(&argv[1..])?;
    let planner = Planner::default();

    match cmd {
        "info" => {
            let rt = Runtime::new_or_simulated();
            println!("platform: {}", rt.platform());
            println!("artifacts ({}):", rt.manifest.artifacts.len());
            for (name, e) in &rt.manifest.artifacts {
                println!(
                    "  {name:<26} {} inputs, {} outputs, tags: {}",
                    e.inputs.len(),
                    e.outputs.len(),
                    e.tags.join(",")
                );
            }
        }
        "run" => {
            let artifact = args.positional.first().map(String::as_str).unwrap_or("fire_full");
            let seed: u64 = args.flag_parse("seed", 0)?;
            let rt = Runtime::new_or_simulated();
            let exe = rt.load(artifact)?;
            let inputs = rt.synth_inputs(artifact, seed)?;
            let t0 = std::time::Instant::now();
            let outs = exe.run(&inputs)?;
            let dt = t0.elapsed();
            println!("{artifact}: {} outputs in {dt:?}", outs.len());
            for (i, o) in outs.iter().enumerate() {
                let amax = o.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                println!("  out[{i}] shape {:?} max|x| {amax:.4}", o.shape);
            }
        }
        "fig1" => println!("{}", experiments::fig1(&planner).to_text()),
        "fig4" => {
            let model = args.positional.first().map(String::as_str).unwrap_or("all");
            let names: Vec<&str> = if model == "all" {
                vec!["squeezenet", "mobilenetv2_05", "shufflenetv2_05"]
            } else {
                vec![model]
            };
            for m in names {
                println!("{}", experiments::fig4(&planner, m).to_text());
            }
        }
        "table1" => println!("{}", experiments::table1(&planner).to_text()),
        "headline" => println!("{}", experiments::headline_summary(&planner).to_text()),
        "partition" => {
            let model = args.positional.first().map(String::as_str).unwrap_or("squeezenet");
            let g = parse_model(model)?;
            println!("model {} — per-module strategy exploration", g.name);
            for m in &g.modules {
                print!("  {:<10} {:?}:", m.name, m.kind);
                for strat in Strategy::MODULE_LEVEL {
                    match planner.plan_module(m, strat) {
                        Ok(p) => {
                            let c = sched::evaluate(&p).total;
                            print!(" {strat}={:.3}ms/{:.3}mJ", c.ms(), c.mj());
                        }
                        Err(_) => print!(" {strat}=n/a"),
                    }
                }
                println!();
            }
        }
        "floorplan" => {
            let model = args.positional.first().map(String::as_str).unwrap_or("shufflenetv2_05");
            let g = parse_model(model)?;
            let dhm = planner.sdhm();
            for (name, plan) in [
                ("deployable (auto, shared fabric)", planner.plan_model(&g, Strategy::Auto)),
                ("paper methodology", planner.plan_model_paper(&g)),
            ] {
                println!("== {name} ==");
                match hetero_dnn::dhm::floorplan::floorplan(&dhm, &plan) {
                    Ok(fp) => print!("{}", fp.report(&dhm)),
                    Err(e) => println!("  DOES NOT FIT one device: {e}"),
                }
                println!();
            }
        }
        "trace" => {
            let model = args.positional.first().map(String::as_str).unwrap_or("squeezenet");
            let out = args.flag("out").unwrap_or("trace.json").to_string();
            let g = parse_model(model)?;
            let plan = planner.plan_model_paper(&g);
            let text = hetero_dnn::sched::trace::model_trace_json(
                &plan,
                hetero_dnn::sched::IdleParams::paper(),
            );
            std::fs::write(&out, &text)?;
            println!("wrote {out} ({} bytes) — open in chrome://tracing or Perfetto", text.len());
        }
        "pipeline" => {
            let model = args.positional.first().map(String::as_str).unwrap_or("shufflenetv2_05");
            let batch: usize = args.flag_parse("batch", 32)?;
            let g = parse_model(model)?;
            use hetero_dnn::sched::{pipeline, IdleParams};
            for (name, plan) in [
                ("gpu-only", planner.plan_model(&g, Strategy::GpuOnly)),
                ("paper hetero", planner.plan_model_paper(&g)),
                ("deployable", planner.plan_model(&g, Strategy::Auto)),
            ] {
                let run = pipeline::evaluate_pipeline(&plan, batch, IdleParams::default());
                println!(
                    "{name:<14} batch {batch}: {:.1} img/s, {:.3} mJ/img, bottleneck {:?}",
                    run.throughput,
                    run.joules_per_image() * 1e3,
                    run.bottleneck
                );
            }
        }
        "serve-tcp" => {
            use hetero_dnn::coordinator::{protocol, server::ServerConfig};
            let addr = args.flag("addr").unwrap_or("127.0.0.1:7878").to_string();
            let v2 = match args.flag("protocol").unwrap_or("v2") {
                "v1" => false,
                "v2" => true,
                other => bail!("--protocol must be v1 or v2, got {other:?}"),
            };
            let cfg = ServerConfig {
                chunk_elems: args.flag_parse("chunk-elems", protocol::DEFAULT_CHUNK_ELEMS)?,
                v2,
            };
            let trace_out = args.flag("trace-out").map(str::to_string);
            let mut builder = EngineBuilder::new()
                .max_batch(args.flag_parse("max-batch", 8)?)
                .max_wait(Duration::from_millis(args.flag_parse("max-wait-ms", 2)?));
            if trace_out.is_some() {
                builder = builder.tracing();
            }
            for spec in model_specs(&args)? {
                builder = builder.model(spec);
            }
            let handle = builder.build()?;
            let engine = handle.engine.clone();
            let server = hetero_dnn::coordinator::server::Server::start_with(
                &addr,
                engine.clone(),
                cfg.clone(),
            )?;
            if cfg.v2 {
                println!(
                    "serving [{}] on {} — wire v2 (binary, pipelined, streaming; chunk {} elems) \
                     with v1 JSON fallback; spec: PROTOCOL.md",
                    engine.models().join(", "),
                    server.addr,
                    cfg.chunk_elems
                );
            } else {
                println!(
                    "serving [{}] on {} — wire v1 only: u32 len | {{id,model,shape}} JSON | f32 payload",
                    engine.models().join(", "),
                    server.addr
                );
            }
            if let Some(path) = &trace_out {
                println!(
                    "flight recorder on — rewriting {path} every 5 s \
                     (measured Chrome trace; open in ui.perfetto.dev)"
                );
            }
            println!("press ctrl-c to stop");
            loop {
                // with the recorder on, keep the trace file fresh so a
                // ctrl-c always leaves a current measured timeline behind
                let tick = if trace_out.is_some() { 5 } else { 3600 };
                std::thread::sleep(Duration::from_secs(tick));
                if let (Some(path), Some(snap)) = (&trace_out, engine.trace_snapshot()) {
                    std::fs::write(path, snap.chrome_trace_json())?;
                }
            }
        }
        "serve-cluster" => {
            use hetero_dnn::cluster::{Node, Router, RouterConfig, Topology};
            use hetero_dnn::coordinator::protocol;
            let addr = args.flag("addr").unwrap_or("127.0.0.1:7979").to_string();
            let nodes: usize = args.flag_parse("nodes", 3)?;
            if nodes == 0 {
                bail!("--nodes must be at least 1");
            }
            let affinity = match args.flag("affinity").unwrap_or("on") {
                "on" => true,
                "off" => false,
                other => bail!("--affinity must be on or off, got {other:?}"),
            };
            let specs = model_specs(&args)?;
            let max_batch = args.flag_parse("max-batch", 8)?;
            let max_wait = Duration::from_millis(args.flag_parse("max-wait-ms", 2)?);
            let topo = Topology::new();
            for _ in 0..nodes {
                topo.add(Node::start_with(specs.clone(), max_batch, max_wait)?);
            }
            let cfg = RouterConfig {
                affinity,
                max_retries: args.flag_parse("retries", 2)?,
                chunk_elems: args.flag_parse("chunk-elems", protocol::DEFAULT_CHUNK_ELEMS)?,
                ..RouterConfig::default()
            };
            let router = Router::start(&addr, &topo.addrs(), cfg)?;
            println!(
                "cluster: {nodes} node(s) serving [{}] behind the router on {} \
                 (digest affinity {}; wire v2 with v1 fallback, see PROTOCOL.md)",
                specs.iter().map(|s| s.name.as_str()).collect::<Vec<_>>().join(", "),
                router.addr,
                if affinity { "on" } else { "off" },
            );
            for (i, a) in topo.addrs().iter().enumerate() {
                println!("  replica {i}: {a}");
            }
            println!("press ctrl-c to stop");
            loop {
                std::thread::sleep(Duration::from_secs(3600));
            }
        }
        "traffic-lab" => {
            use hetero_dnn::workloads::{
                build_schedule, replay_engine, ControllerConfig, ReplayConfig, ScenarioSpec,
                SCENARIO_NAMES,
            };
            let seed: u64 = args.flag_parse("seed", 42)?;
            let duration = Duration::from_millis(args.flag_parse("duration-ms", 300)?);
            let slo_p99_us: u64 = args.flag_parse("slo-p99-us", 50_000)?;
            let controller = match args.flag("controller").unwrap_or("on") {
                "on" => true,
                "off" => false,
                other => bail!("--controller must be on or off, got {other:?}"),
            };
            let which = args.flag("scenario").unwrap_or("all");
            let scenarios: Vec<ScenarioSpec> = if which == "all" {
                ScenarioSpec::all()
            } else {
                vec![ScenarioSpec::named(which).with_context(|| {
                    format!("unknown scenario {which:?}; one of {SCENARIO_NAMES:?} or all")
                })?]
            };
            let specs = model_specs(&args)?;
            let max_batch: usize = args.flag_parse("max-batch", 8)?;
            let max_wait = Duration::from_millis(args.flag_parse("max-wait-ms", 0)?);
            let trace_out = args.flag("trace-out").map(str::to_string);
            let multi = scenarios.len() > 1;
            println!(
                "traffic lab: {} scenario(s), seed {seed}, {duration:?} schedule, \
                 slo p99 {slo_p99_us}us, controller {}",
                scenarios.len(),
                if controller { "on" } else { "off" },
            );
            for scenario in scenarios {
                // a fresh engine per scenario: replays never see a sibling
                // scenario's cache warmth or controller re-specs, so equal
                // seeds print equal fingerprints run after run
                let mut builder = EngineBuilder::new().max_batch(max_batch).max_wait(max_wait);
                if trace_out.is_some() {
                    builder = builder.tracing();
                }
                for spec in specs.clone() {
                    builder = builder.model(spec);
                }
                let handle = builder.build()?;
                let engine = handle.engine.clone();
                let schedule = build_schedule(&scenario, engine.models().len(), seed, duration);
                let cfg = ReplayConfig {
                    slo_p99_us,
                    controller: controller
                        .then(|| ControllerConfig { slo_p99_us, ..ControllerConfig::default() }),
                    ..ReplayConfig::default()
                };
                let report = replay_engine(&engine, &schedule, &cfg);
                println!(
                    "{report}  [schedule {:#018x} report {:#018x}]",
                    schedule.fingerprint(),
                    report.fingerprint()
                );
                if let Some(base) = &trace_out {
                    // one measured timeline per scenario engine; suffix
                    // the file name so `--scenario all` keeps them all
                    let path = if multi {
                        match base.rsplit_once('.') {
                            Some((stem, ext)) => format!("{stem}-{}.{ext}", scenario.name),
                            None => format!("{base}-{}", scenario.name),
                        }
                    } else {
                        base.clone()
                    };
                    if let Some(snap) = engine.trace_snapshot() {
                        let text = snap.chrome_trace_json();
                        std::fs::write(&path, &text)?;
                        println!(
                            "  wrote {path} ({} bytes) — measured timeline; \
                             open in ui.perfetto.dev",
                            text.len()
                        );
                    }
                }
                drop(engine);
                handle.shutdown();
            }
        }
        "serve" => {
            let specs = model_specs(&args)?;
            let max_batch = args.flag_parse("max-batch", 8)?;
            let max_wait = Duration::from_millis(args.flag_parse("max-wait-ms", 2)?);
            let requests: usize = args.flag_parse("requests", 32)?;
            let clients: usize = args.flag_parse("clients", 4)?;
            let trace_out = args.flag("trace-out").map(str::to_string);
            serve(specs, max_batch, max_wait, requests, clients, trace_out)?;
        }
        other => {
            eprintln!("unknown command {other:?}\n\n{USAGE}");
            std::process::exit(2);
        }
    }
    Ok(())
}

/// Build the engine model registry from
/// --models/--artifact/--workers/--seed/--cache/--budget.
fn model_specs(args: &Args) -> Result<Vec<ModelSpec>> {
    let workers: usize = args.flag_parse("workers", 2)?;
    let seed: u64 = args.flag_parse("seed", 0)?;
    let cache: usize = args.flag_parse("cache", 0)?;
    let budget: u64 = args.flag_parse("budget", 0)?;
    let names: Vec<String> = args
        .flag("models")
        .or_else(|| args.flag("model"))
        .unwrap_or("squeezenet")
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if names.is_empty() {
        bail!("--models needs at least one model name");
    }
    // cache 0 / budget 0 both mean "off", so the flags pass straight through
    let mut specs: Vec<ModelSpec> = names
        .iter()
        .map(|n| ModelSpec::net(n).workers(workers).seed(seed).cache(cache).budget(budget))
        .collect();
    match args.flag("placement") {
        None | Some("pool") => {}
        Some(p) => {
            let strat: Strategy =
                p.parse().map_err(|e: String| anyhow::anyhow!("--placement {p}: {e}"))?;
            specs = specs.into_iter().map(|s| s.placement(strat)).collect();
        }
    }
    if let Some(artifact) = args.flag("artifact") {
        if specs.len() != 1 {
            bail!("--artifact only applies when exactly one model is listed");
        }
        specs[0].artifact = artifact.to_string();
    }
    Ok(specs)
}

fn serve(
    specs: Vec<ModelSpec>,
    max_batch: usize,
    max_wait: Duration,
    requests: usize,
    clients: usize,
    trace_out: Option<String>,
) -> Result<()> {
    let mut builder = EngineBuilder::new().max_batch(max_batch).max_wait(max_wait);
    if trace_out.is_some() {
        builder = builder.tracing();
    }
    for spec in &specs {
        builder = builder.model(spec.clone());
    }
    let handle = builder.build()?;
    let engine = handle.engine.clone();
    let names: Vec<String> = engine.models();
    println!("serving {} model(s):", names.len());
    for name in &names {
        let lanes = match engine.placement(name) {
            Some(hetero_dnn::coordinator::Placement::Hetero) => "device lanes (hetero pipeline)",
            _ => "workers (flat pool)",
        };
        println!(
            "  {name:<18} input {:?}, {} {lanes}",
            engine.input_shape(name).expect("registered"),
            engine.workers(name).expect("registered")
        );
    }
    let t0 = std::time::Instant::now();
    let mut joins = Vec::new();
    for c in 0..clients {
        let engine = engine.clone();
        let names = names.clone();
        let per_client = requests / clients + usize::from(c < requests % clients);
        joins.push(std::thread::spawn(move || {
            for i in 0..per_client {
                // round-robin the registered models across the client's stream
                let model = &names[(c + i) % names.len()];
                let shape = engine.input_shape(model).expect("registered");
                let x = Tensor::randn(&shape, (c * 10_000 + i) as u64);
                let resp = match engine.infer(InferenceRequest::new(model.clone(), x)) {
                    Ok(r) => r,
                    // overload rejections are expected under --budget /
                    // admission; a real client would back off and retry
                    Err(e) if matches!(e.code(), "budget_exhausted" | "shed") => continue,
                    Err(e) => panic!("infer: {e}"),
                };
                if i == 0 && c == 0 {
                    println!(
                        "first: model {} exec {:?} queued {:?} batch {} | simulated platform: {:.3} ms / {:.3} mJ",
                        resp.model, resp.exec, resp.queued, resp.batch_size,
                        resp.simulated.ms(), resp.simulated.mj()
                    );
                }
            }
        }));
    }
    for j in joins {
        j.join().expect("client thread");
    }
    let wall = t0.elapsed();
    let mut total_served = 0u64;
    for name in &names {
        let metrics = engine.metrics(name).expect("registered");
        let m = metrics.lock().unwrap();
        total_served += m.served + m.cache_hits;
        print!(
            "{name:<18} served {:>5} | exec mean {:.1} ms | p50 {:.1} ms | p99 {:.1} ms | mean batch {:.2}",
            m.served,
            m.exec_us_total as f64 / m.served.max(1) as f64 / 1e3,
            m.percentile(0.5) as f64 / 1e3,
            m.percentile(0.99) as f64 / 1e3,
            m.mean_batch()
        );
        if m.cache_hits + m.cache_misses > 0 {
            print!(
                " | cache {}/{} hit ({:.0}%), {} evicted",
                m.cache_hits,
                m.cache_hits + m.cache_misses,
                m.cache_hit_rate() * 100.0,
                m.cache_evictions
            );
        }
        if m.budget_rejected > 0 {
            print!(" | budget rejected {}", m.budget_rejected);
        }
        println!();
        if let Some(dm) = engine.device_metrics(name) {
            let (bottleneck, _) = dm.busiest();
            println!(
                "{:<18} lanes: gpu {:.1} ms sim / {:.2} J | fpga {:.1} ms / {:.2} J | \
                 link {:.1} ms, {:.2} MB | bottleneck {bottleneck} | {} images",
                "",
                dm.gpu.sim_busy().as_secs_f64() * 1e3,
                dm.gpu.joules(),
                dm.fpga.sim_busy().as_secs_f64() * 1e3,
                dm.fpga.joules(),
                dm.link.sim_busy().as_secs_f64() * 1e3,
                dm.transferred_bytes() as f64 / 1e6,
                dm.images()
            );
        }
    }
    println!(
        "total: {total_served} requests in {:.2?}  ({:.1} req/s wall)",
        wall,
        total_served as f64 / wall.as_secs_f64()
    );
    let stats = engine.node_stats();
    if !stats.is_empty() {
        println!("stage latency breakdown (flight recorder):");
        print!("{}", stats.table());
    }
    if let Some(path) = &trace_out {
        if let Some(snap) = engine.trace_snapshot() {
            let text = snap.chrome_trace_json();
            std::fs::write(path, &text)?;
            println!(
                "wrote {path} ({} bytes) — measured timeline; open in ui.perfetto.dev",
                text.len()
            );
        }
    }
    // simulated platform comparison for each served model graph
    let planner = Planner::default();
    for spec in &specs {
        let g = parse_model(&spec.graph)?;
        let base = sched::evaluate_model(&planner.plan_model(&g, Strategy::GpuOnly)).total;
        let het = sched::evaluate_model(&planner.plan_model(&g, Strategy::Auto)).total;
        let gain = Gain::of(base, het);
        println!(
            "{:<18} simulated hetero gain vs GPU-only: energy {:.2}x, latency {:.2}x",
            spec.graph, gain.energy_gain, gain.latency_speedup
        );
    }
    drop(engine);
    handle.shutdown();
    Ok(())
}
