//! Log-bucketed latency histogram (HdrHistogram-style, in-tree).
//!
//! The coordinator's percentile reporting originally kept every latency in
//! a Vec and sorted on read — O(n log n) per metrics scrape and unbounded
//! memory over long serving runs. This histogram gives O(1) record, O(B)
//! quantile, bounded memory, and < 2^(1/SUB_BITS) relative quantile error.

/// Sub-bucket resolution: 2^5 = 32 sub-buckets per octave -> <= ~2.2%
/// relative error on reported quantiles.
const SUB_BITS: u32 = 5;
const SUBS: usize = 1 << SUB_BITS;
/// 48 octaves of u64 span: 1 us granularity units up to ~8.9e9 s.
const OCTAVES: usize = 48;

/// Fixed-size log histogram over u64 values (microseconds by convention).
#[derive(Debug, Clone)]
pub struct LogHistogram {
    buckets: Vec<u64>,
    count: u64,
    sum: u128,
    min: u64,
    max: u64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LogHistogram {
    pub fn new() -> Self {
        Self {
            buckets: vec![0; OCTAVES * SUBS],
            count: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    fn index(v: u64) -> usize {
        if v < SUBS as u64 {
            return v as usize; // exact for small values
        }
        let msb = 63 - v.leading_zeros();
        let octave = (msb - SUB_BITS) as usize;
        let sub = (v >> (msb - SUB_BITS)) as usize & (SUBS - 1);
        (SUBS + octave * SUBS + sub).min(OCTAVES * SUBS - 1)
    }

    /// Representative (upper-bound) value of a bucket.
    fn value_of(idx: usize) -> u64 {
        if idx < SUBS {
            return idx as u64;
        }
        let rel = idx - SUBS;
        let octave = rel / SUBS;
        let sub = rel % SUBS;
        ((SUBS + sub) as u64) << octave
    }

    pub fn record(&mut self, v: u64) {
        self.buckets[Self::index(v)] += 1;
        self.count += 1;
        self.sum += v as u128;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.sum as f64 / self.count as f64 }
    }

    pub fn min(&self) -> u64 {
        if self.count == 0 { 0 } else { self.min }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    /// Quantile in [0, 1]; returns an upper bound of the bucket holding it.
    /// An empty histogram reports 0 (never the min/max sentinels); a
    /// non-finite `q` is treated as 1.0.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let q = if q.is_finite() { q.clamp(0.0, 1.0) } else { 1.0 };
        let target = ((self.count as f64 - 1.0) * q).round() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if c > 0 && seen > target {
                return Self::value_of(i).min(self.max).max(self.min);
            }
        }
        self.max
    }

    /// Merge another histogram into this one.
    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram_safe() {
        let h = LogHistogram::new();
        // must report 0, not panic or leak the u64::MAX min-sentinel
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(h.quantile(q), 0);
        }
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.count(), 0);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 0);
    }

    #[test]
    fn non_finite_quantile_is_clamped() {
        let mut h = LogHistogram::new();
        h.record(7);
        assert_eq!(h.quantile(f64::NAN), 7);
        assert_eq!(h.quantile(f64::INFINITY), 7);
        assert_eq!(h.quantile(-1.0), 7);
    }

    #[test]
    fn merge_with_empty_keeps_sentinels_sane() {
        let mut a = LogHistogram::new();
        a.record(42);
        let empty = LogHistogram::new();
        a.merge(&empty);
        assert_eq!(a.min(), 42);
        assert_eq!(a.max(), 42);
        assert_eq!(a.quantile(0.5), 42);
        let mut b = LogHistogram::new();
        b.merge(&a);
        assert_eq!(b.min(), 42);
        assert_eq!(b.quantile(1.0), 42);
    }

    #[test]
    fn small_values_exact() {
        let mut h = LogHistogram::new();
        for v in [1u64, 2, 3, 4, 5] {
            h.record(v);
        }
        assert_eq!(h.quantile(0.0), 1);
        assert_eq!(h.quantile(1.0), 5);
        assert_eq!(h.quantile(0.5), 3);
        assert!((h.mean() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn quantile_error_bounded() {
        let mut h = LogHistogram::new();
        // exact ground truth over a deterministic spread
        let mut vals: Vec<u64> = (0..10_000).map(|i| (i * i) % 1_000_003 + 1).collect();
        for &v in &vals {
            h.record(v);
        }
        vals.sort_unstable();
        for q in [0.5, 0.9, 0.99, 0.999] {
            let want = vals[((vals.len() - 1) as f64 * q).round() as usize] as f64;
            let got = h.quantile(q) as f64;
            let rel = (got - want).abs() / want;
            assert!(rel < 0.05, "q{q}: {got} vs {want} ({rel})");
        }
    }

    #[test]
    fn min_max_tracked() {
        let mut h = LogHistogram::new();
        h.record(17);
        h.record(9_999_999);
        assert_eq!(h.min(), 17);
        assert_eq!(h.max(), 9_999_999);
        assert!(h.quantile(1.0) <= 9_999_999);
    }

    #[test]
    fn merge_equals_combined_recording() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        let mut c = LogHistogram::new();
        for i in 0..1000u64 {
            let v = i * 37 % 100_000 + 1;
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
            c.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), c.count());
        for q in [0.1, 0.5, 0.9, 0.99] {
            assert_eq!(a.quantile(q), c.quantile(q));
        }
    }

    #[test]
    fn single_sample_quantiles_are_exact() {
        // one sample must be reported exactly at every q — even for values
        // that land inexactly in a log bucket, the [min, max] clamp in
        // quantile() recovers the sample itself
        for v in [0u64, 1, 31, 32, 33, 63, 64, 65, 1_000_003, u64::MAX] {
            let mut h = LogHistogram::new();
            h.record(v);
            for q in [0.0, 0.5, 0.99, 1.0] {
                assert_eq!(h.quantile(q), v, "value {v} q {q}");
            }
            assert_eq!(h.min(), v);
            assert_eq!(h.max(), v);
            assert_eq!(h.count(), 1);
        }
    }

    #[test]
    fn bucket_indexing_at_boundaries() {
        // values below SUBS are stored exactly, bucket index == value
        for v in 0..SUBS as u64 {
            assert_eq!(LogHistogram::index(v), v as usize);
        }
        // the first log bucket starts exactly at SUBS
        assert_eq!(LogHistogram::index(SUBS as u64), SUBS);
        // crossing every octave edge never decreases the bucket index
        for msb in SUB_BITS..63 {
            let edge = 1u64 << (msb + 1);
            let below = LogHistogram::index(edge - 1);
            let at = LogHistogram::index(edge);
            assert!(at >= below, "octave edge {edge}: index {at} < {below}");
        }
        // a bucket's representative value stays within 1/SUBS of any
        // sample it holds (the advertised relative-error bound)
        for v in [31u64, 32, 33, 63, 64, 65, 1 << 20, (1 << 20) + 1] {
            let rep = LogHistogram::value_of(LogHistogram::index(v));
            assert!(rep <= v, "representative {rep} above sample {v}");
            let rel = (v - rep) as f64 / v as f64;
            assert!(rel <= 1.0 / SUBS as f64, "value {v}: rel error {rel}");
        }
        // u64::MAX saturates into the last bucket instead of overflowing
        assert_eq!(LogHistogram::index(u64::MAX), OCTAVES * SUBS - 1);
    }

    #[test]
    fn monotone_quantiles() {
        let mut h = LogHistogram::new();
        for i in 1..5000u64 {
            h.record(i * 13 % 999_983);
        }
        let mut prev = 0;
        for i in 0..=100 {
            let q = h.quantile(i as f64 / 100.0);
            assert!(q >= prev, "quantiles must be monotone");
            prev = q;
        }
    }
}
