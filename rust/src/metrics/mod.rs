//! Latency/energy accounting and report emission.
//!
//! The paper's two metrics (§III) are processing latency (LAT, ms) and
//! energy (E, mJ). [`Cost`] carries both through every model and the
//! scheduler; [`Report`] renders the paper-style tables and CSV series the
//! bench harness emits.


pub mod device;
pub mod histogram;

/// A (latency, energy) pair. Latency in seconds, energy in joules.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Cost {
    pub seconds: f64,
    pub joules: f64,
}

impl Cost {
    pub const ZERO: Cost = Cost { seconds: 0.0, joules: 0.0 };

    pub fn new(seconds: f64, joules: f64) -> Self {
        Self { seconds, joules }
    }

    /// Sequential composition: latencies and energies both add.
    pub fn then(self, other: Cost) -> Cost {
        Cost { seconds: self.seconds + other.seconds, joules: self.joules + other.joules }
    }

    /// Parallel composition (the paper's latency-hiding max): latency is the
    /// max of the branches, energy still adds — both devices burn power.
    pub fn alongside(self, other: Cost) -> Cost {
        Cost { seconds: self.seconds.max(other.seconds), joules: self.joules + other.joules }
    }

    pub fn ms(&self) -> f64 {
        self.seconds * 1e3
    }

    pub fn mj(&self) -> f64 {
        self.joules * 1e3
    }

    /// Average power in watts over this interval (0 for zero-latency costs).
    pub fn watts(&self) -> f64 {
        if self.seconds > 0.0 { self.joules / self.seconds } else { 0.0 }
    }
}

impl std::ops::Add for Cost {
    type Output = Cost;
    fn add(self, rhs: Cost) -> Cost {
        self.then(rhs)
    }
}

impl std::iter::Sum for Cost {
    fn sum<I: Iterator<Item = Cost>>(iter: I) -> Cost {
        iter.fold(Cost::ZERO, Cost::then)
    }
}

/// Speedup / gain pair the paper reports in Table I.
#[derive(Debug, Clone, Copy)]
pub struct Gain {
    /// baseline_energy / ours_energy (>1 means we save energy).
    pub energy_gain: f64,
    /// baseline_latency / ours_latency (>1 means we are faster).
    pub latency_speedup: f64,
}

impl Gain {
    /// Gain of `ours` vs `baseline`. Degenerate (zero-cost) denominators
    /// are guarded instead of producing NaN/inf: a zero-vs-zero comparison
    /// is a 1.0x gain, a zero-cost `ours` against real baseline cost is
    /// reported as the maximum finite gain.
    pub fn of(baseline: Cost, ours: Cost) -> Gain {
        fn ratio(base: f64, ours: f64) -> f64 {
            if ours > 0.0 {
                base / ours
            } else if base > 0.0 {
                f64::MAX
            } else {
                1.0
            }
        }
        Gain {
            energy_gain: ratio(baseline.joules, ours.joules),
            latency_speedup: ratio(baseline.seconds, ours.seconds),
        }
    }

    /// Percent energy reduction vs baseline (paper abstract phrasing).
    /// Always finite: a zero gain (free baseline, costly ours) clamps to a
    /// huge-but-finite negative percentage instead of -inf.
    pub fn energy_reduction_pct(&self) -> f64 {
        (1.0 - 1.0 / self.energy_gain.max(1e-9)) * 100.0
    }

    pub fn latency_reduction_pct(&self) -> f64 {
        (1.0 - 1.0 / self.latency_speedup.max(1e-9)) * 100.0
    }
}

/// Fixed-width text table builder (paper-style rows) with CSV twin output.
#[derive(Debug, Default)]
pub struct Report {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Report {
    pub fn new(title: &str, columns: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Render as an aligned text table.
    pub fn to_text(&self) -> String {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("## {}\n", self.title));
        let head: Vec<String> = self
            .columns
            .iter()
            .zip(&widths)
            .map(|(c, w)| format!("{:<w$}", c, w = w))
            .collect();
        out.push_str(&head.join(" | "));
        out.push('\n');
        out.push_str(&"-".repeat(head.join(" | ").len()));
        out.push('\n');
        for row in &self.rows {
            let cells: Vec<String> = row
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{:<w$}", c, w = w))
                .collect();
            out.push_str(&cells.join(" | "));
            out.push('\n');
        }
        out
    }

    /// Render as CSV (series twin for plotting).
    pub fn to_csv(&self) -> String {
        let mut out = self.columns.join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }

    /// Write both representations under `dir` as `<stem>.txt` / `<stem>.csv`.
    pub fn write_to(&self, dir: &std::path::Path, stem: &str) -> std::io::Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(format!("{stem}.txt")), self.to_text())?;
        std::fs::write(dir.join(format!("{stem}.csv")), self.to_csv())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_composition_adds() {
        let a = Cost::new(1e-3, 2e-3);
        let b = Cost::new(2e-3, 3e-3);
        let c = a.then(b);
        assert!((c.seconds - 3e-3).abs() < 1e-12);
        assert!((c.joules - 5e-3).abs() < 1e-12);
    }

    #[test]
    fn parallel_composition_hides_latency_sums_energy() {
        let gpu = Cost::new(5e-3, 10e-3);
        let fpga = Cost::new(2e-3, 1e-3);
        let c = gpu.alongside(fpga);
        assert!((c.seconds - 5e-3).abs() < 1e-12, "latency hidden under max");
        assert!((c.joules - 11e-3).abs() < 1e-12, "energy adds");
    }

    #[test]
    fn gain_math() {
        let base = Cost::new(10e-3, 20e-3);
        let ours = Cost::new(8e-3, 10e-3);
        let g = Gain::of(base, ours);
        assert!((g.energy_gain - 2.0).abs() < 1e-9);
        assert!((g.latency_speedup - 1.25).abs() < 1e-9);
        assert!((g.energy_reduction_pct() - 50.0).abs() < 1e-9);
        assert!((g.latency_reduction_pct() - 20.0).abs() < 1e-9);
    }

    #[test]
    fn cost_sum_over_iterator() {
        let total: Cost = (0..4).map(|_| Cost::new(1e-3, 2e-3)).sum();
        assert!((total.seconds - 4e-3).abs() < 1e-12);
    }

    #[test]
    fn report_renders_text_and_csv() {
        let mut r = Report::new("Fig X", &["a", "b"]);
        r.row(vec!["1".into(), "2".into()]);
        let txt = r.to_text();
        assert!(txt.contains("Fig X") && txt.contains("1"));
        assert_eq!(r.to_csv(), "a,b\n1,2\n");
    }

    #[test]
    #[should_panic]
    fn report_rejects_bad_arity() {
        let mut r = Report::new("t", &["a", "b"]);
        r.row(vec!["1".into()]);
    }

    #[test]
    fn watts() {
        assert!((Cost::new(2.0, 10.0).watts() - 5.0).abs() < 1e-12);
        assert_eq!(Cost::ZERO.watts(), 0.0);
    }

    #[test]
    fn gain_of_zero_costs_is_guarded() {
        // zero vs zero: neutral gain, no NaN
        let g = Gain::of(Cost::ZERO, Cost::ZERO);
        assert_eq!(g.energy_gain, 1.0);
        assert_eq!(g.latency_speedup, 1.0);
        assert!(g.energy_reduction_pct().is_finite());
        // real baseline vs zero ours: finite (capped) gain, 100% reduction
        let g = Gain::of(Cost::new(1e-3, 2e-3), Cost::ZERO);
        assert!(g.energy_gain.is_finite() && g.energy_gain > 1.0);
        assert!((g.energy_reduction_pct() - 100.0).abs() < 1e-9);
        // zero baseline vs real ours: zero gain, but the pct stays finite
        let g = Gain::of(Cost::ZERO, Cost::new(1e-3, 2e-3));
        assert_eq!(g.energy_gain, 0.0);
        assert!(g.energy_reduction_pct().is_finite());
        assert!(g.energy_reduction_pct() < 0.0);
    }
}
