//! Per-device occupancy / transfer / energy counters for the online
//! heterogeneous executor (`hetero`).
//!
//! Each simulated device lane ([`crate::runtime::device`]) records, per
//! image it services: its **simulated** busy time (the cost-model seconds
//! the real hardware would spend), its **wall-clock** lane occupancy (the
//! scaled time the lane thread actually held the device), and the
//! simulated active energy. The link lane additionally counts the feature
//! map elements/bytes that crossed the simulated PCIe boundary.
//!
//! All counters are lock-free atomics: lanes are on the serving hot path
//! and the serve summary scrapes them live. Times are stored in integer
//! microseconds and energy in microjoules, so sub-microsecond costs of a
//! single image can round to zero individually — the counters are for
//! aggregate occupancy over many images, not per-image accounting (the
//! per-image truth stays in `Cost`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Counters of one simulated device lane.
#[derive(Debug, Default)]
pub struct DeviceCounters {
    jobs: AtomicU64,
    sim_busy_us: AtomicU64,
    wall_busy_us: AtomicU64,
    microjoules: AtomicU64,
}

impl DeviceCounters {
    /// Record one serviced job: `sim_seconds` of modeled device time,
    /// `wall` of lane occupancy, `joules` of modeled active energy.
    pub fn record(&self, sim_seconds: f64, wall: Duration, joules: f64) {
        self.jobs.fetch_add(1, Ordering::Relaxed);
        self.sim_busy_us.fetch_add((sim_seconds.max(0.0) * 1e6) as u64, Ordering::Relaxed);
        self.wall_busy_us.fetch_add(wall.as_micros() as u64, Ordering::Relaxed);
        self.microjoules.fetch_add((joules.max(0.0) * 1e6) as u64, Ordering::Relaxed);
    }

    /// Jobs serviced so far.
    pub fn jobs(&self) -> u64 {
        self.jobs.load(Ordering::Relaxed)
    }

    /// Total **simulated** device-busy time (cost-model seconds).
    pub fn sim_busy(&self) -> Duration {
        Duration::from_micros(self.sim_busy_us.load(Ordering::Relaxed))
    }

    /// Total **wall-clock** lane occupancy (scaled simulation time).
    pub fn wall_busy(&self) -> Duration {
        Duration::from_micros(self.wall_busy_us.load(Ordering::Relaxed))
    }

    /// Total simulated active energy, joules.
    pub fn joules(&self) -> f64 {
        self.microjoules.load(Ordering::Relaxed) as f64 / 1e6
    }

    /// Fraction of a wall-clock `window` this lane was occupied
    /// (0.0 on an empty window).
    pub fn occupancy(&self, window: Duration) -> f64 {
        if window.is_zero() {
            0.0
        } else {
            self.wall_busy().as_secs_f64() / window.as_secs_f64()
        }
    }
}

/// The counter set of one heterogeneous pipeline: one lane per simulated
/// device, plus link traffic and completed-image totals.
#[derive(Debug, Default)]
pub struct HeteroMetrics {
    /// GPU lane counters.
    pub gpu: DeviceCounters,
    /// FPGA lane counters.
    pub fpga: DeviceCounters,
    /// PCIe link lane counters.
    pub link: DeviceCounters,
    transferred_elems: AtomicU64,
    transferred_bytes: AtomicU64,
    images: AtomicU64,
}

impl HeteroMetrics {
    /// Record one simulated link crossing of `elems` feature-map elements
    /// occupying `bytes` on the wire.
    pub fn record_transfer(&self, elems: u64, bytes: u64) {
        self.transferred_elems.fetch_add(elems, Ordering::Relaxed);
        self.transferred_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Record one image completing the whole pipeline.
    pub fn record_image(&self) {
        self.images.fetch_add(1, Ordering::Relaxed);
    }

    /// Images that completed the whole pipeline.
    pub fn images(&self) -> u64 {
        self.images.load(Ordering::Relaxed)
    }

    /// Feature-map elements that crossed the simulated link.
    pub fn transferred_elems(&self) -> u64 {
        self.transferred_elems.load(Ordering::Relaxed)
    }

    /// Bytes that crossed the simulated link.
    pub fn transferred_bytes(&self) -> u64 {
        self.transferred_bytes.load(Ordering::Relaxed)
    }

    /// The lane with the largest *simulated* busy time — the measured
    /// pipeline bottleneck, comparable against the analytic
    /// `sched::pipeline::ServiceDemand::bottleneck` prediction.
    pub fn busiest(&self) -> (&'static str, Duration) {
        let mut best = ("gpu", self.gpu.sim_busy());
        if self.fpga.sim_busy() > best.1 {
            best = ("fpga", self.fpga.sim_busy());
        }
        if self.link.sim_busy() > best.1 {
            best = ("link", self.link.sim_busy());
        }
        best
    }
}

/// Cross-tenant arbitration counters of one **shared node device**
/// ([`crate::runtime::arbiter::DeviceSet`]): how often the device was
/// granted, how long acquirers queued for it, how long grants held it,
/// and how many waits were cancelled by a tenant retiring.
///
/// Holds are recorded with the *same* wall `Duration` (and the same
/// microsecond truncation) each tenant lane records into its own
/// [`DeviceCounters`], so when every tenant on the node is shared the
/// accounting identity is exact:
/// `node.holds() == Σ tenant.wall_busy()` and
/// `node.grants() == Σ tenant.jobs()` per device.
#[derive(Debug, Default)]
pub struct ArbiterCounters {
    grants: AtomicU64,
    wait_us: AtomicU64,
    hold_us: AtomicU64,
    cancelled: AtomicU64,
}

impl ArbiterCounters {
    /// Record one grant after `wait` of queueing.
    pub fn record_grant(&self, wait: Duration) {
        self.grants.fetch_add(1, Ordering::Relaxed);
        self.wait_us.fetch_add(wait.as_micros() as u64, Ordering::Relaxed);
    }

    /// Record one grant's `wall` hold (the lane's occupied time).
    pub fn record_hold(&self, wall: Duration) {
        self.hold_us.fetch_add(wall.as_micros() as u64, Ordering::Relaxed);
    }

    /// Record one wait cancelled by its tenant retiring.
    pub fn record_cancelled(&self) {
        self.cancelled.fetch_add(1, Ordering::Relaxed);
    }

    /// Grants issued so far.
    pub fn grants(&self) -> u64 {
        self.grants.load(Ordering::Relaxed)
    }

    /// Total wall-clock time acquirers spent queued for this device.
    pub fn waits(&self) -> Duration {
        Duration::from_micros(self.wait_us.load(Ordering::Relaxed))
    }

    /// Total wall-clock time grants held this device.
    pub fn holds(&self) -> Duration {
        Duration::from_micros(self.hold_us.load(Ordering::Relaxed))
    }

    /// Waits cancelled by tenant retirement.
    pub fn cancelled(&self) -> u64 {
        self.cancelled.load(Ordering::Relaxed)
    }

    /// Fraction of a wall-clock `window` this device was held by *some*
    /// tenant (0.0 on an empty window).
    pub fn utilization(&self, window: Duration) -> f64 {
        if window.is_zero() {
            0.0
        } else {
            self.holds().as_secs_f64() / window.as_secs_f64()
        }
    }
}

/// Node-level counters of a shared [`crate::runtime::arbiter::DeviceSet`]:
/// one [`ArbiterCounters`] per arbitrated device, aggregated across all
/// co-located tenants.
#[derive(Debug, Default)]
pub struct NodeDeviceMetrics {
    /// Shared GPU arbitration counters.
    pub gpu: ArbiterCounters,
    /// Shared FPGA arbitration counters.
    pub fpga: ArbiterCounters,
    /// Shared link arbitration counters.
    pub link: ArbiterCounters,
}

impl NodeDeviceMetrics {
    /// The device whose grants held the node longest (by wall hold).
    pub fn most_contended(&self) -> (&'static str, Duration) {
        let mut best = ("gpu", self.gpu.holds());
        if self.fpga.holds() > best.1 {
            best = ("fpga", self.fpga.holds());
        }
        if self.link.holds() > best.1 {
            best = ("link", self.link.holds());
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let c = DeviceCounters::default();
        c.record(1e-3, Duration::from_micros(500), 2e-3);
        c.record(2e-3, Duration::from_micros(500), 3e-3);
        assert_eq!(c.jobs(), 2);
        assert_eq!(c.sim_busy(), Duration::from_micros(3000));
        assert_eq!(c.wall_busy(), Duration::from_micros(1000));
        assert!((c.joules() - 5e-3).abs() < 1e-6);
    }

    #[test]
    fn occupancy_against_window() {
        let c = DeviceCounters::default();
        c.record(1.0, Duration::from_millis(250), 0.0);
        assert!((c.occupancy(Duration::from_secs(1)) - 0.25).abs() < 1e-9);
        assert_eq!(c.occupancy(Duration::ZERO), 0.0);
    }

    #[test]
    fn busiest_lane_wins() {
        let m = HeteroMetrics::default();
        m.gpu.record(1e-3, Duration::ZERO, 0.0);
        m.fpga.record(5e-3, Duration::ZERO, 0.0);
        m.link.record(2e-3, Duration::ZERO, 0.0);
        assert_eq!(m.busiest().0, "fpga");
        m.record_transfer(100, 100);
        m.record_image();
        assert_eq!(m.transferred_elems(), 100);
        assert_eq!(m.images(), 1);
    }

    #[test]
    fn arbiter_counters_track_grants_waits_and_holds() {
        let n = NodeDeviceMetrics::default();
        n.gpu.record_grant(Duration::from_micros(40));
        n.gpu.record_grant(Duration::from_micros(60));
        n.gpu.record_hold(Duration::from_millis(2));
        n.link.record_cancelled();
        assert_eq!(n.gpu.grants(), 2);
        assert_eq!(n.gpu.waits(), Duration::from_micros(100));
        assert_eq!(n.gpu.holds(), Duration::from_millis(2));
        assert_eq!(n.link.cancelled(), 1);
        assert_eq!(n.most_contended().0, "gpu");
        assert!((n.gpu.utilization(Duration::from_millis(4)) - 0.5).abs() < 1e-9);
        assert_eq!(n.fpga.utilization(Duration::ZERO), 0.0);
    }
}
