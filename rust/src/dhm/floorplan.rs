//! Resident-set floorplanner: turn a model plan's FPGA allocation into a
//! placement-level account of the device.
//!
//! The shared-fabric allocator (partition::plan_model / partition::dp)
//! decides *what* lives on the FPGA; this module answers *whether it
//! routes*: per-region ALM packing with a congestion model (placement
//! efficiency falls as utilization rises), M20K column assignment for the
//! line buffers, and the resulting achievable clock — the last check a
//! real DHM flow would run through Quartus before committing a partition.
//!
//! `hetero-dnn floorplan <model>` prints the report.

use crate::dhm::{DhmModel, ResourceUsage};
use crate::partition::ModelPlan;

/// The GX220 fabric is organised in columns of LAB rows; we model a
/// coarse grid of placement regions.
pub const REGIONS: usize = 16;

/// One placed chain (an FPGA step of some module).
#[derive(Debug, Clone)]
pub struct Placement {
    pub label: String,
    pub usage: ResourceUsage,
    /// Region indices this chain's logic occupies.
    pub regions: Vec<usize>,
}

/// Whole-device floorplan.
#[derive(Debug, Clone)]
pub struct Floorplan {
    pub placements: Vec<Placement>,
    pub region_alms: Vec<u64>,
    pub region_capacity: u64,
    pub total: ResourceUsage,
    pub m20k_capacity: u64,
}

/// Floorplan errors.
#[derive(Debug, thiserror::Error)]
pub enum FloorplanError {
    #[error("chain {label} needs {need} ALMs but only {free} remain placeable")]
    OutOfFabric { label: String, need: u64, free: u64 },
    #[error("M20K demand {need} exceeds device {have}")]
    OutOfM20k { need: u64, have: u64 },
}

impl Floorplan {
    /// Peak region utilization (routing congestion proxy).
    pub fn peak_utilization(&self) -> f64 {
        self.region_alms
            .iter()
            .map(|&a| a as f64 / self.region_capacity as f64)
            .fold(0.0, f64::max)
    }

    /// Achievable clock under congestion: DHM closes f_nom when every
    /// region sits below 80% and degrades ~linearly to 60% of f_nom at a
    /// fully packed worst region (empirical Quartus behaviour).
    pub fn achievable_clock(&self, f_nominal: f64) -> f64 {
        let peak = self.peak_utilization();
        if peak <= 0.80 {
            f_nominal
        } else {
            let derate = 1.0 - 0.4 * ((peak - 0.80) / 0.20).min(1.0).max(0.0);
            f_nominal * derate
        }
    }

    /// Text report (CLI face).
    pub fn report(&self, dhm: &DhmModel) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "floorplan — {} ({} regions x {} ALMs)\n",
            dhm.dev.name, REGIONS, self.region_capacity
        ));
        for p in &self.placements {
            out.push_str(&format!(
                "  {:<28} {:>7} ALMs {:>4} M20K  regions {:?}\n",
                p.label, p.usage.alms, p.usage.m20ks, p.regions
            ));
        }
        out.push_str(&format!(
            "  total: {} ALMs ({:.0}% of device), {} M20K, peak region {:.0}%\n",
            self.total.alms,
            self.total.alms as f64 / dhm.dev.alms as f64 * 100.0,
            self.total.m20ks,
            self.peak_utilization() * 100.0
        ));
        out.push_str(&format!(
            "  achievable clock: {:.0} MHz (nominal {:.0})\n",
            self.achievable_clock(dhm.dev.f_clk) / 1e6,
            dhm.dev.f_clk / 1e6
        ));
        out
    }
}

/// Greedy best-fit-decreasing placement of a plan's FPGA chains.
pub fn floorplan(dhm: &DhmModel, plan: &ModelPlan) -> Result<Floorplan, FloorplanError> {
    let region_capacity = dhm.dev.alms / REGIONS as u64;
    let mut region_alms = vec![0u64; REGIONS];
    let mut placements = Vec::new();
    let mut total = ResourceUsage::default();

    // collect chains, largest first (best-fit-decreasing)
    let mut chains: Vec<(String, ResourceUsage)> = Vec::new();
    for m in &plan.modules {
        collect(&m.steps, &mut chains);
    }
    chains.sort_by(|a, b| b.1.alms.cmp(&a.1.alms));

    for (label, usage) in chains {
        total = total.add(usage);
        if total.m20ks > dhm.dev.m20ks {
            return Err(FloorplanError::OutOfM20k { need: total.m20ks, have: dhm.dev.m20ks });
        }
        // spread the chain over the emptiest regions until placed
        let mut need = usage.alms;
        let mut used_regions = Vec::new();
        while need > 0 {
            let (ri, &load) = region_alms
                .iter()
                .enumerate()
                .min_by_key(|(_, &a)| a)
                .expect("regions");
            let free = region_capacity.saturating_sub(load);
            if free == 0 {
                let total_free: u64 =
                    region_alms.iter().map(|&a| region_capacity.saturating_sub(a)).sum();
                return Err(FloorplanError::OutOfFabric { label, need, free: total_free });
            }
            // chunked round-robin: never dump a whole chain into one region
            // — even spreading keeps peak utilization (and thus timing) flat
            let chunk = (region_capacity / 8).max(1);
            let take = need.min(free).min(chunk);
            region_alms[ri] += take;
            need -= take;
            used_regions.push(ri);
        }
        used_regions.sort_unstable();
        used_regions.dedup();
        placements.push(Placement { label, usage, regions: used_regions });
    }

    Ok(Floorplan {
        placements,
        region_alms,
        region_capacity,
        total,
        m20k_capacity: dhm.dev.m20ks,
    })
}

fn collect(steps: &[crate::partition::Step], out: &mut Vec<(String, ResourceUsage)>) {
    use crate::partition::Step;
    for s in steps {
        match s {
            Step::Fpga { label, usage, .. } => out.push((label.clone(), *usage)),
            Step::Parallel { gpu, fpga } => {
                collect(gpu, out);
                collect(fpga, out);
            }
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::partition::{Planner, Strategy};

    #[test]
    fn deployable_plans_floorplan_cleanly() {
        let p = Planner::default();
        let dhm = p.sdhm();
        for g in models::all_models() {
            let plan = p.plan_model(&g, Strategy::Auto);
            let fp = floorplan(&dhm, &plan).unwrap_or_else(|e| panic!("{}: {e}", g.name));
            assert!(fp.peak_utilization() <= 1.0);
            // deployable plans must not derate the clock catastrophically
            assert!(fp.achievable_clock(dhm.dev.f_clk) >= 0.6 * dhm.dev.f_clk);
        }
    }

    #[test]
    fn placement_conserves_alms() {
        let p = Planner::default();
        let dhm = p.sdhm();
        let g = models::shufflenetv2_05(224);
        let plan = p.plan_model(&g, Strategy::Auto);
        let fp = floorplan(&dhm, &plan).unwrap();
        let placed: u64 = fp.region_alms.iter().sum();
        assert_eq!(placed, fp.total.alms, "every ALM must land in a region");
    }

    #[test]
    fn paper_plan_may_exceed_single_fabric() {
        // the paper-methodology plan assumes per-module fabric availability;
        // its resident set can exceed one device — the floorplanner is the
        // component that catches this
        let p = Planner::default();
        let dhm = p.sdhm();
        let g = models::squeezenet(224);
        let plan = p.plan_model_paper(&g);
        let usage = plan.fpga_usage();
        let ceiling = (dhm.dev.alms as f64 * dhm.dev.util_ceiling) as u64;
        if usage.alms > ceiling {
            assert!(floorplan(&dhm, &plan).is_err());
        } else {
            assert!(floorplan(&dhm, &plan).is_ok());
        }
    }

    #[test]
    fn clock_derates_under_congestion() {
        let fp = Floorplan {
            placements: vec![],
            region_alms: vec![5000; REGIONS],
            region_capacity: 5020, // ~99.6% everywhere
            total: ResourceUsage::default(),
            m20k_capacity: 587,
        };
        let f = fp.achievable_clock(150e6);
        assert!(f < 150e6 && f >= 0.6 * 150e6, "{f}");
    }

    #[test]
    fn empty_plan_floorplans_trivially() {
        let p = Planner::default();
        let g = models::squeezenet(224);
        let plan = p.plan_model(&g, Strategy::GpuOnly);
        let fp = floorplan(&p.sdhm(), &plan).unwrap();
        assert!(fp.placements.is_empty());
        assert_eq!(fp.peak_utilization(), 0.0);
    }
}
