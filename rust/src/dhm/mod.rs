//! Direct Hardware Mapping (DHM) FPGA simulator — Intel Cyclone 10 GX.
//!
//! DHM [Abdelouahab et al., ESL'17] instantiates *every* MAC of a CNN layer
//! spatially on the fabric: one multiplier per weight, adder trees per
//! neuron, weights in registers next to the logic, line buffers in on-chip
//! M20K RAM, and a fully pipelined streaming datapath that absorbs one
//! input pixel (all channels in parallel) per clock. The result is the
//! paper's headline trade-off: orders-of-magnitude energy efficiency, but
//! resource usage proportional to `k*k*Ci*Co` — only small layers fit
//! (paper §III-A: 64 filters of 5x5 over 3 channels max the device out).
//!
//! The paper's FPGA numbers come from the Quartus Power Estimator over DHM
//! netlists; this module reproduces the same first-order model
//! (DESIGN.md §2): resource mapping -> fit check -> pipeline latency at
//! f_clk -> activity-based power integration.

pub mod floorplan;

use crate::graph::{Layer, OpKind};
use crate::metrics::Cost;

/// Resource budget of an FPGA device.
#[derive(Debug, Clone, Copy)]
pub struct FpgaDevice {
    pub name: &'static str,
    /// Adaptive logic modules (Cyclone 10 GX 220: 80,330 ALMs ~ 220K LEs).
    pub alms: u64,
    /// 18x19 DSP blocks; each maps two 8-bit MACs when split.
    pub dsps: u64,
    /// M20K embedded RAM blocks (20 kbit each).
    pub m20ks: u64,
    /// DHM pipeline clock (Hz). DHM designs on Cyclone 10 close ~150 MHz.
    pub f_clk: f64,
    /// Static power (W) incl. PCIe hard IP.
    pub p_static: f64,
    /// Dynamic power per active ALM at f_clk (W) — Quartus-PE-style
    /// activity-weighted coefficient.
    pub p_alm: f64,
    /// Dynamic power per DSP block at f_clk (W).
    pub p_dsp: f64,
    /// Dynamic power per active M20K at f_clk (W).
    pub p_m20k: f64,
    /// Max usable fraction of ALMs before routing congestion kills timing.
    pub util_ceiling: f64,
}

/// The board the paper uses.
pub const CYCLONE10_GX220: FpgaDevice = FpgaDevice {
    name: "Cyclone 10 GX 220",
    alms: 80_330,
    dsps: 192,
    m20ks: 587,
    f_clk: 150.0e6,
    p_static: 0.25,
    p_alm: 25.0e-6,
    p_dsp: 1.5e-3,
    p_m20k: 1.0e-3,
    util_ceiling: 0.95,
};

/// ALMs per 8-bit MAC mapped to soft logic (multiplier slice + its share of
/// the adder tree + weight register). Calibrated so the paper's observed
/// cliff — 64 filters of 5x5 over 3 channels ~ a full GX220 — holds:
/// (4800 - 384 DSP-mapped) * 16 = 70.7K ALMs ~ 88% of the device.
pub const ALMS_PER_MAC: u64 = 16;

/// Bytes per M20K block usable as line buffer (20 kbit = 2.5 KB).
pub const M20K_BYTES: u64 = 2_560;

/// Max pixel-level replication of the DHM datapath. When a layer's MAC
/// array is small, DHM replicates it P times and streams P pixels per
/// clock (the ESL'17 paper's throughput knob) — bounded by line-buffer
/// port bandwidth, not only logic.
pub const MAX_PIXEL_PARALLEL: u64 = 8;

/// Resource usage of one DHM-mapped layer.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ResourceUsage {
    pub macs_spatial: u64,
    pub dsps: u64,
    pub alms: u64,
    pub m20ks: u64,
}

impl ResourceUsage {
    pub fn add(self, other: ResourceUsage) -> ResourceUsage {
        ResourceUsage {
            macs_spatial: self.macs_spatial + other.macs_spatial,
            dsps: self.dsps + other.dsps,
            alms: self.alms + other.alms,
            m20ks: self.m20ks + other.m20ks,
        }
    }
}

/// Why a layer cannot be direct-hardware-mapped.
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
pub enum DhmError {
    #[error("layer needs {need} ALMs, device ceiling is {ceiling}")]
    AlmOverflow { need: u64, ceiling: u64 },
    #[error("layer needs {need} M20K blocks, device has {have}")]
    M20kOverflow { need: u64, have: u64 },
    #[error("op not DHM-mappable: {0}")]
    Unmappable(String),
}

/// DHM mapper/estimator for one FPGA device.
#[derive(Debug, Clone, Copy)]
pub struct DhmModel {
    pub dev: FpgaDevice,
    /// Cap on pixel-parallel replication. The default (standalone) model
    /// replicates small designs up to [`MAX_PIXEL_PARALLEL`]; the *shared
    /// fabric* model used for whole-network planning pins this to 1 —
    /// every FPGA-resident layer of the net coexists on the device, so no
    /// layer gets the fabric to itself (paper §IV: "delegating all the 1x1
    /// convolution on the FPGA for all layers").
    pub max_parallel: u64,
}

impl Default for DhmModel {
    fn default() -> Self {
        Self { dev: CYCLONE10_GX220, max_parallel: MAX_PIXEL_PARALLEL }
    }
}

impl DhmModel {
    pub fn new(dev: FpgaDevice) -> Self {
        Self { dev, max_parallel: MAX_PIXEL_PARALLEL }
    }

    /// Shared-fabric variant for whole-network planning: no replication,
    /// and no per-layer DSP monopoly (DSP blocks are a rounding error at
    /// network scale; every MAC is costed in soft logic, conservatively).
    pub fn shared(dev: FpgaDevice) -> Self {
        Self { dev: FpgaDevice { dsps: 0, ..dev }, max_parallel: 1 }
    }

    /// Spatial MAC units a layer instantiates (one per weight of the
    /// sliding window datapath).
    pub fn spatial_macs(&self, l: &Layer) -> Result<u64, DhmError> {
        let ci = l.input.c as u64;
        Ok(match l.op {
            OpKind::Conv { k, cout, .. } => (k * k) as u64 * ci * cout as u64,
            OpKind::DwConv { k, .. } => (k * k) as u64 * ci,
            OpKind::PwConv { cout, .. } => ci * cout as u64,
            OpKind::GConv { k, groups, cout, .. } => {
                // all groups instantiated side by side (they stream in parallel)
                (k * k) as u64 * (ci / groups as u64) * (cout / groups) as u64 * groups as u64
            }
            OpKind::MaxPool { k, .. } => (k * k) as u64 * ci, // comparators
            OpKind::GlobalAvgPool => ci,                      // accumulators
            ref op => return Err(DhmError::Unmappable(format!("{op:?}"))),
        })
    }

    /// Map a layer to device resources (without fit check).
    pub fn resources(&self, l: &Layer) -> Result<ResourceUsage, DhmError> {
        let macs = self.spatial_macs(l)?;
        // DSP blocks first (2 int8 MACs each), remainder in soft logic.
        let dsp_macs = (self.dev.dsps * 2).min(macs);
        let dsps = dsp_macs.div_ceil(2);
        let alms = (macs - dsp_macs) * ALMS_PER_MAC;
        // line buffers: (k-1) input rows of W x Ci bytes (8-bit features)
        let k = match l.op {
            OpKind::Conv { k, .. } | OpKind::DwConv { k, .. } | OpKind::GConv { k, .. } => k,
            OpKind::MaxPool { k, .. } => k,
            _ => 1,
        };
        let line_bytes = (k.saturating_sub(1) * l.input.w * l.input.c) as u64;
        let m20ks = line_bytes.div_ceil(M20K_BYTES);
        Ok(ResourceUsage { macs_spatial: macs, dsps, alms, m20ks })
    }

    /// Fit check against the device budget (for a set of fused layers the
    /// caller sums usages first).
    pub fn check_fit(&self, u: ResourceUsage) -> Result<(), DhmError> {
        let ceiling = (self.dev.alms as f64 * self.dev.util_ceiling) as u64;
        if u.alms > ceiling {
            return Err(DhmError::AlmOverflow { need: u.alms, ceiling });
        }
        if u.m20ks > self.dev.m20ks {
            return Err(DhmError::M20kOverflow { need: u.m20ks, have: self.dev.m20ks });
        }
        Ok(())
    }

    /// True if the layer can be mapped alone on the device.
    pub fn fits(&self, l: &Layer) -> bool {
        self.resources(l).map(|u| self.check_fit(u).is_ok()).unwrap_or(false)
    }

    /// Largest input-channel split `g <= l.input.c` such that the layer
    /// restricted to `g` input channels fits (Fig 2b GConv partitioning).
    /// Returns 0 if not even one channel fits.
    pub fn max_feasible_split(&self, l: &Layer) -> usize {
        let mut lo = 0usize;
        let mut hi = l.input.c;
        // monotone in g -> binary search the cliff
        while lo < hi {
            let mid = (lo + hi + 1) / 2;
            let mut probe = *l;
            probe.input.c = mid;
            if self.resources(&probe).map(|u| self.check_fit(u).is_ok()).unwrap_or(false) {
                lo = mid;
            } else {
                hi = mid - 1;
            }
        }
        lo
    }

    /// Pixel-parallel replication factor for a layer: the largest power of
    /// two P (<= MAX_PIXEL_PARALLEL) such that P copies of the datapath
    /// still fit the device. Small layers stream P pixels per clock.
    pub fn pixel_parallel(&self, u: ResourceUsage) -> u64 {
        let mut p = 1;
        while p < self.max_parallel {
            let scaled = ResourceUsage {
                macs_spatial: u.macs_spatial * (p * 2),
                dsps: (u.dsps * (p * 2)).min(self.dev.dsps),
                alms: u.alms * (p * 2)
                    + (u.dsps * (p * 2)).saturating_sub(self.dev.dsps) * 2 * ALMS_PER_MAC,
                m20ks: u.m20ks * (p * 2),
            };
            if self.check_fit(scaled).is_err() {
                break;
            }
            p *= 2;
        }
        p
    }

    /// Replicated resource usage at pixel-parallelism P (DSPs saturate;
    /// overflow MACs spill to ALMs).
    pub fn replicated(&self, u: ResourceUsage, p: u64) -> ResourceUsage {
        let want_dsp_macs = u.dsps * 2 * p;
        let dsp_macs = want_dsp_macs.min(self.dev.dsps * 2);
        ResourceUsage {
            macs_spatial: u.macs_spatial * p,
            dsps: dsp_macs.div_ceil(2),
            alms: u.alms * p + (want_dsp_macs - dsp_macs) * ALMS_PER_MAC / 2,
            m20ks: u.m20ks * p,
        }
    }

    /// Pipeline cycles to stream one feature map through the layer at
    /// pixel-parallelism `p`: fill (k-1 rows + k pixels) + H*W/p pixels +
    /// adder-tree depth.
    pub fn cycles_at(&self, l: &Layer, p: u64) -> Result<u64, DhmError> {
        let macs = self.spatial_macs(l)?; // validates mappability
        let (h, w) = (l.input.h as u64, l.input.w as u64);
        let k = match l.op {
            OpKind::Conv { k, .. } | OpKind::DwConv { k, .. } | OpKind::GConv { k, .. } => k as u64,
            OpKind::MaxPool { k, .. } => k as u64,
            _ => 1,
        };
        let fill = (k - 1) * w + k;
        let tree_depth = 64 - u64::leading_zeros(macs.max(1)) as u64; // ~log2
        Ok((h * w).div_ceil(p) + fill + tree_depth)
    }

    /// Pipeline cycles at the layer's natural replication factor.
    pub fn cycles(&self, l: &Layer) -> Result<u64, DhmError> {
        let u = self.resources(l)?;
        self.cycles_at(l, self.pixel_parallel(u))
    }

    /// Streaming latency of one layer (seconds).
    pub fn latency(&self, l: &Layer) -> Result<f64, DhmError> {
        Ok(self.cycles(l)? as f64 / self.dev.f_clk)
    }

    /// Average power while streaming (W), Quartus-PE style.
    pub fn power(&self, u: ResourceUsage) -> f64 {
        self.dev.p_static
            + u.alms as f64 * self.dev.p_alm
            + u.dsps as f64 * self.dev.p_dsp
            + u.m20ks as f64 * self.dev.p_m20k
    }

    /// Full cost of streaming one feature map through a DHM-mapped layer,
    /// at the layer's natural pixel-parallel replication.
    pub fn cost(&self, l: &Layer) -> Result<Cost, DhmError> {
        let u = self.resources(l)?;
        self.check_fit(u)?;
        let p = self.pixel_parallel(u);
        let lat = self.cycles_at(l, p)? as f64 / self.dev.f_clk;
        Ok(Cost::new(lat, self.power(self.replicated(u, p)) * lat))
    }

    /// Cost of a *fused chain* of layers resident together (Fig 2c):
    /// resources add, the pipeline streams once (latency = slowest stage
    /// input stream + per-stage fills), intermediates never leave chip.
    pub fn fused_cost(&self, layers: &[Layer]) -> Result<Cost, DhmError> {
        let mut usage = ResourceUsage::default();
        for l in layers {
            usage = usage.add(self.resources(l)?);
        }
        self.check_fit(usage)?;
        // the chain is one deep pipeline: total cycles = first-layer stream
        // + downstream fill latencies
        let first = layers.first().ok_or_else(|| DhmError::Unmappable("empty chain".into()))?;
        let p = self.pixel_parallel(usage);
        let mut cycles = self.cycles_at(first, p)?;
        for l in &layers[1..] {
            let k = match l.op {
                OpKind::Conv { k, .. } | OpKind::DwConv { k, .. } | OpKind::GConv { k, .. } => k as u64,
                _ => 1,
            };
            cycles += (k - 1) * l.input.w as u64 + k + 8; // fill + register stages
        }
        let lat = cycles as f64 / self.dev.f_clk;
        Ok(Cost::new(lat, self.power(self.replicated(usage, p)) * lat))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Activation, Layer, OpKind, TensorShape};

    fn conv(h: usize, ci: usize, k: usize, n: usize) -> Layer {
        Layer::new(
            OpKind::Conv { k, stride: 1, pad: k / 2, cout: n, act: Activation::Relu },
            TensorShape::new(h, h, ci),
        )
    }

    #[test]
    fn paper_cliff_conv5x5x64_fits_128_does_not() {
        // paper §III-B: "64 filters of size 5x5 in this case" is the max
        let m = DhmModel::default();
        assert!(m.fits(&conv(224, 3, 5, 64)), "5x5x64 over 3ch must fit");
        assert!(!m.fits(&conv(224, 3, 5, 128)), "5x5x128 must overflow");
        assert!(!m.fits(&conv(224, 3, 7, 64)), "7x7x64 must overflow");
    }

    #[test]
    fn small_convs_fit_easily() {
        let m = DhmModel::default();
        assert!(m.fits(&conv(224, 3, 1, 64)));
        assert!(m.fits(&conv(224, 3, 3, 64)));
        // typical FPGA-side module stages
        let pw = Layer::new(
            OpKind::PwConv { cout: 16, act: Activation::None },
            TensorShape::new(28, 28, 96),
        );
        assert!(m.fits(&pw));
    }

    #[test]
    fn fire_expand3_needs_split() {
        // fire2 expand3x3 (16ch -> 64, k3) = 9216 MACs: over budget alone,
        // the GConv split must find a feasible partial mapping.
        let m = DhmModel::default();
        let e3 = conv(54, 16, 3, 64);
        assert!(!m.fits(&e3));
        let g = m.max_feasible_split(&e3);
        assert!(g >= 4 && g < 16, "feasible split {g}");
        // the split is the cliff: g fits, g+1 does not
        let mut probe = e3;
        probe.input.c = g + 1;
        assert!(!m.fits(&probe));
    }

    #[test]
    fn resources_monotone_in_filters() {
        let m = DhmModel::default();
        let a = m.resources(&conv(56, 8, 3, 16)).unwrap();
        let b = m.resources(&conv(56, 8, 3, 32)).unwrap();
        assert!(b.alms > a.alms);
        assert!(b.macs_spatial == 2 * a.macs_spatial);
    }

    #[test]
    fn latency_is_streaming_dominated() {
        // at P=1 the pipeline absorbs one pixel/cycle: cycles ~ H*W,
        // nearly independent of the filter count
        let m = DhmModel::default();
        let c16 = m.cycles_at(&conv(224, 3, 3, 16), 1).unwrap() as f64;
        let c64 = m.cycles_at(&conv(224, 3, 3, 64), 1).unwrap() as f64;
        let stream = 224.0 * 224.0;
        assert!((c16 - stream) / stream < 0.02);
        assert!((c64 - c16).abs() / c16 < 0.01, "filters barely change latency");
    }

    #[test]
    fn pixel_parallel_speeds_up_small_layers() {
        // small MAC arrays replicate; the cliff design (5x5x64) cannot
        let m = DhmModel::default();
        let small = m.resources(&conv(224, 3, 3, 2)).unwrap();
        let big = m.resources(&conv(224, 3, 5, 64)).unwrap();
        assert!(m.pixel_parallel(small) >= 4);
        assert_eq!(m.pixel_parallel(big), 1);
        // latency improves accordingly
        let l_small = m.latency(&conv(224, 3, 3, 2)).unwrap();
        let l_big = m.latency(&conv(224, 3, 5, 64)).unwrap();
        assert!(l_small < 0.4 * l_big, "{l_small} vs {l_big}");
    }

    #[test]
    fn power_scales_with_resources() {
        let m = DhmModel::default();
        let small = m.resources(&conv(224, 3, 1, 8)).unwrap();
        let big = m.resources(&conv(224, 3, 5, 64)).unwrap();
        assert!(m.power(big) > 2.0 * m.power(small));
        // full-ish device lands in the 1.5-3.5 W envelope Quartus PE reports
        assert!(m.power(big) > 1.5 && m.power(big) < 3.5, "{}", m.power(big));
    }

    #[test]
    fn energy_orders_of_magnitude_table() {
        // paper Fig 1b: FPGA energy for a small conv is sub-mJ
        let m = DhmModel::default();
        let c = m.cost(&conv(224, 3, 3, 64)).unwrap();
        assert!(c.mj() < 1.0, "DHM conv energy {} mJ", c.mj());
        assert!(c.ms() < 1.0, "DHM conv latency {} ms", c.ms());
    }

    #[test]
    fn fused_chain_beats_unfused_with_interlayer_transfers() {
        // Fig 2c's point: fusing avoids the PCIe round trips between
        // stages. Compare the fused chain against per-layer execution
        // with an inter-stage transfer each way.
        let m = DhmModel::default();
        let link = crate::link::LinkModel::default();
        let pw1 = Layer::new(
            OpKind::PwConv { cout: 24, act: Activation::Relu },
            TensorShape::new(28, 28, 24),
        );
        let dw = Layer::new(OpKind::DwConv { k: 3, stride: 1, act: Activation::None }, pw1.output);
        let pw2 = Layer::new(OpKind::PwConv { cout: 24, act: Activation::Relu }, dw.output);
        let fused = m.fused_cost(&[pw1, dw, pw2]).unwrap();
        let mut unfused = Cost::ZERO;
        for l in [pw1, dw, pw2] {
            unfused = unfused.then(m.cost(&l).unwrap());
        }
        // two inter-stage round trips the fused version never pays
        for l in [pw1, dw] {
            unfused = unfused
                .then(link.transfer(l.output.elems(), crate::link::Precision::Int8))
                .then(link.transfer(l.output.elems(), crate::link::Precision::Int8));
        }
        assert!(
            fused.seconds < 0.6 * unfused.seconds,
            "fused {} vs unfused+transfers {}",
            fused.seconds,
            unfused.seconds
        );
    }

    #[test]
    fn unmappable_ops_error() {
        let m = DhmModel::default();
        let l = Layer::new(OpKind::Add, TensorShape::new(8, 8, 8));
        assert!(matches!(m.cost(&l), Err(DhmError::Unmappable(_))));
    }

    #[test]
    fn max_feasible_split_zero_when_nothing_fits() {
        let tiny = FpgaDevice { alms: 100, dsps: 0, m20ks: 1, ..CYCLONE10_GX220 };
        let m = DhmModel::new(tiny);
        assert_eq!(m.max_feasible_split(&conv(224, 16, 3, 64)), 0);
    }

    #[test]
    fn max_feasible_split_full_when_everything_fits() {
        let m = DhmModel::default();
        let pw = Layer::new(
            OpKind::PwConv { cout: 16, act: Activation::None },
            TensorShape::new(28, 28, 96),
        );
        assert_eq!(m.max_feasible_split(&pw), 96);
    }
}
