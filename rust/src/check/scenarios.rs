//! The serving stack's checked scenarios.
//!
//! Each scenario models one concurrency surface of the engine as a
//! [`Checker`] over the **production cores** — the batcher runs the real
//! [`BatcherCore`], the pipeline scenario routes jobs with the real
//! [`LaneCore`] plans, the admission scenario drives the real
//! [`AdmissionController`] — with the channels and the clock replaced by
//! the deterministic stand-ins from [`super::sync`]. The invariants are
//! the [`super::invariants`] ledgers, shared with the property tests.
//!
//! The nine core scenarios are the serving stack's headline claims:
//!
//! 1. [`reply_exactly_once`] — batcher + worker + window timeouts +
//!    deadline shedding: every submitted request is answered exactly once
//!    whether it was served, shed, or drained.
//! 2. [`slot_exactly_once`] — the real admission controller against
//!    budget rejections, cache hits, retires and racing submits: every
//!    slot taken is returned exactly once and the controller's in-flight
//!    count always equals the ledger's outstanding slots.
//! 3. [`drain_empties_queues`] — a Stop racing live producers: after the
//!    close → drain → join sequence, no queue holds an unanswered
//!    request.
//! 4. [`backpressure_no_deadlock`] — a three-lane pipeline over
//!    capacity-1 queues at full backpressure: the explorer's built-in
//!    deadlock detection is the property.
//! 5. [`hot_swap_linearized`] — retire (unregister, then drain) and
//!    register racing in-flight traffic: the registry window is
//!    linearized, nothing is double-answered or stranded.
//! 6. [`router_failover_exactly_once`] — the cluster router's
//!    [`RouterCore`] against a replica that answers, fails retryably, or
//!    dies mid-request: the reply for a failed-over request is delivered
//!    exactly once even when the original replica's late response races
//!    the retry, and no client request fails while a sibling is healthy.
//! 7. [`controller_actions_linearized`] — the traffic lab's adaptive
//!    [`ControllerCore`] flipping a model's placement (the real two-step
//!    retire + register) against a racing operator swap and live
//!    clients: no request or slot is lost, the model always survives
//!    the race, nobody registers a duplicate, and the core's flips
//!    honor the hysteresis window on every interleaving.
//! 8. [`arbiter_grants_exactly_once`] — the node-level device
//!    [`ArbiterCore`] against two tenants racing acquire / release /
//!    retire-mid-wait on capacity-1 shared devices: every ticket is
//!    granted at most once, a release always returns capacity (the head
//!    waiter is granted in the same step), a retire cancels exactly the
//!    tenant's queued tickets and loses nothing, and the node always
//!    quiesces with every ticket settled.
//! 9. [`trace_spans_well_nested`] — the flight recorder's **real**
//!    [`Recorder`] under two emitter lanes walking the canonical span
//!    script against freely interleaved snapshots: every admitted
//!    [`TraceId`] gets its `admitted` and `reply_written` endpoints
//!    exactly once, device acquire/release spans nest properly within
//!    each per-thread ring, and the recorder never blocks (or loses) an
//!    emit no matter where a snapshot lands.
//!
//! [`buggy_double_reply`] is the checker's own regression: a deliberately
//! seeded shed-but-still-dispatched bug the explorer must catch and the
//! replayer must reproduce from the printed schedule alone.

use super::dfs::{ActionOutcome, Checker, Profile, Report, Violation};
use super::invariants::{ReplyLedger, SlotLedger};
use super::sync::{Clock, RecvOutcome, SendBlocked, VChan};
use crate::cluster::{FailClass, RouterCore, RouterEffect, RouterEvent};
use crate::coordinator::admission::{Admission, AdmissionConfig, AdmissionController};
use crate::coordinator::step::{
    BatchItem, BatcherCore, BatcherEffect, BatcherEvent, BatcherWait, StopCause,
};
use crate::coordinator::{Placement, Priority};
use crate::hetero::pipeline::{LaneCore, LaneOp};
use crate::obs::{EventKind, Recorder, ThreadRing, TraceId};
use crate::partition::Resource;
use crate::runtime::arbiter::{ArbiterCore, ArbiterEffect, ArbiterEvent, DeviceId, TenantId, Ticket};
use crate::workloads::{
    ControllerConfig, ControllerCore, ControllerEffect, ControllerEvent, FlipTo, ModelObservation,
};
use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// The modeled batch window (virtual — only ever crossed by an explicit
/// clock-advance step).
const MAX_WAIT: Duration = Duration::from_millis(10);

/// The modeled per-request service time fed to the admission EWMA.
const SERVICE: Duration = Duration::from_millis(1);

/// A checker-side batch item: what the engine's `Request` looks like to
/// [`BatcherCore`], minus the payload and the reply channel (the
/// [`ReplyLedger`] plays that part).
#[derive(Debug)]
struct TestReq {
    tag: u64,
    priority: Priority,
    deadline: Option<Duration>,
    enqueued: Instant,
}

impl BatchItem for TestReq {
    fn priority(&self) -> Priority {
        self.priority
    }
    fn deadline(&self) -> Option<Duration> {
        self.deadline
    }
    fn enqueued(&self) -> Instant {
        self.enqueued
    }
}

/// The batcher mailbox alphabet (the engine's `Msg`).
enum Mail {
    Req(TestReq),
    Stop(StopCause),
}

// ---------------------------------------------------------------------------
// scenarios 1 & 3: batcher + worker over the production BatcherCore

/// State for the batcher scenarios: two producers, the production
/// [`BatcherCore`] pumped by a recv action and a window-timeout action,
/// one worker, and a stop path.
struct BatcherWorld {
    clock: Clock,
    core: BatcherCore<TestReq>,
    mailbox: VChan<Mail>,
    dispatched: VChan<Vec<TestReq>>,
    replies: ReplyLedger,
    slots: SlotLedger,
    produced: u64,
    n: u64,
    a_left: u64,
    b_left: u64,
    with_deadlines: bool,
    cause: StopCause,
    stop_sent: bool,
    batcher_done: bool,
}

/// Requests submitted across both producers in the batcher scenarios.
const N_BATCHER: u64 = 4;

impl BatcherWorld {
    fn new(max_batch: usize, with_deadlines: bool, cause: StopCause) -> Self {
        Self {
            clock: Clock::new(),
            core: BatcherCore::new(max_batch, MAX_WAIT),
            mailbox: VChan::unbounded(),
            dispatched: VChan::unbounded(),
            replies: ReplyLedger::new(),
            slots: SlotLedger::new(),
            produced: 0,
            n: N_BATCHER,
            a_left: N_BATCHER / 2,
            b_left: N_BATCHER - N_BATCHER / 2,
            with_deadlines,
            cause,
            stop_sent: false,
            batcher_done: false,
        }
    }

    /// One reply delivery: the response channel fires and the request's
    /// admission slot drop-guard releases.
    fn reply(&mut self, tag: u64) {
        self.replies.record(tag);
        self.slots.put(tag);
    }

    /// Submit the next request (the engine's front door: slot taken
    /// first, then the mailbox send — a closed mailbox bounces into an
    /// immediate error reply, releasing the slot).
    fn submit_one(&mut self) {
        let tag = self.produced;
        self.produced += 1;
        // a zero deadline expires as soon as virtual time moves at all,
        // so the same request is served on fast paths and shed on
        // window-elapsed paths — both must answer exactly once
        let deadline = (self.with_deadlines && tag % 2 == 1).then_some(Duration::ZERO);
        let priority = if tag % 3 == 0 { Priority::High } else { Priority::Normal };
        let req = TestReq { tag, priority, deadline, enqueued: self.clock.now() };
        self.slots.take(tag);
        if let Err(SendBlocked::Closed(Mail::Req(r)) | SendBlocked::Full(Mail::Req(r))) =
            self.mailbox.try_send(Mail::Req(req))
        {
            self.reply(r.tag);
        }
    }

    /// Send the Stop marker (idempotent across probes via `stop_sent`).
    fn send_stop(&mut self) -> ActionOutcome {
        if self.stop_sent {
            return ActionOutcome::Done;
        }
        self.stop_sent = true;
        let _ = self.mailbox.try_send(Mail::Stop(self.cause));
        ActionOutcome::Ran
    }

    /// The batcher shell's recv arm: translate one mailbox observation
    /// into a [`BatcherEvent`] and execute the core's effects.
    fn batcher_recv(&mut self) -> ActionOutcome {
        if self.batcher_done {
            return ActionOutcome::Done;
        }
        let event = match self.mailbox.try_recv() {
            RecvOutcome::Item(Mail::Req(r)) => BatcherEvent::Arrived(r),
            RecvOutcome::Item(Mail::Stop(c)) => BatcherEvent::Stop(c),
            RecvOutcome::Empty => return ActionOutcome::Blocked,
            RecvOutcome::Closed => BatcherEvent::MailboxClosed,
        };
        let fx = self.core.step(self.clock.now(), event);
        self.apply(fx);
        ActionOutcome::Ran
    }

    /// The batcher shell's timeout arm: when a window is open, advance
    /// virtual time to it and feed `WindowElapsed`. A real `recv_timeout`
    /// may fire even while a message sits undelivered — so this action is
    /// runnable whenever a window is open, not only when the mailbox is
    /// empty.
    fn batcher_timeout(&mut self) -> ActionOutcome {
        if self.batcher_done {
            return ActionOutcome::Done;
        }
        let BatcherWait::Window(window) = self.core.wait() else {
            return ActionOutcome::Blocked;
        };
        self.clock.advance(window.saturating_duration_since(self.clock.now()));
        let fx = self.core.step(self.clock.now(), BatcherEvent::WindowElapsed);
        self.apply(fx);
        ActionOutcome::Ran
    }

    /// The worker: serve one dispatched batch (every request answered).
    fn worker(&mut self) -> ActionOutcome {
        match self.dispatched.try_recv() {
            RecvOutcome::Item(batch) => {
                for r in batch {
                    self.reply(r.tag);
                }
                ActionOutcome::Ran
            }
            RecvOutcome::Empty => ActionOutcome::Blocked,
            RecvOutcome::Closed => ActionOutcome::Done,
        }
    }

    /// Execute one event's effects, in order — the model of the
    /// production shell's effect loop, including the post-exit mailbox
    /// drain (close → drain → join).
    fn apply(&mut self, effects: Vec<BatcherEffect<TestReq>>) {
        for effect in effects {
            match effect {
                // the accepted counter is engine telemetry, not a
                // checked invariant here
                BatcherEffect::Accepted => {}
                BatcherEffect::Shed { expired, .. } => {
                    for r in expired {
                        self.reply(r.tag);
                    }
                }
                BatcherEffect::Dispatch(batch) => {
                    let send = self.dispatched.try_send(batch);
                    if let Err(SendBlocked::Full(b) | SendBlocked::Closed(b)) = send {
                        // dispatch to a dead/jammed worker: answer the
                        // batch with errors rather than strand it
                        for r in b {
                            self.reply(r.tag);
                        }
                    }
                }
                BatcherEffect::Exit(_) => {
                    self.batcher_done = true;
                    loop {
                        match self.mailbox.try_recv() {
                            RecvOutcome::Item(Mail::Req(r)) => self.reply(r.tag),
                            RecvOutcome::Item(Mail::Stop(_)) => {}
                            RecvOutcome::Empty | RecvOutcome::Closed => break,
                        }
                    }
                    // receiver dropped: later sends bounce at the front
                    // door; worker channel closes so workers drain out
                    self.mailbox.close();
                    self.dispatched.close();
                }
            }
        }
    }
}

/// The invariants every batcher scenario shares.
fn batcher_invariants(c: Checker<BatcherWorld>) -> Checker<BatcherWorld> {
    c.invariant("reply at-most-once", |w: &BatcherWorld| w.replies.at_most_once())
        .invariant("slot at-most-once", |w: &BatcherWorld| w.slots.at_most_once())
        .finally("reply exactly-once", |w: &BatcherWorld| w.replies.exactly_once(w.n))
        .finally("slots balanced", |w: &BatcherWorld| w.slots.balanced())
        .finally("queues drained", |w: &BatcherWorld| {
            if w.mailbox.is_empty() && w.dispatched.is_empty() {
                Ok(())
            } else {
                Err(format!(
                    "mailbox holds {} item(s), dispatch queue {} batch(es) after quiescence",
                    w.mailbox.len(),
                    w.dispatched.len()
                ))
            }
        })
}

/// Producer action: submit until this client's quota is spent.
fn producer(
    left: fn(&mut BatcherWorld) -> &mut u64,
) -> impl Fn(&mut BatcherWorld) -> ActionOutcome {
    move |w: &mut BatcherWorld| {
        if *left(w) == 0 {
            return ActionOutcome::Done;
        }
        *left(w) -= 1;
        w.submit_one();
        ActionOutcome::Ran
    }
}

/// Scenario 1 — **reply-exactly-once**: two producers (half the requests
/// carry an already-tight deadline), the production batcher core with
/// both its recv and its window-timeout arms schedulable, one worker,
/// and an orderly stop once the producers are done. Served, shed, and
/// drained requests must each be answered exactly once.
pub fn reply_exactly_once(profile: Profile) -> Result<Report, Violation> {
    let checker = Checker::new(|| BatcherWorld::new(2, true, StopCause::Shutdown))
        .action("client_a", producer(|w| &mut w.a_left))
        .action("client_b", producer(|w| &mut w.b_left))
        .action("closer", |w: &mut BatcherWorld| {
            // orderly shutdown: stop only once every request is in
            if w.produced < w.n {
                return ActionOutcome::Blocked;
            }
            w.send_stop()
        })
        .action("batcher_recv", BatcherWorld::batcher_recv)
        .action("batcher_timeout", BatcherWorld::batcher_timeout)
        .action("worker", BatcherWorld::worker);
    batcher_invariants(checker).explore(profile)
}

/// Scenario 3 — **drain-empties-queues**: like scenario 1, but the
/// closer races the producers — Stop can land before, between, or after
/// any submit. Wherever it lands, the close → drain → join sequence must
/// leave every queue empty with every request answered (late submits
/// bounce off the closed mailbox into immediate error replies).
pub fn drain_empties_queues(profile: Profile) -> Result<Report, Violation> {
    let checker = Checker::new(|| BatcherWorld::new(2, false, StopCause::Retire))
        .action("client_a", producer(|w| &mut w.a_left))
        .action("client_b", producer(|w| &mut w.b_left))
        .action("closer", BatcherWorld::send_stop)
        .action("batcher_recv", BatcherWorld::batcher_recv)
        .action("batcher_timeout", BatcherWorld::batcher_timeout)
        .action("worker", BatcherWorld::worker);
    batcher_invariants(checker)
        .finally("batcher exited", |w: &BatcherWorld| {
            if w.batcher_done {
                Ok(())
            } else {
                Err("stop was sent but the batcher never exited".to_string())
            }
        })
        .explore(profile)
}

// ---------------------------------------------------------------------------
// scenario 2: the real AdmissionController at the front door

/// Requests submitted in the admission scenario.
const N_ADMIT: u64 = 6;

/// State for the admission scenario: the **real** lock-free
/// [`AdmissionController`] (atomics and all — this is why the explorer
/// replays instead of cloning), two models sharing it, a per-model
/// budget on X, a result cache on X, and a retire racing the traffic.
struct FrontDoorWorld {
    ctl: AdmissionController,
    replies: ReplyLedger,
    slots: SlotLedger,
    /// Model X's result cache, keyed by content digest.
    cache: BTreeSet<u64>,
    queue_x: VChan<u64>,
    queue_y: VChan<u64>,
    in_flight_x: u64,
    in_flight_y: u64,
    budget_x: u64,
    produced: u64,
    n: u64,
    registry_x: bool,
    retired_x: bool,
    shut_y: bool,
}

impl FrontDoorWorld {
    fn new() -> Self {
        Self {
            ctl: AdmissionController::new(AdmissionConfig {
                deadline: Duration::from_secs(1),
                // small enough that three queued requests shed the fourth
                max_in_flight: 3,
                alpha: 0.2,
            }),
            replies: ReplyLedger::new(),
            slots: SlotLedger::new(),
            cache: BTreeSet::new(),
            queue_x: VChan::unbounded(),
            queue_y: VChan::unbounded(),
            in_flight_x: 0,
            in_flight_y: 0,
            budget_x: 1,
            produced: 0,
            n: N_ADMIT,
            registry_x: true,
            retired_x: false,
            shut_y: false,
        }
    }

    /// The engine front door for one request (even tags → model X with
    /// budget + cache, odd tags → model Y), exactly in the engine's
    /// order: registry, cache, shared admission, per-model budget, then
    /// the pool mailbox.
    fn submit(&mut self) -> ActionOutcome {
        if self.produced >= self.n {
            return ActionOutcome::Done;
        }
        let tag = self.produced;
        self.produced += 1;
        let to_x = tag % 2 == 0;
        if to_x && !self.registry_x {
            // unknown model: answered before any slot is taken
            self.replies.record(tag);
            return ActionOutcome::Ran;
        }
        if to_x && self.cache.contains(&(tag % 4)) {
            // cache hit: answered without admission
            self.replies.record(tag);
            return ActionOutcome::Ran;
        }
        match self.ctl.admit() {
            Admission::Accept => {}
            Admission::Reject { .. } => {
                // shed at the shared door: no slot was ever taken
                self.replies.record(tag);
                return ActionOutcome::Ran;
            }
        }
        self.slots.take(tag);
        if to_x {
            self.in_flight_x += 1;
            if self.in_flight_x > self.budget_x {
                // per-model budget: return the shared slot via cancel
                self.in_flight_x -= 1;
                self.ctl.cancel();
                self.slots.put(tag);
                self.replies.record(tag);
                return ActionOutcome::Ran;
            }
        } else {
            self.in_flight_y += 1;
        }
        let queue = if to_x { &mut self.queue_x } else { &mut self.queue_y };
        if let Err(SendBlocked::Closed(t) | SendBlocked::Full(t)) = queue.try_send(tag) {
            // the pool stopped after the registry said live: error
            // reply, and the slot drop-guard completes the controller
            if to_x {
                self.in_flight_x -= 1;
            } else {
                self.in_flight_y -= 1;
            }
            self.ctl.complete(SERVICE);
            self.slots.put(t);
            self.replies.record(t);
        }
        ActionOutcome::Ran
    }

    /// Serve one queued request of model X (cache-filling).
    fn worker_x(&mut self) -> ActionOutcome {
        match self.queue_x.try_recv() {
            RecvOutcome::Item(tag) => {
                self.in_flight_x -= 1;
                self.ctl.complete(SERVICE);
                self.slots.put(tag);
                self.cache.insert(tag % 4);
                self.replies.record(tag);
                ActionOutcome::Ran
            }
            RecvOutcome::Empty => ActionOutcome::Blocked,
            RecvOutcome::Closed => ActionOutcome::Done,
        }
    }

    /// Serve one queued request of model Y.
    fn worker_y(&mut self) -> ActionOutcome {
        match self.queue_y.try_recv() {
            RecvOutcome::Item(tag) => {
                self.in_flight_y -= 1;
                self.ctl.complete(SERVICE);
                self.slots.put(tag);
                self.replies.record(tag);
                ActionOutcome::Ran
            }
            RecvOutcome::Empty => ActionOutcome::Blocked,
            RecvOutcome::Closed => ActionOutcome::Done,
        }
    }

    /// Retire model X at any point: unregister, drain its queue with
    /// `ModelRetiring` replies (each releasing its slot), close it.
    fn retire_x(&mut self) -> ActionOutcome {
        if self.retired_x {
            return ActionOutcome::Done;
        }
        self.retired_x = true;
        self.registry_x = false;
        while let RecvOutcome::Item(tag) = self.queue_x.try_recv() {
            self.in_flight_x -= 1;
            self.ctl.complete(SERVICE);
            self.slots.put(tag);
            self.replies.record(tag);
        }
        self.queue_x.close();
        ActionOutcome::Ran
    }

    /// Engine shutdown for model Y once the clients are quiet: drain and
    /// close its queue.
    fn shutdown_y(&mut self) -> ActionOutcome {
        if self.shut_y {
            return ActionOutcome::Done;
        }
        if self.produced < self.n {
            return ActionOutcome::Blocked;
        }
        self.shut_y = true;
        while let RecvOutcome::Item(tag) = self.queue_y.try_recv() {
            self.in_flight_y -= 1;
            self.ctl.complete(SERVICE);
            self.slots.put(tag);
            self.replies.record(tag);
        }
        self.queue_y.close();
        ActionOutcome::Ran
    }
}

/// Scenario 2 — **slot-exactly-once**: every path through the front door
/// (accept, shared-door shed, budget cancel, cache hit, unknown model,
/// retire drain, closed-pool bounce) must return exactly the slots it
/// took, and the real controller's in-flight gauge must agree with the
/// ledger after every step.
pub fn slot_exactly_once(profile: Profile) -> Result<Report, Violation> {
    Checker::new(FrontDoorWorld::new)
        .action("client", FrontDoorWorld::submit)
        .action("worker_x", FrontDoorWorld::worker_x)
        .action("worker_y", FrontDoorWorld::worker_y)
        .action("retire_x", FrontDoorWorld::retire_x)
        .action("shutdown_y", FrontDoorWorld::shutdown_y)
        .invariant("slot at-most-once", |w: &FrontDoorWorld| w.slots.at_most_once())
        .invariant("reply at-most-once", |w: &FrontDoorWorld| w.replies.at_most_once())
        .invariant("controller matches ledger", |w: &FrontDoorWorld| {
            let ctl = w.ctl.in_flight() as i64;
            let ledger = w.slots.outstanding();
            if ctl == ledger {
                Ok(())
            } else {
                Err(format!("controller counts {ctl} in flight, slot ledger {ledger}"))
            }
        })
        .invariant("budget respected", |w: &FrontDoorWorld| {
            if w.in_flight_x <= w.budget_x {
                Ok(())
            } else {
                Err(format!("model X holds {} > budget {}", w.in_flight_x, w.budget_x))
            }
        })
        .finally("reply exactly-once", |w: &FrontDoorWorld| w.replies.exactly_once(w.n))
        .finally("slots balanced", |w: &FrontDoorWorld| w.slots.balanced())
        .finally("controller quiescent", |w: &FrontDoorWorld| {
            if w.ctl.in_flight() == 0 {
                Ok(())
            } else {
                Err(format!("{} requests still admitted after quiescence", w.ctl.in_flight()))
            }
        })
        .explore(profile)
}

// ---------------------------------------------------------------------------
// scenario 4: the hetero pipeline lanes under full backpressure

/// Jobs pushed through the modeled pipeline.
const N_PIPE: u64 = 4;

/// State for the backpressure scenario: a three-lane chain (the paper's
/// FPGA → PCIe link → GPU shape) over capacity-1 queues, with each
/// lane's forward/complete role taken from the production [`LaneCore`]
/// plan. `hand0`/`hand1` model a lane mid-job: it has popped its input
/// but not yet pushed downstream, which is exactly the state a real lane
/// thread parks in when the next queue is full.
struct PipeWorld {
    core0: LaneCore,
    core1: LaneCore,
    core2: LaneCore,
    intake: VChan<u64>,
    q1: VChan<u64>,
    q2: VChan<u64>,
    hand0: Option<u64>,
    hand1: Option<u64>,
    produced: u64,
    n: u64,
    replies: ReplyLedger,
}

impl PipeWorld {
    fn new() -> Self {
        Self {
            // FPGA lane folds the image; GPU lane completes
            core0: LaneCore::new(true, false, true),
            core1: LaneCore::new(false, false, false),
            core2: LaneCore::new(false, true, false),
            intake: VChan::bounded(1),
            q1: VChan::bounded(1),
            q2: VChan::bounded(1),
            hand0: None,
            hand1: None,
            produced: 0,
            n: N_PIPE,
            replies: ReplyLedger::new(),
        }
    }

    /// Submit jobs through the bounded intake, then close it (the
    /// pipeline's shutdown signal propagates lane to lane from here).
    fn producer(&mut self) -> ActionOutcome {
        if self.produced < self.n {
            return match self.intake.try_send(self.produced) {
                Ok(()) => {
                    self.produced += 1;
                    ActionOutcome::Ran
                }
                Err(SendBlocked::Full(_)) => ActionOutcome::Blocked,
                Err(SendBlocked::Closed(_)) => unreachable!("only the producer closes intake"),
            };
        }
        if self.intake.is_closed() {
            ActionOutcome::Done
        } else {
            self.intake.close();
            ActionOutcome::Ran
        }
    }

    /// One interior-lane step: finish forwarding the in-hand job, else
    /// pop the next one, else propagate the close downstream. The lane's
    /// role is read off its production plan, never hardcoded.
    fn interior_lane(
        core: &LaneCore,
        hand: &mut Option<u64>,
        input: &mut VChan<u64>,
        output: &mut VChan<u64>,
    ) -> ActionOutcome {
        if let Some(job) = *hand {
            return match output.try_send(job) {
                Ok(()) => {
                    *hand = None;
                    ActionOutcome::Ran
                }
                Err(SendBlocked::Full(_)) => ActionOutcome::Blocked,
                Err(SendBlocked::Closed(_)) => unreachable!("downstream closes only after us"),
            };
        }
        match input.try_recv() {
            RecvOutcome::Item(job) => {
                match core.plan().last() {
                    Some(LaneOp::Forward) => *hand = Some(job),
                    op => panic!("interior lane must plan a Forward, got {op:?}"),
                }
                ActionOutcome::Ran
            }
            RecvOutcome::Empty => ActionOutcome::Blocked,
            RecvOutcome::Closed => {
                if output.is_closed() {
                    ActionOutcome::Done
                } else {
                    output.close();
                    ActionOutcome::Ran
                }
            }
        }
    }

    fn lane0(&mut self) -> ActionOutcome {
        Self::interior_lane(&self.core0, &mut self.hand0, &mut self.intake, &mut self.q1)
    }

    fn lane1(&mut self) -> ActionOutcome {
        Self::interior_lane(&self.core1, &mut self.hand1, &mut self.q1, &mut self.q2)
    }

    /// The last lane: completes jobs (answers their callbacks).
    fn lane2(&mut self) -> ActionOutcome {
        match self.q2.try_recv() {
            RecvOutcome::Item(job) => {
                match self.core2.plan().last() {
                    Some(LaneOp::Complete) => self.replies.record(job),
                    op => panic!("last lane must plan a Complete, got {op:?}"),
                }
                ActionOutcome::Ran
            }
            RecvOutcome::Empty => ActionOutcome::Blocked,
            RecvOutcome::Closed => ActionOutcome::Done,
        }
    }
}

/// Scenario 4 — **backpressure-no-deadlock**: with every inter-lane
/// queue at capacity 1 and more jobs than total queue capacity, every
/// interleaving must still complete every job exactly once and shut the
/// chain down — the explorer's deadlock detection (no action runnable,
/// work remaining) is the property under test.
pub fn backpressure_no_deadlock(profile: Profile) -> Result<Report, Violation> {
    Checker::new(PipeWorld::new)
        .action("producer", PipeWorld::producer)
        .action("fpga_lane", PipeWorld::lane0)
        .action("link_lane", PipeWorld::lane1)
        .action("gpu_lane", PipeWorld::lane2)
        .invariant("reply at-most-once", |w: &PipeWorld| w.replies.at_most_once())
        .invariant("queue capacity respected", |w: &PipeWorld| {
            if w.intake.len() <= 1 && w.q1.len() <= 1 && w.q2.len() <= 1 {
                Ok(())
            } else {
                Err(format!(
                    "queue over capacity: intake {} / q1 {} / q2 {}",
                    w.intake.len(),
                    w.q1.len(),
                    w.q2.len()
                ))
            }
        })
        .finally("reply exactly-once", |w: &PipeWorld| w.replies.exactly_once(w.n))
        .finally("pipeline drained", |w: &PipeWorld| {
            let stranded = w.intake.len() + w.q1.len() + w.q2.len();
            if stranded == 0 && w.hand0.is_none() && w.hand1.is_none() {
                Ok(())
            } else {
                Err(format!(
                    "{stranded} job(s) stranded in queues, hands {:?}/{:?}",
                    w.hand0, w.hand1
                ))
            }
        })
        .explore(profile)
}

// ---------------------------------------------------------------------------
// scenario 5: hot-swap register/retire against in-flight traffic

/// Requests submitted in the hot-swap scenario.
const N_SWAP: u64 = 4;

/// State for the hot-swap scenario: model `m` live from the start and
/// retired mid-traffic in **two steps** (unregister, then drain+close —
/// the real `Engine::retire`'s window), model `n` registered mid-traffic,
/// clients alternating between them.
struct SwapWorld {
    registry_m: bool,
    registry_n: bool,
    mailbox_m: VChan<u64>,
    mailbox_n: VChan<u64>,
    replies: ReplyLedger,
    slots: SlotLedger,
    produced: u64,
    n_reqs: u64,
    /// 0 = live, 1 = unregistered (drain pending), 2 = drained+closed.
    retire_phase: u8,
    /// `mailbox_m.len()` at the moment of unregistration: once `m` left
    /// the registry its backlog may only shrink.
    m_backlog_at_unregister: usize,
    shut_n: bool,
}

impl SwapWorld {
    fn new() -> Self {
        Self {
            registry_m: true,
            registry_n: false,
            mailbox_m: VChan::unbounded(),
            mailbox_n: VChan::unbounded(),
            replies: ReplyLedger::new(),
            slots: SlotLedger::new(),
            produced: 0,
            n_reqs: N_SWAP,
            retire_phase: 0,
            m_backlog_at_unregister: 0,
            shut_n: false,
        }
    }

    /// The front door: registry lookup, then slot + mailbox send. A
    /// model that left the registry answers `UnknownModel` immediately;
    /// a pool that stopped after the lookup bounces with an error reply.
    fn submit(&mut self) -> ActionOutcome {
        if self.produced >= self.n_reqs {
            return ActionOutcome::Done;
        }
        let tag = self.produced;
        self.produced += 1;
        let (registered, mailbox) = if tag % 2 == 0 {
            (self.registry_m, &mut self.mailbox_m)
        } else {
            (self.registry_n, &mut self.mailbox_n)
        };
        if !registered {
            self.replies.record(tag);
            return ActionOutcome::Ran;
        }
        self.slots.take(tag);
        if let Err(SendBlocked::Closed(t) | SendBlocked::Full(t)) = mailbox.try_send(tag) {
            self.slots.put(t);
            self.replies.record(t);
        }
        ActionOutcome::Ran
    }

    fn worker(
        mailbox: &mut VChan<u64>,
        replies: &mut ReplyLedger,
        slots: &mut SlotLedger,
    ) -> ActionOutcome {
        match mailbox.try_recv() {
            RecvOutcome::Item(tag) => {
                replies.record(tag);
                slots.put(tag);
                ActionOutcome::Ran
            }
            RecvOutcome::Empty => ActionOutcome::Blocked,
            RecvOutcome::Closed => ActionOutcome::Done,
        }
    }

    /// Retire `m` in the engine's real order: leave the registry first
    /// (new lookups fail fast), then drain the pool with `ModelRetiring`
    /// replies and close its mailbox.
    fn retire_m(&mut self) -> ActionOutcome {
        match self.retire_phase {
            0 => {
                self.registry_m = false;
                self.m_backlog_at_unregister = self.mailbox_m.len();
                self.retire_phase = 1;
                ActionOutcome::Ran
            }
            1 => {
                while let RecvOutcome::Item(tag) = self.mailbox_m.try_recv() {
                    self.replies.record(tag);
                    self.slots.put(tag);
                }
                self.mailbox_m.close();
                self.retire_phase = 2;
                ActionOutcome::Ran
            }
            _ => ActionOutcome::Done,
        }
    }

    /// Register `n` at any point (clients that raced ahead of the
    /// registration already got `UnknownModel`).
    fn register_n(&mut self) -> ActionOutcome {
        if self.registry_n {
            return ActionOutcome::Done;
        }
        self.registry_n = true;
        ActionOutcome::Ran
    }

    /// Engine shutdown for `n` once the clients are quiet.
    fn shutdown_n(&mut self) -> ActionOutcome {
        if self.shut_n {
            return ActionOutcome::Done;
        }
        if self.produced < self.n_reqs {
            return ActionOutcome::Blocked;
        }
        self.shut_n = true;
        while let RecvOutcome::Item(tag) = self.mailbox_n.try_recv() {
            self.replies.record(tag);
            self.slots.put(tag);
        }
        self.mailbox_n.close();
        ActionOutcome::Ran
    }
}

/// Scenario 5 — **hot-swap-linearized**: retire and register race the
/// clients, yet every request is answered exactly once (served, drained,
/// bounced, or `UnknownModel`), every slot is returned, and once a model
/// leaves the registry its backlog only shrinks — the observable
/// linearization of `Engine::register`/`Engine::retire` against
/// in-flight traffic.
pub fn hot_swap_linearized(profile: Profile) -> Result<Report, Violation> {
    Checker::new(SwapWorld::new)
        .action("client", SwapWorld::submit)
        .action("worker_m", |w: &mut SwapWorld| {
            SwapWorld::worker(&mut w.mailbox_m, &mut w.replies, &mut w.slots)
        })
        .action("worker_n", |w: &mut SwapWorld| {
            SwapWorld::worker(&mut w.mailbox_n, &mut w.replies, &mut w.slots)
        })
        .action("retire_m", SwapWorld::retire_m)
        .action("register_n", SwapWorld::register_n)
        .action("shutdown_n", SwapWorld::shutdown_n)
        .invariant("reply at-most-once", |w: &SwapWorld| w.replies.at_most_once())
        .invariant("slot at-most-once", |w: &SwapWorld| w.slots.at_most_once())
        .invariant("retired backlog shrinks", |w: &SwapWorld| {
            if w.retire_phase >= 1 && w.mailbox_m.len() > w.m_backlog_at_unregister {
                Err(format!(
                    "model m left the registry with {} queued but now holds {}",
                    w.m_backlog_at_unregister,
                    w.mailbox_m.len()
                ))
            } else {
                Ok(())
            }
        })
        .invariant("retired pool drained", |w: &SwapWorld| {
            if w.retire_phase == 2 && !w.mailbox_m.is_empty() {
                Err(format!("{} request(s) left in a retired pool", w.mailbox_m.len()))
            } else {
                Ok(())
            }
        })
        .finally("reply exactly-once", |w: &SwapWorld| w.replies.exactly_once(w.n_reqs))
        .finally("slots balanced", |w: &SwapWorld| w.slots.balanced())
        .finally("queues drained", |w: &SwapWorld| {
            if w.mailbox_m.is_empty() && w.mailbox_n.is_empty() {
                Ok(())
            } else {
                Err("a mailbox still holds requests after quiescence".to_string())
            }
        })
        .explore(profile)
}

// ---------------------------------------------------------------------------
// scenario 6: router failover over the production RouterCore

/// State for the router-failover scenario: the production
/// [`RouterCore`] fronting two modeled replicas. All requests carry the
/// same digest with affinity on, so every Accept lands on one "home"
/// replica (whichever the rendezvous hash picks — recorded from the
/// first Forward effect). The home replica's serving is split into
/// separately schedulable halves — pop a queued request into `held`,
/// then either answer it ([`RouterWorld::home_deliver`]) or fail it
/// retryably ([`RouterWorld::home_fail`]) — and [`RouterWorld::home_down`]
/// can kill the replica *while a request is held*, which is exactly the
/// race the ISSUE names: the core fails the held request over to the
/// sibling, and the home replica's late success then races the retry.
/// First answer wins; the loser must be discarded, never delivered
/// twice and never errored to the client.
struct RouterWorld {
    core: RouterCore<u64>,
    /// Per-replica forward queues (the shell's uplink channels).
    queues: [Vec<u64>; 2],
    /// The request the home replica popped and is "executing".
    held: Option<u64>,
    /// The replica the affine digest hashes to; set by the first
    /// Forward effect.
    home: Option<usize>,
    replies: ReplyLedger,
    submitted: u64,
    delivered: u64,
    client_failed: u64,
    downed: bool,
    n: u64,
}

/// Requests submitted in the router-failover scenario.
const N_ROUTER: u64 = 3;

/// The shared content digest: with affinity on, every request
/// rendezvous-hashes to the same home replica.
const AFFINE_DIGEST: u64 = 7;

impl RouterWorld {
    fn new() -> Self {
        Self {
            core: RouterCore::new(2, true, 2),
            queues: [Vec::new(), Vec::new()],
            held: None,
            home: None,
            replies: ReplyLedger::new(),
            submitted: 0,
            delivered: 0,
            client_failed: 0,
            downed: false,
            n: N_ROUTER,
        }
    }

    /// Quiescent: every request submitted and answered. Stale queue
    /// copies (the discarded losers of failover races) may remain.
    fn done(&self) -> bool {
        self.submitted == self.n && self.delivered == self.n
    }

    /// Execute the core's effects the way the shell threads would:
    /// Forward enqueues on the replica, Deliver/Fail answer the client.
    fn apply(&mut self, effects: Vec<RouterEffect<u64>>) {
        for effect in effects {
            match effect {
                RouterEffect::Forward { tag, replica } => {
                    if self.home.is_none() {
                        self.home = Some(replica);
                    }
                    self.queues[replica].push(tag);
                }
                RouterEffect::Deliver { ctx, .. } => {
                    self.replies.record(ctx);
                    self.delivered += 1;
                }
                RouterEffect::Fail { ctx, .. } => {
                    self.replies.record(ctx);
                    self.delivered += 1;
                    self.client_failed += 1;
                }
            }
        }
    }

    /// The client: accept the next request into the core.
    fn submit(&mut self) -> ActionOutcome {
        if self.submitted == self.n {
            return ActionOutcome::Done;
        }
        let tag = self.submitted;
        self.submitted += 1;
        let effects =
            self.core.step(RouterEvent::Accept { tag, digest: Some(AFFINE_DIGEST), ctx: tag });
        self.apply(effects);
        ActionOutcome::Ran
    }

    /// Home replica, first half: pop the next forwarded request.
    fn home_pop(&mut self) -> ActionOutcome {
        if self.done() {
            return ActionOutcome::Done;
        }
        let Some(home) = self.home else { return ActionOutcome::Blocked };
        if self.held.is_some() || self.queues[home].is_empty() {
            return ActionOutcome::Blocked;
        }
        self.held = Some(self.queues[home].remove(0));
        ActionOutcome::Ran
    }

    /// Home replica, second half: answer the held request. After
    /// [`RouterWorld::home_down`] reassigned it, this is the *late
    /// success racing the retry* — the core must deliver it exactly
    /// once (first answer wins) or discard it (retry already won).
    fn home_deliver(&mut self) -> ActionOutcome {
        if self.done() {
            return ActionOutcome::Done;
        }
        let Some(tag) = self.held else { return ActionOutcome::Blocked };
        self.held = None;
        let effects = self.core.step(RouterEvent::Reply { tag });
        self.apply(effects);
        ActionOutcome::Ran
    }

    /// Home replica, second half, unlucky: answer the held request with
    /// a retryable error (`model_retiring` mid-swap). If the request
    /// already failed over, this is the stale error the core's guard
    /// must ignore.
    fn home_fail(&mut self) -> ActionOutcome {
        if self.done() {
            return ActionOutcome::Done;
        }
        let (Some(tag), Some(home)) = (self.held, self.home) else {
            return ActionOutcome::Blocked;
        };
        self.held = None;
        let effects = self.core.step(RouterEvent::Fail {
            tag,
            replica: home,
            class: FailClass::Retryable,
        });
        self.apply(effects);
        ActionOutcome::Ran
    }

    /// The home replica's connection dies (once). Its queued requests
    /// are stale copies the shell drops at submit time; the core fails
    /// everything assigned to it over to the sibling. A held request
    /// survives as an in-flight answer that may still land late.
    fn home_down(&mut self) -> ActionOutcome {
        if self.downed || self.done() {
            return ActionOutcome::Done;
        }
        let Some(home) = self.home else { return ActionOutcome::Blocked };
        self.downed = true;
        self.queues[home].clear();
        let effects = self.core.step(RouterEvent::ReplicaDown { replica: home });
        self.apply(effects);
        ActionOutcome::Ran
    }

    /// The sibling replica: serve its queue head. A stale copy whose
    /// tag was already answered by the home replica's late success must
    /// come back as an empty effect set, not a second delivery.
    fn sibling_serve(&mut self) -> ActionOutcome {
        if self.done() {
            return ActionOutcome::Done;
        }
        let Some(home) = self.home else { return ActionOutcome::Blocked };
        let sibling = 1 - home;
        if self.queues[sibling].is_empty() {
            return ActionOutcome::Blocked;
        }
        let tag = self.queues[sibling].remove(0);
        let effects = self.core.step(RouterEvent::Reply { tag });
        self.apply(effects);
        ActionOutcome::Ran
    }
}

/// Scenario 6: the cluster router's failover claim, over the production
/// [`RouterCore`]. Affine traffic lands on a home replica that can
/// answer, fail retryably, or die mid-request; the reply for a
/// failed-over request is delivered **exactly once** even when the home
/// replica's late response races the retry on the sibling, and with a
/// healthy sibling available no client ever sees an error.
pub fn router_failover_exactly_once(profile: Profile) -> Result<Report, Violation> {
    Checker::new(RouterWorld::new)
        .action("submit", RouterWorld::submit)
        .action("home_pop", RouterWorld::home_pop)
        .action("home_deliver", RouterWorld::home_deliver)
        .action("home_fail", RouterWorld::home_fail)
        .action("home_down", RouterWorld::home_down)
        .action("sibling_serve", RouterWorld::sibling_serve)
        .invariant("reply at-most-once", |w: &RouterWorld| w.replies.at_most_once())
        .invariant("load is bounded by pendings", |w: &RouterWorld| {
            for i in 0..2 {
                let view = w.core.replica(i).expect("two replicas");
                if view.load > w.core.pending_len() as u64 {
                    return Err(format!(
                        "replica {i} claims load {} with {} pending",
                        view.load,
                        w.core.pending_len()
                    ));
                }
            }
            Ok(())
        })
        .finally("reply exactly-once", |w: &RouterWorld| w.replies.exactly_once(w.n))
        .finally("no client-visible failures", |w: &RouterWorld| {
            if w.client_failed == 0 {
                Ok(())
            } else {
                Err(format!(
                    "{} request(s) errored to the client with a healthy sibling up",
                    w.client_failed
                ))
            }
        })
        .finally("core quiescent", |w: &RouterWorld| {
            if w.core.pending_len() == 0 {
                Ok(())
            } else {
                Err(format!("{} request(s) still pending in the core", w.core.pending_len()))
            }
        })
        .explore(profile)
}

// ---------------------------------------------------------------------------
// the seeded bug: proves the explorer catches and the replayer reproduces

/// State for the seeded-bug scenario: a hand-rolled batcher flush with
/// the classic shed bug — expired requests are *answered* with
/// `DeadlineExceeded` but not *removed* from the dispatched batch, so
/// the worker answers them a second time. (The production
/// [`BatcherCore::step`] partitions correctly; this reimplements the
/// flush wrong on purpose.)
struct BuggyWorld {
    clock: Clock,
    mailbox: VChan<Mail>,
    batch: Vec<TestReq>,
    dispatched: VChan<Vec<TestReq>>,
    replies: ReplyLedger,
    produced: u64,
    stop_sent: bool,
    batcher_done: bool,
}

impl BuggyWorld {
    fn new() -> Self {
        Self {
            clock: Clock::new(),
            mailbox: VChan::unbounded(),
            batch: Vec::new(),
            dispatched: VChan::unbounded(),
            replies: ReplyLedger::new(),
            produced: 0,
            stop_sent: false,
            batcher_done: false,
        }
    }

    fn client(&mut self) -> ActionOutcome {
        if self.produced < 2 {
            let tag = self.produced;
            self.produced += 1;
            // tag 1 is born expired (zero deadline)
            let deadline = (tag == 1).then_some(Duration::ZERO);
            let req = TestReq {
                tag,
                priority: Priority::Normal,
                deadline,
                enqueued: self.clock.now(),
            };
            let _ = self.mailbox.try_send(Mail::Req(req));
            return ActionOutcome::Ran;
        }
        if self.stop_sent {
            return ActionOutcome::Done;
        }
        self.stop_sent = true;
        let _ = self.mailbox.try_send(Mail::Stop(StopCause::Shutdown));
        ActionOutcome::Ran
    }

    fn batcher(&mut self) -> ActionOutcome {
        if self.batcher_done {
            return ActionOutcome::Done;
        }
        match self.mailbox.try_recv() {
            RecvOutcome::Item(Mail::Req(r)) => {
                self.batch.push(r);
                ActionOutcome::Ran
            }
            RecvOutcome::Item(Mail::Stop(_)) => {
                let now = self.clock.now();
                let shed: Vec<u64> = self
                    .batch
                    .iter()
                    .filter(|r| {
                        r.deadline
                            .is_some_and(|d| now.saturating_duration_since(r.enqueued) >= d)
                    })
                    .map(|r| r.tag)
                    .collect();
                for tag in shed {
                    self.replies.record(tag);
                }
                // BUG: the expired requests were answered above but stay
                // in the dispatched batch
                let batch = std::mem::take(&mut self.batch);
                if !batch.is_empty() {
                    let _ = self.dispatched.try_send(batch);
                }
                self.batcher_done = true;
                self.dispatched.close();
                ActionOutcome::Ran
            }
            RecvOutcome::Empty => ActionOutcome::Blocked,
            RecvOutcome::Closed => unreachable!("nobody closes the buggy mailbox"),
        }
    }

    fn worker(&mut self) -> ActionOutcome {
        match self.dispatched.try_recv() {
            RecvOutcome::Item(batch) => {
                for r in batch {
                    self.replies.record(r.tag);
                }
                ActionOutcome::Ran
            }
            RecvOutcome::Empty => ActionOutcome::Blocked,
            RecvOutcome::Closed => ActionOutcome::Done,
        }
    }
}

// ---------------------------------------------------------------------------
// scenario 7: adaptive-controller flip racing an operator swap

/// Requests submitted in the controller scenario.
const N_CTL: u64 = 4;

/// The controller scenario's virtual tick spacing.
const CTL_TICK: Duration = Duration::from_millis(10);

/// The controller scenario's hysteresis window (3 ticks).
const CTL_HYSTERESIS: Duration = Duration::from_millis(30);

/// Observation ticks the scenario feeds the core (tick 0 breaches the
/// SLO, every later tick reports full recovery — so the real core wants
/// to flip fast once and flip back exactly when hysteresis allows).
const CTL_TICKS: u32 = 6;

/// State for the controller scenario: the **real** [`ControllerCore`]
/// deciding placement flips for model `m` from scripted observations on
/// a virtual [`Clock`], with the flip *applied* in the engine's real
/// two-step order (unregister, then re-register) — racing a concurrent
/// operator-driven retire+register over the same registry seam, plus
/// live client traffic. The loser of the registry race observes exactly
/// what `Engine::retire` returns (`UnknownModel`) and must abort its
/// whole swap rather than register a duplicate.
struct CtlWorld {
    core: ControllerCore,
    clock: Clock,
    /// Whether `m` is in the registry right now.
    registered: bool,
    /// Set if any party registered over a live registration — the
    /// linearization bug the scenario exists to rule out.
    double_register: bool,
    mailbox: VChan<u64>,
    replies: ReplyLedger,
    slots: SlotLedger,
    produced: u64,
    ticks: u32,
    /// A core-emitted placement flip awaiting shell application.
    pending_flip: Option<FlipTo>,
    /// 0 = not started, 1 = unregistered (register pending), 2 = done.
    flip_phase: u8,
    /// Same phases for the racing operator swap.
    ops_phase: u8,
    /// Every flip the core emitted, with its virtual timestamp.
    flips: Vec<(Instant, FlipTo)>,
}

impl CtlWorld {
    fn new() -> Self {
        let cfg = ControllerConfig {
            slo_p99_us: 1_000,
            breach_ticks: 1,
            clear_ticks: 1,
            clear_frac: 0.8,
            hysteresis: CTL_HYSTERESIS,
            ..ControllerConfig::default()
        };
        Self {
            core: ControllerCore::new(cfg),
            clock: Clock::new(),
            registered: true,
            double_register: false,
            mailbox: VChan::unbounded(),
            replies: ReplyLedger::new(),
            slots: SlotLedger::new(),
            produced: 0,
            ticks: 0,
            pending_flip: None,
            flip_phase: 0,
            ops_phase: 0,
            flips: Vec::new(),
        }
    }

    /// The front door: registry lookup, then slot + mailbox send. While
    /// either swap holds `m` out of the registry, clients get
    /// `UnknownModel` — answered immediately, exactly once.
    fn submit(&mut self) -> ActionOutcome {
        if self.produced >= N_CTL {
            return ActionOutcome::Done;
        }
        let tag = self.produced;
        self.produced += 1;
        if !self.registered {
            self.replies.record(tag);
            return ActionOutcome::Ran;
        }
        self.slots.take(tag);
        if let Err(SendBlocked::Closed(t) | SendBlocked::Full(t)) = self.mailbox.try_send(tag) {
            self.slots.put(t);
            self.replies.record(t);
        }
        ActionOutcome::Ran
    }

    fn worker(&mut self) -> ActionOutcome {
        if self.produced >= N_CTL && self.replies.count() >= N_CTL {
            return ActionOutcome::Done;
        }
        match self.mailbox.try_recv() {
            RecvOutcome::Item(tag) => {
                self.replies.record(tag);
                self.slots.put(tag);
                ActionOutcome::Ran
            }
            // Closed means a swap drained this pool; the next register
            // installs a fresh mailbox, so wait rather than finish
            RecvOutcome::Empty | RecvOutcome::Closed => ActionOutcome::Blocked,
        }
    }

    /// One observation tick into the real core. The shell applies
    /// effects synchronously, so a tick cannot land while a flip is
    /// still being applied ([`ActionOutcome::Blocked`] — no mutation).
    fn tick(&mut self) -> ActionOutcome {
        if self.ticks >= CTL_TICKS {
            return ActionOutcome::Done;
        }
        if self.pending_flip.is_some() {
            return ActionOutcome::Blocked;
        }
        self.clock.advance(CTL_TICK);
        let now = self.clock.now();
        // scripted health: tick 0 breaches hard, the rest are recovered
        let p99_us = if self.ticks == 0 { 5_000 } else { 100 };
        self.ticks += 1;
        let effects = self.core.step(ControllerEvent::Tick {
            now,
            observations: vec![ModelObservation {
                model: "m".to_string(),
                p99_us,
                in_flight: self.mailbox.len() as u64,
                placement: Placement::Pool,
            }],
        });
        for effect in effects {
            if let ControllerEffect::Flip { to, .. } = effect {
                self.flips.push((now, to));
                self.pending_flip = Some(to);
                self.flip_phase = 0;
            }
        }
        ActionOutcome::Ran
    }

    /// Apply the pending flip in the engine's real two-step order. A
    /// flip that finds `m` already gone (the operator swap holds it)
    /// aborts, exactly like the shell does when `Engine::retire` returns
    /// `UnknownModel`.
    fn apply_flip(&mut self) -> ActionOutcome {
        if self.pending_flip.is_none() {
            return if self.ticks >= CTL_TICKS {
                ActionOutcome::Done
            } else {
                ActionOutcome::Blocked
            };
        }
        match self.flip_phase {
            0 => {
                if !self.registered {
                    // lost the registry race: abort the whole flip
                    self.pending_flip = None;
                    return ActionOutcome::Ran;
                }
                self.registered = false;
                while let RecvOutcome::Item(tag) = self.mailbox.try_recv() {
                    self.replies.record(tag);
                    self.slots.put(tag);
                }
                self.mailbox.close();
                self.flip_phase = 1;
                ActionOutcome::Ran
            }
            _ => {
                if self.registered {
                    self.double_register = true;
                } else {
                    self.registered = true;
                }
                self.mailbox = VChan::unbounded();
                self.pending_flip = None;
                self.flip_phase = 2;
                ActionOutcome::Ran
            }
        }
    }

    /// The racing operator: one client-driven retire+register of `m`
    /// (the same hot-swap the engine exposes), interleaved freely with
    /// the controller's flip.
    fn ops_swap(&mut self) -> ActionOutcome {
        match self.ops_phase {
            0 => {
                if !self.registered {
                    // retire returned UnknownModel: the swap aborts
                    self.ops_phase = 2;
                    return ActionOutcome::Ran;
                }
                self.registered = false;
                while let RecvOutcome::Item(tag) = self.mailbox.try_recv() {
                    self.replies.record(tag);
                    self.slots.put(tag);
                }
                self.mailbox.close();
                self.ops_phase = 1;
                ActionOutcome::Ran
            }
            1 => {
                if self.registered {
                    self.double_register = true;
                } else {
                    self.registered = true;
                }
                self.mailbox = VChan::unbounded();
                self.ops_phase = 2;
                ActionOutcome::Ran
            }
            _ => ActionOutcome::Done,
        }
    }

    /// The no-flap check: consecutive opposite flips must be at least
    /// one full hysteresis window apart.
    fn no_flap(&self) -> Result<(), String> {
        for pair in self.flips.windows(2) {
            let (t1, d1) = pair[0];
            let (t2, d2) = pair[1];
            if d1 != d2 && t2.saturating_duration_since(t1) < CTL_HYSTERESIS {
                return Err(format!(
                    "opposite flips {:?} -> {:?} only {:?} apart (hysteresis {:?})",
                    d1,
                    d2,
                    t2.saturating_duration_since(t1),
                    CTL_HYSTERESIS
                ));
            }
        }
        Ok(())
    }
}

/// Scenario 7 — **controller-actions-linearized**: the real
/// [`ControllerCore`] flips model `m`'s placement from scripted SLO
/// observations while an operator retire+register races it over the
/// same registry seam, with live clients submitting throughout. Holds:
/// every request is answered exactly once (served, drained, or
/// `UnknownModel` during a swap window), every slot is returned, the
/// model is **never lost** (whoever loses the registry race aborts;
/// whoever wins re-registers — `m` is always back at quiescence, and
/// nobody registers a duplicate), and the core's flips honor the
/// hysteresis window (no flapping) on every interleaving.
pub fn controller_actions_linearized(profile: Profile) -> Result<Report, Violation> {
    Checker::new(CtlWorld::new)
        .action("client", CtlWorld::submit)
        .action("worker", CtlWorld::worker)
        .action("tick", CtlWorld::tick)
        .action("ctl_flip", CtlWorld::apply_flip)
        .action("ops_swap", CtlWorld::ops_swap)
        .invariant("reply at-most-once", |w: &CtlWorld| w.replies.at_most_once())
        .invariant("slot at-most-once", |w: &CtlWorld| w.slots.at_most_once())
        .invariant("register at-most-once", |w: &CtlWorld| {
            if w.double_register {
                Err("a swap registered m over a live registration".to_string())
            } else {
                Ok(())
            }
        })
        .invariant("no flap inside hysteresis", |w: &CtlWorld| w.no_flap())
        .finally("reply exactly-once", |w: &CtlWorld| w.replies.exactly_once(N_CTL))
        .finally("slots balanced", |w: &CtlWorld| w.slots.balanced())
        .finally("model never lost", |w: &CtlWorld| {
            if w.registered {
                Ok(())
            } else {
                Err("m is gone from the registry at quiescence".to_string())
            }
        })
        .finally("core flipped fast", |w: &CtlWorld| {
            if w.flips.first().map(|&(_, d)| d) == Some(FlipTo::Fast) {
                Ok(())
            } else {
                Err("the breached tick never produced a fast flip".to_string())
            }
        })
        .explore(profile)
}

// ---------------------------------------------------------------------------

/// Acquire→hold→release cycles each tenant performs in the arbiter
/// scenario.
const ARB_OPS: usize = 2;

/// What one tenant lane is doing right now in the arbiter scenario.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum TenantPhase {
    /// Between holds: may submit the next request.
    Idle,
    /// Submitted this ticket; waiting for its grant (or cancel).
    Waiting(u64),
    /// Claimed this ticket's grant; the next step releases it.
    Holding(u64),
    /// Retired (or saw its wait cancelled): no further requests.
    Retired,
}

/// Scenario 8 world: the **real** [`ArbiterCore`] under two tenant
/// lanes cycling acquire→release on the capacity-1 shared GPU, with
/// tenant B free to retire at any point after its first request — so
/// the explorer schedules retire against a grant already queued,
/// already claimable, and already held.
struct ArbWorld {
    core: ArbiterCore,
    /// Tickets granted by the core but not yet claimed by their lane.
    granted: BTreeSet<u64>,
    /// Tickets cancelled by the core but not yet observed by their lane.
    cancelled: BTreeSet<u64>,
    /// Every ticket ever granted (at-most-once is checked on insert).
    granted_ever: BTreeSet<u64>,
    /// Every ticket ever cancelled.
    cancelled_ever: BTreeSet<u64>,
    /// Every ticket whose hold was released back.
    released: BTreeSet<u64>,
    /// Tickets submitted per tenant, in submission order.
    submitted: [Vec<u64>; 2],
    phases: [TenantPhase; 2],
    remaining: [usize; 2],
    next_ticket: u64,
    b_retired: bool,
    /// Set when the core grants one ticket twice — the headline bug.
    double_grant: bool,
}

impl ArbWorld {
    fn new() -> Self {
        Self {
            core: ArbiterCore::new(),
            granted: BTreeSet::new(),
            cancelled: BTreeSet::new(),
            granted_ever: BTreeSet::new(),
            cancelled_ever: BTreeSet::new(),
            released: BTreeSet::new(),
            submitted: [Vec::new(), Vec::new()],
            phases: [TenantPhase::Idle; 2],
            remaining: [ARB_OPS; 2],
            next_ticket: 0,
            b_retired: false,
            double_grant: false,
        }
    }

    fn apply(&mut self, effects: Vec<ArbiterEffect>) {
        for fx in effects {
            match fx {
                ArbiterEffect::Granted { ticket, .. } => {
                    if !self.granted_ever.insert(ticket.0) {
                        self.double_grant = true;
                    }
                    self.granted.insert(ticket.0);
                }
                ArbiterEffect::Cancelled { ticket, .. } => {
                    self.cancelled_ever.insert(ticket.0);
                    self.cancelled.insert(ticket.0);
                }
            }
        }
    }

    /// One step of tenant `i`'s lane loop: request, claim the grant,
    /// or release — whichever its phase calls for.
    fn step_tenant(&mut self, i: usize) -> ActionOutcome {
        match self.phases[i] {
            TenantPhase::Retired => ActionOutcome::Done,
            TenantPhase::Idle => {
                if self.remaining[i] == 0 || (i == 1 && self.b_retired) {
                    return ActionOutcome::Done;
                }
                let t = self.next_ticket;
                self.next_ticket += 1;
                self.submitted[i].push(t);
                let fx = self.core.step(ArbiterEvent::Request {
                    ticket: Ticket(t),
                    tenant: TenantId(i as u64),
                    device: DeviceId::Gpu,
                    priority: 0,
                });
                self.apply(fx);
                self.phases[i] = TenantPhase::Waiting(t);
                ActionOutcome::Ran
            }
            TenantPhase::Waiting(t) => {
                if self.granted.remove(&t) {
                    self.phases[i] = TenantPhase::Holding(t);
                    ActionOutcome::Ran
                } else if self.cancelled.remove(&t) {
                    self.phases[i] = TenantPhase::Retired;
                    ActionOutcome::Ran
                } else {
                    ActionOutcome::Blocked
                }
            }
            TenantPhase::Holding(t) => {
                let fx = self.core.step(ArbiterEvent::Release { ticket: Ticket(t) });
                self.apply(fx);
                self.released.insert(t);
                self.remaining[i] -= 1;
                self.phases[i] = if i == 1 && self.b_retired {
                    TenantPhase::Retired
                } else {
                    TenantPhase::Idle
                };
                ActionOutcome::Ran
            }
        }
    }

    fn tenant_a(&mut self) -> ActionOutcome {
        self.step_tenant(0)
    }

    fn tenant_b(&mut self) -> ActionOutcome {
        self.step_tenant(1)
    }

    /// Tenant B's retire, schedulable at any point after B's first
    /// request — including while B waits or holds.
    fn retire_b(&mut self) -> ActionOutcome {
        if self.b_retired {
            return ActionOutcome::Done;
        }
        if self.submitted[1].is_empty() {
            return ActionOutcome::Blocked;
        }
        self.b_retired = true;
        let fx = self.core.step(ArbiterEvent::Retire { tenant: TenantId(1) });
        self.apply(fx);
        ActionOutcome::Ran
    }

    /// Capacity-1 accounting: the device is held iff exactly one ticket
    /// is claimed-or-claimable, and that ticket is the core's holder.
    fn capacity_consistent(&self) -> Result<(), String> {
        let claimed = self.phases.iter().filter(|p| matches!(p, TenantPhase::Holding(_))).count();
        let holding = claimed + self.granted.len();
        match (self.core.holder(DeviceId::Gpu), holding) {
            (Some(_), 1) | (None, 0) => Ok(()),
            (holder, n) => Err(format!("holder {holder:?} but {n} claimed-or-claimable tickets")),
        }
    }
}

/// Scenario 8 — **arbiter-grants-exactly-once**: the node-level device
/// [`ArbiterCore`] under two tenants racing acquire / release /
/// retire-mid-wait on the capacity-1 shared GPU. Holds on every
/// interleaving: a ticket is granted at most once and never after a
/// cancel, the device never serves two holders, a retire cancels
/// exactly the retiring tenant's queued tickets (the surviving tenant's
/// grants are never lost), every release returns capacity, and the node
/// quiesces with every submitted ticket settled (granted + released, or
/// cancelled) and all queues empty.
pub fn arbiter_grants_exactly_once(profile: Profile) -> Result<Report, Violation> {
    Checker::new(ArbWorld::new)
        .action("tenant_a", ArbWorld::tenant_a)
        .action("tenant_b", ArbWorld::tenant_b)
        .action("retire_b", ArbWorld::retire_b)
        .invariant("grant at-most-once", |w: &ArbWorld| {
            if w.double_grant {
                Err("a ticket was granted twice".to_string())
            } else {
                Ok(())
            }
        })
        .invariant("grant xor cancel", |w: &ArbWorld| {
            let both: Vec<u64> = w.granted_ever.intersection(&w.cancelled_ever).copied().collect();
            if both.is_empty() {
                Ok(())
            } else {
                Err(format!("tickets both granted and cancelled: {both:?}"))
            }
        })
        .invariant("capacity-1 respected", ArbWorld::capacity_consistent)
        .invariant("cancels only hit the retiring tenant", |w: &ArbWorld| {
            if w.cancelled_ever.iter().all(|t| w.submitted[1].contains(t)) {
                Ok(())
            } else {
                Err(format!("tenant A ticket cancelled: {:?}", w.cancelled_ever))
            }
        })
        .finally("node quiescent", |w: &ArbWorld| {
            if w.core.quiescent() {
                Ok(())
            } else {
                Err("a device is still held or queued at quiescence".to_string())
            }
        })
        .finally("every ticket settled", |w: &ArbWorld| {
            for (i, subs) in w.submitted.iter().enumerate() {
                for t in subs {
                    let granted = w.granted_ever.contains(t);
                    let cancelled = w.cancelled_ever.contains(t);
                    if !(granted ^ cancelled) {
                        return Err(format!(
                            "tenant {i} ticket {t}: granted={granted} cancelled={cancelled}"
                        ));
                    }
                    if granted && !w.released.contains(t) {
                        return Err(format!("tenant {i} ticket {t} granted but never released"));
                    }
                }
            }
            Ok(())
        })
        .finally("survivor lost no grants", |w: &ArbWorld| {
            if w.submitted[0].iter().all(|t| w.granted_ever.contains(t)) {
                Ok(())
            } else {
                Err(format!("tenant A submitted {:?} granted {:?}", w.submitted[0], w.granted_ever))
            }
        })
        .explore(profile)
}

// ---------------------------------------------------------------------------
// scenario 9: flight-recorder span chains against interleaved snapshots

/// Traces each emitter lane records in the recorder scenario.
const OBS_TRACES: u64 = 2;

/// Snapshots the observer may take mid-run.
const OBS_SNAPSHOTS: u8 = 2;

/// One modeled emitter thread in the recorder scenario: its own
/// [`ThreadRing`] (the recorder's single-writer contract) walking the
/// canonical span script — `admitted` → `device_acquire` →
/// `device_hold` + `device_release` (the pair [`crate::obs::LaneObs`]
/// emits together) → `reply_written` — once per trace.
struct ObsLane {
    ring: Arc<ThreadRing>,
    dev: Resource,
    /// First trace id this lane owns (lanes never share a trace).
    base: u64,
    /// Traces this lane has finished.
    trace: u64,
    /// Position in the current trace's span script (0..=3).
    step: u8,
}

/// State for the recorder scenario: the **real** [`Recorder`] under two
/// emitter lanes and an observer draining snapshots at arbitrary points
/// in between — the race the hot-path contract (DESIGN.md §15) is
/// about: a snapshot copy must never block or lose an emit, and the
/// span chains it sees must be well-formed at every prefix.
struct ObsWorld {
    recorder: Recorder,
    lanes: [ObsLane; 2],
    snapshots_left: u8,
    /// Set if any emit was refused ([`ThreadRing::emit`] returned
    /// `false`) — with copy-then-release snapshots this must never
    /// happen under the checker's sequential interleavings.
    emit_refused: bool,
}

impl ObsWorld {
    fn new() -> Self {
        let recorder = Recorder::new(64);
        let lanes = [
            ObsLane {
                ring: recorder.register("fpga_emitter"),
                dev: Resource::Fpga,
                base: 0,
                trace: 0,
                step: 0,
            },
            ObsLane {
                ring: recorder.register("gpu_emitter"),
                dev: Resource::Gpu,
                base: OBS_TRACES,
                trace: 0,
                step: 0,
            },
        ];
        Self { recorder, lanes, snapshots_left: OBS_SNAPSHOTS, emit_refused: false }
    }

    /// One emit step of lane `i`'s span script.
    fn emit_step(&mut self, i: usize) -> ActionOutcome {
        let lane = &mut self.lanes[i];
        if lane.trace >= OBS_TRACES {
            return ActionOutcome::Done;
        }
        let trace = TraceId(lane.base + lane.trace);
        let ok = match lane.step {
            0 => lane.ring.emit(trace, EventKind::Admitted),
            1 => lane.ring.emit(trace, EventKind::DeviceAcquire { dev: lane.dev }),
            2 => {
                // the production LaneObs emits the hold/release pair in
                // one call, after the hold ends
                lane.ring.emit(trace, EventKind::DeviceHold { dev: lane.dev, wait_us: 2 })
                    && lane
                        .ring
                        .emit(trace, EventKind::DeviceRelease { dev: lane.dev, held_us: 10 })
            }
            _ => lane.ring.emit(trace, EventKind::ReplyWritten),
        };
        if !ok {
            self.emit_refused = true;
        }
        if lane.step == 3 {
            lane.step = 0;
            lane.trace += 1;
        } else {
            lane.step += 1;
        }
        ActionOutcome::Ran
    }

    /// The observer: drain one mid-run snapshot. Loss counters are
    /// folded into `emit_refused` so the invariant names the failure.
    fn observe(&mut self) -> ActionOutcome {
        if self.snapshots_left == 0 {
            return ActionOutcome::Done;
        }
        self.snapshots_left -= 1;
        let snap = self.recorder.snapshot();
        if snap.dropped != 0 || snap.overwritten != 0 {
            self.emit_refused = true;
        }
        ActionOutcome::Ran
    }

    /// Prefix well-formedness of the recorded history: per trace, at
    /// most one `admitted` and one `reply_written`, every other event
    /// inside that window, and device acquire/release properly nested.
    fn well_nested(&self) -> Result<(), String> {
        // (admitted, open acquires, replied) per trace
        let mut state: BTreeMap<TraceId, (bool, u64, bool)> = BTreeMap::new();
        for te in &self.recorder.snapshot().events {
            let e = &te.event;
            let s = state.entry(e.trace).or_insert((false, 0, false));
            if s.2 {
                return Err(format!("{}: {} after reply_written", e.trace, e.kind.name()));
            }
            match e.kind {
                EventKind::Admitted => {
                    if s.0 {
                        return Err(format!("{} admitted twice", e.trace));
                    }
                    s.0 = true;
                }
                EventKind::DeviceAcquire { .. } => {
                    if !s.0 {
                        return Err(format!("{} acquired a device before admission", e.trace));
                    }
                    s.1 += 1;
                }
                EventKind::DeviceRelease { .. } => {
                    if s.1 == 0 {
                        return Err(format!("{} released a device it never acquired", e.trace));
                    }
                    s.1 -= 1;
                }
                EventKind::ReplyWritten => {
                    if !s.0 {
                        return Err(format!("{} replied without admission", e.trace));
                    }
                    if s.1 != 0 {
                        return Err(format!("{} replied with {} device span(s) open", e.trace, s.1));
                    }
                    s.2 = true;
                }
                _ => {}
            }
        }
        Ok(())
    }
}

/// Scenario 9 — **trace-spans-well-nested**: the flight recorder's real
/// [`Recorder`] under two emitter lanes and an observer snapshotting at
/// arbitrary interleavings. Holds on every schedule: the span chains a
/// snapshot reconstructs are well-formed at every prefix (one
/// `admitted`, one `reply_written`, device spans properly nested inside
/// the request window), the recorder never blocks or loses an emit, and
/// at quiescence every [`TraceId`] has its two endpoints exactly once.
pub fn trace_spans_well_nested(profile: Profile) -> Result<Report, Violation> {
    Checker::new(ObsWorld::new)
        .action("fpga_emitter", |w: &mut ObsWorld| w.emit_step(0))
        .action("gpu_emitter", |w: &mut ObsWorld| w.emit_step(1))
        .action("observer", ObsWorld::observe)
        .invariant("spans well-nested", ObsWorld::well_nested)
        .invariant("recorder never blocks", |w: &ObsWorld| {
            let snap = w.recorder.snapshot();
            if w.emit_refused || snap.dropped != 0 || snap.overwritten != 0 {
                Err(format!(
                    "recorder lost events (refused={}, dropped={}, overwritten={})",
                    w.emit_refused, snap.dropped, snap.overwritten
                ))
            } else {
                Ok(())
            }
        })
        .finally("span chains exactly once", |w: &ObsWorld| {
            let chains = w.recorder.snapshot().chains();
            if chains.len() as u64 != 2 * OBS_TRACES {
                return Err(format!(
                    "{} trace chain(s) recorded, expected {}",
                    chains.len(),
                    2 * OBS_TRACES
                ));
            }
            for (trace, (admitted, replies)) in chains {
                if (admitted, replies) != (1, 1) {
                    return Err(format!(
                        "{trace}: {admitted} admitted / {replies} reply_written (want 1/1)"
                    ));
                }
            }
            Ok(())
        })
        .explore(profile)
}

/// The checker's own regression: explore the seeded shed bug until the
/// `reply at-most-once` invariant fires, then replay the printed
/// schedule from scratch. Returns the explored violation and its replay.
///
/// # Panics
///
/// If the explorer fails to find the seeded violation, or the replay
/// fails to reproduce it — either is a checker regression.
pub fn buggy_double_reply(profile: Profile) -> (Violation, Violation) {
    let build = || {
        Checker::new(BuggyWorld::new)
            .action("client", BuggyWorld::client)
            .action("batcher", BuggyWorld::batcher)
            .action("worker", BuggyWorld::worker)
            .invariant("reply at-most-once", |w: &BuggyWorld| w.replies.at_most_once())
    };
    let found = build()
        .explore(profile)
        .expect_err("the seeded double-reply bug must be found");
    let replayed = build()
        .replay(&found.schedule)
        .expect_err("the printed schedule must reproduce the violation");
    (found, replayed)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A tiny exploration budget for smoke tests — the full quick
    /// profile runs in `tests/model_check.rs` (and in CI's model-check
    /// job).
    fn smoke() -> Profile {
        Profile { max_schedules: 64, max_depth: 64, max_preemptions: Some(4) }
    }

    #[test]
    fn all_core_scenarios_hold_under_smoke_budget() {
        for (name, result) in [
            ("reply_exactly_once", reply_exactly_once(smoke())),
            ("slot_exactly_once", slot_exactly_once(smoke())),
            ("drain_empties_queues", drain_empties_queues(smoke())),
            ("backpressure_no_deadlock", backpressure_no_deadlock(smoke())),
            ("hot_swap_linearized", hot_swap_linearized(smoke())),
            ("router_failover_exactly_once", router_failover_exactly_once(smoke())),
            ("controller_actions_linearized", controller_actions_linearized(smoke())),
            ("trace_spans_well_nested", trace_spans_well_nested(smoke())),
        ] {
            let report = result.unwrap_or_else(|v| panic!("{name} violated:\n{v}"));
            assert!(report.completed > 0, "{name} completed no schedules");
        }
    }

    #[test]
    fn seeded_bug_is_found_and_replays() {
        let (found, replayed) = buggy_double_reply(smoke());
        assert_eq!(found.invariant, "reply at-most-once");
        assert_eq!(replayed.invariant, found.invariant);
        assert_eq!(replayed.detail, found.detail);
        assert_eq!(replayed.schedule, found.schedule);
        // tag 1 is the one answered twice (shed, then dispatched anyway)
        assert!(found.detail.contains("request 1"), "{found}");
    }
}
