//! Asserter ledgers: the shared definition of "exactly once".
//!
//! Both the checker scenarios ([`super::scenarios`]) and the property
//! tests (`tests/prop_invariants.rs`) assert the same two engine
//! contracts — every request is answered exactly once, and every
//! admission slot taken is returned exactly once. These ledgers are that
//! contract as code: scenario actions record what the modeled system
//! does, and the asserters read the ledger after every step (duplicates
//! are caught *eagerly*, at the step that commits them, so the failing
//! schedule pinpoints the guilty interleaving, not the post-mortem).

use std::collections::BTreeMap;

/// Reply bookkeeping: how many times each request tag was answered.
#[derive(Debug, Default)]
pub struct ReplyLedger {
    counts: BTreeMap<u64, u32>,
}

impl ReplyLedger {
    /// Empty ledger.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one reply (served, shed, rejected, or drain-errored — any
    /// delivery through the request's response channel counts).
    pub fn record(&mut self, tag: u64) {
        *self.counts.entry(tag).or_insert(0) += 1;
    }

    /// Replies recorded for `tag` so far.
    pub fn count(&self, tag: u64) -> u32 {
        self.counts.get(&tag).copied().unwrap_or(0)
    }

    /// Step asserter: no tag has ever been answered twice.
    pub fn at_most_once(&self) -> Result<(), String> {
        match self.counts.iter().find(|(_, &c)| c > 1) {
            Some((tag, c)) => Err(format!("request {tag} answered {c} times")),
            None => Ok(()),
        }
    }

    /// Quiescent asserter: every tag in `0..n` was answered exactly once.
    pub fn exactly_once(&self, n: u64) -> Result<(), String> {
        self.at_most_once()?;
        match (0..n).find(|t| self.count(*t) == 0) {
            Some(tag) => Err(format!("request {tag} was never answered")),
            None => Ok(()),
        }
    }
}

/// Slot bookkeeping: per-tag takes and returns of a capacity slot
/// (shared admission, per-model in-flight — anything drop-guarded by
/// the engine's `Slot`).
#[derive(Debug, Default)]
pub struct SlotLedger {
    /// tag → (taken, returned).
    slots: BTreeMap<u64, (u32, u32)>,
}

impl SlotLedger {
    /// Empty ledger.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a slot take for `tag` (front-door admission).
    pub fn take(&mut self, tag: u64) {
        self.slots.entry(tag).or_insert((0, 0)).0 += 1;
    }

    /// Record a slot return for `tag` (the `Slot` drop-guard firing).
    pub fn put(&mut self, tag: u64) {
        self.slots.entry(tag).or_insert((0, 0)).1 += 1;
    }

    /// Slots currently held (takes minus returns, across all tags).
    pub fn outstanding(&self) -> i64 {
        self.slots.values().map(|&(t, p)| i64::from(t) - i64::from(p)).sum()
    }

    /// Step asserter: no tag has returned more slots than it took, and
    /// no tag took more than one.
    pub fn at_most_once(&self) -> Result<(), String> {
        for (tag, &(taken, put)) in &self.slots {
            if taken > 1 {
                return Err(format!("request {tag} took its slot {taken} times"));
            }
            if put > taken {
                return Err(format!("request {tag} returned {put} slots for {taken} taken"));
            }
        }
        Ok(())
    }

    /// Quiescent asserter: every take has exactly one matching return.
    pub fn balanced(&self) -> Result<(), String> {
        self.at_most_once()?;
        for (tag, &(taken, put)) in &self.slots {
            if put != taken {
                return Err(format!("request {tag}: {taken} slot takes, {put} returns"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reply_ledger_catches_double_and_missing() {
        let mut r = ReplyLedger::new();
        r.record(0);
        r.record(1);
        assert!(r.at_most_once().is_ok());
        assert!(r.exactly_once(2).is_ok());
        assert!(r.exactly_once(3).unwrap_err().contains("never answered"));
        r.record(1);
        assert!(r.at_most_once().unwrap_err().contains("2 times"));
    }

    #[test]
    fn slot_ledger_catches_over_return_eagerly() {
        let mut s = SlotLedger::new();
        s.take(0);
        assert_eq!(s.outstanding(), 1);
        assert!(s.balanced().unwrap_err().contains("1 slot takes, 0 returns"));
        s.put(0);
        assert!(s.balanced().is_ok());
        assert_eq!(s.outstanding(), 0);
        s.put(0);
        assert!(s.at_most_once().unwrap_err().contains("returned 2 slots"));
    }
}
