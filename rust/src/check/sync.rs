//! Deterministic in-model stand-ins for the production `std::sync`
//! primitives.
//!
//! Scenario states own these as plain fields — actions take `&mut S`, so
//! there is no sharing, no locking, and no nondeterminism. A [`VChan`]
//! models what an `mpsc::channel` / `mpsc::sync_channel` *does* to the
//! schedule (FIFO delivery, capacity backpressure, close-on-drop), and a
//! [`Clock`] models `Instant::now()` as something a schedule step
//! advances explicitly. The production shells use the real primitives;
//! the cores they drive cannot tell the difference — that is the step
//! seam's whole point (DESIGN.md §11).

use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// Why a [`VChan::try_send`] did not deliver.
#[derive(Debug, PartialEq, Eq)]
pub enum SendBlocked<T> {
    /// The channel is at capacity — the sender would park. The item is
    /// handed back so the action can retry on a later step.
    Full(T),
    /// The channel is closed — the send fails permanently, item
    /// returned (models `SendError`).
    Closed(T),
}

/// What a [`VChan::try_recv`] observed.
#[derive(Debug, PartialEq, Eq)]
pub enum RecvOutcome<T> {
    /// The FIFO head.
    Item(T),
    /// Nothing queued, channel still open — a receiver would park.
    Empty,
    /// Nothing queued and the channel is closed (models
    /// `RecvError` / `Disconnected`).
    Closed,
}

/// A deterministic FIFO channel: unbounded (`mpsc::channel`) or bounded
/// (`mpsc::sync_channel`), with explicit close semantics. Closing stops
/// *sends* immediately; queued items still drain (exactly like dropping
/// every `Sender` of a real channel).
#[derive(Debug)]
pub struct VChan<T> {
    queue: VecDeque<T>,
    cap: Option<usize>,
    closed: bool,
}

impl<T> VChan<T> {
    /// Unbounded channel (models `mpsc::channel`).
    pub fn unbounded() -> Self {
        Self { queue: VecDeque::new(), cap: None, closed: false }
    }

    /// Bounded channel of capacity `cap >= 1` (models
    /// `mpsc::sync_channel(cap)`).
    pub fn bounded(cap: usize) -> Self {
        assert!(cap >= 1, "a zero-capacity rendezvous channel is not modeled");
        Self { queue: VecDeque::new(), cap: Some(cap), closed: false }
    }

    /// Attempt to enqueue. Never blocks — a full bounded channel hands
    /// the item back as [`SendBlocked::Full`] so the scheduling decision
    /// (park the sender) belongs to the action, where the explorer can
    /// see it.
    pub fn try_send(&mut self, item: T) -> Result<(), SendBlocked<T>> {
        if self.closed {
            return Err(SendBlocked::Closed(item));
        }
        if self.cap.is_some_and(|c| self.queue.len() >= c) {
            return Err(SendBlocked::Full(item));
        }
        self.queue.push_back(item);
        Ok(())
    }

    /// Attempt to dequeue the FIFO head.
    pub fn try_recv(&mut self) -> RecvOutcome<T> {
        match self.queue.pop_front() {
            Some(item) => RecvOutcome::Item(item),
            None if self.closed => RecvOutcome::Closed,
            None => RecvOutcome::Empty,
        }
    }

    /// Close the channel: later sends fail, queued items still drain.
    pub fn close(&mut self) {
        self.closed = true;
    }

    /// Whether [`VChan::close`] has been called.
    pub fn is_closed(&self) -> bool {
        self.closed
    }

    /// Items currently queued.
    pub fn len(&self) -> usize {
        self.queue.len()
    }

    /// True when nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }
}

/// A virtual clock: `base` is read from the wall exactly once (at state
/// construction — the one permitted wall-clock read, because only
/// *differences* ever matter), and every later reading is `base +
/// offset` with the offset advanced explicitly by schedule steps. Two
/// replays of the same schedule therefore observe identical durations
/// everywhere, which is what makes deadline/window decisions replayable.
#[derive(Debug, Clone, Copy)]
pub struct Clock {
    base: Instant,
    offset: Duration,
}

impl Clock {
    /// Clock at virtual time zero.
    #[allow(clippy::new_without_default)]
    pub fn new() -> Self {
        Self { base: Instant::now(), offset: Duration::ZERO }
    }

    /// The current virtual instant.
    pub fn now(&self) -> Instant {
        self.base + self.offset
    }

    /// Advance virtual time by `d` (a schedule step's explicit choice).
    pub fn advance(&mut self, d: Duration) {
        self.offset += d;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounded_chan_backpressures_and_drains_after_close() {
        let mut ch: VChan<u32> = VChan::bounded(2);
        ch.try_send(1).unwrap();
        ch.try_send(2).unwrap();
        assert_eq!(ch.try_send(3), Err(SendBlocked::Full(3)));
        ch.close();
        assert_eq!(ch.try_send(4), Err(SendBlocked::Closed(4)));
        assert_eq!(ch.try_recv(), RecvOutcome::Item(1), "queued items drain past close");
        assert_eq!(ch.try_recv(), RecvOutcome::Item(2));
        assert_eq!(ch.try_recv(), RecvOutcome::Closed);
    }

    #[test]
    fn unbounded_chan_reports_empty_while_open() {
        let mut ch: VChan<u32> = VChan::unbounded();
        assert_eq!(ch.try_recv(), RecvOutcome::Empty);
        ch.try_send(7).unwrap();
        assert_eq!(ch.len(), 1);
        assert!(!ch.is_empty());
        assert_eq!(ch.try_recv(), RecvOutcome::Item(7));
        assert!(ch.is_empty());
    }

    #[test]
    fn clock_advances_deterministically() {
        let mut c = Clock::new();
        let t0 = c.now();
        c.advance(Duration::from_millis(5));
        assert_eq!(c.now() - t0, Duration::from_millis(5));
    }
}
