//! Deterministic-schedule model checker for the serving stack's
//! concurrency cores.
//!
//! The engine's correctness claims — every accepted request answered
//! exactly once, admission slots returned exactly once, close → drain →
//! join leaves no queue non-empty, bounded-queue pipelines never
//! deadlock under backpressure, hot-swap register/retire linearizes
//! against in-flight traffic — are properties of *interleavings*, and
//! wall-clock test runs only ever sample a few of them. This module
//! explores them systematically instead:
//!
//! - [`dfs`] — the explorer: a depth-first search over schedules of
//!   **named actions** (`Fn(&mut S) -> ActionOutcome`), with bounded
//!   depth and preemptions, invariant asserters checked after every
//!   step, deadlock detection (all live actions blocked), and — on any
//!   violation — the exact failing schedule, replayable verbatim.
//! - [`sync`] — deterministic in-model primitives the scenario states
//!   are built from: bounded/unbounded queues with close semantics
//!   ([`sync::VChan`]) and a virtual clock ([`sync::Clock`]) that only
//!   advances when a schedule step says so.
//! - [`invariants`] — the asserter ledgers ([`invariants::ReplyLedger`],
//!   [`invariants::SlotLedger`]) shared between the checker scenarios
//!   and `tests/prop_invariants.rs`, so the property tests and the
//!   schedule explorer agree on what "exactly once" means.
//! - [`scenarios`] — the nine core scenarios over the *production* step
//!   cores ([`crate::coordinator::step`], [`crate::hetero::pipeline`],
//!   [`crate::cluster::RouterCore`],
//!   [`crate::workloads::ControllerCore`],
//!   [`crate::runtime::arbiter::ArbiterCore`]) and the *real*
//!   [`crate::coordinator::admission::AdmissionController`] and
//!   [`crate::obs::Recorder`],
//!   plus a deliberately buggy scenario that proves the explorer and the
//!   replayer actually catch and reproduce violations.
//!
//! The determinism contract the cores uphold (no wall clock, no real
//! channels, no I/O inside `step`) and the recipe for writing a new
//! invariant or replaying a failing schedule are documented in
//! DESIGN.md §11. Quick-profile exploration runs in CI as the
//! `model-check` job.

#![warn(missing_docs)]

pub mod dfs;
pub mod invariants;
pub mod scenarios;
pub mod sync;

pub use dfs::{ActionOutcome, Checker, Profile, Report, Violation};
