//! The DFS schedule explorer: stateless re-execution over named actions.
//!
//! A scenario is a state type `S` plus a set of **actions** — named
//! closures that each advance one logical thread of the system by one
//! step. The explorer enumerates interleavings depth-first: at every
//! node it probes which actions can run, recurses into each runnable
//! branch, and checks the scenario's invariants after every executed
//! step. Schedules are replayed **from scratch** for every probe
//! (stateless re-execution, the stride-rs/havoc idiom): scenario states
//! hold things like the real `AdmissionController` (atomics — not
//! `Clone`), so forking the state is not an option, but replaying a
//! deterministic prefix is free of that constraint. Scenario actions are
//! cheap (queue pushes, counter bumps), so the quick profile's full
//! exploration stays in tier-1-test territory.
//!
//! Determinism contract: an action invoked at the same position of the
//! same schedule must do the same thing — no wall clock, no OS threads,
//! no randomness inside actions (DESIGN.md §11 spells this out). The
//! explorer enforces it cheaply: a replayed step that no longer reports
//! [`ActionOutcome::Ran`] panics, naming the action.

use std::fmt;

/// What one action invocation did (the three-valued outcome the
/// explorer schedules around).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ActionOutcome {
    /// The action advanced its thread by one step (state mutated).
    Ran,
    /// The action cannot run *now* (e.g. its queue is empty/full) but
    /// may become runnable after another action runs. MUST NOT mutate.
    Blocked,
    /// The action has nothing left to do, ever. MUST NOT mutate.
    Done,
}

/// One named action: a logical thread's single-step closure.
struct Action<S> {
    name: &'static str,
    run: Box<dyn Fn(&mut S) -> ActionOutcome>,
}

/// An invariant asserter: checked against the state after every executed
/// step (`step`) or once per completed schedule (`finally`).
struct Asserter<S> {
    name: &'static str,
    check: Box<dyn Fn(&S) -> Result<(), String>>,
}

/// Exploration bounds. The defaults in [`Profile::quick`] are the CI
/// `model-check` job's budget: minutes, not hours.
#[derive(Debug, Clone, Copy)]
pub struct Profile {
    /// Stop after this many *completed* schedules (coverage cap).
    pub max_schedules: usize,
    /// Abandon (and flag) schedules longer than this many steps.
    pub max_depth: usize,
    /// Bound on **voluntary preemptions** per schedule: switching away
    /// from an action that could still run. Forced switches (the last
    /// action is blocked or done) are free — under tight backpressure
    /// every step is a forced switch, and charging for them would make
    /// bounded exploration of exactly those scenarios impossible.
    /// `None` removes the bound.
    pub max_preemptions: Option<usize>,
}

impl Profile {
    /// The CI quick profile: 1500 schedules, depth 64, 8 preemptions.
    pub fn quick() -> Self {
        Self { max_schedules: 1500, max_depth: 64, max_preemptions: Some(8) }
    }
}

/// What an exploration covered.
#[derive(Debug, Clone, Copy)]
pub struct Report {
    /// Complete schedules explored (every action reported Done).
    pub completed: usize,
    /// True when a bound ([`Profile`]) cut exploration short — coverage
    /// is a sample of the schedule space, not all of it.
    pub truncated: bool,
    /// Longest schedule seen, in steps.
    pub deepest: usize,
}

/// An invariant violation (or deadlock), carrying the exact schedule
/// that produced it. `Display` prints the schedule one numbered action
/// per line — paste those names into [`Checker::replay`] (or rerun the
/// same scenario, which is deterministic) to reproduce it.
#[derive(Debug)]
pub struct Violation {
    /// The violated invariant's name, or `"deadlock"`.
    pub invariant: &'static str,
    /// What the asserter saw (or which actions were blocked).
    pub detail: String,
    /// The failing schedule: action names in execution order.
    pub schedule: Vec<&'static str>,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "invariant violated: {} — {}", self.invariant, self.detail)?;
        writeln!(f, "failing schedule ({} steps, replayable):", self.schedule.len())?;
        for (i, name) in self.schedule.iter().enumerate() {
            writeln!(f, "  {i:>3}. {name}")?;
        }
        Ok(())
    }
}

/// The explorer: a scenario's state factory, actions, and asserters.
///
/// ```
/// use hetero_dnn::check::{ActionOutcome, Checker, Profile};
///
/// // two producers increment; the invariant caps the counter
/// let checker = Checker::new(|| 0u32)
///     .action("inc_a", |s: &mut u32| {
///         if *s < 4 {
///             *s += 1;
///             ActionOutcome::Ran
///         } else {
///             ActionOutcome::Done
///         }
///     })
///     .action("inc_b", |s: &mut u32| {
///         if *s < 4 {
///             *s += 1;
///             ActionOutcome::Ran
///         } else {
///             ActionOutcome::Done
///         }
///     })
///     .invariant("counter bounded", |s: &u32| {
///         if *s <= 4 { Ok(()) } else { Err(format!("counter {s}")) }
///     });
/// let report = checker.explore(Profile::quick()).expect("no violation");
/// assert!(report.completed >= 1);
/// ```
pub struct Checker<S> {
    factory: Box<dyn Fn() -> S>,
    actions: Vec<Action<S>>,
    invariants: Vec<Asserter<S>>,
    finals: Vec<Asserter<S>>,
}

impl<S> Checker<S> {
    /// Checker over states produced by `factory` (one fresh state per
    /// replayed schedule — the factory must be deterministic).
    pub fn new(factory: impl Fn() -> S + 'static) -> Self {
        Self {
            factory: Box::new(factory),
            actions: Vec::new(),
            invariants: Vec::new(),
            finals: Vec::new(),
        }
    }

    /// Add a named action (one logical thread's step function).
    pub fn action(
        mut self,
        name: &'static str,
        run: impl Fn(&mut S) -> ActionOutcome + 'static,
    ) -> Self {
        self.actions.push(Action { name, run: Box::new(run) });
        self
    }

    /// Add an invariant checked after **every executed step**.
    pub fn invariant(
        mut self,
        name: &'static str,
        check: impl Fn(&S) -> Result<(), String> + 'static,
    ) -> Self {
        self.invariants.push(Asserter { name, check: Box::new(check) });
        self
    }

    /// Add an invariant checked once per **completed schedule** (for
    /// quiescent properties like "every queue drained").
    pub fn finally(
        mut self,
        name: &'static str,
        check: impl Fn(&S) -> Result<(), String> + 'static,
    ) -> Self {
        self.finals.push(Asserter { name, check: Box::new(check) });
        self
    }

    /// Rebuild the state a schedule prefix leads to, from scratch.
    /// Panics if a replayed step no longer runs — that is a determinism
    /// breach in the scenario, not a schedule property.
    fn rerun(&self, prefix: &[usize]) -> S {
        let mut s = (self.factory)();
        for &i in prefix {
            let out = (self.actions[i].run)(&mut s);
            assert!(
                out == ActionOutcome::Ran,
                "non-deterministic scenario: replayed action {:?} reported {:?}",
                self.actions[i].name,
                out,
            );
        }
        s
    }

    /// The schedule (action names) a prefix of indices denotes.
    fn names(&self, prefix: &[usize]) -> Vec<&'static str> {
        prefix.iter().map(|&i| self.actions[i].name).collect()
    }

    /// Explore schedules depth-first under `profile`. Returns the
    /// coverage report, or the first violation found (invariant failure
    /// or deadlock) with its replayable schedule.
    pub fn explore(&self, profile: Profile) -> Result<Report, Violation> {
        assert!(!self.actions.is_empty(), "a scenario needs at least one action");
        let mut report = Report { completed: 0, truncated: false, deepest: 0 };
        let mut prefix = Vec::new();
        self.dfs(&mut prefix, 0, profile, &mut report)?;
        Ok(report)
    }

    /// One DFS node: probe every action on a fresh replay of `prefix`,
    /// detect completion/deadlock, then recurse into runnable branches.
    /// `preemptions` is the voluntary-switch count along this path —
    /// carried down the recursion, never recomputed (a replay cannot
    /// know which switches were forced when they happened).
    fn dfs(
        &self,
        prefix: &mut Vec<usize>,
        preemptions: usize,
        profile: Profile,
        report: &mut Report,
    ) -> Result<(), Violation> {
        if report.completed >= profile.max_schedules {
            report.truncated = true;
            return Ok(());
        }
        if prefix.len() >= profile.max_depth {
            report.truncated = true;
            return Ok(());
        }

        // probe: which actions can run here? (each probe replays the
        // prefix fresh — a Ran probe has consumed its step, so its state
        // is only valid for that branch's invariant check)
        let mut runnable = Vec::new();
        let mut blocked = Vec::new();
        let mut done = 0usize;
        for (i, action) in self.actions.iter().enumerate() {
            let mut s = self.rerun(prefix);
            match (action.run)(&mut s) {
                ActionOutcome::Ran => {
                    runnable.push(i);
                    // the asserters see the state right after the step
                    for inv in &self.invariants {
                        if let Err(detail) = (inv.check)(&s) {
                            let mut schedule = self.names(prefix);
                            schedule.push(action.name);
                            return Err(Violation { invariant: inv.name, detail, schedule });
                        }
                    }
                }
                ActionOutcome::Blocked => blocked.push(action.name),
                ActionOutcome::Done => done += 1,
            }
        }

        if runnable.is_empty() {
            if done == self.actions.len() {
                // complete schedule: quiescent asserters run once
                let s = self.rerun(prefix);
                for inv in &self.finals {
                    if let Err(detail) = (inv.check)(&s) {
                        return Err(Violation {
                            invariant: inv.name,
                            detail,
                            schedule: self.names(prefix),
                        });
                    }
                }
                report.completed += 1;
                report.deepest = report.deepest.max(prefix.len());
                return Ok(());
            }
            // nothing can run, somebody still has work: deadlock
            return Err(Violation {
                invariant: "deadlock",
                detail: format!("no action runnable; blocked: {blocked:?}"),
                schedule: self.names(prefix),
            });
        }

        let last = prefix.last().copied();
        let last_runnable = last.is_some_and(|l| runnable.contains(&l));
        for &i in &runnable {
            // a voluntary preemption = switching away from a still-
            // runnable last action; continuing it (or switching because
            // we must) is free and never pruned
            let cost = usize::from(last_runnable && Some(i) != last);
            if let Some(cap) = profile.max_preemptions {
                if preemptions + cost > cap {
                    report.truncated = true;
                    continue;
                }
            }
            prefix.push(i);
            self.dfs(prefix, preemptions + cost, profile, report)?;
            prefix.pop();
            if report.completed >= profile.max_schedules {
                report.truncated = true;
                return Ok(());
            }
        }
        Ok(())
    }

    /// Replay a printed schedule (action names, in order) against a
    /// fresh state, checking every step asserter along the way and the
    /// quiescent asserters at the end if the schedule runs to
    /// completion. Returns the violation it reproduces, if any.
    ///
    /// This is the failure-reproduction entry point: paste the numbered
    /// names from a [`Violation`]'s display output.
    pub fn replay(&self, schedule: &[&str]) -> Result<(), Violation> {
        let mut s = (self.factory)();
        let mut executed: Vec<&'static str> = Vec::with_capacity(schedule.len());
        for name in schedule {
            let idx = self
                .actions
                .iter()
                .position(|a| a.name == *name)
                .unwrap_or_else(|| panic!("schedule names unknown action {name:?}"));
            let out = (self.actions[idx].run)(&mut s);
            assert!(
                out == ActionOutcome::Ran,
                "replayed action {name:?} reported {out:?} — schedule does not fit this scenario",
            );
            executed.push(self.actions[idx].name);
            for inv in &self.invariants {
                if let Err(detail) = (inv.check)(&s) {
                    return Err(Violation { invariant: inv.name, detail, schedule: executed });
                }
            }
        }
        // quiescent checks only apply if every action is in fact done
        let all_done = (0..self.actions.len()).all(|i| {
            // probing mutates on Ran; replay clones nothing, so probe on
            // a scratch replay of the full schedule instead
            let mut scratch = (self.factory)();
            for name in schedule {
                let idx = self.actions.iter().position(|a| a.name == *name).expect("checked");
                (self.actions[idx].run)(&mut scratch);
            }
            (self.actions[i].run)(&mut scratch) == ActionOutcome::Done
        });
        if all_done {
            for inv in &self.finals {
                if let Err(detail) = (inv.check)(&s) {
                    return Err(Violation {
                        invariant: inv.name,
                        detail,
                        schedule: executed.clone(),
                    });
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two logical threads, each needing the other's token: the classic
    /// deadlock, found by the explorer with the schedule attached.
    #[test]
    fn detects_deadlock() {
        struct S {
            a_has: bool,
            b_has: bool,
        }
        let checker = Checker::new(|| S { a_has: false, b_has: false })
            .action("a_takes", |s: &mut S| {
                if s.a_has {
                    ActionOutcome::Done
                } else if s.b_has {
                    ActionOutcome::Blocked
                } else {
                    s.a_has = true;
                    ActionOutcome::Ran
                }
            })
            .action("b_takes", |s: &mut S| {
                if s.b_has {
                    ActionOutcome::Done
                } else if s.a_has {
                    ActionOutcome::Blocked
                } else {
                    s.b_has = true;
                    ActionOutcome::Ran
                }
            });
        let v = checker.explore(Profile::quick()).expect_err("must deadlock");
        assert_eq!(v.invariant, "deadlock");
        assert_eq!(v.schedule.len(), 1, "one take, then the other blocks: {v}");
    }

    /// A step invariant violation carries a schedule that replays to the
    /// same violation.
    #[test]
    fn violation_replays() {
        let build = || {
            Checker::new(|| (0u32, 0u32))
                .action("a", |s: &mut (u32, u32)| {
                    if s.0 < 3 {
                        s.0 += 1;
                        ActionOutcome::Ran
                    } else {
                        ActionOutcome::Done
                    }
                })
                .action("b", |s: &mut (u32, u32)| {
                    if s.1 < 3 {
                        s.1 += 1;
                        ActionOutcome::Ran
                    } else {
                        ActionOutcome::Done
                    }
                })
                .invariant("sum under 5", |s: &(u32, u32)| {
                    if s.0 + s.1 < 5 {
                        Ok(())
                    } else {
                        Err(format!("sum {}", s.0 + s.1))
                    }
                })
        };
        let v = build().explore(Profile::quick()).expect_err("sum reaches 5");
        let replayed = build().replay(&v.schedule).expect_err("same schedule, same violation");
        assert_eq!(replayed.invariant, v.invariant);
        assert_eq!(replayed.detail, v.detail);
        assert_eq!(replayed.schedule, v.schedule);
    }

    /// Exploration without violations counts complete schedules and
    /// respects the schedule cap.
    #[test]
    fn counts_and_caps_schedules() {
        let build = |cap: usize| {
            Checker::new(|| (0u32, 0u32))
                .action("a", |s: &mut (u32, u32)| {
                    if s.0 < 3 {
                        s.0 += 1;
                        ActionOutcome::Ran
                    } else {
                        ActionOutcome::Done
                    }
                })
                .action("b", |s: &mut (u32, u32)| {
                    if s.1 < 3 {
                        s.1 += 1;
                        ActionOutcome::Ran
                    } else {
                        ActionOutcome::Done
                    }
                })
                .explore(Profile { max_schedules: cap, max_depth: 64, max_preemptions: None })
                .expect("no invariants to violate")
        };
        // 3 a-steps and 3 b-steps interleave in C(6,3) = 20 ways
        let full = build(1000);
        assert_eq!(full.completed, 20);
        assert!(!full.truncated);
        assert_eq!(full.deepest, 6);
        let capped = build(7);
        assert_eq!(capped.completed, 7);
        assert!(capped.truncated);
    }

    /// The preemption bound prunes voluntary switches but forced ones
    /// (the last action blocked/done) stay free.
    #[test]
    fn preemption_bound_keeps_forced_switches() {
        // strict ping-pong: each action is blocked unless it is its turn,
        // so EVERY switch is forced and a zero-preemption budget still
        // completes the lone legal schedule
        let r = Checker::new(|| 0u32)
            .action("ping", |s: &mut u32| match *s {
                6.. => ActionOutcome::Done,
                n if n % 2 == 0 => {
                    *s += 1;
                    ActionOutcome::Ran
                }
                _ => ActionOutcome::Blocked,
            })
            .action("pong", |s: &mut u32| match *s {
                6.. => ActionOutcome::Done,
                n if n % 2 == 1 => {
                    *s += 1;
                    ActionOutcome::Ran
                }
                _ => ActionOutcome::Blocked,
            })
            .explore(Profile { max_schedules: 100, max_depth: 32, max_preemptions: Some(0) })
            .expect("ping-pong never deadlocks");
        assert_eq!(r.completed, 1, "exactly one legal schedule");
        assert!(!r.truncated, "no voluntary switch was ever attempted");
    }
}
