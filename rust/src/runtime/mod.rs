//! Artifact runtime: load AOT artifacts and execute them deterministically.
//!
//! The original Layer-3 design wrapped the `xla` crate (PJRT C API, CPU
//! plugin) to execute the HLO-text artifacts that `python/compile/aot.py`
//! lowers from the L2 JAX modules. The offline build constraint
//! (DESIGN.md §Offline) forbids external native bindings, so execution is
//! provided by an **in-tree deterministic backend**: every artifact is a
//! pure function of its manifest-described inputs, reproducible bit-for-bit
//! across threads, processes and worker replicas. That is exactly the
//! property the serving stack needs (batching, worker pools and the wire
//! protocol are all verified against it); *numerical* equivalence with the
//! real kernels is the PJRT backend's job and is tracked as future work in
//! DESIGN.md §Backends.
//!
//! Two manifest sources feed the runtime:
//! - [`Runtime::new`] — requires `artifacts/manifest.json` (written by
//!   `make artifacts`); fails fast when absent.
//! - [`Runtime::new_or_simulated`] — falls back to the in-tree
//!   [`Manifest::simulated`] geometry with a one-time notice, so serving
//!   demos and CI smoke tests run end-to-end in a fresh checkout.
//!
//! Executables are cached per artifact name behind `Rc` (a [`Runtime`] is
//! single-threaded by construction; the coordinator gives each worker
//! thread its own instance).

#![warn(missing_docs)]

pub mod arbiter;
pub mod chain;
pub mod device;

use crate::config::{ArtifactEntry, ConfigError, Manifest};
use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

/// One xorshift64 step over `state`; returns a uniform sample in [0, 1).
/// Shared by [`Tensor::randn`] and the simulated backend so the PRNG core
/// exists exactly once.
fn xorshift_uniform(state: &mut u64) -> f64 {
    *state ^= *state << 13;
    *state ^= *state >> 7;
    *state ^= *state << 17;
    (*state >> 11) as f64 / (1u64 << 53) as f64
}

/// A host-side f32 tensor (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    /// Dimensions, outermost first.
    pub shape: Vec<usize>,
    /// Row-major element buffer; `data.len() == shape.iter().product()`.
    pub data: Vec<f32>,
}

impl Tensor {
    /// Tensor from explicit shape + data.
    ///
    /// # Panics
    /// Panics when `data.len()` disagrees with the shape's element count.
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Self { shape, data }
    }

    /// All-zero tensor of the given shape.
    pub fn zeros(shape: &[usize]) -> Self {
        Self { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    /// Deterministic pseudo-normal tensor (Box-Muller over xorshift) —
    /// used to generate synthetic weights/inputs reproducibly.
    pub fn randn(shape: &[usize], seed: u64) -> Self {
        let n: usize = shape.iter().product();
        let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(1);
        let mut data = Vec::with_capacity(n);
        while data.len() < n {
            let (u1, u2): (f64, f64) =
                (xorshift_uniform(&mut state).max(1e-12), xorshift_uniform(&mut state));
            let r = (-2.0 * u1.ln()).sqrt();
            let th = 2.0 * std::f64::consts::PI * u2;
            data.push((r * th.cos()) as f32);
            if data.len() < n {
                data.push((r * th.sin()) as f32);
            }
        }
        Self { shape: shape.to_vec(), data }
    }

    /// Number of elements.
    pub fn elems(&self) -> usize {
        self.data.len()
    }

    /// Content digest over (shape, data) — the execution key the simulated
    /// backend reads. One hash pass, no allocation; identical to the digest
    /// carried by a [`Literal`] built from this tensor.
    pub fn digest(&self) -> u64 {
        digest_tensor(&self.shape, &self.data)
    }

    /// Max absolute difference vs another tensor of identical shape.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape, "shape mismatch in comparison");
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0f32, |m, (a, b)| m.max((a - b).abs()))
    }

    /// Relative max-error vs a reference (for q8-vs-float comparisons).
    pub fn rel_error(&self, reference: &Tensor) -> f32 {
        let amax = reference.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        self.max_abs_diff(reference) / (amax + 1e-9)
    }

    /// Concatenate along the last (channel) axis — NHWC module joins.
    pub fn concat_last(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape.len(), other.shape.len());
        let d = self.shape.len() - 1;
        assert_eq!(self.shape[..d], other.shape[..d], "leading dims must match");
        let (ca, cb) = (self.shape[d], other.shape[d]);
        let rows = self.elems() / ca;
        let mut data = Vec::with_capacity(self.elems() + other.elems());
        for r in 0..rows {
            data.extend_from_slice(&self.data[r * ca..(r + 1) * ca]);
            data.extend_from_slice(&other.data[r * cb..(r + 1) * cb]);
        }
        let mut shape = self.shape.clone();
        shape[d] = ca + cb;
        Tensor::new(shape, data)
    }

    /// Slice channels [lo, hi) along the last axis.
    pub fn slice_last(&self, lo: usize, hi: usize) -> Tensor {
        let d = self.shape.len() - 1;
        let c = self.shape[d];
        assert!(lo < hi && hi <= c, "bad channel slice {lo}..{hi} of {c}");
        let rows = self.elems() / c;
        let mut data = Vec::with_capacity(rows * (hi - lo));
        for r in 0..rows {
            data.extend_from_slice(&self.data[r * c + lo..r * c + hi]);
        }
        let mut shape = self.shape.clone();
        shape[d] = hi - lo;
        Tensor::new(shape, data)
    }

    /// ShuffleNet channel shuffle over the last axis (G groups).
    pub fn channel_shuffle(&self, groups: usize) -> Tensor {
        let d = self.shape.len() - 1;
        let c = self.shape[d];
        assert_eq!(c % groups, 0);
        let cg = c / groups;
        let rows = self.elems() / c;
        let mut data = vec![0.0f32; self.elems()];
        for r in 0..rows {
            for g in 0..groups {
                for j in 0..cg {
                    data[r * c + j * groups + g] = self.data[r * c + g * cg + j];
                }
            }
        }
        Tensor::new(self.shape.clone(), data)
    }
}

/// Runtime errors.
#[derive(Debug, thiserror::Error)]
pub enum RuntimeError {
    /// Manifest/config loading or parsing failed.
    #[error("config: {0}")]
    Config(#[from] ConfigError),
    /// A serving-stack failure (startup, shutdown, dead worker, …).
    #[error("serving: {0}")]
    Serving(String),
    /// The request was malformed at the wire-protocol layer (bad field
    /// value — e.g. an undefined priority); the connection survives.
    #[error("bad request: {0}")]
    BadRequest(String),
    /// The request named a model the engine does not serve.
    #[error("unknown model {name:?}; registered: {registered:?}")]
    UnknownModel {
        /// The model name the request carried.
        name: String,
        /// Models registered at lookup time, in registration order.
        registered: Vec<String>,
    },
    /// Shared admission control shed this request at the front door.
    #[error("shed: projected wait {projected_wait:?} exceeds the admission deadline")]
    Shed {
        /// Projected queueing delay at rejection time (retry signal).
        projected_wait: std::time::Duration,
    },
    /// The model's per-request admission budget is exhausted
    /// (`ModelSpec::budget`): this model already has `in_flight`
    /// requests in flight against a cap of `budget`.
    #[error("model {model:?} over budget: {in_flight} in flight >= cap {budget}")]
    BudgetExhausted {
        /// The model whose budget rejected the request.
        model: String,
        /// In-flight requests observed at rejection time.
        in_flight: u64,
        /// The model's configured in-flight cap.
        budget: u64,
    },
    /// The request was queued on a model that got retired before its
    /// batch formed (`Engine::retire`); resubmit against another model.
    #[error("model {model:?} is retiring; request drained before execution")]
    ModelRetiring {
        /// The model that was retired out from under the request.
        model: String,
    },
    /// The request's own queue-time deadline expired while it waited.
    #[error("deadline exceeded: waited {waited:?} against a {deadline:?} deadline")]
    DeadlineExceeded {
        /// Time the request actually waited before being shed.
        waited: std::time::Duration,
        /// The deadline the request carried.
        deadline: std::time::Duration,
    },
    /// Wrong number of positional inputs for an artifact.
    #[error("artifact {name}: expected {expected} inputs, got {got}")]
    ArityMismatch {
        /// Artifact name.
        name: String,
        /// Inputs the manifest declares.
        expected: usize,
        /// Inputs the caller supplied.
        got: usize,
    },
    /// One positional input's shape disagrees with the manifest.
    #[error("artifact {name} input {index} ({arg}): expected shape {expected:?}, got {got:?}")]
    ShapeMismatch {
        /// Artifact name.
        name: String,
        /// Positional index of the offending input.
        index: usize,
        /// Manifest argument name of the offending input.
        arg: String,
        /// Shape the manifest declares.
        expected: Vec<usize>,
        /// Shape the caller supplied.
        got: Vec<usize>,
    },
}

impl RuntimeError {
    /// Every stable wire code [`RuntimeError::code`] can return, one per
    /// variant. This is the list PROTOCOL.md §6's wire-code table is
    /// verified against in CI (`tests/wire_code_table.rs`) — extend both
    /// together.
    pub const CODES: &'static [&'static str] = &[
        "config",
        "serving",
        "bad_request",
        "unknown_model",
        "shed",
        "budget_exhausted",
        "model_retiring",
        "deadline",
        "arity_mismatch",
        "shape_mismatch",
    ];

    /// Stable machine-readable code, used by the wire protocol's
    /// structured error frames (v1 `{"id", "code", "error"}` / v2 ERROR
    /// frames). The normative table lives in PROTOCOL.md §6.
    pub fn code(&self) -> &'static str {
        match self {
            RuntimeError::Config(_) => "config",
            RuntimeError::Serving(_) => "serving",
            RuntimeError::BadRequest(_) => "bad_request",
            RuntimeError::UnknownModel { .. } => "unknown_model",
            RuntimeError::Shed { .. } => "shed",
            RuntimeError::BudgetExhausted { .. } => "budget_exhausted",
            RuntimeError::ModelRetiring { .. } => "model_retiring",
            RuntimeError::DeadlineExceeded { .. } => "deadline",
            RuntimeError::ArityMismatch { .. } => "arity_mismatch",
            RuntimeError::ShapeMismatch { .. } => "shape_mismatch",
        }
    }
}

/// A device-side literal: a tensor converted for execution, carrying a
/// content digest so repeated executions (pre-converted weights on the
/// serving hot path) never re-hash the bulk data.
#[derive(Debug, Clone)]
pub struct Literal {
    /// Dimensions, outermost first (same convention as [`Tensor::shape`]).
    pub shape: Vec<usize>,
    /// Row-major element buffer, taken from the source tensor by move.
    pub data: Vec<f32>,
    digest: u64,
}

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

fn fnv1a_bytes(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

fn fnv1a_f32(mut h: u64, data: &[f32]) -> u64 {
    for v in data {
        h ^= v.to_bits() as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// One digest definition for tensors and literals — the batch path hashes
/// tensors directly and must agree bit-for-bit with the literal path.
fn digest_tensor(shape: &[usize], data: &[f32]) -> u64 {
    let mut h = FNV_OFFSET;
    for &d in shape {
        h = fnv1a_bytes(h, &(d as u64).to_le_bytes());
    }
    fnv1a_f32(h, data)
}

impl Literal {
    /// Convert a host tensor by **move**: the buffer is taken, not copied
    /// (the simulated backend only reads the digest, and a real backend
    /// should donate the buffer to the device — ROADMAP). Callers that
    /// need to keep the tensor clone explicitly at the call site.
    pub fn from_tensor(t: Tensor) -> Literal {
        let digest = digest_tensor(&t.shape, &t.data);
        Literal { shape: t.shape, data: t.data, digest }
    }

    /// Convert by move with a digest **already computed** via
    /// [`Tensor::digest`] — the serving front door hashes each input once
    /// for its result-cache lookup, and the worker reuses that digest
    /// here instead of paying a second hash pass over the bulk data.
    ///
    /// The caller must pass exactly `t.digest()`; a wrong digest would
    /// silently change what the simulated backend computes (debug builds
    /// assert agreement).
    pub fn from_tensor_with_digest(t: Tensor, digest: u64) -> Literal {
        debug_assert_eq!(digest, t.digest(), "digest must be the tensor's own");
        Literal { shape: t.shape, data: t.data, digest }
    }

    /// Content digest over (shape, data), computed once at conversion.
    pub fn digest(&self) -> u64 {
        self.digest
    }
}

/// Execution backend. `Simulated` is the offline in-tree interpreter; a
/// real PJRT backend slots in here when native bindings are available
/// (DESIGN.md §Backends).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// The in-tree deterministic interpreter (see module docs).
    Simulated,
}

impl Backend {
    /// True for backends that execute the real lowered kernels (none are
    /// compiled into offline builds). The numeric-equivalence test suites
    /// gate on this so they never assert kernel math against the
    /// deterministic stand-in.
    pub fn is_real(&self) -> bool {
        match self {
            Backend::Simulated => false,
        }
    }
}

/// Initial fold state of the simulated backend: the artifact name seeds
/// the hash, so two artifacts with identical inputs still differ.
fn sim_fold_init(name: &str) -> u64 {
    fnv1a_bytes(FNV_OFFSET, name.as_bytes())
}

/// Fold one input digest into the state — THE single definition of the
/// simulated backend's input combination, shared by the one-shot
/// [`sim_outputs`] path and the staged [`Executable::stage_fold`] path so
/// a split execution can never diverge from a monolithic one.
fn sim_fold_digest(h: u64, d: u64) -> u64 {
    (h.rotate_left(17) ^ d).wrapping_mul(FNV_PRIME)
}

/// Synthesize the output tuple from a fully-folded state. Values land in
/// [-1, 1].
fn sim_synthesize(entry: &ArtifactEntry, h: u64) -> Vec<Tensor> {
    entry
        .outputs
        .iter()
        .enumerate()
        .map(|(oi, d)| {
            let n = d.elems();
            let mut s = (h ^ (oi as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15)) | 1;
            let data = (0..n)
                .map(|_| (xorshift_uniform(&mut s) * 2.0 - 1.0) as f32)
                .collect();
            Tensor::new(d.shape.clone(), data)
        })
        .collect()
}

/// Deterministic output synthesis: a pure function of (artifact name,
/// output index, input digests).
fn sim_outputs(name: &str, entry: &ArtifactEntry, digests: &[u64]) -> Vec<Tensor> {
    let mut h = sim_fold_init(name);
    for &d in digests {
        h = sim_fold_digest(h, d);
    }
    sim_synthesize(entry, h)
}

/// An in-flight **staged execution**: the digest-fold state after some
/// prefix of an artifact's inputs has been consumed.
///
/// This is the runtime's device-execution seam (used by [`crate::hetero`]):
/// a heterogeneous pipeline splits an artifact's input chain at its plan's
/// device boundaries, each simulated device folds the span it owns via
/// [`Executable::stage_fold`], and only this small state — the
/// deterministic backend's analogue of the intermediate feature map —
/// crosses the simulated link between stages. Because every stage applies
/// the *same* fold the monolithic paths apply (one shared definition),
/// [`Executable::stage_finish`] is guaranteed bit-identical to
/// [`Executable::run`] / [`Executable::run_batch`] over the same inputs.
#[derive(Debug, Clone)]
pub struct StagedRun {
    h: u64,
    consumed: usize,
}

impl StagedRun {
    /// How many positional inputs have been folded so far.
    pub fn consumed(&self) -> usize {
        self.consumed
    }
}

/// A loaded artifact bound to a backend.
pub struct Executable {
    /// Artifact name, as listed in the manifest.
    pub name: String,
    /// The manifest entry: ordered input/output names, shapes, tags.
    pub entry: ArtifactEntry,
    backend: Backend,
}

impl Executable {
    /// Convert host tensors to literals, validating shapes against the
    /// manifest inputs starting at `offset`. Use this to prepare
    /// *invariant* inputs (weights) once and skip the per-request
    /// conversion + digest on the serving hot path (§Perf).
    pub fn prepare(&self, inputs: &[Tensor], offset: usize) -> Result<Vec<Literal>, RuntimeError> {
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, t) in inputs.iter().enumerate() {
            self.check_one(offset + i, &t.shape)?;
            literals.push(Literal::from_tensor(t.clone()));
        }
        Ok(literals)
    }

    /// Validate one positional input against the manifest — THE single
    /// definition of arity/shape acceptance; every execute path (tensor,
    /// literal, batch, offset prepare) routes through it, so the paths
    /// can never diverge on which inputs they accept.
    fn check_one(&self, index: usize, shape: &[usize]) -> Result<(), RuntimeError> {
        let d = self.entry.inputs.get(index).ok_or_else(|| RuntimeError::ArityMismatch {
            name: self.name.clone(),
            expected: self.entry.inputs.len(),
            got: index + 1,
        })?;
        if shape != d.shape.as_slice() {
            return Err(RuntimeError::ShapeMismatch {
                name: self.name.clone(),
                index,
                arg: d.name.clone(),
                expected: d.shape.clone(),
                got: shape.to_vec(),
            });
        }
        Ok(())
    }

    /// Validate one request's full positional input list: exact arity,
    /// then [`Executable::check_one`] per input.
    fn check_shapes<'a, I>(&self, shapes: I) -> Result<(), RuntimeError>
    where
        I: ExactSizeIterator<Item = &'a [usize]>,
    {
        if shapes.len() != self.entry.inputs.len() {
            return Err(RuntimeError::ArityMismatch {
                name: self.name.clone(),
                expected: self.entry.inputs.len(),
                got: shapes.len(),
            });
        }
        for (i, shape) in shapes.enumerate() {
            self.check_one(i, shape)?;
        }
        Ok(())
    }

    /// Execute with pre-converted literals (see [`Executable::prepare`]).
    pub fn run_literals(&self, literals: &[&Literal]) -> Result<Vec<Tensor>, RuntimeError> {
        self.check_shapes(literals.iter().map(|l| l.shape.as_slice()))?;
        let digests: Vec<u64> = literals.iter().map(|l| l.digest).collect();
        match self.backend {
            Backend::Simulated => Ok(sim_outputs(&self.name, &self.entry, &digests)),
        }
    }

    /// Execute with host tensors; validates arity + shapes against the
    /// manifest (via `prepare` + `run_literals`), returns the output
    /// tuple flattened to host tensors.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>, RuntimeError> {
        let literals = self.prepare(inputs, 0)?;
        let refs: Vec<&Literal> = literals.iter().collect();
        self.run_literals(&refs)
    }

    /// Execute a **formed batch as one backend call** (the batch seam —
    /// DESIGN.md §Engine). Each element is one request's full input list;
    /// outputs are bit-identical to N independent [`Executable::run`]
    /// calls. Every element is validated before anything executes (a
    /// batch either forms or fails as a unit), and inputs are *hashed,
    /// never copied* — unlike `run`, which must materialize owning
    /// literals from its borrowed tensors.
    pub fn run_batch(&self, batch: &[&[Tensor]]) -> Result<Vec<Vec<Tensor>>, RuntimeError> {
        let mut digests: Vec<Vec<u64>> = Vec::with_capacity(batch.len());
        for inputs in batch {
            self.check_shapes(inputs.iter().map(|t| t.shape.as_slice()))?;
            digests.push(inputs.iter().map(Tensor::digest).collect());
        }
        match self.backend {
            Backend::Simulated => {
                Ok(digests.iter().map(|d| sim_outputs(&self.name, &self.entry, d)).collect())
            }
        }
    }

    /// Begin a staged execution (see [`StagedRun`]): no inputs consumed
    /// yet. Feed inputs in manifest order with [`Executable::stage_fold`],
    /// then synthesize outputs with [`Executable::stage_finish`].
    pub fn stage_begin(&self) -> StagedRun {
        StagedRun { h: sim_fold_init(&self.name), consumed: 0 }
    }

    /// Fold the next `literals.len()` positional inputs into a staged
    /// execution. Each literal is validated against the manifest at the
    /// run's current position (`check_one` — the same acceptance rule
    /// every other execute path uses), so a staged run
    /// rejects exactly what a monolithic run rejects, at the stage where
    /// the offending input lives.
    pub fn stage_fold(
        &self,
        run: &mut StagedRun,
        literals: &[&Literal],
    ) -> Result<(), RuntimeError> {
        for l in literals {
            self.check_one(run.consumed, &l.shape)?;
            run.h = sim_fold_digest(run.h, l.digest);
            run.consumed += 1;
        }
        Ok(())
    }

    /// Finish a staged execution: requires every manifest input to have
    /// been folded, then synthesizes the output tuple — **bit-identical**
    /// to [`Executable::run`] over the same inputs in the same order.
    pub fn stage_finish(&self, run: StagedRun) -> Result<Vec<Tensor>, RuntimeError> {
        if run.consumed != self.entry.inputs.len() {
            return Err(RuntimeError::ArityMismatch {
                name: self.name.clone(),
                expected: self.entry.inputs.len(),
                got: run.consumed,
            });
        }
        match self.backend {
            Backend::Simulated => Ok(sim_synthesize(&self.entry, run.h)),
        }
    }

    /// Batch twin of [`Executable::run_literals`] — the serving hot path:
    /// each element is one request's literal list (its moved input plus
    /// the pool's shared pre-converted weights). One backend dispatch for
    /// the whole batch; all elements validated up front.
    pub fn run_literals_batch(
        &self,
        batch: &[Vec<&Literal>],
    ) -> Result<Vec<Vec<Tensor>>, RuntimeError> {
        for literals in batch {
            self.check_shapes(literals.iter().map(|l| l.shape.as_slice()))?;
        }
        match self.backend {
            Backend::Simulated => Ok(batch
                .iter()
                .map(|literals| {
                    let digests: Vec<u64> = literals.iter().map(|l| l.digest).collect();
                    sim_outputs(&self.name, &self.entry, &digests)
                })
                .collect()),
        }
    }
}

/// Synthesize one manifest-shaped input: seeded by position, He-ish
/// scaled so activations stay in range. THE single definition behind
/// [`Runtime::synth_inputs`] and [`Runtime::synth_input`] — the full-set
/// and span-wise paths can never drift apart.
fn synth_one(d: &crate::config::TensorDesc, seed: u64, index: usize) -> Tensor {
    let mut t = Tensor::randn(&d.shape, seed.wrapping_add(index as u64 * 7919));
    let fan_in: usize = d.shape[..d.shape.len().saturating_sub(1)].iter().product();
    let scale = (2.0 / fan_in.max(1) as f32).sqrt();
    for v in &mut t.data {
        *v *= scale;
    }
    t
}

/// Manifest-driven artifact runtime with a per-artifact executable cache.
/// Single-threaded by construction (`Rc` cache) — the coordinator pins one
/// instance per executor worker thread.
pub struct Runtime {
    backend: Backend,
    /// The manifest this runtime serves artifacts from.
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
}

impl Runtime {
    /// Runtime over the built artifacts; fails fast when `make artifacts`
    /// has not produced a manifest.
    pub fn new() -> Result<Self, RuntimeError> {
        Ok(Self::with_manifest(Manifest::load()?))
    }

    /// Runtime over an explicit manifest.
    pub fn with_manifest(manifest: Manifest) -> Self {
        Self { backend: Backend::Simulated, manifest, cache: RefCell::new(HashMap::new()) }
    }

    /// Runtime over the in-tree simulated manifest (no artifacts needed).
    pub fn simulated() -> Self {
        Self::with_manifest(Manifest::simulated())
    }

    /// Built artifacts when available, simulated platform otherwise. The
    /// fallback is announced once per process so serving logs make the
    /// execution substrate unambiguous.
    pub fn new_or_simulated() -> Self {
        match Manifest::load() {
            Ok(m) => Self::with_manifest(m),
            Err(e) => {
                // surface the real cause: "not built" (NotFound) reads very
                // differently from a corrupted manifest or a bad
                // HETERO_DNN_ARTIFACTS path
                static NOTICE: std::sync::Once = std::sync::Once::new();
                NOTICE.call_once(|| {
                    eprintln!(
                        "[runtime] no usable AOT artifacts ({e}); falling back to the \
                         simulated platform (deterministic in-tree backend)"
                    );
                });
                Self::simulated()
            }
        }
    }

    /// True when running against [`Manifest::simulated`].
    pub fn is_simulated(&self) -> bool {
        self.manifest.simulated
    }

    /// True when execution goes through real lowered kernels rather than
    /// the deterministic stand-in (see [`Backend::is_real`]).
    pub fn has_real_backend(&self) -> bool {
        self.backend.is_real()
    }

    /// Human-readable execution substrate, for serving logs.
    pub fn platform(&self) -> String {
        match self.backend {
            Backend::Simulated if self.manifest.simulated => {
                "sim-cpu (deterministic interpreter, simulated manifest)".into()
            }
            Backend::Simulated => "sim-cpu (deterministic interpreter)".into(),
        }
    }

    /// Load an artifact; cached after the first call.
    pub fn load(&self, name: &str) -> Result<Rc<Executable>, RuntimeError> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let entry = self.manifest.entry(name)?.clone();
        let e = Rc::new(Executable { name: name.to_string(), entry, backend: self.backend });
        self.cache.borrow_mut().insert(name.to_string(), e.clone());
        Ok(e)
    }

    /// Generate manifest-shaped random inputs for an artifact (synthetic
    /// weights — DESIGN.md §2 substitution for ImageNet checkpoints).
    pub fn synth_inputs(&self, name: &str, seed: u64) -> Result<Vec<Tensor>, RuntimeError> {
        let entry = self.manifest.entry(name)?;
        Ok(entry.inputs.iter().enumerate().map(|(i, d)| synth_one(d, seed, i)).collect())
    }

    /// Generate ONE manifest-shaped random input, positional `index` —
    /// identical to `synth_inputs(name, seed)?[index]` without paying
    /// for the rest of the set. A hetero pipeline lane synthesizes only
    /// the weight span it owns through this.
    pub fn synth_input(&self, name: &str, seed: u64, index: usize) -> Result<Tensor, RuntimeError> {
        let entry = self.manifest.entry(name)?;
        let d = entry.inputs.get(index).ok_or_else(|| RuntimeError::ArityMismatch {
            name: name.to_string(),
            expected: entry.inputs.len(),
            got: index + 1,
        })?;
        Ok(synth_one(d, seed, index))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_randn_deterministic() {
        let a = Tensor::randn(&[4, 4], 42);
        let b = Tensor::randn(&[4, 4], 42);
        assert_eq!(a, b);
        let c = Tensor::randn(&[4, 4], 43);
        assert!(a.max_abs_diff(&c) > 0.0);
    }

    #[test]
    fn tensor_randn_is_roughly_normal() {
        let t = Tensor::randn(&[10_000], 7);
        let mean: f32 = t.data.iter().sum::<f32>() / 1e4;
        let var: f32 = t.data.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 1e4;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn concat_then_slice_roundtrip() {
        let a = Tensor::randn(&[1, 2, 2, 3], 1);
        let b = Tensor::randn(&[1, 2, 2, 5], 2);
        let c = a.concat_last(&b);
        assert_eq!(c.shape, vec![1, 2, 2, 8]);
        assert_eq!(c.slice_last(0, 3), a);
        assert_eq!(c.slice_last(3, 8), b);
    }

    #[test]
    fn channel_shuffle_matches_python_semantics() {
        // out[.., j*G + g] = in[.., g*(C/G) + j]
        let t = Tensor::new(vec![1, 1, 1, 6], vec![0., 1., 2., 3., 4., 5.]);
        let s = t.channel_shuffle(2);
        assert_eq!(s.data, vec![0., 3., 1., 4., 2., 5.]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let t = Tensor::randn(&[1, 3, 3, 8], 9);
        let s = t.channel_shuffle(2);
        let mut a = t.data.clone();
        let mut b = s.data.clone();
        a.sort_by(f32::total_cmp);
        b.sort_by(f32::total_cmp);
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic]
    fn tensor_shape_mismatch_panics() {
        Tensor::new(vec![2, 2], vec![0.0; 5]);
    }

    // ---------------------------------------------------------------------
    // simulated backend invariants

    #[test]
    fn sim_runtime_loads_and_runs() {
        let rt = Runtime::simulated();
        assert!(rt.is_simulated());
        assert!(rt.platform().contains("cpu"));
        let exe = rt.load("fire_full").expect("load");
        let inputs = rt.synth_inputs("fire_full", 0).unwrap();
        let outs = exe.run(&inputs).expect("run");
        assert_eq!(outs.len(), 1);
        assert_eq!(outs[0].shape, vec![1, 56, 56, 128]);
        assert!(outs[0].data.iter().all(|v| v.is_finite()));
        assert!(outs[0].data.iter().any(|&v| v != 0.0));
    }

    #[test]
    fn sim_execution_is_deterministic_and_input_sensitive() {
        let rt = Runtime::simulated();
        let exe = rt.load("fire_full").unwrap();
        let a = rt.synth_inputs("fire_full", 7).unwrap();
        let x = exe.run(&a).unwrap();
        let y = exe.run(&a).unwrap();
        assert_eq!(x[0].max_abs_diff(&y[0]), 0.0, "same inputs, same outputs");
        let b = rt.synth_inputs("fire_full", 8).unwrap();
        let z = exe.run(&b).unwrap();
        assert!(x[0].max_abs_diff(&z[0]) > 0.0, "different inputs must differ");
    }

    #[test]
    fn sim_prepared_literals_match_tensor_path() {
        // the serving hot path (pre-converted weights) must agree with run()
        let rt = Runtime::simulated();
        let exe = rt.load("fire_full").unwrap();
        let inputs = rt.synth_inputs("fire_full", 3).unwrap();
        let via_run = exe.run(&inputs).unwrap();
        let weights = exe.prepare(&inputs[1..], 1).unwrap();
        let input_lit = exe.prepare(&inputs[..1], 0).unwrap();
        let mut refs: Vec<&Literal> = vec![&input_lit[0]];
        refs.extend(weights.iter());
        let via_lits = exe.run_literals(&refs).unwrap();
        assert_eq!(via_run[0].max_abs_diff(&via_lits[0]), 0.0);
    }

    #[test]
    fn sim_wrong_arity_and_shape_rejected() {
        let rt = Runtime::simulated();
        let exe = rt.load("conv3x3").unwrap();
        let inputs = rt.synth_inputs("conv3x3", 1).unwrap();
        assert!(exe.run(&inputs[..1]).is_err());
        let mut bad = inputs.clone();
        bad[0] = Tensor::zeros(&[1, 28, 28, 16]);
        assert!(matches!(exe.run(&bad), Err(RuntimeError::ShapeMismatch { .. })));
    }

    #[test]
    fn sim_unknown_artifact_errors() {
        let rt = Runtime::simulated();
        assert!(rt.load("no_such_artifact").is_err());
    }

    #[test]
    fn sim_cache_returns_same_instance() {
        let rt = Runtime::simulated();
        let a = rt.load("pwconv_relu").unwrap();
        let b = rt.load("pwconv_relu").unwrap();
        assert!(Rc::ptr_eq(&a, &b));
    }

    #[test]
    fn sim_multi_output_artifact() {
        let rt = Runtime::simulated();
        let exe = rt.load("fire_gpu").unwrap();
        let inputs = rt.synth_inputs("fire_gpu", 2).unwrap();
        let outs = exe.run(&inputs).unwrap();
        assert_eq!(outs.len(), 2);
        assert_eq!(outs[0].shape, vec![1, 56, 56, 16]);
        assert_eq!(outs[1].shape, vec![1, 56, 56, 64]);
        assert!(outs[0].max_abs_diff(&Tensor::zeros(&outs[0].shape)) > 0.0);
    }

    #[test]
    fn literals_from_wrong_artifact_rejected() {
        // same arity, different geometry: must fail loudly, not synthesize
        let rt = Runtime::simulated();
        let a = rt.load("conv3x3").unwrap();
        let b = rt.load("pwconv_relu").unwrap();
        let lits = a.prepare(&rt.synth_inputs("conv3x3", 1).unwrap(), 0).unwrap();
        let refs: Vec<&Literal> = lits.iter().collect();
        assert!(matches!(b.run_literals(&refs), Err(RuntimeError::ShapeMismatch { .. })));
    }

    #[test]
    fn simulated_backend_is_not_real() {
        let rt = Runtime::simulated();
        assert!(!rt.has_real_backend());
    }

    #[test]
    fn literal_digest_is_content_addressed() {
        let a = Literal::from_tensor(Tensor::randn(&[2, 3], 1));
        let b = Literal::from_tensor(Tensor::randn(&[2, 3], 1));
        let c = Literal::from_tensor(Tensor::randn(&[2, 3], 2));
        assert_eq!(a.digest(), b.digest());
        assert_ne!(a.digest(), c.digest());
    }

    #[test]
    fn tensor_digest_matches_literal_digest() {
        // the batch path hashes tensors directly; it must agree with the
        // literal path bit-for-bit or batch results would diverge
        let t = Tensor::randn(&[3, 5], 11);
        let d = t.digest();
        assert_eq!(d, Literal::from_tensor(t).digest());
    }

    // ---------------------------------------------------------------------
    // batch seam

    #[test]
    fn run_batch_matches_independent_runs() {
        let rt = Runtime::simulated();
        for artifact in ["fire_full", "bottleneck_full", "conv3x3"] {
            let exe = rt.load(artifact).unwrap();
            let per_req: Vec<Vec<Tensor>> =
                (0..5).map(|s| rt.synth_inputs(artifact, 100 + s).unwrap()).collect();
            let refs: Vec<&[Tensor]> = per_req.iter().map(Vec::as_slice).collect();
            let batched = exe.run_batch(&refs).expect("run_batch");
            assert_eq!(batched.len(), 5);
            for (inputs, outs) in per_req.iter().zip(&batched) {
                let independent = exe.run(inputs).unwrap();
                assert_eq!(independent.len(), outs.len(), "{artifact}");
                for (a, b) in independent.iter().zip(outs) {
                    assert_eq!(a.max_abs_diff(b), 0.0, "{artifact}: batch != independent");
                }
            }
        }
    }

    #[test]
    fn run_batch_empty_is_empty() {
        let rt = Runtime::simulated();
        let exe = rt.load("fire_full").unwrap();
        assert!(exe.run_batch(&[]).unwrap().is_empty());
    }

    #[test]
    fn run_batch_rejects_any_bad_element() {
        // one malformed element fails the whole batch before any execution
        let rt = Runtime::simulated();
        let exe = rt.load("fire_full").unwrap();
        let good = rt.synth_inputs("fire_full", 1).unwrap();
        let mut bad = good.clone();
        bad[0] = Tensor::zeros(&[1, 28, 28, 96]);
        let batch: Vec<&[Tensor]> = vec![&good, &bad];
        assert!(matches!(exe.run_batch(&batch), Err(RuntimeError::ShapeMismatch { .. })));
        let short: Vec<&[Tensor]> = vec![&good, &good[..2]];
        assert!(matches!(exe.run_batch(&short), Err(RuntimeError::ArityMismatch { .. })));
    }

    #[test]
    fn run_literals_batch_matches_literal_path() {
        let rt = Runtime::simulated();
        let exe = rt.load("fire_full").unwrap();
        let inputs: Vec<Vec<Tensor>> =
            (0..3).map(|s| rt.synth_inputs("fire_full", 200 + s).unwrap()).collect();
        let lits: Vec<Vec<Literal>> =
            inputs.iter().map(|i| exe.prepare(i, 0).unwrap()).collect();
        let elements: Vec<Vec<&Literal>> =
            lits.iter().map(|l| l.iter().collect()).collect();
        let batched = exe.run_literals_batch(&elements).expect("batch");
        for (element, outs) in elements.iter().zip(&batched) {
            let single = exe.run_literals(element).unwrap();
            assert_eq!(single[0].max_abs_diff(&outs[0]), 0.0);
        }
    }

    #[test]
    fn error_codes_are_stable() {
        let shed = RuntimeError::Shed { projected_wait: std::time::Duration::from_millis(5) };
        assert_eq!(shed.code(), "shed");
        assert!(shed.to_string().contains("shed"), "{shed}");
        assert_eq!(RuntimeError::Serving("shutting down".into()).code(), "serving");
        assert_eq!(
            RuntimeError::UnknownModel { name: "x".into(), registered: vec![] }.code(),
            "unknown_model"
        );
        let budget =
            RuntimeError::BudgetExhausted { model: "fire".into(), in_flight: 4, budget: 4 };
        assert_eq!(budget.code(), "budget_exhausted");
        assert!(budget.to_string().contains("budget"), "{budget}");
        let retiring = RuntimeError::ModelRetiring { model: "fire".into() };
        assert_eq!(retiring.code(), "model_retiring");
        assert!(retiring.to_string().contains("retiring"), "{retiring}");
        let bad = RuntimeError::BadRequest("priority 7 undefined".into());
        assert_eq!(bad.code(), "bad_request");
        assert!(bad.to_string().contains("bad request"), "{bad}");
    }

    #[test]
    fn codes_const_covers_every_variant() {
        // samples of every variant; the exhaustive match in code() plus
        // this containment check keep CODES from drifting
        let samples = [
            RuntimeError::Serving("x".into()),
            RuntimeError::BadRequest("x".into()),
            RuntimeError::UnknownModel { name: "x".into(), registered: vec![] },
            RuntimeError::Shed { projected_wait: std::time::Duration::ZERO },
            RuntimeError::BudgetExhausted { model: "x".into(), in_flight: 1, budget: 1 },
            RuntimeError::ModelRetiring { model: "x".into() },
            RuntimeError::DeadlineExceeded {
                waited: std::time::Duration::ZERO,
                deadline: std::time::Duration::ZERO,
            },
            RuntimeError::ArityMismatch { name: "x".into(), expected: 1, got: 2 },
            RuntimeError::ShapeMismatch {
                name: "x".into(),
                index: 0,
                arg: "x".into(),
                expected: vec![1],
                got: vec![2],
            },
        ];
        for e in &samples {
            assert!(RuntimeError::CODES.contains(&e.code()), "{} missing from CODES", e.code());
        }
        // every code except `config` (whose variant wraps a ConfigError)
        // has a sample above
        assert_eq!(samples.len() + 1, RuntimeError::CODES.len());
    }

    #[test]
    fn synth_input_matches_full_set() {
        // the span-wise path must agree element-for-element with the
        // full-set path, or hetero lanes would fold different weights
        // than pool workers
        let rt = Runtime::simulated();
        let full = rt.synth_inputs("fire_full", 5).unwrap();
        for (i, t) in full.iter().enumerate() {
            assert_eq!(&rt.synth_input("fire_full", 5, i).unwrap(), t, "input {i}");
        }
        assert!(matches!(
            rt.synth_input("fire_full", 5, full.len()),
            Err(RuntimeError::ArityMismatch { .. })
        ));
    }

    // ---------------------------------------------------------------------
    // staged execution seam

    #[test]
    fn staged_fold_matches_monolithic_at_every_cut() {
        // splitting the input chain at ANY device boundary must be
        // bit-identical to the one-shot path — the hetero pipeline's
        // correctness rests on this
        let rt = Runtime::simulated();
        let exe = rt.load("fire_full").unwrap();
        let inputs = rt.synth_inputs("fire_full", 21).unwrap();
        let lits = exe.prepare(&inputs, 0).unwrap();
        let refs: Vec<&Literal> = lits.iter().collect();
        let mono = exe.run_literals(&refs).unwrap();
        for cut in 0..=refs.len() {
            let mut run = exe.stage_begin();
            exe.stage_fold(&mut run, &refs[..cut]).unwrap();
            assert_eq!(run.consumed(), cut);
            exe.stage_fold(&mut run, &refs[cut..]).unwrap();
            let staged = exe.stage_finish(run).unwrap();
            assert_eq!(staged.len(), mono.len());
            for (a, b) in staged.iter().zip(&mono) {
                assert_eq!(a, b, "cut {cut}: staged output differs");
            }
        }
    }

    #[test]
    fn staged_fold_validates_like_monolithic() {
        let rt = Runtime::simulated();
        let exe = rt.load("fire_full").unwrap();
        let inputs = rt.synth_inputs("fire_full", 1).unwrap();
        let lits = exe.prepare(&inputs, 0).unwrap();
        // wrong shape at position 1 is rejected at the fold, not finish
        let bad = Literal::from_tensor(Tensor::zeros(&[2, 2]));
        let mut run = exe.stage_begin();
        exe.stage_fold(&mut run, &[&lits[0]]).unwrap();
        assert!(matches!(
            exe.stage_fold(&mut run, &[&bad]),
            Err(RuntimeError::ShapeMismatch { .. })
        ));
        // finishing early is an arity error
        let mut run = exe.stage_begin();
        exe.stage_fold(&mut run, &[&lits[0]]).unwrap();
        assert!(matches!(exe.stage_finish(run), Err(RuntimeError::ArityMismatch { .. })));
        // folding past the manifest arity is rejected too
        let mut run = exe.stage_begin();
        let all: Vec<&Literal> = lits.iter().collect();
        exe.stage_fold(&mut run, &all).unwrap();
        assert!(matches!(
            exe.stage_fold(&mut run, &[&lits[0]]),
            Err(RuntimeError::ArityMismatch { .. })
        ));
    }

    #[test]
    fn literal_with_precomputed_digest_matches_hashing_path() {
        // the front door hashes once and the worker trusts that digest;
        // both constructions must agree or cached results would diverge
        let t = Tensor::randn(&[2, 7], 5);
        let d = t.digest();
        let a = Literal::from_tensor_with_digest(t.clone(), d);
        let b = Literal::from_tensor(t);
        assert_eq!(a.digest(), b.digest());
        assert_eq!(a.data, b.data);
    }
}
