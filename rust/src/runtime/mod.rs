//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! Wraps the `xla` crate (PJRT C API, CPU plugin): `PjRtClient::cpu()` ->
//! `HloModuleProto::from_text_file` -> `compile` -> `execute`. HLO *text* is
//! the interchange format — jax >= 0.5 emits protos with 64-bit instruction
//! ids that xla_extension 0.5.1 rejects; the text parser reassigns ids
//! (see /opt/xla-example/README.md and python/compile/aot.py).
//!
//! PJRT handles hold raw pointers (`!Send`), so a [`Runtime`] is pinned to
//! one thread; the [`crate::coordinator`] owns it on a dedicated executor
//! thread, vLLM-style. Compiled executables are cached per artifact name.
//!
//! All artifacts are lowered with `return_tuple=True`: outputs come back as
//! one tuple literal which [`Executable::run`] flattens to host [`Tensor`]s.

pub mod chain;

use crate::config::{ArtifactEntry, ConfigError, Manifest};
use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

/// A host-side f32 tensor (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Self { shape, data }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Self { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    /// Deterministic pseudo-normal tensor (Box-Muller over xorshift) —
    /// used to generate synthetic weights/inputs reproducibly.
    pub fn randn(shape: &[usize], seed: u64) -> Self {
        let n: usize = shape.iter().product();
        let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(1);
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            (state >> 11) as f64 / (1u64 << 53) as f64
        };
        let mut data = Vec::with_capacity(n);
        while data.len() < n {
            let (u1, u2): (f64, f64) = (next().max(1e-12), next());
            let r = (-2.0 * u1.ln()).sqrt();
            let th = 2.0 * std::f64::consts::PI * u2;
            data.push((r * th.cos()) as f32);
            if data.len() < n {
                data.push((r * th.sin()) as f32);
            }
        }
        Self { shape: shape.to_vec(), data }
    }

    pub fn elems(&self) -> usize {
        self.data.len()
    }

    /// Max absolute difference vs another tensor of identical shape.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape, "shape mismatch in comparison");
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0f32, |m, (a, b)| m.max((a - b).abs()))
    }

    /// Relative max-error vs a reference (for q8-vs-float comparisons).
    pub fn rel_error(&self, reference: &Tensor) -> f32 {
        let amax = reference.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        self.max_abs_diff(reference) / (amax + 1e-9)
    }

    /// Concatenate along the last (channel) axis — NHWC module joins.
    pub fn concat_last(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape.len(), other.shape.len());
        let d = self.shape.len() - 1;
        assert_eq!(self.shape[..d], other.shape[..d], "leading dims must match");
        let (ca, cb) = (self.shape[d], other.shape[d]);
        let rows = self.elems() / ca;
        let mut data = Vec::with_capacity(self.elems() + other.elems());
        for r in 0..rows {
            data.extend_from_slice(&self.data[r * ca..(r + 1) * ca]);
            data.extend_from_slice(&other.data[r * cb..(r + 1) * cb]);
        }
        let mut shape = self.shape.clone();
        shape[d] = ca + cb;
        Tensor::new(shape, data)
    }

    /// Slice channels [lo, hi) along the last axis.
    pub fn slice_last(&self, lo: usize, hi: usize) -> Tensor {
        let d = self.shape.len() - 1;
        let c = self.shape[d];
        assert!(lo < hi && hi <= c, "bad channel slice {lo}..{hi} of {c}");
        let rows = self.elems() / c;
        let mut data = Vec::with_capacity(rows * (hi - lo));
        for r in 0..rows {
            data.extend_from_slice(&self.data[r * c + lo..r * c + hi]);
        }
        let mut shape = self.shape.clone();
        shape[d] = hi - lo;
        Tensor::new(shape, data)
    }

    /// ShuffleNet channel shuffle over the last axis (G groups).
    pub fn channel_shuffle(&self, groups: usize) -> Tensor {
        let d = self.shape.len() - 1;
        let c = self.shape[d];
        assert_eq!(c % groups, 0);
        let cg = c / groups;
        let rows = self.elems() / c;
        let mut data = vec![0.0f32; self.elems()];
        for r in 0..rows {
            for g in 0..groups {
                for j in 0..cg {
                    data[r * c + j * groups + g] = self.data[r * c + g * cg + j];
                }
            }
        }
        Tensor::new(self.shape.clone(), data)
    }
}

/// Runtime errors.
#[derive(Debug, thiserror::Error)]
pub enum RuntimeError {
    #[error("config: {0}")]
    Config(#[from] ConfigError),
    #[error("xla: {0}")]
    Xla(#[from] xla::Error),
    #[error("artifact {name}: expected {expected} inputs, got {got}")]
    ArityMismatch { name: String, expected: usize, got: usize },
    #[error("artifact {name} input {index} ({arg}): expected shape {expected:?}, got {got:?}")]
    ShapeMismatch { name: String, index: usize, arg: String, expected: Vec<usize>, got: Vec<usize> },
}

/// A compiled artifact bound to the PJRT client.
pub struct Executable {
    pub name: String,
    pub entry: ArtifactEntry,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Convert host tensors to device literals, validating shapes against
    /// the manifest inputs starting at `offset`. Use this to prepare
    /// *invariant* inputs (weights) once and skip the per-request copy —
    /// the §Perf fix that removed the 5 MB/request weight memcpy from the
    /// serving hot path.
    pub fn prepare(&self, inputs: &[Tensor], offset: usize) -> Result<Vec<xla::Literal>, RuntimeError> {
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, t) in inputs.iter().enumerate() {
            let d = self.entry.inputs.get(offset + i).ok_or_else(|| {
                RuntimeError::ArityMismatch {
                    name: self.name.clone(),
                    expected: self.entry.inputs.len(),
                    got: offset + inputs.len(),
                }
            })?;
            if t.shape != d.shape {
                return Err(RuntimeError::ShapeMismatch {
                    name: self.name.clone(),
                    index: offset + i,
                    arg: d.name.clone(),
                    expected: d.shape.clone(),
                    got: t.shape.clone(),
                });
            }
            let dims: Vec<i64> = t.shape.iter().map(|&x| x as i64).collect();
            literals.push(xla::Literal::vec1(&t.data).reshape(&dims)?);
        }
        Ok(literals)
    }

    /// Execute with pre-converted literals (see [`Executable::prepare`]).
    pub fn run_literals(&self, literals: &[&xla::Literal]) -> Result<Vec<Tensor>, RuntimeError> {
        if literals.len() != self.entry.inputs.len() {
            return Err(RuntimeError::ArityMismatch {
                name: self.name.clone(),
                expected: self.entry.inputs.len(),
                got: literals.len(),
            });
        }
        let result = self.exe.execute::<&xla::Literal>(literals)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for (lit, desc) in parts.into_iter().zip(&self.entry.outputs) {
            out.push(Tensor::new(desc.shape.clone(), lit.to_vec::<f32>()?));
        }
        Ok(out)
    }

    /// Execute with host tensors; validates arity + shapes against the
    /// manifest, returns the flattened output tuple.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>, RuntimeError> {
        if inputs.len() != self.entry.inputs.len() {
            return Err(RuntimeError::ArityMismatch {
                name: self.name.clone(),
                expected: self.entry.inputs.len(),
                got: inputs.len(),
            });
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (t, d)) in inputs.iter().zip(&self.entry.inputs).enumerate() {
            if t.shape != d.shape {
                return Err(RuntimeError::ShapeMismatch {
                    name: self.name.clone(),
                    index: i,
                    arg: d.name.clone(),
                    expected: d.shape.clone(),
                    got: t.shape.clone(),
                });
            }
            let dims: Vec<i64> = t.shape.iter().map(|&x| x as i64).collect();
            literals.push(xla::Literal::vec1(&t.data).reshape(&dims)?);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for (lit, desc) in parts.into_iter().zip(&self.entry.outputs) {
            out.push(Tensor::new(desc.shape.clone(), lit.to_vec::<f32>()?));
        }
        Ok(out)
    }
}

/// PJRT CPU runtime with a per-artifact executable cache. `!Send` by
/// construction — pin to one thread (the coordinator's executor thread).
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
}

impl Runtime {
    /// CPU client + manifest discovery.
    pub fn new() -> Result<Self, RuntimeError> {
        let manifest = Manifest::load()?;
        Self::with_manifest(manifest)
    }

    pub fn with_manifest(manifest: Manifest) -> Result<Self, RuntimeError> {
        Ok(Self { client: xla::PjRtClient::cpu()?, manifest, cache: RefCell::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load (compile) an artifact; cached after the first call.
    pub fn load(&self, name: &str) -> Result<Rc<Executable>, RuntimeError> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let entry = self.manifest.entry(name)?.clone();
        let path = self.manifest.hlo_path(name)?;
        let proto = xla::HloModuleProto::from_text_file(path.to_str().expect("utf-8 path"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let e = Rc::new(Executable { name: name.to_string(), entry, exe });
        self.cache.borrow_mut().insert(name.to_string(), e.clone());
        Ok(e)
    }

    /// Generate manifest-shaped random inputs for an artifact (synthetic
    /// weights — DESIGN.md §2 substitution for ImageNet checkpoints).
    pub fn synth_inputs(&self, name: &str, seed: u64) -> Result<Vec<Tensor>, RuntimeError> {
        let entry = self.manifest.entry(name)?;
        Ok(entry
            .inputs
            .iter()
            .enumerate()
            .map(|(i, d)| {
                let mut t = Tensor::randn(&d.shape, seed.wrapping_add(i as u64 * 7919));
                // He-ish scaling for weights keeps activations in range
                let fan_in: usize = d.shape[..d.shape.len().saturating_sub(1)].iter().product();
                let scale = (2.0 / fan_in.max(1) as f32).sqrt();
                for v in &mut t.data {
                    *v *= scale;
                }
                t
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_randn_deterministic() {
        let a = Tensor::randn(&[4, 4], 42);
        let b = Tensor::randn(&[4, 4], 42);
        assert_eq!(a, b);
        let c = Tensor::randn(&[4, 4], 43);
        assert!(a.max_abs_diff(&c) > 0.0);
    }

    #[test]
    fn tensor_randn_is_roughly_normal() {
        let t = Tensor::randn(&[10_000], 7);
        let mean: f32 = t.data.iter().sum::<f32>() / 1e4;
        let var: f32 = t.data.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 1e4;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn concat_then_slice_roundtrip() {
        let a = Tensor::randn(&[1, 2, 2, 3], 1);
        let b = Tensor::randn(&[1, 2, 2, 5], 2);
        let c = a.concat_last(&b);
        assert_eq!(c.shape, vec![1, 2, 2, 8]);
        assert_eq!(c.slice_last(0, 3), a);
        assert_eq!(c.slice_last(3, 8), b);
    }

    #[test]
    fn channel_shuffle_matches_python_semantics() {
        // out[.., j*G + g] = in[.., g*(C/G) + j]
        let t = Tensor::new(vec![1, 1, 1, 6], vec![0., 1., 2., 3., 4., 5.]);
        let s = t.channel_shuffle(2);
        assert_eq!(s.data, vec![0., 3., 1., 4., 2., 5.]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let t = Tensor::randn(&[1, 3, 3, 8], 9);
        let s = t.channel_shuffle(2);
        let mut a = t.data.clone();
        let mut b = s.data.clone();
        a.sort_by(f32::total_cmp);
        b.sort_by(f32::total_cmp);
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic]
    fn tensor_shape_mismatch_panics() {
        Tensor::new(vec![2, 2], vec![0.0; 5]);
    }
}
