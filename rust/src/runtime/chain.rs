//! Module-chain executor: run SqueezeNet module-by-module through the
//! per-module AOT artifacts — the *functional emulation* of the paper's
//! heterogeneous execution.
//!
//! Two modes:
//!
//! - [`ChainExecutor::run_monolithic`]  — every module's `_full` artifact
//!   in sequence (the GPU-only dataflow).
//! - [`ChainExecutor::run_hetero`]      — Fire modules execute exactly the
//!   paper's Fig 2b split: the GPU artifact produces (squeeze OFM,
//!   expand1x1 OFM); the squeeze OFM crosses the "PCIe boundary" (int8
//!   quantize-dequantize via [`crate::quant`], as the real link would) to
//!   the FPGA artifact (8-bit DHM datapath or its float twin); the
//!   coordinator concatenates the OFMs. Everything else stays "on the
//!   GPU".
//!
//! The two modes consuming identical weights let integration tests assert
//! the end-to-end claim behind the whole paper: partitioning the network
//! across devices — including the 8-bit link and DHM arithmetic — leaves
//! the classification output intact up to quantization noise.

use super::{Runtime, RuntimeError, Tensor};
use crate::quant;

/// Which FPGA-side artifact flavor to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FpgaPrecision {
    /// 8-bit DHM datapath (`*_fpga` artifacts) + int8 link boundary.
    Int8,
    /// Float twin (`*_fpga_f32`), float link — exact-equality checks.
    F32,
}

/// The SqueezeNet chain layout (mirrors python/compile/aot.py tags).
const FIRES: [&str; 8] = [
    "sq_fire2", "sq_fire3", "sq_fire4", "sq_fire5",
    "sq_fire6", "sq_fire7", "sq_fire8", "sq_fire9",
];
/// Pools appear after these fire indices (fire4 and fire8).
const POOL_AFTER: [usize; 2] = [2, 6];

/// Executes the SqueezeNet module chain from per-module artifacts.
pub struct ChainExecutor<'rt> {
    rt: &'rt Runtime,
    /// Weights per fire module: (squeeze_w, expand1_w, expand3_w).
    fire_weights: Vec<[Tensor; 3]>,
    stem_w: Tensor,
    conv10_w: Tensor,
}

impl<'rt> ChainExecutor<'rt> {
    /// Synthesize one consistent weight set for the whole chain.
    pub fn new(rt: &'rt Runtime, seed: u64) -> Result<Self, RuntimeError> {
        let stem_inputs = rt.synth_inputs("sq_stem", seed)?;
        let mut fire_weights = Vec::with_capacity(FIRES.len());
        for (i, name) in FIRES.iter().enumerate() {
            let inputs = rt.synth_inputs(&format!("{name}_full"), seed.wrapping_add(i as u64 + 1))?;
            let [_, ws, we1, we3]: [Tensor; 4] =
                inputs.try_into().map_err(|_| RuntimeError::ArityMismatch {
                    name: name.to_string(),
                    expected: 4,
                    got: 0,
                })?;
            fire_weights.push([ws, we1, we3]);
        }
        let conv10_inputs = rt.synth_inputs("sq_conv10", seed.wrapping_add(100))?;
        Ok(Self {
            rt,
            fire_weights,
            stem_w: stem_inputs[1].clone(),
            conv10_w: conv10_inputs[1].clone(),
        })
    }

    /// Weights in the order the monolithic `squeezenet_224` artifact takes
    /// them (stem, 8 x fire triples, conv10) — for cross-checking against
    /// the single-artifact net.
    pub fn flat_weights(&self) -> Vec<Tensor> {
        let mut v = vec![self.stem_w.clone()];
        for [a, b, c] in &self.fire_weights {
            v.push(a.clone());
            v.push(b.clone());
            v.push(c.clone());
        }
        v.push(self.conv10_w.clone());
        v
    }

    fn run1(&self, artifact: &str, inputs: &[Tensor]) -> Result<Tensor, RuntimeError> {
        Ok(self.rt.load(artifact)?.run(inputs)?.remove(0))
    }

    /// The int8 PCIe boundary: symmetric per-tensor quantize-dequantize,
    /// exactly what the feature map suffers crossing to the FPGA.
    fn link_boundary(t: &Tensor) -> Tensor {
        let scale = quant::scale_for(&t.data);
        Tensor::new(t.shape.clone(), quant::fake_quant(&t.data, scale))
    }

    /// GPU-only dataflow: every module's `_full` artifact in sequence.
    pub fn run_monolithic(&self, x: &Tensor) -> Result<Tensor, RuntimeError> {
        let mut t = self.run1("sq_stem", &[x.clone(), self.stem_w.clone()])?;
        t = self.run1("sq_pool1", &[t])?;
        for (i, name) in FIRES.iter().enumerate() {
            let [ws, we1, we3] = &self.fire_weights[i];
            t = self.run1(
                &format!("{name}_full"),
                &[t, ws.clone(), we1.clone(), we3.clone()],
            )?;
            if POOL_AFTER.contains(&i) {
                t = self.run1(&format!("sq_pool{}", i + 2), &[t])?;
            }
        }
        t = self.run1("sq_conv10", &[t, self.conv10_w.clone()])?;
        self.run1("sq_gap", &[t])
    }

    /// Heterogeneous dataflow: Fire modules split per Fig 2b.
    pub fn run_hetero(&self, x: &Tensor, prec: FpgaPrecision) -> Result<Tensor, RuntimeError> {
        let mut t = self.run1("sq_stem", &[x.clone(), self.stem_w.clone()])?;
        t = self.run1("sq_pool1", &[t])?;
        for (i, name) in FIRES.iter().enumerate() {
            let [ws, we1, we3] = &self.fire_weights[i];
            // GPU side: squeeze + expand1x1
            let mut outs = self
                .rt
                .load(&format!("{name}_gpu"))?
                .run(&[t, ws.clone(), we1.clone()])?;
            let a = outs.remove(1);
            let s = outs.remove(0);
            // PCIe boundary + FPGA side: expand3x3
            let (artifact, s_linked) = match prec {
                FpgaPrecision::Int8 => (format!("{name}_fpga"), Self::link_boundary(&s)),
                FpgaPrecision::F32 => (format!("{name}_fpga_f32"), s),
            };
            let b = self.run1(&artifact, &[s_linked, we3.clone()])?;
            // back on the GPU: concat
            t = a.concat_last(&b);
            if POOL_AFTER.contains(&i) {
                t = self.run1(&format!("sq_pool{}", i + 2), &[t])?;
            }
        }
        t = self.run1("sq_conv10", &[t, self.conv10_w.clone()])?;
        self.run1("sq_gap", &[t])
    }
}

#[cfg(test)]
mod tests {
    // exercised by rust/tests/integration_chain.rs (needs artifacts)
}
