//! Simulated **online devices** for the heterogeneous executor: a GPU
//! lane, an FPGA lane and a PCIe link channel that *occupy real wall-clock
//! time* proportional to the paper's cost models.
//!
//! The offline stack already knows what each piece of work costs — the
//! [`crate::gpu::GpuModel`] roofline, the [`crate::dhm::DhmModel`]
//! pipeline model and the [`crate::link::LinkModel`] DMA model price every
//! plan step. These devices make those prices *bind at serving time*: a
//! stage's [`crate::metrics::Cost`] is served by busy-holding the lane for
//! `cost.seconds * time_scale` wall-clock seconds (a calibrated spin —
//! `thread::sleep` cannot hit the sub-millisecond scaled durations), so a
//! pipeline of lanes exhibits the same steady-state behaviour the analytic
//! model `sched::pipeline` predicts: throughput limited by the
//! busiest lane, other lanes idling in the slack.
//!
//! Naming note: [`crate::gpu::GpuDevice`] is the *parameter set* of the
//! offline cost model (peak FLOPs, bandwidth, power rails); this module's
//! [`GpuDevice`] is the *online lane* that spends the modeled time. Same
//! split as the FPGA ([`crate::dhm::FpgaDevice`] parameters vs this
//! [`FpgaDevice`] lane) and the link.
//!
//! Every service call lands in the shared [`HeteroMetrics`] counter set:
//! simulated busy seconds, wall-clock occupancy and active energy per
//! device, plus element/byte traffic on the link — the serve summary and
//! the `hotpath` hybrid-vs-GPU-only verdict read these.

use crate::metrics::device::HeteroMetrics;
use crate::metrics::Cost;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Default wall-clock seconds per simulated second (1/20 speed): a ~10 ms
/// simulated inference occupies its lanes for ~500 µs — long enough for
/// spin-wait precision and to dominate host-side per-image overheads
/// (queue hops, the input-digest hash), short enough that benches and
/// tests stay fast.
pub const DEFAULT_TIME_SCALE: f64 = 0.05;

/// Busy-hold the calling thread for `sim_seconds * time_scale` of wall
/// time; returns the wall time actually held.
fn occupy(sim_seconds: f64, time_scale: f64) -> Duration {
    if sim_seconds <= 0.0 || time_scale <= 0.0 {
        return Duration::ZERO;
    }
    let dur = Duration::from_secs_f64(sim_seconds * time_scale);
    let t0 = Instant::now();
    loop {
        let elapsed = t0.elapsed();
        if elapsed >= dur {
            return elapsed;
        }
        std::hint::spin_loop();
    }
}

/// Common behaviour of a simulated device lane.
pub trait Device {
    /// Lane name, as it appears in the serve summary.
    fn name(&self) -> &'static str;

    /// Service one unit of work priced at `cost`: hold the lane for the
    /// scaled duration and record it in the shared counters.
    fn service(&self, cost: Cost);
}

/// The online GPU lane (Jetson TX2 side of the board).
pub struct GpuDevice {
    metrics: Arc<HeteroMetrics>,
    time_scale: f64,
}

impl GpuDevice {
    /// Lane over the shared counter set at the given time scale.
    pub fn new(metrics: Arc<HeteroMetrics>, time_scale: f64) -> Self {
        Self { metrics, time_scale }
    }
}

impl Device for GpuDevice {
    fn name(&self) -> &'static str {
        "gpu"
    }

    fn service(&self, cost: Cost) {
        let wall = occupy(cost.seconds, self.time_scale);
        self.metrics.gpu.record(cost.seconds, wall, cost.joules);
    }
}

/// The online FPGA lane (Cyclone 10 GX DHM side of the board).
pub struct FpgaDevice {
    metrics: Arc<HeteroMetrics>,
    time_scale: f64,
}

impl FpgaDevice {
    /// Lane over the shared counter set at the given time scale.
    pub fn new(metrics: Arc<HeteroMetrics>, time_scale: f64) -> Self {
        Self { metrics, time_scale }
    }
}

impl Device for FpgaDevice {
    fn name(&self) -> &'static str {
        "fpga"
    }

    fn service(&self, cost: Cost) {
        let wall = occupy(cost.seconds, self.time_scale);
        self.metrics.fpga.record(cost.seconds, wall, cost.joules);
    }
}

/// The online PCIe link channel between the two boards.
pub struct LinkChannel {
    metrics: Arc<HeteroMetrics>,
    time_scale: f64,
}

impl LinkChannel {
    /// Channel over the shared counter set at the given time scale.
    pub fn new(metrics: Arc<HeteroMetrics>, time_scale: f64) -> Self {
        Self { metrics, time_scale }
    }

    /// One image's DMA traffic: `elems` feature-map elements occupying
    /// `bytes` on the wire, priced at `cost` (both directions summed by
    /// the caller). Holds the channel and records the traffic counters.
    pub fn dma(&self, elems: u64, bytes: u64, cost: Cost) {
        self.service(cost);
        self.metrics.record_transfer(elems, bytes);
    }
}

impl Device for LinkChannel {
    fn name(&self) -> &'static str {
        "link"
    }

    fn service(&self, cost: Cost) {
        let wall = occupy(cost.seconds, self.time_scale);
        self.metrics.link.record(cost.seconds, wall, cost.joules);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn occupy_holds_scaled_wall_time() {
        // 10 ms simulated at 1/100 scale -> >= 100 µs wall
        let wall = occupy(10e-3, 0.01);
        assert!(wall >= Duration::from_micros(100), "{wall:?}");
        assert_eq!(occupy(0.0, 0.01), Duration::ZERO);
        assert_eq!(occupy(1.0, 0.0), Duration::ZERO);
    }

    #[test]
    fn lanes_record_into_their_own_counters() {
        let m = Arc::new(HeteroMetrics::default());
        let gpu = GpuDevice::new(m.clone(), 0.001);
        let fpga = FpgaDevice::new(m.clone(), 0.001);
        let link = LinkChannel::new(m.clone(), 0.001);
        gpu.service(Cost::new(5e-3, 1e-3));
        fpga.service(Cost::new(3e-3, 2e-3));
        link.dma(1024, 1024, Cost::new(1e-3, 1e-4));
        assert_eq!(m.gpu.jobs(), 1);
        assert_eq!(m.fpga.jobs(), 1);
        assert_eq!(m.link.jobs(), 1);
        assert_eq!(m.transferred_elems(), 1024);
        assert_eq!(m.busiest().0, "gpu");
        assert!(m.gpu.wall_busy() >= Duration::from_micros(5));
        assert!((m.fpga.joules() - 2e-3).abs() < 1e-6);
    }
}
