//! Simulated **online devices** for the heterogeneous executor: a GPU
//! lane, an FPGA lane and a PCIe link channel that *occupy real wall-clock
//! time* proportional to the paper's cost models.
//!
//! The offline stack already knows what each piece of work costs — the
//! [`crate::gpu::GpuModel`] roofline, the [`crate::dhm::DhmModel`]
//! pipeline model and the [`crate::link::LinkModel`] DMA model price every
//! plan step. These devices make those prices *bind at serving time*: a
//! stage's [`crate::metrics::Cost`] is served by busy-holding the lane for
//! `cost.seconds * time_scale` wall-clock seconds (a calibrated spin —
//! `thread::sleep` cannot hit the sub-millisecond scaled durations), so a
//! pipeline of lanes exhibits the same steady-state behaviour the analytic
//! model `sched::pipeline` predicts: throughput limited by the
//! busiest lane, other lanes idling in the slack.
//!
//! Naming note: [`crate::gpu::GpuDevice`] is the *parameter set* of the
//! offline cost model (peak FLOPs, bandwidth, power rails); this module's
//! [`GpuDevice`] is the *online lane* that spends the modeled time. Same
//! split as the FPGA ([`crate::dhm::FpgaDevice`] parameters vs this
//! [`FpgaDevice`] lane) and the link.
//!
//! Every service call lands in the shared [`HeteroMetrics`] counter set:
//! simulated busy seconds, wall-clock occupancy and active energy per
//! device, plus element/byte traffic on the link — the serve summary and
//! the `hotpath` hybrid-vs-GPU-only verdict read these.
//!
//! **Private vs node-scoped lanes.** A lane built with `new` *owns* its
//! simulated silicon: holds never contend. A lane built with `shared`
//! instead acquires the node's one physical device through a
//! [`TenantLease`] on the [`crate::runtime::arbiter::DeviceSet`] before
//! every hold, so co-located models queue for the same GPU/FPGA/link.
//! Shared link holds are additionally priced by the node's analytic
//! [`crate::link::contention::BusModel`] from the actual bytes on the
//! wire — the contention model is the live seam, not a standalone
//! calculator. Timing never feeds the digest fold, so shared execution
//! stays bit-identical to private execution by construction.

use crate::metrics::device::HeteroMetrics;
use crate::metrics::Cost;
use crate::runtime::arbiter::{DeviceId, TenantLease};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Default wall-clock seconds per simulated second (1/20 speed): a ~10 ms
/// simulated inference occupies its lanes for ~500 µs — long enough for
/// spin-wait precision and to dominate host-side per-image overheads
/// (queue hops, the input-digest hash), short enough that benches and
/// tests stay fast.
pub const DEFAULT_TIME_SCALE: f64 = 0.05;

/// Busy-hold the calling thread for `sim_seconds * time_scale` of wall
/// time; returns the wall time actually held.
fn occupy(sim_seconds: f64, time_scale: f64) -> Duration {
    if sim_seconds <= 0.0 || time_scale <= 0.0 {
        return Duration::ZERO;
    }
    let dur = Duration::from_secs_f64(sim_seconds * time_scale);
    let t0 = Instant::now();
    loop {
        let elapsed = t0.elapsed();
        if elapsed >= dur {
            return elapsed;
        }
        std::hint::spin_loop();
    }
}

/// What one device hold actually cost: wall time queued for the grant
/// (zero on a private lane) and wall time the device was held.
///
/// The flight recorder ([`crate::obs`]) turns these into
/// `device_hold`/`device_release` span events. [`HoldStats::held_us`]
/// applies the **same** microsecond truncation
/// [`crate::metrics::device::ArbiterCounters::record_hold`] uses, so a
/// snapshot's per-device hold totals reconcile *exactly* against the
/// node's arbiter counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct HoldStats {
    /// Wall time spent queued for the grant (zero on private lanes).
    pub wait: Duration,
    /// Wall time the device was held.
    pub held: Duration,
}

impl HoldStats {
    /// Grant-queue wait, truncated to whole microseconds.
    pub fn wait_us(&self) -> u64 {
        self.wait.as_micros() as u64
    }

    /// Hold duration, truncated to whole microseconds — bit-for-bit the
    /// value `record_hold` adds to the node counters.
    pub fn held_us(&self) -> u64 {
        self.held.as_micros() as u64
    }
}

/// Hold the device for the scaled duration — arbitrated through the
/// node's grant queue when a lease is present, uncontended otherwise.
/// The hold's wall time is recorded into the node counters with the
/// same value (and truncation) the caller records into its own tenant
/// counters, keeping the cross-tenant accounting identity exact.
fn hold(
    lease: &Option<Arc<TenantLease>>,
    device: DeviceId,
    sim_seconds: f64,
    time_scale: f64,
) -> HoldStats {
    match lease {
        Some(lease) => {
            let queued = Instant::now();
            let grant = lease.acquire(device).expect("tenant lease outlives its lanes");
            let wait = queued.elapsed();
            let wall = occupy(sim_seconds, time_scale);
            lease.counters(device).record_hold(wall);
            drop(grant);
            HoldStats { wait, held: wall }
        }
        None => HoldStats { wait: Duration::ZERO, held: occupy(sim_seconds, time_scale) },
    }
}

/// Common behaviour of a simulated device lane.
pub trait Device {
    /// Lane name, as it appears in the serve summary.
    fn name(&self) -> &'static str;

    /// Service one unit of work priced at `cost`: hold the lane for the
    /// scaled duration and record it in the shared counters. Returns
    /// what the hold cost (grant wait + held wall time) so the flight
    /// recorder can span it; callers that don't trace ignore it.
    fn service(&self, cost: Cost) -> HoldStats;
}

/// The online GPU lane (Jetson TX2 side of the board).
pub struct GpuDevice {
    metrics: Arc<HeteroMetrics>,
    time_scale: f64,
    lease: Option<Arc<TenantLease>>,
}

impl GpuDevice {
    /// Private lane over the tenant counter set at the given time scale.
    pub fn new(metrics: Arc<HeteroMetrics>, time_scale: f64) -> Self {
        Self { metrics, time_scale, lease: None }
    }

    /// Node-scoped lane: every hold is acquired through `lease`'s
    /// shared-device grant queue.
    pub fn shared(metrics: Arc<HeteroMetrics>, time_scale: f64, lease: Arc<TenantLease>) -> Self {
        Self { metrics, time_scale, lease: Some(lease) }
    }
}

impl Device for GpuDevice {
    fn name(&self) -> &'static str {
        "gpu"
    }

    fn service(&self, cost: Cost) -> HoldStats {
        let hs = hold(&self.lease, DeviceId::Gpu, cost.seconds, self.time_scale);
        self.metrics.gpu.record(cost.seconds, hs.held, cost.joules);
        hs
    }
}

/// The online FPGA lane (Cyclone 10 GX DHM side of the board).
pub struct FpgaDevice {
    metrics: Arc<HeteroMetrics>,
    time_scale: f64,
    lease: Option<Arc<TenantLease>>,
}

impl FpgaDevice {
    /// Private lane over the tenant counter set at the given time scale.
    pub fn new(metrics: Arc<HeteroMetrics>, time_scale: f64) -> Self {
        Self { metrics, time_scale, lease: None }
    }

    /// Node-scoped lane: every hold is acquired through `lease`'s
    /// shared-device grant queue.
    pub fn shared(metrics: Arc<HeteroMetrics>, time_scale: f64, lease: Arc<TenantLease>) -> Self {
        Self { metrics, time_scale, lease: Some(lease) }
    }
}

impl Device for FpgaDevice {
    fn name(&self) -> &'static str {
        "fpga"
    }

    fn service(&self, cost: Cost) -> HoldStats {
        let hs = hold(&self.lease, DeviceId::Fpga, cost.seconds, self.time_scale);
        self.metrics.fpga.record(cost.seconds, hs.held, cost.joules);
        hs
    }
}

/// The online PCIe link channel between the two boards.
pub struct LinkChannel {
    metrics: Arc<HeteroMetrics>,
    time_scale: f64,
    lease: Option<Arc<TenantLease>>,
}

impl LinkChannel {
    /// Private channel over the tenant counter set at the given time scale.
    pub fn new(metrics: Arc<HeteroMetrics>, time_scale: f64) -> Self {
        Self { metrics, time_scale, lease: None }
    }

    /// Node-scoped channel: holds go through `lease`'s grant queue and
    /// are priced by the node's analytic bus model from the bytes on
    /// the wire.
    pub fn shared(metrics: Arc<HeteroMetrics>, time_scale: f64, lease: Arc<TenantLease>) -> Self {
        Self { metrics, time_scale, lease: Some(lease) }
    }

    /// One image's DMA traffic: `elems` feature-map elements occupying
    /// `bytes` on the wire, priced at `cost` (both directions summed by
    /// the caller). Holds the channel and records the traffic counters.
    ///
    /// A node-scoped channel ignores `cost.seconds` and instead prices
    /// the hold from `bytes` via
    /// [`crate::link::contention::BusModel::service_seconds`] — the
    /// contention model as the live seam (`cost.joules` still carries
    /// the plan's energy price).
    pub fn dma(&self, elems: u64, bytes: u64, cost: Cost) -> HoldStats {
        let seconds = match &self.lease {
            Some(lease) => lease.bus().service_seconds(bytes),
            None => cost.seconds,
        };
        let hs = hold(&self.lease, DeviceId::Link, seconds, self.time_scale);
        self.metrics.link.record(seconds, hs.held, cost.joules);
        self.metrics.record_transfer(elems, bytes);
        hs
    }
}

impl Device for LinkChannel {
    fn name(&self) -> &'static str {
        "link"
    }

    fn service(&self, cost: Cost) -> HoldStats {
        let hs = hold(&self.lease, DeviceId::Link, cost.seconds, self.time_scale);
        self.metrics.link.record(cost.seconds, hs.held, cost.joules);
        hs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn occupy_holds_scaled_wall_time() {
        // 10 ms simulated at 1/100 scale -> >= 100 µs wall
        let wall = occupy(10e-3, 0.01);
        assert!(wall >= Duration::from_micros(100), "{wall:?}");
        assert_eq!(occupy(0.0, 0.01), Duration::ZERO);
        assert_eq!(occupy(1.0, 0.0), Duration::ZERO);
    }

    #[test]
    fn lanes_record_into_their_own_counters() {
        let m = Arc::new(HeteroMetrics::default());
        let gpu = GpuDevice::new(m.clone(), 0.001);
        let fpga = FpgaDevice::new(m.clone(), 0.001);
        let link = LinkChannel::new(m.clone(), 0.001);
        gpu.service(Cost::new(5e-3, 1e-3));
        fpga.service(Cost::new(3e-3, 2e-3));
        link.dma(1024, 1024, Cost::new(1e-3, 1e-4));
        assert_eq!(m.gpu.jobs(), 1);
        assert_eq!(m.fpga.jobs(), 1);
        assert_eq!(m.link.jobs(), 1);
        assert_eq!(m.transferred_elems(), 1024);
        assert_eq!(m.busiest().0, "gpu");
        assert!(m.gpu.wall_busy() >= Duration::from_micros(5));
        assert!((m.fpga.joules() - 2e-3).abs() < 1e-6);
    }

    #[test]
    fn shared_link_hold_is_priced_by_the_analytic_bus_formula() {
        use crate::runtime::arbiter::DeviceSet;
        let set = Arc::new(DeviceSet::new());
        let lease = Arc::new(set.register_tenant());
        let m = Arc::new(HeteroMetrics::default());
        // time_scale 0 -> no wall spin; only the sim-seconds price matters
        let link = LinkChannel::shared(m.clone(), 0.0, lease.clone());
        let bytes = 64 * 1024u64;
        // the caller's cost.seconds is deliberately wrong: the node's
        // bus model must win
        link.dma(bytes, bytes, Cost::new(123.0, 1e-4));
        let want_us = (lease.bus().service_seconds(bytes) * 1e6) as u64;
        assert_eq!(m.link.sim_busy(), Duration::from_micros(want_us));
        assert_eq!(m.link.jobs(), 1);
        assert_eq!(set.metrics().link.grants(), 1);
    }

    #[test]
    fn shared_holds_reconcile_exactly_with_tenant_wall_time() {
        use crate::runtime::arbiter::DeviceSet;
        let set = Arc::new(DeviceSet::new());
        let mut tenants = Vec::new();
        let mut stats_held_us = 0u64;
        for _ in 0..2 {
            let lease = Arc::new(set.register_tenant());
            let m = Arc::new(HeteroMetrics::default());
            let gpu = GpuDevice::shared(m.clone(), 0.01, lease.clone());
            for _ in 0..3 {
                stats_held_us += gpu.service(Cost::new(2e-3, 0.0)).held_us();
            }
            tenants.push(m);
        }
        let node = set.metrics();
        let tenant_jobs: u64 = tenants.iter().map(|m| m.gpu.jobs()).sum();
        let tenant_wall_us: u128 =
            tenants.iter().map(|m| m.gpu.wall_busy().as_micros()).sum();
        assert_eq!(node.gpu.grants(), tenant_jobs);
        assert_eq!(node.gpu.holds().as_micros(), tenant_wall_us);
        // the flight-recorder identity: per-call HoldStats sum to the
        // node's arbiter hold total, microsecond for microsecond
        assert_eq!(u128::from(stats_held_us), node.gpu.holds().as_micros());
    }

    #[test]
    fn private_holds_report_zero_wait() {
        let m = Arc::new(HeteroMetrics::default());
        let gpu = GpuDevice::new(m, 0.001);
        let hs = gpu.service(Cost::new(5e-3, 0.0));
        assert_eq!(hs.wait, Duration::ZERO, "no lease, no grant queue");
        assert!(hs.held >= Duration::from_micros(5), "{hs:?}");
        assert_eq!(hs.held_us(), hs.held.as_micros() as u64);
    }
}
